//! Offline shim for the `anyhow` crate.
//!
//! The build environment for this repository has no registry access, so the
//! subset of the `anyhow` API the workspace uses is reimplemented here as a
//! path dependency: [`Error`], [`Result`], the [`Context`] extension trait
//! (for both `Result` and `Option`), typed-cause retention
//! ([`Error::new`] / [`Error::chain`] / [`Error::downcast_ref`]), and the
//! `anyhow!` / `bail!` / `ensure!` macros. Drop-in source compatibility
//! with real `anyhow` is the goal — swapping the path dependency for the
//! crates.io release must not require any code change.

use std::error::Error as StdError;
use std::fmt;

/// A context-carrying error: an outermost message plus a cause chain, and
/// — when built from a typed error value — the value itself, retained so
/// callers can [`downcast_ref`](Error::downcast_ref) it back out (the
/// collective fabric's `PeerDeath` recovery decisions depend on this).
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
    /// The typed root-cause value, when one was retained. Context layers
    /// stack *around* it without erasing it.
    typed: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message (no typed cause).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()], typed: None }
    }

    /// Build an error from a typed error value, retaining it for
    /// [`chain`](Error::chain) / [`downcast_ref`](Error::downcast_ref).
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain, typed: Some(Box::new(error)) }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterator over the retained typed cause and its sources, outermost
    /// first. Empty for message-only errors — exactly the errors that
    /// cannot hold a downcastable value.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: self.typed.as_deref().map(|e| e as &(dyn StdError + 'static)) }
    }

    /// Downcast against the retained typed cause chain.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        self.chain().find_map(|e| e.downcast_ref::<T>())
    }
}

/// Iterator returned by [`Error::chain`].
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                f.write_str(head)?;
                for cause in rest {
                    write!(f, "\n\nCaused by:\n    {cause}")?;
                }
                Ok(())
            }
        }
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent (and
// the identity `From<Error> for Error` available to `Context` below).
impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Self::new(e)
    }
}

/// `anyhow::Result<T>` — `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// Bound on `Into<Error>` (std errors via the blanket `From`, `Error`
// itself via the identity `From`) rather than `Display`, so contexting a
// `Result<_, Error>` stacks a layer without erasing the typed cause.
impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn context_layers_display_and_debug() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context_and_ensure() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        let ok: Option<u32> = Some(3);
        assert_eq!(ok.with_context(|| "never").unwrap(), 3);

        fn check(x: i32) -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            ensure!(x < 10);
            Ok(())
        }
        assert!(check(5).is_ok());
        assert!(check(-1).unwrap_err().to_string().contains("positive"));
        assert!(check(11).unwrap_err().to_string().contains("x < 10"));
    }

    #[test]
    fn std_errors_convert_with_source_chain() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
            Ok(s)
        }
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
        // the `?` conversion retains the typed io::Error
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }

    #[derive(Debug, PartialEq)]
    struct Marker(u32);

    impl fmt::Display for Marker {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "marker {}", self.0)
        }
    }

    impl StdError for Marker {}

    #[test]
    fn typed_cause_survives_context_layers() {
        let wrapped: Result<()> = Err(Error::new(Marker(7)));
        let e = wrapped.context("outer").with_context(|| "outermost").unwrap_err();
        assert_eq!(e.to_string(), "outermost: outer: marker 7");
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert_eq!(e.chain().count(), 1);
        assert!(e.chain().next().unwrap().downcast_ref::<Marker>().is_some());
        // message-only errors have nothing downcastable
        assert!(fails().unwrap_err().chain().next().is_none());
    }
}
