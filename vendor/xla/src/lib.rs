//! Compile-time **stub** of the `xla` PJRT bindings.
//!
//! The `pjrt` cargo feature of the `adama` crate must type-check on any
//! machine — including ones without the native `xla_extension` toolchain —
//! so this crate mirrors exactly the API surface `runtime::pjrt` uses.
//! Every runtime entry point returns [`Error`] with a clear message; to
//! actually execute AOT artifacts, patch the real bindings in at the
//! workspace level:
//!
//! ```toml
//! [patch."crates-io"]          # or a [patch] on this path dependency
//! xla = { path = "/path/to/real/xla-rs" }
//! ```

use std::fmt;

/// Error returned by every stubbed runtime entry point.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build links the stub `vendor/xla` crate; patch in the \
         real xla PJRT bindings to execute AOT artifacts"
    )))
}

/// XLA primitive types (subset used by the artifacts: f32 / s32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Element-type tags mirroring the real crate's `ElementType`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn primitive_type(self) -> PrimitiveType {
        match self {
            ElementType::F32 => PrimitiveType::F32,
            ElementType::S32 => PrimitiveType::S32,
        }
    }
}

/// Host element types storable in literals/buffers.
pub trait ArrayElement: Copy {
    const TY: ElementType;
    const ELEMENT_SIZE_IN_BYTES: usize;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    const ELEMENT_SIZE_IN_BYTES: usize = 4;
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    const ELEMENT_SIZE_IN_BYTES: usize = 4;
}

/// PJRT client handle (stub).
#[derive(Clone)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _priv: () }
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn create_from_shape(_ty: PrimitiveType, _dims: &[usize]) -> Literal {
        Literal { _priv: () }
    }

    pub fn copy_raw_from<T: ArrayElement>(&mut self, _src: &[T]) -> Result<()> {
        unavailable("Literal::copy_raw_from")
    }

    pub fn copy_raw_to<T: ArrayElement>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_with_guidance() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn element_types_map() {
        assert_eq!(ElementType::F32.primitive_type(), PrimitiveType::F32);
        assert_eq!(<i32 as ArrayElement>::ELEMENT_SIZE_IN_BYTES, 4);
    }
}
