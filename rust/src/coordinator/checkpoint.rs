//! Checkpoint cadence, rotation, and discovery — shared by the
//! single-rank [`super::Trainer`] and the distributed runners.
//!
//! Naming: a single-rank checkpoint is a file `step{N:08}.ck2` inside the
//! checkpoint directory; a world checkpoint is a *directory* `step{N:08}/`
//! (per-rank shard files + `world.ck2` manifest, see
//! [`crate::collective::ckpt`]). Rotation and latest-checkpoint discovery
//! handle both shapes.
//!
//! Env knobs (all strict-parsed — a malformed value is an error naming
//! the accepted forms, never a silent default):
//!
//! * `ADAMA_CKPT_DIR`   — checkpoint directory (created on first write)
//! * `ADAMA_CKPT_EVERY` — write every k steps (positive integer; unset
//!   disables checkpointing)
//! * `ADAMA_CKPT_KEEP`  — keep the newest n checkpoints (positive
//!   integer, default 2)

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// When to cut checkpoints and how many to retain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// Write a checkpoint after every `every_k_steps`-th step.
    pub every_k_steps: u64,
    /// Retain only the newest `keep_last_n` checkpoints (rotation).
    pub keep_last_n: usize,
}

impl CheckpointPolicy {
    /// Strict parse from the raw `ADAMA_CKPT_EVERY` / `ADAMA_CKPT_KEEP`
    /// strings. Unset/empty `every` disables checkpointing (`None`);
    /// `keep` without `every` is a configuration error, not dead state.
    pub fn parse(every: Option<&str>, keep: Option<&str>) -> Result<Option<Self>> {
        let every = match every.map(str::trim) {
            None | Some("") => {
                if let Some(k) = keep.map(str::trim) {
                    if !k.is_empty() {
                        bail!(
                            "ADAMA_CKPT_KEEP='{k}' is set but ADAMA_CKPT_EVERY is not — \
                             retention without a cadence does nothing; set ADAMA_CKPT_EVERY \
                             or unset ADAMA_CKPT_KEEP"
                        );
                    }
                }
                return Ok(None);
            }
            Some(s) => match s.parse::<u64>() {
                Ok(k) if k >= 1 => k,
                _ => bail!(
                    "invalid ADAMA_CKPT_EVERY='{s}': want a positive integer step cadence"
                ),
            },
        };
        let keep = match keep.map(str::trim) {
            None | Some("") => 2,
            Some(s) => match s.parse::<usize>() {
                Ok(n) if n >= 1 => n,
                _ => bail!(
                    "invalid ADAMA_CKPT_KEEP='{s}': want a positive integer checkpoint count"
                ),
            },
        };
        Ok(Some(Self { every_k_steps: every, keep_last_n: keep }))
    }

    pub fn from_env() -> Result<Option<Self>> {
        Self::parse(
            std::env::var("ADAMA_CKPT_EVERY").ok().as_deref(),
            std::env::var("ADAMA_CKPT_KEEP").ok().as_deref(),
        )
    }

    /// Is `step` a checkpoint boundary under this policy?
    pub fn due(&self, step: u64) -> bool {
        step > 0 && step % self.every_k_steps == 0
    }
}

/// `ADAMA_CKPT_DIR`, or `None` when unset/empty.
pub fn dir_from_env() -> Option<PathBuf> {
    match std::env::var("ADAMA_CKPT_DIR") {
        Ok(s) if !s.trim().is_empty() => Some(PathBuf::from(s)),
        _ => None,
    }
}

/// Resolve the full env checkpoint configuration: `Some((dir, policy))`
/// when checkpointing is on, `None` when off, an error when the knobs
/// contradict each other (a cadence without a directory, or vice versa a
/// malformed value).
pub fn from_env() -> Result<Option<(PathBuf, CheckpointPolicy)>> {
    let policy = CheckpointPolicy::from_env()?;
    let dir = dir_from_env();
    match (dir, policy) {
        (Some(d), Some(p)) => Ok(Some((d, p))),
        (None, Some(_)) => bail!(
            "ADAMA_CKPT_EVERY is set but ADAMA_CKPT_DIR is not — checkpoints need a \
             directory to land in"
        ),
        (_, None) => Ok(None),
    }
}

/// Canonical single-rank checkpoint file name for `step`.
pub fn step_file(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("step{step:08}.ck2"))
}

/// Canonical world-checkpoint directory name for `step`.
pub fn step_dir(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("step{step:08}"))
}

/// All checkpoint entries (files or world dirs) under `dir`, sorted by
/// step ascending. Non-matching names are ignored (the directory may hold
/// unrelated files); a `.tmp` straggler from a crashed write never
/// matches.
pub fn list_steps(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    for entry in rd {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let stem = name.strip_suffix(".ck2").unwrap_or(name);
        if let Some(num) = stem.strip_prefix("step") {
            if !num.is_empty() && num.bytes().all(|b| b.is_ascii_digit()) {
                if let Ok(step) = num.parse::<u64>() {
                    out.push((step, entry.path()));
                }
            }
        }
    }
    out.sort_by_key(|(s, _)| *s);
    Ok(out)
}

/// Delete all but the newest `keep` checkpoint entries under `dir`.
pub fn rotate(dir: &Path, keep: usize) -> Result<()> {
    let entries = list_steps(dir)?;
    if entries.len() <= keep {
        return Ok(());
    }
    for (_, path) in &entries[..entries.len() - keep] {
        let res = if path.is_dir() {
            std::fs::remove_dir_all(path)
        } else {
            std::fs::remove_file(path)
        };
        res.with_context(|| format!("rotating out {}", path.display()))?;
    }
    Ok(())
}

/// Newest checkpoint entry under `dir`, if any.
pub fn latest(dir: &Path) -> Result<Option<(u64, PathBuf)>> {
    Ok(list_steps(dir)?.into_iter().next_back())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_strict() {
        assert_eq!(CheckpointPolicy::parse(None, None).unwrap(), None);
        assert_eq!(CheckpointPolicy::parse(Some(""), None).unwrap(), None);
        assert_eq!(
            CheckpointPolicy::parse(Some("4"), None).unwrap(),
            Some(CheckpointPolicy { every_k_steps: 4, keep_last_n: 2 })
        );
        assert_eq!(
            CheckpointPolicy::parse(Some("1"), Some("5")).unwrap(),
            Some(CheckpointPolicy { every_k_steps: 1, keep_last_n: 5 })
        );
        for bad in ["0", "-1", "x", "2.5"] {
            assert!(CheckpointPolicy::parse(Some(bad), None).is_err(), "{bad}");
            assert!(CheckpointPolicy::parse(Some("2"), Some(bad)).is_err(), "{bad}");
        }
        // keep without a cadence is a configuration error, not dead state
        assert!(CheckpointPolicy::parse(None, Some("3")).is_err());
    }

    #[test]
    fn due_steps() {
        let p = CheckpointPolicy { every_k_steps: 3, keep_last_n: 1 };
        assert!(!p.due(0));
        assert!(!p.due(2));
        assert!(p.due(3));
        assert!(p.due(6));
    }

    #[test]
    fn list_rotate_latest() {
        let dir = std::env::temp_dir().join(format!("adama_rot_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for s in [1u64, 2, 3, 4] {
            std::fs::write(step_file(&dir, s), b"x").unwrap();
        }
        // a world-checkpoint dir and unrelated files mix in
        std::fs::create_dir_all(step_dir(&dir, 5)).unwrap();
        std::fs::write(dir.join("notes.txt"), b"y").unwrap();
        std::fs::write(dir.join("step0000000a.ck2"), b"y").unwrap();

        let steps: Vec<u64> = list_steps(&dir).unwrap().into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![1, 2, 3, 4, 5]);
        assert_eq!(latest(&dir).unwrap().unwrap().0, 5);

        rotate(&dir, 2).unwrap();
        let steps: Vec<u64> = list_steps(&dir).unwrap().into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![4, 5]);
        assert!(dir.join("notes.txt").exists(), "rotation must not touch unrelated files");
        std::fs::remove_dir_all(&dir).ok();
    }
}
