//! Per-step training metrics + CSV/JSON export for the bench harnesses.
//!
//! Besides the per-step loss/throughput log, [`Metrics`] carries the
//! latest [`MemorySnapshot`]: the coordinator-level tracker peaks
//! (weights/grads/states/activations) next to the executor-level
//! activation instrumentation ([`crate::runtime::MemStats`] — stash
//! arena + kernel workspace), so one object answers both "what did the
//! training loop hold" and "what did the backend hold".

use crate::memory::MemoryReport;
use crate::runtime::MemStats;
use crate::util::json::{obj, Json};

/// Coordinator + executor memory peaks, recorded once per train step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemorySnapshot {
    /// Category-exact peaks from the coordinator's `MemoryTracker`.
    pub tracker: MemoryReport,
    /// Backend activation instrumentation (None: backend not
    /// instrumented, e.g. PJRT).
    pub host: Option<MemStats>,
}

impl MemorySnapshot {
    /// Total measured activation bytes: tracker-level stashed block
    /// inputs plus backend-level stash arena.
    pub fn activation_peak_bytes(&self) -> u64 {
        self.tracker.peak_activations as u64
            + self.host.map(|m| m.stash_peak_bytes).unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("peak_weights", self.tracker.peak_weights.into()),
            ("peak_gradients", self.tracker.peak_gradients.into()),
            ("peak_optimizer", self.tracker.peak_optimizer.into()),
            ("peak_activations", self.tracker.peak_activations.into()),
            ("peak_workspace", self.tracker.peak_workspace.into()),
            ("peak_total", self.tracker.peak_total.into()),
        ];
        if let Some(m) = self.host {
            fields.push(("host_stash_peak", (m.stash_peak_bytes as usize).into()));
            fields.push(("host_stash_live", (m.stash_live_bytes as usize).into()));
            fields.push(("host_ws_peak", (m.workspace_peak_bytes as usize).into()));
            fields.push(("host_stash_hits", (m.stash_hits as usize).into()));
            fields.push(("host_remats", (m.remats as usize).into()));
            fields.push(("host_evictions", (m.stash_evictions as usize).into()));
            fields.push(("host_kv_peak", (m.kv_peak_bytes as usize).into()));
            fields.push(("host_kv_live", (m.kv_live_bytes as usize).into()));
        }
        obj(fields)
    }
}

/// Per-rank [`MemorySnapshot`]s from a distributed run, with the
/// world-level aggregations the coordinator reports: the field-wise
/// per-rank maximum (what a uniform cluster must provision per device —
/// the paper's Table-2/3 axis) and the summed tracker peak (the whole
/// cluster's footprint).
#[derive(Debug, Clone, Default)]
pub struct WorldMemory {
    /// One snapshot per rank, in rank order.
    pub ranks: Vec<MemorySnapshot>,
}

impl WorldMemory {
    pub fn new(ranks: Vec<MemorySnapshot>) -> Self {
        Self { ranks }
    }

    pub fn world(&self) -> usize {
        self.ranks.len()
    }

    /// Field-wise maximum across ranks. The host `stash_budget_bytes`
    /// (a configuration, not a peak) is carried from the first rank.
    pub fn max_per_rank(&self) -> Option<MemorySnapshot> {
        let mut it = self.ranks.iter().copied();
        let first = it.next()?;
        Some(it.fold(first, |a, b| MemorySnapshot {
            tracker: MemoryReport {
                peak_weights: a.tracker.peak_weights.max(b.tracker.peak_weights),
                peak_gradients: a.tracker.peak_gradients.max(b.tracker.peak_gradients),
                peak_optimizer: a.tracker.peak_optimizer.max(b.tracker.peak_optimizer),
                peak_activations: a.tracker.peak_activations.max(b.tracker.peak_activations),
                peak_workspace: a.tracker.peak_workspace.max(b.tracker.peak_workspace),
                peak_total: a.tracker.peak_total.max(b.tracker.peak_total),
            },
            host: match (a.host, b.host) {
                (Some(x), Some(y)) => Some(MemStats {
                    stash_budget_bytes: x.stash_budget_bytes,
                    stash_live_bytes: x.stash_live_bytes.max(y.stash_live_bytes),
                    stash_peak_bytes: x.stash_peak_bytes.max(y.stash_peak_bytes),
                    workspace_live_bytes: x.workspace_live_bytes.max(y.workspace_live_bytes),
                    workspace_peak_bytes: x.workspace_peak_bytes.max(y.workspace_peak_bytes),
                    stashed: x.stashed.max(y.stashed),
                    stash_hits: x.stash_hits.max(y.stash_hits),
                    stash_evictions: x.stash_evictions.max(y.stash_evictions),
                    remats: x.remats.max(y.remats),
                    kv_live_bytes: x.kv_live_bytes.max(y.kv_live_bytes),
                    kv_peak_bytes: x.kv_peak_bytes.max(y.kv_peak_bytes),
                }),
                (x, y) => x.or(y),
            },
        }))
    }

    /// Summed tracker `peak_total` across ranks — the whole-cluster
    /// coordinator footprint.
    pub fn total_peak_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.tracker.peak_total as u64).sum()
    }

    /// Largest per-rank activation peak (tracker + host stash arena).
    pub fn activation_peak_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.activation_peak_bytes()).max().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("world", self.world().into()),
            ("total_peak_bytes", (self.total_peak_bytes() as usize).into()),
        ];
        if let Some(mx) = self.max_per_rank() {
            fields.push(("max_per_rank", mx.to_json()));
        }
        fields.push(("ranks", Json::Arr(self.ranks.iter().map(|r| r.to_json()).collect())));
        obj(fields)
    }
}

#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    pub duration_s: f64,
    pub tokens: usize,
}

impl StepStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.duration_s > 0.0 {
            self.tokens as f64 / self.duration_s
        } else {
            0.0
        }
    }
}

/// Aggregate serving metrics from an inference run (`serve::Engine`):
/// per-request completion latencies plus generated-token throughput —
/// the tokens/s and p50/p99 rows the serving benches publish to
/// `BENCH_perf.json`. Latencies are whatever unit the caller records
/// (the synthetic load driver records wall seconds; deterministic tests
/// record scheduler steps).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    latencies: crate::util::stats::Summary,
    tokens: u64,
    wall_s: f64,
}

impl ServeStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request: its end-to-end latency and how many
    /// tokens it generated.
    pub fn record(&mut self, latency: f64, tokens: u64) {
        self.latencies.push(latency);
        self.tokens += tokens;
    }

    /// Set the total wall-clock of the serving run (throughput base).
    pub fn set_wall_seconds(&mut self, secs: f64) {
        self.wall_s = secs;
    }

    /// Completed requests.
    pub fn requests(&self) -> usize {
        self.latencies.n()
    }

    /// Generated tokens across all completed requests.
    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    /// Generated tokens per wall second (0 when no wall time recorded).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Median request latency.
    pub fn p50(&self) -> f64 {
        self.latencies.percentile(50.0)
    }

    /// 99th-percentile request latency.
    pub fn p99(&self) -> f64 {
        self.latencies.percentile(99.0)
    }

    pub fn mean_latency(&self) -> f64 {
        self.latencies.mean()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("requests", self.requests().into()),
            ("tokens", (self.tokens as usize).into()),
            ("tokens_per_sec", self.tokens_per_sec().into()),
            ("latency_p50", self.p50().into()),
            ("latency_p99", self.p99().into()),
        ])
    }
}

/// Append-only step log + the latest memory snapshot.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    steps: Vec<StepStats>,
    memory: Option<MemorySnapshot>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, s: StepStats) {
        self.steps.push(s);
    }

    /// Record the current memory peaks (overwrites — peaks are
    /// monotonic, so the latest snapshot is the step-wise maximum).
    pub fn set_memory(&mut self, m: MemorySnapshot) {
        self.memory = Some(m);
    }

    pub fn memory(&self) -> Option<&MemorySnapshot> {
        self.memory.as_ref()
    }

    pub fn steps(&self) -> &[StepStats] {
        &self.steps
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.steps.last().map(|s| s.loss)
    }

    /// Mean loss over the last `n` steps (smoother convergence signal).
    pub fn mean_loss_tail(&self, n: usize) -> f32 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32
    }

    /// Aggregate samples/s over the last `n` steps.
    pub fn throughput_tail(&self, n: usize) -> f64 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        let toks: usize = tail.iter().map(|s| s.tokens).sum();
        let secs: f64 = tail.iter().map(|s| s.duration_s).sum();
        if secs > 0.0 {
            toks as f64 / secs
        } else {
            0.0
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,lr,duration_s,tokens_per_sec\n");
        for st in &self.steps {
            s.push_str(&format!(
                "{},{:.6},{:.6e},{:.6},{:.1}\n",
                st.step, st.loss, st.lr, st.duration_s, st.tokens_per_sec()
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.steps
                .iter()
                .map(|s| {
                    obj(vec![
                        ("step", (s.step as usize).into()),
                        ("loss", (s.loss as f64).into()),
                        ("lr", (s.lr as f64).into()),
                        ("duration_s", s.duration_s.into()),
                    ])
                })
                .collect(),
        )
    }

    /// Steps + memory snapshot as one report object.
    pub fn to_json_full(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("steps", self.to_json())];
        if let Some(m) = &self.memory {
            fields.push(("memory", m.to_json()));
        }
        obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(step: u64, loss: f32, dur: f64, tokens: usize) -> StepStats {
        StepStats { step, loss, lr: 1e-3, duration_s: dur, tokens }
    }

    #[test]
    fn tail_means() {
        let mut m = Metrics::new();
        for i in 1..=10 {
            m.push(stat(i, i as f32, 0.1, 100));
        }
        assert_eq!(m.mean_loss_tail(2), 9.5);
        assert_eq!(m.last_loss(), Some(10.0));
        assert!((m.throughput_tail(10) - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = Metrics::new();
        m.push(stat(1, 2.0, 0.5, 50));
        let csv = m.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let mut m = Metrics::new();
        m.push(stat(1, 2.0, 0.5, 50));
        let j = m.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn world_memory_aggregates_per_rank_peaks() {
        let snap = |total: usize, grads: usize, stash: u64| MemorySnapshot {
            tracker: MemoryReport {
                peak_weights: 1,
                peak_gradients: grads,
                peak_optimizer: 2,
                peak_activations: 3,
                peak_workspace: 4,
                peak_total: total,
            },
            host: Some(MemStats { stash_peak_bytes: stash, ..MemStats::default() }),
        };
        let w = WorldMemory::new(vec![snap(100, 7, 10), snap(80, 9, 30)]);
        assert_eq!(w.world(), 2);
        assert_eq!(w.total_peak_bytes(), 180);
        let mx = w.max_per_rank().unwrap();
        assert_eq!(mx.tracker.peak_total, 100);
        assert_eq!(mx.tracker.peak_gradients, 9);
        assert_eq!(mx.host.unwrap().stash_peak_bytes, 30);
        // activation peak: tracker (3) + host stash arena (30) on rank 1
        assert_eq!(w.activation_peak_bytes(), 33);

        let j = w.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("world").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("total_peak_bytes").unwrap().as_usize().unwrap(), 180);
        let mx = parsed.get("max_per_rank").unwrap();
        assert_eq!(mx.get("peak_gradients").unwrap().as_usize().unwrap(), 9);
        assert_eq!(parsed.get("ranks").unwrap().as_arr().unwrap().len(), 2);

        assert!(WorldMemory::new(vec![]).max_per_rank().is_none());
        assert_eq!(WorldMemory::new(vec![]).activation_peak_bytes(), 0);
    }

    #[test]
    fn serve_stats_throughput_and_percentiles() {
        let mut s = ServeStats::new();
        for i in 1..=100 {
            s.record(i as f64, 4);
        }
        s.set_wall_seconds(2.0);
        assert_eq!(s.requests(), 100);
        assert_eq!(s.tokens(), 400);
        assert_eq!(s.tokens_per_sec(), 200.0);
        // Summary::percentile rounds the rank: idx 50 of the sorted 1..=100
        assert_eq!(s.p50(), 51.0);
        assert_eq!(s.p99(), 99.0);
        let j = s.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("requests").unwrap().as_usize().unwrap(), 100);
        // empty stats degrade to zeros, never NaN/panic
        let e = ServeStats::new();
        assert_eq!(e.tokens_per_sec(), 0.0);
        assert_eq!(e.p50(), 0.0);
    }

    #[test]
    fn memory_snapshot_surfaces_in_full_json() {
        let mut m = Metrics::new();
        m.push(stat(1, 2.0, 0.5, 50));
        let tracker = MemoryReport {
            peak_weights: 10,
            peak_gradients: 20,
            peak_optimizer: 30,
            peak_activations: 40,
            peak_workspace: 5,
            peak_total: 105,
        };
        let host = MemStats { stash_peak_bytes: 7, stash_hits: 3, ..MemStats::default() };
        let snap = MemorySnapshot { tracker, host: Some(host) };
        assert_eq!(snap.activation_peak_bytes(), 47);
        m.set_memory(snap);
        let j = m.to_json_full();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        let mem = parsed.get("memory").unwrap();
        assert_eq!(mem.get("peak_activations").unwrap().as_usize().unwrap(), 40);
        assert_eq!(mem.get("host_stash_peak").unwrap().as_usize().unwrap(), 7);
        assert_eq!(mem.get("host_stash_hits").unwrap().as_usize().unwrap(), 3);
    }
}
