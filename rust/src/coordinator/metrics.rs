//! Per-step training metrics + CSV/JSON export for the bench harnesses.

use crate::util::json::{obj, Json};

#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    pub duration_s: f64,
    pub tokens: usize,
}

impl StepStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.duration_s > 0.0 {
            self.tokens as f64 / self.duration_s
        } else {
            0.0
        }
    }
}

/// Append-only step log.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    steps: Vec<StepStats>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, s: StepStats) {
        self.steps.push(s);
    }

    pub fn steps(&self) -> &[StepStats] {
        &self.steps
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.steps.last().map(|s| s.loss)
    }

    /// Mean loss over the last `n` steps (smoother convergence signal).
    pub fn mean_loss_tail(&self, n: usize) -> f32 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32
    }

    /// Aggregate samples/s over the last `n` steps.
    pub fn throughput_tail(&self, n: usize) -> f64 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        let toks: usize = tail.iter().map(|s| s.tokens).sum();
        let secs: f64 = tail.iter().map(|s| s.duration_s).sum();
        if secs > 0.0 {
            toks as f64 / secs
        } else {
            0.0
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,lr,duration_s,tokens_per_sec\n");
        for st in &self.steps {
            s.push_str(&format!(
                "{},{:.6},{:.6e},{:.6},{:.1}\n",
                st.step, st.loss, st.lr, st.duration_s, st.tokens_per_sec()
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.steps
                .iter()
                .map(|s| {
                    obj(vec![
                        ("step", (s.step as usize).into()),
                        ("loss", (s.loss as f64).into()),
                        ("lr", (s.lr as f64).into()),
                        ("duration_s", s.duration_s.into()),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(step: u64, loss: f32, dur: f64, tokens: usize) -> StepStats {
        StepStats { step, loss, lr: 1e-3, duration_s: dur, tokens }
    }

    #[test]
    fn tail_means() {
        let mut m = Metrics::new();
        for i in 1..=10 {
            m.push(stat(i, i as f32, 0.1, 100));
        }
        assert_eq!(m.mean_loss_tail(2), 9.5);
        assert_eq!(m.last_loss(), Some(10.0));
        assert!((m.throughput_tail(10) - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = Metrics::new();
        m.push(stat(1, 2.0, 0.5, 50));
        let csv = m.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let mut m = Metrics::new();
        m.push(stat(1, 2.0, 0.5, 50));
        let j = m.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
    }
}
