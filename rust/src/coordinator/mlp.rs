//! MLP classifier trainer over the `mlp_*` artifacts — the paper's
//! convolution-model substitute (Fig. 3 / Fig. 7a parity experiments).
//!
//! Two release-granularity layers: `[W1, b1]` and `[W2, b2]`, driven
//! through the same [`Optimizer`] trait as the transformer, so every
//! optimizer (AdamA / AdamGA / Adafactor / SM3) runs unchanged.

use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::BlobBatch;
use crate::memory::{Category, MemoryTracker};
use crate::model::{LayerParams, ModelSpec, ParamView};
use crate::optim::{build_optimizer, Optimizer};
use crate::runtime::{lit_f32, lit_i32, scalar_f32, scalar_i32, Library, Program, Value};
use crate::tensor::Rng;

pub struct MlpTrainer {
    cfg: TrainConfig,
    pub hyper: crate::runtime::MlpHyper,
    spec: ModelSpec,
    params: Vec<LayerParams>,
    opt: Box<dyn Optimizer>,
    tracker: MemoryTracker,
    train_exe: Arc<dyn Program>,
    eval_exe: Arc<dyn Program>,
    step: u64,
}

/// Build a transformer-shaped `ModelSpec` for the MLP so the optimizer
/// trait (which works on layer specs) applies. Two layers + head markers
/// are faked with the embed/head grouping rules.
fn mlp_spec(h: &crate::runtime::MlpHyper) -> ModelSpec {
    use crate::runtime::{ModelConfigEntry, ModelHyper};
    let entry = ModelConfigEntry {
        model: ModelHyper {
            vocab: h.classes,
            hidden: h.hidden,
            layers: 1,
            heads: 1,
            seq: 1,
            microbatch: h.microbatch,
            ffn: h.hidden,
        },
        param_shapes: vec![
            ("embed.W1".into(), vec![h.features, h.hidden]),
            ("embed.b1".into(), vec![h.hidden]),
            ("block0.w2".into(), vec![h.hidden, h.classes]),
            ("block0.b2".into(), vec![h.classes]),
            ("head.unused".into(), vec![1]),
        ],
        artifacts: Default::default(),
    };
    ModelSpec::from_manifest("mlp", &entry).expect("mlp spec")
}

impl MlpTrainer {
    pub fn new(lib: Arc<Library>, cfg: TrainConfig) -> Result<Self> {
        let hyper = lib.manifest().mlp_config(&cfg.model)?.model.clone();
        let spec = mlp_spec(&hyper);
        let tracker = MemoryTracker::new();
        let mut rng = Rng::new(cfg.seed);
        // init: He-style for W1, small for W2, zero biases
        let params: Vec<LayerParams> = spec
            .layers
            .iter()
            .map(|l| {
                let mut flat = vec![0.0f32; l.flat_len];
                for p in &l.params {
                    if p.shape.len() == 2 {
                        let std = (2.0 / p.shape[0] as f32).sqrt() * 0.7;
                        for x in &mut flat[p.range.clone()] {
                            *x = std * rng.normal();
                        }
                    }
                }
                tracker.alloc_raw(Category::Weights, flat.len() * 4);
                LayerParams { flat }
            })
            .collect();
        let opt = build_optimizer(&cfg, &spec, &lib, &tracker)?;
        let train_exe = lib.get(&format!("mlp_{}/mlp_train", cfg.model))?;
        let eval_exe = lib.get(&format!("mlp_{}/mlp_eval", cfg.model))?;
        Ok(Self { cfg, hyper, spec, params, opt, tracker, train_exe, eval_exe, step: 0 })
    }

    pub fn tracker(&self) -> &MemoryTracker {
        &self.tracker
    }

    pub fn params(&self) -> &[LayerParams] {
        &self.params
    }

    fn view(&self, layer: usize, idx: usize) -> (&[f32], &ParamView) {
        let p = &self.spec.layers[layer].params[idx];
        (self.params[layer].view(p), p)
    }

    fn param_values(&self) -> Result<Vec<Value>> {
        let mut out = Vec::with_capacity(4);
        for (layer, idx) in [(0usize, 0usize), (0, 1), (1, 0), (1, 1)] {
            let (data, p) = self.view(layer, idx);
            out.push(lit_f32(data, &p.shape)?);
        }
        Ok(out)
    }

    /// One mini-batch step over `micro_batches`.
    pub fn train_step(&mut self, micro_batches: &[BlobBatch]) -> Result<f32> {
        let t = self.step + 1;
        let gscale = 1.0 / micro_batches.len() as f32;
        self.opt.begin_minibatch(t)?;
        let mut loss_sum = 0.0f64;
        for mb in micro_batches {
            let mut args = vec![
                lit_f32(&mb.x, &[mb.batch, self.hyper.features])?,
                lit_i32(&mb.y, &[mb.batch])?,
            ];
            args.extend(self.param_values()?);
            let out = self.train_exe.run_v(&args)?;
            loss_sum += scalar_f32(&out[0])? as f64;
            // (dW1, db1) -> layer 0 flat; (dW2, db2) -> layer 1 flat
            for (layer, lits) in [(0usize, &out[1..3]), (1, &out[3..5])] {
                let spec_l = &self.spec.layers[layer];
                let mut grad = vec![0.0f32; spec_l.flat_len];
                let _g = self.tracker.alloc(Category::Gradients, spec_l.flat_len * 4);
                for (p, lit) in spec_l.params.iter().zip(lits.iter()) {
                    crate::runtime::copy_into_f32(lit, &mut grad[p.range.clone()])?;
                }
                self.opt.accumulate(layer, &grad, gscale)?;
            }
        }
        let lr = self.cfg.lr.at(t);
        self.opt.apply(&mut self.params, lr)?;
        self.step = t;
        Ok((loss_sum / micro_batches.len() as f64) as f32)
    }

    /// (mean loss, accuracy) over held-out batches.
    pub fn eval(&self, batches: &[BlobBatch]) -> Result<(f32, f32)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let mut total = 0usize;
        for mb in batches {
            let mut args = vec![
                lit_f32(&mb.x, &[mb.batch, self.hyper.features])?,
                lit_i32(&mb.y, &[mb.batch])?,
            ];
            args.extend(self.param_values()?);
            let out = self.eval_exe.run_v(&args)?;
            loss_sum += scalar_f32(&out[0])? as f64;
            correct += scalar_i32(&out[1])? as usize;
            total += mb.batch;
        }
        Ok(((loss_sum / batches.len() as f64) as f32, correct as f32 / total as f32))
    }
}
