//! The training coordinator — the paper's Algorithm 2 as a rust event loop.
//!
//! [`Trainer`] drives mini-batch → micro-batch → layer loops over the AOT
//! artifacts: forward stashes each block's input activation (per-layer
//! remat protocol), backward walks the layers in reverse, and the moment a
//! layer's gradient materialises it is handed to a *gradient sink* and
//! **freed** — the release point that lets AdamA cap gradient memory at
//! one layer.  The default sink is the configured optimizer's
//! [`crate::optim::Optimizer::accumulate`]; distributed runners install
//! their own sinks (optimizer-state all-reduce, ZeRO reduce-scatter).
//!
//! Every buffer is registered with the [`MemoryTracker`], so the paper's
//! Figure-5/6 peak-memory claims are *measured*, not estimated.
//!
//! Activation accounting is two-level: the coordinator stashes each
//! block's **input** (the per-layer remat protocol, tracked here under
//! [`Category::Activations`]), while the host executor may additionally
//! stash full block **intermediates** under its `ADAMA_ACT_BUDGET`
//! arena — surfaced per step through [`MemorySnapshot`] so both levels
//! appear side by side in [`Metrics`].

pub mod checkpoint;
mod metrics;
pub mod mlp;

pub use checkpoint::CheckpointPolicy;
pub use metrics::{MemorySnapshot, Metrics, ServeStats, StepStats, WorldMemory};
pub use mlp::MlpTrainer;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::data::MicroBatch;
use crate::memory::{Category, MemoryTracker};
use crate::model::ckpt;
use crate::model::{init_params, LayerKind, LayerParams, ModelSpec};
use crate::optim::{build_optimizer, Optimizer};
use crate::runtime::{
    lit_f32, lit_i32, scalar_f32, scalar_i32, to_vec_f32, Library, Program, Value,
};

/// Per-layer gradient consumer — called the instant a layer's gradient
/// exists; the buffer is released when it returns.
pub type GradSink<'a> = dyn FnMut(usize, &[f32]) -> Result<()> + 'a;

/// Loaded model programs for one config (backend-neutral: pure-rust host
/// implementations or compiled PJRT artifacts, depending on the library).
struct ModelPrograms {
    embed_fwd: Arc<dyn Program>,
    embed_bwd: Arc<dyn Program>,
    block_fwd: Arc<dyn Program>,
    block_bwd: Arc<dyn Program>,
    head_loss: Arc<dyn Program>,
    head_eval: Arc<dyn Program>,
}

impl ModelPrograms {
    fn load(lib: &Library, config: &str) -> Result<Self> {
        let get = |n: &str| lib.get(&format!("{config}/{n}"));
        Ok(Self {
            embed_fwd: get("embed_fwd")?,
            embed_bwd: get("embed_bwd")?,
            block_fwd: get("block_fwd")?,
            block_bwd: get("block_bwd")?,
            head_loss: get("head_loss")?,
            head_eval: get("head_eval")?,
        })
    }
}

/// Model execution state (everything except the optimizer) — split out so
/// distributed sinks can borrow the optimizer mutably alongside it.
pub struct TrainerCore {
    lib: Arc<Library>,
    cfg: TrainConfig,
    spec: ModelSpec,
    params: Vec<LayerParams>,
    tracker: MemoryTracker,
    exe: ModelPrograms,
}

impl TrainerCore {
    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn tracker(&self) -> &MemoryTracker {
        &self.tracker
    }

    pub fn params(&self) -> &[LayerParams] {
        &self.params
    }

    pub fn params_mut(&mut self) -> &mut [LayerParams] {
        &mut self.params
    }

    pub fn library(&self) -> &Arc<Library> {
        &self.lib
    }

    /// Values for one layer's parameter tensors (artifact argument order).
    fn layer_values(&self, layer: usize) -> Result<Vec<Value>> {
        let spec_l = &self.spec.layers[layer];
        let flat = &self.params[layer];
        spec_l.params.iter().map(|p| lit_f32(flat.view(p), &p.shape)).collect()
    }

    /// Forward through embed + blocks. Returns the final hidden state and,
    /// if `stash` is set, every block's input activation (for backward).
    fn forward(
        &self,
        mb: &MicroBatch,
        stash: Option<&mut Vec<(Value, crate::memory::Allocation)>>,
    ) -> Result<Value> {
        let h = &self.spec.hyper;
        let tokens = lit_i32(&mb.tokens, &[mb.batch, mb.seq])?;
        let mut embed_args = vec![tokens];
        embed_args.extend(self.layer_values(0)?);
        let mut x = self
            .exe
            .embed_fwd
            .run_v(&embed_args)?
            .into_iter()
            .next()
            .context("embed_fwd output")?;
        let act_bytes = mb.batch * mb.seq * h.hidden * 4;
        let mut stash = stash;
        for (li, layer) in self.spec.layers.iter().enumerate() {
            if !matches!(layer.kind, LayerKind::Block(_)) {
                continue;
            }
            let mut args = vec![x.clone()];
            args.extend(self.layer_values(li)?);
            let y = self
                .exe
                .block_fwd
                .run_v(&args)?
                .into_iter()
                .next()
                .context("block_fwd output")?;
            if let Some(st) = stash.as_deref_mut() {
                let guard = self.tracker.alloc(Category::Activations, act_bytes);
                st.push((x, guard));
            }
            x = y;
        }
        Ok(x)
    }

    /// One micro-batch forward + layer-wise backward (Alg. 2 inner loop),
    /// streaming each layer gradient into `on_grad` and releasing it.
    /// Returns the micro-batch mean loss.
    pub fn run_microbatch(&self, mb: &MicroBatch, on_grad: &mut GradSink) -> Result<f32> {
        let head_idx = self.spec.layers.len() - 1;

        // ---- forward, stashing block inputs ----
        let mut stash: Vec<(Value, crate::memory::Allocation)> = Vec::new();
        let x_last = self.forward(mb, Some(&mut stash))?;

        // ---- head: fused loss fwd+bwd ----
        let labels = lit_i32(&mb.labels, &[mb.batch, mb.seq])?;
        let head_w = self.layer_values(head_idx)?;
        let mut args = vec![x_last];
        args.extend(head_w);
        args.push(labels);
        let out = self.exe.head_loss.run_v(&args)?;
        let loss = scalar_f32(&out[0])?;
        let mut dx = out[1].clone();
        {
            // head gradient: hand off and release immediately
            let dw = to_vec_f32(&out[2])?;
            let _g = self.tracker.alloc(Category::Gradients, dw.len() * 4);
            on_grad(head_idx, &dw)?;
        }
        drop(out);

        // ---- blocks in reverse: bwd, hand off, release ----
        for li in (0..self.spec.layers.len()).rev() {
            let layer = &self.spec.layers[li];
            if !matches!(layer.kind, LayerKind::Block(_)) {
                continue;
            }
            let (x_in, act_guard) = stash.pop().context("activation stash underflow")?;
            let mut args = vec![x_in, dx];
            args.extend(self.layer_values(li)?);
            let out = self.exe.block_bwd.run_v(&args)?;
            drop(act_guard); // activation consumed
            dx = out[0].clone();
            // flatten the 12 per-tensor grads into the layer's flat order
            let flat_len = layer.flat_len;
            let mut grad = vec![0.0f32; flat_len];
            let _g = self.tracker.alloc(Category::Gradients, flat_len * 4);
            for (p, lit) in layer.params.iter().zip(&out[1..]) {
                crate::runtime::copy_into_f32(lit, &mut grad[p.range.clone()])?;
            }
            on_grad(li, &grad)?;
            // grad + guard dropped here — the paper's release point
        }

        // ---- embedding ----
        let tokens = lit_i32(&mb.tokens, &[mb.batch, mb.seq])?;
        let out = self.exe.embed_bwd.run_v(&[tokens, dx])?;
        let embed_spec = &self.spec.layers[0];
        let mut grad = vec![0.0f32; embed_spec.flat_len];
        let _g = self.tracker.alloc(Category::Gradients, embed_spec.flat_len * 4);
        for (p, lit) in embed_spec.params.iter().zip(&out[..]) {
            crate::runtime::copy_into_f32(lit, &mut grad[p.range.clone()])?;
        }
        on_grad(0, &grad)?;
        Ok(loss)
    }

    /// Evaluate mean loss + token accuracy on held-out micro-batches.
    pub fn eval(&self, micro_batches: &[MicroBatch]) -> Result<(f32, f32)> {
        let head_idx = self.spec.layers.len() - 1;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let mut total = 0usize;
        for mb in micro_batches {
            let x = self.forward(mb, None)?;
            let labels = lit_i32(&mb.labels, &[mb.batch, mb.seq])?;
            let mut args = vec![x];
            args.extend(self.layer_values(head_idx)?);
            args.push(labels);
            let out = self.exe.head_eval.run_v(&args)?;
            loss_sum += scalar_f32(&out[0])? as f64;
            correct += scalar_i32(&out[1])? as usize;
            total += mb.batch * mb.seq;
            // eval is forward-only: any activation stash the executor
            // kept for this micro-batch will never be consumed by a
            // backward — release it immediately so eval phases don't
            // inflate the stash accounting (live or peak)
            self.lib.executor().clear_stash();
        }
        Ok((
            (loss_sum / micro_batches.len() as f64) as f32,
            correct as f32 / total as f32,
        ))
    }
}

/// Single-device training coordinator (optimizer-in-the-loop).
pub struct Trainer {
    core: TrainerCore,
    opt: Box<dyn Optimizer>,
    metrics: Metrics,
    step: u64,
}

impl Trainer {
    /// Build a trainer: resolve the model spec from the manifest, init
    /// parameters, construct the configured optimizer, compile artifacts.
    pub fn new(lib: Arc<Library>, cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let tracker = MemoryTracker::new();
        Self::with_tracker(lib, cfg, tracker)
    }

    /// As [`Trainer::new`] but sharing an external tracker (DP workers).
    pub fn with_tracker(
        lib: Arc<Library>,
        cfg: TrainConfig,
        tracker: MemoryTracker,
    ) -> Result<Self> {
        let entry = lib.manifest().model_config(&cfg.model)?.clone();
        let spec = ModelSpec::from_manifest(&cfg.model, &entry)?;
        let params = init_params(&spec, cfg.seed, &tracker);
        let opt = build_optimizer(&cfg, &spec, &lib, &tracker)?;
        let exe = ModelPrograms::load(&lib, &cfg.model)
            .with_context(|| format!("loading model artifacts for '{}'", cfg.model))?;
        let core = TrainerCore { lib, cfg, spec, params, tracker, exe };
        Ok(Self { core, opt, metrics: Metrics::new(), step: 0 })
    }

    /// Build with an externally-managed optimizer (e.g. [`crate::optim::NullOpt`]
    /// for ZeRO-S1 flows where state lives in shards outside the trainer).
    pub fn with_optimizer(
        lib: Arc<Library>,
        cfg: TrainConfig,
        tracker: MemoryTracker,
        opt: Box<dyn Optimizer>,
    ) -> Result<Self> {
        let entry = lib.manifest().model_config(&cfg.model)?.clone();
        let spec = ModelSpec::from_manifest(&cfg.model, &entry)?;
        let params = init_params(&spec, cfg.seed, &tracker);
        let exe = ModelPrograms::load(&lib, &cfg.model)
            .with_context(|| format!("loading model artifacts for '{}'", cfg.model))?;
        let core = TrainerCore { lib, cfg, spec, params, tracker, exe };
        Ok(Self { core, opt, metrics: Metrics::new(), step: 0 })
    }

    // ---- accessors (delegate to core) ----

    pub fn spec(&self) -> &ModelSpec {
        self.core.spec()
    }

    pub fn config(&self) -> &TrainConfig {
        self.core.config()
    }

    pub fn tracker(&self) -> &MemoryTracker {
        self.core.tracker()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    pub fn params(&self) -> &[LayerParams] {
        self.core.params()
    }

    pub fn params_mut(&mut self) -> &mut [LayerParams] {
        self.core.params_mut()
    }

    pub fn library(&self) -> &Arc<Library> {
        self.core.library()
    }

    pub fn core(&self) -> &TrainerCore {
        &self.core
    }

    pub fn optimizer_mut(&mut self) -> &mut dyn Optimizer {
        self.opt.as_mut()
    }

    /// Split borrow: model-execution core + optimizer, for distributed
    /// sinks that need both simultaneously.
    pub fn parts_mut(&mut self) -> (&mut TrainerCore, &mut dyn Optimizer) {
        (&mut self.core, self.opt.as_mut())
    }

    /// One full training step over `micro_batches` (one mini-batch).
    pub fn train_step(&mut self, micro_batches: &[MicroBatch]) -> Result<StepStats> {
        self.train_step_scaled(micro_batches, 1.0 / micro_batches.len() as f32)
    }

    /// As [`Self::train_step`] with an explicit gradient scale (Eq. 5-6:
    /// DP workers pass 1/N and let the all-reduce supply 1/M).
    pub fn train_step_scaled(
        &mut self,
        micro_batches: &[MicroBatch],
        gscale: f32,
    ) -> Result<StepStats> {
        let t0 = std::time::Instant::now();
        let loss = self.accumulate_minibatch(micro_batches, gscale)?;
        let stats = self.apply_update_timed(loss, micro_batches, t0)?;
        Ok(stats)
    }

    /// Backward-only phase: decay states, stream all micro-batch gradients
    /// into the optimizer. Distributed runners call this, synchronise
    /// states (Eq. 7-8), then [`Self::apply_update`].
    pub fn accumulate_minibatch(
        &mut self,
        micro_batches: &[MicroBatch],
        gscale: f32,
    ) -> Result<f32> {
        let t = self.step + 1;
        let (core, opt) = (&self.core, self.opt.as_mut());
        opt.begin_minibatch(t)?;
        let mut loss_sum = 0.0f64;
        for mb in micro_batches {
            let loss =
                core.run_microbatch(mb, &mut |layer, grad| opt.accumulate(layer, grad, gscale))?;
            loss_sum += loss as f64;
        }
        Ok((loss_sum / micro_batches.len() as f64) as f32)
    }

    /// Backward-only phase with a custom gradient sink (ZeRO flows).
    pub fn accumulate_minibatch_sink(
        &mut self,
        micro_batches: &[MicroBatch],
        sink: &mut GradSink,
    ) -> Result<f32> {
        let mut loss_sum = 0.0f64;
        for mb in micro_batches {
            loss_sum += self.core.run_microbatch(mb, sink)? as f64;
        }
        Ok((loss_sum / micro_batches.len() as f64) as f32)
    }

    /// Finish a step after external state synchronisation.
    pub fn apply_update(&mut self) -> Result<StepStats> {
        let t0 = std::time::Instant::now();
        self.apply_update_timed(f32::NAN, &[], t0)
    }

    fn apply_update_timed(
        &mut self,
        loss: f32,
        micro_batches: &[MicroBatch],
        t0: std::time::Instant,
    ) -> Result<StepStats> {
        let t = self.step + 1;
        let lr = self.core.cfg.lr.at(t);
        self.opt.apply(&mut self.core.params, lr)?;
        self.step = t;
        let tokens: usize = micro_batches.iter().map(|m| m.batch * m.seq).sum();
        let stats =
            StepStats { step: t, loss, lr, duration_s: t0.elapsed().as_secs_f64(), tokens };
        self.metrics.push(stats.clone());
        // surface coordinator + executor memory peaks alongside the step
        // log (peaks are monotonic: the latest snapshot is the maximum)
        self.metrics.set_memory(MemorySnapshot {
            tracker: self.core.tracker.report(),
            host: self.core.lib.executor().memory(),
        });
        Ok(stats)
    }

    /// Advance the step counter without an optimizer apply (ZeRO flows
    /// apply shard updates themselves).
    pub fn advance_step(&mut self) -> u64 {
        self.step += 1;
        self.step
    }

    pub fn eval(&self, micro_batches: &[MicroBatch]) -> Result<(f32, f32)> {
        self.core.eval(micro_batches)
    }

    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        crate::model::checkpoint::save(path, &self.core.spec, &self.core.params)
    }

    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        self.core.params = crate::model::checkpoint::load(path, &self.core.spec)?;
        Ok(())
    }

    // ---- full-state checkpointing (ADAMACK2) ----

    /// Set the step counter directly (resume flows that restore the
    /// optimizer/shard state externally).
    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    /// Snapshot the complete training state at the current step boundary:
    /// params, optimizer state, step, loss history, and the caller's data
    /// cursors (`data_rngs` — one [`crate::tensor::Rng`] per corpus
    /// stream feeding this trainer).
    pub fn train_state(&self, data_rngs: &[crate::tensor::Rng]) -> Result<ckpt::TrainState> {
        let opt = self.opt.export_state()?;
        let fingerprint = ckpt::config_fingerprint(&self.core.spec, &self.core.cfg, &opt.tag);
        Ok(ckpt::TrainState {
            fingerprint,
            step: self.step,
            params: self.core.params.iter().map(|p| p.flat.clone()).collect(),
            opt,
            rngs: data_rngs.to_vec(),
            losses: self.metrics.steps().iter().map(|s| s.loss).collect(),
        })
    }

    /// Write the complete training state to `path` (atomic `ADAMACK2`).
    pub fn save_state(&self, path: &std::path::Path, data_rngs: &[crate::tensor::Rng]) -> Result<()> {
        self.train_state(data_rngs)?.save(path)
    }

    /// Restore a full-state snapshot in place. The config fingerprint must
    /// match this trainer's model/config/optimizer — a checkpoint can
    /// never be replayed against a different run shape. Buffers are copied
    /// in place, so memory metering is untouched and a later step's peaks
    /// equal an uninterrupted run's.
    pub fn restore_state(&mut self, st: &ckpt::TrainState) -> Result<()> {
        let want = ckpt::config_fingerprint(&self.core.spec, &self.core.cfg, &st.opt.tag);
        if st.fingerprint != want {
            anyhow::bail!(
                "checkpoint fingerprint {:#018x} does not match this run's {:#018x} — \
                 the file was written under a different model/config/optimizer",
                st.fingerprint,
                want
            );
        }
        if st.params.len() != self.core.params.len() {
            anyhow::bail!(
                "checkpoint has {} param layers, model wants {}",
                st.params.len(),
                self.core.params.len()
            );
        }
        for (l, (dst, src)) in self.core.params.iter_mut().zip(&st.params).enumerate() {
            if dst.flat.len() != src.len() {
                anyhow::bail!(
                    "checkpoint layer '{}' (#{l}) has {} params, model wants {}",
                    self.core.spec.layers[l].name,
                    src.len(),
                    dst.flat.len()
                );
            }
            dst.flat.copy_from_slice(src);
        }
        self.opt.import_state(&st.opt)?;
        self.step = st.step;
        if st.losses.len() as u64 != st.step {
            anyhow::bail!(
                "checkpoint records {} losses for step {} — the loss history must cover \
                 every step",
                st.losses.len(),
                st.step
            );
        }
        // rebuild the metrics log (durations are wall-clock, not part of
        // the bit-exactness contract — restored as 0)
        self.metrics = Metrics::new();
        for (i, &loss) in st.losses.iter().enumerate() {
            let step = i as u64 + 1;
            let lr = self.core.cfg.lr.at(step);
            self.metrics.push(StepStats { step, loss, lr, duration_s: 0.0, tokens: 0 });
        }
        Ok(())
    }

    /// Build a trainer and restore it from an `ADAMACK2` file in one move.
    /// Returns the trainer plus the checkpointed data cursors (in the
    /// order they were passed to [`Trainer::save_state`]).
    pub fn resume(
        lib: Arc<Library>,
        cfg: TrainConfig,
        path: &std::path::Path,
    ) -> Result<(Self, Vec<crate::tensor::Rng>)> {
        let st = ckpt::TrainState::load(path)?;
        let mut trainer = Self::new(lib, cfg)?;
        trainer.restore_state(&st)?;
        Ok((trainer, st.rngs))
    }

    /// Drive the checkpoint rotation: if `policy` says the current step is
    /// a boundary, write `dir/step{N:08}.ck2` and delete checkpoints
    /// beyond `keep_last_n`. Returns the written path when one was cut.
    pub fn maybe_checkpoint(
        &self,
        dir: &std::path::Path,
        policy: &CheckpointPolicy,
        data_rngs: &[crate::tensor::Rng],
    ) -> Result<Option<std::path::PathBuf>> {
        if !policy.due(self.step) {
            return Ok(None);
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let path = checkpoint::step_file(dir, self.step);
        self.save_state(&path, data_rngs)?;
        checkpoint::rotate(dir, policy.keep_last_n)?;
        Ok(Some(path))
    }
}
