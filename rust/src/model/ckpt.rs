//! `ADAMACK2` — the versioned full-training-state checkpoint container.
//!
//! The paper's trick (folding micro-batch gradients straight into the
//! optimizer accumulator) makes the optimizer state *live* training state:
//! a params-only file (the legacy `ADAMACK1` in [`super::checkpoint`]) is
//! not a checkpoint at all. `ADAMACK2` therefore captures everything the
//! bit-reproducibility contract needs to resume a run as if it had never
//! stopped: params, optimizer/zoo state buffers, the step counter, every
//! RNG data cursor, the loss history, and a config fingerprint covering
//! `ModelSpec`/`TrainConfig`/opt algo so a file can never be replayed
//! against a different run shape.
//!
//! ## Wire format
//!
//! ```text
//! magic   "ADAMACK2"                     (8 bytes)
//! count   u64 LE                         number of sections
//! section tag      [u8; 8] ASCII, space-padded
//!         len      u64 LE                payload byte length
//!         payload  [u8; len]
//!         hash     u64 LE                FNV-1a 64 of the payload
//! ...     (exactly `count` sections, then EOF — trailing bytes are an error)
//! ```
//!
//! Every read is strict: wrong magic names the version it understands,
//! truncation reports the byte offset where the file ran out, a flipped
//! bit anywhere in a payload fails that section's FNV-1a hash, and bytes
//! after the last section are rejected. Writes are atomic: the encoded
//! file goes to `<path>.tmp` first and is `rename`d over the canonical
//! path only once fully written and synced, so a crash mid-write can
//! never leave a half-checkpoint behind.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::ModelSpec;
use crate::config::TrainConfig;
use crate::tensor::Rng;

pub const MAGIC: &[u8; 8] = b"ADAMACK2";

/// FNV-1a 64-bit — the per-section integrity hash. Dependency-free and
/// byte-order independent; collisions are irrelevant here (we detect
/// corruption, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Atomically publish `bytes` at `path`: write + sync `<path>.tmp`, then
/// rename over the canonical name. Shared with the legacy `ADAMACK1`
/// writer so *no* checkpoint path can leave a truncated canonical file.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp_os = path.as_os_str().to_owned();
    tmp_os.push(".tmp");
    let tmp = PathBuf::from(tmp_os);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
}

/// One tagged, hashed payload inside the container.
#[derive(Debug, Clone)]
pub struct Section {
    pub tag: String,
    pub payload: Vec<u8>,
}

/// A parsed (or under-construction) `ADAMACK2` container.
#[derive(Debug, Clone, Default)]
pub struct Container {
    sections: Vec<Section>,
}

impl Container {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, tag: &str, payload: Vec<u8>) {
        debug_assert!(tag.len() <= 8 && tag.is_ascii());
        self.sections.push(Section { tag: tag.to_string(), payload });
    }

    pub fn get(&self, tag: &str) -> Result<&[u8]> {
        self.try_get(tag)
            .with_context(|| format!("checkpoint is missing the '{tag}' section"))
    }

    pub fn try_get(&self, tag: &str) -> Option<&[u8]> {
        self.sections.iter().find(|s| s.tag == tag).map(|s| s.payload.as_slice())
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.sections.len() as u64).to_le_bytes());
        for s in &self.sections {
            let mut tag8 = [b' '; 8];
            tag8[..s.tag.len()].copy_from_slice(s.tag.as_bytes());
            out.extend_from_slice(&tag8);
            out.extend_from_slice(&(s.payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&s.payload);
            out.extend_from_slice(&fnv1a64(&s.payload).to_le_bytes());
        }
        out
    }

    /// Encode and atomically publish at `path`.
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.encode())
    }

    /// Strict parse of an encoded container (see module docs for the
    /// failure taxonomy: magic/version, truncation offset, per-section
    /// hash, trailing garbage).
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let magic = r.take(8, "magic")?;
        if magic != MAGIC {
            bail!(
                "not an ADAMACK2 checkpoint (magic {:?}; this reader understands \
                 container version 2 only)",
                String::from_utf8_lossy(magic)
            );
        }
        let count = r.u64("section count")? as usize;
        let mut sections = Vec::with_capacity(count);
        for i in 0..count {
            let tag_start = r.offset();
            let tag8 = r.take(8, "section tag")?;
            let tag = String::from_utf8_lossy(tag8).trim_end().to_string();
            let len = r.u64("section length")? as usize;
            let payload = r
                .take(len, "section payload")
                .with_context(|| format!("section '{tag}' (#{i} at byte offset {tag_start})"))?
                .to_vec();
            let stored = r.u64("section hash")?;
            let computed = fnv1a64(&payload);
            if stored != computed {
                bail!(
                    "section '{tag}' (#{i} at byte offset {tag_start}) integrity hash \
                     mismatch: stored {stored:#018x}, computed {computed:#018x} — \
                     the checkpoint is corrupt"
                );
            }
            sections.push(Section { tag, payload });
        }
        if r.remaining() != 0 {
            bail!(
                "checkpoint has {} trailing byte(s) after the last section \
                 (at byte offset {}) — refusing a file that parses but was not \
                 written by this container",
                r.remaining(),
                r.offset()
            );
        }
        Ok(Self { sections })
    }

    pub fn read(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

/// Strict cursor over a byte slice: every under-read reports what was
/// wanted and the byte offset where the data ran out.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn offset(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated: wanted {n} byte(s) of {what} at byte offset {}, \
                 only {} left",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let b = self.take(n * 4, what)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn str(&mut self, what: &str) -> Result<String> {
        let n = self.u64(what)? as usize;
        let b = self.take(n, what)?;
        String::from_utf8(b.to_vec()).with_context(|| format!("{what}: invalid utf-8"))
    }
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// A serializable snapshot of an optimizer's complete mutable state:
/// an algorithm tag, the step counter, and the state buffers in a
/// deterministic (layer, tensor, buffer) order. Produced/consumed by
/// `Optimizer::{export_state, import_state}`.
#[derive(Debug, Clone, PartialEq)]
pub struct OptSnapshot {
    pub tag: String,
    pub t: u64,
    pub bufs: Vec<Vec<f32>>,
}

impl OptSnapshot {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_str(&mut out, &self.tag);
        put_u64(&mut out, self.t);
        put_u64(&mut out, self.bufs.len() as u64);
        for b in &self.bufs {
            put_u64(&mut out, b.len() as u64);
            put_f32s(&mut out, b);
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let s = Self::read_from(&mut r)?;
        if r.remaining() != 0 {
            bail!("optimizer snapshot has {} trailing byte(s)", r.remaining());
        }
        Ok(s)
    }

    pub fn read_from(r: &mut ByteReader<'_>) -> Result<Self> {
        let tag = r.str("optimizer tag")?;
        let t = r.u64("optimizer step")?;
        let n = r.u64("optimizer buffer count")? as usize;
        let mut bufs = Vec::with_capacity(n);
        for i in 0..n {
            let len = r.u64("optimizer buffer length")? as usize;
            bufs.push(r.f32s(len, &format!("optimizer buffer #{i}"))?);
        }
        Ok(Self { tag, t, bufs })
    }
}

/// Encode a set of RNG cursors (data streams, one per corpus).
pub fn encode_rngs(rngs: &[Rng]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, rngs.len() as u64);
    for rng in rngs {
        let (state, cached) = rng.state();
        put_u64(&mut out, state);
        match cached {
            Some(z) => {
                out.push(1);
                out.extend_from_slice(&z.to_le_bytes());
            }
            None => out.push(0),
        }
    }
    out
}

pub fn decode_rngs(bytes: &[u8]) -> Result<Vec<Rng>> {
    let mut r = ByteReader::new(bytes);
    let n = r.u64("rng count")? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let state = r.u64("rng state")?;
        let cached = match r.u8("rng cached-normal flag")? {
            0 => None,
            1 => {
                let b = r.take(4, "rng cached normal")?;
                Some(f32::from_le_bytes(b.try_into().unwrap()))
            }
            x => bail!("rng cached-normal flag must be 0|1, got {x}"),
        };
        out.push(Rng::from_state(state, cached));
    }
    if r.remaining() != 0 {
        bail!("rng section has {} trailing byte(s)", r.remaining());
    }
    Ok(out)
}

/// Encode per-layer flat f32 buffers (params, or any layer-shaped state).
pub fn encode_layers(layers: &[Vec<f32>]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, layers.len() as u64);
    for l in layers {
        put_u64(&mut out, l.len() as u64);
        put_f32s(&mut out, l);
    }
    out
}

pub fn decode_layers(bytes: &[u8]) -> Result<Vec<Vec<f32>>> {
    let mut r = ByteReader::new(bytes);
    let n = r.u64("layer count")? as usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let len = r.u64("layer length")? as usize;
        out.push(r.f32s(len, &format!("layer #{i}"))?);
    }
    if r.remaining() != 0 {
        bail!("layer section has {} trailing byte(s)", r.remaining());
    }
    Ok(out)
}

pub fn encode_f32s(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, xs.len() as u64);
    put_f32s(&mut out, xs);
    out
}

pub fn decode_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    let mut r = ByteReader::new(bytes);
    let n = r.u64("f32 count")? as usize;
    let out = r.f32s(n, "f32 payload")?;
    if r.remaining() != 0 {
        bail!("f32 section has {} trailing byte(s)", r.remaining());
    }
    Ok(out)
}

/// FNV-1a fingerprint of everything that shapes the *math* of a run:
/// the model's layer graph, the optimizer algorithm, and the `TrainConfig`
/// knobs that alter the update sequence. Deliberately excludes world size
/// (resharding is allowed), step count (resume extends runs), threads /
/// SIMD / chunk (bit-invariant perf knobs by contract).
pub fn config_fingerprint(spec: &ModelSpec, cfg: &TrainConfig, opt_tag: &str) -> u64 {
    let mut canon = String::new();
    canon.push_str("model=");
    canon.push_str(&cfg.model);
    canon.push_str(";opt=");
    canon.push_str(opt_tag);
    canon.push_str(";layers=");
    for l in &spec.layers {
        canon.push_str(&format!("{}:{},", l.name, l.flat_len));
    }
    canon.push_str(&format!(
        ";accum={};lr={:?};seed={};wd={};mom={}",
        cfg.accum_steps, cfg.lr, cfg.seed, cfg.weight_decay, cfg.momentum
    ));
    fnv1a64(canon.as_bytes())
}

// ---- the single-rank full-training-state file --------------------------

pub const SEC_FPRINT: &str = "FPRINT";
pub const SEC_STEP: &str = "STEP";
pub const SEC_PARAMS: &str = "PARAMS";
pub const SEC_OPT: &str = "OPTSTATE";
pub const SEC_RNGS: &str = "RNGS";
pub const SEC_LOSSES: &str = "LOSSES";

/// The complete single-process training state at a step boundary.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub fingerprint: u64,
    pub step: u64,
    pub params: Vec<Vec<f32>>,
    pub opt: OptSnapshot,
    pub rngs: Vec<Rng>,
    pub losses: Vec<f32>,
}

impl TrainState {
    pub fn to_container(&self) -> Container {
        let mut c = Container::new();
        c.push(SEC_FPRINT, self.fingerprint.to_le_bytes().to_vec());
        c.push(SEC_STEP, self.step.to_le_bytes().to_vec());
        c.push(SEC_PARAMS, encode_layers(&self.params));
        c.push(SEC_OPT, self.opt.encode());
        c.push(SEC_RNGS, encode_rngs(&self.rngs));
        c.push(SEC_LOSSES, encode_f32s(&self.losses));
        c
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_container().write_atomic(path)
    }

    pub fn from_container(c: &Container) -> Result<Self> {
        let fingerprint = u64_section(c, SEC_FPRINT)?;
        let step = u64_section(c, SEC_STEP)?;
        let params = decode_layers(c.get(SEC_PARAMS)?)?;
        let opt = OptSnapshot::decode(c.get(SEC_OPT)?)?;
        let rngs = decode_rngs(c.get(SEC_RNGS)?)?;
        let losses = decode_f32s(c.get(SEC_LOSSES)?)?;
        Ok(Self { fingerprint, step, params, opt, rngs, losses })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_container(&Container::read(path)?)
    }
}

pub fn u64_section(c: &Container, tag: &str) -> Result<u64> {
    let b = c.get(tag)?;
    if b.len() != 8 {
        bail!("section '{tag}' must be exactly 8 bytes, got {}", b.len());
    }
    Ok(u64::from_le_bytes(b.try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> TrainState {
        TrainState {
            fingerprint: 0xDEAD_BEEF_1234_5678,
            step: 7,
            params: vec![vec![1.0, -2.5, 3.25], vec![0.0; 5]],
            opt: OptSnapshot {
                tag: "adama".into(),
                t: 7,
                bufs: vec![vec![0.5; 3], vec![0.25; 3], vec![1e-8; 5], vec![2.0; 5]],
            },
            rngs: vec![Rng::from_state(42, Some(0.125)), Rng::from_state(99, None)],
            losses: vec![3.5, 3.25, 3.0],
        }
    }

    #[test]
    fn roundtrip_bit_exact() {
        let dir = std::env::temp_dir().join("adamack2_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.ck2");
        let st = sample_state();
        st.save(&path).unwrap();
        let back = TrainState::load(&path).unwrap();
        assert_eq!(back.fingerprint, st.fingerprint);
        assert_eq!(back.step, st.step);
        assert_eq!(back.params, st.params);
        assert_eq!(back.opt, st.opt);
        assert_eq!(back.rngs, st.rngs);
        assert_eq!(back.losses, st.losses);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bit_flip_fails_section_hash() {
        let mut bytes = sample_state().to_container().encode();
        // flip one bit inside the PARAMS payload (well past the header)
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = Container::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("integrity hash mismatch"), "{err}");
    }

    #[test]
    fn truncation_is_a_clean_offset_error() {
        let bytes = sample_state().to_container().encode();
        let cut = &bytes[..bytes.len() - 5];
        let err = Container::decode(cut).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("truncated"), "{chain}");
        assert!(chain.contains("byte offset"), "{chain}");
    }

    #[test]
    fn wrong_magic_is_a_versioned_error() {
        let mut bytes = sample_state().to_container().encode();
        bytes[..8].copy_from_slice(b"ADAMACK9");
        let err = Container::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("version 2"), "{err}");
    }

    #[test]
    fn trailing_garbage_rejected_with_offset() {
        let mut bytes = sample_state().to_container().encode();
        let clean_len = bytes.len();
        bytes.extend_from_slice(b"junk");
        let err = Container::decode(&bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("trailing"), "{msg}");
        assert!(msg.contains(&format!("byte offset {clean_len}")), "{msg}");
    }

    #[test]
    fn fingerprint_moves_with_math_knobs() {
        let a = fnv1a64(b"x");
        let b = fnv1a64(b"y");
        assert_ne!(a, b);
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
    }
}
