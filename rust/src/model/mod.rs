//! Model topology on the rust side: layer graph, parameter registry, init.
//!
//! The ground truth for shapes is `manifest.json` (written at AOT time from
//! `python/compile/model.py::ModelConfig.param_shapes`); this module groups
//! those tensors into *layers* — the granularity at which AdamA releases
//! gradients — and lays each layer's tensors out in one contiguous flat
//! buffer so the chunked optimizer kernels and collectives can stream it.

use std::ops::Range;

use anyhow::{bail, Result};

pub mod ckpt;

use crate::memory::{Category, MemoryTracker};
use crate::runtime::ModelConfigEntry;
use crate::tensor::Rng;

/// One tensor's view into its layer's flat buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamView {
    pub name: String,
    pub shape: Vec<usize>,
    pub range: Range<usize>,
}

impl ParamView {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Layer role in the forward/backward sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Embed,
    Block(usize),
    Head,
}

/// A release-granularity unit: all tensors updated together.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub kind: LayerKind,
    pub name: String,
    pub params: Vec<ParamView>,
    pub flat_len: usize,
}

/// The full layer graph for one manifest model config.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub config: String,
    pub hyper: crate::runtime::ModelHyper,
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    /// Group the manifest's ordered `param_shapes` into layers:
    /// `embed.*` | `block{i}.*` | `head.*`.
    pub fn from_manifest(config: &str, entry: &ModelConfigEntry) -> Result<Self> {
        let mut layers: Vec<LayerSpec> = Vec::new();
        for (name, shape) in &entry.param_shapes {
            let (layer_name, kind) = match name.split_once('.') {
                Some(("embed", _)) => ("embed".to_string(), LayerKind::Embed),
                Some(("head", _)) => ("head".to_string(), LayerKind::Head),
                Some((blk, _)) if blk.starts_with("block") => {
                    let idx: usize = blk[5..].parse()?;
                    (blk.to_string(), LayerKind::Block(idx))
                }
                _ => bail!("unparseable param name '{name}'"),
            };
            if layers.last().map(|l| l.name != layer_name).unwrap_or(true) {
                layers.push(LayerSpec {
                    kind,
                    name: layer_name,
                    params: Vec::new(),
                    flat_len: 0,
                });
            }
            let layer = layers.last_mut().unwrap();
            let n: usize = shape.iter().product();
            layer.params.push(ParamView {
                name: name.clone(),
                shape: shape.clone(),
                range: layer.flat_len..layer.flat_len + n,
            });
            layer.flat_len += n;
        }
        // sanity: embed first, head last, blocks contiguous
        if layers.first().map(|l| l.kind) != Some(LayerKind::Embed) {
            bail!("expected embed layer first");
        }
        if layers.last().map(|l| l.kind) != Some(LayerKind::Head) {
            bail!("expected head layer last");
        }
        Ok(Self { config: config.to_string(), hyper: entry.model.clone(), layers })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn n_blocks(&self) -> usize {
        self.layers.iter().filter(|l| matches!(l.kind, LayerKind::Block(_))).count()
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.flat_len).sum()
    }

    /// Largest single layer — AdamA's gradient-memory peak (paper's 1/M).
    pub fn max_layer_params(&self) -> usize {
        self.layers.iter().map(|l| l.flat_len).max().unwrap_or(0)
    }

    pub fn layer(&self, idx: usize) -> &LayerSpec {
        &self.layers[idx]
    }

    /// Activation elements stashed per block input per micro-batch
    /// (`[mb, seq, hidden]` — the per-layer remat protocol).
    pub fn block_input_elems(&self) -> usize {
        self.hyper.microbatch * self.hyper.seq * self.hyper.hidden
    }
}

/// One layer's parameters in a contiguous flat buffer.
#[derive(Debug, Clone)]
pub struct LayerParams {
    pub flat: Vec<f32>,
}

impl LayerParams {
    pub fn view<'a>(&'a self, p: &ParamView) -> &'a [f32] {
        &self.flat[p.range.clone()]
    }

    pub fn view_mut<'a>(&'a mut self, p: &ParamView) -> &'a mut [f32] {
        &mut self.flat[p.range.clone()]
    }
}

/// Initialise all layers (mirrors `python/compile/model.py::init_params`:
/// std 0.02 for embeddings, fan_in^-1/2 for matrices, ones for LN gains,
/// zeros for biases). Registers bytes with the tracker as `Weights`.
pub fn init_params(spec: &ModelSpec, seed: u64, tracker: &MemoryTracker) -> Vec<LayerParams> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(spec.layers.len());
    for layer in &spec.layers {
        let mut flat = vec![0.0f32; layer.flat_len];
        for p in &layer.params {
            let dst = &mut flat[p.range.clone()];
            init_tensor(&p.name, &p.shape, dst, &mut rng);
        }
        tracker.alloc_raw(Category::Weights, flat.len() * 4);
        out.push(LayerParams { flat });
    }
    out
}

fn init_tensor(name: &str, shape: &[usize], dst: &mut [f32], rng: &mut Rng) {
    let last = name.rsplit('.').next().unwrap_or("");
    match last {
        "g" => dst.fill(1.0),                            // LN gain
        "b" | "bqkv" | "bo" | "b1" | "b2" => dst.fill(0.0), // biases
        _ => {
            let std = if name.starts_with("embed") {
                0.02
            } else {
                let fan_in = shape.first().copied().unwrap_or(1).max(1);
                (fan_in as f32).powf(-0.5)
            };
            for x in dst.iter_mut() {
                *x = std * rng.normal();
            }
        }
    }
}

/// Serialize parameters to a simple binary checkpoint (version + per-layer
/// f32 blobs). Used by Table-1 style pretrain->finetune flows.
pub mod checkpoint {
    use std::io::Read;
    use std::path::Path;

    use anyhow::{bail, Context, Result};

    use super::{LayerParams, ModelSpec};

    const MAGIC: &[u8; 8] = b"ADAMACK1";

    pub fn save(path: &Path, spec: &ModelSpec, params: &[LayerParams]) -> Result<()> {
        if params.len() != spec.layers.len() {
            bail!(
                "cannot save: params have {} layers, spec '{}' wants {}",
                params.len(),
                spec.config,
                spec.layers.len()
            );
        }
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(params.len() as u64).to_le_bytes());
        for (i, (layer, spec_l)) in params.iter().zip(&spec.layers).enumerate() {
            if layer.flat.len() != spec_l.flat_len {
                bail!(
                    "cannot save: layer '{}' (#{}) has {} params, spec wants {}",
                    spec_l.name,
                    i,
                    layer.flat.len(),
                    spec_l.flat_len
                );
            }
            buf.extend_from_slice(&(layer.flat.len() as u64).to_le_bytes());
            for x in &layer.flat {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        // atomic publish: a crash mid-write leaves only `<path>.tmp`, never
        // a truncated file at the canonical path
        super::ckpt::write_atomic(path, &buf)
    }

    pub fn load(path: &Path, spec: &ModelSpec) -> Result<Vec<LayerParams>> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).context("truncated checkpoint: no magic")?;
        if &magic != MAGIC {
            bail!(
                "not an ADAMACK1 checkpoint (magic {:?}; full-state files use \
                 the ADAMACK2 container in model::ckpt)",
                String::from_utf8_lossy(&magic)
            );
        }
        let mut n8 = [0u8; 8];
        f.read_exact(&mut n8).context("truncated checkpoint: no layer count")?;
        let n_layers = u64::from_le_bytes(n8) as usize;
        if n_layers != spec.layers.len() {
            bail!("checkpoint has {} layers, spec wants {}", n_layers, spec.layers.len());
        }
        let mut offset = 16usize;
        let mut out = Vec::with_capacity(n_layers);
        for (i, spec_l) in spec.layers.iter().enumerate() {
            f.read_exact(&mut n8).with_context(|| {
                format!(
                    "truncated checkpoint: no length for layer '{}' (#{i}) at byte \
                     offset {offset}",
                    spec_l.name
                )
            })?;
            offset += 8;
            let len = u64::from_le_bytes(n8) as usize;
            if len != spec_l.flat_len {
                bail!("layer '{}' len {} != {}", spec_l.name, len, spec_l.flat_len);
            }
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes).with_context(|| {
                format!(
                    "truncated checkpoint: layer '{}' (#{i}) cut short at byte \
                     offset {offset}",
                    spec_l.name
                )
            })?;
            offset += len * 4;
            let flat = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            out.push(LayerParams { flat });
        }
        // strict EOF: a valid file ends exactly after the last layer
        let mut probe = [0u8; 1];
        match f.read(&mut probe) {
            Ok(0) => Ok(out),
            Ok(_) => bail!(
                "checkpoint has trailing garbage after the last layer (byte offset \
                 {offset}) — refusing a file this writer did not produce"
            ),
            Err(e) => Err(e).context("probing for trailing bytes"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, ModelHyper};

    fn toy_entry() -> ModelConfigEntry {
        ModelConfigEntry {
            model: ModelHyper {
                vocab: 16, hidden: 8, layers: 2, heads: 2, seq: 4, microbatch: 2, ffn: 32,
            },
            param_shapes: vec![
                ("embed.E".into(), vec![16, 8]),
                ("embed.P".into(), vec![4, 8]),
                ("block0.ln1.g".into(), vec![8]),
                ("block0.attn.wqkv".into(), vec![8, 24]),
                ("block1.ln1.g".into(), vec![8]),
                ("block1.attn.wqkv".into(), vec![8, 24]),
                ("head.W".into(), vec![8, 16]),
            ],
            artifacts: Default::default(),
        }
    }

    use crate::runtime::ModelConfigEntry;

    #[test]
    fn groups_layers_in_order() {
        let spec = ModelSpec::from_manifest("toy", &toy_entry()).unwrap();
        let names: Vec<_> = spec.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["embed", "block0", "block1", "head"]);
        assert_eq!(spec.total_params(), 16 * 8 + 4 * 8 + 2 * (8 + 8 * 24) + 8 * 16);
        assert_eq!(spec.max_layer_params(), 8 + 8 * 24); // block > embed here
        assert_eq!(spec.n_blocks(), 2);
    }

    #[test]
    fn param_views_are_contiguous_and_cover() {
        let spec = ModelSpec::from_manifest("toy", &toy_entry()).unwrap();
        for layer in &spec.layers {
            let mut off = 0;
            for p in &layer.params {
                assert_eq!(p.range.start, off);
                off = p.range.end;
            }
            assert_eq!(off, layer.flat_len);
        }
    }

    #[test]
    fn init_respects_tensor_roles() {
        let spec = ModelSpec::from_manifest("toy", &toy_entry()).unwrap();
        let tracker = MemoryTracker::new();
        let params = init_params(&spec, 7, &tracker);
        // LN gain = ones
        let blk0 = &spec.layers[1];
        let g = params[1].view(&blk0.params[0]);
        assert!(g.iter().all(|&x| x == 1.0));
        // embeddings have std ~0.02
        let e = params[0].view(&spec.layers[0].params[0]);
        let std = (e.iter().map(|x| x * x).sum::<f32>() / e.len() as f32).sqrt();
        assert!((std - 0.02).abs() < 0.01, "std {std}");
        // tracker saw all weights
        assert_eq!(tracker.live(Category::Weights), spec.total_params() * 4);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let spec = ModelSpec::from_manifest("toy", &toy_entry()).unwrap();
        let tracker = MemoryTracker::new();
        let params = init_params(&spec, 9, &tracker);
        let dir = std::env::temp_dir().join("adama_ck_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.ck");
        checkpoint::save(&path, &spec, &params).unwrap();
        let loaded = checkpoint::load(&path, &spec).unwrap();
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a.flat, b.flat);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn from_real_manifest_if_present() {
        let root = crate::runtime::Library::default_root();
        let Ok(m) = Manifest::load(root.join("manifest.json")) else { return };
        let entry = m.model_config("tiny").unwrap();
        let spec = ModelSpec::from_manifest("tiny", entry).unwrap();
        assert_eq!(spec.n_blocks(), entry.model.layers);
        // 12 tensors per block
        for l in &spec.layers {
            if matches!(l.kind, LayerKind::Block(_)) {
                assert_eq!(l.params.len(), 12);
            }
        }
    }
}
