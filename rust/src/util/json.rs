//! Minimal JSON: recursive-descent parser + writer.
//!
//! Parses the `artifacts/manifest.json` written by `python/compile/aot.py`
//! and serializes metrics/checkpoints. Supports the full JSON grammar
//! except `\u` surrogate pairs outside the BMP (not needed here).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- accessors ----

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---- writer ----

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    x.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected '{}' got '{}' at byte {}", b as char, got as char, self.pos - 1);
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string().context("object key")?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| anyhow!("bad \\u digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => bail!("raw control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte by byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        self.pos = start + len;
                        let chunk = self
                            .bytes
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        s.push_str(std::str::from_utf8(chunk).context("invalid UTF-8")?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = text.parse().with_context(|| format!("bad number '{text}'"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
          "hyper": {"beta1": 0.9, "eps": 1e-08},
          "chunk_sizes": [16384, 65536],
          "configs": {"tiny": {"artifacts": {"a": {"file": "tiny/a.hlo.txt",
            "inputs": [{"shape": [4, 32], "dtype": "s32"}]}}}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("hyper").unwrap().get("beta1").unwrap().as_f64().unwrap(), 0.9);
        assert_eq!(j.get("chunk_sizes").unwrap().usize_vec().unwrap(), vec![16384, 65536]);
        let entry = j.get("configs").unwrap().get("tiny").unwrap().get("artifacts").unwrap()
            .get("a").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str().unwrap(), "tiny/a.hlo.txt");
        assert_eq!(
            entry.get("inputs").unwrap().as_arr().unwrap()[0].get("shape").unwrap()
                .usize_vec().unwrap(),
            vec![4, 32]
        );
    }

    #[test]
    fn roundtrip_write_parse() {
        let v = obj(vec![
            ("a", Json::Arr(vec![1usize.into(), 2usize.into()])),
            ("b", "hi \"there\"\n".into()),
            ("c", Json::Bool(true)),
            ("d", Json::Null),
            ("e", 1.5.into()),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
        let text = v.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn scientific_and_negative_numbers() {
        let j = Json::parse("[-1.5e3, 0.25, -0]").unwrap();
        let v: Vec<f64> = j.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(v, vec![-1500.0, 0.25, 0.0]);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""aéb""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "aéb");
    }
}
