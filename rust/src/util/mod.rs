//! Dependency-light utilities: JSON, CLI parsing, timing stats.
//!
//! The offline build environment only ships the `xla` crate's dependency
//! closure, so the usual suspects (serde_json, clap, criterion) are
//! re-implemented here at the scale this project needs.

pub mod cliargs;
pub mod json;
pub mod stats;
