//! Timing / summary statistics for the in-tree bench harness
//! (criterion is unavailable offline — see Cargo.toml header note).

use std::time::{Duration, Instant};

/// Online summary of a sample set (times, losses, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
}

/// Measure a closure `iters` times after `warmup` runs; returns per-call
/// seconds. The shared shape of every `rust/benches/*` harness.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// Wall-clock stopwatch with named laps (perf logs).
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, laps: Vec::new(), last: now }
    }

    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Human-readable byte count (GiB/MiB/KiB) for table output.
pub fn fmt_bytes(b: usize) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= G {
        format!("{:.2} GiB", b / G)
    } else if b >= M {
        format!("{:.2} MiB", b / M)
    } else if b >= K {
        format!("{:.1} KiB", b / K)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert!((s.std() - 1.2909944).abs() < 1e-5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.percentile(50.0), 3.0);
    }

    #[test]
    fn bench_runs_expected_iters() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert!(fmt_bytes(5_368_709_120).starts_with("5.00 GiB"));
    }

    #[test]
    fn stopwatch_laps() {
        let mut w = Stopwatch::new();
        w.lap("a");
        w.lap("b");
        assert_eq!(w.laps().len(), 2);
        assert!(w.total().as_nanos() > 0);
    }
}
