//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults; unknown-flag detection.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(args: impl IntoIterator<Item = String>) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(rest.to_string(), iter.next().unwrap());
                } else {
                    flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self { flags, positional }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={s}: {e}")),
        }
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing required --{key}"))
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error if any flag is not in `known` (catches typos in scripts).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k}; known: {known:?}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = args("train --steps 10 --lr=0.1 --verbose --out x.json");
        assert_eq!(a.positional(), &["train"]);
        assert_eq!(a.parse_or("steps", 0usize).unwrap(), 10);
        assert_eq!(a.parse_or("lr", 0.0f64).unwrap(), 0.1);
        assert!(a.flag("verbose"));
        assert_eq!(a.str_or("out", ""), "x.json");
    }

    #[test]
    fn defaults_and_missing() {
        let a = args("run");
        assert_eq!(a.parse_or("steps", 7usize).unwrap(), 7);
        assert!(a.require("model").is_err());
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn bad_value_errors() {
        let a = args("--steps abc");
        assert!(a.parse_or("steps", 0usize).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = args("--good 1 --typo 2");
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "typo"]).is_ok());
    }
}
