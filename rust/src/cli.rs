//! `adama` CLI — leader entrypoint for training runs and paper experiments.
//!
//! Subcommands:
//!   train     single-device training on the synthetic Markov corpus
//!   dp        data-parallel training (state/grad/naive sync strategies)
//!   zero1     ZeRO-S1 (+AdamA or +GA) training
//!   memmodel  analytic paper-scale memory projections
//!   info      artifact/manifest inventory

use adama::collective::{run_data_parallel, run_zero1, DpSpec, SyncStrategy, Zero1Spec};
use adama::config::TrainConfig;
use adama::data::MarkovCorpus;
use adama::memmodel::{peak_memory, DtypePolicy, PaperModel, Scenario, Strategy};
use adama::runtime::Library;
use adama::util::cliargs::Args;
use adama::util::stats::fmt_bytes;
use adama::Trainer;
use anyhow::{bail, Result};

const USAGE: &str = "usage: adama <train|dp|zero1|memmodel|info> [--flags]
  train    --model tiny --optimizer adama|adamga|adafactor|sm3 --accum-steps N
           --steps S --lr X [--backend kernel|host] [--decay cosine --total-steps S]
  dp       as train, plus --workers M --sync state|grad|naive
  zero1    as train (adama|adamga), plus --workers M
  memmodel [--params 4e9] [--minibatch 32] [--accum-steps 8] [--gpus 8]
  info     (no flags)";

pub struct Cli {
    args: Args,
}

impl Cli {
    pub fn parse() -> Self {
        Self { args: Args::parse_env() }
    }
}

pub fn run(cli: Cli) -> Result<()> {
    let args = cli.args;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => train(&args),
        "dp" => dp(&args),
        "zero1" => zero1(&args),
        "memmodel" => memmodel(&args),
        "info" => info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn train(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let lib = Library::open_default()?;
    let mut trainer = Trainer::new(lib, cfg.clone())?;
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, cfg.seed);
    println!(
        "training '{}' ({} params) with {} N={} for {} steps",
        cfg.model,
        trainer.spec().total_params(),
        cfg.optimizer.name(),
        cfg.accum_steps,
        cfg.steps
    );
    for step in 1..=cfg.steps {
        let stats = trainer.train_step(&corpus.minibatch(cfg.accum_steps, h.microbatch, h.seq))?;
        if step % 10 == 0 || step == 1 || step == cfg.steps {
            println!(
                "step {:>4}  loss {:.4}  lr {:.2e}  {:>6.0} tok/s",
                stats.step, stats.loss, stats.lr, stats.tokens_per_sec()
            );
        }
    }
    println!("\n{}", trainer.tracker().report());
    Ok(())
}

fn dp(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let sync = match args.str_or("sync", "state").as_str() {
        "state" => SyncStrategy::OptimizerStates,
        "grad" => SyncStrategy::Gradients,
        "naive" => SyncStrategy::GradPerMicrobatch,
        s => bail!("unknown --sync '{s}' (state|grad|naive)"),
    };
    let steps = cfg.steps;
    let lib = Library::open_default()?;
    let r = run_data_parallel(lib, DpSpec::new(cfg, sync, steps, 7))?;
    println!(
        "losses: {:.4} -> {:.4} over {} steps",
        r.losses[0],
        r.losses.last().unwrap(),
        r.losses.len()
    );
    println!(
        "comm: {} total ({} per step), {} collectives",
        fmt_bytes(r.comm_bytes as usize),
        fmt_bytes((r.comm_bytes / steps.max(1)) as usize),
        r.comm_ops
    );
    println!("wall: {:.2}s; ranks verified identical", r.elapsed_s);
    Ok(())
}

fn zero1(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let steps = cfg.steps;
    let lib = Library::open_default()?;
    let r = run_zero1(lib, Zero1Spec::new(cfg, steps, 7))?;
    println!(
        "losses: {:.4} -> {:.4}; comm/step {}; grad peak {}; optstate {}",
        r.losses[0],
        r.losses.last().unwrap(),
        fmt_bytes((r.comm_bytes / steps.max(1)) as usize),
        fmt_bytes(r.memory.peak_gradients),
        fmt_bytes(r.memory.peak_optimizer)
    );
    Ok(())
}

fn memmodel(args: &Args) -> Result<()> {
    let params = args.parse_or("params", 4e9f64)? as u64;
    let mb = args.parse_or("minibatch", 32u64)?;
    let n = args.parse_or("accum-steps", 8u64)?;
    let gpus = args.parse_or("gpus", 8u64)?;
    let model = PaperModel::gpt3_scaled("custom", params);
    println!(
        "model: {:.2}B params (hidden {}, layers {})",
        model.params as f64 / 1e9,
        model.hidden,
        model.layers
    );
    println!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "strategy", "weights", "grads", "optstate", "acts", "TOTAL(GB)"
    );
    for strategy in [
        Strategy::NoAccum,
        Strategy::GradAccum,
        Strategy::AdamA,
        Strategy::Zero1,
        Strategy::Zero1GradAccum,
        Strategy::Zero1AdamA,
        Strategy::Zero2GradAccum,
    ] {
        let b = peak_memory(&Scenario {
            model: model.clone(),
            dtype: DtypePolicy::paper_fp32(),
            strategy,
            optimizer: adama::config::OptimizerKind::AdamGA,
            minibatch_per_gpu: mb,
            accum_steps: n,
            gpus,
        });
        let gb = |x: u64| x as f64 / 1e9;
        println!(
            "{:<16} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>10.2}",
            strategy.name(),
            gb(b.weights),
            gb(b.gradients),
            gb(b.optimizer_states),
            gb(b.activations),
            gb(b.total())
        );
    }
    Ok(())
}

fn info() -> Result<()> {
    let lib = Library::open_default()?;
    let m = lib.manifest();
    println!("backend: {}", lib.executor().platform());
    println!("hyper: beta1={} beta2={} eps={}", m.hyper.beta1, m.hyper.beta2, m.hyper.eps);
    println!("chunk sizes: {:?}", m.chunk_sizes);
    for (name, c) in &m.configs {
        println!(
            "model '{}': vocab {} hidden {} layers {} seq {} microbatch {} ({} artifacts)",
            name,
            c.model.vocab,
            c.model.hidden,
            c.model.layers,
            c.model.seq,
            c.model.microbatch,
            c.artifacts.len()
        );
    }
    for (name, c) in &m.mlp_configs {
        println!(
            "mlp '{}': features {} hidden {} classes {} ({} artifacts)",
            name, c.model.features, c.model.hidden, c.model.classes, c.artifacts.len()
        );
    }
    println!("common optimizer artifacts: {}", m.common.len());
    Ok(())
}
