//! Category-exact training memory accounting.
//!
//! The paper's headline metric is peak device memory split into the four
//! classic categories (weights / gradients / optimizer states /
//! activations).  Instead of reading `nvidia-smi`, every buffer the
//! coordinator materialises is registered here, giving bit-exact live and
//! peak byte counts per category — the instrument behind Figures 5–6 and
//! the tracker-vs-analytic-model validation tests.

use std::fmt;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

/// The four memory categories of the paper (§2) plus transient workspace.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Weights,
    Gradients,
    OptimizerStates,
    Activations,
    Workspace,
}

impl Category {
    pub const ALL: [Category; 5] = [
        Category::Weights,
        Category::Gradients,
        Category::OptimizerStates,
        Category::Activations,
        Category::Workspace,
    ];

    fn idx(self) -> usize {
        match self {
            Category::Weights => 0,
            Category::Gradients => 1,
            Category::OptimizerStates => 2,
            Category::Activations => 3,
            Category::Workspace => 4,
        }
    }
}

impl fmt::Display for Category {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Category::Weights => "weights",
            Category::Gradients => "gradients",
            Category::OptimizerStates => "optimizer_states",
            Category::Activations => "activations",
            Category::Workspace => "workspace",
        };
        f.write_str(s)
    }
}

#[derive(Default)]
struct Counters {
    live: AtomicI64,
    peak: AtomicI64,
}

impl Counters {
    fn add(&self, delta: i64) {
        let now = self.live.fetch_add(delta, Ordering::SeqCst) + delta;
        debug_assert!(now >= 0, "negative live bytes");
        self.peak.fetch_max(now, Ordering::SeqCst);
    }
}

/// Thread-safe live/peak byte tracker. Cloneable handle (Arc inside).
#[derive(Clone)]
pub struct MemoryTracker {
    inner: Arc<Inner>,
}

struct Inner {
    cats: [Counters; 5],
    total_live: AtomicI64,
    total_peak: AtomicI64,
    allocs: AtomicU64,
}

impl Default for MemoryTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryTracker {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Inner {
                cats: Default::default(),
                total_live: AtomicI64::new(0),
                total_peak: AtomicI64::new(0),
                allocs: AtomicU64::new(0),
            }),
        }
    }

    fn record(&self, cat: Category, delta: i64) {
        self.inner.cats[cat.idx()].add(delta);
        let now = self.inner.total_live.fetch_add(delta, Ordering::SeqCst) + delta;
        self.inner.total_peak.fetch_max(now, Ordering::SeqCst);
        if delta > 0 {
            self.inner.allocs.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Register an allocation; the returned guard frees it on drop.
    pub fn alloc(&self, cat: Category, bytes: usize) -> Allocation {
        self.record(cat, bytes as i64);
        Allocation { tracker: self.clone(), cat, bytes }
    }

    /// Register a long-lived allocation without a guard (freed via `free`).
    pub fn alloc_raw(&self, cat: Category, bytes: usize) {
        self.record(cat, bytes as i64);
    }

    pub fn free_raw(&self, cat: Category, bytes: usize) {
        self.record(cat, -(bytes as i64));
    }

    pub fn live(&self, cat: Category) -> usize {
        self.inner.cats[cat.idx()].live.load(Ordering::SeqCst).max(0) as usize
    }

    pub fn peak(&self, cat: Category) -> usize {
        self.inner.cats[cat.idx()].peak.load(Ordering::SeqCst).max(0) as usize
    }

    pub fn total_live(&self) -> usize {
        self.inner.total_live.load(Ordering::SeqCst).max(0) as usize
    }

    pub fn total_peak(&self) -> usize {
        self.inner.total_peak.load(Ordering::SeqCst).max(0) as usize
    }

    pub fn alloc_count(&self) -> u64 {
        self.inner.allocs.load(Ordering::Relaxed)
    }

    /// Reset peaks to current live values (e.g. after warm-up steps).
    pub fn reset_peaks(&self) {
        for c in &self.inner.cats {
            c.peak.store(c.live.load(Ordering::SeqCst), Ordering::SeqCst);
        }
        self.inner
            .total_peak
            .store(self.inner.total_live.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    /// Snapshot of peaks per category, for reports.
    pub fn report(&self) -> MemoryReport {
        MemoryReport {
            peak_weights: self.peak(Category::Weights),
            peak_gradients: self.peak(Category::Gradients),
            peak_optimizer: self.peak(Category::OptimizerStates),
            peak_activations: self.peak(Category::Activations),
            peak_workspace: self.peak(Category::Workspace),
            peak_total: self.total_peak(),
        }
    }
}

/// RAII guard for a tracked allocation.
pub struct Allocation {
    tracker: MemoryTracker,
    cat: Category,
    bytes: usize,
}

impl Allocation {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for Allocation {
    fn drop(&mut self) {
        self.tracker.record(self.cat, -(self.bytes as i64));
    }
}

/// Peak-bytes snapshot per category.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryReport {
    pub peak_weights: usize,
    pub peak_gradients: usize,
    pub peak_optimizer: usize,
    pub peak_activations: usize,
    pub peak_workspace: usize,
    pub peak_total: usize,
}

impl fmt::Display for MemoryReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "peak memory (bytes):")?;
        writeln!(f, "  weights          {:>14}", self.peak_weights)?;
        writeln!(f, "  gradients        {:>14}", self.peak_gradients)?;
        writeln!(f, "  optimizer states {:>14}", self.peak_optimizer)?;
        writeln!(f, "  activations      {:>14}", self.peak_activations)?;
        writeln!(f, "  workspace        {:>14}", self.peak_workspace)?;
        write!(f, "  TOTAL            {:>14}", self.peak_total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_frees_on_drop() {
        let t = MemoryTracker::new();
        {
            let _a = t.alloc(Category::Gradients, 100);
            assert_eq!(t.live(Category::Gradients), 100);
        }
        assert_eq!(t.live(Category::Gradients), 0);
        assert_eq!(t.peak(Category::Gradients), 100);
    }

    #[test]
    fn peak_tracks_maximum_concurrent() {
        let t = MemoryTracker::new();
        let a = t.alloc(Category::Activations, 10);
        let b = t.alloc(Category::Activations, 20);
        drop(a);
        let _c = t.alloc(Category::Activations, 5);
        drop(b);
        assert_eq!(t.peak(Category::Activations), 30);
        assert_eq!(t.live(Category::Activations), 5);
    }

    #[test]
    fn total_spans_categories() {
        let t = MemoryTracker::new();
        let _a = t.alloc(Category::Weights, 7);
        let _b = t.alloc(Category::Gradients, 8);
        assert_eq!(t.total_live(), 15);
        assert_eq!(t.total_peak(), 15);
    }

    #[test]
    fn reset_peaks_to_live() {
        let t = MemoryTracker::new();
        {
            let _a = t.alloc(Category::Workspace, 1000);
        }
        let _b = t.alloc(Category::Workspace, 10);
        t.reset_peaks();
        assert_eq!(t.peak(Category::Workspace), 10);
    }

    #[test]
    fn raw_alloc_free_balance() {
        let t = MemoryTracker::new();
        t.alloc_raw(Category::OptimizerStates, 64);
        t.free_raw(Category::OptimizerStates, 64);
        assert_eq!(t.live(Category::OptimizerStates), 0);
        assert_eq!(t.peak(Category::OptimizerStates), 64);
    }
}
