//! Minimal host tensor: shaped `Vec<f32>` with the chunking / RNG / math
//! helpers the coordinator, optimizers and collectives need.
//!
//! This is deliberately not a general ndarray — the request path runs all
//! heavy math through PJRT artifacts; host tensors exist for parameter and
//! optimizer-state bookkeeping, collectives, baselines and tests.

mod rng;

pub use rng::Rng;

use std::fmt;

/// Dense f32 host tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], value: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![value; n] }
    }

    /// Normal(0, std) init from a deterministic stream.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.data.len(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }

    // ---- elementwise ops (bookkeeping-scale, not the hot path) ----

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

/// Split a flat length into `chunk`-sized pieces; the last may be partial.
/// Returned as (offset, len) pairs. This is the bucketing scheme the
/// optimizer kernels use (fused-Adam-over-flat-buffer).
pub fn chunk_ranges(total: usize, chunk: usize) -> Vec<(usize, usize)> {
    assert!(chunk > 0);
    let mut out = Vec::with_capacity(total.div_ceil(chunk));
    let mut off = 0;
    while off < total {
        let len = chunk.min(total - off);
        out.push((off, len));
        off += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.bytes(), 24);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn axpy_and_norms() {
        let mut a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        a.axpy(2.0, &b);
        assert_eq!(a.data(), &[7.0, 10.0]);
        assert!((b.l2_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn chunking_covers_exactly() {
        let r = chunk_ranges(10, 4);
        assert_eq!(r, vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(chunk_ranges(8, 4), vec![(0, 4), (4, 4)]);
        assert!(chunk_ranges(0, 4).is_empty());
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let a = Tensor::randn(&[16], 1.0, &mut r1);
        let b = Tensor::randn(&[16], 1.0, &mut r2);
        assert_eq!(a, b);
        assert!(a.l2_norm() > 0.0);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let mut b = a.clone();
        b.data_mut()[0] += 1e-7;
        assert!(a.allclose(&b, 1e-5, 1e-6));
        b.data_mut()[0] += 1.0;
        assert!(!a.allclose(&b, 1e-5, 1e-6));
    }
}
