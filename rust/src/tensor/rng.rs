//! Deterministic RNG (splitmix64 + Box-Muller) — reproducible init and
//! synthetic data without external crates, seedable per worker/stream.

/// Splitmix64-based RNG with cached Gaussian (Box-Muller pairs).
#[derive(Debug, Clone, PartialEq)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), cached_normal: None }
    }

    /// The complete cursor of this stream: (splitmix state, cached
    /// Box-Muller half). Together with [`Rng::from_state`] this makes the
    /// stream checkpointable — restoring reproduces the exact draw
    /// sequence, including a pending cached normal.
    pub fn state(&self) -> (u64, Option<f32>) {
        (self.state, self.cached_normal)
    }

    /// Rebuild a stream at an exact cursor captured by [`Rng::state`].
    pub fn from_state(state: u64, cached_normal: Option<f32>) -> Self {
        Self { state, cached_normal }
    }

    /// Derive an independent stream (worker shards, data vs init, ...).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Sample from unnormalised weights (categorical).
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(7);
        let mut fa = a.fork(1);
        let mut fb = a.fork(2);
        assert_ne!(fa.next_u64(), fb.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(123);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn uniform_in_range_and_below() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.categorical(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0]);
    }
}
