//! Training configuration: optimizer, accumulation, parallelism, schedule.
//!
//! Configs load from CLI flags or JSON files and carry everything the
//! [`crate::coordinator::Trainer`] and the distributed launcher need.

use anyhow::{bail, Result};

use crate::util::cliargs::Args;
use crate::util::json::{obj, Json};

/// Which optimizer drives the mini-batch update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// The paper's contribution: per-micro-batch integration of gradients
    /// into (m, v); gradient buffers released layer-by-layer.
    AdamA,
    /// Baseline: gradient accumulation + standard Adam at mini-batch end.
    AdamGA,
    /// Memory-efficient comparator (Table 2): factored second moments.
    Adafactor,
    /// Memory-efficient comparator (Table 2): cover-based second moments.
    Sm3,
    /// Memory-efficient comparator (Table 2): block-wise learning rates.
    AdamMini,
    /// §5 extension: optimizer accumulation applied to momentum SGD.
    SgdmA,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "adama" => Self::AdamA,
            "adam" | "adamga" | "adam-ga" | "ga" => Self::AdamGA,
            "adafactor" => Self::Adafactor,
            "sm3" => Self::Sm3,
            "adam_mini" | "adam-mini" | "adammini" => Self::AdamMini,
            "sgdma" | "sgdm" => Self::SgdmA,
            _ => bail!("unknown optimizer '{s}' (adama|adamga|adafactor|sm3|adam_mini|sgdma)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::AdamA => "adama",
            Self::AdamGA => "adamga",
            Self::Adafactor => "adafactor",
            Self::Sm3 => "sm3",
            Self::AdamMini => "adam_mini",
            Self::SgdmA => "sgdma",
        }
    }

    /// The exec-layer [`crate::runtime::OptAlgo`] this config kind maps to,
    /// for kinds served by the zoo (`None` for AdamA / SGDM-A, which keep
    /// their dedicated state-resident implementations).
    pub fn zoo_algo(self) -> Option<crate::runtime::OptAlgo> {
        use crate::runtime::OptAlgo;
        match self {
            Self::AdamGA => Some(OptAlgo::Adam),
            Self::Adafactor => Some(OptAlgo::Adafactor),
            Self::Sm3 => Some(OptAlgo::Sm3),
            Self::AdamMini => Some(OptAlgo::AdamMini),
            Self::AdamA | Self::SgdmA => None,
        }
    }
}

/// Where optimizer arithmetic runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimBackend {
    /// Through the AOT Pallas kernels via PJRT (the paper's fused path).
    Kernel,
    /// Pure-rust host loops (ablation baseline + comparator optimizers).
    Host,
}

impl OptimBackend {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "kernel" | "pjrt" => Self::Kernel,
            "host" => Self::Host,
            _ => bail!("unknown backend '{s}' (kernel|host)"),
        })
    }
}

/// Learning-rate schedule: linear warmup then constant / cosine / inv-sqrt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LrSchedule {
    pub base: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_lr: f32,
    pub kind: LrDecay,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrDecay {
    Constant,
    Cosine,
    /// `t^{-1/2}` decay — the schedule under which Theorem 1 holds.
    InvSqrt,
}

impl LrSchedule {
    pub fn constant(base: f32) -> Self {
        Self { base, warmup_steps: 0, total_steps: 0, min_lr: 0.0, kind: LrDecay::Constant }
    }

    pub fn cosine(base: f32, warmup: u64, total: u64, min_lr: f32) -> Self {
        Self { base, warmup_steps: warmup, total_steps: total, min_lr, kind: LrDecay::Cosine }
    }

    pub fn inv_sqrt(base: f32, warmup: u64) -> Self {
        Self { base, warmup_steps: warmup, total_steps: 0, min_lr: 0.0, kind: LrDecay::InvSqrt }
    }

    /// LR at 1-based step `t`.
    pub fn at(&self, t: u64) -> f32 {
        if self.warmup_steps > 0 && t <= self.warmup_steps {
            return self.base * t as f32 / self.warmup_steps as f32;
        }
        match self.kind {
            LrDecay::Constant => self.base,
            LrDecay::InvSqrt => {
                let t0 = self.warmup_steps.max(1) as f32;
                self.base * (t0 / t as f32).sqrt()
            }
            LrDecay::Cosine => {
                let total = self.total_steps.max(self.warmup_steps + 1);
                let progress = (t.saturating_sub(self.warmup_steps)) as f32
                    / (total - self.warmup_steps) as f32;
                let progress = progress.clamp(0.0, 1.0);
                self.min_lr
                    + 0.5 * (self.base - self.min_lr)
                        * (1.0 + (std::f32::consts::PI * progress).cos())
            }
        }
    }
}

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Manifest model config name (`tiny`, `small`, ...).
    pub model: String,
    pub optimizer: OptimizerKind,
    pub backend: OptimBackend,
    /// N — micro-batches per mini-batch (accumulation steps).
    pub accum_steps: usize,
    /// Flat-buffer chunk size for the optimizer kernels.
    pub chunk: usize,
    pub lr: LrSchedule,
    pub seed: u64,
    pub steps: u64,
    /// M — data-parallel worker count (1 = single device).
    pub workers: usize,
    /// ZeRO-S1: partition optimizer states across workers.
    pub zero1: bool,
    /// Decoupled weight decay (AdamW-A / SGDM-A §5 extensions); 0 = off.
    pub weight_decay: f32,
    /// Heavy-ball momentum for SGDM-A.
    pub momentum: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "tiny".into(),
            optimizer: OptimizerKind::AdamA,
            backend: OptimBackend::Kernel,
            accum_steps: 4,
            chunk: 16384,
            lr: LrSchedule::constant(1e-3),
            seed: 42,
            steps: 50,
            workers: 1,
            zero1: false,
            weight_decay: 0.0,
            momentum: 0.9,
        }
    }
}

impl TrainConfig {
    pub const CLI_FLAGS: &'static [&'static str] = &[
        "model", "optimizer", "backend", "accum-steps", "chunk", "lr", "warmup", "total-steps",
        "min-lr", "decay", "seed", "steps", "workers", "zero1", "weight-decay", "momentum",
    ];

    /// Build from parsed CLI flags (missing flags keep defaults).
    pub fn from_args(args: &Args) -> Result<Self> {
        let d = Self::default();
        let base_lr = args.parse_or("lr", 1e-3f32)?;
        let warmup = args.parse_or("warmup", 0u64)?;
        let total = args.parse_or("total-steps", 0u64)?;
        let min_lr = args.parse_or("min-lr", 0.0f32)?;
        let decay = args.str_or("decay", "constant");
        let lr = match decay.as_str() {
            "constant" => LrSchedule::constant(base_lr),
            "cosine" => LrSchedule::cosine(base_lr, warmup, total, min_lr),
            "invsqrt" => LrSchedule::inv_sqrt(base_lr, warmup.max(1)),
            other => bail!("unknown --decay '{other}'"),
        };
        Ok(Self {
            model: args.str_or("model", &d.model),
            optimizer: OptimizerKind::parse(&args.str_or("optimizer", "adama"))?,
            backend: OptimBackend::parse(&args.str_or("backend", "kernel"))?,
            accum_steps: args.parse_or("accum-steps", d.accum_steps)?,
            chunk: args.parse_or("chunk", d.chunk)?,
            lr,
            seed: args.parse_or("seed", d.seed)?,
            steps: args.parse_or("steps", d.steps)?,
            workers: args.parse_or("workers", d.workers)?,
            zero1: args.flag("zero1"),
            weight_decay: args.parse_or("weight-decay", d.weight_decay)?,
            momentum: args.parse_or("momentum", d.momentum)?,
        })
    }

    pub fn validate(&self) -> Result<()> {
        if self.accum_steps == 0 {
            bail!("accum_steps must be >= 1");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.chunk == 0 || self.chunk % 128 != 0 {
            bail!("chunk must be a positive multiple of 128 (got {})", self.chunk);
        }
        if self.zero1 && self.workers < 2 {
            bail!("zero1 requires workers >= 2");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", self.model.as_str().into()),
            ("optimizer", self.optimizer.name().into()),
            ("accum_steps", self.accum_steps.into()),
            ("chunk", self.chunk.into()),
            ("seed", (self.seed as usize).into()),
            ("steps", (self.steps as usize).into()),
            ("workers", self.workers.into()),
            ("zero1", Json::Bool(self.zero1)),
            ("base_lr", (self.lr.base as f64).into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimizer_kind_parsing() {
        assert_eq!(OptimizerKind::parse("adama").unwrap(), OptimizerKind::AdamA);
        assert_eq!(OptimizerKind::parse("GA").unwrap(), OptimizerKind::AdamGA);
        assert_eq!(OptimizerKind::parse("adafactor").unwrap(), OptimizerKind::Adafactor);
        assert_eq!(OptimizerKind::parse("adam-mini").unwrap(), OptimizerKind::AdamMini);
        assert_eq!(OptimizerKind::parse("adam_mini").unwrap().name(), "adam_mini");
        assert!(OptimizerKind::parse("sgd").is_err());
    }

    #[test]
    fn lr_warmup_then_cosine() {
        let s = LrSchedule::cosine(1.0, 10, 110, 0.1);
        assert!((s.at(5) - 0.5).abs() < 1e-6);
        assert!((s.at(10) - 1.0).abs() < 1e-6);
        assert!((s.at(110) - 0.1).abs() < 1e-4);
        let mid = s.at(60);
        assert!(mid < 1.0 && mid > 0.1);
    }

    #[test]
    fn lr_invsqrt_matches_theorem_rate() {
        let s = LrSchedule::inv_sqrt(1.0, 1);
        assert!((s.at(1) - 1.0).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert!((s.at(100) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn config_from_args_and_validate() {
        let args = Args::parse(
            "--model tiny --optimizer adamga --accum-steps 8 --workers 2 --zero1"
                .split_whitespace()
                .map(String::from),
        );
        let c = TrainConfig::from_args(&args).unwrap();
        assert_eq!(c.optimizer, OptimizerKind::AdamGA);
        assert_eq!(c.accum_steps, 8);
        assert!(c.zero1);
        c.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut c = TrainConfig::default();
        c.accum_steps = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.chunk = 100;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.zero1 = true;
        assert!(c.validate().is_err());
    }
}
