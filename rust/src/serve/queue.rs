//! Request queue, continuous batching, and KV-budget eviction.
//!
//! The [`Scheduler`] admits and retires sequences only at decode-step
//! boundaries ("continuous batching"): a finished sequence's batch slot
//! is reused by the next queued request on the very next step, and a
//! freshly admitted request prefills its whole prompt in the same ragged
//! batch that advances everyone else by one token — no padding, no
//! separate prefill phase.
//!
//! KV memory is governed by `ADAMA_KV_BUDGET` (same grammar as
//! `ADAMA_ACT_BUDGET`; unset/`0`/`unlimited` → uncapped). When the
//! caches of the active set plus this step's growth would exceed the
//! cap, the scheduler evicts the *oldest-admitted* sequence: its cache
//! is dropped (freeing metered bytes) and the request returns to the
//! front of the queue with its generated tokens intact, so a later
//! re-prefill of prompt + generated resumes it — bit-exact decode
//! guarantees the continuation is token-identical, only timing changes.
//! The newest-admitted sequence is never evicted, and [`Scheduler::submit`]
//! rejects any request whose worst-case cache could never fit, so the
//! system always makes progress.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::ServeStats;
use crate::runtime::{ActBudget, MemoryPlan};
use crate::tensor::Rng;

use super::engine::{DecodeEntry, InferenceEngine};
use super::kv::KvCache;

/// Parse an `ADAMA_KV_BUDGET`-style spec: `None`/empty/`0` and
/// `unlimited` mean uncapped; `<n>[k|m|g]` caps total KV bytes.
pub fn kv_budget_from_spec(spec: Option<&str>) -> Result<Option<u64>> {
    let plan = MemoryPlan::parse_named(spec, "ADAMA_KV_BUDGET")?;
    Ok(match plan.budget {
        ActBudget::Remat | ActBudget::Unlimited => None,
        ActBudget::Bytes(n) => Some(n),
    })
}

/// [`kv_budget_from_spec`] applied to the `ADAMA_KV_BUDGET` env var.
pub fn kv_budget_from_env() -> Result<Option<u64>> {
    kv_budget_from_spec(std::env::var("ADAMA_KV_BUDGET").ok().as_deref())
}

/// A finished request: its generated tokens plus scheduling telemetry.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// The `max_new` greedily decoded tokens, in order.
    pub tokens: Vec<i32>,
    /// Step at which the request first entered the active set.
    pub admitted_step: u64,
    /// Step whose decode produced the final token.
    pub finished_step: u64,
    /// Prompt prefills run: 1 + one per KV-budget eviction.
    pub prefills: u32,
    /// Wall seconds from [`Scheduler::submit`] to retirement.
    pub latency_s: f64,
}

struct Pending {
    id: u64,
    prompt: Vec<i32>,
    /// Tokens decoded before an eviction; re-prefilled on re-admission.
    generated: Vec<i32>,
    max_new: usize,
    born: Instant,
    first_admit_step: Option<u64>,
    prefills: u32,
}

struct Active {
    id: u64,
    prompt: Vec<i32>,
    generated: Vec<i32>,
    max_new: usize,
    born: Instant,
    first_admit_step: u64,
    prefills: u32,
    /// Admission order; eviction removes the minimum (oldest).
    admit_seq: u64,
    cache: KvCache,
    /// Tokens this step feeds the engine; refreshed by [`Scheduler::step`].
    pending_tokens: Vec<i32>,
}

impl Active {
    /// Tokens this sequence will append to its cache next step.
    fn next_news(&self) -> u64 {
        if self.cache.tokens() == 0 {
            (self.prompt.len() + self.generated.len()) as u64
        } else {
            1
        }
    }
}

/// Continuous-batching scheduler over one [`InferenceEngine`].
pub struct Scheduler {
    engine: InferenceEngine,
    budget: Option<u64>,
    max_batch: usize,
    queue: VecDeque<Pending>,
    active: Vec<Active>,
    done: Vec<Completion>,
    next_id: u64,
    admit_counter: u64,
    steps: u64,
}

impl Scheduler {
    /// Scheduler with the KV budget taken from `ADAMA_KV_BUDGET`.
    pub fn new(engine: InferenceEngine, max_batch: usize) -> Result<Self> {
        let budget = kv_budget_from_env()?;
        Ok(Self::with_budget(engine, max_batch, budget))
    }

    /// Scheduler with an explicit KV byte cap (`None` = uncapped).
    pub fn with_budget(engine: InferenceEngine, max_batch: usize, budget: Option<u64>) -> Self {
        Self {
            engine,
            budget,
            max_batch: max_batch.max(1),
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            next_id: 0,
            admit_counter: 0,
            steps: 0,
        }
    }

    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Decode steps run so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Nothing queued and nothing decoding.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// KV bytes currently pinned by the active set.
    pub fn kv_live_bytes(&self) -> u64 {
        self.active.iter().map(|a| a.cache.bytes()).sum()
    }

    /// Completions accumulated since the last take, oldest first.
    pub fn take_completed(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Enqueue a request for `max_new` greedy tokens. Rejects requests
    /// that could never run: empty prompts, contexts beyond the model's
    /// trained sequence length, and — under a KV budget — sequences
    /// whose worst-case cache (`prompt + max_new − 1` tokens; the final
    /// token is returned but never cached) exceeds the cap even alone.
    pub fn submit(&mut self, prompt: &[i32], max_new: usize) -> Result<u64> {
        let hy = self.engine.hyper();
        ensure!(!prompt.is_empty(), "empty prompt");
        ensure!(max_new > 0, "max_new must be at least 1");
        ensure!(
            prompt.len() + max_new <= hy.seq,
            "prompt ({}) + max_new ({max_new}) exceeds '{}' context length {}",
            prompt.len(),
            self.engine.spec().config,
            hy.seq
        );
        if let Some(cap) = self.budget {
            let need = (prompt.len() + max_new - 1) as u64 * self.engine.kv_bytes_per_token();
            ensure!(
                need <= cap,
                "request needs up to {need} KV bytes but ADAMA_KV_BUDGET caps at {cap}"
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending {
            id,
            prompt: prompt.to_vec(),
            generated: Vec::new(),
            max_new,
            born: Instant::now(),
            first_admit_step: None,
            prefills: 0,
        });
        Ok(id)
    }

    /// Run one decode step: admit from the queue into free batch slots,
    /// evict oldest-admitted sequences if this step's KV growth would
    /// burst the budget, advance the ragged batch by one engine call,
    /// and retire sequences that reached `max_new`. Returns the number
    /// of sequences advanced (0 = nothing to do).
    pub fn step(&mut self) -> Result<usize> {
        let per_token = self.engine.kv_bytes_per_token();
        let step_no = self.steps;

        // Admit while slots are free and this step's total KV growth —
        // live bytes + every active sequence's next append + the
        // candidate's prefill — fits the cap. An empty batch always
        // admits: `submit` guaranteed a lone sequence fits.
        while self.active.len() < self.max_batch {
            let Some(p) = self.queue.front() else { break };
            if let Some(cap) = self.budget {
                if !self.active.is_empty() {
                    let planned = self.kv_live_bytes()
                        + self.active.iter().map(Active::next_news).sum::<u64>() * per_token;
                    let prefill = (p.prompt.len() + p.generated.len()) as u64 * per_token;
                    if planned + prefill > cap {
                        break;
                    }
                }
            }
            let p = self.queue.pop_front().unwrap();
            let cache = self.engine.new_cache();
            self.active.push(Active {
                id: p.id,
                prompt: p.prompt,
                generated: p.generated,
                max_new: p.max_new,
                born: p.born,
                first_admit_step: p.first_admit_step.unwrap_or(step_no),
                prefills: p.prefills + 1,
                admit_seq: self.admit_counter,
                cache,
                pending_tokens: Vec::new(),
            });
            self.admit_counter += 1;
        }
        if self.active.is_empty() {
            return Ok(0);
        }

        // Evict oldest-admitted until this step's growth fits the cap.
        // `submit` guarantees a lone sequence always fits, so stopping at
        // one active sequence never over-commits.
        if let Some(cap) = self.budget {
            loop {
                let growth: u64 = self.active.iter().map(Active::next_news).sum::<u64>() * per_token;
                if self.kv_live_bytes() + growth <= cap || self.active.len() <= 1 {
                    break;
                }
                let oldest = self
                    .active
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, a)| a.admit_seq)
                    .map(|(i, _)| i)
                    .unwrap();
                let mut a = self.active.remove(oldest);
                a.cache.clear();
                self.queue.push_front(Pending {
                    id: a.id,
                    prompt: a.prompt,
                    generated: a.generated,
                    max_new: a.max_new,
                    born: a.born,
                    first_admit_step: Some(a.first_admit_step),
                    prefills: a.prefills,
                });
            }
        }

        // Refresh each sequence's pending tokens: the whole accumulated
        // context at (re-)prefill, else just the latest generated token.
        for a in &mut self.active {
            a.pending_tokens = if a.cache.tokens() == 0 {
                let mut t = a.prompt.clone();
                t.extend_from_slice(&a.generated);
                t
            } else {
                vec![*a.generated.last().expect("warm cache implies a generated token")]
            };
        }

        let mut entries: Vec<DecodeEntry<'_>> = self
            .active
            .iter_mut()
            .map(|a| DecodeEntry { cache: &mut a.cache, pending: &a.pending_tokens })
            .collect();
        let next = self.engine.decode(&mut entries)?;
        drop(entries);
        let advanced = next.len();
        for (a, t) in self.active.iter_mut().zip(next) {
            a.generated.push(t);
        }
        self.steps += 1;

        // Retire finished sequences; their KvCache drop releases the
        // metered bytes, freeing slots and budget for the next admit.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated.len() >= self.active[i].max_new {
                let a = self.active.remove(i);
                self.done.push(Completion {
                    id: a.id,
                    tokens: a.generated,
                    admitted_step: a.first_admit_step,
                    finished_step: step_no,
                    prefills: a.prefills,
                    latency_s: a.born.elapsed().as_secs_f64(),
                });
            } else {
                i += 1;
            }
        }
        Ok(advanced)
    }

    /// Step until every submitted request completes, with a hard cap to
    /// turn scheduler bugs into errors instead of hangs.
    pub fn run_to_completion(&mut self, max_steps: u64) -> Result<Vec<Completion>> {
        let mut budget = max_steps;
        while !self.is_idle() {
            ensure!(budget > 0, "scheduler did not drain within {max_steps} steps");
            budget -= 1;
            self.step()?;
        }
        Ok(self.take_completed())
    }
}

/// Deterministic synthetic request stream for benchmarks and tests:
/// `requests` prompts of `prompt_len` uniform tokens (seeded), arriving
/// one per `arrive_every` decode steps (0 = all at once), each asking
/// for `max_new` tokens.
#[derive(Debug, Clone)]
pub struct SyntheticLoad {
    pub requests: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub arrive_every: usize,
    pub seed: u64,
}

impl SyntheticLoad {
    /// The deterministic prompts this load submits.
    pub fn prompts(&self, vocab: usize) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(self.seed);
        (0..self.requests)
            .map(|_| (0..self.prompt_len).map(|_| rng.below(vocab) as i32).collect())
            .collect()
    }

    /// Drive `sched` through the whole stream and summarise throughput
    /// and latency. Token output is deterministic (seeded prompts +
    /// greedy bit-exact decode); only the timings vary run to run.
    pub fn run(&self, sched: &mut Scheduler) -> Result<ServeStats> {
        let vocab = sched.engine().hyper().vocab;
        let prompts = self.prompts(vocab);
        let wall = Instant::now();
        let mut stats = ServeStats::new();
        let mut submitted = 0usize;
        let mut tick = 0usize;
        while submitted < prompts.len() || !sched.is_idle() {
            while submitted < prompts.len()
                && (self.arrive_every == 0 || tick >= submitted * self.arrive_every)
            {
                sched.submit(&prompts[submitted], self.max_new)?;
                submitted += 1;
            }
            sched.step()?;
            tick += 1;
        }
        for c in sched.take_completed() {
            stats.record(c.latency_s, c.tokens.len() as u64);
        }
        stats.set_wall_seconds(wall.elapsed().as_secs_f64());
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Library;

    fn tiny_engine() -> InferenceEngine {
        InferenceEngine::init_random(Library::host_with_threads(1), "tiny", 11).unwrap()
    }

    #[test]
    fn budget_spec_grammar() {
        assert_eq!(kv_budget_from_spec(None).unwrap(), None);
        assert_eq!(kv_budget_from_spec(Some("")).unwrap(), None);
        assert_eq!(kv_budget_from_spec(Some("0")).unwrap(), None);
        assert_eq!(kv_budget_from_spec(Some("unlimited")).unwrap(), None);
        assert_eq!(kv_budget_from_spec(Some("64k")).unwrap(), Some(64 * 1024));
        assert_eq!(kv_budget_from_spec(Some("2m")).unwrap(), Some(2 * 1024 * 1024));
        let err = kv_budget_from_spec(Some("lots")).unwrap_err().to_string();
        assert!(err.contains("ADAMA_KV_BUDGET"), "error names the knob: {err}");
    }

    #[test]
    fn submit_rejects_impossible_requests() {
        let eng = tiny_engine();
        let seq = eng.hyper().seq;
        let mut s = Scheduler::with_budget(eng, 4, None);
        assert!(s.submit(&[], 4).is_err(), "empty prompt");
        assert!(s.submit(&[1, 2], 0).is_err(), "zero max_new");
        assert!(s.submit(&vec![1; seq], 1).is_err(), "context overflow");

        let eng = tiny_engine();
        let per = eng.kv_bytes_per_token();
        let mut s = Scheduler::with_budget(eng, 4, Some(3 * per));
        assert!(s.submit(&[1, 2], 3).is_err(), "needs 4 cached tokens, cap is 3");
        assert!(s.submit(&[1, 2], 2).is_ok(), "3 cached tokens fit exactly");
    }

    #[test]
    fn drains_queue_with_continuous_batching() {
        let mut s = Scheduler::with_budget(tiny_engine(), 2, None);
        for len in [3usize, 1, 2] {
            s.submit(&vec![5; len], 4).unwrap();
        }
        let mut done = s.run_to_completion(64).unwrap();
        assert_eq!(done.len(), 3);
        done.sort_by_key(|c| c.id);
        for c in &done {
            assert_eq!(c.tokens.len(), 4);
            assert_eq!(c.prefills, 1, "no evictions without a budget");
        }
        assert_eq!(s.kv_live_bytes(), 0, "retired caches release their bytes");
    }
}
