//! Batched inference serving: forward-only engine, KV cache, scheduler.
//!
//! Training (the AdamA side of this repo, arXiv:2305.19982) shrinks the
//! footprint of *activations and gradients*; serving keeps neither. This
//! module is the forward-only split of the host executor stack: an
//! [`InferenceEngine`] holds parameters plus three decode artifacts
//! (`embed_decode`, `block_decode`, `head_logits`) and nothing else — no
//! gradient buffers, no optimizer state, and the activation stash arena
//! is cleared on construction because no backward will ever replay it.
//!
//! What *does* grow at serving time is the KV cache, and it is treated
//! exactly the way the paper treats activations: as a metered client of
//! the backend's memory instrumentation. Every [`KvCache`] append and
//! release flows through [`crate::runtime::Executor::kv_alloc`] /
//! `kv_free`, so measured [`crate::runtime::MemStats::kv_live_bytes`]
//! reconciles byte-for-byte against the closed-form
//! `memmodel::HostBlockDims::kv_cache_bytes` — and a strict
//! `ADAMA_KV_BUDGET` cap (same grammar as `ADAMA_ACT_BUDGET`) bounds it,
//! with oldest-sequence eviction in the [`Scheduler`].
//!
//! # Contracts
//!
//! * **Bit-exact decode.** Token-by-token decode through the KV cache is
//!   bit-identical (0 ULP on logits) to the full-context forward at
//!   every thread count × SIMD level × GEMM mode, because the decode
//!   kernels replicate the forward's per-element reduction trees
//!   verbatim (`runtime::hostexec::transformer`). Verified in
//!   `rust/tests/serve.rs`.
//! * **Deterministic batching.** Ragged-batch rows are mathematically
//!   independent (per-row LayerNorm, per-output-element GEMM folds,
//!   per-sequence attention), so a request's tokens do not depend on
//!   which other requests shared its batches — any arrival interleaving
//!   yields the same output tokens.
//! * **Exact KV accounting.** `Scheduler` eviction and admission decide
//!   against the same byte formulas `memmodel` predicts; the measured
//!   and modelled KV bytes must agree exactly, not approximately.
//!
//! # Quickstart
//!
//! ```no_run
//! use adama::runtime::Library;
//! use adama::serve::{InferenceEngine, Scheduler, SyntheticLoad};
//!
//! # fn main() -> anyhow::Result<()> {
//! let lib = Library::host();
//! let engine = InferenceEngine::init_random(lib, "tiny", 42)?;
//! let mut sched = Scheduler::new(engine, /*max_batch=*/ 4)?;
//! let stats = SyntheticLoad {
//!     requests: 8,
//!     prompt_len: 8,
//!     max_new: 8,
//!     arrive_every: 1,
//!     seed: 7,
//! }
//! .run(&mut sched)?;
//! println!("{:.1} tok/s, p99 {:.3}s", stats.tokens_per_sec(), stats.p99());
//! # Ok(())
//! # }
//! ```

pub mod engine;
pub mod kv;
pub mod queue;

pub use engine::{DecodeEntry, InferenceEngine};
pub use kv::KvCache;
pub use queue::{
    kv_budget_from_env, kv_budget_from_spec, Completion, Scheduler, SyntheticLoad,
};
