//! Per-sequence KV cache — the serving engine's only growing state.
//!
//! One [`KvCache`] holds, per transformer block, the K and V rows of
//! every token the sequence has decoded so far — exactly the bits of the
//! block's `knew`/`vnew` outputs (qkv columns `h..2h` / `2h..3h`), which
//! is what makes incremental decode bit-identical to the full-context
//! forward (see `runtime::hostexec::transformer`).
//!
//! Every append and release is registered with the executing backend
//! ([`crate::runtime::Executor::kv_alloc`] / `kv_free`), so the KV cache
//! is *just another metered activation client*: the measured
//! [`crate::runtime::MemStats::kv_live_bytes`] reconciles byte-for-byte
//! against `memmodel::HostBlockDims::kv_cache_bytes` — a tested
//! invariant (`rust/tests/serve.rs`), like the stash arena's accounting.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::runtime::Executor;

/// Per-sequence, per-block key/value rows, metered through the backend.
pub struct KvCache {
    exec: Arc<dyn Executor>,
    hidden: usize,
    /// K rows per block: `[tokens, hidden]` row-major.
    k: Vec<Vec<f32>>,
    /// V rows per block, same layout.
    v: Vec<Vec<f32>>,
    /// Bytes currently registered with the backend's KV meter.
    registered: u64,
}

impl KvCache {
    /// Empty cache for a model with `blocks` transformer blocks of width
    /// `hidden`, metered through `exec`.
    pub fn new(exec: Arc<dyn Executor>, blocks: usize, hidden: usize) -> Self {
        Self {
            exec,
            hidden,
            k: vec![Vec::new(); blocks],
            v: vec![Vec::new(); blocks],
            registered: 0,
        }
    }

    pub fn blocks(&self) -> usize {
        self.k.len()
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    /// Cached tokens (rows per block). Uniform across blocks: the engine
    /// appends the same rows to every block each step.
    pub fn tokens(&self) -> usize {
        self.k.first().map_or(0, |rows| rows.len() / self.hidden)
    }

    /// Bytes this cache currently registers with the backend's KV meter:
    /// `blocks · tokens · 2 · hidden · 4` once populated — exactly
    /// `memmodel::HostBlockDims::kv_cache_bytes(blocks, tokens)`.
    pub fn bytes(&self) -> u64 {
        self.registered
    }

    /// The concatenated K rows of one block (`[tokens, hidden]`).
    pub fn k_rows(&self, block: usize) -> &[f32] {
        &self.k[block]
    }

    /// The concatenated V rows of one block (`[tokens, hidden]`).
    pub fn v_rows(&self, block: usize) -> &[f32] {
        &self.v[block]
    }

    /// Append freshly decoded K/V rows to one block's cache (the
    /// `knew`/`vnew` outputs of `block_decode`, verbatim bits) and meter
    /// the growth.
    pub fn append(&mut self, block: usize, knew: &[f32], vnew: &[f32]) -> Result<()> {
        ensure!(block < self.k.len(), "block {block} out of range 0..{}", self.k.len());
        ensure!(
            knew.len() == vnew.len() && !knew.is_empty() && knew.len() % self.hidden == 0,
            "KV append rows must be non-empty [n, {}] pairs",
            self.hidden
        );
        self.k[block].extend_from_slice(knew);
        self.v[block].extend_from_slice(vnew);
        let bytes = ((knew.len() + vnew.len()) * 4) as u64;
        self.exec.kv_alloc(bytes);
        self.registered += bytes;
        Ok(())
    }

    /// Drop every cached row and release the metered bytes (eviction
    /// under `ADAMA_KV_BUDGET`, or sequence retirement).
    pub fn clear(&mut self) {
        for rows in self.k.iter_mut().chain(self.v.iter_mut()) {
            rows.clear();
        }
        if self.registered > 0 {
            self.exec.kv_free(self.registered);
            self.registered = 0;
        }
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        if self.registered > 0 {
            self.exec.kv_free(self.registered);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{HostExecutor, MemoryPlan};

    fn host() -> Arc<dyn Executor> {
        Arc::new(HostExecutor::with_plan(1, MemoryPlan::remat()))
    }

    #[test]
    fn append_meters_and_drop_frees() {
        let exec = host();
        let h = 4usize;
        let mut c = KvCache::new(exec.clone(), 2, h);
        assert_eq!(c.tokens(), 0);
        let rows = vec![1.0f32; 3 * h];
        c.append(0, &rows, &rows).unwrap();
        c.append(1, &rows, &rows).unwrap();
        assert_eq!(c.tokens(), 3);
        // 2 blocks · 3 tokens · 2 (K+V) · h · 4 bytes
        let want = (2 * 3 * 2 * h * 4) as u64;
        assert_eq!(c.bytes(), want);
        assert_eq!(exec.memory().unwrap().kv_live_bytes, want);
        drop(c);
        let m = exec.memory().unwrap();
        assert_eq!(m.kv_live_bytes, 0);
        assert_eq!(m.kv_peak_bytes, want);
    }

    #[test]
    fn clear_releases_and_cache_is_reusable() {
        let exec = host();
        let mut c = KvCache::new(exec.clone(), 1, 2);
        c.append(0, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        c.clear();
        assert_eq!(c.tokens(), 0);
        assert_eq!(c.bytes(), 0);
        assert_eq!(exec.memory().unwrap().kv_live_bytes, 0);
        c.append(0, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        assert_eq!(c.tokens(), 1);
    }

    #[test]
    fn shape_errors_are_loud() {
        let mut c = KvCache::new(host(), 1, 4);
        assert!(c.append(1, &[0.0; 4], &[0.0; 4]).is_err(), "block out of range");
        assert!(c.append(0, &[0.0; 3], &[0.0; 3]).is_err(), "ragged row width");
        assert!(c.append(0, &[0.0; 4], &[0.0; 8]).is_err(), "K/V mismatch");
    }
}
