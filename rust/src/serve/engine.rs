//! Forward-only inference engine over the runtime seam.
//!
//! [`InferenceEngine`] is the serving counterpart of the trainer's
//! `ModelPrograms`: it loads only the three decode artifacts
//! (`embed_decode`, `block_decode`, `head_logits`), keeps no gradient
//! buffers or optimizer state, and clears the activation stash arena on
//! construction — eval mode holds parameters plus KV cache, nothing
//! else.
//!
//! One [`InferenceEngine::decode`] call advances a *ragged batch*: each
//! sequence contributes however many new tokens it has pending (a whole
//! prompt at prefill, one token thereafter) and the rows are packed
//! back-to-back with no padding, so prompt-length skew costs no FLOPs.
//! Decode through the per-sequence [`KvCache`] is bit-identical to the
//! full-context forward at every thread count × SIMD level × GEMM mode
//! (`rust/tests/serve.rs`).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::model::{checkpoint, ckpt::TrainState, init_params, LayerKind, LayerParams, ModelSpec};
use crate::memory::MemoryTracker;
use crate::runtime::{lit_f32, lit_i32, Library, ModelHyper, Program};

use super::kv::KvCache;

/// One sequence's slot in a ragged decode batch.
pub struct DecodeEntry<'a> {
    /// The sequence's KV cache; grows by `pending.len()` tokens per call.
    pub cache: &'a mut KvCache,
    /// New tokens to run this step: the whole prompt at prefill, then the
    /// single most recent token. Must be non-empty.
    pub pending: &'a [i32],
}

/// Forward-only engine: parameters + the three decode programs.
pub struct InferenceEngine {
    lib: Arc<Library>,
    spec: ModelSpec,
    params: Vec<LayerParams>,
    embed_decode: Arc<dyn Program>,
    block_decode: Arc<dyn Program>,
    head_logits: Arc<dyn Program>,
}

impl InferenceEngine {
    /// Engine for `config` with caller-supplied parameters (one flat
    /// vector per layer in spec order, as the trainer holds them).
    pub fn with_params(
        lib: Arc<Library>,
        config: &str,
        params: Vec<LayerParams>,
    ) -> Result<Self> {
        let entry = lib.manifest().model_config(config)?.clone();
        let spec = ModelSpec::from_manifest(config, &entry)?;
        ensure!(
            params.len() == spec.layers.len(),
            "'{config}' has {} layers, got {} parameter sets",
            spec.layers.len(),
            params.len()
        );
        for (l, p) in spec.layers.iter().zip(&params) {
            ensure!(
                p.flat.len() == l.flat_len,
                "layer '{}' expects {} parameters, got {}",
                l.name,
                l.flat_len,
                p.flat.len()
            );
        }
        let embed_decode = lib.get(&format!("{config}/embed_decode"))?;
        let block_decode = lib.get(&format!("{config}/block_decode"))?;
        let head_logits = lib.get(&format!("{config}/head_logits"))?;
        // Eval mode: no recompute plan will ever replay these layers, so
        // whatever the backend stashed for training is dead weight.
        lib.executor().clear_stash();
        Ok(Self { lib, spec, params, embed_decode, block_decode, head_logits })
    }

    /// Engine with freshly initialised parameters (demos, benchmarks).
    pub fn init_random(lib: Arc<Library>, config: &str, seed: u64) -> Result<Self> {
        let entry = lib.manifest().model_config(config)?.clone();
        let spec = ModelSpec::from_manifest(config, &entry)?;
        let params = init_params(&spec, seed, &MemoryTracker::new());
        Self::with_params(lib, config, params)
    }

    /// Load parameters from a checkpoint, sniffing the container format:
    /// `ADAMACK1` (params-only, `model::checkpoint`) and `ADAMACK2`
    /// (full train state, `model::ckpt` — optimizer moments, RNGs and
    /// loss history are simply not materialised here).
    pub fn from_checkpoint(lib: Arc<Library>, config: &str, path: &Path) -> Result<Self> {
        let entry = lib.manifest().model_config(config)?.clone();
        let spec = ModelSpec::from_manifest(config, &entry)?;
        let magic = {
            use std::io::Read;
            let mut f = std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?;
            let mut m = [0u8; 8];
            f.read_exact(&mut m).context("truncated checkpoint: no magic")?;
            m
        };
        let params = match &magic {
            b"ADAMACK1" => checkpoint::load(path, &spec)?,
            b"ADAMACK2" => {
                let ts = TrainState::load(path)?;
                ensure!(
                    ts.params.len() == spec.layers.len(),
                    "'{config}' has {} layers, checkpoint holds {}",
                    spec.layers.len(),
                    ts.params.len()
                );
                ts.params.into_iter().map(|flat| LayerParams { flat }).collect()
            }
            other => bail!(
                "{}: unknown checkpoint magic {:?} (want ADAMACK1 or ADAMACK2)",
                path.display(),
                String::from_utf8_lossy(other)
            ),
        };
        Self::with_params(lib, config, params)
    }

    pub fn hyper(&self) -> &ModelHyper {
        &self.spec.hyper
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn lib(&self) -> &Arc<Library> {
        &self.lib
    }

    /// KV bytes one decoded token pins across all blocks:
    /// `layers · 2 · hidden · 4` — `memmodel`'s
    /// `kv_bytes_per_token_per_layer` summed over the stack.
    pub fn kv_bytes_per_token(&self) -> u64 {
        (self.spec.hyper.layers * 2 * self.spec.hyper.hidden * 4) as u64
    }

    /// Fresh empty cache metered through this engine's backend.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(
            self.lib.executor().clone(),
            self.spec.hyper.layers,
            self.spec.hyper.hidden,
        )
    }

    /// Advance every sequence in `batch` by its pending tokens and
    /// return the greedy (argmax, first-max-wins — matching
    /// `math::softmax_xent`'s tie-break) next token per sequence.
    pub fn decode(&self, batch: &mut [DecodeEntry<'_>]) -> Result<Vec<i32>> {
        Ok(self.decode_logits(batch)?.1)
    }

    /// As [`decode`](Self::decode), also returning the raw logits of
    /// each sequence's last position (`[batch, vocab]` row-major) — the
    /// bit-exactness tests compare these at 0 ULP against the
    /// full-context forward.
    pub fn decode_logits(&self, batch: &mut [DecodeEntry<'_>]) -> Result<(Vec<f32>, Vec<i32>)> {
        let hy = &self.spec.hyper;
        let (v, h) = (hy.vocab, hy.hidden);
        let nseq = batch.len();
        ensure!(nseq > 0, "decode batch is empty");

        // Snapshot cache lengths BEFORE any append: `lens`/positions must
        // describe the context as the attention kernels will see it.
        let start_lens: Vec<usize> = batch.iter().map(|e| e.cache.tokens()).collect();
        let news: Vec<i32> = batch
            .iter()
            .map(|e| {
                ensure!(!e.pending.is_empty(), "sequence with no pending tokens");
                Ok(e.pending.len() as i32)
            })
            .collect::<Result<_>>()?;
        for (e, &l) in batch.iter().zip(&start_lens) {
            ensure!(
                e.cache.blocks() == hy.layers && e.cache.hidden() == h,
                "cache shape mismatch: {} blocks × hidden {} (model wants {} × {})",
                e.cache.blocks(),
                e.cache.hidden(),
                hy.layers,
                h
            );
            ensure!(
                l + e.pending.len() <= hy.seq,
                "sequence would reach {} tokens; '{}' caps context at {}",
                l + e.pending.len(),
                self.spec.config,
                hy.seq
            );
        }
        let n: usize = news.iter().map(|&x| x as usize).sum();
        let p: usize = start_lens.iter().sum();

        // Ragged token/position rows, packed back-to-back (no padding).
        let mut tokens = Vec::with_capacity(n);
        let mut pos = Vec::with_capacity(n);
        for (e, &l) in batch.iter().zip(&start_lens) {
            for (i, &t) in e.pending.iter().enumerate() {
                tokens.push(t);
                pos.push((l + i) as i32);
            }
        }

        let embed = &self.spec.layers[0];
        ensure!(embed.kind == LayerKind::Embed, "layer 0 must be the embedding");
        let out = self.embed_decode.run_v(&[
            lit_i32(&tokens, &[n])?,
            lit_i32(&pos, &[n])?,
            lit_f32(self.params[0].view(&embed.params[0]), &embed.params[0].shape)?,
            lit_f32(self.params[0].view(&embed.params[1]), &embed.params[1].shape)?,
        ])?;
        let mut x = out.into_iter().next().context("embed_decode output")?;

        let lens_v = lit_i32(
            &start_lens.iter().map(|&l| l as i32).collect::<Vec<i32>>(),
            &[nseq],
        )?;
        let news_v = lit_i32(&news, &[nseq])?;
        for b in 0..hy.layers {
            let layer = &self.spec.layers[1 + b];
            ensure!(layer.kind == LayerKind::Block(b), "layer {} must be block {b}", 1 + b);
            // Concatenate the per-sequence caches for this block into the
            // packed [p, hidden] context the kernel consumes.
            let mut kcat = Vec::with_capacity(p * h);
            let mut vcat = Vec::with_capacity(p * h);
            for e in batch.iter() {
                kcat.extend_from_slice(e.cache.k_rows(b));
                vcat.extend_from_slice(e.cache.v_rows(b));
            }
            let mut args = vec![
                x,
                news_v.clone(),
                lens_v.clone(),
                lit_f32(&kcat, &[p, h])?,
                lit_f32(&vcat, &[p, h])?,
            ];
            for pv in &layer.params {
                args.push(lit_f32(self.params[1 + b].view(pv), &pv.shape)?);
            }
            let mut out = self.block_decode.run_v(&args)?;
            ensure!(out.len() == 3, "block_decode must return [y, knew, vnew]");
            let vnew = out.pop().unwrap();
            let knew = out.pop().unwrap();
            x = out.pop().unwrap();
            let (knew, vnew) = (knew.as_f32()?, vnew.as_f32()?);
            let mut row = 0usize;
            for (e, &nw) in batch.iter_mut().zip(&news) {
                let nw = nw as usize;
                e.cache.append(
                    b,
                    &knew[row * h..(row + nw) * h],
                    &vnew[row * h..(row + nw) * h],
                )?;
                row += nw;
            }
        }

        // Only each sequence's final position feeds the head.
        let xf = x.as_f32()?;
        let mut xlast = Vec::with_capacity(nseq * h);
        let mut row = 0usize;
        for &nw in &news {
            row += nw as usize;
            xlast.extend_from_slice(&xf[(row - 1) * h..row * h]);
        }
        let head = self.spec.layers.last().context("model has no head layer")?;
        ensure!(head.kind == LayerKind::Head, "last layer must be the head");
        let out = self.head_logits.run_v(&[
            lit_f32(&xlast, &[nseq, h])?,
            lit_f32(
                self.params.last().unwrap().view(&head.params[0]),
                &head.params[0].shape,
            )?,
        ])?;
        let logits = out.into_iter().next().context("head_logits output")?;
        let logits = logits.as_f32()?.to_vec();

        let mut next = Vec::with_capacity(nseq);
        for r in 0..nseq {
            let rowv = &logits[r * v..(r + 1) * v];
            let mut best = 0usize;
            for (j, &val) in rowv.iter().enumerate() {
                if val > rowv[best] {
                    best = j;
                }
            }
            next.push(best as i32);
        }
        Ok((logits, next))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Library;

    #[test]
    fn with_params_rejects_wrong_layer_count() {
        let lib = Library::host_with_threads(1);
        let err = InferenceEngine::with_params(lib, "tiny", Vec::new()).unwrap_err();
        assert!(err.to_string().contains("layers"), "{err}");
    }

    #[test]
    fn from_checkpoint_rejects_unknown_magic() {
        let lib = Library::host_with_threads(1);
        let dir = std::env::temp_dir().join(format!("adama_serve_magic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bogus.ck");
        std::fs::write(&path, b"NOTACKPT????????").unwrap();
        let err = InferenceEngine::from_checkpoint(lib, "tiny", &path).unwrap_err();
        assert!(err.to_string().contains("unknown checkpoint magic"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn decode_rejects_empty_batch_and_overlong_context() {
        let lib = Library::host_with_threads(1);
        let eng = InferenceEngine::init_random(lib, "tiny", 7).unwrap();
        assert!(eng.decode(&mut []).is_err());
        let seq = eng.hyper().seq;
        let mut cache = eng.new_cache();
        let prompt: Vec<i32> = vec![1; seq + 1];
        let err = eng
            .decode(&mut [DecodeEntry { cache: &mut cache, pending: &prompt }])
            .unwrap_err();
        assert!(err.to_string().contains("caps context"), "{err}");
    }
}
