//! Synthetic workloads: learnable token corpora for the LM and Gaussian
//! blob classification for the MLP (the paper's ImageNet substitute).
//!
//! The LM corpus is a sparse Markov chain: each vocab state transitions to
//! `k` fixed successors with fixed weights. Entropy is ≈ ln(k), far below
//! ln(V), so a transformer that learns the transition table drives the
//! loss from ln(V) toward ln(k) — giving a real, visible convergence curve
//! for Figure-2 style experiments.

use crate::tensor::Rng;

/// One micro-batch of LM training data: `tokens[B,S]` and next-token
/// `labels[B,S]` (both row-major flattened).
#[derive(Debug, Clone)]
pub struct MicroBatch {
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Sparse-transition Markov corpus generator.
#[derive(Debug, Clone)]
pub struct MarkovCorpus {
    vocab: usize,
    successors: Vec<[usize; 4]>,
    weights: [f32; 4],
    rng: Rng,
}

impl MarkovCorpus {
    /// Build a corpus with a fixed random transition structure derived
    /// from `structure_seed`; `stream_seed` controls the sample stream so
    /// different workers/epochs draw different text from the *same*
    /// language.
    pub fn new(vocab: usize, structure_seed: u64, stream_seed: u64) -> Self {
        let mut srng = Rng::new(structure_seed);
        let successors = (0..vocab)
            .map(|_| {
                [srng.below(vocab), srng.below(vocab), srng.below(vocab), srng.below(vocab)]
            })
            .collect();
        Self {
            vocab,
            successors,
            weights: [0.5, 0.25, 0.15, 0.1],
            rng: Rng::new(stream_seed),
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The sample-stream cursor (checkpointing seam). The transition
    /// structure is derived purely from `structure_seed`, so the stream
    /// RNG is the *only* mutable state a resume has to restore.
    pub fn rng(&self) -> &Rng {
        &self.rng
    }

    /// Restore the sample-stream cursor captured by [`MarkovCorpus::rng`].
    pub fn set_rng(&mut self, rng: Rng) {
        self.rng = rng;
    }

    /// Theoretical per-token cross-entropy of the generating process —
    /// the floor the LM loss approaches.
    pub fn entropy(&self) -> f32 {
        -self.weights.iter().map(|w| w * w.ln()).sum::<f32>()
    }

    fn next_token(&mut self, state: usize) -> usize {
        let k = self.rng.categorical(&self.weights);
        self.successors[state][k]
    }

    /// Sample one `[batch, seq]` micro-batch with next-token labels.
    pub fn microbatch(&mut self, batch: usize, seq: usize) -> MicroBatch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut state = self.rng.below(self.vocab);
            for _ in 0..seq {
                tokens.push(state as i32);
                state = self.next_token(state);
                labels.push(state as i32);
            }
        }
        MicroBatch { tokens, labels, batch, seq }
    }

    /// Sample a full mini-batch as `n_micro` micro-batches.
    pub fn minibatch(&mut self, n_micro: usize, batch: usize, seq: usize) -> Vec<MicroBatch> {
        (0..n_micro).map(|_| self.microbatch(batch, seq)).collect()
    }
}

/// A different downstream "language" built on the same vocab — used by the
/// Table-1 style pretrain→finetune parity experiments. Cycles with skips:
/// token t -> (t + stride) mod V with occasional restarts.
#[derive(Debug, Clone)]
pub struct CycleCorpus {
    vocab: usize,
    stride: usize,
    restart_p: f32,
    rng: Rng,
}

impl CycleCorpus {
    pub fn new(vocab: usize, stride: usize, stream_seed: u64) -> Self {
        Self { vocab, stride: stride.max(1), restart_p: 0.05, rng: Rng::new(stream_seed) }
    }

    pub fn microbatch(&mut self, batch: usize, seq: usize) -> MicroBatch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut state = self.rng.below(self.vocab);
            for _ in 0..seq {
                tokens.push(state as i32);
                state = if self.rng.uniform() < self.restart_p {
                    self.rng.below(self.vocab)
                } else {
                    (state + self.stride) % self.vocab
                };
                labels.push(state as i32);
            }
        }
        MicroBatch { tokens, labels, batch, seq }
    }

    pub fn minibatch(&mut self, n_micro: usize, batch: usize, seq: usize) -> Vec<MicroBatch> {
        (0..n_micro).map(|_| self.microbatch(batch, seq)).collect()
    }
}

/// Gaussian-blob classification set (vision substitute, Fig. 3 / 7a).
#[derive(Debug, Clone)]
pub struct BlobData {
    pub features: usize,
    pub classes: usize,
    centers: Vec<Vec<f32>>,
    noise: f32,
    rng: Rng,
}

/// One classification micro-batch: `x[B,F]` features, `y[B]` labels.
#[derive(Debug, Clone)]
pub struct BlobBatch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
}

impl BlobData {
    pub fn new(features: usize, classes: usize, structure_seed: u64, stream_seed: u64) -> Self {
        Self::with_noise(features, classes, structure_seed, stream_seed, 0.8)
    }

    /// `noise` controls the per-sample gradient-noise regime: large noise
    /// (≳ 2) puts training in the noise-dominated regime where AdamA and
    /// Adam coincide (paper Fig. 3/4); tiny noise approaches the
    /// mean-dominated limit where AdamA's v is ~N× smaller.
    pub fn with_noise(
        features: usize,
        classes: usize,
        structure_seed: u64,
        stream_seed: u64,
        noise: f32,
    ) -> Self {
        let mut srng = Rng::new(structure_seed);
        let centers = (0..classes)
            .map(|_| (0..features).map(|_| 2.0 * srng.normal()).collect())
            .collect();
        Self { features, classes, centers, noise, rng: Rng::new(stream_seed) }
    }

    pub fn batch(&mut self, batch: usize) -> BlobBatch {
        let mut x = Vec::with_capacity(batch * self.features);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = self.rng.below(self.classes);
            y.push(c as i32);
            for f in 0..self.features {
                x.push(self.centers[c][f] + self.noise * self.rng.normal());
            }
        }
        BlobBatch { x, y, batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markov_shapes_and_ranges() {
        let mut c = MarkovCorpus::new(64, 1, 2);
        let mb = c.microbatch(4, 16);
        assert_eq!(mb.tokens.len(), 64);
        assert_eq!(mb.labels.len(), 64);
        assert!(mb.tokens.iter().all(|&t| (0..64).contains(&(t as usize))));
    }

    #[test]
    fn labels_are_next_tokens() {
        let mut c = MarkovCorpus::new(32, 3, 4);
        let mb = c.microbatch(2, 8);
        // within a row, token[i+1] == label[i]
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(mb.tokens[row * 8 + i + 1], mb.labels[row * 8 + i]);
            }
        }
    }

    #[test]
    fn labels_follow_transition_structure() {
        let mut c = MarkovCorpus::new(64, 7, 8);
        let succ = c.successors.clone();
        let mb = c.microbatch(8, 32);
        for i in 0..mb.tokens.len() {
            let s = mb.tokens[i] as usize;
            let l = mb.labels[i] as usize;
            assert!(succ[s].contains(&l), "label {l} not a successor of {s}");
        }
    }

    #[test]
    fn entropy_below_uniform() {
        let c = MarkovCorpus::new(256, 1, 2);
        assert!(c.entropy() < (256f32).ln());
        assert!(c.entropy() > 0.5);
    }

    #[test]
    fn same_structure_different_stream() {
        let mut a = MarkovCorpus::new(64, 9, 1);
        let mut b = MarkovCorpus::new(64, 9, 2);
        assert_eq!(a.successors, b.successors);
        assert_ne!(a.microbatch(2, 8).tokens, b.microbatch(2, 8).tokens);
    }

    #[test]
    fn blobs_are_separable() {
        let mut d = BlobData::new(8, 3, 11, 12);
        let b = d.batch(64);
        assert_eq!(b.x.len(), 64 * 8);
        // same-class points are closer to their center than to others (mostly)
        let mut correct = 0;
        for i in 0..64 {
            let x = &b.x[i * 8..(i + 1) * 8];
            let mut best = (f32::INFINITY, 0usize);
            for (c, ctr) in d.centers.iter().enumerate() {
                let dist: f32 = x.iter().zip(ctr).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == b.y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 48, "only {correct}/64 nearest-center correct");
    }

    #[test]
    fn cycle_corpus_mostly_strided() {
        let mut c = CycleCorpus::new(64, 5, 3);
        let mb = c.microbatch(4, 32);
        let mut strided = 0;
        for i in 0..mb.tokens.len() {
            if (mb.tokens[i] as usize + 5) % 64 == mb.labels[i] as usize {
                strided += 1;
            }
        }
        assert!(strided as f32 > 0.8 * mb.tokens.len() as f32);
    }
}
