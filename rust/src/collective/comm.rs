//! Ring collectives over in-process channels.
//!
//! `CommGroup::new(M)` yields one [`CommHandle`] per rank; handles move
//! into worker threads. All collectives are synchronous and must be
//! entered by every rank (like NCCL). Byte counters record the volume a
//! real interconnect would carry: ring all-reduce moves
//! `2·(M-1)/M · bytes` per rank per call.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

/// Aggregate communication statistics for a group (shared by all ranks).
///
/// Counters are attributed at **completion**: an op lands in the ledger
/// only when the collective returns to (or, for a fabric ticket, is
/// waited by) the issuing rank — never at issue time. Under async issue a
/// step-end snapshot taken after every ticket has been waited therefore
/// can never observe a half-counted in-flight op, and the
/// serial==channel==fabric ledger equality holds with overlap enabled.
#[derive(Debug, Default)]
pub struct CommStats {
    /// Total payload bytes sent over the ring (all ranks).
    pub bytes_sent: AtomicU64,
    /// Number of collective operations completed.
    pub ops: AtomicU64,
}

impl CommStats {
    pub fn bytes(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn op_count(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }
}

/// One rank's endpoint in the ring.
pub struct CommHandle {
    rank: usize,
    world: usize,
    to_next: Sender<Vec<f32>>,
    from_prev: Receiver<Vec<f32>>,
    stats: Arc<CommStats>,
}

/// Factory for ring-connected handles.
pub struct CommGroup;

impl CommGroup {
    /// Create `world` ring-connected handles (rank i sends to i+1 mod M).
    pub fn new(world: usize) -> Vec<CommHandle> {
        assert!(world >= 1);
        let stats = Arc::new(CommStats::default());
        let mut senders = Vec::with_capacity(world);
        let mut receivers = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        // rank i's receiver gets what rank i-1 sends
        let mut handles: Vec<CommHandle> = Vec::with_capacity(world);
        let mut receivers: Vec<Option<Receiver<Vec<f32>>>> =
            receivers.into_iter().map(Some).collect();
        for rank in 0..world {
            let to_next = senders[(rank + 1) % world].clone();
            let from_prev = receivers[rank].take().unwrap();
            handles.push(CommHandle {
                rank,
                world,
                to_next,
                from_prev,
                stats: stats.clone(),
            });
        }
        handles
    }
}

impl CommHandle {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    fn send(&self, data: Vec<f32>) -> Result<()> {
        self.stats.bytes_sent.fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        self.to_next.send(data).context("ring send (peer gone)")
    }

    fn recv(&self) -> Result<Vec<f32>> {
        self.from_prev.recv().context("ring recv (peer gone)")
    }

    /// Contiguous shard ranges for a buffer of `len` across the world.
    pub fn shard_ranges(len: usize, world: usize) -> Vec<std::ops::Range<usize>> {
        let base = len / world;
        let rem = len % world;
        let mut out = Vec::with_capacity(world);
        let mut off = 0;
        for r in 0..world {
            let sz = base + usize::from(r < rem);
            out.push(off..off + sz);
            off += sz;
        }
        out
    }

    /// Ring all-reduce (sum) in place. All ranks must call with equal-length
    /// buffers; on return every rank holds the element-wise sum.
    pub fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        if self.world > 1 {
            let m = self.world;
            let shards = Self::shard_ranges(data.len(), m);

            // phase 1: reduce-scatter. After M-1 steps rank r owns the full
            // sum of shard (r+1) mod M.
            for step in 0..m - 1 {
                let send_idx = (self.rank + m - step) % m;
                let recv_idx = (self.rank + m - step - 1) % m;
                self.send(data[shards[send_idx].clone()].to_vec())?;
                let incoming = self.recv()?;
                ensure!(incoming.len() == shards[recv_idx].len(), "ring shard size mismatch");
                for (dst, src) in data[shards[recv_idx].clone()].iter_mut().zip(&incoming) {
                    *dst += src;
                }
            }
            // phase 2: all-gather the reduced shards.
            for step in 0..m - 1 {
                let send_idx = (self.rank + 1 + m - step) % m;
                let recv_idx = (self.rank + m - step) % m;
                self.send(data[shards[send_idx].clone()].to_vec())?;
                let incoming = self.recv()?;
                data[shards[recv_idx].clone()].copy_from_slice(&incoming);
            }
        }
        self.stats.ops.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// All-reduce then scale by `1/world` (mean) — Eq. 7's m-averaging.
    pub fn all_reduce_mean(&self, data: &mut [f32]) -> Result<()> {
        self.all_reduce_sum(data)?;
        let inv = 1.0 / self.world as f32;
        for x in data.iter_mut() {
            *x *= inv;
        }
        Ok(())
    }

    /// Reduce-scatter (sum): on return, `data`'s own shard holds the sum
    /// across ranks; the returned range identifies it. Other regions are
    /// left partially reduced (callers must not read them).
    pub fn reduce_scatter_sum(&self, data: &mut [f32]) -> Result<std::ops::Range<usize>> {
        let m = self.world;
        let shards = Self::shard_ranges(data.len(), m);
        if m > 1 {
            for step in 0..m - 1 {
                let send_idx = (self.rank + m - step) % m;
                let recv_idx = (self.rank + m - step - 1) % m;
                self.send(data[shards[send_idx].clone()].to_vec())?;
                let incoming = self.recv()?;
                for (dst, src) in data[shards[recv_idx].clone()].iter_mut().zip(&incoming) {
                    *dst += src;
                }
            }
        }
        self.stats.ops.fetch_add(1, Ordering::Relaxed);
        // after M-1 steps, rank r owns shard (r+1) mod M
        Ok(shards[(self.rank + 1) % m].clone())
    }

    /// All-gather: each rank contributes its shard (as defined by
    /// [`Self::shard_ranges`] index `owner`); on return the whole buffer
    /// is consistent on every rank. `owner_of` maps shard index -> the
    /// rank that owns it, matching [`Self::reduce_scatter_sum`] layout.
    pub fn all_gather_owned(&self, data: &mut [f32]) -> Result<()> {
        let m = self.world;
        if m > 1 {
            let shards = Self::shard_ranges(data.len(), m);
            // rank r owns shard (r+1) mod M (reduce_scatter layout)
            for step in 0..m - 1 {
                let send_idx = (self.rank + 1 + m - step) % m;
                let recv_idx = (self.rank + m - step) % m;
                self.send(data[shards[send_idx].clone()].to_vec())?;
                let incoming = self.recv()?;
                data[shards[recv_idx].clone()].copy_from_slice(&incoming);
            }
        }
        self.stats.ops.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Barrier (token ring, twice around).
    pub fn barrier(&self) -> Result<()> {
        for _ in 0..2 {
            self.send(vec![])?;
            self.recv()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_world<F>(m: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(CommHandle) -> Vec<f32> + Send + Sync + Clone + 'static,
    {
        let handles = CommGroup::new(m);
        let mut joins = Vec::new();
        for h in handles {
            let f = f.clone();
            joins.push(std::thread::spawn(move || f(h)));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        for m in [1, 2, 3, 4, 8] {
            let out = run_world(m, move |h| {
                let mut data: Vec<f32> =
                    (0..10).map(|i| (h.rank() * 100 + i) as f32).collect();
                h.all_reduce_sum(&mut data).unwrap();
                data
            });
            let want: Vec<f32> = (0..10)
                .map(|i| (0..m).map(|r| (r * 100 + i) as f32).sum())
                .collect();
            for r in 0..m {
                assert_eq!(out[r], want, "world {m} rank {r}");
            }
        }
    }

    #[test]
    fn all_reduce_mean_divides() {
        let out = run_world(4, |h| {
            let mut data = vec![h.rank() as f32; 5];
            h.all_reduce_mean(&mut data).unwrap();
            data
        });
        for r in 0..4 {
            assert_eq!(out[r], vec![1.5; 5]);
        }
    }

    #[test]
    fn uneven_lengths_still_reduce() {
        // len 7 not divisible by world 3
        let out = run_world(3, |h| {
            let mut data = vec![1.0f32; 7];
            h.all_reduce_sum(&mut data).unwrap();
            data
        });
        for r in 0..3 {
            assert_eq!(out[r], vec![3.0; 7]);
        }
    }

    #[test]
    fn reduce_scatter_then_gather_equals_allreduce() {
        let out = run_world(4, |h| {
            let mut data: Vec<f32> = (0..16).map(|i| (i + h.rank()) as f32).collect();
            let own = h.reduce_scatter_sum(&mut data).unwrap();
            // zero everything except the owned shard, then gather
            let owned: Vec<f32> = data[own.clone()].to_vec();
            for (i, x) in data.iter_mut().enumerate() {
                if !own.contains(&i) {
                    *x = f32::NAN;
                }
            }
            data[own.clone()].copy_from_slice(&owned);
            h.all_gather_owned(&mut data).unwrap();
            data
        });
        let want: Vec<f32> = (0..16).map(|i| (0..4).map(|r| (i + r) as f32).sum()).collect();
        for r in 0..4 {
            assert_eq!(out[r], want, "rank {r}");
        }
    }

    #[test]
    fn ring_volume_matches_theory() {
        // all-reduce moves 2*(M-1)/M * bytes per rank
        let m = 4;
        let n = 1024usize;
        let handles = CommGroup::new(m);
        let stats = handles[0].stats().clone();
        let mut joins = Vec::new();
        for h in handles {
            joins.push(std::thread::spawn(move || {
                let mut data = vec![1.0f32; n];
                h.all_reduce_sum(&mut data).unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let want = (2 * (m - 1) * n * 4) as u64; // summed over all ranks: M * 2(M-1)/M * bytes
        assert_eq!(stats.bytes(), want);
    }

    #[test]
    fn shard_ranges_cover() {
        let r = CommHandle::shard_ranges(10, 3);
        assert_eq!(r, vec![0..4, 4..7, 7..10]);
        let r = CommHandle::shard_ranges(8, 4);
        assert_eq!(r.iter().map(|x| x.len()).sum::<usize>(), 8);
    }

    #[test]
    fn barrier_does_not_deadlock() {
        run_world(3, |h| {
            for _ in 0..5 {
                h.barrier().unwrap();
            }
            vec![]
        });
    }
}
