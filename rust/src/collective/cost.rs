//! α-β communication cost model at DGX scale.
//!
//! The in-process ring reproduces collective *math* and *volume*; wall
//! clock on a CPU testbed says nothing about NVLink. For Figure-7-style
//! projections at paper scale we price each collective with the classic
//! α-β model: `T = α·(steps) + bytes/β`, parameterised per DGX system.

/// Interconnect + compute envelope of one cluster configuration.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub gpus: usize,
    /// Per-GPU memory capacity (bytes) — Table 3's constraint.
    pub mem_bytes: u64,
    /// All-reduce bandwidth per GPU (bytes/s).
    pub bw: f64,
    /// Per-collective latency (s).
    pub alpha: f64,
    /// Sustained training compute per GPU (FLOP/s) for step-time estims.
    pub flops: f64,
}

impl ClusterSpec {
    /// DGX-1: 8× V100-16GB, NVLink gen1.
    pub fn dgx1() -> Self {
        Self {
            name: "DGX-1",
            gpus: 8,
            mem_bytes: 16 << 30,
            bw: 100e9,
            alpha: 10e-6,
            flops: 15e12,
        }
    }

    /// DGX-2: 16× V100-32GB, NVSwitch.
    pub fn dgx2() -> Self {
        Self {
            name: "DGX-2",
            gpus: 16,
            mem_bytes: 32 << 30,
            bw: 200e9,
            alpha: 10e-6,
            flops: 15e12,
        }
    }

    /// DGX A100: 8× A100-80GB, NVSwitch gen2.
    pub fn dgx_a100() -> Self {
        Self {
            name: "DGX A100",
            gpus: 8,
            mem_bytes: 80 << 30,
            bw: 300e9,
            alpha: 8e-6,
            flops: 120e12,
        }
    }

    pub const ALL: [fn() -> ClusterSpec; 3] = [Self::dgx1, Self::dgx2, Self::dgx_a100];
}

/// Prices collectives on a [`ClusterSpec`].
#[derive(Debug, Clone, Copy)]
pub struct CommCostModel {
    pub cluster: ClusterSpec,
}

impl CommCostModel {
    pub fn new(cluster: ClusterSpec) -> Self {
        Self { cluster }
    }

    /// Ring all-reduce time for `bytes` payload across `m` ranks.
    pub fn all_reduce(&self, bytes: u64, m: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let steps = 2 * (m - 1);
        let wire = 2.0 * (m as f64 - 1.0) / m as f64 * bytes as f64;
        steps as f64 * self.cluster.alpha + wire / self.cluster.bw
    }

    /// Reduce-scatter or all-gather: half an all-reduce.
    pub fn half_collective(&self, bytes: u64, m: usize) -> f64 {
        self.all_reduce(bytes, m) / 2.0
    }

    /// Compute time for one micro-batch fwd+bwd: ~6·P·tokens FLOPs.
    pub fn microbatch_compute(&self, params: u64, tokens: u64) -> f64 {
        6.0 * params as f64 * tokens as f64 / self.cluster.flops
    }

    /// Mini-batch step time under a given sync strategy.
    ///
    /// * `n` micro-batches, `tokens` per micro-batch, `params` model size.
    /// * `state_syncs` all-reduces of `state_bytes` per step (AdamA: 2·P·4
    ///   once; grad sync: P·4 once (GA) or N times (naive)).
    pub fn step_time(
        &self,
        params: u64,
        n: usize,
        tokens: u64,
        sync_bytes_per_step: u64,
        syncs_per_step: usize,
    ) -> f64 {
        let compute = n as f64 * self.microbatch_compute(params, tokens);
        let comm = syncs_per_step as f64
            * self.all_reduce(sync_bytes_per_step / syncs_per_step.max(1) as u64, self.cluster.gpus);
        compute + comm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_scales_with_bytes_and_world() {
        let m = CommCostModel::new(ClusterSpec::dgx_a100());
        let t1 = m.all_reduce(1 << 30, 8);
        let t2 = m.all_reduce(2 << 30, 8);
        assert!(t2 > t1 * 1.8 && t2 < t1 * 2.2);
        assert_eq!(m.all_reduce(1 << 30, 1), 0.0);
    }

    #[test]
    fn state_sync_beats_naive_grad_sync_for_large_n() {
        // the paper's §3.3 argument: O(1) state all-reduce vs O(N) grads
        let m = CommCostModel::new(ClusterSpec::dgx_a100());
        let p = 340_000_000u64; // BERT-Large
        let n = 8;
        // AdamA state sync: one all-reduce of 2P floats
        let adama = m.all_reduce(2 * p * 4, 8);
        // naive per-micro-batch grad sync: N all-reduces of P floats
        let naive = n as f64 * m.all_reduce(p * 4, 8);
        // standard GA: one all-reduce of P floats
        let ga = m.all_reduce(p * 4, 8);
        assert!(adama < naive, "O(1) vs O(N)");
        assert!(adama <= 2.1 * ga, "state sync costs ~2x grads, constant in N");
    }

    #[test]
    fn dgx_presets_sane() {
        for f in ClusterSpec::ALL {
            let c = f();
            assert!(c.mem_bytes >= 16 << 30);
            assert!(c.bw > 0.0 && c.flops > 0.0 && c.gpus >= 8);
        }
    }

    #[test]
    fn compute_time_positive_and_linear() {
        let m = CommCostModel::new(ClusterSpec::dgx1());
        let a = m.microbatch_compute(1_000_000, 4096);
        let b = m.microbatch_compute(2_000_000, 4096);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
