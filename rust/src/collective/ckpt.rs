//! Distributed (world) checkpoints for the DP/ZeRO runners.
//!
//! A world checkpoint at step `N` is a *directory* `step{N:08}/` under the
//! checkpoint root holding one `rank{r}.ck2` per rank (that rank's RNG
//! data cursor and its optimizer-state shard) plus a `world.ck2` manifest
//! (replicated params, loss history, comm-ledger snapshot, flow tag). All
//! files are `ADAMACK2` containers ([`crate::model::ckpt`]) written
//! atomically; the **manifest is written last, by rank 0**, so its
//! presence is the commit marker — a crash at any earlier point leaves a
//! directory that [`latest_valid`] recognizes as incomplete and skips in
//! favor of the next older checkpoint.
//!
//! The write protocol ([`write_world`]) needs exactly two barriers:
//!
//! 1. every rank creates the step directory (racing `create_dir_all` is
//!    fine) and atomically writes its own rank file;
//! 2. **barrier** — all rank files exist, and no rank can issue further
//!    ledger-visible traffic until the manifest is cut;
//! 3. rank 0 snapshots the comm ledger (stable: barriers record no bytes
//!    and no ops on any engine), writes `world.ck2`, and rotates old
//!    checkpoints out;
//! 4. **barrier** — peers resume only once the checkpoint is committed.
//!
//! Resharding: the manifest records the *saved* world size `M`, and rank
//! files store ZeRO-S1 owned shards in the `(r+1) mod M` layout of
//! [`CommHandle::shard_ranges`]. [`unshard_layer`] reassembles a full
//! buffer from all `M` shards and [`shard_slice`] re-cuts it for a new
//! world size `N`, so `N` ranks can deterministically resume a
//! world-of-`M` checkpoint.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::comm::CommHandle;
use super::Collective;
use crate::coordinator::checkpoint;
use crate::model::ckpt::{
    decode_f32s, decode_layers, decode_rngs, encode_f32s, encode_layers, encode_rngs, put_u64,
    u64_section, Container, OptSnapshot, SEC_FPRINT, SEC_LOSSES, SEC_OPT, SEC_PARAMS, SEC_RNGS,
    SEC_STEP,
};
use crate::tensor::Rng;

/// Manifest-only: the saved world size `M`.
pub const SEC_WORLD: &str = "WORLD";
/// Rank-file-only: which rank wrote the file.
pub const SEC_RANK: &str = "RANK";
/// Manifest-only: the flow tag (e.g. `dp:state-allreduce`, `zero1:adama`)
/// — a resumed run must re-enter the same flow.
pub const SEC_FLOW: &str = "FLOW";
/// Manifest-only: comm-ledger snapshot, `bytes u64 LE ++ ops u64 LE`.
pub const SEC_LEDGER: &str = "LEDGER";

/// Canonical rank-file name inside a step directory.
pub fn rank_file(step_dir: &Path, rank: usize) -> PathBuf {
    step_dir.join(format!("rank{rank}.ck2"))
}

/// Canonical manifest name inside a step directory.
pub fn manifest_file(step_dir: &Path) -> PathBuf {
    step_dir.join("world.ck2")
}

/// One rank's private state at a checkpoint cut.
#[derive(Debug, Clone)]
pub struct RankState {
    pub rank: usize,
    /// The rank's data-stream RNG cursor.
    pub rng: Rng,
    /// The rank's optimizer-state shard (flow-specific tag and layout).
    pub opt: OptSnapshot,
}

/// The world-level manifest payload — supplied by rank 0 only.
#[derive(Debug, Clone)]
pub struct WorldMeta {
    pub flow: String,
    /// Replicated parameters (identical on every rank by the sync
    /// invariant; rank 0's copy is written).
    pub params: Vec<Vec<f32>>,
    /// Per-step loss history, one entry per completed step.
    pub losses: Vec<f32>,
}

/// A fully parsed world checkpoint.
#[derive(Debug, Clone)]
pub struct WorldState {
    pub fingerprint: u64,
    pub step: u64,
    /// The world size the checkpoint was *saved* at (`M`); a resume may
    /// run a different world size and reshard.
    pub world: usize,
    pub flow: String,
    pub params: Vec<Vec<f32>>,
    pub losses: Vec<f32>,
    /// `(bytes, ops)` comm-ledger snapshot at the cut — the base a
    /// resumed run adds its fresh board's stats to, so a recovered run's
    /// final ledger equals an uninterrupted run's.
    pub ledger: (u64, u64),
    /// Per-rank states, index == rank, exactly `world` entries.
    pub ranks: Vec<RankState>,
}

/// One rank's side of the two-barrier world-checkpoint protocol (module
/// docs). Every rank passes its own `mine`; rank 0 — and only rank 0 —
/// additionally passes the manifest payload. `ledger_base` is the ledger
/// snapshot of the checkpoint this run resumed from (zeros for a fresh
/// run). Callers must have waited out all in-flight async tickets first.
#[allow(clippy::too_many_arguments)]
pub fn write_world<C: Collective + ?Sized>(
    comm: &C,
    root: &Path,
    keep: usize,
    fingerprint: u64,
    step: u64,
    mine: &RankState,
    meta: Option<&WorldMeta>,
    ledger_base: (u64, u64),
) -> Result<()> {
    ensure!(
        mine.rank == comm.rank(),
        "write_world: rank state says rank {}, collective handle says rank {}",
        mine.rank,
        comm.rank()
    );
    ensure!(
        (comm.rank() == 0) == meta.is_some(),
        "write_world: rank 0 (and only rank 0) supplies the manifest payload"
    );
    let dir = checkpoint::step_dir(root, step);
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let mut c = Container::new();
    c.push(SEC_FPRINT, fingerprint.to_le_bytes().to_vec());
    c.push(SEC_STEP, step.to_le_bytes().to_vec());
    c.push(SEC_RANK, (mine.rank as u64).to_le_bytes().to_vec());
    c.push(SEC_RNGS, encode_rngs(std::slice::from_ref(&mine.rng)));
    c.push(SEC_OPT, mine.opt.encode());
    c.write_atomic(&rank_file(&dir, mine.rank))?;
    comm.barrier()?;
    if let Some(meta) = meta {
        // Stable snapshot: every rank has finished its step traffic (it
        // reached the barrier above) and can only be blocked in the
        // barrier below, and barriers are ledger-invisible on every
        // engine — so no stat can move under this read.
        let stats = comm.stats();
        let ledger = (ledger_base.0 + stats.bytes(), ledger_base.1 + stats.op_count());
        let mut m = Container::new();
        m.push(SEC_FPRINT, fingerprint.to_le_bytes().to_vec());
        m.push(SEC_STEP, step.to_le_bytes().to_vec());
        m.push(SEC_WORLD, (comm.world() as u64).to_le_bytes().to_vec());
        m.push(SEC_FLOW, meta.flow.as_bytes().to_vec());
        m.push(SEC_PARAMS, encode_layers(&meta.params));
        m.push(SEC_LOSSES, encode_f32s(&meta.losses));
        let mut lb = Vec::with_capacity(16);
        put_u64(&mut lb, ledger.0);
        put_u64(&mut lb, ledger.1);
        m.push(SEC_LEDGER, lb);
        m.write_atomic(&manifest_file(&dir))?;
        checkpoint::rotate(root, keep)?;
    }
    comm.barrier()?;
    Ok(())
}

/// Strictly load the world checkpoint in step directory `dir`: manifest
/// first, then every rank file the manifest promises, cross-checking each
/// one's fingerprint / step / rank stamp.
pub fn load_world(dir: &Path) -> Result<WorldState> {
    let mc = Container::read(&manifest_file(dir))?;
    let fingerprint = u64_section(&mc, SEC_FPRINT)?;
    let step = u64_section(&mc, SEC_STEP)?;
    let world = u64_section(&mc, SEC_WORLD)? as usize;
    ensure!(world >= 1, "world checkpoint claims {world} ranks");
    let flow = String::from_utf8(mc.get(SEC_FLOW)?.to_vec())
        .context("FLOW section: invalid utf-8")?;
    let params = decode_layers(mc.get(SEC_PARAMS)?)?;
    let losses = decode_f32s(mc.get(SEC_LOSSES)?)?;
    let lb = mc.get(SEC_LEDGER)?;
    ensure!(lb.len() == 16, "LEDGER section must be 16 bytes, got {}", lb.len());
    let ledger = (
        u64::from_le_bytes(lb[..8].try_into().unwrap()),
        u64::from_le_bytes(lb[8..].try_into().unwrap()),
    );
    let mut ranks = Vec::with_capacity(world);
    for r in 0..world {
        let path = rank_file(dir, r);
        let rc = Container::read(&path)?;
        let ctx = || format!("rank file {}", path.display());
        ensure!(
            u64_section(&rc, SEC_FPRINT)? == fingerprint,
            "{}: fingerprint differs from the manifest",
            ctx()
        );
        ensure!(
            u64_section(&rc, SEC_STEP)? == step,
            "{}: step differs from the manifest",
            ctx()
        );
        let stamped = u64_section(&rc, SEC_RANK)? as usize;
        ensure!(stamped == r, "{}: stamped rank {stamped}, expected {r}", ctx());
        let rngs = decode_rngs(rc.get(SEC_RNGS)?)?;
        ensure!(rngs.len() == 1, "{}: wanted 1 rng cursor, got {}", ctx(), rngs.len());
        let opt = OptSnapshot::decode(rc.get(SEC_OPT)?)?;
        ranks.push(RankState { rank: r, rng: rngs[0].clone(), opt });
    }
    Ok(WorldState { fingerprint, step, world, flow, params, losses, ledger, ranks })
}

/// Newest *fully valid* world checkpoint under `root`. Entries are probed
/// newest-first; one that fails to parse — a crash before the manifest
/// commit, a corrupted section, a missing rank file, a step stamp that
/// contradicts the directory name — is skipped in favor of the next older
/// one. Single-rank `.ck2` files are not world checkpoints and are
/// skipped too.
pub fn latest_valid(root: &Path) -> Result<Option<(u64, WorldState)>> {
    for (step, path) in checkpoint::list_steps(root)?.into_iter().rev() {
        if !path.is_dir() {
            continue;
        }
        if let Ok(ws) = load_world(&path) {
            if ws.step == step {
                return Ok(Some((step, ws)));
            }
        }
    }
    Ok(None)
}

/// Reassemble one layer's full buffer from the per-rank owned ZeRO-S1
/// shards: `shards[r]` is rank `r`-of-`shards.len()`'s slice, and rank
/// `r` owns `shard_ranges(len, world)[(r+1) % world]`.
pub fn unshard_layer(len: usize, shards: &[Vec<f32>]) -> Result<Vec<f32>> {
    let world = shards.len();
    ensure!(world >= 1, "unshard_layer needs at least one shard");
    let ranges = CommHandle::shard_ranges(len, world);
    let mut full = vec![0.0f32; len];
    for (r, s) in shards.iter().enumerate() {
        let range = ranges[(r + 1) % world].clone();
        if s.len() != range.len() {
            bail!(
                "rank {r} shard has {} element(s), the (r+1) mod {world} layout of a \
                 {len}-element layer wants {}",
                s.len(),
                range.len()
            );
        }
        full[range].copy_from_slice(s);
    }
    Ok(full)
}

/// Rank `rank`-of-`world`'s owned slice of a full buffer (same layout as
/// [`unshard_layer`]) — the re-cut side of resharding.
pub fn shard_slice(full: &[f32], rank: usize, world: usize) -> Vec<f32> {
    let ranges = CommHandle::shard_ranges(full.len(), world);
    full[ranges[(rank + 1) % world].clone()].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Fabric;

    #[test]
    fn write_load_roundtrip_and_latest_valid() {
        let root = std::env::temp_dir().join(format!("adama_wck_{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        let m = 2;
        for step in [2u64, 4] {
            let handles = Fabric::new(m);
            let mut joins = Vec::new();
            for h in handles {
                let root = root.clone();
                joins.push(std::thread::spawn(move || {
                    let rank = h.rank();
                    // real traffic so the ledger snapshot is nonzero
                    let mut d = vec![1.0f32; 8];
                    h.all_reduce_sum(&mut d).unwrap();
                    let mine = RankState {
                        rank,
                        rng: Rng::from_state(100 + rank as u64, None),
                        opt: OptSnapshot {
                            tag: "zero:adama".into(),
                            t: step,
                            bufs: vec![vec![rank as f32; 3]],
                        },
                    };
                    let meta = (rank == 0).then(|| WorldMeta {
                        flow: "zero1:adama".into(),
                        params: vec![vec![1.0, 2.0], vec![3.0; 3]],
                        losses: (0..step).map(|s| s as f32).collect(),
                    });
                    write_world(&h, &root, 2, 0xABCD, step, &mine, meta.as_ref(), (7, 3))
                        .unwrap();
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
        }
        let (step, ws) = latest_valid(&root).unwrap().expect("a valid checkpoint");
        assert_eq!(step, 4);
        assert_eq!(ws.fingerprint, 0xABCD);
        assert_eq!(ws.world, 2);
        assert_eq!(ws.flow, "zero1:adama");
        assert_eq!(ws.params, vec![vec![1.0, 2.0], vec![3.0; 3]]);
        assert_eq!(ws.losses, vec![0.0, 1.0, 2.0, 3.0]);
        // ledger = base (7, 3) + one all-reduce per rank on this board:
        // m=2, len 8 → 32 wire bytes and 1 op per rank
        assert_eq!(ws.ledger, (7 + 2 * 32, 3 + 2));
        assert_eq!(ws.ranks.len(), 2);
        assert_eq!(ws.ranks[0].rng, Rng::from_state(100, None));
        assert_eq!(ws.ranks[1].opt.bufs, vec![vec![1.0f32; 3]]);
        // both steps retained under keep=2, the write is discoverable via
        // the shared rotation machinery
        assert_eq!(checkpoint::list_steps(&root).unwrap().len(), 2);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn latest_valid_skips_incomplete_and_corrupt_entries() {
        let root = std::env::temp_dir().join(format!("adama_wckv_{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        // step 1: a complete single-rank world checkpoint
        {
            let mut handles = Fabric::new(1);
            let h = handles.pop().unwrap();
            let mine = RankState {
                rank: 0,
                rng: Rng::from_state(1, None),
                opt: OptSnapshot { tag: "adama".into(), t: 1, bufs: vec![] },
            };
            let meta = WorldMeta {
                flow: "dp:state-allreduce".into(),
                params: vec![vec![0.5]],
                losses: vec![1.0],
            };
            write_world(&h, &root, 8, 0x11, 1, &mine, Some(&meta), (0, 0)).unwrap();
        }
        // step 2: rank file only — crashed before the manifest commit
        let d2 = checkpoint::step_dir(&root, 2);
        std::fs::create_dir_all(&d2).unwrap();
        std::fs::write(rank_file(&d2, 0), b"half-written").unwrap();
        // step 3: manifest present but corrupt
        let d3 = checkpoint::step_dir(&root, 3);
        std::fs::create_dir_all(&d3).unwrap();
        std::fs::write(manifest_file(&d3), b"garbage").unwrap();

        let (step, ws) = latest_valid(&root).unwrap().expect("falls back to the valid one");
        assert_eq!(step, 1);
        assert_eq!(ws.flow, "dp:state-allreduce");
        // an empty root is a clean None, not an error
        std::fs::remove_dir_all(&root).ok();
        assert!(latest_valid(&root).unwrap().is_none());
    }

    #[test]
    fn shard_unshard_roundtrip() {
        for &world in &[1usize, 2, 3, 5] {
            for &len in &[0usize, 1, 4, 7, 13] {
                let full: Vec<f32> = (0..len).map(|i| i as f32 * 0.5 - 1.0).collect();
                let shards: Vec<Vec<f32>> =
                    (0..world).map(|r| shard_slice(&full, r, world)).collect();
                let back = unshard_layer(len, &shards).unwrap();
                assert_eq!(back, full, "world {world} len {len}");
            }
        }
        // a shard that does not fit the layout is an error naming the rank
        let err = unshard_layer(4, &[vec![0.0; 3], vec![0.0; 1]]).unwrap_err();
        assert!(format!("{err}").contains("rank 0"), "{err}");
    }
}
