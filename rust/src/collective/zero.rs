//! ZeRO-S1 (`P_os`) substrate + its AdamA combination (paper §4.2, Fig 6b,
//! Table 3).
//!
//! Optimizer states are partitioned: rank `r` owns, for every layer, the
//! contiguous shard that ring reduce-scatter leaves fully reduced on it.
//! Two flows:
//!
//! * **ZeRO-S1 + AdamA** — every layer gradient of every micro-batch is
//!   reduce-scattered the moment it exists (the paper's
//!   release-immediately overlap: the collective is issued inside the
//!   backward's gradient sink, while later layers are still to come); the
//!   owner integrates its shard into its (m, v) shard and the gradient is
//!   released (grad peak = one layer, activation peak = one micro-batch,
//!   states = 2P/M). The micro-batch granularity becomes *global* (M-way
//!   averaged), i.e. AdamA with N effective micro-batches of M× size —
//!   still Alg. 2 semantics. Comm: 2·N half-collectives per layer per
//!   step (the ~5% throughput cost the paper reports for this combo).
//! * **ZeRO-S1 + GA** — the DeepSpeed baseline: full local gradient
//!   accumulator (P floats), one reduce-scatter at mini-batch end, shard
//!   update, param all-gather.
//! * **ZeRO-S1 + zoo rule** (exec-layer seam: `ADAMA_OPT` /
//!   [`Zero1Spec::with_opt`]) — the optimizer-zoo rules composed with the
//!   paper's trick: every layer gradient is reduce-scattered per
//!   micro-batch and folded linearly into a *sharded* state-resident
//!   accumulator, then released. At mini-batch end `adam` updates its
//!   (m, v) shards and all-gathers parameters; the sublinear rules
//!   (adafactor / sm3 / adam_mini) all-gather the accumulator shards back
//!   into the full mean gradient and apply the replicated-statistics rule
//!   identically on every rank — no parameter gather needed.
//!
//! All flows run on any [`CollectiveEngine`] — concurrent fabric
//! (default), channel ring, or the serial simulator — with bit-identical
//! results (`rust/tests/fabric_parity.rs`).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use super::ckpt as wckpt;
use super::comm::CommHandle;
use super::fabric::{serial, Fabric, FaultPlan, PeerDeath, Ticket, Topology};
use super::{rank_threads, Collective, CollectiveEngine, CommGroup, CommStats};
use crate::config::{OptimBackend, OptimizerKind, TrainConfig};
use crate::coordinator::{CheckpointPolicy, MemorySnapshot, Trainer, WorldMemory};
use crate::data::{MarkovCorpus, MicroBatch};
use crate::memory::{Allocation, Category, MemoryReport, MemoryTracker};
use crate::model::ckpt::{config_fingerprint, OptSnapshot};
use crate::model::ModelSpec;
use crate::optim::{host_math, Hyper, NullOpt, UpdateBackend, ZooStates};
use crate::runtime::{Library, OptAlgo};

#[derive(Debug, Clone)]
pub struct Zero1Spec {
    pub cfg: TrainConfig,
    pub steps: u64,
    pub data_seed: u64,
    /// Execution engine (default: the concurrent fabric).
    pub engine: CollectiveEngine,
    /// Host pool threads per rank; 0 (default) = split the default pool
    /// (`ADAMA_THREADS`) evenly across ranks.
    pub threads_per_rank: usize,
    /// Reduction topology; `None` = `ADAMA_FABRIC` (default ring).
    pub topology: Option<Topology>,
    /// Async issue of the per-layer reduce-scatter (AdamA flow): `None` =
    /// `ADAMA_ASYNC` (default off). Pure scheduling knob — sync and async
    /// runs are bit-identical, ledgers included.
    pub async_issue: Option<bool>,
    /// Gradient-bucket threshold in bytes for the async flow: `None` =
    /// `ADAMA_BUCKET_BYTES` (default 0 = every gradient issues its own
    /// collective). Boundaries depend only on layer sizes, so every rank
    /// cuts identical buckets.
    pub bucket_bytes: Option<usize>,
    /// Exec-layer optimizer override for every rank
    /// ([`Library::fork_with_opt`]); `None` inherits the launch library's
    /// seam (`ADAMA_OPT` / `host_with_opt`). With a zoo rule resolved the
    /// run takes the sharded-accumulator zoo flow instead of AdamA/GA.
    pub opt: Option<OptAlgo>,
    /// World checkpointing: directory + cadence/retention. `None` =
    /// resolve the strict `ADAMA_CKPT_DIR` / `ADAMA_CKPT_EVERY` /
    /// `ADAMA_CKPT_KEEP` knobs (all unset = off). Rank files carry the
    /// ZeRO-S1 owned state shards, so a resume may reshard to a
    /// different world size ([`super::ckpt`]).
    pub checkpoint: Option<(PathBuf, CheckpointPolicy)>,
    /// Resume from the newest valid world checkpoint under the
    /// checkpoint directory before training (requires `checkpoint`);
    /// absent any valid checkpoint the run starts fresh.
    pub resume: bool,
    /// Deterministic rank death for crash-recovery drills; `None` = the
    /// strict `ADAMA_FAULT` knob (unset = none). Fabric engine only.
    pub fault: Option<FaultPlan>,
}

impl Zero1Spec {
    pub fn new(cfg: TrainConfig, steps: u64, data_seed: u64) -> Self {
        Self {
            cfg,
            steps,
            data_seed,
            engine: CollectiveEngine::Fabric,
            threads_per_rank: 0,
            topology: None,
            async_issue: None,
            bucket_bytes: None,
            opt: None,
            checkpoint: None,
            resume: false,
            fault: None,
        }
    }

    pub fn with_engine(mut self, engine: CollectiveEngine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_topology(mut self, topo: Topology) -> Self {
        self.topology = Some(topo);
        self
    }

    pub fn with_rank_threads(mut self, threads: usize) -> Self {
        self.threads_per_rank = threads;
        self
    }

    pub fn with_async(mut self, async_issue: bool) -> Self {
        self.async_issue = Some(async_issue);
        self
    }

    pub fn with_bucket_bytes(mut self, bytes: usize) -> Self {
        self.bucket_bytes = Some(bytes);
        self
    }

    pub fn with_opt(mut self, opt: OptAlgo) -> Self {
        self.opt = Some(opt);
        self
    }

    pub fn with_checkpoint(mut self, dir: impl Into<PathBuf>, policy: CheckpointPolicy) -> Self {
        self.checkpoint = Some((dir.into(), policy));
        self
    }

    pub fn with_resume(mut self) -> Self {
        self.resume = true;
        self
    }

    pub fn with_fault(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }
}

#[derive(Debug, Clone)]
pub struct Zero1Report {
    pub losses: Vec<f32>,
    pub final_params: Vec<Vec<f32>>,
    pub comm_bytes: u64,
    pub comm_ops: u64,
    pub elapsed_s: f64,
    /// Rank-0 coordinator tracker peaks (back-compat convenience).
    pub memory: MemoryReport,
    /// Coordinator + executor peaks for every rank, in rank order.
    pub per_rank_memory: Vec<MemorySnapshot>,
    pub engine: CollectiveEngine,
    /// `Some(step)` when the (possibly supervisor-restarted) run that
    /// produced this report started from a step-`step` world checkpoint.
    pub resumed_from: Option<u64>,
}

impl Zero1Report {
    /// Per-rank snapshots with world-level aggregation.
    pub fn world_memory(&self) -> WorldMemory {
        WorldMemory::new(self.per_rank_memory.clone())
    }
}

/// Per-worker partitioned Adam state.
struct ShardState {
    /// Owned range per layer (reduce-scatter layout: shard (rank+1) mod M).
    ranges: Vec<std::ops::Range<usize>>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    hyper: Hyper,
    backend: UpdateBackend,
}

impl ShardState {
    fn new(
        spec: &ModelSpec,
        rank: usize,
        world: usize,
        hyper: Hyper,
        backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let owner = (rank + 1) % world;
        let ranges: Vec<_> = spec
            .layers
            .iter()
            .map(|l| CommHandle::shard_ranges(l.flat_len, world)[owner].clone())
            .collect();
        let m: Vec<Vec<f32>> = ranges.iter().map(|r| vec![0.0; r.len()]).collect();
        let v = m.clone();
        let bytes: usize = ranges.iter().map(|r| r.len() * 8).sum();
        tracker.alloc_raw(Category::OptimizerStates, bytes);
        Self { ranges, m, v, hyper, backend }
    }

    fn decay(&mut self, vfactor: f32) -> Result<()> {
        let (b1, b2) = (self.hyper.beta1, self.hyper.beta2);
        for (m, v) in self.m.iter_mut().zip(self.v.iter_mut()) {
            self.backend.adama_decay(m, v, b1, vfactor * b2)?;
        }
        Ok(())
    }

    fn integrate(&mut self, layer: usize, shard_grad: &[f32], gscale: f32) -> Result<()> {
        self.backend.adama_acc(&mut self.m[layer], &mut self.v[layer], shard_grad, gscale)
    }

    fn adam_full_shard(
        &mut self,
        layer: usize,
        p: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        self.backend
            .adam_full(p, &mut self.m[layer], &mut self.v[layer], g, lr, bc1, bc2)
    }

    fn update_shard(&mut self, layer: usize, p: &mut [f32], lr: f32, bc1: f32, bc2: f32) -> Result<()> {
        self.backend.adam_update(p, &self.m[layer], &self.v[layer], lr, bc1, bc2)
    }
}

/// Run ZeRO-S1 training: `cfg.optimizer` selects AdamA (combined scheme)
/// or AdamGA (DeepSpeed-style baseline).
pub fn run_zero1(lib: Arc<Library>, spec: Zero1Spec) -> Result<Zero1Report> {
    spec.cfg.validate()?;
    let m = spec.cfg.workers;
    if m < 2 {
        bail!("ZeRO-S1 needs >= 2 workers");
    }
    // normalize the exec-layer seam once, before the ranks fork: a spec
    // override beats the ambient `ADAMA_OPT`; `None` inherits it. A
    // resolved zoo rule takes the sharded-accumulator zoo flow.
    let lib = match spec.opt {
        Some(algo) => lib.fork_with_opt(Some(algo)),
        None => lib,
    };
    if lib.executor().opt_algo().is_none() {
        match spec.cfg.optimizer {
            OptimizerKind::AdamA | OptimizerKind::AdamGA => {}
            k => bail!("ZeRO-S1 supports adama|adamga, got {:?}", k),
        }
    }
    let topo = match spec.topology {
        Some(t) => t,
        None => Topology::from_env()?,
    };
    // resolve the scheduling knobs once, before the workers fork, so every
    // rank (and the serial oracle) sees one strictly-parsed decision
    let mut spec = spec;
    if spec.async_issue.is_none() {
        spec.async_issue = Some(super::fabric::async_from_env()?);
    }
    if spec.bucket_bytes.is_none() {
        spec.bucket_bytes = Some(super::fabric::bucket_bytes_from_env()?);
    }
    if spec.checkpoint.is_none() {
        spec.checkpoint = crate::coordinator::checkpoint::from_env()?;
    }
    if spec.fault.is_none() {
        spec.fault = FaultPlan::from_env()?;
    }
    let tpr = rank_threads(spec.threads_per_rank, m)?;
    if spec.engine == CollectiveEngine::Serial {
        ensure!(
            spec.checkpoint.is_none() && !spec.resume && spec.fault.is_none(),
            "the serial engine does not drive checkpoints, resume, or fault injection — \
             use the fabric or channel engine"
        );
        return run_zero_serial(lib, spec, topo, tpr);
    }
    if let Some(f) = spec.fault {
        ensure!(
            spec.engine == CollectiveEngine::Fabric,
            "fault injection requires the fabric engine (got '{}')",
            spec.engine.name()
        );
        ensure!(
            f.rank < m,
            "fault plan names rank {} but the world has {m} rank(s)",
            f.rank
        );
    }
    let flow = match lib.executor().opt_algo() {
        Some(algo) => format!("zero1:zoo:{}", algo.name()),
        None => match spec.cfg.optimizer {
            OptimizerKind::AdamA => "zero1:adama".to_string(),
            _ => "zero1:adamga".to_string(),
        },
    };
    let mut resume_ws: Option<Arc<wckpt::WorldState>> = None;
    if spec.resume {
        let (dir, _) = spec.checkpoint.as_ref().context(
            "resume requires a checkpoint directory (ADAMA_CKPT_DIR / Zero1Spec::with_checkpoint)",
        )?;
        resume_ws = wckpt::latest_valid(dir)?.map(|(_, ws)| Arc::new(ws));
    }
    // Supervisor loop: run the world; when a rank dies (injected fault or
    // real defect) and checkpoints are configured, restart every rank
    // from the newest valid world checkpoint with the fault disarmed.
    let mut fault_arm = spec.fault;
    let mut attempts = 0usize;
    loop {
        if let Some(ws) = resume_ws.as_deref() {
            ensure!(
                ws.flow == flow,
                "checkpoint was written by flow '{}', this run is '{flow}'",
                ws.flow
            );
        }
        let res = match spec.engine {
            CollectiveEngine::Channel => {
                // the channel ring's fold order *is* the ring topology; a
                // tree request must not be silently downgraded
                super::ensure_ring_only(topo)?;
                let handles = CommGroup::new(m);
                run_zero_threaded(lib.clone(), spec.clone(), handles, tpr, resume_ws.clone())
            }
            CollectiveEngine::Fabric => {
                let handles = Fabric::with_topology(m, topo);
                if let Some(f) = fault_arm {
                    handles[f.rank].arm_fault(f);
                }
                run_zero_threaded(lib.clone(), spec.clone(), handles, tpr, resume_ws.clone())
            }
            CollectiveEngine::Serial => unreachable!("serial handled above"),
        };
        match res {
            Ok(report) => return Ok(report),
            Err(e) => {
                let died = e.chain().any(|c| c.downcast_ref::<PeerDeath>().is_some());
                let Some((dir, _)) = spec.checkpoint.as_ref() else { return Err(e) };
                attempts += 1;
                if !died || attempts >= 3 {
                    return Err(e);
                }
                resume_ws = wckpt::latest_valid(dir)?.map(|(_, ws)| Arc::new(ws));
                fault_arm = None;
            }
        }
    }
}

fn run_zero_threaded<C: Collective + 'static>(
    lib: Arc<Library>,
    spec: Zero1Spec,
    handles: Vec<C>,
    tpr: usize,
    resume: Option<Arc<wckpt::WorldState>>,
) -> Result<Zero1Report> {
    let stats = handles[0].stats().clone();
    // fresh handles carry fresh ledgers; a resumed run reports the
    // checkpointed ledger plus what this attempt adds, which is exactly
    // the straight-run ledger (abandoned partial steps are re-done)
    let ledger_base = resume.as_deref().map(|ws| ws.ledger).unwrap_or((0, 0));
    let resumed_from = resume.as_deref().map(|ws| ws.step);
    let t0 = Instant::now();

    let mut joins = Vec::new();
    for comm in handles {
        // Per-rank fork: pins the host pool to `tpr` workers per rank (see
        // `run_data_parallel`) and gives each rank a private activation
        // arena when stashing is enabled — same bits either way.
        let lib = lib.fork_with_threads(tpr);
        let spec = spec.clone();
        let resume = resume.clone();
        // the seam travels with the fork, so the per-rank library decides
        // the flow exactly as `run_zero1`'s gate did
        joins.push(std::thread::spawn(move || match lib.executor().opt_algo() {
            Some(algo) => worker_zoo(lib, spec, algo, comm, resume),
            None => match spec.cfg.optimizer {
                OptimizerKind::AdamA => worker_adama(lib, spec, comm, resume),
                OptimizerKind::AdamGA => worker_ga(lib, spec, comm, resume),
                k => bail!("ZeRO-S1 supports adama|adamga, got {:?}", k),
            },
        }));
    }
    // Join every rank before surfacing an error: bailing on the first
    // Err would detach still-running peer threads mid-collective. A
    // rank death outranks the survivors' collateral errors — it is the
    // root cause and the one the supervisor can recover from.
    let mut results: Vec<WorkerOut> = Vec::new();
    let mut death: Option<anyhow::Error> = None;
    let mut other: Option<anyhow::Error> = None;
    for j in joins {
        let joined = j.join().map_err(|_| anyhow::anyhow!("zero1 worker panicked"));
        match joined.and_then(|r| r) {
            Ok(out) => results.push(out),
            Err(e) if e.chain().any(|c| c.downcast_ref::<PeerDeath>().is_some()) => {
                death.get_or_insert(e);
            }
            Err(e) => {
                other.get_or_insert(e);
            }
        }
    }
    if let Some(e) = death {
        return Err(e);
    }
    if let Some(e) = other {
        return Err(e);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    let r0 = &results[0];
    for (r, out) in results.iter().enumerate().skip(1) {
        for (l, (a, b)) in r0.params.iter().zip(&out.params).enumerate() {
            ensure!(a == b, "rank {r} layer {l} diverged after all-gather");
        }
    }
    Ok(Zero1Report {
        losses: r0.losses.clone(),
        final_params: r0.params.clone(),
        comm_bytes: ledger_base.0 + stats.bytes(),
        comm_ops: ledger_base.1 + stats.op_count(),
        elapsed_s,
        memory: r0.mem.tracker,
        per_rank_memory: results.iter().map(|r| r.mem).collect(),
        engine: spec.engine,
        resumed_from,
    })
}

struct WorkerOut {
    losses: Vec<f32>,
    params: Vec<Vec<f32>>,
    mem: MemorySnapshot,
}

fn make_backend(cfg: &TrainConfig, lib: &Arc<Library>) -> Result<UpdateBackend> {
    let hyper = Hyper::from_manifest(lib.manifest());
    Ok(match cfg.backend {
        OptimBackend::Kernel => UpdateBackend::kernel(lib.clone(), cfg.chunk)?,
        OptimBackend::Host => UpdateBackend::host(hyper),
    })
}

fn snapshot(trainer: &Trainer, tracker: &MemoryTracker) -> MemorySnapshot {
    MemorySnapshot {
        tracker: tracker.report(),
        host: trainer.library().executor().memory(),
    }
}

/// Optimizer-snapshot tag of the ZeRO-S1 + AdamA flow (`bufs` = per-layer
/// `m` shards then per-layer `v` shards, owned-shard layout).
const TAG_ZERO_ADAMA: &str = "zero:adama";
/// Same layout for the ZeRO-S1 + GA flow (the accumulator is zeroed at
/// every step start, so only (m, v) live across a step boundary).
const TAG_ZERO_ADAMGA: &str = "zero:adamga";

/// Flow-agnostic half of a ZeRO resume: fingerprint gate, per-rank
/// snapshot tag/step cross-checks, replicated parameters, step counter,
/// and this rank's data cursor (a rank the saved world did not have
/// starts its own stream from scratch).
fn resume_restore(
    trainer: &mut Trainer,
    corpus: &mut MarkovCorpus,
    ws: &wckpt::WorldState,
    tag: &str,
    rank: usize,
) -> Result<()> {
    let want = config_fingerprint(trainer.spec(), trainer.config(), tag);
    ensure!(
        ws.fingerprint == want,
        "checkpoint fingerprint {:#018x} does not match this run's {want:#018x} — \
         model/optimizer/schedule changed since the save",
        ws.fingerprint
    );
    for (r, rs) in ws.ranks.iter().enumerate() {
        ensure!(
            rs.opt.tag == tag,
            "rank {r} optimizer snapshot is '{}', this flow wants '{tag}'",
            rs.opt.tag
        );
        ensure!(
            rs.opt.t == ws.step,
            "rank {r} snapshot step {} contradicts the manifest step {}",
            rs.opt.t,
            ws.step
        );
    }
    let n_layers = trainer.spec().layers.len();
    ensure!(
        ws.params.len() == n_layers,
        "checkpoint holds {} layer(s), the model has {n_layers}",
        ws.params.len()
    );
    for (l, saved) in ws.params.iter().enumerate() {
        let flat = &mut trainer.params_mut()[l].flat;
        ensure!(
            flat.len() == saved.len(),
            "layer {l}: checkpoint holds {} element(s), the model wants {}",
            saved.len(),
            flat.len()
        );
        flat.copy_from_slice(saved);
    }
    trainer.set_step(ws.step);
    if rank < ws.world {
        corpus.set_rng(ws.ranks[rank].rng.clone());
    }
    Ok(())
}

/// Re-cut saved shard buffers for this rank at the current world size.
/// Every saved rank holds `bufs = [group₀ layer₀.., group₁ layer₀..]` —
/// `per / n_layers` groups (e.g. m then v) of one owned shard per layer
/// in the `(r+1) mod M` layout; the groups are reassembled layer by
/// layer ([`wckpt::unshard_layer`]) and re-sliced for `rank`-of-`world`.
fn reshard_bufs(
    ws: &wckpt::WorldState,
    lens: &[usize],
    rank: usize,
    world: usize,
) -> Result<Vec<Vec<f32>>> {
    let n_layers = lens.len();
    let per = ws.ranks[0].opt.bufs.len();
    ensure!(
        n_layers > 0 && per % n_layers == 0,
        "snapshot holds {per} shard buffer(s) for {n_layers} layer(s)"
    );
    for (r, rs) in ws.ranks.iter().enumerate() {
        ensure!(
            rs.opt.bufs.len() == per,
            "rank {r} snapshot has {} shard buffer(s), rank 0 has {per}",
            rs.opt.bufs.len()
        );
    }
    let mut out = Vec::with_capacity(per);
    for g in 0..per / n_layers {
        for (l, &len) in lens.iter().enumerate() {
            let idx = g * n_layers + l;
            let shards: Vec<Vec<f32>> =
                ws.ranks.iter().map(|r| r.opt.bufs[idx].clone()).collect();
            let full = wckpt::unshard_layer(len, &shards)
                .with_context(|| format!("resharding group {g} layer {l}"))?;
            out.push(wckpt::shard_slice(&full, rank, world));
        }
    }
    Ok(out)
}

/// One rank's side of a ZeRO world-checkpoint cut at the end of step
/// `step` (parameters are replicated again — the all-gather ran).
#[allow(clippy::too_many_arguments)]
fn write_zero_ckpt<C: Collective>(
    comm: &C,
    dir: &Path,
    keep: usize,
    flow: &str,
    tag: &str,
    step: u64,
    trainer: &Trainer,
    corpus: &MarkovCorpus,
    bufs: Vec<Vec<f32>>,
    losses: &[f32],
    ledger_base: (u64, u64),
) -> Result<()> {
    let fingerprint = config_fingerprint(trainer.spec(), trainer.config(), tag);
    let mine = wckpt::RankState {
        rank: comm.rank(),
        rng: corpus.rng().clone(),
        opt: OptSnapshot { tag: tag.to_string(), t: step, bufs },
    };
    let meta = (comm.rank() == 0).then(|| wckpt::WorldMeta {
        flow: flow.to_string(),
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        losses: losses.to_vec(),
    });
    wckpt::write_world(comm, dir, keep, fingerprint, step, &mine, meta.as_ref(), ledger_base)
}

/// One AdamA micro-batch with **async issue**: the gradient sink coalesces
/// layer gradients into size-thresholded buckets and hands each closed
/// bucket to the comm thread (`reduce_scatter_many_async`) without
/// blocking — layer *k*'s reduction folds while the pool computes layer
/// *k−1*'s backward. Tickets are waited at micro-batch end and integrated
/// **in issue order** — the production order, exactly where the sync sink
/// integrates — and the backward never reads (m, v), so deferring the
/// integrate past the backward is unobservable: sync and async are
/// bit-identical.
#[allow(clippy::too_many_arguments)]
fn microbatch_async<C: Collective>(
    trainer: &mut Trainer,
    mb: &MicroBatch,
    comm: &C,
    ranges: &[std::ops::Range<usize>],
    integrate: &mut dyn FnMut(usize, &[f32]) -> Result<()>,
    tracker: &MemoryTracker,
    bucket_bytes: usize,
    inv_m: f32,
) -> Result<f32> {
    // (layers, in-flight workspace guard, ticket) per issued bucket
    let mut pending: Vec<(Vec<usize>, Allocation, Ticket)> = Vec::new();
    let mut bucket: Vec<(usize, Vec<f32>)> = Vec::new();
    let mut filled = 0usize;
    let loss = {
        let pending = &mut pending;
        let bucket = &mut bucket;
        let filled = &mut filled;
        let mut sink = |layer: usize, grad: &[f32]| -> Result<()> {
            bucket.push((layer, grad.to_vec()));
            *filled += grad.len() * 4;
            // the bucket closes on reaching the threshold (0 = every
            // gradient issues immediately); boundaries depend only on
            // layer sizes, so every rank cuts identical buckets
            if *filled >= bucket_bytes {
                issue_bucket(comm, tracker, bucket, pending);
                *filled = 0;
            }
            Ok(())
        };
        trainer.accumulate_minibatch_sink(std::slice::from_ref(mb), &mut sink)?
    };
    issue_bucket(comm, tracker, &mut bucket, &mut pending);
    for (layers, _ws, ticket) in pending {
        let reduced = ticket.wait()?;
        ensure!(reduced.len() == layers.len(), "batched reduce returned wrong buffer count");
        for (layer, rb) in layers.into_iter().zip(reduced) {
            debug_assert_eq!(rb.owned, ranges[layer]);
            let mut g: Vec<f32> = rb.data[rb.owned.clone()].to_vec();
            host_math::scale(&mut g, inv_m); // sum -> mean over ranks
            integrate(layer, &g)?;
        }
    }
    Ok(loss)
}

/// Hand the open bucket to the comm thread as one batched reduce-scatter.
fn issue_bucket<C: Collective>(
    comm: &C,
    tracker: &MemoryTracker,
    bucket: &mut Vec<(usize, Vec<f32>)>,
    pending: &mut Vec<(Vec<usize>, Allocation, Ticket)>,
) {
    if bucket.is_empty() {
        return;
    }
    let (layers, bufs): (Vec<usize>, Vec<Vec<f32>>) = bucket.drain(..).unzip();
    // the in-flight gradient copies are workspace until integrated
    let ws = tracker.alloc(Category::Workspace, bufs.iter().map(|b| b.len() * 4).sum());
    pending.push((layers, ws, comm.reduce_scatter_many_async(bufs)));
}

/// ZeRO-S1 + AdamA: per-micro-batch per-layer reduce-scatter + shard
/// integrate + release.
fn worker_adama<C: Collective>(
    lib: Arc<Library>,
    spec: Zero1Spec,
    comm: C,
    resume: Option<Arc<wckpt::WorldState>>,
) -> Result<WorkerOut> {
    let n = spec.cfg.accum_steps;
    let m = comm.world();
    let rank = comm.rank();
    let tracker = MemoryTracker::new();
    let mut trainer =
        Trainer::with_optimizer(lib.clone(), spec.cfg.clone(), tracker.clone(), Box::new(NullOpt))?;
    let hyper = Hyper::from_manifest(lib.manifest());
    let mut shard = ShardState::new(
        trainer.spec(),
        rank,
        comm.world(),
        hyper,
        make_backend(&spec.cfg, &lib)?,
        &tracker,
    );
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (rank as u64 + 1));

    // gradients are globally averaged before integration, so each of the N
    // effective micro-batches is M× larger: gscale = 1/N, mean over M via
    // the reduce-scatter sum / M.
    let gscale = 1.0 / n as f32;
    let inv_m = 1.0 / m as f32;
    let async_issue = spec.async_issue.unwrap_or(false);
    let bucket_bytes = spec.bucket_bytes.unwrap_or(0);

    let mut losses = Vec::new();
    let mut start = 0u64;
    if let Some(ws) = resume.as_deref() {
        resume_restore(&mut trainer, &mut corpus, ws, TAG_ZERO_ADAMA, rank)?;
        // (m, v) live in owned shards: reassemble the saved world's
        // shards and re-cut them for this rank of the current world
        let lens: Vec<usize> = trainer.spec().layers.iter().map(|l| l.flat_len).collect();
        let bufs = reshard_bufs(ws, &lens, rank, m)?;
        let nl = lens.len();
        ensure!(bufs.len() == 2 * nl, "{TAG_ZERO_ADAMA} wants m and v shards per layer");
        shard.m = bufs[..nl].to_vec();
        shard.v = bufs[nl..].to_vec();
        losses.extend_from_slice(&ws.losses);
        start = ws.step;
    }
    let ledger_base = resume.as_deref().map(|ws| ws.ledger).unwrap_or((0, 0));

    for step in start + 1..=spec.steps {
        comm.begin_step(step);
        let t = trainer.step() + 1;
        shard.decay(1.0)?;
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let mut loss_sum = 0.0f64;
        for mb in &mbs {
            let loss = if async_issue {
                let ranges = shard.ranges.clone();
                let shard = &mut shard;
                microbatch_async(
                    &mut trainer,
                    mb,
                    &comm,
                    &ranges,
                    &mut |layer, g| shard.integrate(layer, g, gscale),
                    &tracker,
                    bucket_bytes,
                    inv_m,
                )?
            } else {
                let shard = &mut shard;
                let comm_ref = &comm;
                let tracker_ref = &tracker;
                let mut sink = |layer: usize, grad: &[f32]| -> Result<()> {
                    // workspace copy (reduce-scatter mutates in place)
                    let _w = tracker_ref.alloc(Category::Workspace, grad.len() * 4);
                    let mut buf = grad.to_vec();
                    let own = comm_ref.reduce_scatter_sum(&mut buf)?;
                    debug_assert_eq!(own, shard.ranges[layer]);
                    let mut g: Vec<f32> = buf[own].to_vec();
                    host_math::scale(&mut g, inv_m); // sum -> mean over ranks
                    shard.integrate(layer, &g, gscale)
                };
                trainer.accumulate_minibatch_sink(std::slice::from_ref(mb), &mut sink)?
            };
            loss_sum += loss as f64;
        }
        // shard param update + all-gather
        let (bc1, bc2) = hyper.bias_corrections(t);
        let lr = spec.cfg.lr.at(t);
        let n_layers = trainer.spec().layers.len();
        for l in 0..n_layers {
            let range = shard.ranges[l].clone();
            let flat = &mut trainer.params_mut()[l].flat;
            let mut shard_p: Vec<f32> = flat[range.clone()].to_vec();
            shard.update_shard(l, &mut shard_p, lr, bc1, bc2)?;
            flat[range].copy_from_slice(&shard_p);
            comm.all_gather_owned(flat)?;
        }
        trainer.advance_step();

        let mut l = vec![(loss_sum / n as f64) as f32];
        comm.all_reduce_mean(&mut l)?;
        losses.push(l[0]);

        if let Some((dir, policy)) = spec.checkpoint.as_ref() {
            if policy.due(step) {
                let bufs: Vec<Vec<f32>> =
                    shard.m.iter().chain(shard.v.iter()).cloned().collect();
                write_zero_ckpt(
                    &comm,
                    dir,
                    policy.keep_last_n,
                    "zero1:adama",
                    TAG_ZERO_ADAMA,
                    step,
                    &trainer,
                    &corpus,
                    bufs,
                    &losses,
                    ledger_base,
                )?;
            }
        }
    }

    let mem = snapshot(&trainer, &tracker);
    Ok(WorkerOut {
        losses,
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        mem,
    })
}

/// ZeRO-S1 + GA: full local accumulator, one reduce-scatter per step.
fn worker_ga<C: Collective>(
    lib: Arc<Library>,
    spec: Zero1Spec,
    comm: C,
    resume: Option<Arc<wckpt::WorldState>>,
) -> Result<WorkerOut> {
    let n = spec.cfg.accum_steps;
    let m = comm.world();
    let rank = comm.rank();
    let tracker = MemoryTracker::new();
    let mut trainer =
        Trainer::with_optimizer(lib.clone(), spec.cfg.clone(), tracker.clone(), Box::new(NullOpt))?;
    let hyper = Hyper::from_manifest(lib.manifest());
    let mut shard = ShardState::new(
        trainer.spec(),
        rank,
        comm.world(),
        hyper,
        make_backend(&spec.cfg, &lib)?,
        &tracker,
    );
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (rank as u64 + 1));

    // full-model gradient accumulator (the memory ZeRO-S1 alone keeps)
    let mut acc: Vec<Vec<f32>> =
        trainer.spec().layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
    tracker.alloc_raw(Category::Gradients, trainer.spec().total_params() * 4);
    let gscale = 1.0 / n as f32;
    let inv_m = 1.0 / m as f32;

    let mut losses = Vec::new();
    let mut start = 0u64;
    if let Some(ws) = resume.as_deref() {
        resume_restore(&mut trainer, &mut corpus, ws, TAG_ZERO_ADAMGA, rank)?;
        // the accumulator is zeroed at every step start — only the (m, v)
        // shards live across the boundary
        let lens: Vec<usize> = trainer.spec().layers.iter().map(|l| l.flat_len).collect();
        let bufs = reshard_bufs(ws, &lens, rank, m)?;
        let nl = lens.len();
        ensure!(bufs.len() == 2 * nl, "{TAG_ZERO_ADAMGA} wants m and v shards per layer");
        shard.m = bufs[..nl].to_vec();
        shard.v = bufs[nl..].to_vec();
        losses.extend_from_slice(&ws.losses);
        start = ws.step;
    }
    let ledger_base = resume.as_deref().map(|ws| ws.ledger).unwrap_or((0, 0));

    for step in start + 1..=spec.steps {
        comm.begin_step(step);
        let t = trainer.step() + 1;
        for a in &mut acc {
            a.fill(0.0);
        }
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let mut loss_sum = 0.0f64;
        {
            let acc = &mut acc;
            let mut sink = |layer: usize, grad: &[f32]| -> Result<()> {
                host_math::grad_acc(&mut acc[layer], grad, gscale);
                Ok(())
            };
            loss_sum += trainer.accumulate_minibatch_sink(&mbs, &mut sink)? as f64;
        }
        let (bc1, bc2) = hyper.bias_corrections(t);
        let lr = spec.cfg.lr.at(t);
        let n_layers = trainer.spec().layers.len();
        for l in 0..n_layers {
            let own = comm.reduce_scatter_sum(&mut acc[l])?;
            debug_assert_eq!(own, shard.ranges[l]);
            let mut g: Vec<f32> = acc[l][own.clone()].to_vec();
            host_math::scale(&mut g, inv_m);
            let flat = &mut trainer.params_mut()[l].flat;
            let mut shard_p: Vec<f32> = flat[own.clone()].to_vec();
            shard.adam_full_shard(l, &mut shard_p, &g, lr, bc1, bc2)?;
            flat[own].copy_from_slice(&shard_p);
            comm.all_gather_owned(flat)?;
        }
        trainer.advance_step();

        let mut l = vec![loss_sum as f32];
        comm.all_reduce_mean(&mut l)?;
        losses.push(l[0]);

        if let Some((dir, policy)) = spec.checkpoint.as_ref() {
            if policy.due(step) {
                let bufs: Vec<Vec<f32>> =
                    shard.m.iter().chain(shard.v.iter()).cloned().collect();
                write_zero_ckpt(
                    &comm,
                    dir,
                    policy.keep_last_n,
                    "zero1:adamga",
                    TAG_ZERO_ADAMGA,
                    step,
                    &trainer,
                    &corpus,
                    bufs,
                    &losses,
                    ledger_base,
                )?;
            }
        }
    }

    let mem = snapshot(&trainer, &tracker);
    Ok(WorkerOut {
        losses,
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        mem,
    })
}

/// Per-rank ZeRO-S1 state for an optimizer-zoo rule.
///
/// The mean-gradient accumulator is *sharded* (reduce-scatter layout,
/// state-resident — the paper's trick composed with the rule). The moment
/// statistics are sharded for `adam` (m, v — the ZeRO win is linear) and
/// replicated for the sublinear rules, whose whole point is that their
/// statistics are already tiny; those gather the accumulator shards back
/// into the full mean gradient at apply time and update replicated
/// parameters identically on every rank.
struct ZooShard {
    ranges: Vec<std::ops::Range<usize>>,
    /// Shard-sized accumulators, one per layer.
    acc: Vec<Vec<f32>>,
    fold: UpdateBackend,
    mode: ZooShardMode,
}

enum ZooShardMode {
    Adam { m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, hyper: Hyper, backend: UpdateBackend },
    Replicated(ZooStates),
}

impl ZooShard {
    fn new(
        algo: OptAlgo,
        spec: &ModelSpec,
        rank: usize,
        world: usize,
        hyper: Hyper,
        fold: UpdateBackend,
        rule_backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let owner = (rank + 1) % world;
        let ranges: Vec<_> = spec
            .layers
            .iter()
            .map(|l| CommHandle::shard_ranges(l.flat_len, world)[owner].clone())
            .collect();
        let acc: Vec<Vec<f32>> = ranges.iter().map(|r| vec![0.0; r.len()]).collect();
        let shard_len: usize = ranges.iter().map(|r| r.len()).sum();
        // the accumulator is optimizer state here, not a gradient buffer
        tracker.alloc_raw(Category::OptimizerStates, shard_len * 4);
        let mode = match algo {
            OptAlgo::Adam => {
                let m: Vec<Vec<f32>> = ranges.iter().map(|r| vec![0.0; r.len()]).collect();
                let v = m.clone();
                tracker.alloc_raw(Category::OptimizerStates, shard_len * 8);
                ZooShardMode::Adam { m, v, hyper, backend: rule_backend }
            }
            _ => ZooShardMode::Replicated(ZooStates::new(algo, spec, hyper, rule_backend, tracker)),
        };
        Self { ranges, acc, fold, mode }
    }

    fn begin_step(&mut self) {
        for a in &mut self.acc {
            a.fill(0.0);
        }
    }

    /// Linear fold of one reduce-scattered (already rank-averaged) shard
    /// gradient — same bits for any micro-batch split.
    fn integrate(&mut self, layer: usize, shard_grad: &[f32], gscale: f32) -> Result<()> {
        self.fold.grad_acc(&mut self.acc[layer], shard_grad, gscale)
    }
}

/// ZeRO-S1 + zoo rule: per-micro-batch reduce-scatter into the sharded
/// accumulator; rule apply at mini-batch end (see [`ZooShard`]).
fn worker_zoo<C: Collective>(
    lib: Arc<Library>,
    spec: Zero1Spec,
    algo: OptAlgo,
    comm: C,
    resume: Option<Arc<wckpt::WorldState>>,
) -> Result<WorkerOut> {
    let n = spec.cfg.accum_steps;
    let m = comm.world();
    let rank = comm.rank();
    let tracker = MemoryTracker::new();
    let mut trainer =
        Trainer::with_optimizer(lib.clone(), spec.cfg.clone(), tracker.clone(), Box::new(NullOpt))?;
    let hyper = Hyper::from_manifest(lib.manifest());
    let mut shard = ZooShard::new(
        algo,
        trainer.spec(),
        rank,
        comm.world(),
        hyper,
        make_backend(&spec.cfg, &lib)?,
        make_backend(&spec.cfg, &lib)?,
        &tracker,
    );
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (rank as u64 + 1));

    let gscale = 1.0 / n as f32;
    let inv_m = 1.0 / m as f32;
    let async_issue = spec.async_issue.unwrap_or(false);
    let bucket_bytes = spec.bucket_bytes.unwrap_or(0);
    let tag = format!("zero:zoo:{}", algo.name());
    let flow = format!("zero1:zoo:{}", algo.name());

    let mut losses = Vec::new();
    let mut start = 0u64;
    if let Some(ws) = resume.as_deref() {
        resume_restore(&mut trainer, &mut corpus, ws, &tag, rank)?;
        // the accumulator is zeroed at every step start; what lives across
        // the boundary is mode-specific
        match &mut shard.mode {
            ZooShardMode::Adam { m: sm, v: sv, .. } => {
                // sharded (m, v): reassemble and re-cut like the AdamA flow
                let lens: Vec<usize> =
                    trainer.spec().layers.iter().map(|l| l.flat_len).collect();
                let bufs = reshard_bufs(ws, &lens, rank, m)?;
                let nl = lens.len();
                ensure!(bufs.len() == 2 * nl, "{tag} wants m and v shards per layer");
                *sm = bufs[..nl].to_vec();
                *sv = bufs[nl..].to_vec();
            }
            ZooShardMode::Replicated(states) => {
                // replicated statistics are identical on every saved rank,
                // so any rank file serves a rank the saved world lacked
                states.import_bufs(&ws.ranks[rank.min(ws.world - 1)].opt.bufs)?;
            }
        }
        losses.extend_from_slice(&ws.losses);
        start = ws.step;
    }
    let ledger_base = resume.as_deref().map(|ws| ws.ledger).unwrap_or((0, 0));

    for step in start + 1..=spec.steps {
        comm.begin_step(step);
        let t = trainer.step() + 1;
        shard.begin_step();
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let mut loss_sum = 0.0f64;
        for mb in &mbs {
            let loss = if async_issue {
                let ranges = shard.ranges.clone();
                let shard = &mut shard;
                microbatch_async(
                    &mut trainer,
                    mb,
                    &comm,
                    &ranges,
                    &mut |layer, g| shard.integrate(layer, g, gscale),
                    &tracker,
                    bucket_bytes,
                    inv_m,
                )?
            } else {
                let shard = &mut shard;
                let comm_ref = &comm;
                let tracker_ref = &tracker;
                let mut sink = |layer: usize, grad: &[f32]| -> Result<()> {
                    let _w = tracker_ref.alloc(Category::Workspace, grad.len() * 4);
                    let mut buf = grad.to_vec();
                    let own = comm_ref.reduce_scatter_sum(&mut buf)?;
                    debug_assert_eq!(own, shard.ranges[layer]);
                    let mut g: Vec<f32> = buf[own].to_vec();
                    host_math::scale(&mut g, inv_m); // sum -> mean over ranks
                    shard.integrate(layer, &g, gscale)
                };
                trainer.accumulate_minibatch_sink(std::slice::from_ref(mb), &mut sink)?
            };
            loss_sum += loss as f64;
        }
        let lr = spec.cfg.lr.at(t);
        let n_layers = trainer.spec().layers.len();
        for l in 0..n_layers {
            let range = shard.ranges[l].clone();
            match &mut shard.mode {
                ZooShardMode::Adam { m, v, hyper, backend } => {
                    let (bc1, bc2) = hyper.bias_corrections(t);
                    let flat = &mut trainer.params_mut()[l].flat;
                    let mut shard_p: Vec<f32> = flat[range.clone()].to_vec();
                    backend.adam_full(
                        &mut shard_p,
                        &mut m[l],
                        &mut v[l],
                        &shard.acc[l],
                        lr,
                        bc1,
                        bc2,
                    )?;
                    flat[range].copy_from_slice(&shard_p);
                    comm.all_gather_owned(flat)?;
                }
                ZooShardMode::Replicated(states) => {
                    // gather the accumulator shards back into the full
                    // mean gradient; every rank then applies the same
                    // full-tensor rule on replicated parameters
                    let flat_len = trainer.spec().layers[l].flat_len;
                    let _w = tracker.alloc(Category::Workspace, flat_len * 4);
                    let mut full = vec![0.0f32; flat_len];
                    full[range].copy_from_slice(&shard.acc[l]);
                    comm.all_gather_owned(&mut full)?;
                    let flat = &mut trainer.params_mut()[l].flat;
                    states.apply_layer(l, flat, &full, t, lr)?;
                }
            }
        }
        trainer.advance_step();

        let mut l = vec![(loss_sum / n as f64) as f32];
        comm.all_reduce_mean(&mut l)?;
        losses.push(l[0]);

        if let Some((dir, policy)) = spec.checkpoint.as_ref() {
            if policy.due(step) {
                let bufs: Vec<Vec<f32>> = match &shard.mode {
                    ZooShardMode::Adam { m: sm, v: sv, .. } => {
                        sm.iter().chain(sv.iter()).cloned().collect()
                    }
                    ZooShardMode::Replicated(states) => states.export_bufs(),
                };
                write_zero_ckpt(
                    &comm,
                    dir,
                    policy.keep_last_n,
                    &flow,
                    &tag,
                    step,
                    &trainer,
                    &corpus,
                    bufs,
                    &losses,
                    ledger_base,
                )?;
            }
        }
    }

    let mem = snapshot(&trainer, &tracker);
    Ok(WorkerOut {
        losses,
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        mem,
    })
}

/// Per-rank context of the serial ZeRO simulator.
struct SerialRank {
    trainer: Trainer,
    shard: ShardState,
    corpus: MarkovCorpus,
    tracker: MemoryTracker,
}

fn serial_ranks(
    lib: &Arc<Library>,
    spec: &Zero1Spec,
    tpr: usize,
) -> Result<(Vec<SerialRank>, Hyper)> {
    let m = spec.cfg.workers;
    let mut ranks = Vec::with_capacity(m);
    let mut hyper = None;
    for r in 0..m {
        let rlib = lib.fork_with_threads(tpr);
        let tracker = MemoryTracker::new();
        let trainer = Trainer::with_optimizer(
            rlib.clone(),
            spec.cfg.clone(),
            tracker.clone(),
            Box::new(NullOpt),
        )?;
        let hy = Hyper::from_manifest(rlib.manifest());
        let shard = ShardState::new(
            trainer.spec(),
            r,
            m,
            hy,
            make_backend(&spec.cfg, &rlib)?,
            &tracker,
        );
        let h = trainer.spec().hyper.clone();
        let corpus = MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (r as u64 + 1));
        hyper = Some(hy);
        ranks.push(SerialRank { trainer, shard, corpus, tracker });
    }
    Ok((ranks, hyper.expect("world >= 2")))
}

/// The serial ZeRO simulator: ranks advance micro-batch by micro-batch in
/// one thread; every per-layer gradient is buffered, reduce-scattered in
/// the fixed chain order, integrated, then released — the bit-for-bit
/// oracle for the concurrent workers.
fn run_zero_serial(
    lib: Arc<Library>,
    spec: Zero1Spec,
    topo: Topology,
    tpr: usize,
) -> Result<Zero1Report> {
    if let Some(algo) = lib.executor().opt_algo() {
        return run_zero_serial_zoo(lib, spec, topo, tpr, algo);
    }
    let m = spec.cfg.workers;
    let n = spec.cfg.accum_steps;
    let stats = Arc::new(CommStats::default());
    let t0 = Instant::now();
    let (mut ranks, hyper) = serial_ranks(&lib, &spec, tpr)?;
    let h = ranks[0].trainer.spec().hyper.clone();
    let n_layers = ranks[0].trainer.spec().layers.len();
    let adama = spec.cfg.optimizer == OptimizerKind::AdamA;
    let gscale = 1.0 / n as f32;
    let inv_m = 1.0 / m as f32;

    // ZeRO-S1+GA keeps a full-model accumulator per rank
    let mut acc: Vec<Vec<Vec<f32>>> = if adama {
        Vec::new()
    } else {
        let template: Vec<Vec<f32>> =
            ranks[0].trainer.spec().layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        for rc in &ranks {
            rc.tracker
                .alloc_raw(Category::Gradients, rc.trainer.spec().total_params() * 4);
        }
        (0..m).map(|_| template.clone()).collect()
    };

    let mut losses = Vec::new();
    for _ in 0..spec.steps {
        let t = ranks[0].trainer.step() + 1;
        let mbs: Vec<Vec<MicroBatch>> = ranks
            .iter_mut()
            .map(|rc| rc.corpus.minibatch(n, h.microbatch, h.seq))
            .collect();
        let mut rank_loss = vec![0.0f32; m];

        if adama {
            for rc in ranks.iter_mut() {
                rc.shard.decay(1.0)?;
            }
            let mut sums = vec![0.0f64; m];
            for i in 0..n {
                // every rank's i-th micro-batch, gradients buffered in
                // production order (the concurrent sink issues the
                // reduce-scatter at exactly these points)
                let mut grads: Vec<Vec<(usize, Vec<f32>)>> = Vec::with_capacity(m);
                for (r, rc) in ranks.iter_mut().enumerate() {
                    let mut buf: Vec<(usize, Vec<f32>)> = Vec::new();
                    let loss = rc.trainer.accumulate_minibatch_sink(
                        std::slice::from_ref(&mbs[r][i]),
                        &mut |layer, grad| {
                            buf.push((layer, grad.to_vec()));
                            Ok(())
                        },
                    )?;
                    sums[r] += loss as f64;
                    grads.push(buf);
                }
                let k_count = grads[0].len();
                for g in &grads {
                    ensure!(g.len() == k_count, "ranks produced different gradient counts");
                }
                for k in 0..k_count {
                    let layer = grads[0][k].0;
                    let mut bufs: Vec<Vec<f32>> =
                        grads.iter().map(|g| g[k].1.clone()).collect();
                    let owned = serial::reduce_scatter_sum(topo, &mut bufs, &stats)?;
                    for (rc, (b, own)) in
                        ranks.iter_mut().zip(bufs.iter().zip(owned.iter()))
                    {
                        let _w = rc.tracker.alloc(Category::Workspace, b.len() * 4);
                        debug_assert_eq!(own.clone(), rc.shard.ranges[layer]);
                        let mut g: Vec<f32> = b[own.clone()].to_vec();
                        host_math::scale(&mut g, inv_m);
                        rc.shard.integrate(layer, &g, gscale)?;
                    }
                }
            }
            for (r, loss) in rank_loss.iter_mut().enumerate() {
                *loss = (sums[r] / n as f64) as f32;
            }
        } else {
            for a in acc.iter_mut().flatten() {
                a.fill(0.0);
            }
            for (r, rc) in ranks.iter_mut().enumerate() {
                let racc = &mut acc[r];
                let mut sink = |layer: usize, grad: &[f32]| -> Result<()> {
                    host_math::grad_acc(&mut racc[layer], grad, gscale);
                    Ok(())
                };
                rank_loss[r] =
                    rc.trainer.accumulate_minibatch_sink(&mbs[r], &mut sink)?;
            }
        }

        // shard param update + all-gather (identical math for both flows:
        // AdamA updates from integrated (m, v); GA applies the fused
        // update with the freshly reduced mean gradient)
        let (bc1, bc2) = hyper.bias_corrections(t);
        let lr = spec.cfg.lr.at(t);
        for l in 0..n_layers {
            if !adama {
                let mut bufs: Vec<Vec<f32>> = (0..m).map(|r| acc[r][l].clone()).collect();
                let owned = serial::reduce_scatter_sum(topo, &mut bufs, &stats)?;
                for (r, rc) in ranks.iter_mut().enumerate() {
                    let own = owned[r].clone();
                    debug_assert_eq!(own, rc.shard.ranges[l]);
                    let mut g: Vec<f32> = bufs[r][own.clone()].to_vec();
                    host_math::scale(&mut g, inv_m);
                    let flat = &mut rc.trainer.params_mut()[l].flat;
                    let mut shard_p: Vec<f32> = flat[own.clone()].to_vec();
                    rc.shard.adam_full_shard(l, &mut shard_p, &g, lr, bc1, bc2)?;
                    flat[own].copy_from_slice(&shard_p);
                }
            } else {
                for rc in ranks.iter_mut() {
                    let range = rc.shard.ranges[l].clone();
                    let flat = &mut rc.trainer.params_mut()[l].flat;
                    let mut shard_p: Vec<f32> = flat[range.clone()].to_vec();
                    rc.shard.update_shard(l, &mut shard_p, lr, bc1, bc2)?;
                    flat[range].copy_from_slice(&shard_p);
                }
            }
            let mut flats: Vec<Vec<f32>> =
                ranks.iter().map(|rc| rc.trainer.params()[l].flat.clone()).collect();
            serial::all_gather_owned(&mut flats, &stats)?;
            for (rc, f) in ranks.iter_mut().zip(&flats) {
                rc.trainer.params_mut()[l].flat.copy_from_slice(f);
            }
        }
        for rc in ranks.iter_mut() {
            rc.trainer.advance_step();
        }

        let mut lbufs: Vec<Vec<f32>> = rank_loss.iter().map(|&l| vec![l]).collect();
        serial::all_reduce_mean(topo, &mut lbufs, &stats)?;
        losses.push(lbufs[0][0]);
    }

    let final_params: Vec<Vec<f32>> =
        ranks[0].trainer.params().iter().map(|p| p.flat.clone()).collect();
    for (r, rc) in ranks.iter().enumerate().skip(1) {
        for (l, (a, b)) in final_params
            .iter()
            .zip(rc.trainer.params().iter().map(|p| &p.flat))
            .enumerate()
        {
            ensure!(a == b, "rank {r} layer {l} diverged after all-gather");
        }
    }
    let per_rank_memory: Vec<MemorySnapshot> =
        ranks.iter().map(|rc| snapshot(&rc.trainer, &rc.tracker)).collect();

    Ok(Zero1Report {
        losses,
        final_params,
        comm_bytes: stats.bytes(),
        comm_ops: stats.op_count(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        memory: per_rank_memory[0].tracker,
        per_rank_memory,
        engine: CollectiveEngine::Serial,
        resumed_from: None,
    })
}

/// Per-rank context of the serial zoo simulator.
struct SerialZooRank {
    trainer: Trainer,
    shard: ZooShard,
    corpus: MarkovCorpus,
    tracker: MemoryTracker,
}

/// The serial ZeRO zoo simulator — bit-for-bit oracle for [`worker_zoo`]:
/// the same production-order reduce-scatter + shard fold per micro-batch,
/// the same per-layer apply (shard Adam + param gather, or accumulator
/// gather + replicated rule).
fn run_zero_serial_zoo(
    lib: Arc<Library>,
    spec: Zero1Spec,
    topo: Topology,
    tpr: usize,
    algo: OptAlgo,
) -> Result<Zero1Report> {
    let m = spec.cfg.workers;
    let n = spec.cfg.accum_steps;
    let stats = Arc::new(CommStats::default());
    let t0 = Instant::now();

    let mut ranks = Vec::with_capacity(m);
    for r in 0..m {
        let rlib = lib.fork_with_threads(tpr);
        let tracker = MemoryTracker::new();
        let trainer = Trainer::with_optimizer(
            rlib.clone(),
            spec.cfg.clone(),
            tracker.clone(),
            Box::new(NullOpt),
        )?;
        let hy = Hyper::from_manifest(rlib.manifest());
        let shard = ZooShard::new(
            algo,
            trainer.spec(),
            r,
            m,
            hy,
            make_backend(&spec.cfg, &rlib)?,
            make_backend(&spec.cfg, &rlib)?,
            &tracker,
        );
        let h = trainer.spec().hyper.clone();
        let corpus = MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (r as u64 + 1));
        ranks.push(SerialZooRank { trainer, shard, corpus, tracker });
    }
    let h = ranks[0].trainer.spec().hyper.clone();
    let n_layers = ranks[0].trainer.spec().layers.len();
    let gscale = 1.0 / n as f32;
    let inv_m = 1.0 / m as f32;

    let mut losses = Vec::new();
    for _ in 0..spec.steps {
        let t = ranks[0].trainer.step() + 1;
        let mbs: Vec<Vec<MicroBatch>> = ranks
            .iter_mut()
            .map(|rc| rc.corpus.minibatch(n, h.microbatch, h.seq))
            .collect();
        for rc in ranks.iter_mut() {
            rc.shard.begin_step();
        }
        let mut sums = vec![0.0f64; m];
        for i in 0..n {
            // every rank's i-th micro-batch, gradients buffered in
            // production order (the concurrent sink issues the
            // reduce-scatter at exactly these points)
            let mut grads: Vec<Vec<(usize, Vec<f32>)>> = Vec::with_capacity(m);
            for (r, rc) in ranks.iter_mut().enumerate() {
                let mut buf: Vec<(usize, Vec<f32>)> = Vec::new();
                let loss = rc.trainer.accumulate_minibatch_sink(
                    std::slice::from_ref(&mbs[r][i]),
                    &mut |layer, grad| {
                        buf.push((layer, grad.to_vec()));
                        Ok(())
                    },
                )?;
                sums[r] += loss as f64;
                grads.push(buf);
            }
            let k_count = grads[0].len();
            for g in &grads {
                ensure!(g.len() == k_count, "ranks produced different gradient counts");
            }
            for k in 0..k_count {
                let layer = grads[0][k].0;
                let mut bufs: Vec<Vec<f32>> = grads.iter().map(|g| g[k].1.clone()).collect();
                let owned = serial::reduce_scatter_sum(topo, &mut bufs, &stats)?;
                for (rc, (b, own)) in ranks.iter_mut().zip(bufs.iter().zip(owned.iter())) {
                    let _w = rc.tracker.alloc(Category::Workspace, b.len() * 4);
                    debug_assert_eq!(own.clone(), rc.shard.ranges[layer]);
                    let mut g: Vec<f32> = b[own.clone()].to_vec();
                    host_math::scale(&mut g, inv_m);
                    rc.shard.integrate(layer, &g, gscale)?;
                }
            }
        }
        let mut rank_loss = vec![0.0f32; m];
        for (r, loss) in rank_loss.iter_mut().enumerate() {
            *loss = (sums[r] / n as f64) as f32;
        }

        let lr = spec.cfg.lr.at(t);
        for l in 0..n_layers {
            if matches!(ranks[0].shard.mode, ZooShardMode::Replicated(_)) {
                // gather accumulator shards into the full mean gradient,
                // then every rank applies the same replicated rule
                let flat_len = ranks[0].trainer.spec().layers[l].flat_len;
                let mut fulls: Vec<Vec<f32>> = ranks
                    .iter()
                    .map(|rc| {
                        let mut full = vec![0.0f32; flat_len];
                        full[rc.shard.ranges[l].clone()].copy_from_slice(&rc.shard.acc[l]);
                        full
                    })
                    .collect();
                serial::all_gather_owned(&mut fulls, &stats)?;
                for (rc, full) in ranks.iter_mut().zip(&fulls) {
                    let _w = rc.tracker.alloc(Category::Workspace, flat_len * 4);
                    let flat = &mut rc.trainer.params_mut()[l].flat;
                    if let ZooShardMode::Replicated(states) = &mut rc.shard.mode {
                        states.apply_layer(l, flat, full, t, lr)?;
                    }
                }
            } else {
                for rc in ranks.iter_mut() {
                    let range = rc.shard.ranges[l].clone();
                    if let ZooShardMode::Adam { m, v, hyper, backend } = &mut rc.shard.mode {
                        let (bc1, bc2) = hyper.bias_corrections(t);
                        let flat = &mut rc.trainer.params_mut()[l].flat;
                        let mut shard_p: Vec<f32> = flat[range.clone()].to_vec();
                        backend.adam_full(
                            &mut shard_p,
                            &mut m[l],
                            &mut v[l],
                            &rc.shard.acc[l],
                            lr,
                            bc1,
                            bc2,
                        )?;
                        flat[range].copy_from_slice(&shard_p);
                    }
                }
                let mut flats: Vec<Vec<f32>> =
                    ranks.iter().map(|rc| rc.trainer.params()[l].flat.clone()).collect();
                serial::all_gather_owned(&mut flats, &stats)?;
                for (rc, f) in ranks.iter_mut().zip(&flats) {
                    rc.trainer.params_mut()[l].flat.copy_from_slice(f);
                }
            }
        }
        for rc in ranks.iter_mut() {
            rc.trainer.advance_step();
        }

        let mut lbufs: Vec<Vec<f32>> = rank_loss.iter().map(|&l| vec![l]).collect();
        serial::all_reduce_mean(topo, &mut lbufs, &stats)?;
        losses.push(lbufs[0][0]);
    }

    let final_params: Vec<Vec<f32>> =
        ranks[0].trainer.params().iter().map(|p| p.flat.clone()).collect();
    for (r, rc) in ranks.iter().enumerate().skip(1) {
        for (l, (a, b)) in final_params
            .iter()
            .zip(rc.trainer.params().iter().map(|p| &p.flat))
            .enumerate()
        {
            ensure!(a == b, "rank {r} layer {l} diverged in the zoo flow");
        }
    }
    let per_rank_memory: Vec<MemorySnapshot> =
        ranks.iter().map(|rc| snapshot(&rc.trainer, &rc.tracker)).collect();

    Ok(Zero1Report {
        losses,
        final_params,
        comm_bytes: stats.bytes(),
        comm_ops: stats.op_count(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        memory: per_rank_memory[0].tracker,
        per_rank_memory,
        engine: CollectiveEngine::Serial,
        resumed_from: None,
    })
}
