//! ZeRO-S1 (`P_os`) substrate + its AdamA combination (paper §4.2, Fig 6b,
//! Table 3).
//!
//! Optimizer states are partitioned: rank `r` owns, for every layer, the
//! contiguous shard that ring reduce-scatter leaves fully reduced on it.
//! Two flows:
//!
//! * **ZeRO-S1 + AdamA** — every layer gradient of every micro-batch is
//!   reduce-scattered the moment it exists; the owner integrates its shard
//!   into its (m, v) shard and the gradient is released (grad peak = one
//!   layer, activation peak = one micro-batch, states = 2P/M). The
//!   micro-batch granularity becomes *global* (M-way averaged), i.e.
//!   AdamA with N effective micro-batches of M× size — still Alg. 2
//!   semantics. Comm: 2·N half-collectives per layer per step (the ~5%
//!   throughput cost the paper reports for this combo).
//! * **ZeRO-S1 + GA** — the DeepSpeed baseline: full local gradient
//!   accumulator (P floats), one reduce-scatter at mini-batch end, shard
//!   update, param all-gather.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::comm::{CommGroup, CommHandle};
use crate::config::{OptimBackend, OptimizerKind, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::MarkovCorpus;
use crate::memory::{Category, MemoryReport, MemoryTracker};
use crate::model::ModelSpec;
use crate::optim::{host_math, Hyper, NullOpt, UpdateBackend};
use crate::runtime::Library;

#[derive(Debug, Clone)]
pub struct Zero1Spec {
    pub cfg: TrainConfig,
    pub steps: u64,
    pub data_seed: u64,
}

#[derive(Debug, Clone)]
pub struct Zero1Report {
    pub losses: Vec<f32>,
    pub final_params: Vec<Vec<f32>>,
    pub comm_bytes: u64,
    pub comm_ops: u64,
    pub elapsed_s: f64,
    pub memory: MemoryReport,
}

/// Per-worker partitioned Adam state.
struct ShardState {
    /// Owned range per layer (reduce-scatter layout: shard (rank+1) mod M).
    ranges: Vec<std::ops::Range<usize>>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    hyper: Hyper,
    backend: UpdateBackend,
}

impl ShardState {
    fn new(
        spec: &ModelSpec,
        comm: &CommHandle,
        hyper: Hyper,
        backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let owner = (comm.rank() + 1) % comm.world();
        let ranges: Vec<_> = spec
            .layers
            .iter()
            .map(|l| CommHandle::shard_ranges(l.flat_len, comm.world())[owner].clone())
            .collect();
        let m: Vec<Vec<f32>> = ranges.iter().map(|r| vec![0.0; r.len()]).collect();
        let v = m.clone();
        let bytes: usize = ranges.iter().map(|r| r.len() * 8).sum();
        tracker.alloc_raw(Category::OptimizerStates, bytes);
        Self { ranges, m, v, hyper, backend }
    }

    fn decay(&mut self, vfactor: f32) -> Result<()> {
        let (b1, b2) = (self.hyper.beta1, self.hyper.beta2);
        for (m, v) in self.m.iter_mut().zip(self.v.iter_mut()) {
            self.backend.adama_decay(m, v, b1, vfactor * b2)?;
        }
        Ok(())
    }

    fn integrate(&mut self, layer: usize, shard_grad: &[f32], gscale: f32) -> Result<()> {
        self.backend.adama_acc(&mut self.m[layer], &mut self.v[layer], shard_grad, gscale)
    }

    fn adam_full_shard(
        &mut self,
        layer: usize,
        p: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        self.backend
            .adam_full(p, &mut self.m[layer], &mut self.v[layer], g, lr, bc1, bc2)
    }

    fn update_shard(&mut self, layer: usize, p: &mut [f32], lr: f32, bc1: f32, bc2: f32) -> Result<()> {
        self.backend.adam_update(p, &self.m[layer], &self.v[layer], lr, bc1, bc2)
    }
}

/// Run ZeRO-S1 training: `cfg.optimizer` selects AdamA (combined scheme)
/// or AdamGA (DeepSpeed-style baseline).
pub fn run_zero1(lib: Arc<Library>, spec: Zero1Spec) -> Result<Zero1Report> {
    spec.cfg.validate()?;
    let m = spec.cfg.workers;
    if m < 2 {
        bail!("ZeRO-S1 needs >= 2 workers");
    }
    let handles = CommGroup::new(m);
    let stats = handles[0].stats().clone();
    let t0 = std::time::Instant::now();

    let mut joins = Vec::new();
    for comm in handles {
        // Per-rank fork: pins the host pool to 1 worker per rank (see
        // `run_data_parallel`) and gives each rank a private activation
        // arena when stashing is enabled — same bits either way.
        let lib = lib.fork_with_threads(1);
        let spec = spec.clone();
        joins.push(std::thread::spawn(move || match spec.cfg.optimizer {
            OptimizerKind::AdamA => worker_adama(lib, spec, comm),
            OptimizerKind::AdamGA => worker_ga(lib, spec, comm),
            k => bail!("ZeRO-S1 supports adama|adamga, got {:?}", k),
        }));
    }
    let mut results = Vec::new();
    for j in joins {
        results.push(j.join().map_err(|_| anyhow::anyhow!("zero1 worker panicked"))??);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    let r0 = &results[0];
    for (r, out) in results.iter().enumerate().skip(1) {
        for (l, (a, b)) in r0.params.iter().zip(&out.params).enumerate() {
            anyhow::ensure!(a == b, "rank {r} layer {l} diverged after all-gather");
        }
    }
    Ok(Zero1Report {
        losses: r0.losses.clone(),
        final_params: r0.params.clone(),
        comm_bytes: stats.bytes(),
        comm_ops: stats.op_count(),
        elapsed_s,
        memory: r0.memory,
    })
}

struct WorkerOut {
    losses: Vec<f32>,
    params: Vec<Vec<f32>>,
    memory: MemoryReport,
}

fn make_backend(cfg: &TrainConfig, lib: &Arc<Library>) -> Result<UpdateBackend> {
    let hyper = Hyper::from_manifest(lib.manifest());
    Ok(match cfg.backend {
        OptimBackend::Kernel => UpdateBackend::kernel(lib.clone(), cfg.chunk)?,
        OptimBackend::Host => UpdateBackend::host(hyper),
    })
}

/// ZeRO-S1 + AdamA: per-micro-batch per-layer reduce-scatter + shard
/// integrate + release.
fn worker_adama(lib: Arc<Library>, spec: Zero1Spec, comm: CommHandle) -> Result<WorkerOut> {
    let n = spec.cfg.accum_steps;
    let m = comm.world();
    let tracker = MemoryTracker::new();
    let mut trainer =
        Trainer::with_optimizer(lib.clone(), spec.cfg.clone(), tracker.clone(), Box::new(NullOpt))?;
    let hyper = Hyper::from_manifest(lib.manifest());
    let mut shard = ShardState::new(
        trainer.spec(),
        &comm,
        hyper,
        make_backend(&spec.cfg, &lib)?,
        &tracker,
    );
    let h = trainer.spec().hyper.clone();
    let mut corpus =
        MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (comm.rank() as u64 + 1));

    // gradients are globally averaged before integration, so each of the N
    // effective micro-batches is M× larger: gscale = 1/N, mean over M via
    // the reduce-scatter sum / M.
    let gscale = 1.0 / n as f32;
    let inv_m = 1.0 / m as f32;

    let mut losses = Vec::new();
    for _ in 0..spec.steps {
        let t = trainer.step() + 1;
        shard.decay(1.0)?;
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let mut loss_sum = 0.0f64;
        {
            let shard = &mut shard;
            let comm_ref = &comm;
            let tracker_ref = &tracker;
            let mut sink = |layer: usize, grad: &[f32]| -> Result<()> {
                // workspace copy (reduce-scatter mutates in place)
                let _w = tracker_ref.alloc(Category::Workspace, grad.len() * 4);
                let mut buf = grad.to_vec();
                let own = comm_ref.reduce_scatter_sum(&mut buf)?;
                debug_assert_eq!(own, shard.ranges[layer]);
                let mut g: Vec<f32> = buf[own].to_vec();
                host_math::scale(&mut g, inv_m); // sum -> mean over ranks
                shard.integrate(layer, &g, gscale)
            };
            for mb in &mbs {
                loss_sum += trainer.accumulate_minibatch_sink(
                    std::slice::from_ref(mb),
                    &mut sink,
                )? as f64;
            }
        }
        // shard param update + all-gather
        let (bc1, bc2) = hyper.bias_corrections(t);
        let lr = spec.cfg.lr.at(t);
        let n_layers = trainer.spec().layers.len();
        for l in 0..n_layers {
            let range = shard.ranges[l].clone();
            let flat = &mut trainer.params_mut()[l].flat;
            let mut shard_p: Vec<f32> = flat[range.clone()].to_vec();
            shard.update_shard(l, &mut shard_p, lr, bc1, bc2)?;
            flat[range].copy_from_slice(&shard_p);
            comm.all_gather_owned(flat)?;
        }
        trainer.advance_step();

        let mut l = vec![(loss_sum / n as f64) as f32];
        comm.all_reduce_mean(&mut l)?;
        losses.push(l[0]);
    }

    Ok(WorkerOut {
        losses,
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        memory: tracker.report(),
    })
}

/// ZeRO-S1 + GA: full local accumulator, one reduce-scatter per step.
fn worker_ga(lib: Arc<Library>, spec: Zero1Spec, comm: CommHandle) -> Result<WorkerOut> {
    let n = spec.cfg.accum_steps;
    let m = comm.world();
    let tracker = MemoryTracker::new();
    let mut trainer =
        Trainer::with_optimizer(lib.clone(), spec.cfg.clone(), tracker.clone(), Box::new(NullOpt))?;
    let hyper = Hyper::from_manifest(lib.manifest());
    let mut shard = ShardState::new(
        trainer.spec(),
        &comm,
        hyper,
        make_backend(&spec.cfg, &lib)?,
        &tracker,
    );
    let h = trainer.spec().hyper.clone();
    let mut corpus =
        MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (comm.rank() as u64 + 1));

    // full-model gradient accumulator (the memory ZeRO-S1 alone keeps)
    let mut acc: Vec<Vec<f32>> =
        trainer.spec().layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
    tracker.alloc_raw(Category::Gradients, trainer.spec().total_params() * 4);
    let gscale = 1.0 / n as f32;
    let inv_m = 1.0 / m as f32;

    let mut losses = Vec::new();
    for _ in 0..spec.steps {
        let t = trainer.step() + 1;
        for a in &mut acc {
            a.fill(0.0);
        }
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let mut loss_sum = 0.0f64;
        {
            let acc = &mut acc;
            let mut sink = |layer: usize, grad: &[f32]| -> Result<()> {
                host_math::grad_acc(&mut acc[layer], grad, gscale);
                Ok(())
            };
            loss_sum += trainer.accumulate_minibatch_sink(&mbs, &mut sink)? as f64;
        }
        let (bc1, bc2) = hyper.bias_corrections(t);
        let lr = spec.cfg.lr.at(t);
        let n_layers = trainer.spec().layers.len();
        for l in 0..n_layers {
            let own = comm.reduce_scatter_sum(&mut acc[l])?;
            debug_assert_eq!(own, shard.ranges[l]);
            let mut g: Vec<f32> = acc[l][own.clone()].to_vec();
            host_math::scale(&mut g, inv_m);
            let flat = &mut trainer.params_mut()[l].flat;
            let mut shard_p: Vec<f32> = flat[own.clone()].to_vec();
            shard.adam_full_shard(l, &mut shard_p, &g, lr, bc1, bc2)?;
            flat[own].copy_from_slice(&shard_p);
            comm.all_gather_owned(flat)?;
        }
        trainer.advance_step();

        let mut l = vec![loss_sum as f32];
        comm.all_reduce_mean(&mut l)?;
        losses.push(l[0]);
    }

    Ok(WorkerOut {
        losses,
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        memory: tracker.report(),
    })
}
