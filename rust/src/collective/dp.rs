//! Data-parallel training runner (paper §3.3).
//!
//! Spawns `M` worker threads, each owning a full [`Trainer`] replica and a
//! disjoint data shard, connected by a ring [`CommGroup`]. Three sync
//! strategies reproduce the paper's design space:
//!
//! * [`SyncStrategy::OptimizerStates`] — **the paper's scheme**: decay `v`
//!   by `M·β₂` (Eq. 6), integrate local micro-grads with gscale `1/N`,
//!   then once per mini-batch all-reduce `m` (mean, Eq. 7) and `v`
//!   (sum/M², Eq. 8). Comm volume constant in N.
//! * [`SyncStrategy::Gradients`] — classic DDP+GA baseline: accumulate
//!   locally, one gradient all-reduce (mean) per mini-batch.
//! * [`SyncStrategy::GradPerMicrobatch`] — the naive AdamA distribution
//!   the paper rejects: all-reduce every layer gradient every micro-batch
//!   (O(N) collectives), integrating the *global* mean gradient.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::comm::{CommGroup, CommHandle};
use crate::config::{OptimizerKind, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::MarkovCorpus;
use crate::memory::MemoryReport;
use crate::runtime::Library;

/// How workers synchronise per mini-batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncStrategy {
    OptimizerStates,
    Gradients,
    GradPerMicrobatch,
}

impl SyncStrategy {
    pub fn name(self) -> &'static str {
        match self {
            Self::OptimizerStates => "state-allreduce",
            Self::Gradients => "grad-allreduce",
            Self::GradPerMicrobatch => "grad-per-microbatch",
        }
    }
}

/// A distributed run specification.
#[derive(Debug, Clone)]
pub struct DpSpec {
    pub cfg: TrainConfig,
    pub sync: SyncStrategy,
    pub steps: u64,
    /// Markov corpus structure seed (shared); stream seeds fork per worker.
    pub data_seed: u64,
}

/// Result of a distributed run.
#[derive(Debug, Clone)]
pub struct DpReport {
    pub losses: Vec<f32>,
    /// Rank-0 final parameters (all ranks are asserted identical).
    pub final_params: Vec<Vec<f32>>,
    pub comm_bytes: u64,
    pub comm_ops: u64,
    pub elapsed_s: f64,
    pub memory: MemoryReport,
}

/// Run `spec.steps` mini-batches across `spec.cfg.workers` worker threads.
pub fn run_data_parallel(lib: Arc<Library>, spec: DpSpec) -> Result<DpReport> {
    let m = spec.cfg.workers;
    spec.cfg.validate()?;
    if spec.sync != SyncStrategy::Gradients
        && spec.cfg.optimizer != OptimizerKind::AdamA
    {
        bail!("{:?} sync requires AdamA", spec.sync);
    }
    let handles = CommGroup::new(m);
    let stats = handles[0].stats().clone();
    let t0 = std::time::Instant::now();

    let mut joins = Vec::new();
    for comm in handles {
        // Per-rank fork. Each rank is already its own OS thread: pin the
        // host executor's intra-op pool to one worker per rank so M ranks
        // don't fan out into M·T pool threads (oversubscription), and —
        // when an activation stash budget is set — give every rank a
        // private arena so concurrent ranks never evict or meter each
        // other's entries. Numerics are unaffected — the pool is
        // bit-for-bit identical at any thread count, and stash/remat are
        // bit-identical.
        let lib = lib.fork_with_threads(1);
        let spec = spec.clone();
        joins.push(std::thread::spawn(move || worker(lib, spec, comm)));
    }
    let mut results: Vec<WorkerOut> = Vec::new();
    for j in joins {
        results.push(j.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    // determinism invariant: every rank must hold identical parameters.
    let r0 = &results[0];
    for (r, out) in results.iter().enumerate().skip(1) {
        for (l, (a, b)) in r0.params.iter().zip(&out.params).enumerate() {
            anyhow::ensure!(
                a == b,
                "rank {r} layer {l} parameters diverged from rank 0"
            );
        }
    }

    Ok(DpReport {
        losses: r0.losses.clone(),
        final_params: r0.params.clone(),
        comm_bytes: stats.bytes(),
        comm_ops: stats.op_count(),
        elapsed_s,
        memory: r0.memory,
    })
}

struct WorkerOut {
    losses: Vec<f32>,
    params: Vec<Vec<f32>>,
    memory: MemoryReport,
}

fn worker(lib: Arc<Library>, spec: DpSpec, comm: CommHandle) -> Result<WorkerOut> {
    let m = comm.world();
    let n = spec.cfg.accum_steps;
    let mut trainer = Trainer::new(lib, spec.cfg.clone())?;
    let h = trainer.spec().hyper.clone();
    // same language (structure seed), disjoint stream per rank
    let mut corpus =
        MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (comm.rank() as u64 + 1));

    let mut losses = Vec::with_capacity(spec.steps as usize);
    for _ in 0..spec.steps {
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let loss = match spec.sync {
            SyncStrategy::OptimizerStates => {
                // Eq. 6: v decays by M·β₂ at mini-batch start.
                trainer.optimizer_mut().set_v_decay_factor(m as f32);
                let loss = trainer.accumulate_minibatch(&mbs, 1.0 / n as f32)?;
                // Eq. 7-8: m := mean over ranks; v := sum / M².
                let states = trainer
                    .optimizer_mut()
                    .adam_states_mut()
                    .context("AdamA states")?;
                let inv_m2 = 1.0 / (m * m) as f32;
                for layer_m in states.m.iter_mut() {
                    comm.all_reduce_mean(layer_m)?;
                }
                for layer_v in states.v.iter_mut() {
                    comm.all_reduce_sum(layer_v)?;
                    for x in layer_v.iter_mut() {
                        *x *= inv_m2;
                    }
                }
                trainer.apply_update()?;
                loss
            }
            SyncStrategy::Gradients => {
                // classic DDP: local accumulation then one grad all-reduce
                let loss = trainer.accumulate_minibatch(&mbs, 1.0 / n as f32)?;
                let opt = trainer.optimizer_mut();
                let ga = opt
                    .as_adamga_mut()
                    .context("Gradients sync requires AdamGA")?;
                for acc in ga.grad_acc_mut() {
                    comm.all_reduce_mean(acc)?;
                }
                trainer.apply_update()?;
                loss
            }
            SyncStrategy::GradPerMicrobatch => {
                // naive AdamA distribution: every layer gradient of every
                // micro-batch is globally averaged before integration.
                trainer.optimizer_mut().set_v_decay_factor(1.0);
                let gscale = 1.0 / n as f32;
                let t = trainer.step() + 1;
                let (core, opt) = trainer.parts_mut();
                opt.begin_minibatch(t)?;
                let mut loss_sum = 0.0f64;
                for mb in &mbs {
                    let loss = core.run_microbatch(mb, &mut |layer, grad| {
                        let mut g = grad.to_vec();
                        comm.all_reduce_mean(&mut g)?;
                        opt.accumulate(layer, &g, gscale)
                    })?;
                    loss_sum += loss as f64;
                }
                trainer.apply_update()?;
                (loss_sum / mbs.len() as f64) as f32
            }
        };
        // mini-batch loss averaged across ranks (reporting only)
        let mut l = vec![loss];
        comm.all_reduce_mean(&mut l)?;
        losses.push(l[0]);
    }

    Ok(WorkerOut {
        losses,
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        memory: trainer.tracker().report(),
    })
}
