//! Data-parallel training runner (paper §3.3).
//!
//! `M` workers, each owning a full [`Trainer`] replica and a disjoint
//! data shard, synchronise through a [`Collective`] group. Three sync
//! strategies reproduce the paper's design space:
//!
//! * [`SyncStrategy::OptimizerStates`] — **the paper's scheme**: decay `v`
//!   by `M·β₂` (Eq. 6), integrate local micro-grads with gscale `1/N`,
//!   then once per mini-batch all-reduce `m` (mean, Eq. 7) and `v`
//!   (sum/M², Eq. 8). Comm volume constant in N.
//! * [`SyncStrategy::Gradients`] — classic DDP+GA baseline: accumulate
//!   locally, one gradient all-reduce (mean) per mini-batch.
//! * [`SyncStrategy::GradPerMicrobatch`] — the naive AdamA distribution
//!   the paper rejects: all-reduce every layer gradient every micro-batch
//!   (O(N) collectives), integrating the *global* mean gradient.
//!
//! The [`CollectiveEngine`] picks how ranks execute: the concurrent
//! fabric (default), the legacy channel ring, or the single-threaded
//! serial simulator — all bit-for-bit identical
//! (`rust/tests/fabric_parity.rs`). Concurrent ranks run on real OS
//! threads; `threads_per_rank` re-pins each rank's host pool
//! (`Library::fork_with_threads`), composing with `runtime::pool` /
//! `runtime::simd` without changing a single bit.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use super::ckpt as wckpt;
use super::fabric::{serial, Fabric, FaultPlan, PeerDeath, Ticket, Topology};
use super::{rank_threads, Collective, CollectiveEngine, CommGroup, CommStats};
use crate::config::{OptimizerKind, TrainConfig};
use crate::coordinator::{CheckpointPolicy, MemorySnapshot, Trainer, WorldMemory};
use crate::data::{MarkovCorpus, MicroBatch};
use crate::memory::MemoryReport;
use crate::runtime::{Library, OptAlgo};

/// How workers synchronise per mini-batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncStrategy {
    OptimizerStates,
    Gradients,
    GradPerMicrobatch,
}

impl SyncStrategy {
    pub fn name(self) -> &'static str {
        match self {
            Self::OptimizerStates => "state-allreduce",
            Self::Gradients => "grad-allreduce",
            Self::GradPerMicrobatch => "grad-per-microbatch",
        }
    }
}

/// A distributed run specification.
#[derive(Debug, Clone)]
pub struct DpSpec {
    pub cfg: TrainConfig,
    pub sync: SyncStrategy,
    pub steps: u64,
    /// Markov corpus structure seed (shared); stream seeds fork per worker.
    pub data_seed: u64,
    /// Execution engine (default: the concurrent fabric).
    pub engine: CollectiveEngine,
    /// Host pool threads per rank (`Library::fork_with_threads`); 0
    /// (default) = split the default pool (`ADAMA_THREADS`) evenly
    /// across ranks, so M ranks never fan out into M·T pool threads.
    /// Pure performance knob — the pool is bit-exact at any count.
    pub threads_per_rank: usize,
    /// Reduction topology; `None` = `ADAMA_FABRIC` (default ring).
    pub topology: Option<Topology>,
    /// Async issue of the per-layer state all-reduces
    /// ([`SyncStrategy::OptimizerStates`]): `None` = `ADAMA_ASYNC`
    /// (default off). Pure scheduling knob — sync and async runs are
    /// bit-identical, ledgers included.
    pub async_issue: Option<bool>,
    /// Exec-layer optimizer override for every rank
    /// ([`Library::fork_with_opt`]); `None` inherits the launch library's
    /// seam (`ADAMA_OPT` / `host_with_opt`). Zoo rules pair with
    /// [`SyncStrategy::Gradients`].
    pub opt: Option<OptAlgo>,
    /// World checkpointing: directory + cadence/retention. `None` =
    /// resolve the strict `ADAMA_CKPT_DIR` / `ADAMA_CKPT_EVERY` /
    /// `ADAMA_CKPT_KEEP` knobs (all unset = off). A `stepNNNNNNNN/`
    /// directory of per-rank shards plus a rank-0 manifest is cut at
    /// every due step boundary ([`super::ckpt`]).
    pub checkpoint: Option<(PathBuf, CheckpointPolicy)>,
    /// Resume from the newest valid world checkpoint under the
    /// checkpoint directory before training (requires `checkpoint`);
    /// absent any valid checkpoint the run starts fresh.
    pub resume: bool,
    /// Deterministic rank death for crash-recovery drills; `None` = the
    /// strict `ADAMA_FAULT` knob (unset = none). Fabric engine only.
    pub fault: Option<FaultPlan>,
}

impl DpSpec {
    pub fn new(cfg: TrainConfig, sync: SyncStrategy, steps: u64, data_seed: u64) -> Self {
        Self {
            cfg,
            sync,
            steps,
            data_seed,
            engine: CollectiveEngine::Fabric,
            threads_per_rank: 0,
            topology: None,
            async_issue: None,
            opt: None,
            checkpoint: None,
            resume: false,
            fault: None,
        }
    }

    pub fn with_engine(mut self, engine: CollectiveEngine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_topology(mut self, topo: Topology) -> Self {
        self.topology = Some(topo);
        self
    }

    pub fn with_rank_threads(mut self, threads: usize) -> Self {
        self.threads_per_rank = threads;
        self
    }

    pub fn with_async(mut self, async_issue: bool) -> Self {
        self.async_issue = Some(async_issue);
        self
    }

    pub fn with_opt(mut self, opt: OptAlgo) -> Self {
        self.opt = Some(opt);
        self
    }

    pub fn with_checkpoint(mut self, dir: impl Into<PathBuf>, policy: CheckpointPolicy) -> Self {
        self.checkpoint = Some((dir.into(), policy));
        self
    }

    pub fn with_resume(mut self) -> Self {
        self.resume = true;
        self
    }

    pub fn with_fault(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }
}

/// Result of a distributed run.
#[derive(Debug, Clone)]
pub struct DpReport {
    pub losses: Vec<f32>,
    /// Rank-0 final parameters (all ranks are asserted identical).
    pub final_params: Vec<Vec<f32>>,
    pub comm_bytes: u64,
    pub comm_ops: u64,
    pub elapsed_s: f64,
    /// Rank-0 coordinator tracker peaks (back-compat convenience).
    pub memory: MemoryReport,
    /// Coordinator + executor peaks for every rank, in rank order.
    pub per_rank_memory: Vec<MemorySnapshot>,
    pub engine: CollectiveEngine,
    /// `Some(step)` when the (possibly supervisor-restarted) run that
    /// produced this report started from a step-`step` world checkpoint.
    pub resumed_from: Option<u64>,
}

impl DpReport {
    /// Per-rank snapshots with world-level aggregation.
    pub fn world_memory(&self) -> WorldMemory {
        WorldMemory::new(self.per_rank_memory.clone())
    }
}

/// Run `spec.steps` mini-batches across `spec.cfg.workers` workers.
pub fn run_data_parallel(lib: Arc<Library>, spec: DpSpec) -> Result<DpReport> {
    let m = spec.cfg.workers;
    spec.cfg.validate()?;
    // normalize the exec-layer seam once, before the ranks fork: a spec
    // override beats the ambient `ADAMA_OPT`; `None` inherits it.
    let lib = match spec.opt {
        Some(algo) => lib.fork_with_opt(Some(algo)),
        None => lib,
    };
    let seam_opt = lib.executor().opt_algo();
    if spec.sync != SyncStrategy::Gradients
        && (spec.cfg.optimizer != OptimizerKind::AdamA || seam_opt.is_some())
    {
        bail!("{:?} sync requires AdamA", spec.sync);
    }
    let topo = match spec.topology {
        Some(t) => t,
        None => Topology::from_env()?,
    };
    // strictly-parsed once, before the workers fork
    let mut spec = spec;
    if spec.async_issue.is_none() {
        spec.async_issue = Some(super::fabric::async_from_env()?);
    }
    if spec.checkpoint.is_none() {
        spec.checkpoint = crate::coordinator::checkpoint::from_env()?;
    }
    if spec.fault.is_none() {
        spec.fault = FaultPlan::from_env()?;
    }
    let tpr = rank_threads(spec.threads_per_rank, m)?;
    if spec.engine == CollectiveEngine::Serial {
        ensure!(
            spec.checkpoint.is_none() && !spec.resume && spec.fault.is_none(),
            "the serial engine does not drive checkpoints, resume, or fault injection — \
             use the fabric or channel engine"
        );
        return run_dp_serial(lib, spec, topo, tpr);
    }
    if let Some(f) = spec.fault {
        ensure!(
            spec.engine == CollectiveEngine::Fabric,
            "fault injection requires the fabric engine (got '{}')",
            spec.engine.name()
        );
        ensure!(
            f.rank < m,
            "fault plan names rank {} but the world has {m} rank(s)",
            f.rank
        );
    }
    let flow = format!("dp:{}", spec.sync.name());
    let mut resume_ws: Option<Arc<wckpt::WorldState>> = None;
    if spec.resume {
        let (dir, _) = spec.checkpoint.as_ref().context(
            "resume requires a checkpoint directory (ADAMA_CKPT_DIR / DpSpec::with_checkpoint)",
        )?;
        resume_ws = wckpt::latest_valid(dir)?.map(|(_, ws)| Arc::new(ws));
    }
    // Supervisor loop: run the world; when a rank dies (injected fault or
    // real defect) and checkpoints are configured, restart every rank
    // from the newest valid world checkpoint with the fault disarmed.
    let mut fault_arm = spec.fault;
    let mut attempts = 0usize;
    loop {
        if let Some(ws) = resume_ws.as_deref() {
            ensure!(
                ws.flow == flow,
                "checkpoint was written by flow '{}', this run is '{flow}'",
                ws.flow
            );
        }
        let res = match spec.engine {
            CollectiveEngine::Channel => {
                // the channel ring's fold order *is* the ring topology; a
                // tree request must not be silently downgraded
                super::ensure_ring_only(topo)?;
                let handles = CommGroup::new(m);
                run_dp_threaded(lib.clone(), spec.clone(), handles, tpr, resume_ws.clone())
            }
            CollectiveEngine::Fabric => {
                let handles = Fabric::with_topology(m, topo);
                if let Some(f) = fault_arm {
                    handles[f.rank].arm_fault(f);
                }
                run_dp_threaded(lib.clone(), spec.clone(), handles, tpr, resume_ws.clone())
            }
            CollectiveEngine::Serial => unreachable!("serial handled above"),
        };
        match res {
            Ok(report) => return Ok(report),
            Err(e) => {
                let died = e.chain().any(|c| c.downcast_ref::<PeerDeath>().is_some());
                let Some((dir, _)) = spec.checkpoint.as_ref() else { return Err(e) };
                attempts += 1;
                if !died || attempts >= 3 {
                    return Err(e);
                }
                resume_ws = wckpt::latest_valid(dir)?.map(|(_, ws)| Arc::new(ws));
                fault_arm = None;
            }
        }
    }
}

fn run_dp_threaded<C: Collective + 'static>(
    lib: Arc<Library>,
    spec: DpSpec,
    handles: Vec<C>,
    tpr: usize,
    resume: Option<Arc<wckpt::WorldState>>,
) -> Result<DpReport> {
    let stats = handles[0].stats().clone();
    // fresh handles carry fresh ledgers; a resumed run reports the
    // checkpointed ledger plus what this attempt adds, which is exactly
    // the straight-run ledger (abandoned partial steps are re-done)
    let ledger_base = resume.as_deref().map(|ws| ws.ledger).unwrap_or((0, 0));
    let resumed_from = resume.as_deref().map(|ws| ws.step);
    let t0 = Instant::now();

    let mut joins = Vec::new();
    for comm in handles {
        // Per-rank fork. Each rank is its own OS thread: re-pin the host
        // executor's intra-op pool to `tpr` workers per rank so M ranks
        // don't fan out into M·T pool threads (oversubscription), and —
        // when an activation stash budget is set — give every rank a
        // private arena so concurrent ranks never evict or meter each
        // other's entries. Numerics are unaffected — the pool is
        // bit-for-bit identical at any thread count, and stash/remat are
        // bit-identical.
        let lib = lib.fork_with_threads(tpr);
        let spec = spec.clone();
        let resume = resume.clone();
        joins.push(std::thread::spawn(move || worker(lib, spec, comm, resume)));
    }
    // Join every rank before surfacing an error: bailing on the first
    // Err would detach still-running peer threads mid-collective. A
    // rank death outranks the survivors' collateral errors — it is the
    // root cause and the one the supervisor can recover from.
    let mut results: Vec<WorkerOut> = Vec::new();
    let mut death: Option<anyhow::Error> = None;
    let mut other: Option<anyhow::Error> = None;
    for j in joins {
        let joined = j.join().map_err(|_| anyhow::anyhow!("worker panicked"));
        match joined.and_then(|r| r) {
            Ok(out) => results.push(out),
            Err(e) if e.chain().any(|c| c.downcast_ref::<PeerDeath>().is_some()) => {
                death.get_or_insert(e);
            }
            Err(e) => {
                other.get_or_insert(e);
            }
        }
    }
    if let Some(e) = death {
        return Err(e);
    }
    if let Some(e) = other {
        return Err(e);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    // determinism invariant: every rank must hold identical parameters.
    let r0 = &results[0];
    for (r, out) in results.iter().enumerate().skip(1) {
        for (l, (a, b)) in r0.params.iter().zip(&out.params).enumerate() {
            ensure!(a == b, "rank {r} layer {l} parameters diverged from rank 0");
        }
    }

    Ok(DpReport {
        losses: r0.losses.clone(),
        final_params: r0.params.clone(),
        comm_bytes: ledger_base.0 + stats.bytes(),
        comm_ops: ledger_base.1 + stats.op_count(),
        elapsed_s,
        memory: r0.mem.tracker,
        per_rank_memory: results.iter().map(|r| r.mem).collect(),
        engine: spec.engine,
        resumed_from,
    })
}

struct WorkerOut {
    losses: Vec<f32>,
    params: Vec<Vec<f32>>,
    mem: MemorySnapshot,
}

fn worker<C: Collective>(
    lib: Arc<Library>,
    spec: DpSpec,
    comm: C,
    resume: Option<Arc<wckpt::WorldState>>,
) -> Result<WorkerOut> {
    let m = comm.world();
    let rank = comm.rank();
    let n = spec.cfg.accum_steps;
    let mut trainer = Trainer::new(lib, spec.cfg.clone())?;
    let h = trainer.spec().hyper.clone();
    // same language (structure seed), disjoint stream per rank
    let mut corpus = MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (rank as u64 + 1));

    let mut losses = Vec::with_capacity(spec.steps as usize);
    let mut start = 0u64;
    if let Some(ws) = resume.as_deref() {
        // Replicated state (params / step / optimizer) restores through
        // the single-rank path. The DP sync invariant makes the saved
        // optimizer state identical on every rank, so any rank file can
        // serve a rank the saved world did not have.
        let rs = &ws.ranks[rank.min(ws.world - 1)];
        trainer.restore_state(&crate::model::ckpt::TrainState {
            fingerprint: ws.fingerprint,
            step: ws.step,
            params: ws.params.clone(),
            opt: rs.opt.clone(),
            rngs: Vec::new(),
            losses: ws.losses.clone(),
        })?;
        // data cursors are per-rank streams: a rank the saved world had
        // continues its stream; a new rank starts its own from scratch
        if rank < ws.world {
            corpus.set_rng(ws.ranks[rank].rng.clone());
        }
        losses.extend_from_slice(&ws.losses);
        start = ws.step;
    }
    let ledger_base = resume.as_deref().map(|ws| ws.ledger).unwrap_or((0, 0));

    for step in start + 1..=spec.steps {
        comm.begin_step(step);
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let loss = match spec.sync {
            SyncStrategy::OptimizerStates => {
                // Eq. 6: v decays by M·β₂ at mini-batch start.
                trainer.optimizer_mut().set_v_decay_factor(m as f32);
                let loss = trainer.accumulate_minibatch(&mbs, 1.0 / n as f32)?;
                // Eq. 7-8: m := mean over ranks; v := sum / M².
                let states = trainer
                    .optimizer_mut()
                    .adam_states_mut()
                    .context("AdamA states")?;
                let inv_m2 = 1.0 / (m * m) as f32;
                if spec.async_issue.unwrap_or(false) {
                    // issue every layer's state reduction before waiting
                    // any — the comm thread folds layer k while layer k+1
                    // is still being posted. Same per-rank entry order as
                    // the sync arm (all m layers, then all v layers); the
                    // mean is the sum ×1/M, so bits and ledger match the
                    // sync arm exactly.
                    let m_tickets: Vec<Ticket> =
                        states.m.iter().map(|b| comm.all_reduce_sum_async(b.clone())).collect();
                    let v_tickets: Vec<Ticket> =
                        states.v.iter().map(|b| comm.all_reduce_sum_async(b.clone())).collect();
                    let inv_m = 1.0 / m as f32;
                    for (layer_m, t) in states.m.iter_mut().zip(m_tickets) {
                        let rb = t.wait()?.pop().expect("one buffer per ticket");
                        layer_m.copy_from_slice(&rb.data);
                        for x in layer_m.iter_mut() {
                            *x *= inv_m;
                        }
                    }
                    for (layer_v, t) in states.v.iter_mut().zip(v_tickets) {
                        let rb = t.wait()?.pop().expect("one buffer per ticket");
                        layer_v.copy_from_slice(&rb.data);
                        for x in layer_v.iter_mut() {
                            *x *= inv_m2;
                        }
                    }
                } else {
                    for layer_m in states.m.iter_mut() {
                        comm.all_reduce_mean(layer_m)?;
                    }
                    for layer_v in states.v.iter_mut() {
                        comm.all_reduce_sum(layer_v)?;
                        for x in layer_v.iter_mut() {
                            *x *= inv_m2;
                        }
                    }
                }
                trainer.apply_update()?;
                loss
            }
            SyncStrategy::Gradients => {
                // classic DDP: local accumulation then one grad all-reduce
                let loss = trainer.accumulate_minibatch(&mbs, 1.0 / n as f32)?;
                let opt = trainer.optimizer_mut();
                let accs = opt
                    .grad_acc_mut()
                    .context("Gradients sync requires a gradient-accumulating optimizer")?;
                for acc in accs.iter_mut() {
                    comm.all_reduce_mean(acc)?;
                }
                trainer.apply_update()?;
                loss
            }
            SyncStrategy::GradPerMicrobatch => {
                // naive AdamA distribution: every layer gradient of every
                // micro-batch is globally averaged before integration.
                trainer.optimizer_mut().set_v_decay_factor(1.0);
                let gscale = 1.0 / n as f32;
                let t = trainer.step() + 1;
                let (core, opt) = trainer.parts_mut();
                opt.begin_minibatch(t)?;
                let mut loss_sum = 0.0f64;
                for mb in &mbs {
                    let loss = core.run_microbatch(mb, &mut |layer, grad| {
                        let mut g = grad.to_vec();
                        comm.all_reduce_mean(&mut g)?;
                        opt.accumulate(layer, &g, gscale)
                    })?;
                    loss_sum += loss as f64;
                }
                trainer.apply_update()?;
                (loss_sum / mbs.len() as f64) as f32
            }
        };
        // mini-batch loss averaged across ranks (reporting only)
        let mut l = vec![loss];
        comm.all_reduce_mean(&mut l)?;
        losses.push(l[0]);

        if let Some((dir, policy)) = spec.checkpoint.as_ref() {
            if policy.due(step) {
                let opt = trainer.optimizer_mut().export_state()?;
                let fingerprint = crate::model::ckpt::config_fingerprint(
                    trainer.spec(),
                    trainer.config(),
                    &opt.tag,
                );
                let mine = wckpt::RankState { rank, rng: corpus.rng().clone(), opt };
                let meta = (rank == 0).then(|| wckpt::WorldMeta {
                    flow: format!("dp:{}", spec.sync.name()),
                    params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
                    losses: losses.clone(),
                });
                wckpt::write_world(
                    &comm,
                    dir,
                    policy.keep_last_n,
                    fingerprint,
                    step,
                    &mine,
                    meta.as_ref(),
                    ledger_base,
                )?;
            }
        }
    }

    Ok(WorkerOut {
        losses,
        params: trainer.params().iter().map(|p| p.flat.clone()).collect(),
        mem: MemorySnapshot {
            tracker: trainer.tracker().report(),
            host: trainer.library().executor().memory(),
        },
    })
}

/// The serial simulator: all ranks advance in one thread, phase by phase,
/// with reductions folded by [`serial`] in the same fixed order the
/// concurrent engines use — the bit-for-bit oracle for the fabric.
fn run_dp_serial(
    lib: Arc<Library>,
    spec: DpSpec,
    topo: Topology,
    tpr: usize,
) -> Result<DpReport> {
    let m = spec.cfg.workers;
    let n = spec.cfg.accum_steps;
    let stats = Arc::new(CommStats::default());
    let t0 = Instant::now();

    let mut trainers = Vec::with_capacity(m);
    let mut corpora = Vec::with_capacity(m);
    for r in 0..m {
        let rlib = lib.fork_with_threads(tpr);
        let trainer = Trainer::new(rlib, spec.cfg.clone())?;
        let h = trainer.spec().hyper.clone();
        corpora.push(MarkovCorpus::new(h.vocab, spec.data_seed, 1_000_003 * (r as u64 + 1)));
        trainers.push(trainer);
    }
    let h = trainers[0].spec().hyper.clone();
    let n_layers = trainers[0].spec().layers.len();

    let mut losses = Vec::with_capacity(spec.steps as usize);
    for _ in 0..spec.steps {
        let mbs: Vec<Vec<MicroBatch>> =
            corpora.iter_mut().map(|c| c.minibatch(n, h.microbatch, h.seq)).collect();
        let mut rank_loss = vec![0.0f32; m];
        match spec.sync {
            SyncStrategy::OptimizerStates => {
                for (r, t) in trainers.iter_mut().enumerate() {
                    t.optimizer_mut().set_v_decay_factor(m as f32);
                    rank_loss[r] = t.accumulate_minibatch(&mbs[r], 1.0 / n as f32)?;
                }
                let inv_m2 = 1.0 / (m * m) as f32;
                for l in 0..n_layers {
                    // Eq. 7: m := ring-mean across ranks
                    let mut bufs = Vec::with_capacity(m);
                    for t in trainers.iter_mut() {
                        bufs.push(
                            t.optimizer_mut().adam_states_mut().context("AdamA states")?.m[l]
                                .clone(),
                        );
                    }
                    serial::all_reduce_mean(topo, &mut bufs, &stats)?;
                    for (t, b) in trainers.iter_mut().zip(&bufs) {
                        t.optimizer_mut().adam_states_mut().context("AdamA states")?.m[l]
                            .copy_from_slice(b);
                    }
                    // Eq. 8: v := ring-sum / M²
                    let mut bufs = Vec::with_capacity(m);
                    for t in trainers.iter_mut() {
                        bufs.push(
                            t.optimizer_mut().adam_states_mut().context("AdamA states")?.v[l]
                                .clone(),
                        );
                    }
                    serial::all_reduce_sum(topo, &mut bufs, &stats)?;
                    for (t, b) in trainers.iter_mut().zip(&bufs) {
                        let states =
                            t.optimizer_mut().adam_states_mut().context("AdamA states")?;
                        states.v[l].copy_from_slice(b);
                        for x in states.v[l].iter_mut() {
                            *x *= inv_m2;
                        }
                    }
                }
                for t in trainers.iter_mut() {
                    t.apply_update()?;
                }
            }
            SyncStrategy::Gradients => {
                for (r, t) in trainers.iter_mut().enumerate() {
                    rank_loss[r] = t.accumulate_minibatch(&mbs[r], 1.0 / n as f32)?;
                }
                for l in 0..n_layers {
                    let mut bufs = Vec::with_capacity(m);
                    for t in trainers.iter_mut() {
                        bufs.push(
                            t.optimizer_mut()
                                .grad_acc_mut()
                                .context("Gradients sync requires a gradient accumulator")?[l]
                                .clone(),
                        );
                    }
                    serial::all_reduce_mean(topo, &mut bufs, &stats)?;
                    for (t, b) in trainers.iter_mut().zip(&bufs) {
                        t.optimizer_mut()
                            .grad_acc_mut()
                            .context("Gradients sync requires a gradient accumulator")?[l]
                            .copy_from_slice(b);
                    }
                }
                for t in trainers.iter_mut() {
                    t.apply_update()?;
                }
            }
            SyncStrategy::GradPerMicrobatch => {
                let gscale = 1.0 / n as f32;
                let t_next = trainers[0].step() + 1;
                for t in trainers.iter_mut() {
                    t.optimizer_mut().set_v_decay_factor(1.0);
                    let (_core, opt) = t.parts_mut();
                    opt.begin_minibatch(t_next)?;
                }
                let mut sums = vec![0.0f64; m];
                for i in 0..n {
                    // run every rank's i-th micro-batch, buffering layer
                    // gradients in production order
                    let mut grads: Vec<Vec<(usize, Vec<f32>)>> = Vec::with_capacity(m);
                    for (r, t) in trainers.iter_mut().enumerate() {
                        let mut buf: Vec<(usize, Vec<f32>)> = Vec::new();
                        let loss = t.accumulate_minibatch_sink(
                            std::slice::from_ref(&mbs[r][i]),
                            &mut |layer, grad| {
                                buf.push((layer, grad.to_vec()));
                                Ok(())
                            },
                        )?;
                        sums[r] += loss as f64;
                        grads.push(buf);
                    }
                    // globally average each gradient in the fixed chain
                    // order, then integrate on every rank — bit-identical
                    // to the concurrent sink (per-layer state integration
                    // commutes with the rest of the backward)
                    let k_count = grads[0].len();
                    for g in &grads {
                        ensure!(
                            g.len() == k_count,
                            "ranks produced different gradient counts"
                        );
                    }
                    for k in 0..k_count {
                        let layer = grads[0][k].0;
                        let mut bufs: Vec<Vec<f32>> =
                            grads.iter().map(|g| g[k].1.clone()).collect();
                        serial::all_reduce_mean(topo, &mut bufs, &stats)?;
                        for (t, b) in trainers.iter_mut().zip(&bufs) {
                            let (_core, opt) = t.parts_mut();
                            opt.accumulate(layer, b, gscale)?;
                        }
                    }
                }
                for (r, t) in trainers.iter_mut().enumerate() {
                    t.apply_update()?;
                    rank_loss[r] = (sums[r] / n as f64) as f32;
                }
            }
        }
        // mini-batch loss averaged across ranks (reporting only) — the
        // same single-element ring mean the worker path applies
        let mut lbufs: Vec<Vec<f32>> = rank_loss.iter().map(|&l| vec![l]).collect();
        serial::all_reduce_mean(topo, &mut lbufs, &stats)?;
        losses.push(lbufs[0][0]);
    }

    let final_params: Vec<Vec<f32>> =
        trainers[0].params().iter().map(|p| p.flat.clone()).collect();
    for (r, t) in trainers.iter().enumerate().skip(1) {
        for (l, (a, b)) in
            final_params.iter().zip(t.params().iter().map(|p| &p.flat)).enumerate()
        {
            ensure!(a == b, "rank {r} layer {l} parameters diverged from rank 0");
        }
    }
    let per_rank_memory: Vec<MemorySnapshot> = trainers
        .iter()
        .map(|t| MemorySnapshot {
            tracker: t.tracker().report(),
            host: t.library().executor().memory(),
        })
        .collect();

    Ok(DpReport {
        losses,
        final_params,
        comm_bytes: stats.bytes(),
        comm_ops: stats.op_count(),
        elapsed_s: t0.elapsed().as_secs_f64(),
        memory: per_rank_memory[0].tracker,
        per_rank_memory,
        engine: CollectiveEngine::Serial,
        resumed_from: None,
    })
}
