//! In-process data-parallel substrate: concurrent collectives with
//! deterministic reductions, a communication-volume ledger, an α-β cost
//! model at DGX scale, the distributed training runner (paper §3.3,
//! Eq. 5–8) and ZeRO-S1.
//!
//! Three interchangeable execution engines drive the same rank algorithms
//! ([`CollectiveEngine`]):
//!
//! * **fabric** (default) — N ranks on real OS threads meeting at a
//!   shared-memory board ([`fabric`]) with a fixed reduction order that is
//!   independent of arrival timing;
//! * **channel** — the legacy lock-step mpsc ring ([`CommHandle`]): rank
//!   threads exchange `Vec<f32>` slices pairwise in `2(M-1)` phases, like
//!   a software NCCL;
//! * **serial** — a single-threaded simulator that advances all ranks
//!   phase by phase and folds reductions with [`fabric::serial`].
//!
//! All three are **bit-for-bit identical** for any world size, sync
//! strategy, `ADAMA_THREADS` and `ADAMA_SIMD` setting
//! (`rust/tests/fabric_parity.rs`); the reduction *math* and the *byte
//! volume* match what a real ring interconnect would do — which is
//! exactly what the paper's Figure 7 measures.

pub mod ckpt;
mod comm;
mod cost;
mod dp;
pub mod fabric;
mod zero;

use std::ops::Range;
use std::sync::Arc;

use anyhow::Result;

pub use comm::{CommGroup, CommHandle, CommStats};
pub use cost::{ClusterSpec, CommCostModel};
pub use dp::{run_data_parallel, DpReport, DpSpec, SyncStrategy};
pub use fabric::{
    async_from_env, bucket_bytes_from_env, parse_async, parse_bucket_bytes, Fabric, FabricHandle,
    FaultPlan, PeerDeath, ReducedBuf, Ticket, Topology,
};
pub use zero::{run_zero1, Zero1Report, Zero1Spec};

/// Which engine drives a distributed run. All engines produce identical
/// bits; they differ in how rank execution is scheduled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveEngine {
    /// Single-threaded reference simulator: ranks advance phase by phase
    /// in one thread, reductions folded by [`fabric::serial`]. The oracle
    /// the concurrent engines are verified against.
    Serial,
    /// Legacy lock-step mpsc channel ring — one OS thread per rank,
    /// point-to-point sends ([`CommHandle`]). Ring topology only: a
    /// tree request is rejected rather than silently downgraded.
    Channel,
    /// Shared-memory concurrent fabric — one OS thread per rank, board
    /// rendezvous with timing-independent reduction order
    /// ([`FabricHandle`]). The default.
    Fabric,
}

impl CollectiveEngine {
    pub const ALL: [CollectiveEngine; 3] =
        [CollectiveEngine::Serial, CollectiveEngine::Channel, CollectiveEngine::Fabric];

    pub fn name(self) -> &'static str {
        match self {
            CollectiveEngine::Serial => "serial",
            CollectiveEngine::Channel => "channel",
            CollectiveEngine::Fabric => "fabric",
        }
    }
}

/// Resolve the per-rank host pool size shared by the DP/ZeRO runners:
/// an explicit count, or (0) an even split of the default pool across
/// ranks, floored at 1.
pub(crate) fn rank_threads(spec: usize, world: usize) -> Result<usize> {
    Ok(match spec {
        0 => (crate::runtime::pool::default_threads()? / world.max(1)).max(1),
        t => t,
    })
}

/// The channel engine implements exactly the ring fold order; reject any
/// other topology instead of silently downgrading it.
pub(crate) fn ensure_ring_only(topo: Topology) -> Result<()> {
    anyhow::ensure!(
        topo == Topology::Ring,
        "the channel engine supports only the ring topology (got '{}'); use the fabric \
         or serial engine for ADAMA_FABRIC={}",
        topo.name(),
        topo.name()
    );
    Ok(())
}

/// Rank-side collective interface — the DP/ZeRO workers are generic over
/// it, so the channel ring and the fabric run the identical algorithm.
///
/// Collectives must be entered by every rank in the same order (like
/// NCCL). Buffer lengths must match across ranks. The `_async` family
/// returns a [`Ticket`] to `wait()` later; engines without a native async
/// path (channel ring, serial) inherit blocking shims that complete the
/// collective inline and hand back an already-filled ticket — bitwise and
/// ledger-wise indistinguishable from real overlap, so the DP/ZeRO flows
/// stay engine-generic under `ADAMA_ASYNC=1`.
pub trait Collective: Send {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;
    fn stats(&self) -> &Arc<CommStats>;

    /// Mark the start of 1-based step `step` — the hook the fabric's
    /// deterministic fault injection counts collective calls against
    /// ([`FabricHandle::begin_step`]). Default: no-op (engines without
    /// fault support).
    fn begin_step(&self, _step: u64) {}
    fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()>;
    fn all_reduce_mean(&self, data: &mut [f32]) -> Result<()>;
    fn reduce_scatter_sum(&self, data: &mut [f32]) -> Result<Range<usize>>;
    fn all_gather_owned(&self, data: &mut [f32]) -> Result<()>;
    fn barrier(&self) -> Result<()>;

    /// Async all-reduce (sum); the returned ticket's single [`ReducedBuf`]
    /// owns the whole range. Default: blocking shim.
    fn all_reduce_sum_async(&self, mut data: Vec<f32>) -> Ticket {
        match self.all_reduce_sum(&mut data) {
            Ok(()) => {
                let n = data.len();
                Ticket::ready(Ok(vec![ReducedBuf { data, owned: 0..n }]))
            }
            Err(e) => Ticket::ready(Err(e)),
        }
    }

    /// Async reduce-scatter (sum) of one buffer. Default: blocking shim.
    fn reduce_scatter_sum_async(&self, data: Vec<f32>) -> Ticket {
        self.reduce_scatter_many_async(vec![data])
    }

    /// Async batched reduce-scatter — the gradient-bucketing primitive:
    /// one ticket for the whole batch, one ledger op per logical buffer,
    /// buffers returned in issue order. Default: blocking shim reducing
    /// each buffer in order (identical bits and ledger, no batching).
    fn reduce_scatter_many_async(&self, bufs: Vec<Vec<f32>>) -> Ticket {
        let mut out = Vec::with_capacity(bufs.len());
        for mut b in bufs {
            match self.reduce_scatter_sum(&mut b) {
                Ok(owned) => out.push(ReducedBuf { data: b, owned }),
                Err(e) => return Ticket::ready(Err(e)),
            }
        }
        Ticket::ready(Ok(out))
    }
}

impl Collective for CommHandle {
    fn rank(&self) -> usize {
        CommHandle::rank(self)
    }

    fn world(&self) -> usize {
        CommHandle::world(self)
    }

    fn stats(&self) -> &Arc<CommStats> {
        CommHandle::stats(self)
    }

    fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        CommHandle::all_reduce_sum(self, data)
    }

    fn all_reduce_mean(&self, data: &mut [f32]) -> Result<()> {
        CommHandle::all_reduce_mean(self, data)
    }

    fn reduce_scatter_sum(&self, data: &mut [f32]) -> Result<Range<usize>> {
        CommHandle::reduce_scatter_sum(self, data)
    }

    fn all_gather_owned(&self, data: &mut [f32]) -> Result<()> {
        CommHandle::all_gather_owned(self, data)
    }

    fn barrier(&self) -> Result<()> {
        CommHandle::barrier(self)
    }
}

impl Collective for FabricHandle {
    fn rank(&self) -> usize {
        FabricHandle::rank(self)
    }

    fn begin_step(&self, step: u64) {
        FabricHandle::begin_step(self, step)
    }

    fn world(&self) -> usize {
        FabricHandle::world(self)
    }

    fn stats(&self) -> &Arc<CommStats> {
        FabricHandle::stats(self)
    }

    fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        FabricHandle::all_reduce_sum(self, data)
    }

    fn all_reduce_mean(&self, data: &mut [f32]) -> Result<()> {
        FabricHandle::all_reduce_mean(self, data)
    }

    fn reduce_scatter_sum(&self, data: &mut [f32]) -> Result<Range<usize>> {
        FabricHandle::reduce_scatter_sum(self, data)
    }

    fn all_gather_owned(&self, data: &mut [f32]) -> Result<()> {
        FabricHandle::all_gather_owned(self, data)
    }

    fn barrier(&self) -> Result<()> {
        FabricHandle::barrier(self)
    }

    // the fabric is the one engine with genuine overlap: override the
    // blocking shims with the comm-thread ticketed forms
    fn all_reduce_sum_async(&self, data: Vec<f32>) -> Ticket {
        FabricHandle::all_reduce_sum_async(self, data)
    }

    fn reduce_scatter_sum_async(&self, data: Vec<f32>) -> Ticket {
        FabricHandle::reduce_scatter_sum_async(self, data)
    }

    fn reduce_scatter_many_async(&self, bufs: Vec<Vec<f32>>) -> Ticket {
        FabricHandle::reduce_scatter_many_async(self, bufs)
    }
}
