//! In-process data-parallel substrate: ring collectives over channels,
//! a communication-volume ledger, an α-β cost model at DGX scale, the
//! distributed training runner (paper §3.3, Eq. 5–8) and ZeRO-S1.
//!
//! NCCL is simulated by rank threads exchanging `Vec<f32>` slices through
//! `std::sync::mpsc` channels using the standard ring algorithm
//! (reduce-scatter + all-gather, 2(M-1) phases). The reduction *math* and
//! the *byte volume* are identical to the real thing — which is exactly
//! what the paper's Figure 7 measures.

mod comm;
mod cost;
mod dp;
mod zero;

pub use comm::{CommGroup, CommHandle, CommStats};
pub use cost::{ClusterSpec, CommCostModel};
pub use dp::{run_data_parallel, DpReport, DpSpec, SyncStrategy};
pub use zero::{run_zero1, Zero1Report, Zero1Spec};
