//! `collective::fabric` — a concurrent multi-rank collective fabric with
//! **deterministic** reductions.
//!
//! N ranks run simultaneously on real OS threads (each owning its forked
//! `Library`/executor, composing with `runtime::pool` and `runtime::simd`)
//! and meet at a shared-memory board instead of a point-to-point channel
//! ring. Every collective has a **fixed reduction order that is
//! independent of arrival timing**: ranks post their contributions, a
//! barrier separates the post phase from the compute phase, and each
//! reduced shard is folded in a statically-determined rank order. Under
//! IEEE-754 f32 this makes an N-rank concurrent run bit-for-bit identical
//! to the single-threaded reference in [`serial`] — and, for
//! [`Topology::Ring`], to the legacy lock-step channel ring
//! ([`CommHandle`]) — at any `ADAMA_THREADS` / `ADAMA_SIMD` setting.
//!
//! ## The determinism contract
//!
//! For a buffer split into per-rank shards by
//! [`CommHandle::shard_ranges`], shard `j` is reduced as the left-to-right
//! chain
//!
//! ```text
//! ((x_j + x_{j+1}) + x_{j+2}) + … + x_{j+M-1}        (indices mod M)
//! ```
//!
//! for [`Topology::Ring`] — exactly the order in which the channel ring's
//! reduce-scatter folds contributions (f32 addition is commutative
//! bit-for-bit, so chain-from-`j` equals the ring's arrival order) — and
//! as a fixed balanced pairwise bracketing over rank order `0..M` for
//! [`Topology::Tree`]. Neither depends on *when* a rank arrives, only on
//! rank indices, so injected delays cannot change a single bit
//! (`rust/tests/proptests.rs` asserts this under random per-rank sleeps).
//!
//! ## Volume ledger
//!
//! The fabric never moves bytes over a wire, but it keeps the same
//! [`CommStats`] ledger the channel ring keeps — per rank, the payload a
//! real ring interconnect would carry (`2·(M-1)/M · bytes` for
//! all-reduce, half that for reduce-scatter / all-gather) — so Figure-7
//! style volume measurements are engine-independent.
//!
//! ## Async issue
//!
//! Every collective also exists in a ticketed async form
//! ([`FabricHandle::all_reduce_sum_async`] /
//! [`FabricHandle::reduce_scatter_sum_async`] /
//! [`FabricHandle::reduce_scatter_many_async`]): the buffer moves to a
//! lazily-spawned **per-rank comm thread** and a [`Ticket`] comes back
//! immediately, so the issuing rank keeps computing (layer *k−1*'s
//! backward) while the fabric folds layer *k*. The comm thread executes
//! its queue FIFO, which preserves the one property the board needs —
//! every rank enters every collective in the same order — and the fold
//! order is the same pure function of rank indices as the sync path, so
//! async issue changes *when* work happens, never *what* is folded: sync
//! and async runs are bit-for-bit identical, ledgers included. Once a
//! handle has a comm thread, its synchronous calls funnel through the
//! same queue (one total order per rank; no interleaving hazard).
//! [`FabricHandle::reduce_scatter_many_async`] batches several buffers
//! through a single gate crossing — the `ADAMA_BUCKET_BYTES` bucketing
//! primitive (see [`parse_bucket_bytes`]) — while still recording one
//! ledger op per logical buffer.
//!
//! ## Failure semantics
//!
//! Collectives must be entered by every rank, in the same order (like
//! NCCL). If a rank errors out and drops its handle while peers are
//! blocked inside a collective, the internal gate converts the would-be
//! deadlock into a `"rank handle dropped"` error on the surviving ranks.
//! A handle dropped with async work still queued first **drains** its
//! comm thread — peers blocked in those same collectives complete
//! normally — and only then abandons the gate.
//!
//! ## Deterministic fault injection
//!
//! For crash-recovery testing a handle can be *armed* with a
//! [`FaultPlan`] (`ADAMA_FAULT=rank:step[:op]`,
//! [`FabricHandle::arm_fault`]): at the chosen 1-based step, immediately
//! before the rank's `(op+1)`-th collective call of that step, the handle
//! kills itself — it abandons the gate exactly as a crashed process
//! would, and every later collective on it keeps failing. Survivors
//! blocked in any collective fail with a [`PeerDeath`] error naming the
//! dead rank and step (`err.downcast_ref::<PeerDeath>()`), which is what
//! the distributed runners' supervisors catch to trigger checkpoint
//! recovery. The op index counts collective *calls* in step order (a
//! bucketed batch counts once; barriers count), driven by
//! [`FabricHandle::begin_step`] — so the kill point is a deterministic
//! function of the plan, never of thread timing.

use std::ops::Range;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError, RwLock};
use std::thread::JoinHandle;

use anyhow::{bail, ensure, Result};

use super::comm::{CommHandle, CommStats};

/// Reduction topology of the fabric (`ADAMA_FABRIC`).
///
/// Both orders are fully deterministic; they differ only in how the f32
/// additions are bracketed, so runs under different topologies are each
/// internally reproducible but not bit-comparable to each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Left-to-right chain per shard, starting at the shard's index —
    /// bit-identical to the legacy channel ring (the default).
    Ring,
    /// Fixed balanced pairwise bracketing over rank order `0..M` —
    /// `(x0+x1) + (x2+x3) …` — the order a tree all-reduce applies.
    Tree,
}

impl Topology {
    pub const ALL: [Topology; 2] = [Topology::Ring, Topology::Tree];

    /// Stable lower-case name (the `ADAMA_FABRIC` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::Tree => "tree",
        }
    }

    /// Strictly resolve an `ADAMA_FABRIC` value: unset/empty defaults to
    /// [`Topology::Ring`]; anything other than `ring`/`tree` is an error
    /// naming the accepted values (no silent fallback).
    pub fn parse(spec: Option<&str>) -> Result<Topology> {
        let s = match spec.map(str::trim) {
            Some(s) if !s.is_empty() => s.to_ascii_lowercase(),
            _ => return Ok(Topology::Ring),
        };
        match s.as_str() {
            "ring" => Ok(Topology::Ring),
            "tree" => Ok(Topology::Tree),
            other => {
                bail!("invalid ADAMA_FABRIC '{other}': expected ring|tree (unset = ring)")
            }
        }
    }

    /// Topology from the `ADAMA_FABRIC` environment variable.
    pub fn from_env() -> Result<Topology> {
        Self::parse(std::env::var("ADAMA_FABRIC").ok().as_deref())
    }
}

/// Strictly resolve an `ADAMA_ASYNC` value: unset/empty/`0` = synchronous
/// issue (the default); `1` = issue collectives on the rank's comm thread
/// and overlap them with compute. Anything else is an error naming the
/// accepted values (no silent fallback). Pure scheduling knob: sync and
/// async runs are bit-identical, ledgers included.
pub fn parse_async(spec: Option<&str>) -> Result<bool> {
    match spec.map(str::trim) {
        None | Some("") | Some("0") => Ok(false),
        Some("1") => Ok(true),
        Some(other) => bail!("invalid ADAMA_ASYNC '{other}': expected 0|1 (unset = 0)"),
    }
}

/// Async-issue mode from the `ADAMA_ASYNC` environment variable.
pub fn async_from_env() -> Result<bool> {
    parse_async(std::env::var("ADAMA_ASYNC").ok().as_deref())
}

/// Strictly resolve an `ADAMA_BUCKET_BYTES` value: unset/empty/`0` = no
/// bucketing (every gradient issues its own collective); a byte count
/// (`<n>`, optionally suffixed `k`/`m`/`g`, ×1024 each) closes a bucket
/// once the coalesced tensors reach it, so small tensors share one gate
/// crossing. Anything else is an error naming the accepted values.
/// Bucket boundaries depend only on tensor sizes — identical on every
/// rank — and the ledger still records one op per logical tensor, so the
/// threshold is a pure performance knob.
pub fn parse_bucket_bytes(spec: Option<&str>) -> Result<usize> {
    let s = match spec.map(str::trim) {
        Some(s) if !s.is_empty() => s.to_ascii_lowercase(),
        _ => return Ok(0),
    };
    let (digits, mult): (&str, usize) = match s.chars().last() {
        Some('k') => (&s[..s.len() - 1], 1 << 10),
        Some('m') => (&s[..s.len() - 1], 1 << 20),
        Some('g') => (&s[..s.len() - 1], 1 << 30),
        _ => (s.as_str(), 1),
    };
    match digits.parse::<usize>() {
        Ok(n) => Ok(n.saturating_mul(mult)),
        Err(_) => bail!(
            "invalid ADAMA_BUCKET_BYTES '{s}': expected 0/unset (no bucketing) or <n>[k|m|g]"
        ),
    }
}

/// Bucket threshold from the `ADAMA_BUCKET_BYTES` environment variable.
pub fn bucket_bytes_from_env() -> Result<usize> {
    parse_bucket_bytes(std::env::var("ADAMA_BUCKET_BYTES").ok().as_deref())
}

/// A scheduled rank death for crash-recovery testing (`ADAMA_FAULT`).
///
/// The armed rank kills itself at 1-based step `step`, immediately before
/// its `(op+1)`-th collective call of that step (`op = 0` → before the
/// step's first collective). The kill abandons the gate exactly as a
/// crashed process would; see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub rank: usize,
    pub step: u64,
    pub op: u64,
}

impl FaultPlan {
    /// Strictly resolve an `ADAMA_FAULT` value: unset/empty = no fault;
    /// otherwise `<rank>:<step>[:<op>]` with a 1-based step. Anything
    /// else is an error naming the accepted form (no silent fallback).
    pub fn parse(spec: Option<&str>) -> Result<Option<FaultPlan>> {
        let s = match spec.map(str::trim) {
            Some(s) if !s.is_empty() => s,
            _ => return Ok(None),
        };
        let bad = || {
            anyhow::anyhow!(
                "invalid ADAMA_FAULT '{s}': expected <rank>:<step>[:<op>] — kill rank <rank> \
                 at 1-based step <step> before its (<op>+1)-th collective call of that step \
                 (unset = no fault)"
            )
        };
        let parts: Vec<&str> = s.split(':').map(str::trim).collect();
        if parts.len() < 2 || parts.len() > 3 {
            return Err(bad());
        }
        let rank = parts[0].parse::<usize>().map_err(|_| bad())?;
        let step = match parts[1].parse::<u64>() {
            Ok(t) if t >= 1 => t,
            _ => return Err(bad()),
        };
        let op = match parts.get(2) {
            Some(p) => p.parse::<u64>().map_err(|_| bad())?,
            None => 0,
        };
        Ok(Some(FaultPlan { rank, step, op }))
    }

    /// Fault plan from the `ADAMA_FAULT` environment variable.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        Self::parse(std::env::var("ADAMA_FAULT").ok().as_deref())
    }
}

/// The error every party to a rank death observes: the dying rank itself
/// (`injected = true`) and every survivor that was blocked in — or later
/// enters — a collective on the same board (`injected = false`). Carried
/// as the `anyhow` source so supervisors can
/// `err.downcast_ref::<PeerDeath>()` to decide whether checkpoint
/// recovery applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeerDeath {
    /// The rank that died.
    pub rank: usize,
    /// The 1-based step the rank died in (0 if it never entered a step).
    pub step: u64,
    /// True on the dying rank's own error; false on survivors.
    pub injected: bool,
}

impl std::fmt::Display for PeerDeath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fabric: rank {} died at step {}{}",
            self.rank,
            self.step,
            if self.injected { " (injected fault)" } else { "" }
        )
    }
}

impl std::error::Error for PeerDeath {}

/// Element-wise `dst[i] = dst[i] + src[i]` — the single f32 operation all
/// reduction chains are built from. The per-element addition order *is*
/// the determinism contract; nothing here may reassociate it.
fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// Reduce `contribs` (one slice per rank, equal lengths) in the fixed
/// order the topology prescribes. `start` seeds the ring chain (the
/// shard index); the tree bracketing ignores it.
fn reduce_contribs(topo: Topology, start: usize, contribs: &[&[f32]]) -> Vec<f32> {
    let m = contribs.len();
    debug_assert!(m >= 1);
    match topo {
        Topology::Ring => {
            let mut acc = contribs[start % m].to_vec();
            for k in 1..m {
                add_assign(&mut acc, contribs[(start + k) % m]);
            }
            acc
        }
        Topology::Tree => {
            let mut level: Vec<Vec<f32>> = contribs.iter().map(|c| c.to_vec()).collect();
            while level.len() > 1 {
                let mut next = Vec::with_capacity((level.len() + 1) / 2);
                let mut it = level.into_iter();
                while let Some(mut a) = it.next() {
                    if let Some(b) = it.next() {
                        add_assign(&mut a, &b);
                    }
                    next.push(a);
                }
                level = next;
            }
            level.pop().unwrap()
        }
    }
}

/// Payload bytes rank `rank` would send over a real ring during one
/// reduce-scatter phase of `len` f32s: every shard except the one it ends
/// up owning — exactly the channel ring's per-rank ledger.
fn reduce_scatter_wire_bytes(rank: usize, len: usize, world: usize) -> u64 {
    if world <= 1 {
        return 0;
    }
    let shards = CommHandle::shard_ranges(len, world);
    ((len - shards[(rank + 1) % world].len()) * 4) as u64
}

/// Per-rank ring wire bytes for one all-gather phase: every shard except
/// `(rank + 2) mod M` (the last one it receives).
fn all_gather_wire_bytes(rank: usize, len: usize, world: usize) -> u64 {
    if world <= 1 {
        return 0;
    }
    let shards = CommHandle::shard_ranges(len, world);
    ((len - shards[(rank + 2) % world].len()) * 4) as u64
}

/// Reusable world-wide rendezvous. Unlike `std::sync::Barrier`, a rank
/// handle dropped mid-collective (error/panic on a peer, or mismatched
/// collective entry counts) wakes every waiter with an error instead of
/// deadlocking.
struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    arrived: usize,
    generation: u64,
    /// Handles dropped so far — nonzero while anyone still waits means a
    /// peer can never arrive.
    gone: usize,
    /// Set when a rank died via an injected fault — (rank, step), so
    /// survivors can name the dead rank instead of a generic drop.
    dead: Option<(usize, u64)>,
}

/// The error a waiter surfaces when the gate can never complete: a
/// [`PeerDeath`] naming the dead rank after an injected fault, the legacy
/// messages for a plain handle drop.
fn gone_error(s: &GateState, at_entry: bool) -> anyhow::Error {
    if let Some((rank, step)) = s.dead {
        return anyhow::Error::new(PeerDeath { rank, step, injected: false });
    }
    if at_entry {
        anyhow::anyhow!(
            "fabric: {} rank handle(s) dropped mid-run — every rank must enter every \
             collective, in the same order",
            s.gone
        )
    } else {
        anyhow::anyhow!("fabric: a peer rank exited while this rank was blocked in a collective")
    }
}

impl Gate {
    fn new() -> Self {
        Self {
            state: Mutex::new(GateState { arrived: 0, generation: 0, gone: 0, dead: None }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, GateState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn wait(&self, world: usize) -> Result<()> {
        let mut s = self.lock();
        if s.gone != 0 {
            return Err(gone_error(&s, true));
        }
        s.arrived += 1;
        if s.arrived == world {
            s.arrived = 0;
            s.generation = s.generation.wrapping_add(1);
            drop(s);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = s.generation;
        while s.generation == gen {
            if s.gone != 0 {
                // Roll back this rank's arrival before surfacing the
                // error: the count was consumed by nobody (the generation
                // never advanced), and leaving it behind would miscount
                // the rendezvous for whatever enters the gate next — a
                // later entrant must see the dropped-peer error, not a
                // short-counted (garbage-folding) barrier.
                s.arrived -= 1;
                return Err(gone_error(&s, false));
            }
            s = self.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
        Ok(())
    }

    fn abandon(&self) {
        let mut s = self.lock();
        s.gone += 1;
        drop(s);
        self.cv.notify_all();
    }

    /// Abandon on behalf of an injected rank death: like [`Gate::abandon`]
    /// but records who died so waiters surface a [`PeerDeath`].
    fn abandon_as(&self, rank: usize, step: u64) {
        let mut s = self.lock();
        s.gone += 1;
        if s.dead.is_none() {
            s.dead = Some((rank, step));
        }
        drop(s);
        self.cv.notify_all();
    }
}

/// Shared state of one fabric group.
struct Board {
    world: usize,
    topo: Topology,
    /// Per-rank posted contribution (written only by the owning rank,
    /// read by everyone after the gate).
    input: Vec<RwLock<Vec<f32>>>,
    /// Per-rank reduced shard (reduce-scatter layout: rank `r` publishes
    /// shard `(r+1) mod M` here).
    reduced: Vec<RwLock<Vec<f32>>>,
    gate: Gate,
    stats: Arc<CommStats>,
}

fn read_slot(slot: &RwLock<Vec<f32>>) -> std::sync::RwLockReadGuard<'_, Vec<f32>> {
    slot.read().unwrap_or_else(PoisonError::into_inner)
}

fn write_slot(slot: &RwLock<Vec<f32>>) -> std::sync::RwLockWriteGuard<'_, Vec<f32>> {
    slot.write().unwrap_or_else(PoisonError::into_inner)
}

/// One fully-reduced buffer handed back by an async collective: the data
/// plus the sub-range this rank owns afterwards (`0..len` for all-reduce
/// and all-gather; the reduce-scatter shard `(rank+1) mod M` otherwise —
/// regions outside `owned` are unspecified, matching the sync contract).
#[derive(Debug)]
pub struct ReducedBuf {
    pub data: Vec<f32>,
    pub owned: Range<usize>,
}

/// Completion cell shared between an issued job and its [`Ticket`].
struct TicketCell {
    state: Mutex<Option<Result<Vec<ReducedBuf>>>>,
    cv: Condvar,
}

impl TicketCell {
    fn new() -> Arc<Self> {
        Arc::new(Self { state: Mutex::new(None), cv: Condvar::new() })
    }

    fn fill(&self, res: Result<Vec<ReducedBuf>>) {
        let mut s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        *s = Some(res);
        drop(s);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<Vec<ReducedBuf>> {
        let mut s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(res) = s.take() {
                return res;
            }
            s = self.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// Handle to an in-flight (or already-completed) collective. `wait()`
/// blocks until the fabric has folded the buffers and returns them;
/// [`CommStats`] for the op are recorded strictly *before* `wait`
/// returns (completion attribution), so a ledger snapshot taken after
/// every issued ticket has been waited can never race an in-flight op.
///
/// A `Ticket` stays valid after its issuing [`FabricHandle`] is dropped:
/// the drop drains the comm thread, so queued work completes (or errors)
/// and the cell is always filled.
pub struct Ticket {
    inner: TicketInner,
}

enum TicketInner {
    Ready(Result<Vec<ReducedBuf>>),
    Pending(Arc<TicketCell>),
}

impl Ticket {
    /// An already-completed ticket — what the blocking shims on engines
    /// without a native async path (channel ring, serial) return.
    pub fn ready(res: Result<Vec<ReducedBuf>>) -> Self {
        Self { inner: TicketInner::Ready(res) }
    }

    fn pending(cell: Arc<TicketCell>) -> Self {
        Self { inner: TicketInner::Pending(cell) }
    }

    /// Block until the collective completes; returns one [`ReducedBuf`]
    /// per issued buffer, in issue order.
    pub fn wait(self) -> Result<Vec<ReducedBuf>> {
        match self.inner {
            TicketInner::Ready(res) => res,
            TicketInner::Pending(cell) => cell.wait(),
        }
    }
}

/// Queued unit of work for a rank's comm thread.
type Job = Box<dyn FnOnce(usize, &Board) + Send>;

struct CommThread {
    tx: Sender<Job>,
    join: JoinHandle<()>,
}

/// Factory for fabric-connected rank handles.
pub struct Fabric;

impl Fabric {
    /// Create `world` handles on the default [`Topology::Ring`].
    pub fn new(world: usize) -> Vec<FabricHandle> {
        Self::with_topology(world, Topology::Ring)
    }

    /// Create `world` handles with an explicit reduction topology.
    pub fn with_topology(world: usize, topo: Topology) -> Vec<FabricHandle> {
        assert!(world >= 1, "fabric needs at least one rank");
        let board = Arc::new(Board {
            world,
            topo,
            input: (0..world).map(|_| RwLock::new(Vec::new())).collect(),
            reduced: (0..world).map(|_| RwLock::new(Vec::new())).collect(),
            gate: Gate::new(),
            stats: Arc::new(CommStats::default()),
        });
        (0..world)
            .map(|rank| FabricHandle {
                rank,
                board: board.clone(),
                comm: Mutex::new(None),
                fault: Mutex::new(None),
            })
            .collect()
    }
}

/// Progress of an armed [`FaultPlan`] on one handle.
struct FaultState {
    plan: FaultPlan,
    /// Current 1-based step ([`FabricHandle::begin_step`]); 0 before the
    /// first step, so a fault can never fire outside the step loop.
    step: u64,
    /// Collective calls already made this step.
    ops: u64,
    fired: bool,
}

/// One rank's endpoint in the fabric. Moves into the rank's worker
/// thread; every rank must enter every collective in the same order.
/// Synchronous collectives block inline; the `_async` variants hand the
/// buffer to a lazily-spawned per-rank comm thread and return a
/// [`Ticket`].
pub struct FabricHandle {
    rank: usize,
    board: Arc<Board>,
    /// Lazily-spawned comm thread (first async issue). Once it exists,
    /// *every* collective on this handle — sync calls included — funnels
    /// through its FIFO queue, so the rank crosses the board's gates in
    /// exactly one total order and compute-thread/comm-thread entries can
    /// never interleave mid-collective.
    comm: Mutex<Option<CommThread>>,
    /// Armed fault plan and its progress ([`FabricHandle::arm_fault`]);
    /// `None` on unfaulted handles (the overwhelmingly common case).
    fault: Mutex<Option<FaultState>>,
}

impl Drop for FabricHandle {
    fn drop(&mut self) {
        // Drain before abandon: a handle dropped with async work still
        // queued lets its comm thread finish (or error out of) every
        // outstanding collective first — peers are legitimately blocked
        // inside those same collectives, and abandoning the gate early
        // would poison them mid-fold. Closing the queue ends the thread's
        // recv loop once it empties; the join guarantees none of this
        // rank's jobs can touch the gate after the abandon below. A
        // peer-side failure cannot deadlock the drain: the peer's own
        // abandon (which its drop performs after a drain that never
        // depends on us) errors our blocked jobs out of the gate.
        if let Some(ct) = self.comm.lock().unwrap_or_else(PoisonError::into_inner).take() {
            drop(ct.tx);
            let _ = ct.join.join();
        }
        // After a normal run every rank has left its last collective, so
        // nobody is waiting and this is a no-op; after an error it wakes
        // blocked peers with a clear failure instead of a deadlock.
        self.board.gate.abandon();
    }
}

/// Publish `rank`'s contribution to the board.
fn post(rank: usize, board: &Board, data: &[f32]) {
    let mut slot = write_slot(&board.input[rank]);
    slot.clear();
    slot.extend_from_slice(data);
}

/// Snapshot every rank's posted contribution for shard `j` and fold it in
/// the topology's fixed order. Caller must hold the post gate.
fn reduce_shard(board: &Board, shards: &[Range<usize>], j: usize, len: usize) -> Result<Vec<f32>> {
    let m = board.world;
    let guards: Vec<_> = (0..m).map(|r| read_slot(&board.input[r])).collect();
    for g in &guards {
        ensure!(
            g.len() == len,
            "fabric: ranks posted different buffer lengths ({} vs {len})",
            g.len()
        );
    }
    let contribs: Vec<&[f32]> = guards.iter().map(|g| &g[shards[j].clone()]).collect();
    Ok(reduce_contribs(board.topo, j, &contribs))
}

// The ep_* endpoint functions below are the collectives themselves,
// callable from either the rank's compute thread (sync path) or its comm
// thread (async path). All of them attribute their [`CommStats`] at
// **completion** — after the final gate, just before returning — never at
// issue: under async issue a step-end ledger snapshot must not observe an
// op whose result is still in flight (`fabric_parity` asserts exact
// serial==channel==fabric ledger equality with overlap enabled).

/// All-reduce (sum) in place: every rank ends with the element-wise sum,
/// reduced in the fixed per-shard order (see module docs).
fn ep_all_reduce_sum(rank: usize, board: &Board, data: &mut [f32]) -> Result<()> {
    let m = board.world;
    let wire = reduce_scatter_wire_bytes(rank, data.len(), m)
        + all_gather_wire_bytes(rank, data.len(), m);
    if m > 1 {
        let shards = CommHandle::shard_ranges(data.len(), m);
        post(rank, board, data);
        board.gate.wait(m)?;
        // Each rank folds the shard it owns — shard (rank+1) mod M, the
        // reduce-scatter layout — and publishes it; the fold order is a
        // pure function of (shard index, world), never arrival time.
        let own = (rank + 1) % m;
        let red = reduce_shard(board, &shards, own, data.len())?;
        *write_slot(&board.reduced[rank]) = red;
        board.gate.wait(m)?;
        for (j, shard) in shards.iter().enumerate() {
            let owner = (j + m - 1) % m;
            let g = read_slot(&board.reduced[owner]);
            data[shard.clone()].copy_from_slice(&g);
        }
    }
    board.stats.ops.fetch_add(1, Ordering::Relaxed);
    board.stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
    Ok(())
}

/// Reduce-scatter (sum): on return `data`'s own shard (the returned
/// range, `(rank+1) mod M` of [`CommHandle::shard_ranges`]) holds the
/// cross-rank sum; other regions are left untouched (callers must not
/// read them, matching the channel ring's contract).
fn ep_reduce_scatter_sum(rank: usize, board: &Board, data: &mut [f32]) -> Result<Range<usize>> {
    let m = board.world;
    let shards = CommHandle::shard_ranges(data.len(), m);
    let own = (rank + 1) % m;
    if m > 1 {
        post(rank, board, data);
        board.gate.wait(m)?;
        let red = reduce_shard(board, &shards, own, data.len())?;
        data[shards[own].clone()].copy_from_slice(&red);
        // Trailing gate: nobody may repost for the next collective while
        // a peer still reads this one's board.
        board.gate.wait(m)?;
    }
    board.stats.ops.fetch_add(1, Ordering::Relaxed);
    board
        .stats
        .bytes_sent
        .fetch_add(reduce_scatter_wire_bytes(rank, data.len(), m), Ordering::Relaxed);
    Ok(shards[own].clone())
}

/// Batched reduce-scatter (sum) — the bucketing primitive: every buffer
/// in `bufs` is reduce-scattered exactly as [`ep_reduce_scatter_sum`]
/// would (same per-shard fold order, same owned range, same per-buffer
/// ledger entry), but the whole batch crosses the gate **once** as a
/// concatenated post. Returns the owned range of each buffer.
fn ep_reduce_scatter_many(
    rank: usize,
    board: &Board,
    bufs: &mut [Vec<f32>],
) -> Result<Vec<Range<usize>>> {
    let m = board.world;
    let own = (rank + 1) % m;
    let owned: Vec<Range<usize>> =
        bufs.iter().map(|b| CommHandle::shard_ranges(b.len(), m)[own].clone()).collect();
    let wire: u64 = bufs.iter().map(|b| reduce_scatter_wire_bytes(rank, b.len(), m)).sum();
    if m > 1 {
        let total: usize = bufs.iter().map(|b| b.len()).sum();
        {
            let mut slot = write_slot(&board.input[rank]);
            slot.clear();
            slot.reserve(total);
            for b in bufs.iter() {
                slot.extend_from_slice(b);
            }
        }
        board.gate.wait(m)?;
        {
            let guards: Vec<_> = (0..m).map(|r| read_slot(&board.input[r])).collect();
            for g in &guards {
                ensure!(
                    g.len() == total,
                    "fabric: ranks posted different batched buffer lengths ({} vs {total}) — \
                     bucket boundaries must be identical on every rank",
                    g.len()
                );
            }
            let mut off = 0usize;
            for (b, ownr) in bufs.iter_mut().zip(&owned) {
                let contribs: Vec<&[f32]> =
                    guards.iter().map(|g| &g[off + ownr.start..off + ownr.end]).collect();
                let red = reduce_contribs(board.topo, own, &contribs);
                b[ownr.clone()].copy_from_slice(&red);
                off += b.len();
            }
        }
        board.gate.wait(m)?;
    }
    // one logical op per buffer: transport batching must not change the
    // ledger (serial==channel==fabric, bucketed==unbucketed)
    board.stats.ops.fetch_add(bufs.len() as u64, Ordering::Relaxed);
    board.stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
    Ok(owned)
}

/// All-gather: each rank contributes the shard it owns (reduce-scatter
/// layout); on return the whole buffer is consistent on every rank.
fn ep_all_gather_owned(rank: usize, board: &Board, data: &mut [f32]) -> Result<()> {
    let m = board.world;
    let wire = all_gather_wire_bytes(rank, data.len(), m);
    if m > 1 {
        let shards = CommHandle::shard_ranges(data.len(), m);
        post(rank, board, data);
        board.gate.wait(m)?;
        for (j, shard) in shards.iter().enumerate() {
            let owner = (j + m - 1) % m;
            if owner == rank {
                continue;
            }
            let g = read_slot(&board.input[owner]);
            ensure!(
                g.len() == data.len(),
                "fabric: ranks posted different buffer lengths ({} vs {})",
                g.len(),
                data.len()
            );
            data[shard.clone()].copy_from_slice(&g[shard.clone()]);
        }
        board.gate.wait(m)?;
    }
    board.stats.ops.fetch_add(1, Ordering::Relaxed);
    board.stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
    Ok(())
}

/// Barrier: returns once every rank has entered.
fn ep_barrier(board: &Board) -> Result<()> {
    if board.world == 1 {
        return Ok(());
    }
    board.gate.wait(board.world)
}

impl FabricHandle {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.board.world
    }

    pub fn topology(&self) -> Topology {
        self.board.topo
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.board.stats
    }

    /// Arm a deterministic fault on this handle: it will kill itself at
    /// `plan.step`, before its `(plan.op+1)`-th collective call of that
    /// step (see the module docs). The runner arms only the handle whose
    /// rank the plan names.
    pub fn arm_fault(&self, plan: FaultPlan) {
        *self.fault.lock().unwrap_or_else(PoisonError::into_inner) =
            Some(FaultState { plan, step: 0, ops: 0, fired: false });
    }

    /// Mark the start of 1-based step `step` for fault accounting (resets
    /// the per-step op counter). No-op unless a fault is armed.
    pub fn begin_step(&self, step: u64) {
        if let Some(fs) = self.fault.lock().unwrap_or_else(PoisonError::into_inner).as_mut() {
            fs.step = step;
            fs.ops = 0;
        }
    }

    /// Fires the armed fault when its (step, op) point is reached: the
    /// handle abandons the gate as a crashed process would and this (and
    /// every later) collective call errors with [`PeerDeath`]. Called once
    /// per *logical* collective entry — the `_unchecked` internals let the
    /// sync wrappers delegate to the async path without double-counting.
    fn fault_check(&self) -> Result<()> {
        let mut guard = self.fault.lock().unwrap_or_else(PoisonError::into_inner);
        let Some(fs) = guard.as_mut() else { return Ok(()) };
        if fs.fired {
            return Err(anyhow::Error::new(PeerDeath {
                rank: self.rank,
                step: fs.step,
                injected: true,
            }));
        }
        // `>` catches a plan op past the step's last collective: the rank
        // then dies on the next step's first call instead of surviving.
        let due = fs.step > fs.plan.step || (fs.step == fs.plan.step && fs.ops >= fs.plan.op);
        fs.ops += 1;
        if due {
            fs.fired = true;
            let step = fs.step;
            drop(guard);
            self.board.gate.abandon_as(self.rank, step);
            return Err(anyhow::Error::new(PeerDeath { rank: self.rank, step, injected: true }));
        }
        Ok(())
    }

    fn comm_active(&self) -> bool {
        self.comm.lock().unwrap_or_else(PoisonError::into_inner).is_some()
    }

    /// Enqueue a job on the comm thread, spawning it on first use.
    fn enqueue(&self, job: Job) {
        let mut guard = self.comm.lock().unwrap_or_else(PoisonError::into_inner);
        let ct = guard.get_or_insert_with(|| {
            let (tx, rx) = std::sync::mpsc::channel::<Job>();
            let rank = self.rank;
            let board = self.board.clone();
            let join = std::thread::spawn(move || {
                // FIFO: jobs run in issue order — the order this rank's
                // program entered the collectives — so the gate
                // rendezvous stays in lock-step with every peer.
                while let Ok(job) = rx.recv() {
                    job(rank, &board);
                }
            });
            CommThread { tx, join }
        });
        // the channel only disconnects when the comm thread is gone, and
        // the thread never exits while `tx` is alive
        ct.tx.send(job).expect("fabric comm thread exited prematurely");
    }

    /// Issue `run` on the comm thread and hand back its ticket.
    fn issue<F>(&self, run: F) -> Ticket
    where
        F: FnOnce(usize, &Board) -> Result<Vec<ReducedBuf>> + Send + 'static,
    {
        let cell = TicketCell::new();
        let out = cell.clone();
        self.enqueue(Box::new(move |rank, board| out.fill(run(rank, board))));
        Ticket::pending(cell)
    }

    /// Async all-reduce (sum): the buffer moves to the comm thread; the
    /// ticket's single [`ReducedBuf`] owns the whole range.
    pub fn all_reduce_sum_async(&self, data: Vec<f32>) -> Ticket {
        if let Err(e) = self.fault_check() {
            return Ticket::ready(Err(e));
        }
        self.all_reduce_sum_async_unchecked(data)
    }

    fn all_reduce_sum_async_unchecked(&self, mut data: Vec<f32>) -> Ticket {
        self.issue(move |rank, board| {
            ep_all_reduce_sum(rank, board, &mut data)?;
            let n = data.len();
            Ok(vec![ReducedBuf { data, owned: 0..n }])
        })
    }

    /// Async reduce-scatter (sum) of one buffer.
    pub fn reduce_scatter_sum_async(&self, data: Vec<f32>) -> Ticket {
        self.reduce_scatter_many_async(vec![data])
    }

    /// Async batched reduce-scatter — the `ADAMA_BUCKET_BYTES` bucketing
    /// primitive: the whole batch crosses the gate once, each buffer is
    /// folded exactly as an individual reduce-scatter would fold it, and
    /// the ledger records one op per buffer. Every rank must pass
    /// identically-sized buffer batches in the same order.
    pub fn reduce_scatter_many_async(&self, bufs: Vec<Vec<f32>>) -> Ticket {
        if let Err(e) = self.fault_check() {
            return Ticket::ready(Err(e));
        }
        self.reduce_scatter_many_async_unchecked(bufs)
    }

    fn reduce_scatter_many_async_unchecked(&self, mut bufs: Vec<Vec<f32>>) -> Ticket {
        self.issue(move |rank, board| {
            let owned = ep_reduce_scatter_many(rank, board, &mut bufs)?;
            Ok(bufs
                .into_iter()
                .zip(owned)
                .map(|(data, owned)| ReducedBuf { data, owned })
                .collect())
        })
    }

    /// All-reduce (sum) in place: every rank ends with the element-wise
    /// sum, reduced in the fixed per-shard order (see module docs).
    pub fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        self.fault_check()?;
        if self.comm_active() {
            let out = self.all_reduce_sum_async_unchecked(data.to_vec()).wait()?;
            data.copy_from_slice(&out[0].data);
            return Ok(());
        }
        ep_all_reduce_sum(self.rank, &self.board, data)
    }

    /// All-reduce then scale by `1/world` (mean) — Eq. 7's m-averaging.
    pub fn all_reduce_mean(&self, data: &mut [f32]) -> Result<()> {
        self.all_reduce_sum(data)?;
        let inv = 1.0 / self.board.world as f32;
        for x in data.iter_mut() {
            *x *= inv;
        }
        Ok(())
    }

    /// Reduce-scatter (sum): on return `data`'s own shard (the returned
    /// range, `(rank+1) mod M` of [`CommHandle::shard_ranges`]) holds the
    /// cross-rank sum; other regions are left untouched (callers must not
    /// read them, matching the channel ring's contract).
    pub fn reduce_scatter_sum(&self, data: &mut [f32]) -> Result<Range<usize>> {
        self.fault_check()?;
        if self.comm_active() {
            let mut out = self.reduce_scatter_many_async_unchecked(vec![data.to_vec()]).wait()?;
            let rb = out.pop().expect("one buffer in, one buffer out");
            data[rb.owned.clone()].copy_from_slice(&rb.data[rb.owned.clone()]);
            return Ok(rb.owned);
        }
        ep_reduce_scatter_sum(self.rank, &self.board, data)
    }

    /// All-gather: each rank contributes the shard it owns (reduce-scatter
    /// layout); on return the whole buffer is consistent on every rank.
    pub fn all_gather_owned(&self, data: &mut [f32]) -> Result<()> {
        self.fault_check()?;
        if self.comm_active() {
            let mut buf = data.to_vec();
            let out = self
                .issue(move |rank, board| {
                    ep_all_gather_owned(rank, board, &mut buf)?;
                    let n = buf.len();
                    Ok(vec![ReducedBuf { data: buf, owned: 0..n }])
                })
                .wait()?;
            data.copy_from_slice(&out[0].data);
            return Ok(());
        }
        ep_all_gather_owned(self.rank, &self.board, data)
    }

    /// Barrier: returns once every rank has entered.
    pub fn barrier(&self) -> Result<()> {
        self.fault_check()?;
        if self.comm_active() {
            return self
                .issue(|_rank, board| {
                    ep_barrier(board)?;
                    Ok(Vec::new())
                })
                .wait()
                .map(|_| ());
        }
        ep_barrier(&self.board)
    }
}

/// Single-threaded reference twins of the fabric collectives — the
/// **serial simulator**. Each helper takes one buffer per rank and applies
/// the exact reduction order the concurrent fabric applies, so a serial
/// run is the bit-for-bit oracle for any concurrent run (and, on
/// [`Topology::Ring`], for the legacy channel ring). The [`CommStats`]
/// ledger records the same wire volume the concurrent engines record.
pub mod serial {
    use super::*;

    fn check_world(bufs: &[Vec<f32>]) -> Result<usize> {
        ensure!(!bufs.is_empty(), "serial collective needs at least one rank buffer");
        let len = bufs[0].len();
        for b in bufs {
            ensure!(b.len() == len, "serial collective: rank buffer lengths differ");
        }
        Ok(len)
    }

    /// All-reduce (sum) across `bufs[rank]`, in place on every rank.
    pub fn all_reduce_sum(topo: Topology, bufs: &mut [Vec<f32>], stats: &CommStats) -> Result<()> {
        let len = check_world(bufs)?;
        let m = bufs.len();
        stats.ops.fetch_add(m as u64, Ordering::Relaxed);
        let wire: u64 = (0..m)
            .map(|r| {
                reduce_scatter_wire_bytes(r, len, m) + all_gather_wire_bytes(r, len, m)
            })
            .sum();
        stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
        if m == 1 {
            return Ok(());
        }
        let shards = CommHandle::shard_ranges(len, m);
        let mut reduced: Vec<Vec<f32>> = Vec::with_capacity(m);
        for (j, shard) in shards.iter().enumerate() {
            let contribs: Vec<&[f32]> = bufs.iter().map(|b| &b[shard.clone()]).collect();
            reduced.push(reduce_contribs(topo, j, &contribs));
        }
        for b in bufs.iter_mut() {
            for (j, shard) in shards.iter().enumerate() {
                b[shard.clone()].copy_from_slice(&reduced[j]);
            }
        }
        Ok(())
    }

    /// All-reduce then scale by `1/world` on every rank.
    pub fn all_reduce_mean(topo: Topology, bufs: &mut [Vec<f32>], stats: &CommStats) -> Result<()> {
        all_reduce_sum(topo, bufs, stats)?;
        let inv = 1.0 / bufs.len() as f32;
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x *= inv;
            }
        }
        Ok(())
    }

    /// Reduce-scatter (sum): rank `r`'s owned range (returned, index `r`)
    /// holds the cross-rank sum afterwards; other regions are untouched.
    pub fn reduce_scatter_sum(
        topo: Topology,
        bufs: &mut [Vec<f32>],
        stats: &CommStats,
    ) -> Result<Vec<Range<usize>>> {
        let len = check_world(bufs)?;
        let m = bufs.len();
        stats.ops.fetch_add(m as u64, Ordering::Relaxed);
        let wire: u64 = (0..m).map(|r| reduce_scatter_wire_bytes(r, len, m)).sum();
        stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
        let shards = CommHandle::shard_ranges(len, m);
        let owned: Vec<Range<usize>> = (0..m).map(|r| shards[(r + 1) % m].clone()).collect();
        if m == 1 {
            return Ok(owned);
        }
        for r in 0..m {
            let j = (r + 1) % m;
            let red = {
                let contribs: Vec<&[f32]> = bufs.iter().map(|b| &b[shards[j].clone()]).collect();
                reduce_contribs(topo, j, &contribs)
            };
            // Writing rank r's owned shard never feeds a later chain: each
            // rank owns a distinct shard index, and shard j's chain reads
            // only region j of every buffer.
            bufs[r][shards[j].clone()].copy_from_slice(&red);
        }
        Ok(owned)
    }

    /// All-gather: copy each owned shard (reduce-scatter layout) from its
    /// owner into every rank's buffer.
    pub fn all_gather_owned(bufs: &mut [Vec<f32>], stats: &CommStats) -> Result<()> {
        let len = check_world(bufs)?;
        let m = bufs.len();
        stats.ops.fetch_add(m as u64, Ordering::Relaxed);
        let wire: u64 = (0..m).map(|r| all_gather_wire_bytes(r, len, m)).sum();
        stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
        if m == 1 {
            return Ok(());
        }
        let shards = CommHandle::shard_ranges(len, m);
        for (j, shard) in shards.iter().enumerate() {
            let owner = (j + m - 1) % m;
            let src = bufs[owner][shard.clone()].to_vec();
            for (r, b) in bufs.iter_mut().enumerate() {
                if r != owner {
                    b[shard.clone()].copy_from_slice(&src);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::CommGroup;
    use crate::tensor::Rng;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Run one closure per rank on its own OS thread.
    fn run_fabric<F>(world: usize, topo: Topology, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(FabricHandle) -> Vec<f32> + Send + Sync + Clone + 'static,
    {
        let handles = Fabric::with_topology(world, topo);
        let mut joins = Vec::new();
        for h in handles {
            let f = f.clone();
            joins.push(std::thread::spawn(move || f(h)));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_matches_serial_and_channel_for_awkward_worlds() {
        // non-power-of-two worlds, zero-length shards (len < world), the
        // single-rank degenerate ring, and len = 0
        for &m in &[1usize, 2, 3, 4, 5, 7, 8] {
            for &len in &[0usize, 1, 3, m.saturating_sub(1), 64, 130] {
                let mut rng = Rng::new((m * 1000 + len) as u64);
                let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();

                let mut serial_bufs = inputs.clone();
                let stats = CommStats::default();
                serial::all_reduce_sum(Topology::Ring, &mut serial_bufs, &stats).unwrap();

                let fin = Arc::new(inputs.clone());
                let fab = run_fabric(m, Topology::Ring, move |h| {
                    let mut d = fin[h.rank()].clone();
                    h.all_reduce_sum(&mut d).unwrap();
                    d
                });

                let cin = Arc::new(inputs.clone());
                let chan = {
                    let handles = CommGroup::new(m);
                    let mut joins = Vec::new();
                    for h in handles {
                        let cin = cin.clone();
                        joins.push(std::thread::spawn(move || {
                            let mut d = cin[h.rank()].clone();
                            h.all_reduce_sum(&mut d).unwrap();
                            d
                        }));
                    }
                    joins.into_iter().map(|j| j.join().unwrap()).collect::<Vec<_>>()
                };

                for r in 0..m {
                    assert_eq!(
                        bits(&fab[r]),
                        bits(&serial_bufs[r]),
                        "fabric vs serial, world {m} len {len} rank {r}"
                    );
                    assert_eq!(
                        bits(&chan[r]),
                        bits(&serial_bufs[r]),
                        "channel vs serial, world {m} len {len} rank {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn tree_topology_concurrent_matches_serial() {
        for &m in &[2usize, 3, 4, 6] {
            let len = 37;
            let mut rng = Rng::new(m as u64);
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();
            let mut serial_bufs = inputs.clone();
            serial::all_reduce_sum(Topology::Tree, &mut serial_bufs, &CommStats::default())
                .unwrap();
            let fin = Arc::new(inputs);
            let fab = run_fabric(m, Topology::Tree, move |h| {
                let mut d = fin[h.rank()].clone();
                h.all_reduce_sum(&mut d).unwrap();
                d
            });
            for r in 0..m {
                assert_eq!(bits(&fab[r]), bits(&serial_bufs[r]), "world {m} rank {r}");
            }
        }
    }

    #[test]
    fn reduce_scatter_then_gather_equals_all_reduce() {
        for &m in &[2usize, 3, 5] {
            let len = 4 * m + 1;
            let mut rng = Rng::new(7);
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();
            let mut want = inputs.clone();
            serial::all_reduce_sum(Topology::Ring, &mut want, &CommStats::default()).unwrap();
            let fin = Arc::new(inputs);
            let out = run_fabric(m, Topology::Ring, move |h| {
                let mut d = fin[h.rank()].clone();
                let own = h.reduce_scatter_sum(&mut d).unwrap();
                // poison everything outside the owned shard, then gather
                for (i, x) in d.iter_mut().enumerate() {
                    if !own.contains(&i) {
                        *x = f32::NAN;
                    }
                }
                h.all_gather_owned(&mut d).unwrap();
                d
            });
            for r in 0..m {
                assert_eq!(bits(&out[r]), bits(&want[r]), "world {m} rank {r}");
            }
        }
    }

    #[test]
    fn reduction_order_is_invariant_under_injected_delays() {
        // stagger rank arrival with rank- and round-dependent sleeps; the
        // fixed fold order must make every run bit-identical to serial
        let m = 4;
        let len = 50;
        let mut rng = Rng::new(99);
        let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();
        let mut want = inputs.clone();
        serial::all_reduce_sum(Topology::Ring, &mut want, &CommStats::default()).unwrap();
        for round in 0..3u64 {
            let fin = Arc::new(inputs.clone());
            let out = run_fabric(m, Topology::Ring, move |h| {
                let jitter = (h.rank() as u64 * 7 + round * 3) % 11;
                std::thread::sleep(std::time::Duration::from_millis(jitter));
                let mut d = fin[h.rank()].clone();
                h.all_reduce_sum(&mut d).unwrap();
                d
            });
            for r in 0..m {
                assert_eq!(bits(&out[r]), bits(&want[r]), "round {round} rank {r}");
            }
        }
    }

    #[test]
    fn wire_ledger_matches_channel_ring() {
        let m = 4;
        let n = 1024;
        let fab = Fabric::new(m);
        let stats = fab[0].stats().clone();
        let mut joins = Vec::new();
        for h in fab {
            joins.push(std::thread::spawn(move || {
                let mut d = vec![1.0f32; n];
                h.all_reduce_sum(&mut d).unwrap();
                let own = h.reduce_scatter_sum(&mut d).unwrap();
                let _ = own;
                h.all_gather_owned(&mut d).unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // all-reduce 2(M-1)·len·4 + reduce-scatter (M-1)·len·4 + gather same
        let want = (4 * (m - 1) * n * 4) as u64;
        assert_eq!(stats.bytes(), want);
        assert_eq!(stats.op_count(), 3 * m as u64);

        // the serial twin records the identical ledger
        let serial_stats = CommStats::default();
        let mut bufs: Vec<Vec<f32>> = (0..m).map(|_| vec![1.0f32; n]).collect();
        serial::all_reduce_sum(Topology::Ring, &mut bufs, &serial_stats).unwrap();
        serial::reduce_scatter_sum(Topology::Ring, &mut bufs, &serial_stats).unwrap();
        serial::all_gather_owned(&mut bufs, &serial_stats).unwrap();
        assert_eq!(serial_stats.bytes(), want);
        assert_eq!(serial_stats.op_count(), 3 * m as u64);
    }

    #[test]
    fn dropped_peer_surfaces_as_error_not_deadlock() {
        let mut handles = Fabric::new(2);
        let h1 = handles.pop().unwrap();
        let h0 = handles.pop().unwrap();
        let t = std::thread::spawn(move || {
            let mut d = vec![1.0f32; 8];
            h0.all_reduce_sum(&mut d)
        });
        // rank 1 exits without ever entering the collective
        drop(h1);
        let res = t.join().unwrap();
        assert!(res.is_err(), "waiting rank must error out, not hang");
        let msg = format!("{:?}", res.unwrap_err());
        assert!(msg.contains("fabric"), "{msg}");
    }

    #[test]
    fn barrier_does_not_deadlock() {
        run_fabric(3, Topology::Ring, |h| {
            for _ in 0..5 {
                h.barrier().unwrap();
            }
            vec![]
        });
    }

    #[test]
    fn topology_parse_is_strict() {
        assert_eq!(Topology::parse(None).unwrap(), Topology::Ring);
        assert_eq!(Topology::parse(Some("")).unwrap(), Topology::Ring);
        assert_eq!(Topology::parse(Some("ring")).unwrap(), Topology::Ring);
        assert_eq!(Topology::parse(Some(" Tree ")).unwrap(), Topology::Tree);
        let err = Topology::parse(Some("mesh")).unwrap_err();
        assert!(format!("{err}").contains("ring|tree"), "{err}");
    }

    #[test]
    fn async_and_bucket_parse_are_strict() {
        assert!(!parse_async(None).unwrap());
        assert!(!parse_async(Some("")).unwrap());
        assert!(!parse_async(Some("0")).unwrap());
        assert!(parse_async(Some(" 1 ")).unwrap());
        let err = parse_async(Some("yes")).unwrap_err();
        assert!(format!("{err}").contains("0|1"), "{err}");

        assert_eq!(parse_bucket_bytes(None).unwrap(), 0);
        assert_eq!(parse_bucket_bytes(Some("")).unwrap(), 0);
        assert_eq!(parse_bucket_bytes(Some("0")).unwrap(), 0);
        assert_eq!(parse_bucket_bytes(Some("4096")).unwrap(), 4096);
        assert_eq!(parse_bucket_bytes(Some("64k")).unwrap(), 64 << 10);
        assert_eq!(parse_bucket_bytes(Some(" 2M ")).unwrap(), 2 << 20);
        assert_eq!(parse_bucket_bytes(Some("1g")).unwrap(), 1 << 30);
        let err = parse_bucket_bytes(Some("lots")).unwrap_err();
        assert!(format!("{err}").contains("k|m|g"), "{err}");
    }

    #[test]
    fn gate_error_rolls_back_arrival_count() {
        // regression: an errored waiter used to leave its `arrived`
        // increment behind, miscounting the rendezvous for later entrants
        let gate = Arc::new(Gate::new());
        let g2 = gate.clone();
        let t = std::thread::spawn(move || g2.wait(2));
        while gate.lock().arrived != 1 {
            std::thread::yield_now();
        }
        gate.abandon();
        assert!(t.join().unwrap().is_err(), "abandon must error the waiter out");
        let s = gate.lock();
        assert_eq!(s.arrived, 0, "errored waiter must roll back its arrival");
        assert_eq!(s.gone, 1);
        drop(s);
        // a later entrant on the same gate reports the dropped peer
        // promptly instead of deadlocking or short-counting a barrier
        assert!(gate.wait(2).is_err());
    }

    #[test]
    fn post_error_board_reports_dropped_peer_on_reuse() {
        // two survivors keep issuing collectives after a peer dropped:
        // every attempt must surface the dropped-peer error, never
        // deadlock or fold a short world
        let mut handles = Fabric::new(3);
        let h2 = handles.pop().unwrap();
        let h1 = handles.pop().unwrap();
        let h0 = handles.pop().unwrap();
        let spawn = |h: FabricHandle| {
            std::thread::spawn(move || {
                let mut d = vec![1.0f32; 8];
                let first = h.all_reduce_sum(&mut d);
                let second = h.all_reduce_sum(&mut d);
                (first.is_err(), second.is_err())
            })
        };
        let t0 = spawn(h0);
        let t1 = spawn(h1);
        drop(h2);
        let (a0, b0) = t0.join().unwrap();
        let (a1, b1) = t1.join().unwrap();
        assert!(a0 && a1, "first collective after the drop must error");
        assert!(b0 && b1, "reusing the board must keep reporting the error");
    }

    #[test]
    fn drop_with_outstanding_ticket_drains_instead_of_poisoning() {
        // regression: dropping a handle with async work still queued used
        // to abandon the gate immediately, poisoning a peer blocked in
        // that same (legitimate) collective
        let mut handles = Fabric::new(2);
        let h1 = handles.pop().unwrap();
        let h0 = handles.pop().unwrap();
        let t0 = std::thread::spawn(move || {
            let ticket = h0.reduce_scatter_sum_async(vec![1.0f32; 8]);
            drop(h0); // must drain the comm thread before abandoning
            ticket.wait()
        });
        let t1 = std::thread::spawn(move || {
            // arrive well after rank 0's handle is gone
            std::thread::sleep(std::time::Duration::from_millis(30));
            let mut d = vec![2.0f32; 8];
            h1.reduce_scatter_sum(&mut d).map(|own| d[own].to_vec())
        });
        let r0 = t0.join().unwrap().expect("ticket outlives its handle");
        let r1 = t1.join().unwrap().expect("late peer completes normally");
        // rank 0 owns shard 1 (4..8), rank 1 owns shard 0 (0..4)
        assert_eq!(r0[0].owned, 4..8);
        assert_eq!(bits(&r0[0].data[r0[0].owned.clone()]), bits(&[3.0f32; 4]));
        assert_eq!(bits(&r1), bits(&[3.0f32; 4]));
    }

    #[test]
    fn ledger_attributed_at_completion_not_issue() {
        // regression: stats used to be bumped at issue time, so a ledger
        // snapshot could observe an op whose peers had not even arrived
        let mut handles = Fabric::new(2);
        let h1 = handles.pop().unwrap();
        let h0 = handles.pop().unwrap();
        let stats = h0.stats().clone();
        let ticket = h0.all_reduce_sum_async(vec![1.0f32; 64]);
        std::thread::sleep(std::time::Duration::from_millis(50));
        // rank 1 never arrived: the op is in flight and must be invisible
        assert_eq!(stats.op_count(), 0, "in-flight op leaked into the ledger");
        assert_eq!(stats.bytes(), 0);
        let t1 = std::thread::spawn(move || {
            let mut d = vec![1.0f32; 64];
            h1.all_reduce_sum(&mut d).unwrap();
        });
        let out = ticket.wait().unwrap();
        t1.join().unwrap();
        assert_eq!(bits(&out[0].data), bits(&[2.0f32; 64]));
        // all-reduce, m=2, len 64: per rank (64-32)·4 wire each phase
        assert_eq!(stats.op_count(), 2);
        assert_eq!(stats.bytes(), 2 * 2 * 128);
    }

    #[test]
    fn async_and_bucketed_issue_match_sync_bits_and_ledger() {
        for &topo in &Topology::ALL {
            let m = 3;
            let lens = [13usize, 7, 31, 2];
            let mut rng = Rng::new(42);
            let inputs: Vec<Vec<Vec<f32>>> =
                (0..m).map(|_| lens.iter().map(|&n| randvec(&mut rng, n)).collect()).collect();

            let run = |mode: usize, inputs: Arc<Vec<Vec<Vec<f32>>>>| {
                let handles = Fabric::with_topology(m, topo);
                let stats = handles[0].stats().clone();
                let mut joins = Vec::new();
                for h in handles {
                    let inputs = inputs.clone();
                    joins.push(std::thread::spawn(move || {
                        let mine = &inputs[h.rank()];
                        match mode {
                            // sync, one collective per buffer
                            0 => mine
                                .iter()
                                .map(|buf| {
                                    let mut d = buf.clone();
                                    let own = h.reduce_scatter_sum(&mut d).unwrap();
                                    d[own].to_vec()
                                })
                                .collect::<Vec<_>>(),
                            // async, one ticket per buffer, waited at the end
                            1 => {
                                let tickets: Vec<Ticket> = mine
                                    .iter()
                                    .map(|buf| h.reduce_scatter_sum_async(buf.clone()))
                                    .collect();
                                tickets
                                    .into_iter()
                                    .map(|t| {
                                        let rb = t.wait().unwrap().pop().unwrap();
                                        rb.data[rb.owned].to_vec()
                                    })
                                    .collect()
                            }
                            // async, all buffers bucketed into one batch
                            _ => h
                                .reduce_scatter_many_async(mine.clone())
                                .wait()
                                .unwrap()
                                .into_iter()
                                .map(|rb| rb.data[rb.owned].to_vec())
                                .collect(),
                        }
                    }));
                }
                let out: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
                (out, stats.op_count(), stats.bytes())
            };

            let fin = Arc::new(inputs);
            let (sync_out, sync_ops, sync_bytes) = run(0, fin.clone());
            let (async_out, async_ops, async_bytes) = run(1, fin.clone());
            let (bucket_out, bucket_ops, bucket_bytes) = run(2, fin);

            for r in 0..m {
                for (k, want) in sync_out[r].iter().enumerate() {
                    assert_eq!(
                        bits(&async_out[r][k]),
                        bits(want),
                        "{topo:?} async vs sync, rank {r} buf {k}"
                    );
                    assert_eq!(
                        bits(&bucket_out[r][k]),
                        bits(want),
                        "{topo:?} bucketed vs sync, rank {r} buf {k}"
                    );
                }
            }
            // transport batching must not change the logical ledger
            assert_eq!(async_ops, sync_ops, "{topo:?}");
            assert_eq!(bucket_ops, sync_ops, "{topo:?}");
            assert_eq!(async_bytes, sync_bytes, "{topo:?}");
            assert_eq!(bucket_bytes, sync_bytes, "{topo:?}");
        }
    }

    #[test]
    fn sync_calls_funnel_through_active_comm_thread() {
        // once a handle has issued async work, a following *sync* call on
        // the compute thread must queue behind it — one total order per
        // rank — instead of racing the comm thread into the gate
        let mut handles = Fabric::new(2);
        let h1 = handles.pop().unwrap();
        let h0 = handles.pop().unwrap();
        let t0 = std::thread::spawn(move || {
            let ticket = h0.all_reduce_sum_async(vec![1.0f32; 16]);
            let mut d = vec![10.0f32; 16];
            h0.all_reduce_sum(&mut d).unwrap(); // funnels through the queue
            let first = ticket.wait().unwrap();
            (first, d)
        });
        let t1 = std::thread::spawn(move || {
            let mut a = vec![2.0f32; 16];
            h1.all_reduce_sum(&mut a).unwrap();
            let mut b = vec![20.0f32; 16];
            h1.all_reduce_sum(&mut b).unwrap();
            (a, b)
        });
        let (first, second) = t0.join().unwrap();
        let (a, b) = t1.join().unwrap();
        assert_eq!(bits(&first[0].data), bits(&[3.0f32; 16]));
        assert_eq!(bits(&second), bits(&[30.0f32; 16]));
        assert_eq!(bits(&a), bits(&[3.0f32; 16]));
        assert_eq!(bits(&b), bits(&[30.0f32; 16]));
    }

    #[test]
    fn fault_plan_parse_is_strict() {
        assert_eq!(FaultPlan::parse(None).unwrap(), None);
        assert_eq!(FaultPlan::parse(Some("")).unwrap(), None);
        assert_eq!(
            FaultPlan::parse(Some("1:3")).unwrap(),
            Some(FaultPlan { rank: 1, step: 3, op: 0 })
        );
        assert_eq!(
            FaultPlan::parse(Some(" 0:2:5 ")).unwrap(),
            Some(FaultPlan { rank: 0, step: 2, op: 5 })
        );
        for bad in ["1", "1:0", "x:2", "1:2:z", "1:2:3:4", "-1:2", "1:-2"] {
            let err = FaultPlan::parse(Some(bad)).unwrap_err();
            assert!(format!("{err}").contains("<rank>:<step>[:<op>]"), "{bad}: {err}");
        }
    }

    #[test]
    fn injected_fault_kills_rank_and_names_it_to_survivors() {
        let handles = Fabric::new(3);
        handles[1].arm_fault(FaultPlan { rank: 1, step: 2, op: 0 });
        let mut joins = Vec::new();
        for h in handles {
            joins.push(std::thread::spawn(move || {
                let rank = h.rank();
                let mut res = Ok(());
                for step in 1..=3u64 {
                    h.begin_step(step);
                    let mut d = vec![1.0f32; 8];
                    res = h.all_reduce_sum(&mut d);
                    if res.is_err() {
                        break;
                    }
                }
                (rank, res)
            }));
        }
        for j in joins {
            let (rank, res) = j.join().unwrap();
            let err = res.unwrap_err();
            let death = err
                .downcast_ref::<PeerDeath>()
                .unwrap_or_else(|| panic!("rank {rank} error must downcast: {err:?}"));
            assert_eq!(death.rank, 1, "every party names the dead rank");
            assert_eq!(death.step, 2, "every party names the death step");
            assert_eq!(death.injected, rank == 1, "only the dying rank is 'injected'");
            let msg = format!("{err}");
            assert!(msg.contains("fabric") && msg.contains("rank 1"), "{msg}");
        }
    }

    #[test]
    fn fault_op_offset_counts_collective_calls() {
        // op 1: the step's first collective completes, the second kills
        let handles = Fabric::new(2);
        handles[0].arm_fault(FaultPlan { rank: 0, step: 1, op: 1 });
        let mut joins = Vec::new();
        for h in handles {
            joins.push(std::thread::spawn(move || {
                h.begin_step(1);
                let mut d = vec![1.0f32; 4];
                let first = h.all_reduce_sum(&mut d);
                let second = h.all_reduce_sum(&mut d);
                (first, second, d)
            }));
        }
        let outs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        for (rank, (first, second, d)) in outs.into_iter().enumerate() {
            first.unwrap_or_else(|e| panic!("rank {rank}: op 0 must complete: {e:?}"));
            assert_eq!(bits(&d), bits(&[2.0f32; 4]), "rank {rank}");
            let err = second.unwrap_err();
            let death = err.downcast_ref::<PeerDeath>().expect("downcast");
            assert_eq!((death.rank, death.step), (0, 1));
        }
    }

    #[test]
    fn fault_fires_on_async_issue_as_ticket_error() {
        let handles = Fabric::new(2);
        handles[1].arm_fault(FaultPlan { rank: 1, step: 1, op: 0 });
        let mut joins = Vec::new();
        for h in handles {
            joins.push(std::thread::spawn(move || {
                h.begin_step(1);
                h.all_reduce_sum_async(vec![1.0f32; 8]).wait().map(|_| ())
            }));
        }
        for j in joins {
            let err = j.join().unwrap().unwrap_err();
            let death = err.downcast_ref::<PeerDeath>().expect("downcast");
            assert_eq!((death.rank, death.step), (1, 1));
        }
    }

    #[test]
    fn tree_bracketing_is_fixed() {
        // ((a+b)+(c+d)) for 4 ranks, (a+b)+c for 3
        let a = [1.0e8f32];
        let b = [1.0f32];
        let c = [-1.0e8f32];
        let d = [1.0f32];
        let got = reduce_contribs(Topology::Tree, 0, &[&a[..], &b[..], &c[..], &d[..]]);
        assert_eq!(got[0], (1.0e8f32 + 1.0) + (-1.0e8 + 1.0));
        let got3 = reduce_contribs(Topology::Tree, 2, &[&a[..], &b[..], &c[..]]);
        assert_eq!(got3[0], (1.0e8f32 + 1.0) + -1.0e8);
    }
}
