//! `collective::fabric` — a concurrent multi-rank collective fabric with
//! **deterministic** reductions.
//!
//! N ranks run simultaneously on real OS threads (each owning its forked
//! `Library`/executor, composing with `runtime::pool` and `runtime::simd`)
//! and meet at a shared-memory board instead of a point-to-point channel
//! ring. Every collective has a **fixed reduction order that is
//! independent of arrival timing**: ranks post their contributions, a
//! barrier separates the post phase from the compute phase, and each
//! reduced shard is folded in a statically-determined rank order. Under
//! IEEE-754 f32 this makes an N-rank concurrent run bit-for-bit identical
//! to the single-threaded reference in [`serial`] — and, for
//! [`Topology::Ring`], to the legacy lock-step channel ring
//! ([`CommHandle`]) — at any `ADAMA_THREADS` / `ADAMA_SIMD` setting.
//!
//! ## The determinism contract
//!
//! For a buffer split into per-rank shards by
//! [`CommHandle::shard_ranges`], shard `j` is reduced as the left-to-right
//! chain
//!
//! ```text
//! ((x_j + x_{j+1}) + x_{j+2}) + … + x_{j+M-1}        (indices mod M)
//! ```
//!
//! for [`Topology::Ring`] — exactly the order in which the channel ring's
//! reduce-scatter folds contributions (f32 addition is commutative
//! bit-for-bit, so chain-from-`j` equals the ring's arrival order) — and
//! as a fixed balanced pairwise bracketing over rank order `0..M` for
//! [`Topology::Tree`]. Neither depends on *when* a rank arrives, only on
//! rank indices, so injected delays cannot change a single bit
//! (`rust/tests/proptests.rs` asserts this under random per-rank sleeps).
//!
//! ## Volume ledger
//!
//! The fabric never moves bytes over a wire, but it keeps the same
//! [`CommStats`] ledger the channel ring keeps — per rank, the payload a
//! real ring interconnect would carry (`2·(M-1)/M · bytes` for
//! all-reduce, half that for reduce-scatter / all-gather) — so Figure-7
//! style volume measurements are engine-independent.
//!
//! ## Failure semantics
//!
//! Collectives must be entered by every rank, in the same order (like
//! NCCL). If a rank errors out and drops its handle while peers are
//! blocked inside a collective, the internal gate converts the would-be
//! deadlock into a `"rank handle dropped"` error on the surviving ranks.

use std::ops::Range;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError, RwLock};

use anyhow::{bail, ensure, Result};

use super::comm::{CommHandle, CommStats};

/// Reduction topology of the fabric (`ADAMA_FABRIC`).
///
/// Both orders are fully deterministic; they differ only in how the f32
/// additions are bracketed, so runs under different topologies are each
/// internally reproducible but not bit-comparable to each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Left-to-right chain per shard, starting at the shard's index —
    /// bit-identical to the legacy channel ring (the default).
    Ring,
    /// Fixed balanced pairwise bracketing over rank order `0..M` —
    /// `(x0+x1) + (x2+x3) …` — the order a tree all-reduce applies.
    Tree,
}

impl Topology {
    pub const ALL: [Topology; 2] = [Topology::Ring, Topology::Tree];

    /// Stable lower-case name (the `ADAMA_FABRIC` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::Tree => "tree",
        }
    }

    /// Strictly resolve an `ADAMA_FABRIC` value: unset/empty defaults to
    /// [`Topology::Ring`]; anything other than `ring`/`tree` is an error
    /// naming the accepted values (no silent fallback).
    pub fn parse(spec: Option<&str>) -> Result<Topology> {
        let s = match spec.map(str::trim) {
            Some(s) if !s.is_empty() => s.to_ascii_lowercase(),
            _ => return Ok(Topology::Ring),
        };
        match s.as_str() {
            "ring" => Ok(Topology::Ring),
            "tree" => Ok(Topology::Tree),
            other => {
                bail!("invalid ADAMA_FABRIC '{other}': expected ring|tree (unset = ring)")
            }
        }
    }

    /// Topology from the `ADAMA_FABRIC` environment variable.
    pub fn from_env() -> Result<Topology> {
        Self::parse(std::env::var("ADAMA_FABRIC").ok().as_deref())
    }
}

/// Element-wise `dst[i] = dst[i] + src[i]` — the single f32 operation all
/// reduction chains are built from. The per-element addition order *is*
/// the determinism contract; nothing here may reassociate it.
fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// Reduce `contribs` (one slice per rank, equal lengths) in the fixed
/// order the topology prescribes. `start` seeds the ring chain (the
/// shard index); the tree bracketing ignores it.
fn reduce_contribs(topo: Topology, start: usize, contribs: &[&[f32]]) -> Vec<f32> {
    let m = contribs.len();
    debug_assert!(m >= 1);
    match topo {
        Topology::Ring => {
            let mut acc = contribs[start % m].to_vec();
            for k in 1..m {
                add_assign(&mut acc, contribs[(start + k) % m]);
            }
            acc
        }
        Topology::Tree => {
            let mut level: Vec<Vec<f32>> = contribs.iter().map(|c| c.to_vec()).collect();
            while level.len() > 1 {
                let mut next = Vec::with_capacity((level.len() + 1) / 2);
                let mut it = level.into_iter();
                while let Some(mut a) = it.next() {
                    if let Some(b) = it.next() {
                        add_assign(&mut a, &b);
                    }
                    next.push(a);
                }
                level = next;
            }
            level.pop().unwrap()
        }
    }
}

/// Payload bytes rank `rank` would send over a real ring during one
/// reduce-scatter phase of `len` f32s: every shard except the one it ends
/// up owning — exactly the channel ring's per-rank ledger.
fn reduce_scatter_wire_bytes(rank: usize, len: usize, world: usize) -> u64 {
    if world <= 1 {
        return 0;
    }
    let shards = CommHandle::shard_ranges(len, world);
    ((len - shards[(rank + 1) % world].len()) * 4) as u64
}

/// Per-rank ring wire bytes for one all-gather phase: every shard except
/// `(rank + 2) mod M` (the last one it receives).
fn all_gather_wire_bytes(rank: usize, len: usize, world: usize) -> u64 {
    if world <= 1 {
        return 0;
    }
    let shards = CommHandle::shard_ranges(len, world);
    ((len - shards[(rank + 2) % world].len()) * 4) as u64
}

/// Reusable world-wide rendezvous. Unlike `std::sync::Barrier`, a rank
/// handle dropped mid-collective (error/panic on a peer, or mismatched
/// collective entry counts) wakes every waiter with an error instead of
/// deadlocking.
struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    arrived: usize,
    generation: u64,
    /// Handles dropped so far — nonzero while anyone still waits means a
    /// peer can never arrive.
    gone: usize,
}

impl Gate {
    fn new() -> Self {
        Self {
            state: Mutex::new(GateState { arrived: 0, generation: 0, gone: 0 }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, GateState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn wait(&self, world: usize) -> Result<()> {
        let mut s = self.lock();
        ensure!(
            s.gone == 0,
            "fabric: {} rank handle(s) dropped mid-run — every rank must enter every \
             collective, in the same order",
            s.gone
        );
        s.arrived += 1;
        if s.arrived == world {
            s.arrived = 0;
            s.generation = s.generation.wrapping_add(1);
            drop(s);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = s.generation;
        while s.generation == gen {
            ensure!(
                s.gone == 0,
                "fabric: a peer rank exited while this rank was blocked in a collective"
            );
            s = self.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
        Ok(())
    }

    fn abandon(&self) {
        let mut s = self.lock();
        s.gone += 1;
        drop(s);
        self.cv.notify_all();
    }
}

/// Shared state of one fabric group.
struct Board {
    world: usize,
    topo: Topology,
    /// Per-rank posted contribution (written only by the owning rank,
    /// read by everyone after the gate).
    input: Vec<RwLock<Vec<f32>>>,
    /// Per-rank reduced shard (reduce-scatter layout: rank `r` publishes
    /// shard `(r+1) mod M` here).
    reduced: Vec<RwLock<Vec<f32>>>,
    gate: Gate,
    stats: Arc<CommStats>,
}

fn read_slot(slot: &RwLock<Vec<f32>>) -> std::sync::RwLockReadGuard<'_, Vec<f32>> {
    slot.read().unwrap_or_else(PoisonError::into_inner)
}

fn write_slot(slot: &RwLock<Vec<f32>>) -> std::sync::RwLockWriteGuard<'_, Vec<f32>> {
    slot.write().unwrap_or_else(PoisonError::into_inner)
}

/// Factory for fabric-connected rank handles.
pub struct Fabric;

impl Fabric {
    /// Create `world` handles on the default [`Topology::Ring`].
    pub fn new(world: usize) -> Vec<FabricHandle> {
        Self::with_topology(world, Topology::Ring)
    }

    /// Create `world` handles with an explicit reduction topology.
    pub fn with_topology(world: usize, topo: Topology) -> Vec<FabricHandle> {
        assert!(world >= 1, "fabric needs at least one rank");
        let board = Arc::new(Board {
            world,
            topo,
            input: (0..world).map(|_| RwLock::new(Vec::new())).collect(),
            reduced: (0..world).map(|_| RwLock::new(Vec::new())).collect(),
            gate: Gate::new(),
            stats: Arc::new(CommStats::default()),
        });
        (0..world).map(|rank| FabricHandle { rank, board: board.clone() }).collect()
    }
}

/// One rank's endpoint in the fabric. Moves into the rank's worker
/// thread; all collectives are synchronous and must be entered by every
/// rank in the same order.
pub struct FabricHandle {
    rank: usize,
    board: Arc<Board>,
}

impl Drop for FabricHandle {
    fn drop(&mut self) {
        // After a normal run every rank has left its last collective, so
        // nobody is waiting and this is a no-op; after an error it wakes
        // blocked peers with a clear failure instead of a deadlock.
        self.board.gate.abandon();
    }
}

impl FabricHandle {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.board.world
    }

    pub fn topology(&self) -> Topology {
        self.board.topo
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.board.stats
    }

    /// Publish this rank's contribution to the board.
    fn post(&self, data: &[f32]) {
        let mut slot = write_slot(&self.board.input[self.rank]);
        slot.clear();
        slot.extend_from_slice(data);
    }

    /// Snapshot every rank's posted contribution for shard `j` and fold
    /// it in the topology's fixed order. Caller must hold the post gate.
    fn reduce_shard(&self, shards: &[Range<usize>], j: usize, len: usize) -> Result<Vec<f32>> {
        let m = self.board.world;
        let guards: Vec<_> = (0..m).map(|r| read_slot(&self.board.input[r])).collect();
        for g in &guards {
            ensure!(
                g.len() == len,
                "fabric: ranks posted different buffer lengths ({} vs {len})",
                g.len()
            );
        }
        let contribs: Vec<&[f32]> = guards.iter().map(|g| &g[shards[j].clone()]).collect();
        Ok(reduce_contribs(self.board.topo, j, &contribs))
    }

    /// All-reduce (sum) in place: every rank ends with the element-wise
    /// sum, reduced in the fixed per-shard order (see module docs).
    pub fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        let m = self.board.world;
        self.board.stats.ops.fetch_add(1, Ordering::Relaxed);
        self.board.stats.bytes_sent.fetch_add(
            reduce_scatter_wire_bytes(self.rank, data.len(), m)
                + all_gather_wire_bytes(self.rank, data.len(), m),
            Ordering::Relaxed,
        );
        if m == 1 {
            return Ok(());
        }
        let shards = CommHandle::shard_ranges(data.len(), m);
        self.post(data);
        self.board.gate.wait(m)?;
        // Each rank folds the shard it owns — shard (rank+1) mod M, the
        // reduce-scatter layout — and publishes it; the fold order is a
        // pure function of (shard index, world), never arrival time.
        let own = (self.rank + 1) % m;
        let red = self.reduce_shard(&shards, own, data.len())?;
        *write_slot(&self.board.reduced[self.rank]) = red;
        self.board.gate.wait(m)?;
        for (j, shard) in shards.iter().enumerate() {
            let owner = (j + m - 1) % m;
            let g = read_slot(&self.board.reduced[owner]);
            data[shard.clone()].copy_from_slice(&g);
        }
        Ok(())
    }

    /// All-reduce then scale by `1/world` (mean) — Eq. 7's m-averaging.
    pub fn all_reduce_mean(&self, data: &mut [f32]) -> Result<()> {
        self.all_reduce_sum(data)?;
        let inv = 1.0 / self.board.world as f32;
        for x in data.iter_mut() {
            *x *= inv;
        }
        Ok(())
    }

    /// Reduce-scatter (sum): on return `data`'s own shard (the returned
    /// range, `(rank+1) mod M` of [`CommHandle::shard_ranges`]) holds the
    /// cross-rank sum; other regions are left untouched (callers must not
    /// read them, matching the channel ring's contract).
    pub fn reduce_scatter_sum(&self, data: &mut [f32]) -> Result<Range<usize>> {
        let m = self.board.world;
        self.board.stats.ops.fetch_add(1, Ordering::Relaxed);
        self.board
            .stats
            .bytes_sent
            .fetch_add(reduce_scatter_wire_bytes(self.rank, data.len(), m), Ordering::Relaxed);
        let shards = CommHandle::shard_ranges(data.len(), m);
        let own = (self.rank + 1) % m;
        if m == 1 {
            return Ok(shards[own].clone());
        }
        self.post(data);
        self.board.gate.wait(m)?;
        let red = self.reduce_shard(&shards, own, data.len())?;
        data[shards[own].clone()].copy_from_slice(&red);
        // Trailing gate: nobody may repost for the next collective while
        // a peer still reads this one's board.
        self.board.gate.wait(m)?;
        Ok(shards[own].clone())
    }

    /// All-gather: each rank contributes the shard it owns (reduce-scatter
    /// layout); on return the whole buffer is consistent on every rank.
    pub fn all_gather_owned(&self, data: &mut [f32]) -> Result<()> {
        let m = self.board.world;
        self.board.stats.ops.fetch_add(1, Ordering::Relaxed);
        self.board
            .stats
            .bytes_sent
            .fetch_add(all_gather_wire_bytes(self.rank, data.len(), m), Ordering::Relaxed);
        if m == 1 {
            return Ok(());
        }
        let shards = CommHandle::shard_ranges(data.len(), m);
        self.post(data);
        self.board.gate.wait(m)?;
        for (j, shard) in shards.iter().enumerate() {
            let owner = (j + m - 1) % m;
            if owner == self.rank {
                continue;
            }
            let g = read_slot(&self.board.input[owner]);
            ensure!(
                g.len() == data.len(),
                "fabric: ranks posted different buffer lengths ({} vs {})",
                g.len(),
                data.len()
            );
            data[shard.clone()].copy_from_slice(&g[shard.clone()]);
        }
        self.board.gate.wait(m)?;
        Ok(())
    }

    /// Barrier: returns once every rank has entered.
    pub fn barrier(&self) -> Result<()> {
        if self.board.world == 1 {
            return Ok(());
        }
        self.board.gate.wait(self.board.world)
    }
}

/// Single-threaded reference twins of the fabric collectives — the
/// **serial simulator**. Each helper takes one buffer per rank and applies
/// the exact reduction order the concurrent fabric applies, so a serial
/// run is the bit-for-bit oracle for any concurrent run (and, on
/// [`Topology::Ring`], for the legacy channel ring). The [`CommStats`]
/// ledger records the same wire volume the concurrent engines record.
pub mod serial {
    use super::*;

    fn check_world(bufs: &[Vec<f32>]) -> Result<usize> {
        ensure!(!bufs.is_empty(), "serial collective needs at least one rank buffer");
        let len = bufs[0].len();
        for b in bufs {
            ensure!(b.len() == len, "serial collective: rank buffer lengths differ");
        }
        Ok(len)
    }

    /// All-reduce (sum) across `bufs[rank]`, in place on every rank.
    pub fn all_reduce_sum(topo: Topology, bufs: &mut [Vec<f32>], stats: &CommStats) -> Result<()> {
        let len = check_world(bufs)?;
        let m = bufs.len();
        stats.ops.fetch_add(m as u64, Ordering::Relaxed);
        let wire: u64 = (0..m)
            .map(|r| {
                reduce_scatter_wire_bytes(r, len, m) + all_gather_wire_bytes(r, len, m)
            })
            .sum();
        stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
        if m == 1 {
            return Ok(());
        }
        let shards = CommHandle::shard_ranges(len, m);
        let mut reduced: Vec<Vec<f32>> = Vec::with_capacity(m);
        for (j, shard) in shards.iter().enumerate() {
            let contribs: Vec<&[f32]> = bufs.iter().map(|b| &b[shard.clone()]).collect();
            reduced.push(reduce_contribs(topo, j, &contribs));
        }
        for b in bufs.iter_mut() {
            for (j, shard) in shards.iter().enumerate() {
                b[shard.clone()].copy_from_slice(&reduced[j]);
            }
        }
        Ok(())
    }

    /// All-reduce then scale by `1/world` on every rank.
    pub fn all_reduce_mean(topo: Topology, bufs: &mut [Vec<f32>], stats: &CommStats) -> Result<()> {
        all_reduce_sum(topo, bufs, stats)?;
        let inv = 1.0 / bufs.len() as f32;
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x *= inv;
            }
        }
        Ok(())
    }

    /// Reduce-scatter (sum): rank `r`'s owned range (returned, index `r`)
    /// holds the cross-rank sum afterwards; other regions are untouched.
    pub fn reduce_scatter_sum(
        topo: Topology,
        bufs: &mut [Vec<f32>],
        stats: &CommStats,
    ) -> Result<Vec<Range<usize>>> {
        let len = check_world(bufs)?;
        let m = bufs.len();
        stats.ops.fetch_add(m as u64, Ordering::Relaxed);
        let wire: u64 = (0..m).map(|r| reduce_scatter_wire_bytes(r, len, m)).sum();
        stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
        let shards = CommHandle::shard_ranges(len, m);
        let owned: Vec<Range<usize>> = (0..m).map(|r| shards[(r + 1) % m].clone()).collect();
        if m == 1 {
            return Ok(owned);
        }
        for r in 0..m {
            let j = (r + 1) % m;
            let red = {
                let contribs: Vec<&[f32]> = bufs.iter().map(|b| &b[shards[j].clone()]).collect();
                reduce_contribs(topo, j, &contribs)
            };
            // Writing rank r's owned shard never feeds a later chain: each
            // rank owns a distinct shard index, and shard j's chain reads
            // only region j of every buffer.
            bufs[r][shards[j].clone()].copy_from_slice(&red);
        }
        Ok(owned)
    }

    /// All-gather: copy each owned shard (reduce-scatter layout) from its
    /// owner into every rank's buffer.
    pub fn all_gather_owned(bufs: &mut [Vec<f32>], stats: &CommStats) -> Result<()> {
        let len = check_world(bufs)?;
        let m = bufs.len();
        stats.ops.fetch_add(m as u64, Ordering::Relaxed);
        let wire: u64 = (0..m).map(|r| all_gather_wire_bytes(r, len, m)).sum();
        stats.bytes_sent.fetch_add(wire, Ordering::Relaxed);
        if m == 1 {
            return Ok(());
        }
        let shards = CommHandle::shard_ranges(len, m);
        for (j, shard) in shards.iter().enumerate() {
            let owner = (j + m - 1) % m;
            let src = bufs[owner][shard.clone()].to_vec();
            for (r, b) in bufs.iter_mut().enumerate() {
                if r != owner {
                    b[shard.clone()].copy_from_slice(&src);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::CommGroup;
    use crate::tensor::Rng;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Run one closure per rank on its own OS thread.
    fn run_fabric<F>(world: usize, topo: Topology, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(FabricHandle) -> Vec<f32> + Send + Sync + Clone + 'static,
    {
        let handles = Fabric::with_topology(world, topo);
        let mut joins = Vec::new();
        for h in handles {
            let f = f.clone();
            joins.push(std::thread::spawn(move || f(h)));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_matches_serial_and_channel_for_awkward_worlds() {
        // non-power-of-two worlds, zero-length shards (len < world), the
        // single-rank degenerate ring, and len = 0
        for &m in &[1usize, 2, 3, 4, 5, 7, 8] {
            for &len in &[0usize, 1, 3, m.saturating_sub(1), 64, 130] {
                let mut rng = Rng::new((m * 1000 + len) as u64);
                let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();

                let mut serial_bufs = inputs.clone();
                let stats = CommStats::default();
                serial::all_reduce_sum(Topology::Ring, &mut serial_bufs, &stats).unwrap();

                let fin = Arc::new(inputs.clone());
                let fab = run_fabric(m, Topology::Ring, move |h| {
                    let mut d = fin[h.rank()].clone();
                    h.all_reduce_sum(&mut d).unwrap();
                    d
                });

                let cin = Arc::new(inputs.clone());
                let chan = {
                    let handles = CommGroup::new(m);
                    let mut joins = Vec::new();
                    for h in handles {
                        let cin = cin.clone();
                        joins.push(std::thread::spawn(move || {
                            let mut d = cin[h.rank()].clone();
                            h.all_reduce_sum(&mut d).unwrap();
                            d
                        }));
                    }
                    joins.into_iter().map(|j| j.join().unwrap()).collect::<Vec<_>>()
                };

                for r in 0..m {
                    assert_eq!(
                        bits(&fab[r]),
                        bits(&serial_bufs[r]),
                        "fabric vs serial, world {m} len {len} rank {r}"
                    );
                    assert_eq!(
                        bits(&chan[r]),
                        bits(&serial_bufs[r]),
                        "channel vs serial, world {m} len {len} rank {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn tree_topology_concurrent_matches_serial() {
        for &m in &[2usize, 3, 4, 6] {
            let len = 37;
            let mut rng = Rng::new(m as u64);
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();
            let mut serial_bufs = inputs.clone();
            serial::all_reduce_sum(Topology::Tree, &mut serial_bufs, &CommStats::default())
                .unwrap();
            let fin = Arc::new(inputs);
            let fab = run_fabric(m, Topology::Tree, move |h| {
                let mut d = fin[h.rank()].clone();
                h.all_reduce_sum(&mut d).unwrap();
                d
            });
            for r in 0..m {
                assert_eq!(bits(&fab[r]), bits(&serial_bufs[r]), "world {m} rank {r}");
            }
        }
    }

    #[test]
    fn reduce_scatter_then_gather_equals_all_reduce() {
        for &m in &[2usize, 3, 5] {
            let len = 4 * m + 1;
            let mut rng = Rng::new(7);
            let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();
            let mut want = inputs.clone();
            serial::all_reduce_sum(Topology::Ring, &mut want, &CommStats::default()).unwrap();
            let fin = Arc::new(inputs);
            let out = run_fabric(m, Topology::Ring, move |h| {
                let mut d = fin[h.rank()].clone();
                let own = h.reduce_scatter_sum(&mut d).unwrap();
                // poison everything outside the owned shard, then gather
                for (i, x) in d.iter_mut().enumerate() {
                    if !own.contains(&i) {
                        *x = f32::NAN;
                    }
                }
                h.all_gather_owned(&mut d).unwrap();
                d
            });
            for r in 0..m {
                assert_eq!(bits(&out[r]), bits(&want[r]), "world {m} rank {r}");
            }
        }
    }

    #[test]
    fn reduction_order_is_invariant_under_injected_delays() {
        // stagger rank arrival with rank- and round-dependent sleeps; the
        // fixed fold order must make every run bit-identical to serial
        let m = 4;
        let len = 50;
        let mut rng = Rng::new(99);
        let inputs: Vec<Vec<f32>> = (0..m).map(|_| randvec(&mut rng, len)).collect();
        let mut want = inputs.clone();
        serial::all_reduce_sum(Topology::Ring, &mut want, &CommStats::default()).unwrap();
        for round in 0..3u64 {
            let fin = Arc::new(inputs.clone());
            let out = run_fabric(m, Topology::Ring, move |h| {
                let jitter = (h.rank() as u64 * 7 + round * 3) % 11;
                std::thread::sleep(std::time::Duration::from_millis(jitter));
                let mut d = fin[h.rank()].clone();
                h.all_reduce_sum(&mut d).unwrap();
                d
            });
            for r in 0..m {
                assert_eq!(bits(&out[r]), bits(&want[r]), "round {round} rank {r}");
            }
        }
    }

    #[test]
    fn wire_ledger_matches_channel_ring() {
        let m = 4;
        let n = 1024;
        let fab = Fabric::new(m);
        let stats = fab[0].stats().clone();
        let mut joins = Vec::new();
        for h in fab {
            joins.push(std::thread::spawn(move || {
                let mut d = vec![1.0f32; n];
                h.all_reduce_sum(&mut d).unwrap();
                let own = h.reduce_scatter_sum(&mut d).unwrap();
                let _ = own;
                h.all_gather_owned(&mut d).unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // all-reduce 2(M-1)·len·4 + reduce-scatter (M-1)·len·4 + gather same
        let want = (4 * (m - 1) * n * 4) as u64;
        assert_eq!(stats.bytes(), want);
        assert_eq!(stats.op_count(), 3 * m as u64);

        // the serial twin records the identical ledger
        let serial_stats = CommStats::default();
        let mut bufs: Vec<Vec<f32>> = (0..m).map(|_| vec![1.0f32; n]).collect();
        serial::all_reduce_sum(Topology::Ring, &mut bufs, &serial_stats).unwrap();
        serial::reduce_scatter_sum(Topology::Ring, &mut bufs, &serial_stats).unwrap();
        serial::all_gather_owned(&mut bufs, &serial_stats).unwrap();
        assert_eq!(serial_stats.bytes(), want);
        assert_eq!(serial_stats.op_count(), 3 * m as u64);
    }

    #[test]
    fn dropped_peer_surfaces_as_error_not_deadlock() {
        let mut handles = Fabric::new(2);
        let h1 = handles.pop().unwrap();
        let h0 = handles.pop().unwrap();
        let t = std::thread::spawn(move || {
            let mut d = vec![1.0f32; 8];
            h0.all_reduce_sum(&mut d)
        });
        // rank 1 exits without ever entering the collective
        drop(h1);
        let res = t.join().unwrap();
        assert!(res.is_err(), "waiting rank must error out, not hang");
        let msg = format!("{:?}", res.unwrap_err());
        assert!(msg.contains("fabric"), "{msg}");
    }

    #[test]
    fn barrier_does_not_deadlock() {
        run_fabric(3, Topology::Ring, |h| {
            for _ in 0..5 {
                h.barrier().unwrap();
            }
            vec![]
        });
    }

    #[test]
    fn topology_parse_is_strict() {
        assert_eq!(Topology::parse(None).unwrap(), Topology::Ring);
        assert_eq!(Topology::parse(Some("")).unwrap(), Topology::Ring);
        assert_eq!(Topology::parse(Some("ring")).unwrap(), Topology::Ring);
        assert_eq!(Topology::parse(Some(" Tree ")).unwrap(), Topology::Tree);
        let err = Topology::parse(Some("mesh")).unwrap_err();
        assert!(format!("{err}").contains("ring|tree"), "{err}");
    }

    #[test]
    fn tree_bracketing_is_fixed() {
        // ((a+b)+(c+d)) for 4 ranks, (a+b)+c for 3
        let a = [1.0e8f32];
        let b = [1.0f32];
        let c = [-1.0e8f32];
        let d = [1.0f32];
        let got = reduce_contribs(Topology::Tree, 0, &[&a[..], &b[..], &c[..], &d[..]]);
        assert_eq!(got[0], (1.0e8f32 + 1.0) + (-1.0e8 + 1.0));
        let got3 = reduce_contribs(Topology::Tree, 2, &[&a[..], &b[..], &c[..]]);
        assert_eq!(got3[0], (1.0e8f32 + 1.0) + -1.0e8);
    }
}
