//! # adama — Adam Accumulation for memory-efficient large-scale training
//!
//! Reproduction of *"Adam Accumulation to Reduce Memory Footprints of both
//! Activations and Gradients for Large-scale DNN Training"* (Zhang et al.,
//! 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — Pallas optimizer kernels and a per-layer
//!   transformer LM, AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **L3 (this crate)** — the training coordinator: micro-batch
//!   scheduling, layer-by-layer backward with immediate gradient release,
//!   optimizer-state accumulation (the paper's contribution), in-process
//!   data-parallel workers with optimizer-state all-reduce (Eq. 5–8),
//!   ZeRO-S1 partitioning, category-exact memory accounting, and an
//!   analytic memory model that regenerates the paper's tables/figures.
//!
//! Python never runs on the training path: the [`runtime`] module loads
//! the AOT artifacts through the PJRT C API (`xla` crate) and executes
//! them from rust.
//!
//! Start with [`coordinator::Trainer`] (see `examples/quickstart.rs`).

pub mod collective;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod memmodel;
pub mod memory;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use config::{OptimizerKind, TrainConfig};
pub use coordinator::Trainer;
pub use memory::{Category, MemoryTracker};
pub use runtime::{ArtifactLibrary, Engine};
