//! # adama — Adam Accumulation for memory-efficient large-scale training
//!
//! Reproduction of *"Adam Accumulation to Reduce Memory Footprints of both
//! Activations and Gradients for Large-scale DNN Training"* (Zhang et al.,
//! 2023) as a multi-backend training system.
//!
//! ## Architecture: the backend seam
//!
//! The training stack is layered over the [`runtime`] execution seam
//! (`Value` / `Arg` / `Program` / `Executor`):
//!
//! * **coordinator** — the paper's Algorithm 2: micro-batch scheduling,
//!   layer-by-layer backward with immediate gradient release,
//!   category-exact memory accounting. Speaks only `runtime::Value`.
//! * **optim** — the optimizer zoo (AdamA, Adam+GA, Adafactor, SM3,
//!   SGDM-A). Update arithmetic dispatches through `runtime::Program`
//!   (chunked kernel path) or direct host loops (`optim::host_math`).
//! * **collective** — the concurrent collective fabric: N ranks on real
//!   OS threads with deterministic ring/tree reductions (plus a
//!   lock-step channel ring and a serial simulator, all bit-identical),
//!   optimizer-state all-reduce workers (Eq. 5–8) and ZeRO-S1
//!   partitioning.
//! * **serve** — the forward-only split of the same stack: batched
//!   incremental decoding over a per-sequence KV cache that is metered
//!   through the executor like any other activation, budgeted by
//!   `ADAMA_KV_BUDGET`, and bit-identical to the full-context forward.
//! * **runtime** — `Library` resolves manifest program names through one
//!   of two `Executor` backends:
//!     * `hostexec` (default): pure-rust reference implementations of the
//!       optimizer kernels, the per-layer transformer LM and the MLP
//!       classifier. Zero native dependencies — everything in this crate,
//!       including the distributed simulators, runs on a clean machine.
//!     * `pjrt` (cargo feature `pjrt`): executes the AOT HLO artifacts
//!       produced by `python/compile/aot.py` through the PJRT C API.
//!       Builds against the `vendor/xla` stub by default; patch in the
//!       real bindings to execute artifacts.
//!
//! ## Feature flags & backend selection
//!
//! | build | behaviour |
//! |---|---|
//! | default | host executor + built-in manifest (`Manifest::builtin`) |
//! | `--features pjrt` + artifacts | PJRT over `$ADAMA_ARTIFACTS` / `./artifacts` |
//! | `ADAMA_BACKEND=host` | force the host executor even with `pjrt` |
//! | `ADAMA_BACKEND=pjrt` | require PJRT; fail loudly instead of falling back |
//! | `ADAMA_THREADS=N` | host thread-pool size (bit-identical at any N) |
//! | `ADAMA_ACT_BUDGET=0\|<n>[k\|m\|g]\|unlimited` | activation stash budget: remat (default) ↔ stash per-block intermediates |
//! | `ADAMA_KV_BUDGET=0\|<n>[k\|m\|g]\|unlimited` | serving KV-cache byte cap: uncapped (default) ↔ oldest-sequence eviction |
//! | `ADAMA_FABRIC=ring\|tree` | collective fabric reduction topology (deterministic either way) |
//!
//! Every `ADAMA_*` knob is strictly parsed: invalid values are clear
//! errors naming the accepted spellings, never silent fallbacks.
//!
//! Python never runs on the training path; with default features nothing
//! outside this workspace runs at all.
//!
//! Start with [`coordinator::Trainer`] / [`coordinator::MlpTrainer`]
//! (see `examples/quickstart.rs`).

pub mod collective;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod memmodel;
pub mod memory;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

pub use config::{OptimizerKind, TrainConfig};
pub use coordinator::Trainer;
pub use memory::{Category, MemoryTracker};
pub use runtime::{ArtifactLibrary, Library};
