//! Adam + gradient accumulation — the paper's baseline (Alg. 1, blue).
//!
//! Holds a full-model gradient accumulator (`P` floats, tracked under
//! `Category::Gradients`) that lives across micro-batches; the mini-batch
//! update is the fused standard-Adam step. This is exactly the memory
//! profile AdamA eliminates.

use anyhow::Result;

use super::{AdamStatesMut, Hyper, Optimizer, UpdateBackend};
use crate::config::OptimizerKind;
use crate::memory::{Category, MemoryTracker};
use crate::model::{LayerParams, ModelSpec};

pub struct AdamGA {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Full-model gradient accumulator — the contended memory.
    acc: Vec<Vec<f32>>,
    hyper: Hyper,
    backend: UpdateBackend,
    t: u64,
    state_bytes: usize,
    grad_bytes: usize,
}

impl AdamGA {
    pub fn new(
        spec: &ModelSpec,
        hyper: Hyper,
        backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let zero: Vec<Vec<f32>> = spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let state_bytes = 2 * spec.total_params() * 4;
        let grad_bytes = spec.total_params() * 4;
        tracker.alloc_raw(Category::OptimizerStates, state_bytes);
        tracker.alloc_raw(Category::Gradients, grad_bytes);
        Self {
            m: zero.clone(),
            v: zero.clone(),
            acc: zero,
            hyper,
            backend,
            t: 0,
            state_bytes,
            grad_bytes,
        }
    }
}

impl Optimizer for AdamGA {
    fn kind(&self) -> OptimizerKind {
        OptimizerKind::AdamGA
    }

    fn begin_minibatch(&mut self, t: u64) -> Result<()> {
        self.t = t;
        for a in &mut self.acc {
            a.fill(0.0);
        }
        Ok(())
    }

    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()> {
        self.backend.grad_acc(&mut self.acc[layer], grad, gscale)
    }

    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()> {
        let (bc1, bc2) = self.hyper.bias_corrections(self.t);
        for (l, p) in params.iter_mut().enumerate() {
            self.backend.adam_full(
                &mut p.flat,
                &mut self.m[l],
                &mut self.v[l],
                &self.acc[l],
                lr,
                bc1,
                bc2,
            )?;
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    fn persistent_grad_bytes(&self) -> usize {
        self.grad_bytes
    }

    fn adam_states_mut(&mut self) -> Option<AdamStatesMut<'_>> {
        Some(AdamStatesMut { m: &mut self.m, v: &mut self.v })
    }

    fn as_adamga_mut(&mut self) -> Option<&mut AdamGA> {
        Some(self)
    }
}

/// Mutable access to the gradient accumulator — used by the distributed
/// gradient-all-reduce baseline and ZeRO flows.
impl AdamGA {
    pub fn grad_acc_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.acc
    }

    pub fn grad_acc(&self) -> &[Vec<f32>] {
        &self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::host_math;
    use crate::runtime::{ModelConfigEntry, ModelHyper};

    fn toy_spec() -> ModelSpec {
        let entry = ModelConfigEntry {
            model: ModelHyper {
                vocab: 8, hidden: 4, layers: 1, heads: 1, seq: 2, microbatch: 2, ffn: 16,
            },
            param_shapes: vec![
                ("embed.E".into(), vec![8, 4]),
                ("block0.ln1.g".into(), vec![4]),
                ("head.W".into(), vec![4, 8]),
            ],
            artifacts: Default::default(),
        };
        ModelSpec::from_manifest("toy", &entry).unwrap()
    }

    fn hyper() -> Hyper {
        Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    #[test]
    fn accumulates_scaled_microbatch_grads() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = AdamGA::new(&spec, hyper(), UpdateBackend::host(hyper()), &tracker);
        opt.begin_minibatch(1).unwrap();
        let n = spec.layers[0].flat_len;
        opt.accumulate(0, &vec![2.0; n], 0.25).unwrap();
        opt.accumulate(0, &vec![4.0; n], 0.25).unwrap();
        assert!(opt.acc[0].iter().all(|&x| (x - 1.5).abs() < 1e-6));
    }

    #[test]
    fn matches_manual_adam_over_minibatch_mean() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = AdamGA::new(&spec, hyper(), UpdateBackend::host(hyper()), &tracker);
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
        let n_micro = 4;
        let grads: Vec<Vec<f32>> = (0..n_micro)
            .map(|k| (0..spec.layers[0].flat_len).map(|i| (i + k) as f32 * 0.1).collect())
            .collect();

        opt.begin_minibatch(1).unwrap();
        for g in &grads {
            opt.accumulate(0, g, 1.0 / n_micro as f32).unwrap();
        }
        // zero grads for other layers
        for l in 1..spec.layers.len() {
            opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
        }
        opt.apply(&mut params, 0.01).unwrap();

        // reference: fused Adam on the mean gradient
        let mean: Vec<f32> = (0..spec.layers[0].flat_len)
            .map(|i| grads.iter().map(|g| g[i]).sum::<f32>() / n_micro as f32)
            .collect();
        let mut rp = vec![1.0f32; spec.layers[0].flat_len];
        let mut rm = vec![0.0f32; rp.len()];
        let mut rv = vec![0.0f32; rp.len()];
        let (bc1, bc2) = hyper().bias_corrections(1);
        host_math::adam_full(&mut rp, &mut rm, &mut rv, &mean, 0.01, bc1, bc2, 0.9, 0.999, 1e-8);
        for (a, b) in params[0].flat.iter().zip(&rp) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn holds_full_model_gradient_memory() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = AdamGA::new(&spec, hyper(), UpdateBackend::host(hyper()), &tracker);
        assert_eq!(opt.persistent_grad_bytes(), spec.total_params() * 4);
        assert_eq!(tracker.live(Category::Gradients), spec.total_params() * 4);
    }
}
