//! Adafactor (Shazeer & Stern 2018) — Table-2 comparator.
//!
//! Reduces *optimizer-state* memory by factoring the second moment of each
//! matrix into row/column accumulators (R + C floats instead of R·C);
//! vectors keep a full second moment. First moment disabled (β₁=0), per
//! the memory-saving configuration the paper compares against.  Gradient
//! handling is GA-style (full accumulator) — Adafactor does not release
//! gradients early, which is exactly why AdamA beats it in Table 2.

use anyhow::Result;

use super::{Hyper, Optimizer};
use crate::config::OptimizerKind;
use crate::memory::{Category, MemoryTracker};
use crate::model::{LayerParams, ModelSpec, ParamView};

const EPS1: f32 = 1e-30;

enum Second {
    /// 2-D tensor: factored row/col mean-square accumulators.
    Factored { rows: Vec<f32>, cols: Vec<f32>, r: usize, c: usize },
    /// 1-D tensor: full accumulator.
    Full(Vec<f32>),
}

struct TensorState {
    view: ParamView,
    second: Second,
}

pub struct Adafactor {
    layers: Vec<Vec<TensorState>>,
    acc: Vec<Vec<f32>>,
    beta2: f32,
    t: u64,
    state_bytes: usize,
    grad_bytes: usize,
}

impl Adafactor {
    pub fn new(spec: &ModelSpec, hyper: Hyper, tracker: &MemoryTracker) -> Self {
        let mut state_bytes = 0usize;
        let layers = spec
            .layers
            .iter()
            .map(|l| {
                l.params
                    .iter()
                    .map(|p| {
                        let second = if p.shape.len() == 2 {
                            let (r, c) = (p.shape[0], p.shape[1]);
                            state_bytes += (r + c) * 4;
                            Second::Factored { rows: vec![0.0; r], cols: vec![0.0; c], r, c }
                        } else {
                            state_bytes += p.elements() * 4;
                            Second::Full(vec![0.0; p.elements()])
                        };
                        TensorState { view: p.clone(), second }
                    })
                    .collect()
            })
            .collect();
        let acc: Vec<Vec<f32>> = spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let grad_bytes = spec.total_params() * 4;
        tracker.alloc_raw(Category::OptimizerStates, state_bytes);
        tracker.alloc_raw(Category::Gradients, grad_bytes);
        Self { layers, acc, beta2: hyper.beta2, t: 0, state_bytes, grad_bytes }
    }
}

impl Optimizer for Adafactor {
    fn kind(&self) -> OptimizerKind {
        OptimizerKind::Adafactor
    }

    fn begin_minibatch(&mut self, t: u64) -> Result<()> {
        self.t = t;
        for a in &mut self.acc {
            a.fill(0.0);
        }
        Ok(())
    }

    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()> {
        super::host_math::grad_acc(&mut self.acc[layer], grad, gscale);
        Ok(())
    }

    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()> {
        // decaying beta2-hat per Shazeer-Stern (t^-0.8 schedule)
        let b2 = 1.0 - (self.t as f32).powf(-0.8).min(1.0 - self.beta2);
        for (l, p) in params.iter_mut().enumerate() {
            for ts in &mut self.layers[l] {
                let g = &self.acc[l][ts.view.range.clone()];
                let dst = &mut p.flat[ts.view.range.clone()];
                match &mut ts.second {
                    Second::Factored { rows, cols, r, c } => {
                        let (r, c) = (*r, *c);
                        for i in 0..r {
                            let mean: f32 = (0..c)
                                .map(|j| g[i * c + j] * g[i * c + j] + EPS1)
                                .sum::<f32>()
                                / c as f32;
                            rows[i] = b2 * rows[i] + (1.0 - b2) * mean;
                        }
                        for j in 0..c {
                            let mean: f32 = (0..r)
                                .map(|i| g[i * c + j] * g[i * c + j] + EPS1)
                                .sum::<f32>()
                                / r as f32;
                            cols[j] = b2 * cols[j] + (1.0 - b2) * mean;
                        }
                        let row_mean =
                            rows.iter().sum::<f32>().max(EPS1) / r as f32;
                        for i in 0..r {
                            for j in 0..c {
                                let vhat = rows[i] * cols[j] / row_mean;
                                dst[i * c + j] -= lr * g[i * c + j] / (vhat.sqrt() + 1e-8);
                            }
                        }
                    }
                    Second::Full(v) => {
                        for i in 0..v.len() {
                            v[i] = b2 * v[i] + (1.0 - b2) * (g[i] * g[i] + EPS1);
                            dst[i] -= lr * g[i] / (v[i].sqrt() + 1e-8);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    fn persistent_grad_bytes(&self) -> usize {
        self.grad_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelConfigEntry, ModelHyper};

    fn toy_spec() -> ModelSpec {
        let entry = ModelConfigEntry {
            model: ModelHyper {
                vocab: 8, hidden: 4, layers: 1, heads: 1, seq: 2, microbatch: 2, ffn: 16,
            },
            param_shapes: vec![
                ("embed.E".into(), vec![8, 4]),
                ("block0.ln1.g".into(), vec![4]),
                ("head.W".into(), vec![4, 8]),
            ],
            artifacts: Default::default(),
        };
        ModelSpec::from_manifest("toy", &entry).unwrap()
    }

    #[test]
    fn factored_state_is_sublinear() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = Adafactor::new(&spec, Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, &tracker);
        // matrices factored: (8+4) + (4+8); vector ln1.g full: 4
        assert_eq!(opt.state_bytes(), (12 + 12 + 4) * 4);
        assert!(opt.state_bytes() < spec.total_params() * 4); // < one copy of P
        assert_eq!(opt.persistent_grad_bytes(), spec.total_params() * 4);
    }

    #[test]
    fn descends_on_quadratic() {
        // minimize 0.5*||p||^2 (grad = p): loss must shrink.
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt =
            Adafactor::new(&spec, Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }, &tracker);
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
        let norm0: f32 = params.iter().flat_map(|p| &p.flat).map(|x| x * x).sum();
        for t in 1..=20 {
            opt.begin_minibatch(t).unwrap();
            let grads: Vec<Vec<f32>> = params.iter().map(|p| p.flat.clone()).collect();
            for (l, g) in grads.iter().enumerate() {
                opt.accumulate(l, g, 1.0).unwrap();
            }
            opt.apply(&mut params, 0.05).unwrap();
        }
        let norm1: f32 = params.iter().flat_map(|p| &p.flat).map(|x| x * x).sum();
        assert!(norm1 < norm0 * 0.8, "{norm1} !< {norm0}");
    }
}
