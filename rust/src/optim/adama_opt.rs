//! AdamA — the paper's optimizer-accumulation method (Algorithm 2).
//!
//! State: per-layer (m, v) flat buffers, 2·P floats total.  At mini-batch
//! start the states decay once (`m ← β₁m`, `v ← s·β₂v` where `s = M` in
//! the distributed scheme, Eq. 6); each micro-batch layer gradient is then
//! folded in immediately and *released by the caller* — no gradient
//! accumulator exists anywhere.

use anyhow::Result;

use super::{AdamStatesMut, Hyper, Optimizer, UpdateBackend};
use crate::config::OptimizerKind;
use crate::memory::{Category, MemoryTracker};
use crate::model::ckpt::OptSnapshot;
use crate::model::{LayerParams, ModelSpec};

pub struct AdamA {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    hyper: Hyper,
    backend: UpdateBackend,
    t: u64,
    v_decay_factor: f32,
    /// Decoupled weight decay (AdamW-A, §5 extension); 0 disables.
    weight_decay: f32,
    state_bytes: usize,
    /// Lazy decay (perf pass): instead of a standalone decay sweep at
    /// mini-batch start, each layer's first `accumulate` of the mini-batch
    /// runs the fused decay+accumulate kernel — one HBM round-trip over
    /// (m, v) saved per layer per step, which is exactly the pass-count
    /// gap between AdamA (N+2) and Adam+GA (N+1).
    decay_pending: Vec<bool>,
}

impl AdamA {
    pub fn new(
        spec: &ModelSpec,
        hyper: Hyper,
        backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let m: Vec<Vec<f32>> = spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let v = m.clone();
        let state_bytes = 2 * spec.total_params() * 4;
        tracker.alloc_raw(Category::OptimizerStates, state_bytes);
        let decay_pending = vec![false; m.len()];
        Self {
            m,
            v,
            hyper,
            backend,
            t: 0,
            v_decay_factor: 1.0,
            weight_decay: 0.0,
            state_bytes,
            decay_pending,
        }
    }

    /// Enable decoupled weight decay (AdamW-A).
    pub fn with_weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    pub fn step(&self) -> u64 {
        self.t
    }
}

impl Optimizer for AdamA {
    fn kind(&self) -> OptimizerKind {
        OptimizerKind::AdamA
    }

    fn begin_minibatch(&mut self, t: u64) -> Result<()> {
        self.t = t;
        // decay deferred into each layer's first accumulate (fused kernel)
        self.decay_pending.iter_mut().for_each(|p| *p = true);
        Ok(())
    }

    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()> {
        if std::mem::take(&mut self.decay_pending[layer]) {
            let ms = self.hyper.beta1;
            let vs = self.v_decay_factor * self.hyper.beta2;
            self.backend
                .adama_decay_acc(&mut self.m[layer], &mut self.v[layer], grad, gscale, ms, vs)
        } else {
            self.backend.adama_acc(&mut self.m[layer], &mut self.v[layer], grad, gscale)
        }
    }

    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()> {
        let (bc1, bc2) = self.hyper.bias_corrections(self.t);
        let ms = self.hyper.beta1;
        let vs = self.v_decay_factor * self.hyper.beta2;
        for (l, p) in params.iter_mut().enumerate() {
            // a layer that saw no gradient this mini-batch still decays
            if std::mem::take(&mut self.decay_pending[l]) {
                self.backend.adama_decay(&mut self.m[l], &mut self.v[l], ms, vs)?;
            }
            if self.weight_decay > 0.0 {
                self.backend.adamw_update(
                    &mut p.flat, &self.m[l], &self.v[l], lr, bc1, bc2, self.weight_decay,
                )?;
            } else {
                self.backend.adam_update(&mut p.flat, &self.m[l], &self.v[l], lr, bc1, bc2)?;
            }
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    fn adam_states_mut(&mut self) -> Option<AdamStatesMut<'_>> {
        Some(AdamStatesMut { m: &mut self.m, v: &mut self.v })
    }

    fn set_v_decay_factor(&mut self, factor: f32) {
        self.v_decay_factor = factor;
    }

    fn export_state(&self) -> Result<OptSnapshot> {
        // layer order, m before v; lazy-decay flags are all consumed at the
        // mini-batch boundary where checkpoints are cut, so (t, m, v) is
        // the complete state
        let bufs = self.m.iter().chain(self.v.iter()).cloned().collect();
        Ok(OptSnapshot { tag: "adama".into(), t: self.t, bufs })
    }

    fn import_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        if snap.tag != "adama" {
            anyhow::bail!("AdamA cannot import a '{}' snapshot", snap.tag);
        }
        let n = self.m.len();
        if snap.bufs.len() != 2 * n {
            anyhow::bail!(
                "AdamA snapshot has {} buffers, wanted {} (m ++ v per layer)",
                snap.bufs.len(),
                2 * n
            );
        }
        for (l, buf) in snap.bufs[..n].iter().enumerate() {
            super::restore_buf(&mut self.m[l], buf, &format!("m[{l}]"))?;
        }
        for (l, buf) in snap.bufs[n..].iter().enumerate() {
            super::restore_buf(&mut self.v[l], buf, &format!("v[{l}]"))?;
        }
        self.t = snap.t;
        self.decay_pending.iter_mut().for_each(|p| *p = false);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::host_math;

    fn toy_spec() -> ModelSpec {
        use crate::runtime::{ModelConfigEntry, ModelHyper};
        let entry = ModelConfigEntry {
            model: ModelHyper {
                vocab: 8, hidden: 4, layers: 1, heads: 1, seq: 2, microbatch: 2, ffn: 16,
            },
            param_shapes: vec![
                ("embed.E".into(), vec![8, 4]),
                ("embed.P".into(), vec![2, 4]),
                ("block0.ln1.g".into(), vec![4]),
                ("head.W".into(), vec![4, 8]),
            ],
            artifacts: Default::default(),
        };
        ModelSpec::from_manifest("toy", &entry).unwrap()
    }

    fn hyper() -> Hyper {
        Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    #[test]
    fn n1_equals_fused_adam() {
        // AdamA with one micro-batch must reproduce standard Adam exactly.
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = AdamA::new(&spec, hyper(), UpdateBackend::host(hyper()), &tracker);

        let mut params: Vec<LayerParams> = spec
            .layers
            .iter()
            .map(|l| LayerParams { flat: (0..l.flat_len).map(|i| i as f32 * 0.1).collect() })
            .collect();
        let mut ref_params: Vec<Vec<f32>> = params.iter().map(|p| p.flat.clone()).collect();
        let mut ref_m: Vec<Vec<f32>> =
            spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let mut ref_v = ref_m.clone();

        for t in 1..=3u64 {
            let grads: Vec<Vec<f32>> = spec
                .layers
                .iter()
                .enumerate()
                .map(|(li, l)| {
                    (0..l.flat_len).map(|i| ((i + li) as f32 - 2.0) * 0.3 * t as f32).collect()
                })
                .collect();
            opt.begin_minibatch(t).unwrap();
            for (li, g) in grads.iter().enumerate() {
                opt.accumulate(li, g, 1.0).unwrap();
            }
            opt.apply(&mut params, 0.01).unwrap();

            let (bc1, bc2) = hyper().bias_corrections(t);
            for li in 0..spec.layers.len() {
                host_math::adam_full(
                    &mut ref_params[li], &mut ref_m[li], &mut ref_v[li], &grads[li],
                    0.01, bc1, bc2, 0.9, 0.999, 1e-8,
                );
            }
        }
        for (got, want) in params.iter().zip(&ref_params) {
            for (a, b) in got.flat.iter().zip(want) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn state_bytes_is_two_p() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = AdamA::new(&spec, hyper(), UpdateBackend::host(hyper()), &tracker);
        assert_eq!(opt.state_bytes(), 2 * spec.total_params() * 4);
        assert_eq!(opt.persistent_grad_bytes(), 0); // the paper's point
        assert_eq!(tracker.live(Category::OptimizerStates), opt.state_bytes());
    }

    #[test]
    fn v_decay_factor_scales_v_only() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = AdamA::new(&spec, hyper(), UpdateBackend::host(hyper()), &tracker);
        // seed states
        let g: Vec<f32> = vec![1.0; spec.layers[0].flat_len];
        opt.begin_minibatch(1).unwrap();
        opt.accumulate(0, &g, 1.0).unwrap();
        let m_before = opt.m[0][0];
        let v_before = opt.v[0][0];
        opt.set_v_decay_factor(4.0);
        opt.begin_minibatch(2).unwrap();
        // decay is lazy: applied on the layer's first accumulate
        let zeros = vec![0.0f32; spec.layers[0].flat_len];
        opt.accumulate(0, &zeros, 1.0).unwrap();
        assert!((opt.m[0][0] - 0.9 * m_before).abs() < 1e-7);
        assert!((opt.v[0][0] - 4.0 * 0.999 * v_before).abs() < 1e-7);
    }

    #[test]
    fn layers_without_grads_still_decay_at_apply() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = AdamA::new(&spec, hyper(), UpdateBackend::host(hyper()), &tracker);
        let g: Vec<f32> = vec![1.0; spec.layers[0].flat_len];
        opt.begin_minibatch(1).unwrap();
        opt.accumulate(0, &g, 1.0).unwrap();
        let m_before = opt.m[0][0];
        // layer 1/2 get no gradient this mini-batch
        opt.begin_minibatch(2).unwrap();
        opt.accumulate(0, &g, 1.0).unwrap();
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
        opt.apply(&mut params, 0.01).unwrap();
        // layer 0 decayed through the fused path
        assert!((opt.m[0][0] - (0.9 * m_before + 0.1)).abs() < 1e-6);
        // untouched layers decayed at apply (were zero, stay zero) and no
        // pending flags remain
        assert!(opt.decay_pending.iter().all(|p| !p));
    }
}
