//! SM3 (Anil et al. 2019) — Table-2 comparator.
//!
//! Memory-efficient adaptive method: for a 2-D tensor it keeps one
//! accumulator per row and per column (cover sets); the per-element second
//! moment is reconstructed as `min(row[i], col[j]) + g²`. Vectors fall
//! back to full AdaGrad accumulators. GA-style gradient handling, like
//! Adafactor.

use anyhow::Result;

use super::Optimizer;
use crate::config::OptimizerKind;
use crate::memory::{Category, MemoryTracker};
use crate::model::{LayerParams, ModelSpec, ParamView};

enum Cover {
    RowsCols { rows: Vec<f32>, cols: Vec<f32>, r: usize, c: usize },
    Full(Vec<f32>),
}

struct TensorState {
    view: ParamView,
    cover: Cover,
}

pub struct Sm3 {
    layers: Vec<Vec<TensorState>>,
    acc: Vec<Vec<f32>>,
    state_bytes: usize,
    grad_bytes: usize,
}

impl Sm3 {
    pub fn new(spec: &ModelSpec, tracker: &MemoryTracker) -> Self {
        let mut state_bytes = 0usize;
        let layers = spec
            .layers
            .iter()
            .map(|l| {
                l.params
                    .iter()
                    .map(|p| {
                        let cover = if p.shape.len() == 2 {
                            let (r, c) = (p.shape[0], p.shape[1]);
                            state_bytes += (r + c) * 4;
                            Cover::RowsCols { rows: vec![0.0; r], cols: vec![0.0; c], r, c }
                        } else {
                            state_bytes += p.elements() * 4;
                            Cover::Full(vec![0.0; p.elements()])
                        };
                        TensorState { view: p.clone(), cover }
                    })
                    .collect()
            })
            .collect();
        let acc: Vec<Vec<f32>> = spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let grad_bytes = spec.total_params() * 4;
        tracker.alloc_raw(Category::OptimizerStates, state_bytes);
        tracker.alloc_raw(Category::Gradients, grad_bytes);
        Self { layers, acc, state_bytes, grad_bytes }
    }
}

impl Optimizer for Sm3 {
    fn kind(&self) -> OptimizerKind {
        OptimizerKind::Sm3
    }

    fn begin_minibatch(&mut self, _t: u64) -> Result<()> {
        for a in &mut self.acc {
            a.fill(0.0);
        }
        Ok(())
    }

    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()> {
        super::host_math::grad_acc(&mut self.acc[layer], grad, gscale);
        Ok(())
    }

    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()> {
        for (l, p) in params.iter_mut().enumerate() {
            for ts in &mut self.layers[l] {
                let g = &self.acc[l][ts.view.range.clone()];
                let dst = &mut p.flat[ts.view.range.clone()];
                match &mut ts.cover {
                    Cover::RowsCols { rows, cols, r, c } => {
                        let (r, c) = (*r, *c);
                        // SM3-II: nu_ij = min(row_i, col_j) + g_ij^2
                        let mut new_rows = vec![0.0f32; r];
                        let mut new_cols = vec![0.0f32; c];
                        for i in 0..r {
                            for j in 0..c {
                                let nu = rows[i].min(cols[j]) + g[i * c + j] * g[i * c + j];
                                dst[i * c + j] -= lr * g[i * c + j] / (nu.sqrt() + 1e-8);
                                new_rows[i] = new_rows[i].max(nu);
                                new_cols[j] = new_cols[j].max(nu);
                            }
                        }
                        rows.copy_from_slice(&new_rows);
                        cols.copy_from_slice(&new_cols);
                    }
                    Cover::Full(v) => {
                        for i in 0..v.len() {
                            v[i] += g[i] * g[i];
                            dst[i] -= lr * g[i] / (v[i].sqrt() + 1e-8);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    fn persistent_grad_bytes(&self) -> usize {
        self.grad_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelConfigEntry, ModelHyper};

    fn toy_spec() -> ModelSpec {
        let entry = ModelConfigEntry {
            model: ModelHyper {
                vocab: 8, hidden: 4, layers: 1, heads: 1, seq: 2, microbatch: 2, ffn: 16,
            },
            param_shapes: vec![
                ("embed.E".into(), vec![8, 4]),
                ("block0.ln1.g".into(), vec![4]),
                ("head.W".into(), vec![4, 8]),
            ],
            artifacts: Default::default(),
        };
        ModelSpec::from_manifest("toy", &entry).unwrap()
    }

    #[test]
    fn cover_state_is_sublinear() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = Sm3::new(&spec, &tracker);
        assert_eq!(opt.state_bytes(), (12 + 12 + 4) * 4);
        assert!(opt.state_bytes() < spec.total_params() * 4);
    }

    #[test]
    fn descends_on_quadratic() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = Sm3::new(&spec, &tracker);
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
        let norm0: f32 = params.iter().flat_map(|p| &p.flat).map(|x| x * x).sum();
        for t in 1..=20 {
            opt.begin_minibatch(t).unwrap();
            let grads: Vec<Vec<f32>> = params.iter().map(|p| p.flat.clone()).collect();
            for (l, g) in grads.iter().enumerate() {
                opt.accumulate(l, g, 1.0).unwrap();
            }
            opt.apply(&mut params, 0.05).unwrap();
        }
        let norm1: f32 = params.iter().flat_map(|p| &p.flat).map(|x| x * x).sum();
        assert!(norm1 < norm0 * 0.8);
    }

    #[test]
    fn cover_upper_bounds_elementwise_adagrad() {
        // SM3 invariant: min(row_i, col_j) >= sum of g^2 seen at (i, j).
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = Sm3::new(&spec, &tracker);
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![0.0; l.flat_len] }).collect();
        let n = spec.layers[0].flat_len;
        let mut sums = vec![0.0f32; n];
        let mut rng = crate::tensor::Rng::new(5);
        for t in 1..=10 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for (s, gi) in sums.iter_mut().zip(&g) {
                *s += gi * gi;
            }
            opt.begin_minibatch(t).unwrap();
            opt.accumulate(0, &g, 1.0).unwrap();
            for l in 1..spec.layers.len() {
                opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
            }
            opt.apply(&mut params, 0.01).unwrap();
        }
        if let Cover::RowsCols { rows, cols, r, c } = &opt.layers[0][0].cover {
            for i in 0..*r {
                for j in 0..*c {
                    let bound = rows[i].min(cols[j]);
                    assert!(
                        bound + 1e-4 >= sums[i * c + j],
                        "cover {bound} < adagrad {}",
                        sums[i * c + j]
                    );
                }
            }
        } else {
            panic!("expected factored cover");
        }
    }
}
