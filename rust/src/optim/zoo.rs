//! The optimizer zoo behind the exec-layer [`OptStep`] seam, plus the
//! SGDM-A §5 extension.
//!
//! One [`ZooOpt`] drives all four `ADAMA_OPT` rules (adam, adafactor,
//! sm3, adam_mini). The mini-batch flow is the paper's Algorithm-1 shape
//! with a **linear** fold: each layer's micro-batch gradient is folded
//! into a state-resident accumulator the moment it exists
//! (`acc += gscale·g` through the chunked `grad_acc` kernel) and the
//! gradient buffer is released; the rule's nonlinear moment math runs
//! once per mini-batch in [`OptStep::apply`]. Because the fold is linear
//! and `gscale = 1/M` is a power of two for M ∈ {1,2,4,8}, an M-way
//! micro-batch split is bit-for-bit identical to a single fold of the
//! summed gradient — the invariant `rust/tests/optzoo.rs` asserts per
//! rule against a serial scalar oracle.
//!
//! Metering is dual, mirroring the paper's Table-2 framing:
//!
//! * built from `cfg.optimizer` (the GA-style comparator baselines) the
//!   accumulator is a persistent *gradient* buffer
//!   (`Category::Gradients`, `persistent_grad_bytes = P·4`) — exactly the
//!   memory the seed-era `AdamGA`/`Adafactor`/`Sm3` structs reported;
//! * built through the `ADAMA_OPT` seam (`state_resident = true`) the
//!   accumulator is optimizer state (`Category::OptimizerStates`,
//!   `persistent_grad_bytes = 0`) — the rule *composed with* the paper's
//!   trick. The update math is identical either way.

use anyhow::Result;

use super::{Hyper, Optimizer, UpdateBackend};
use crate::config::OptimizerKind;
use crate::memory::{Category, MemoryTracker};
use crate::model::ckpt::OptSnapshot;
use crate::model::{LayerParams, ModelSpec, ParamView};
use crate::runtime::{OptAlgo, OptStep};

/// Adafactor's additive regulariser on squared gradients (ε₁ in
/// Shazeer & Stern, Alg. 4).
const EPS1: f32 = 1e-30;

/// (rows, cols) geometry for a tensor; `cols == 0` encodes 1-D.
fn dims(view: &ParamView) -> (usize, usize) {
    if view.shape.len() == 2 {
        (view.shape[0], view.shape[1])
    } else {
        (view.elements(), 0)
    }
}

/// Build the [`OptStep`] rule for `algo`, owning its update backend.
pub fn make_rule(algo: OptAlgo, hyper: Hyper, backend: UpdateBackend) -> Box<dyn OptStep> {
    match algo {
        OptAlgo::Adam => Box::new(AdamRule { backend, hyper }),
        OptAlgo::Adafactor => Box::new(AdafactorRule { backend, hyper }),
        OptAlgo::Sm3 => Box::new(Sm3Rule { backend }),
        OptAlgo::AdamMini => Box::new(AdamMiniRule { backend, hyper }),
    }
}

/// Standard Adam on the accumulated mean gradient: the fused
/// `adam_full` kernel per tensor. Element-wise, so the per-tensor walk
/// is bit-identical to the seed's per-layer flat application.
struct AdamRule {
    backend: UpdateBackend,
    hyper: Hyper,
}

impl OptStep for AdamRule {
    fn algo(&self) -> OptAlgo {
        OptAlgo::Adam
    }

    fn apply(
        &mut self,
        p: &mut [f32],
        acc: &[f32],
        state: &mut [Vec<f32>],
        _rows: usize,
        _cols: usize,
        step: u64,
        lr: f32,
    ) -> Result<()> {
        let (bc1, bc2) = self.hyper.bias_corrections(step);
        let (m, v) = state.split_at_mut(1);
        self.backend.adam_full(p, &mut m[0], &mut v[0], acc, lr, bc1, bc2)
    }
}

/// Adafactor (β₁ = 0 memory-saving config): factored second moments for
/// matrices, full moment for vectors, with the Shazeer-Stern `t^-0.8`
/// decay schedule. The O(r+c) statistic folds are serial; the O(r·c)
/// parameter step runs through the chunked `fac_update` kernel row by
/// row (the row factor is constant across a row).
struct AdafactorRule {
    backend: UpdateBackend,
    hyper: Hyper,
}

impl OptStep for AdafactorRule {
    fn algo(&self) -> OptAlgo {
        OptAlgo::Adafactor
    }

    fn apply(
        &mut self,
        p: &mut [f32],
        acc: &[f32],
        state: &mut [Vec<f32>],
        rows: usize,
        cols: usize,
        step: u64,
        lr: f32,
    ) -> Result<()> {
        let b2 = 1.0 - (step as f32).powf(-0.8).min(1.0 - self.hyper.beta2);
        if cols > 0 {
            let (rv, cv) = state.split_at_mut(1);
            let (rv, cv) = (&mut rv[0], &mut cv[0]);
            for i in 0..rows {
                let mean = (0..cols)
                    .map(|j| acc[i * cols + j] * acc[i * cols + j] + EPS1)
                    .sum::<f32>()
                    / cols as f32;
                rv[i] = b2 * rv[i] + (1.0 - b2) * mean;
            }
            for j in 0..cols {
                let mean = (0..rows)
                    .map(|i| acc[i * cols + j] * acc[i * cols + j] + EPS1)
                    .sum::<f32>()
                    / rows as f32;
                cv[j] = b2 * cv[j] + (1.0 - b2) * mean;
            }
            let row_mean = rv.iter().sum::<f32>().max(EPS1) / rows as f32;
            for i in 0..rows {
                let rfac = rv[i] / row_mean;
                let span = i * cols..(i + 1) * cols;
                self.backend.fac_update(&mut p[span.clone()], &acc[span], cv, lr, rfac)?;
            }
        } else {
            let v = &mut state[0];
            for i in 0..v.len() {
                v[i] = b2 * v[i] + (1.0 - b2) * (acc[i] * acc[i] + EPS1);
            }
            // rfac = 1.0 multiplies exactly: the 1-D step shares the kernel
            self.backend.fac_update(p, acc, v, lr, 1.0)?;
        }
        Ok(())
    }
}

/// SM3-II cover sets: the per-element moment is reconstructed as
/// `min(row_i, col_j) + g²` by the `sm3_update` kernel one row at a time
/// (row accumulator constant per row); the cover maxes fold serially.
/// Vectors fall back to full AdaGrad via `r = +inf`
/// (`min(inf, v) + g² = v + g²`, then the state adopts the fresh `nu`).
struct Sm3Rule {
    backend: UpdateBackend,
}

impl OptStep for Sm3Rule {
    fn algo(&self) -> OptAlgo {
        OptAlgo::Sm3
    }

    fn apply(
        &mut self,
        p: &mut [f32],
        acc: &[f32],
        state: &mut [Vec<f32>],
        rows: usize,
        cols: usize,
        _step: u64,
        lr: f32,
    ) -> Result<()> {
        if cols > 0 {
            let (rv, cv) = state.split_at_mut(1);
            let (rv, cv) = (&mut rv[0], &mut cv[0]);
            let mut new_rows = vec![0.0f32; rows];
            let mut new_cols = vec![0.0f32; cols];
            let mut nu = vec![0.0f32; cols];
            for i in 0..rows {
                let span = i * cols..(i + 1) * cols;
                self.backend.sm3_update(&mut p[span.clone()], &mut nu, &acc[span], cv, lr, rv[i])?;
                for j in 0..cols {
                    new_rows[i] = new_rows[i].max(nu[j]);
                    new_cols[j] = new_cols[j].max(nu[j]);
                }
            }
            rv.copy_from_slice(&new_rows);
            cv.copy_from_slice(&new_cols);
        } else {
            let v = &mut state[0];
            let mut nu = vec![0.0f32; v.len()];
            self.backend.sm3_update(p, &mut nu, acc, v, lr, f32::INFINITY)?;
            v.copy_from_slice(&nu);
        }
        Ok(())
    }
}

/// Adam-mini: full first moment, one shared second-moment scalar per
/// block (per matrix row; one per vector). The momentum fold reuses the
/// `sgdm_decay_acc` kernel (`m = β₁·m + (1-β₁)·g`); the tiny block
/// statistics are serial; the parameter step runs through `mini_update`
/// with the block's precomputed learning-rate scale.
struct AdamMiniRule {
    backend: UpdateBackend,
    hyper: Hyper,
}

impl AdamMiniRule {
    fn block_scale(&self, vb: &mut f32, gsq_mean: f32, bc2: f32, lr: f32) -> f32 {
        let b2 = self.hyper.beta2;
        *vb = b2 * *vb + (1.0 - b2) * gsq_mean;
        lr / ((*vb / bc2).sqrt() + self.hyper.eps)
    }
}

impl OptStep for AdamMiniRule {
    fn algo(&self) -> OptAlgo {
        OptAlgo::AdamMini
    }

    fn apply(
        &mut self,
        p: &mut [f32],
        acc: &[f32],
        state: &mut [Vec<f32>],
        rows: usize,
        cols: usize,
        step: u64,
        lr: f32,
    ) -> Result<()> {
        let b1 = self.hyper.beta1;
        let (m, vb) = state.split_at_mut(1);
        let (m, vb) = (&mut m[0], &mut vb[0]);
        self.backend.sgdm_decay_acc(m, acc, 1.0 - b1, b1)?;
        let (bc1, bc2) = self.hyper.bias_corrections(step);
        if cols > 0 {
            for i in 0..rows {
                let span = i * cols..(i + 1) * cols;
                let gsq = acc[span.clone()].iter().map(|x| x * x).sum::<f32>() / cols as f32;
                let scale = self.block_scale(&mut vb[i], gsq, bc2, lr);
                self.backend.mini_update(&mut p[span.clone()], &m[span], scale, bc1)?;
            }
        } else {
            let gsq = acc.iter().map(|x| x * x).sum::<f32>() / acc.len().max(1) as f32;
            let scale = self.block_scale(&mut vb[0], gsq, bc2, lr);
            self.backend.mini_update(p, m, scale, bc1)?;
        }
        Ok(())
    }
}

/// Per-tensor state buffers for one rule over a whole model — the piece
/// ZeRO-S1 reuses per rank (replicated sublinear statistics, gathered
/// accumulator) independently of [`ZooOpt`]'s gradient-side fold.
pub struct ZooStates {
    rule: Box<dyn OptStep>,
    slots: Vec<Vec<TensorSlot>>,
    state_bytes: usize,
}

struct TensorSlot {
    view: ParamView,
    rows: usize,
    cols: usize,
    bufs: Vec<Vec<f32>>,
}

impl ZooStates {
    pub fn new(
        algo: OptAlgo,
        spec: &ModelSpec,
        hyper: Hyper,
        backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let rule = make_rule(algo, hyper, backend);
        let mut state_bytes = 0usize;
        let slots = spec
            .layers
            .iter()
            .map(|l| {
                l.params
                    .iter()
                    .map(|p| {
                        let (rows, cols) = dims(p);
                        let bufs: Vec<Vec<f32>> = algo
                            .state_lens(rows, cols)
                            .into_iter()
                            .map(|n| {
                                state_bytes += n * 4;
                                vec![0.0; n]
                            })
                            .collect();
                        TensorSlot { view: p.clone(), rows, cols, bufs }
                    })
                    .collect()
            })
            .collect();
        tracker.alloc_raw(Category::OptimizerStates, state_bytes);
        Self { rule, slots, state_bytes }
    }

    pub fn algo(&self) -> OptAlgo {
        self.rule.algo()
    }

    pub fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    /// All state buffers in deterministic (layer, tensor, buffer) order —
    /// the checkpointing seam. The rules themselves are stateless (all
    /// mutable state lives in the slot buffers), so this list plus the
    /// step counter is the complete zoo state.
    pub fn export_bufs(&self) -> Vec<Vec<f32>> {
        self.slots
            .iter()
            .flat_map(|layer| layer.iter().flat_map(|slot| slot.bufs.iter().cloned()))
            .collect()
    }

    /// Restore buffers captured by [`ZooStates::export_bufs`], copying in
    /// place (shape-checked, no re-allocation).
    pub fn import_bufs(&mut self, bufs: &[Vec<f32>]) -> Result<()> {
        let mut it = bufs.iter();
        for (l, layer) in self.slots.iter_mut().enumerate() {
            for slot in layer.iter_mut() {
                for (bi, dst) in slot.bufs.iter_mut().enumerate() {
                    let src = it.next().ok_or_else(|| {
                        anyhow::anyhow!(
                            "zoo snapshot ran out of buffers at layer {l} tensor '{}' buf {bi}",
                            slot.view.name
                        )
                    })?;
                    super::restore_buf(
                        dst,
                        src,
                        &format!("layer {l} tensor '{}' buf {bi}", slot.view.name),
                    )?;
                }
            }
        }
        if it.next().is_some() {
            anyhow::bail!("zoo snapshot has more buffers than the live state");
        }
        Ok(())
    }

    /// Apply the rule to every tensor of `layer` from the layer's
    /// accumulated mean gradient.
    pub fn apply_layer(
        &mut self,
        layer: usize,
        flat: &mut [f32],
        acc: &[f32],
        step: u64,
        lr: f32,
    ) -> Result<()> {
        for slot in &mut self.slots[layer] {
            let range = slot.view.range.clone();
            self.rule.apply(
                &mut flat[range.clone()],
                &acc[range],
                &mut slot.bufs,
                slot.rows,
                slot.cols,
                step,
                lr,
            )?;
        }
        Ok(())
    }
}

/// The zoo optimizer: linear accumulator fold + per-tensor rule apply.
pub struct ZooOpt {
    states: ZooStates,
    acc: Vec<Vec<f32>>,
    fold: UpdateBackend,
    kind: OptimizerKind,
    state_resident: bool,
    acc_bytes: usize,
    t: u64,
}

impl ZooOpt {
    /// `fold` drives the per-micro-batch `grad_acc`; `rule_backend` is
    /// owned by the update rule. `state_resident` picks the metering (see
    /// the module docs); the update math is identical either way.
    pub fn new(
        algo: OptAlgo,
        spec: &ModelSpec,
        hyper: Hyper,
        fold: UpdateBackend,
        rule_backend: UpdateBackend,
        state_resident: bool,
        tracker: &MemoryTracker,
    ) -> Self {
        let states = ZooStates::new(algo, spec, hyper, rule_backend, tracker);
        let acc: Vec<Vec<f32>> = spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let acc_bytes = spec.total_params() * 4;
        let cat = if state_resident { Category::OptimizerStates } else { Category::Gradients };
        tracker.alloc_raw(cat, acc_bytes);
        let kind = match algo {
            OptAlgo::Adam => OptimizerKind::AdamGA,
            OptAlgo::Adafactor => OptimizerKind::Adafactor,
            OptAlgo::Sm3 => OptimizerKind::Sm3,
            OptAlgo::AdamMini => OptimizerKind::AdamMini,
        };
        Self { states, acc, fold, kind, state_resident, acc_bytes, t: 0 }
    }

    pub fn algo(&self) -> OptAlgo {
        self.states.algo()
    }
}

impl Optimizer for ZooOpt {
    fn kind(&self) -> OptimizerKind {
        self.kind
    }

    fn begin_minibatch(&mut self, t: u64) -> Result<()> {
        self.t = t;
        for a in &mut self.acc {
            a.fill(0.0);
        }
        Ok(())
    }

    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()> {
        self.fold.grad_acc(&mut self.acc[layer], grad, gscale)
    }

    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()> {
        for (l, p) in params.iter_mut().enumerate() {
            self.states.apply_layer(l, &mut p.flat, &self.acc[l], self.t, lr)?;
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.states.state_bytes() + if self.state_resident { self.acc_bytes } else { 0 }
    }

    fn persistent_grad_bytes(&self) -> usize {
        if self.state_resident {
            0
        } else {
            self.acc_bytes
        }
    }

    fn grad_acc_mut(&mut self) -> Option<&mut [Vec<f32>]> {
        Some(&mut self.acc)
    }

    fn export_state(&self) -> Result<OptSnapshot> {
        // acc layers first (zeroed at the next begin_minibatch, but kept
        // for completeness), then the rule's slot buffers
        let bufs = self.acc.iter().cloned().chain(self.states.export_bufs()).collect();
        Ok(OptSnapshot { tag: format!("zoo:{}", self.algo().name()), t: self.t, bufs })
    }

    fn import_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        let tag = format!("zoo:{}", self.algo().name());
        if snap.tag != tag {
            anyhow::bail!("ZooOpt({tag}) cannot import a '{}' snapshot", snap.tag);
        }
        let n = self.acc.len();
        if snap.bufs.len() < n {
            anyhow::bail!("zoo snapshot has {} buffers, wanted at least {n}", snap.bufs.len());
        }
        for (l, buf) in snap.bufs[..n].iter().enumerate() {
            super::restore_buf(&mut self.acc[l], buf, &format!("acc[{l}]"))?;
        }
        self.states.import_bufs(&snap.bufs[n..])?;
        self.t = snap.t;
        Ok(())
    }
}

/// SGDM-A — the paper's §5 generalisation: optimizer accumulation applied
/// to heavy-ball momentum SGD.
///
/// Momentum `u` plays the role of (m, v): at mini-batch start it decays
/// once (`u ← μ·u`, fused lazily into the first accumulate), each layer's
/// micro-batch gradient folds in immediately (`u += g/N`) and is released,
/// and the mini-batch update is `θ ← θ − lr·(u + wd·θ)`. State = 1·P
/// floats — even cheaper than AdamA — with the same 1/M gradient peak.
pub struct SgdmA {
    u: Vec<Vec<f32>>,
    momentum: f32,
    weight_decay: f32,
    backend: UpdateBackend,
    decay_pending: Vec<bool>,
    state_bytes: usize,
}

impl SgdmA {
    pub fn new(
        spec: &ModelSpec,
        momentum: f32,
        weight_decay: f32,
        backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let u: Vec<Vec<f32>> = spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let state_bytes = spec.total_params() * 4;
        tracker.alloc_raw(Category::OptimizerStates, state_bytes);
        let decay_pending = vec![false; u.len()];
        Self { u, momentum, weight_decay, backend, decay_pending, state_bytes }
    }
}

impl Optimizer for SgdmA {
    fn kind(&self) -> OptimizerKind {
        OptimizerKind::SgdmA
    }

    fn begin_minibatch(&mut self, _t: u64) -> Result<()> {
        self.decay_pending.iter_mut().for_each(|p| *p = true);
        Ok(())
    }

    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()> {
        if std::mem::take(&mut self.decay_pending[layer]) {
            self.backend.sgdm_decay_acc(&mut self.u[layer], grad, gscale, self.momentum)
        } else {
            self.backend.sgdm_acc(&mut self.u[layer], grad, gscale)
        }
    }

    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()> {
        for (l, p) in params.iter_mut().enumerate() {
            if std::mem::take(&mut self.decay_pending[l]) {
                let zero = vec![0.0f32; self.u[l].len()];
                self.backend.sgdm_decay_acc(&mut self.u[l], &zero, 0.0, self.momentum)?;
            }
            self.backend.sgdm_update(&mut p.flat, &self.u[l], lr, self.weight_decay)?;
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    fn export_state(&self) -> Result<OptSnapshot> {
        Ok(OptSnapshot { tag: "sgdma".into(), t: 0, bufs: self.u.clone() })
    }

    fn import_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        if snap.tag != "sgdma" {
            anyhow::bail!("SgdmA cannot import a '{}' snapshot", snap.tag);
        }
        if snap.bufs.len() != self.u.len() {
            anyhow::bail!(
                "SgdmA snapshot has {} buffers, wanted {}",
                snap.bufs.len(),
                self.u.len()
            );
        }
        for (l, buf) in snap.bufs.iter().enumerate() {
            super::restore_buf(&mut self.u[l], buf, &format!("u[{l}]"))?;
        }
        self.decay_pending.iter_mut().for_each(|p| *p = false);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::host_math;
    use crate::runtime::{ModelConfigEntry, ModelHyper};

    fn toy_spec() -> ModelSpec {
        let entry = ModelConfigEntry {
            model: ModelHyper {
                vocab: 8, hidden: 4, layers: 1, heads: 1, seq: 2, microbatch: 2, ffn: 16,
            },
            param_shapes: vec![
                ("embed.E".into(), vec![8, 4]),
                ("block0.ln1.g".into(), vec![4]),
                ("head.W".into(), vec![4, 8]),
            ],
            artifacts: Default::default(),
        };
        ModelSpec::from_manifest("toy", &entry).unwrap()
    }

    fn hyper() -> Hyper {
        Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    fn host() -> UpdateBackend {
        UpdateBackend::host(hyper())
    }

    fn zoo(algo: OptAlgo, resident: bool, tracker: &MemoryTracker) -> ZooOpt {
        ZooOpt::new(algo, &toy_spec(), hyper(), host(), host(), resident, tracker)
    }

    #[test]
    fn accumulates_scaled_microbatch_grads() {
        let spec = toy_spec();
        let mut opt = zoo(OptAlgo::Adam, false, &MemoryTracker::new());
        opt.begin_minibatch(1).unwrap();
        let n = spec.layers[0].flat_len;
        opt.accumulate(0, &vec![2.0; n], 0.25).unwrap();
        opt.accumulate(0, &vec![4.0; n], 0.25).unwrap();
        assert!(opt.acc[0].iter().all(|&x| (x - 1.5).abs() < 1e-6));
    }

    #[test]
    fn adam_matches_manual_adam_over_minibatch_mean() {
        let spec = toy_spec();
        let mut opt = zoo(OptAlgo::Adam, false, &MemoryTracker::new());
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
        let n_micro = 4;
        let grads: Vec<Vec<f32>> = (0..n_micro)
            .map(|k| (0..spec.layers[0].flat_len).map(|i| (i + k) as f32 * 0.1).collect())
            .collect();

        opt.begin_minibatch(1).unwrap();
        for g in &grads {
            opt.accumulate(0, g, 1.0 / n_micro as f32).unwrap();
        }
        for l in 1..spec.layers.len() {
            opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
        }
        opt.apply(&mut params, 0.01).unwrap();

        // reference: fused Adam on the mean gradient
        let mean: Vec<f32> = (0..spec.layers[0].flat_len)
            .map(|i| grads.iter().map(|g| g[i]).sum::<f32>() / n_micro as f32)
            .collect();
        let mut rp = vec![1.0f32; spec.layers[0].flat_len];
        let mut rm = vec![0.0f32; rp.len()];
        let mut rv = vec![0.0f32; rp.len()];
        let (bc1, bc2) = hyper().bias_corrections(1);
        host_math::adam_full(&mut rp, &mut rm, &mut rv, &mean, 0.01, bc1, bc2, 0.9, 0.999, 1e-8);
        for (a, b) in params[0].flat.iter().zip(&rp) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn ga_metering_holds_full_model_gradient_memory() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = zoo(OptAlgo::Adam, false, &tracker);
        assert_eq!(opt.persistent_grad_bytes(), spec.total_params() * 4);
        assert_eq!(opt.state_bytes(), 2 * spec.total_params() * 4);
        assert_eq!(tracker.live(Category::Gradients), spec.total_params() * 4);
    }

    #[test]
    fn state_resident_metering_moves_acc_into_optimizer_states() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = zoo(OptAlgo::Adam, true, &tracker);
        assert_eq!(opt.persistent_grad_bytes(), 0);
        assert_eq!(opt.state_bytes(), 3 * spec.total_params() * 4);
        assert_eq!(tracker.live(Category::Gradients), 0);
        assert_eq!(tracker.live(Category::OptimizerStates), opt.state_bytes());
    }

    #[test]
    fn factored_state_is_sublinear() {
        let spec = toy_spec();
        for algo in [OptAlgo::Adafactor, OptAlgo::Sm3] {
            let opt = zoo(algo, false, &MemoryTracker::new());
            // matrices factored: (8+4) + (4+8); vector ln1.g full: 4
            assert_eq!(opt.states.state_bytes(), (12 + 12 + 4) * 4, "{algo:?}");
            assert!(opt.states.state_bytes() < spec.total_params() * 4);
            assert_eq!(opt.persistent_grad_bytes(), spec.total_params() * 4);
        }
        // adam-mini: full m + one v per row (one per vector)
        let opt = zoo(OptAlgo::AdamMini, false, &MemoryTracker::new());
        assert_eq!(opt.states.state_bytes(), (spec.total_params() + 8 + 1 + 4) * 4);
    }

    #[test]
    fn every_rule_descends_on_quadratic() {
        // minimize 0.5*||p||^2 (grad = p): loss must shrink for every rule.
        let spec = toy_spec();
        for algo in OptAlgo::ALL {
            let mut opt = zoo(algo, false, &MemoryTracker::new());
            let mut params: Vec<LayerParams> =
                spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
            let norm0: f32 = params.iter().flat_map(|p| &p.flat).map(|x| x * x).sum();
            for t in 1..=20 {
                opt.begin_minibatch(t).unwrap();
                let grads: Vec<Vec<f32>> = params.iter().map(|p| p.flat.clone()).collect();
                for (l, g) in grads.iter().enumerate() {
                    opt.accumulate(l, g, 1.0).unwrap();
                }
                opt.apply(&mut params, 0.05).unwrap();
            }
            let norm1: f32 = params.iter().flat_map(|p| &p.flat).map(|x| x * x).sum();
            assert!(norm1 < norm0 * 0.8, "{algo:?}: {norm1} !< {norm0}");
        }
    }

    #[test]
    fn sm3_cover_upper_bounds_elementwise_adagrad() {
        // SM3 invariant: min(row_i, col_j) >= sum of g^2 seen at (i, j).
        let spec = toy_spec();
        let mut opt = zoo(OptAlgo::Sm3, false, &MemoryTracker::new());
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![0.0; l.flat_len] }).collect();
        let n = spec.layers[0].flat_len;
        let mut sums = vec![0.0f32; n];
        let mut rng = crate::tensor::Rng::new(5);
        for t in 1..=10 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for (s, gi) in sums.iter_mut().zip(&g) {
                *s += gi * gi;
            }
            opt.begin_minibatch(t).unwrap();
            opt.accumulate(0, &g, 1.0).unwrap();
            for l in 1..spec.layers.len() {
                opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
            }
            opt.apply(&mut params, 0.01).unwrap();
        }
        let slot = &opt.states.slots[0][0];
        let (rows, cols) = (&slot.bufs[0], &slot.bufs[1]);
        let c = slot.cols;
        for (i, ri) in rows.iter().enumerate() {
            for (j, cj) in cols.iter().enumerate() {
                let bound = ri.min(*cj);
                assert!(
                    bound + 1e-4 >= sums[i * c + j],
                    "cover {bound} < adagrad {}",
                    sums[i * c + j]
                );
            }
        }
    }

    #[test]
    fn metering_does_not_change_the_math() {
        // GA-baseline vs state-resident builds must walk identical bits.
        let spec = toy_spec();
        for algo in OptAlgo::ALL {
            let mut a = zoo(algo, false, &MemoryTracker::new());
            let mut b = zoo(algo, true, &MemoryTracker::new());
            let mk = || -> Vec<LayerParams> {
                spec.layers.iter().map(|l| LayerParams { flat: vec![0.5; l.flat_len] }).collect()
            };
            let (mut pa, mut pb) = (mk(), mk());
            let mut rng = crate::tensor::Rng::new(9);
            for t in 1..=3 {
                a.begin_minibatch(t).unwrap();
                b.begin_minibatch(t).unwrap();
                for (l, layer) in spec.layers.iter().enumerate() {
                    let g: Vec<f32> = (0..layer.flat_len).map(|_| rng.normal()).collect();
                    a.accumulate(l, &g, 0.5).unwrap();
                    b.accumulate(l, &g, 0.5).unwrap();
                }
                a.apply(&mut pa, 0.01).unwrap();
                b.apply(&mut pb, 0.01).unwrap();
            }
            for (x, y) in pa.iter().zip(&pb) {
                assert_eq!(x.flat, y.flat, "{algo:?}");
            }
        }
    }

    // ---- SGDM-A (ported with the struct from the seed module) ----

    #[test]
    fn sgdma_matches_manual_heavy_ball_over_minibatch() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = SgdmA::new(&spec, 0.9, 0.0, host(), &tracker);
        let n = spec.layers[0].flat_len;
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();

        let mut u_ref = vec![0.0f32; n];
        let mut p_ref = vec![1.0f32; n];
        for step in 1..=3u64 {
            let grads: Vec<Vec<f32>> =
                (0..4).map(|k| (0..n).map(|i| (i + k + step as usize) as f32 * 0.1).collect())
                    .collect();
            opt.begin_minibatch(step).unwrap();
            for g in &grads {
                opt.accumulate(0, g, 0.25).unwrap();
            }
            for l in 1..spec.layers.len() {
                opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
            }
            opt.apply(&mut params, 0.1).unwrap();

            // reference heavy-ball: u = mu*u + mean(g); p -= lr*u
            for i in 0..n {
                let mean: f32 = grads.iter().map(|g| g[i]).sum::<f32>() / 4.0;
                u_ref[i] = 0.9 * u_ref[i] + mean;
                p_ref[i] -= 0.1 * u_ref[i];
            }
        }
        for (a, b) in params[0].flat.iter().zip(&p_ref) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn sgdma_weight_decay_shrinks_params() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = SgdmA::new(&spec, 0.0, 0.1, host(), &tracker);
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
        opt.begin_minibatch(1).unwrap();
        for l in 0..spec.layers.len() {
            opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
        }
        opt.apply(&mut params, 0.5).unwrap();
        // p = 1 - 0.5*(0 + 0.1*1) = 0.95
        assert!(params[0].flat.iter().all(|&x| (x - 0.95).abs() < 1e-6));
    }

    #[test]
    fn sgdma_state_is_one_p() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = SgdmA::new(&spec, 0.9, 0.0, host(), &tracker);
        assert_eq!(opt.state_bytes(), spec.total_params() * 4);
        assert_eq!(opt.persistent_grad_bytes(), 0);
    }
}
