//! SGDM-A — the paper's §5 generalisation: optimizer accumulation applied
//! to heavy-ball momentum SGD.
//!
//! Momentum `u` plays the role of (m, v): at mini-batch start it decays
//! once (`u ← μ·u`, fused lazily into the first accumulate), each layer's
//! micro-batch gradient folds in immediately (`u += g/N`) and is released,
//! and the mini-batch update is `θ ← θ − lr·(u + wd·θ)`. State = 1·P
//! floats — even cheaper than AdamA — with the same 1/M gradient peak.

use anyhow::Result;

use super::{Optimizer, UpdateBackend};
use crate::config::OptimizerKind;
use crate::memory::{Category, MemoryTracker};
use crate::model::{LayerParams, ModelSpec};

pub struct SgdmA {
    u: Vec<Vec<f32>>,
    momentum: f32,
    weight_decay: f32,
    backend: UpdateBackend,
    decay_pending: Vec<bool>,
    state_bytes: usize,
}

impl SgdmA {
    pub fn new(
        spec: &ModelSpec,
        momentum: f32,
        weight_decay: f32,
        backend: UpdateBackend,
        tracker: &MemoryTracker,
    ) -> Self {
        let u: Vec<Vec<f32>> = spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let state_bytes = spec.total_params() * 4;
        tracker.alloc_raw(Category::OptimizerStates, state_bytes);
        let decay_pending = vec![false; u.len()];
        Self { u, momentum, weight_decay, backend, decay_pending, state_bytes }
    }
}

impl Optimizer for SgdmA {
    fn kind(&self) -> OptimizerKind {
        OptimizerKind::SgdmA
    }

    fn begin_minibatch(&mut self, _t: u64) -> Result<()> {
        self.decay_pending.iter_mut().for_each(|p| *p = true);
        Ok(())
    }

    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()> {
        if std::mem::take(&mut self.decay_pending[layer]) {
            self.backend.sgdm_decay_acc(&mut self.u[layer], grad, gscale, self.momentum)
        } else {
            self.backend.sgdm_acc(&mut self.u[layer], grad, gscale)
        }
    }

    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()> {
        for (l, p) in params.iter_mut().enumerate() {
            if std::mem::take(&mut self.decay_pending[l]) {
                let zero = vec![0.0f32; self.u[l].len()];
                self.backend.sgdm_decay_acc(&mut self.u[l], &zero, 0.0, self.momentum)?;
            }
            self.backend.sgdm_update(&mut p.flat, &self.u[l], lr, self.weight_decay)?;
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.state_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Hyper;
    use crate::runtime::{ModelConfigEntry, ModelHyper};

    fn toy_spec() -> ModelSpec {
        let entry = ModelConfigEntry {
            model: ModelHyper {
                vocab: 8, hidden: 4, layers: 1, heads: 1, seq: 2, microbatch: 2, ffn: 16,
            },
            param_shapes: vec![
                ("embed.E".into(), vec![8, 4]),
                ("block0.ln1.g".into(), vec![4]),
                ("head.W".into(), vec![4, 8]),
            ],
            artifacts: Default::default(),
        };
        ModelSpec::from_manifest("toy", &entry).unwrap()
    }

    fn host() -> UpdateBackend {
        UpdateBackend::host(Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 })
    }

    #[test]
    fn matches_manual_heavy_ball_over_minibatch() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = SgdmA::new(&spec, 0.9, 0.0, host(), &tracker);
        let n = spec.layers[0].flat_len;
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();

        let mut u_ref = vec![0.0f32; n];
        let mut p_ref = vec![1.0f32; n];
        for step in 1..=3u64 {
            let grads: Vec<Vec<f32>> =
                (0..4).map(|k| (0..n).map(|i| (i + k + step as usize) as f32 * 0.1).collect())
                    .collect();
            opt.begin_minibatch(step).unwrap();
            for g in &grads {
                opt.accumulate(0, g, 0.25).unwrap();
            }
            for l in 1..spec.layers.len() {
                opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
            }
            opt.apply(&mut params, 0.1).unwrap();

            // reference heavy-ball: u = mu*u + mean(g); p -= lr*u
            for i in 0..n {
                let mean: f32 = grads.iter().map(|g| g[i]).sum::<f32>() / 4.0;
                u_ref[i] = 0.9 * u_ref[i] + mean;
                p_ref[i] -= 0.1 * u_ref[i];
            }
        }
        for (a, b) in params[0].flat.iter().zip(&p_ref) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let mut opt = SgdmA::new(&spec, 0.0, 0.1, host(), &tracker);
        let mut params: Vec<LayerParams> =
            spec.layers.iter().map(|l| LayerParams { flat: vec![1.0; l.flat_len] }).collect();
        opt.begin_minibatch(1).unwrap();
        for l in 0..spec.layers.len() {
            opt.accumulate(l, &vec![0.0; spec.layers[l].flat_len], 1.0).unwrap();
        }
        opt.apply(&mut params, 0.5).unwrap();
        // p = 1 - 0.5*(0 + 0.1*1) = 0.95
        assert!(params[0].flat.iter().all(|&x| (x - 0.95).abs() < 1e-6));
    }

    #[test]
    fn state_is_one_p() {
        let spec = toy_spec();
        let tracker = MemoryTracker::new();
        let opt = SgdmA::new(&spec, 0.9, 0.0, host(), &tracker);
        assert_eq!(opt.state_bytes(), spec.total_params() * 4);
        assert_eq!(opt.persistent_grad_bytes(), 0);
    }
}
