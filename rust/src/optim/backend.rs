//! Update arithmetic backends: chunked kernel programs (host or PJRT,
//! dispatched through [`Program`]) or direct host loops.
//!
//! The kernel backend buckets a layer's flat buffer into fixed-size chunks
//! (tail zero-padded into reusable scratch), mirroring fused-Adam-over-
//! flat-buffer designs. Padding is safe by construction: zero (m, v, g)
//! chunks stay zero through every kernel, and `adam_update` on zero state
//! leaves parameters untouched (0/(sqrt(0)+eps) = 0).
//!
//! `host_math` — the scalar reference kernels — now lives with the host
//! executor (`runtime::hostexec::kernels`) and is re-exported here, so on
//! the host backend the kernel-dispatch path and the direct-loop path are
//! bit-for-bit identical.

use std::sync::Arc;

use anyhow::Result;

/// Pure-rust reference kernel math (ablation baseline; also used by the
/// comparator optimizers, collectives and tests).
pub use crate::runtime::hostexec::kernels as host_math;

use super::Hyper;
use crate::runtime::{lit_f32, Arg, Library, Program, Value};
use crate::tensor::chunk_ranges;

/// Dispatcher between the chunked kernel-program path and host math.
pub enum UpdateBackend {
    Kernel(ChunkRunner),
    Host(Hyper),
}

impl UpdateBackend {
    pub fn kernel(lib: Arc<Library>, chunk: usize) -> Result<Self> {
        Ok(Self::Kernel(ChunkRunner::new(lib, chunk)?))
    }

    pub fn host(hyper: Hyper) -> Self {
        Self::Host(hyper)
    }

    pub fn adama_acc(&mut self, m: &mut [f32], v: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adama_acc(m, v, g, gscale),
            Self::Host(h) => {
                host_math::adama_acc(m, v, g, gscale, h.beta1, h.beta2);
                Ok(())
            }
        }
    }

    /// Fused decay + accumulate (first micro-batch of a mini-batch) —
    /// one HBM round-trip instead of two (perf pass, EXPERIMENTS.md §Perf).
    #[allow(clippy::too_many_arguments)]
    pub fn adama_decay_acc(
        &mut self,
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        gscale: f32,
        ms: f32,
        vs: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adama_decay_acc(m, v, g, gscale, ms, vs),
            Self::Host(h) => {
                host_math::adama_decay_acc(m, v, g, gscale, ms, vs, h.beta1, h.beta2);
                Ok(())
            }
        }
    }

    pub fn adama_decay(&mut self, m: &mut [f32], v: &mut [f32], ms: f32, vs: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adama_decay(m, v, ms, vs),
            Self::Host(_) => {
                host_math::scale(m, ms);
                host_math::scale(v, vs);
                Ok(())
            }
        }
    }

    pub fn adam_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adam_update(p, m, v, lr, bc1, bc2),
            Self::Host(h) => {
                host_math::adam_update(p, m, v, lr, bc1, bc2, h.eps);
                Ok(())
            }
        }
    }

    pub fn adam_full(
        &mut self,
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adam_full(p, m, v, g, lr, bc1, bc2),
            Self::Host(h) => {
                host_math::adam_full(p, m, v, g, lr, bc1, bc2, h.beta1, h.beta2, h.eps);
                Ok(())
            }
        }
    }

    pub fn grad_acc(&mut self, acc: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.grad_acc(acc, g, gscale),
            Self::Host(_) => {
                host_math::grad_acc(acc, g, gscale);
                Ok(())
            }
        }
    }

    /// AdamW parameter step (decoupled weight decay) — §5 extension.
    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        wd: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adamw_update(p, m, v, lr, bc1, bc2, wd),
            Self::Host(h) => {
                host_math::adamw_update(p, m, v, lr, bc1, bc2, wd, h.eps);
                Ok(())
            }
        }
    }

    pub fn sgdm_decay_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32, mu: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.sgdm_decay_acc(u, g, gscale, mu),
            Self::Host(_) => {
                host_math::sgdm_decay_acc(u, g, gscale, mu);
                Ok(())
            }
        }
    }

    pub fn sgdm_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.sgdm_acc(u, g, gscale),
            Self::Host(_) => {
                host_math::sgdm_acc(u, g, gscale);
                Ok(())
            }
        }
    }

    pub fn sgdm_update(&mut self, p: &mut [f32], u: &[f32], lr: f32, wd: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.sgdm_update(p, u, lr, wd),
            Self::Host(_) => {
                host_math::sgdm_update(p, u, lr, wd);
                Ok(())
            }
        }
    }

    // ---- optimizer zoo (ADAMA_OPT) ----

    /// Adafactor parameter step over one row (or a 1-D tensor with
    /// `rfac = 1.0`): `p -= lr·g/(√(rfac·c)+eps)`.
    pub fn fac_update(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        c: &[f32],
        lr: f32,
        rfac: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.fac_update(p, g, c, lr, rfac),
            Self::Host(h) => {
                host_math::fac_update(p, g, c, lr, rfac, h.eps);
                Ok(())
            }
        }
    }

    /// SM3 covered-moment step over one row (or a 1-D tensor with
    /// `r = +inf`): `nu = min(r, c) + g²; p -= lr·g/(√nu+eps)`.
    #[allow(clippy::too_many_arguments)]
    pub fn sm3_update(
        &mut self,
        p: &mut [f32],
        nu: &mut [f32],
        g: &[f32],
        c: &[f32],
        lr: f32,
        r: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(k) => k.sm3_update(p, nu, g, c, lr, r),
            Self::Host(h) => {
                host_math::sm3_update(p, nu, g, c, lr, r, h.eps);
                Ok(())
            }
        }
    }

    /// Adam-mini parameter step over one block with a shared learning
    /// rate: `p -= scale·(m/bc1)`.
    pub fn mini_update(&mut self, p: &mut [f32], m: &[f32], scale: f32, bc1: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.mini_update(p, m, scale, bc1),
            Self::Host(_) => {
                host_math::mini_update(p, m, scale, bc1);
                Ok(())
            }
        }
    }
}

/// Chunked execution of the `common/*` optimizer kernel programs (backend
/// neutral — the same code drives PJRT artifacts and host kernels).
pub struct ChunkRunner {
    chunk: usize,
    acc: Arc<dyn Program>,
    decay_acc: Arc<dyn Program>,
    decay: Arc<dyn Program>,
    update: Arc<dyn Program>,
    full: Arc<dyn Program>,
    gacc: Arc<dyn Program>,
    adamw: Arc<dyn Program>,
    sgdm_dacc: Arc<dyn Program>,
    sgdm_acc_prog: Arc<dyn Program>,
    sgdm_upd: Arc<dyn Program>,
    fac_upd: Arc<dyn Program>,
    sm3_upd: Arc<dyn Program>,
    mini_upd: Arc<dyn Program>,
    // reusable zero-padded scratch (one per operand slot)
    scratch: Vec<Vec<f32>>,
}

impl ChunkRunner {
    pub fn new(lib: Arc<Library>, chunk: usize) -> Result<Self> {
        anyhow::ensure!(
            lib.manifest().chunk_sizes.contains(&chunk),
            "chunk {} not in kernel set {:?}",
            chunk,
            lib.manifest().chunk_sizes
        );
        Ok(Self {
            acc: lib.get(&format!("common/adama_acc_{chunk}"))?,
            decay_acc: lib.get(&format!("common/adama_decay_acc_{chunk}"))?,
            decay: lib.get(&format!("common/adama_decay_{chunk}"))?,
            update: lib.get(&format!("common/adam_update_{chunk}"))?,
            full: lib.get(&format!("common/adam_full_{chunk}"))?,
            gacc: lib.get(&format!("common/grad_acc_{chunk}"))?,
            adamw: lib.get(&format!("common/adamw_update_{chunk}"))?,
            sgdm_dacc: lib.get(&format!("common/sgdm_decay_acc_{chunk}"))?,
            sgdm_acc_prog: lib.get(&format!("common/sgdm_acc_{chunk}"))?,
            sgdm_upd: lib.get(&format!("common/sgdm_update_{chunk}"))?,
            fac_upd: lib.get(&format!("common/fac_update_{chunk}"))?,
            sm3_upd: lib.get(&format!("common/sm3_update_{chunk}"))?,
            mini_upd: lib.get(&format!("common/mini_update_{chunk}"))?,
            scratch: vec![vec![0.0; chunk]; 4],
            chunk,
        })
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Value for `src[off..off+len]`: full chunks are created straight
    /// from the source slice (one memcpy); only the tail chunk goes
    /// through a zero-padded scratch buffer.
    fn chunk_value(&mut self, slot: usize, src: &[f32], off: usize, len: usize) -> Result<Value> {
        if len == self.chunk {
            return lit_f32(&src[off..off + len], &[self.chunk]);
        }
        let buf = &mut self.scratch[slot];
        buf[..len].copy_from_slice(&src[off..off + len]);
        buf[len..].fill(0.0);
        lit_f32(buf, &[self.chunk])
    }

    /// Fused decay+accumulate chunk sweep (slice->backend fast path).
    pub fn adama_decay_acc(
        &mut self,
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        gscale: f32,
        ms: f32,
        vs: f32,
    ) -> Result<()> {
        let chunk = self.chunk;
        let shape = [chunk];
        let sc = [gscale, ms, vs];
        for (off, len) in chunk_ranges(m.len(), chunk) {
            if len < chunk {
                stage(&mut self.scratch[0], &m[off..off + len]);
                stage(&mut self.scratch[1], &v[off..off + len]);
                stage(&mut self.scratch[2], &g[off..off + len]);
            }
            let (a0, a1, a2) = if len == chunk {
                (&m[off..off + len], &v[off..off + len], &g[off..off + len])
            } else {
                (&self.scratch[0][..], &self.scratch[1][..], &self.scratch[2][..])
            };
            let out = self.decay_acc.run(&[
                Arg::F32(a0, &shape),
                Arg::F32(a1, &shape),
                Arg::F32(a2, &shape),
                Arg::F32(&sc, &[3]),
            ])?;
            crate::runtime::copy_chunk(&out[0], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn adama_acc(&mut self, m: &mut [f32], v: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        debug_assert_eq!(m.len(), v.len());
        debug_assert_eq!(m.len(), g.len());
        let chunk = self.chunk;
        let shape = [chunk];
        let sc = [gscale];
        for (off, len) in chunk_ranges(m.len(), chunk) {
            // stage tails first (mutable), then borrow immutably for args
            if len < chunk {
                stage(&mut self.scratch[0], &m[off..off + len]);
                stage(&mut self.scratch[1], &v[off..off + len]);
                stage(&mut self.scratch[2], &g[off..off + len]);
            }
            let (a0, a1, a2) = if len == chunk {
                (&m[off..off + len], &v[off..off + len], &g[off..off + len])
            } else {
                (&self.scratch[0][..], &self.scratch[1][..], &self.scratch[2][..])
            };
            let out = self.acc.run(&[
                Arg::F32(a0, &shape),
                Arg::F32(a1, &shape),
                Arg::F32(a2, &shape),
                Arg::F32(&sc, &[1]),
            ])?;
            crate::runtime::copy_chunk(&out[0], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn adama_decay(&mut self, m: &mut [f32], v: &mut [f32], ms: f32, vs: f32) -> Result<()> {
        for (off, len) in chunk_ranges(m.len(), self.chunk) {
            let args = [
                self.chunk_value(0, m, off, len)?,
                self.chunk_value(1, v, off, len)?,
                lit_f32(&[ms], &[1])?,
                lit_f32(&[vs], &[1])?,
            ];
            let out = self.decay.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn adam_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        let chunk = self.chunk;
        let shape = [chunk];
        let sc = [lr, bc1, bc2];
        for (off, len) in chunk_ranges(p.len(), chunk) {
            if len < chunk {
                stage(&mut self.scratch[0], &p[off..off + len]);
                stage(&mut self.scratch[1], &m[off..off + len]);
                stage(&mut self.scratch[2], &v[off..off + len]);
            }
            let (a0, a1, a2) = if len == chunk {
                (&p[off..off + len], &m[off..off + len], &v[off..off + len])
            } else {
                (&self.scratch[0][..], &self.scratch[1][..], &self.scratch[2][..])
            };
            let out = self.update.run(&[
                Arg::F32(a0, &shape),
                Arg::F32(a1, &shape),
                Arg::F32(a2, &shape),
                Arg::F32(&sc, &[3]),
            ])?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    pub fn adam_full(
        &mut self,
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_value(0, p, off, len)?,
                self.chunk_value(1, m, off, len)?,
                self.chunk_value(2, v, off, len)?,
                self.chunk_value(3, g, off, len)?,
                lit_f32(&[lr, bc1, bc2], &[3])?,
            ];
            let out = self.full.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[2], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn grad_acc(&mut self, acc: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        for (off, len) in chunk_ranges(acc.len(), self.chunk) {
            let args = [
                self.chunk_value(0, acc, off, len)?,
                self.chunk_value(1, g, off, len)?,
                lit_f32(&[gscale], &[1])?,
            ];
            let out = self.gacc.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut acc[off..off + len])?;
        }
        Ok(())
    }

    // ---- §5 extensions ----

    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        wd: f32,
    ) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_value(0, p, off, len)?,
                self.chunk_value(1, m, off, len)?,
                self.chunk_value(2, v, off, len)?,
                lit_f32(&[lr, bc1, bc2, wd], &[4])?,
            ];
            let out = self.adamw.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }

    pub fn sgdm_decay_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32, mu: f32) -> Result<()> {
        for (off, len) in chunk_ranges(u.len(), self.chunk) {
            let args = [
                self.chunk_value(0, u, off, len)?,
                self.chunk_value(1, g, off, len)?,
                lit_f32(&[gscale, mu], &[2])?,
            ];
            let out = self.sgdm_dacc.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut u[off..off + len])?;
        }
        Ok(())
    }

    pub fn sgdm_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        for (off, len) in chunk_ranges(u.len(), self.chunk) {
            let args = [
                self.chunk_value(0, u, off, len)?,
                self.chunk_value(1, g, off, len)?,
                lit_f32(&[gscale], &[1])?,
            ];
            let out = self.sgdm_acc_prog.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut u[off..off + len])?;
        }
        Ok(())
    }

    pub fn sgdm_update(&mut self, p: &mut [f32], u: &[f32], lr: f32, wd: f32) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_value(0, p, off, len)?,
                self.chunk_value(1, u, off, len)?,
                lit_f32(&[lr, wd], &[2])?,
            ];
            let out = self.sgdm_upd.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }

    // ---- optimizer zoo (ADAMA_OPT) ----
    // Rows chunk exactly like flat buffers: the per-row scalars (rfac, r,
    // scale) are constant across the row, so any chunk split is safe, and
    // zero-padded tails map to zero outputs in every zoo kernel.

    pub fn fac_update(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        c: &[f32],
        lr: f32,
        rfac: f32,
    ) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_value(0, p, off, len)?,
                self.chunk_value(1, g, off, len)?,
                self.chunk_value(2, c, off, len)?,
                lit_f32(&[lr, rfac], &[2])?,
            ];
            let out = self.fac_upd.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }

    pub fn sm3_update(
        &mut self,
        p: &mut [f32],
        nu: &mut [f32],
        g: &[f32],
        c: &[f32],
        lr: f32,
        r: f32,
    ) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_value(0, p, off, len)?,
                self.chunk_value(1, g, off, len)?,
                self.chunk_value(2, c, off, len)?,
                lit_f32(&[lr, r], &[2])?,
            ];
            let out = self.sm3_upd.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut nu[off..off + len])?;
        }
        Ok(())
    }

    pub fn mini_update(&mut self, p: &mut [f32], m: &[f32], scale: f32, bc1: f32) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_value(0, p, off, len)?,
                self.chunk_value(1, m, off, len)?,
                lit_f32(&[scale, bc1], &[2])?,
            ];
            let out = self.mini_upd.run_v(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }
}

/// Zero-pad-stage a tail slice into a scratch chunk buffer.
fn stage(buf: &mut [f32], src: &[f32]) {
    buf[..src.len()].copy_from_slice(src);
    buf[src.len()..].fill(0.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_runner_matches_host_loops_including_tails() {
        // buffer length deliberately NOT a multiple of the chunk so the
        // zero-padded tail path is exercised
        let lib = Library::host();
        let chunk = *lib.manifest().chunk_sizes.first().unwrap();
        let (b1, b2) =
            (lib.manifest().hyper.beta1 as f32, lib.manifest().hyper.beta2 as f32);
        let n = chunk + chunk / 2 + 7;
        let mut runner = ChunkRunner::new(lib, chunk).unwrap();

        let mut rng = crate::tensor::Rng::new(3);
        let m0: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let v0: Vec<f32> = (0..n).map(|_| rng.normal().abs()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

        let (mut mk, mut vk) = (m0.clone(), v0.clone());
        runner.adama_acc(&mut mk, &mut vk, &g, 0.25).unwrap();

        let (mut mh, mut vh) = (m0, v0);
        host_math::adama_acc(&mut mh, &mut vh, &g, 0.25, b1, b2);

        assert_eq!(mk, mh, "kernel path must be bit-identical to host math");
        assert_eq!(vk, vh);
    }

    #[test]
    fn rejects_unknown_chunk_size() {
        let lib = Library::host();
        assert!(ChunkRunner::new(lib, 12345).is_err());
    }

    #[test]
    fn zoo_runner_matches_host_loops_including_tails() {
        let lib = Library::host();
        let chunk = *lib.manifest().chunk_sizes.first().unwrap();
        let eps = lib.manifest().hyper.eps as f32;
        let n = chunk + chunk / 2 + 7;
        let mut runner = ChunkRunner::new(lib, chunk).unwrap();

        let mut rng = crate::tensor::Rng::new(11);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..n).map(|_| rng.normal().abs()).collect();
        let m: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

        let mut pk = p0.clone();
        runner.fac_update(&mut pk, &g, &c, 1e-2, 1.25).unwrap();
        let mut ph = p0.clone();
        host_math::fac_update(&mut ph, &g, &c, 1e-2, 1.25, eps);
        assert_eq!(pk, ph, "fac_update kernel path must match host math bitwise");

        let (mut pk, mut nuk) = (p0.clone(), vec![0.0f32; n]);
        runner.sm3_update(&mut pk, &mut nuk, &g, &c, 1e-2, 0.75).unwrap();
        let (mut ph, mut nuh) = (p0.clone(), vec![0.0f32; n]);
        host_math::sm3_update(&mut ph, &mut nuh, &g, &c, 1e-2, 0.75, eps);
        assert_eq!(pk, ph, "sm3_update kernel path must match host math bitwise");
        assert_eq!(nuk, nuh);

        let mut pk = p0.clone();
        runner.mini_update(&mut pk, &m, 3e-3, 0.1).unwrap();
        let mut ph = p0;
        host_math::mini_update(&mut ph, &m, 3e-3, 0.1);
        assert_eq!(pk, ph, "mini_update kernel path must match host math bitwise");
    }
}
