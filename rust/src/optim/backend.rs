//! Update arithmetic backends: AOT Pallas kernels (via PJRT) or host loops.
//!
//! The kernel backend buckets a layer's flat buffer into fixed-size chunks
//! (tail zero-padded into reusable scratch), mirroring fused-Adam-over-
//! flat-buffer designs. Padding is safe by construction: zero (m, v, g)
//! chunks stay zero through every kernel, and `adam_update` on zero state
//! leaves parameters untouched (0/(sqrt(0)+eps) = 0).

use std::sync::Arc;

use anyhow::Result;

use super::Hyper;
use crate::runtime::{lit_f32, Arg, ArtifactLibrary, Executable};
use crate::tensor::chunk_ranges;

/// Dispatcher between the PJRT kernel path and host math.
pub enum UpdateBackend {
    Kernel(ChunkRunner),
    Host(Hyper),
}

impl UpdateBackend {
    pub fn kernel(lib: Arc<ArtifactLibrary>, chunk: usize) -> Result<Self> {
        Ok(Self::Kernel(ChunkRunner::new(lib, chunk)?))
    }

    pub fn host(hyper: Hyper) -> Self {
        Self::Host(hyper)
    }

    pub fn adama_acc(&mut self, m: &mut [f32], v: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adama_acc(m, v, g, gscale),
            Self::Host(h) => {
                host_math::adama_acc(m, v, g, gscale, h.beta1, h.beta2);
                Ok(())
            }
        }
    }

    /// Fused decay + accumulate (first micro-batch of a mini-batch) —
    /// one HBM round-trip instead of two (perf pass, EXPERIMENTS.md §Perf).
    #[allow(clippy::too_many_arguments)]
    pub fn adama_decay_acc(
        &mut self,
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        gscale: f32,
        ms: f32,
        vs: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adama_decay_acc(m, v, g, gscale, ms, vs),
            Self::Host(h) => {
                host_math::adama_decay_acc(m, v, g, gscale, ms, vs, h.beta1, h.beta2);
                Ok(())
            }
        }
    }

    pub fn adama_decay(&mut self, m: &mut [f32], v: &mut [f32], ms: f32, vs: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adama_decay(m, v, ms, vs),
            Self::Host(_) => {
                host_math::scale(m, ms);
                host_math::scale(v, vs);
                Ok(())
            }
        }
    }

    pub fn adam_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adam_update(p, m, v, lr, bc1, bc2),
            Self::Host(h) => {
                host_math::adam_update(p, m, v, lr, bc1, bc2, h.eps);
                Ok(())
            }
        }
    }

    pub fn adam_full(
        &mut self,
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adam_full(p, m, v, g, lr, bc1, bc2),
            Self::Host(h) => {
                host_math::adam_full(p, m, v, g, lr, bc1, bc2, h.beta1, h.beta2, h.eps);
                Ok(())
            }
        }
    }

    pub fn grad_acc(&mut self, acc: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.grad_acc(acc, g, gscale),
            Self::Host(_) => {
                host_math::grad_acc(acc, g, gscale);
                Ok(())
            }
        }
    }

    /// AdamW parameter step (decoupled weight decay) — §5 extension.
    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        wd: f32,
    ) -> Result<()> {
        match self {
            Self::Kernel(r) => r.adamw_update(p, m, v, lr, bc1, bc2, wd),
            Self::Host(h) => {
                host_math::adamw_update(p, m, v, lr, bc1, bc2, wd, h.eps);
                Ok(())
            }
        }
    }

    pub fn sgdm_decay_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32, mu: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.sgdm_decay_acc(u, g, gscale, mu),
            Self::Host(_) => {
                host_math::sgdm_decay_acc(u, g, gscale, mu);
                Ok(())
            }
        }
    }

    pub fn sgdm_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.sgdm_acc(u, g, gscale),
            Self::Host(_) => {
                host_math::sgdm_acc(u, g, gscale);
                Ok(())
            }
        }
    }

    pub fn sgdm_update(&mut self, p: &mut [f32], u: &[f32], lr: f32, wd: f32) -> Result<()> {
        match self {
            Self::Kernel(r) => r.sgdm_update(p, u, lr, wd),
            Self::Host(_) => {
                host_math::sgdm_update(p, u, lr, wd);
                Ok(())
            }
        }
    }
}

/// Chunked execution of the `common/*` optimizer artifacts.
pub struct ChunkRunner {
    chunk: usize,
    acc: Arc<Executable>,
    decay_acc: Arc<Executable>,
    decay: Arc<Executable>,
    update: Arc<Executable>,
    full: Arc<Executable>,
    gacc: Arc<Executable>,
    adamw: Arc<Executable>,
    sgdm_dacc: Arc<Executable>,
    sgdm_acc_exe: Arc<Executable>,
    sgdm_upd: Arc<Executable>,
    // reusable zero-padded scratch (one per operand slot)
    scratch: Vec<Vec<f32>>,
}

impl ChunkRunner {
    pub fn new(lib: Arc<ArtifactLibrary>, chunk: usize) -> Result<Self> {
        anyhow::ensure!(
            lib.manifest().chunk_sizes.contains(&chunk),
            "chunk {} not in AOT set {:?}",
            chunk,
            lib.manifest().chunk_sizes
        );
        Ok(Self {
            acc: lib.get(&format!("common/adama_acc_{chunk}"))?,
            decay_acc: lib.get(&format!("common/adama_decay_acc_{chunk}"))?,
            decay: lib.get(&format!("common/adama_decay_{chunk}"))?,
            update: lib.get(&format!("common/adam_update_{chunk}"))?,
            full: lib.get(&format!("common/adam_full_{chunk}"))?,
            gacc: lib.get(&format!("common/grad_acc_{chunk}"))?,
            adamw: lib.get(&format!("common/adamw_update_{chunk}"))?,
            sgdm_dacc: lib.get(&format!("common/sgdm_decay_acc_{chunk}"))?,
            sgdm_acc_exe: lib.get(&format!("common/sgdm_acc_{chunk}"))?,
            sgdm_upd: lib.get(&format!("common/sgdm_update_{chunk}"))?,
            scratch: vec![vec![0.0; chunk]; 4],
            chunk,
        })
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Literal for `src[off..off+len]`: full chunks are created straight
    /// from the source slice (one memcpy into XLA storage, no staging);
    /// only the tail chunk goes through a zero-padded scratch buffer.
    fn chunk_lit(&mut self, slot: usize, src: &[f32], off: usize, len: usize) -> Result<xla::Literal> {
        if len == self.chunk {
            return lit_f32(&src[off..off + len], &[self.chunk]);
        }
        let buf = &mut self.scratch[slot];
        buf[..len].copy_from_slice(&src[off..off + len]);
        buf[len..].fill(0.0);
        lit_f32(buf, &[self.chunk])
    }

    /// Fused decay+accumulate chunk sweep (slice->buffer fast path).
    pub fn adama_decay_acc(
        &mut self,
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        gscale: f32,
        ms: f32,
        vs: f32,
    ) -> Result<()> {
        let chunk = self.chunk;
        let shape = [chunk];
        let sc = [gscale, ms, vs];
        for (off, len) in chunk_ranges(m.len(), chunk) {
            if len < chunk {
                stage(&mut self.scratch[0], &m[off..off + len]);
                stage(&mut self.scratch[1], &v[off..off + len]);
                stage(&mut self.scratch[2], &g[off..off + len]);
            }
            let (a0, a1, a2) = if len == chunk {
                (&m[off..off + len], &v[off..off + len], &g[off..off + len])
            } else {
                (&self.scratch[0][..], &self.scratch[1][..], &self.scratch[2][..])
            };
            let out = self.decay_acc.run_args(&[
                Arg::F32(a0, &shape),
                Arg::F32(a1, &shape),
                Arg::F32(a2, &shape),
                Arg::F32(&sc, &[3]),
            ])?;
            crate::runtime::copy_chunk(&out[0], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn adama_acc(&mut self, m: &mut [f32], v: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        debug_assert_eq!(m.len(), v.len());
        debug_assert_eq!(m.len(), g.len());
        let chunk = self.chunk;
        let shape = [chunk];
        let sc = [gscale];
        for (off, len) in chunk_ranges(m.len(), chunk) {
            // stage tails first (mutable), then borrow immutably for args
            if len < chunk {
                stage(&mut self.scratch[0], &m[off..off + len]);
                stage(&mut self.scratch[1], &v[off..off + len]);
                stage(&mut self.scratch[2], &g[off..off + len]);
            }
            let (a0, a1, a2) = if len == chunk {
                (&m[off..off + len], &v[off..off + len], &g[off..off + len])
            } else {
                (&self.scratch[0][..], &self.scratch[1][..], &self.scratch[2][..])
            };
            let out = self.acc.run_args(&[
                Arg::F32(a0, &shape),
                Arg::F32(a1, &shape),
                Arg::F32(a2, &shape),
                Arg::F32(&sc, &[1]),
            ])?;
            crate::runtime::copy_chunk(&out[0], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn adama_decay(&mut self, m: &mut [f32], v: &mut [f32], ms: f32, vs: f32) -> Result<()> {
        for (off, len) in chunk_ranges(m.len(), self.chunk) {
            let args = [
                self.chunk_lit(0, m, off, len)?,
                self.chunk_lit(1, v, off, len)?,
                lit_f32(&[ms], &[1])?,
                lit_f32(&[vs], &[1])?,
            ];
            let out = self.decay.run(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn adam_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        let chunk = self.chunk;
        let shape = [chunk];
        let sc = [lr, bc1, bc2];
        for (off, len) in chunk_ranges(p.len(), chunk) {
            if len < chunk {
                stage(&mut self.scratch[0], &p[off..off + len]);
                stage(&mut self.scratch[1], &m[off..off + len]);
                stage(&mut self.scratch[2], &v[off..off + len]);
            }
            let (a0, a1, a2) = if len == chunk {
                (&p[off..off + len], &m[off..off + len], &v[off..off + len])
            } else {
                (&self.scratch[0][..], &self.scratch[1][..], &self.scratch[2][..])
            };
            let out = self.update.run_args(&[
                Arg::F32(a0, &shape),
                Arg::F32(a1, &shape),
                Arg::F32(a2, &shape),
                Arg::F32(&sc, &[3]),
            ])?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    pub fn adam_full(
        &mut self,
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_lit(0, p, off, len)?,
                self.chunk_lit(1, m, off, len)?,
                self.chunk_lit(2, v, off, len)?,
                self.chunk_lit(3, g, off, len)?,
                lit_f32(&[lr, bc1, bc2], &[3])?,
            ];
            let out = self.full.run(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
            crate::runtime::copy_chunk(&out[1], &mut m[off..off + len])?;
            crate::runtime::copy_chunk(&out[2], &mut v[off..off + len])?;
        }
        Ok(())
    }

    pub fn grad_acc(&mut self, acc: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        for (off, len) in chunk_ranges(acc.len(), self.chunk) {
            let args = [
                self.chunk_lit(0, acc, off, len)?,
                self.chunk_lit(1, g, off, len)?,
                lit_f32(&[gscale], &[1])?,
            ];
            let out = self.gacc.run(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut acc[off..off + len])?;
        }
        Ok(())
    }

    // ---- §5 extensions ----

    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update(
        &mut self,
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        wd: f32,
    ) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_lit(0, p, off, len)?,
                self.chunk_lit(1, m, off, len)?,
                self.chunk_lit(2, v, off, len)?,
                lit_f32(&[lr, bc1, bc2, wd], &[4])?,
            ];
            let out = self.adamw.run(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }

    pub fn sgdm_decay_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32, mu: f32) -> Result<()> {
        for (off, len) in chunk_ranges(u.len(), self.chunk) {
            let args = [
                self.chunk_lit(0, u, off, len)?,
                self.chunk_lit(1, g, off, len)?,
                lit_f32(&[gscale, mu], &[2])?,
            ];
            let out = self.sgdm_dacc.run(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut u[off..off + len])?;
        }
        Ok(())
    }

    pub fn sgdm_acc(&mut self, u: &mut [f32], g: &[f32], gscale: f32) -> Result<()> {
        for (off, len) in chunk_ranges(u.len(), self.chunk) {
            let args = [
                self.chunk_lit(0, u, off, len)?,
                self.chunk_lit(1, g, off, len)?,
                lit_f32(&[gscale], &[1])?,
            ];
            let out = self.sgdm_acc_exe.run(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut u[off..off + len])?;
        }
        Ok(())
    }

    pub fn sgdm_update(&mut self, p: &mut [f32], u: &[f32], lr: f32, wd: f32) -> Result<()> {
        for (off, len) in chunk_ranges(p.len(), self.chunk) {
            let args = [
                self.chunk_lit(0, p, off, len)?,
                self.chunk_lit(1, u, off, len)?,
                lit_f32(&[lr, wd], &[2])?,
            ];
            let out = self.sgdm_upd.run(&args)?;
            crate::runtime::copy_chunk(&out[0], &mut p[off..off + len])?;
        }
        Ok(())
    }
}

/// Zero-pad-stage a tail slice into a scratch chunk buffer.
fn stage(buf: &mut [f32], src: &[f32]) {
    buf[..src.len()].copy_from_slice(src);
    buf[src.len()..].fill(0.0);
}

/// Pure-rust reference implementations (ablation baseline; also used by
/// the comparator optimizers and tests).
pub mod host_math {
    pub fn adama_acc(m: &mut [f32], v: &mut [f32], g: &[f32], gscale: f32, b1: f32, b2: f32) {
        for i in 0..m.len() {
            let sg = g[i] * gscale;
            m[i] += (1.0 - b1) * sg;
            v[i] += (1.0 - b2) * sg * sg;
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn adama_decay_acc(
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        gscale: f32,
        ms: f32,
        vs: f32,
        b1: f32,
        b2: f32,
    ) {
        for i in 0..m.len() {
            let sg = g[i] * gscale;
            m[i] = ms * m[i] + (1.0 - b1) * sg;
            v[i] = vs * v[i] + (1.0 - b2) * sg * sg;
        }
    }

    pub fn scale(x: &mut [f32], s: f32) {
        for a in x.iter_mut() {
            *a *= s;
        }
    }

    pub fn adam_update(p: &mut [f32], m: &[f32], v: &[f32], lr: f32, bc1: f32, bc2: f32, eps: f32) {
        for i in 0..p.len() {
            p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn adam_full(
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        b1: f32,
        b2: f32,
        eps: f32,
    ) {
        for i in 0..p.len() {
            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
            v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
            p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
        }
    }

    pub fn grad_acc(acc: &mut [f32], g: &[f32], gscale: f32) {
        for i in 0..acc.len() {
            acc[i] += g[i] * gscale;
        }
    }

    // ---- §5 extensions ----

    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update(
        p: &mut [f32], m: &[f32], v: &[f32],
        lr: f32, bc1: f32, bc2: f32, wd: f32, eps: f32,
    ) {
        for i in 0..p.len() {
            p[i] -= lr * ((m[i] / bc1) / ((v[i] / bc2).sqrt() + eps) + wd * p[i]);
        }
    }

    pub fn sgdm_decay_acc(u: &mut [f32], g: &[f32], gscale: f32, mu: f32) {
        for i in 0..u.len() {
            u[i] = mu * u[i] + gscale * g[i];
        }
    }

    pub fn sgdm_acc(u: &mut [f32], g: &[f32], gscale: f32) {
        for i in 0..u.len() {
            u[i] += gscale * g[i];
        }
    }

    pub fn sgdm_update(p: &mut [f32], u: &[f32], lr: f32, wd: f32) {
        for i in 0..p.len() {
            p[i] -= lr * (u[i] + wd * p[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_adama_acc_math() {
        let mut m = vec![0.0, 1.0];
        let mut v = vec![0.0, 2.0];
        host_math::adama_acc(&mut m, &mut v, &[4.0, -4.0], 0.5, 0.9, 0.999);
        assert!((m[0] - 0.2).abs() < 1e-6);
        assert!((m[1] - 0.8).abs() < 1e-6);
        assert!((v[0] - 0.004).abs() < 1e-6);
        assert!((v[1] - 2.004).abs() < 1e-6);
    }

    #[test]
    fn host_adam_update_is_standard() {
        let mut p = vec![1.0];
        host_math::adam_update(&mut p, &[0.1], &[0.001], 0.01, 0.1, 0.001, 1e-8);
        // mhat=1, vhat=1 -> step = lr
        assert!((p[0] - 0.99).abs() < 1e-5);
    }

    #[test]
    fn host_full_step_equals_acc_plus_update_when_n1() {
        // AdamA(N=1) == Adam: decay + single accumulate + update must equal
        // the fused full step.
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let g = vec![0.3, -0.7, 2.0];
        let mut p1 = vec![1.0, 2.0, 3.0];
        let mut m1 = vec![0.05, -0.02, 0.0];
        let mut v1 = vec![0.01, 0.02, 0.0];
        let (mut p2, mut m2, mut v2) = (p1.clone(), m1.clone(), v1.clone());
        let (lr, bc1, bc2) = (0.01, 0.1, 0.001);

        host_math::adam_full(&mut p1, &mut m1, &mut v1, &g, lr, bc1, bc2, b1, b2, eps);

        host_math::scale(&mut m2, b1);
        host_math::scale(&mut v2, b2);
        host_math::adama_acc(&mut m2, &mut v2, &g, 1.0, b1, b2);
        host_math::adam_update(&mut p2, &m2, &v2, lr, bc1, bc2, eps);

        for i in 0..3 {
            assert!((p1[i] - p2[i]).abs() < 1e-6);
            assert!((m1[i] - m2[i]).abs() < 1e-6);
            assert!((v1[i] - v2[i]).abs() < 1e-7);
        }
    }
}
