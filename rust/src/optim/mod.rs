//! Optimizers at gradient-release granularity.
//!
//! The [`Optimizer`] trait is shaped by the paper's training pipeline
//! (Alg. 2): the coordinator calls [`Optimizer::accumulate`] once per
//! *layer* per *micro-batch* the moment that layer's gradient exists, and
//! the implementation decides what to retain:
//!
//! * [`AdamA`] integrates into (m, v) — the gradient buffer can be freed
//!   immediately (the paper's contribution);
//! * [`ZooOpt`] serves the comparator family (adam / adafactor / sm3 /
//!   adam_mini) behind the exec-layer [`crate::runtime::OptStep`] seam:
//!   built from `cfg.optimizer` it keeps the GA-style persistent gradient
//!   accumulator (the Table-2 baselines); built through the `ADAMA_OPT`
//!   executor override the accumulator becomes optimizer state and the
//!   rule composes with the paper's release-early trick.

mod adama_opt;
mod backend;
mod zoo;

pub use adama_opt::AdamA;
pub use backend::{host_math, ChunkRunner, UpdateBackend};
pub use zoo::{make_rule, SgdmA, ZooOpt, ZooStates};

use std::sync::Arc;

use anyhow::Result;

use crate::config::{OptimBackend, OptimizerKind, TrainConfig};
use crate::memory::MemoryTracker;
use crate::model::ckpt::OptSnapshot;
use crate::model::{LayerParams, ModelSpec};
use crate::runtime::Library;

/// Copy one checkpointed state buffer over a live one, length-checked.
pub(crate) fn restore_buf(dst: &mut [f32], src: &[f32], what: &str) -> Result<()> {
    if dst.len() != src.len() {
        anyhow::bail!(
            "optimizer snapshot mismatch: {what} has {} elements, live state wants {}",
            src.len(),
            dst.len()
        );
    }
    dst.copy_from_slice(src);
    Ok(())
}

/// Adam hyper-parameters (from the manifest; baked into the kernels).
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Hyper {
    pub fn from_manifest(m: &crate::runtime::Manifest) -> Self {
        Self { beta1: m.hyper.beta1 as f32, beta2: m.hyper.beta2 as f32, eps: m.hyper.eps as f32 }
    }

    /// Bias corrections (1-β₁ᵗ, 1-β₂ᵗ) at 1-based step `t`.
    ///
    /// Uses `powf`: the previous `powi(t as i32)` wrapped for
    /// `t > i32::MAX`, flipping β₁ᵗ to a huge β₁⁻ᵏ and producing negative
    /// corrections deep into long runs.
    pub fn bias_corrections(&self, t: u64) -> (f32, f32) {
        (1.0 - self.beta1.powf(t as f32), 1.0 - self.beta2.powf(t as f32))
    }
}

/// Mutable access to Adam-style first/second moments (per layer), used by
/// the distributed optimizer-state all-reduce (Eq. 7–8) and ZeRO-S1.
pub struct AdamStatesMut<'a> {
    pub m: &'a mut [Vec<f32>],
    pub v: &'a mut [Vec<f32>],
}

/// A mini-batch-granularity optimizer driven layer-by-layer.
pub trait Optimizer: Send {
    fn kind(&self) -> OptimizerKind;

    /// Called once at mini-batch start with the 1-based step number.
    /// AdamA decays states here (Alg. 2 line 3); GA zeroes accumulators.
    fn begin_minibatch(&mut self, t: u64) -> Result<()>;

    /// Integrate one layer's micro-batch gradient, scaled by `gscale`
    /// (1/N single-device; 1/N per worker in DP, see Eq. 5-6). The caller
    /// frees `grad` right after this returns — that's the whole point.
    fn accumulate(&mut self, layer: usize, grad: &[f32], gscale: f32) -> Result<()>;

    /// Apply the mini-batch update to the parameters.
    fn apply(&mut self, params: &mut [LayerParams], lr: f32) -> Result<()>;

    /// Bytes of persistent optimizer state (m, v, factored moments, ...).
    fn state_bytes(&self) -> usize;

    /// Bytes of *gradient* storage held across micro-batches (GA's
    /// accumulator; 0 for AdamA — the paper's Figure 5 delta).
    fn persistent_grad_bytes(&self) -> usize {
        0
    }

    /// Adam-style (m, v) access for collectives; None for non-Adam shapes.
    fn adam_states_mut(&mut self) -> Option<AdamStatesMut<'_>> {
        None
    }

    /// Extra factor on the v-decay at mini-batch start: the distributed
    /// scheme decays by `M·β₂` instead of `β₂` (Eq. 6). Default 1.
    fn set_v_decay_factor(&mut self, _factor: f32) {}

    /// Per-layer gradient-accumulator access for the DDP
    /// gradient-all-reduce baseline and ZeRO GA flows; `None` for
    /// optimizers that hold no persistent accumulator (AdamA, SGDM-A).
    fn grad_acc_mut(&mut self) -> Option<&mut [Vec<f32>]> {
        None
    }

    /// Snapshot the optimizer's complete mutable state (checkpointing
    /// seam). Called only at mini-batch boundaries, where every transient
    /// (lazy-decay flags, …) is fully consumed — so tag + step + buffers
    /// is the *whole* state and restoring it is bit-exact.
    fn export_state(&self) -> Result<OptSnapshot> {
        anyhow::bail!("{:?}: optimizer state export not supported", self.kind())
    }

    /// Restore a snapshot produced by [`Optimizer::export_state`] on an
    /// identically-shaped optimizer. Copies in place (no re-allocation, so
    /// memory metering is untouched); tag and buffer shapes are checked.
    fn import_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        let _ = snap;
        anyhow::bail!("{:?}: optimizer state import not supported", self.kind())
    }
}

/// Placeholder optimizer for flows that manage state externally (ZeRO-S1
/// shards): accumulating into it is a bug, so it errors loudly.
pub struct NullOpt;

impl Optimizer for NullOpt {
    fn kind(&self) -> OptimizerKind {
        OptimizerKind::AdamA
    }

    fn begin_minibatch(&mut self, _t: u64) -> Result<()> {
        Ok(())
    }

    fn accumulate(&mut self, _layer: usize, _grad: &[f32], _gscale: f32) -> Result<()> {
        anyhow::bail!("NullOpt: gradients must flow through the external sink")
    }

    fn apply(&mut self, _params: &mut [LayerParams], _lr: f32) -> Result<()> {
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn export_state(&self) -> Result<OptSnapshot> {
        // state lives externally (ZeRO shards) — an empty snapshot is correct
        Ok(OptSnapshot { tag: "null".into(), t: 0, bufs: Vec::new() })
    }

    fn import_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        if snap.tag != "null" || !snap.bufs.is_empty() {
            anyhow::bail!("NullOpt cannot import a '{}' snapshot", snap.tag);
        }
        Ok(())
    }
}

/// Build the optimizer selected by `cfg`, registering its state with
/// `tracker`.
///
/// Precedence: an exec-layer override (`ADAMA_OPT`, `Library::host_with_opt`
/// or `fork_with_opt`) wins over `cfg.optimizer` and builds the zoo rule in
/// its state-resident composition with the paper's trick; otherwise the
/// config kind decides, with the zoo kinds metered GA-style (Table-2
/// comparator baselines).
pub fn build_optimizer(
    cfg: &TrainConfig,
    spec: &ModelSpec,
    lib: &Arc<Library>,
    tracker: &MemoryTracker,
) -> Result<Box<dyn Optimizer>> {
    let hyper = Hyper::from_manifest(lib.manifest());
    let backend = || -> Result<UpdateBackend> {
        Ok(match cfg.backend {
            OptimBackend::Kernel => UpdateBackend::kernel(lib.clone(), cfg.chunk)?,
            OptimBackend::Host => UpdateBackend::host(hyper),
        })
    };
    if let Some(algo) = lib.executor().opt_algo() {
        return Ok(Box::new(ZooOpt::new(algo, spec, hyper, backend()?, backend()?, true, tracker)));
    }
    Ok(match cfg.optimizer {
        OptimizerKind::AdamA => Box::new(
            AdamA::new(spec, hyper, backend()?, tracker).with_weight_decay(cfg.weight_decay),
        ),
        OptimizerKind::SgdmA => Box::new(SgdmA::new(
            spec,
            cfg.momentum,
            cfg.weight_decay,
            backend()?,
            tracker,
        )),
        kind => {
            let algo = kind.zoo_algo().expect("remaining kinds are zoo-served");
            Box::new(ZooOpt::new(algo, spec, hyper, backend()?, backend()?, false, tracker))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_corrections_progression() {
        let h = Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let (b1, b2) = h.bias_corrections(1);
        assert!((b1 - 0.1).abs() < 1e-6);
        assert!((b2 - 0.001).abs() < 1e-6);
        let (b1, _) = h.bias_corrections(100);
        assert!(b1 > 0.9999);
    }

    #[test]
    fn bias_corrections_no_overflow_past_i32_max_steps() {
        // Regression: powi(t as i32) wrapped for t > i32::MAX, producing
        // corrections far outside (0, 1].
        let h = Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let t = i32::MAX as u64 + 12345;
        let (b1, b2) = h.bias_corrections(t);
        assert!((0.0..=1.0).contains(&b1), "bc1 {b1} out of range at t={t}");
        assert!((0.0..=1.0).contains(&b2), "bc2 {b2} out of range at t={t}");
        assert!(b1 > 0.999_999, "bc1 must saturate toward 1, got {b1}");
        assert!(b2 > 0.999_999, "bc2 must saturate toward 1, got {b2}");
        // monotone across the i32 boundary
        let (early, _) = h.bias_corrections(1);
        assert!(early < b1);
    }

    #[test]
    fn null_opt_accumulate_errors_loudly() {
        let mut opt = NullOpt;
        opt.begin_minibatch(1).unwrap();
        let err = opt.accumulate(0, &[1.0, 2.0], 0.5).unwrap_err();
        assert!(
            format!("{err:?}").contains("external sink"),
            "NullOpt must explain itself: {err:?}"
        );
        // apply stays a no-op
        assert!(opt.apply(&mut [], 1e-3).is_ok());
        assert_eq!(opt.state_bytes(), 0);
    }
}
