//! `adama` CLI — leader entrypoint for training runs and paper experiments.



mod cli;

fn main() -> anyhow::Result<()> {
    let args = cli::Cli::parse();
    cli::run(args)
}
