//! Analytic per-GPU peak-memory model of transformer training.
//!
//! Regenerates the paper's memory evaluation (Figures 5–6, Tables 2–3) at
//! paper scale, where the CPU testbed cannot materialise 4B-parameter
//! models. The same formulas, evaluated with this runtime's constants
//! (fp32, per-layer-remat activation coefficient K=4), are validated
//! *exactly* against [`crate::memory::MemoryTracker`] measurements at
//! `tiny` scale — see `rust/tests/integration.rs` and
//! `benches/fig5_memory_bertlarge.rs`.
//!
//! Calibration: the paper trains fp32 with DeepSpeed (weights 4B + grads
//! 4B + Adam states 8B per parameter). BERT-Large (340M @ mb 8/GPU,
//! seq 128) then gives 5.44 GB static + activations; Table 2 reports
//! 6.15 GB total, fixing the activation coefficient K ≈ 28 bytes per
//! (token × layer × hidden).
//!
//! [`HostBlockDims`] extends the model to the host executor's
//! stash-vs-remat activation trade (`ADAMA_ACT_BUDGET`): exact per-block
//! stash and workspace byte formulas, reconciled against the executor's
//! measured [`crate::runtime::MemStats`] in `rust/tests/actstash.rs`.

use crate::config::OptimizerKind;
use crate::runtime::hostexec::gemm::{GemmMode, KC, NC};
use crate::runtime::{MemoryPlan, ModelHyper, OptAlgo};

/// A paper-scale transformer description.
#[derive(Debug, Clone)]
pub struct PaperModel {
    pub name: String,
    pub params: u64,
    pub hidden: u64,
    pub layers: u64,
    pub vocab: u64,
    pub seq: u64,
}

impl PaperModel {
    /// BERT-Large: L=24, H=1024, 340M params (paper §4.1).
    pub fn bert_large() -> Self {
        Self {
            name: "BERT-Large".into(),
            params: 340_000_000,
            hidden: 1024,
            layers: 24,
            vocab: 30522,
            seq: 128,
        }
    }

    /// BERT-4B: BERT scaled to 4e9 weights with GPT-3 proportions (§4.2).
    pub fn bert_4b() -> Self {
        Self::gpt3_scaled("BERT-4B", 4_000_000_000)
    }

    /// Scale a BERT-like model to ~`target` parameters using GPT-3-style
    /// width/depth proportions (hidden grows with P^(1/3)-ish anchors).
    pub fn gpt3_scaled(name: &str, target: u64) -> Self {
        // (params, hidden) anchors from the GPT-3 family
        const ANCHORS: [(u64, u64); 8] = [
            (125_000_000, 768),
            (350_000_000, 1024),
            (760_000_000, 1536),
            (1_300_000_000, 2048),
            (2_700_000_000, 2560),
            (6_700_000_000, 4096),
            (13_000_000_000, 5120),
            (175_000_000_000, 12288),
        ];
        let hidden = ANCHORS
            .iter()
            .min_by_key(|(p, _)| p.abs_diff(target))
            .map(|(_, h)| *h)
            .unwrap();
        let vocab = 30522u64;
        // P ≈ 12·L·H² + 2·V·H  =>  L = (P − 2VH) / 12H²
        let embed = 2 * vocab * hidden;
        let layers = ((target.saturating_sub(embed)) as f64 / (12.0 * (hidden * hidden) as f64))
            .round()
            .max(2.0) as u64;
        let params = 12 * layers * hidden * hidden + embed;
        Self { name: name.into(), params, hidden, layers, vocab, seq: 128 }
    }

    /// Largest single gradient-release unit: max(block, embedding).
    pub fn max_layer_params(&self) -> u64 {
        (12 * self.hidden * self.hidden).max(self.vocab * self.hidden)
    }
}

/// Byte-per-parameter constants of the training setup.
#[derive(Debug, Clone, Copy)]
pub struct DtypePolicy {
    pub weight_bytes: u64,
    pub grad_bytes: u64,
    /// Adam: 8 (m+v fp32).
    pub adam_state_bytes: u64,
    /// Activation bytes per (token × layer × hidden).
    pub act_coeff: u64,
}

impl DtypePolicy {
    /// The paper's fp32 DeepSpeed setup (calibrated; see module docs).
    pub fn paper_fp32() -> Self {
        Self { weight_bytes: 4, grad_bytes: 4, adam_state_bytes: 8, act_coeff: 28 }
    }

    /// This repo's runtime: fp32 + per-layer remat (stash = block inputs).
    pub fn runtime_remat() -> Self {
        Self { weight_bytes: 4, grad_bytes: 4, adam_state_bytes: 8, act_coeff: 4 }
    }
}

/// Memory strategy under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// No micro-batching: full mini-batch activations, full grads.
    NoAccum,
    /// Gradient accumulation: micro-batch activations, full grads.
    GradAccum,
    /// AdamA: micro-batch activations, max-layer grads.
    AdamA,
    /// ZeRO-S1 (`P_os`) without micro-batching (DeepSpeed default batch).
    Zero1,
    /// ZeRO-S1 + gradient accumulation.
    Zero1GradAccum,
    /// ZeRO-S1 + AdamA (the paper's combined scheme).
    Zero1AdamA,
    /// ZeRO-S1+S2 (`P_os+g`): states and grads partitioned (Fig 6b ref).
    Zero2GradAccum,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Self::NoAccum => "no-accum",
            Self::GradAccum => "grad-accum",
            Self::AdamA => "AdamA",
            Self::Zero1 => "ZeRO-S1",
            Self::Zero1GradAccum => "ZeRO-S1+GA",
            Self::Zero1AdamA => "ZeRO-S1+AdamA",
            Self::Zero2GradAccum => "ZeRO-S2+GA",
        }
    }
}

/// One training scenario to price.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub model: PaperModel,
    pub dtype: DtypePolicy,
    pub strategy: Strategy,
    pub optimizer: OptimizerKind,
    /// Mini-batch rows per GPU.
    pub minibatch_per_gpu: u64,
    /// Accumulation steps N (micro-batch = minibatch / N).
    pub accum_steps: u64,
    pub gpus: u64,
}

/// Per-GPU peak bytes, by category.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Breakdown {
    pub weights: u64,
    pub gradients: u64,
    pub optimizer_states: u64,
    pub activations: u64,
}

impl Breakdown {
    pub fn total(&self) -> u64 {
        self.weights + self.gradients + self.optimizer_states + self.activations
    }
}

/// Evaluate the model: per-GPU peak memory for the scenario.
pub fn peak_memory(s: &Scenario) -> Breakdown {
    let p = s.model.params;
    let d = &s.dtype;
    let weights = p * d.weight_bytes;

    let full_grads = p * d.grad_bytes;
    let layer_grads = s.model.max_layer_params() * d.grad_bytes;
    let gradients = match s.strategy {
        Strategy::NoAccum | Strategy::GradAccum | Strategy::Zero1 | Strategy::Zero1GradAccum => {
            full_grads
        }
        // S2 partitions the accumulated grads; transient layer grad remains
        Strategy::Zero2GradAccum => full_grads / s.gpus + layer_grads,
        Strategy::AdamA | Strategy::Zero1AdamA => layer_grads,
    };

    let os_full = optimizer_state_bytes(&s.model, s.optimizer, d);
    let optimizer_states = match s.strategy {
        Strategy::Zero1 | Strategy::Zero1GradAccum | Strategy::Zero1AdamA
        | Strategy::Zero2GradAccum => os_full / s.gpus,
        _ => os_full,
    };

    let rows = match s.strategy {
        // DeepSpeed ZeRO default runs the full per-GPU batch at once
        Strategy::NoAccum | Strategy::Zero1 => s.minibatch_per_gpu,
        _ => (s.minibatch_per_gpu / s.accum_steps).max(1),
    };
    let activations = rows * s.model.seq * s.model.hidden * s.model.layers * d.act_coeff;

    Breakdown { weights, gradients, optimizer_states, activations }
}

/// Optimizer-state bytes for Table 2's comparison set.
pub fn optimizer_state_bytes(m: &PaperModel, opt: OptimizerKind, d: &DtypePolicy) -> u64 {
    match opt {
        OptimizerKind::AdamA | OptimizerKind::AdamGA => m.params * d.adam_state_bytes,
        // Adafactor (β1>0 config): full first moment + factored second
        // moment (rows+cols per matrix ≈ 2·P/hidden).
        OptimizerKind::Adafactor => m.params * 4 + 2 * (m.params / m.hidden) * 4,
        // SM3: row+col covers only.
        OptimizerKind::Sm3 => m.params * 4 + 2 * (m.params / m.hidden) * 4 / 2,
        // Adam-mini: full first moment + one shared v per block (~row).
        OptimizerKind::AdamMini => m.params * 4 + (m.params / m.hidden) * 4,
        // SGDM-A (§5 extension): single momentum buffer.
        OptimizerKind::SgdmA => m.params * 4,
    }
}

/// Exact optimizer-state bytes of an `ADAMA_OPT` zoo rule over explicit
/// tensor shapes (`(rows, cols)`; `cols == 0` encodes 1-D) — the analytic
/// twin of the measured `ZooOpt::state_bytes()`, reconciled byte-for-byte
/// in `rust/tests/optzoo.rs` and `benches/table2_optimizers.rs`.
/// `state_resident` adds the P-float mean-gradient accumulator the
/// exec-layer seam folds into optimizer state (the paper's trick; the
/// GA-style comparator baselines meter it as gradient memory instead).
pub fn zoo_state_bytes(algo: OptAlgo, shapes: &[(u64, u64)], state_resident: bool) -> u64 {
    let p: u64 = shapes.iter().map(|&(r, c)| r * c.max(1)).sum();
    let rule: u64 = shapes
        .iter()
        .map(|&(r, c)| {
            let n = r * c.max(1);
            match algo {
                // m + v, both full
                OptAlgo::Adam => 2 * n,
                // factored / covered second moments: rows + cols per
                // matrix, full moment per vector
                OptAlgo::Adafactor | OptAlgo::Sm3 => {
                    if c > 0 {
                        r + c
                    } else {
                        n
                    }
                }
                // full m + one shared v per row block (one per vector)
                OptAlgo::AdamMini => n + if c > 0 { r } else { 1 },
            }
        })
        .sum();
    4 * (rule + if state_resident { p } else { 0 })
}

/// Tensor shapes of a paper-scale transformer for [`zoo_state_bytes`]:
/// the embedding `[V, H]` plus, per block, the four matmul weights
/// (`12·H²` total — QKV, attention out, FFN up/down) and their
/// vector-shaped biases/LayerNorm gains. Mirrors the runtime's
/// `param_shapes` grouping at paper scale.
pub fn paper_shapes(m: &PaperModel) -> Vec<(u64, u64)> {
    let h = m.hidden;
    let mut shapes = vec![(m.vocab, h)];
    for _ in 0..m.layers {
        shapes.push((h, 3 * h)); // W_qkv
        shapes.push((h, h)); // W_o
        shapes.push((h, 4 * h)); // W_up
        shapes.push((4 * h, h)); // W_down
        shapes.push((3 * h, 0)); // b_qkv
        shapes.push((4 * h, 0)); // b_up
        for _ in 0..6 {
            shapes.push((h, 0)); // b_o, b_down, ln1/ln2 gain+bias
        }
    }
    shapes
}

/// Largest GPT-3-scaled model (params) fitting `capacity` bytes per GPU —
/// binary search, Table 3's procedure.
pub fn max_model_params(
    capacity: u64,
    strategy: Strategy,
    dtype: DtypePolicy,
    minibatch_per_gpu: u64,
    accum_steps: u64,
    gpus: u64,
) -> u64 {
    let fits = |params: u64| {
        let s = Scenario {
            model: PaperModel::gpt3_scaled("probe", params),
            dtype,
            strategy,
            optimizer: OptimizerKind::AdamGA,
            minibatch_per_gpu,
            accum_steps,
            gpus,
        };
        peak_memory(&s).total() <= capacity
    };
    let (mut lo, mut hi) = (50_000_000u64, 400_000_000_000u64);
    if !fits(lo) {
        return 0;
    }
    while hi - lo > 50_000_000 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

// ---------------------------------------------------------------------------
// Host-executor activation accounting (stash vs remat)
// ---------------------------------------------------------------------------

/// Exact byte model of the host executor's transformer **block** programs
/// — the analytic twin of the measured
/// [`crate::runtime::MemStats`]. Every formula mirrors the allocation
/// sites in `runtime::hostexec::transformer` one-for-one, and
/// `rust/tests/actstash.rs` asserts measured == predicted, so a new
/// buffer in the kernel code that is not reflected here is a test
/// failure, not silent drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostBlockDims {
    /// Micro-batch rows.
    pub batch: u64,
    pub seq: u64,
    pub hidden: u64,
    pub heads: u64,
    /// FFN width (4·hidden for every builtin config).
    pub ffn: u64,
}

impl HostBlockDims {
    /// Dims of one block of a manifest model config at its configured
    /// micro-batch.
    pub fn from_model(h: &ModelHyper) -> Self {
        Self {
            batch: h.microbatch as u64,
            seq: h.seq as u64,
            hidden: h.hidden as u64,
            heads: h.heads as u64,
            ffn: h.ffn as u64,
        }
    }

    fn bs(&self) -> u64 {
        self.batch * self.seq
    }

    /// Elements of one [`gemm`](crate::runtime::hostexec::gemm) B-panel
    /// for a `[?,k]·[k,n]` matmul: `min(k, KC)·min(n, NC)` — the u64
    /// twin of [`crate::runtime::hostexec::gemm::panel_elems`].
    fn pe(k: u64, n: u64) -> u64 {
        k.min(KC as u64) * n.min(NC as u64)
    }

    /// B-panel elements of the fattest matmul a `block_fwd` call issues —
    /// zero under the naive engine, which packs nothing. Mirrors
    /// `runtime::hostexec::transformer::fwd_panel_elems` exactly.
    fn fwd_panel_elems(&self, mode: GemmMode) -> u64 {
        if mode == GemmMode::Naive {
            return 0;
        }
        let (h, f) = (self.hidden, self.ffn);
        Self::pe(h, 3 * h).max(Self::pe(h, h)).max(Self::pe(h, f)).max(Self::pe(f, h))
    }

    /// B-panel elements of the fattest matmul a `block_bwd` call issues
    /// (either path — the union panel covers the rematerialised forward
    /// too). Mirrors `runtime::hostexec::transformer::bwd_panel_elems`.
    fn bwd_panel_elems(&self, mode: GemmMode) -> u64 {
        if mode == GemmMode::Naive {
            return 0;
        }
        let (h, f, bs) = (self.hidden, self.ffn, self.bs());
        self.fwd_panel_elems(mode)
            .max(Self::pe(h, f))
            .max(Self::pe(bs, h))
            .max(Self::pe(f, h))
            .max(Self::pe(bs, f))
            .max(Self::pe(h, h))
            .max(Self::pe(3 * h, h))
            .max(Self::pe(bs, 3 * h))
    }

    /// Elements of the causal attention probability tensor
    /// `[b, heads, s, s]`.
    fn probs_elems(&self) -> u64 {
        self.batch * self.heads * self.seq * self.seq
    }

    /// Bytes one stash entry occupies in the activation arena: the
    /// forward state minus the output `y` (which leaves as the program
    /// output) plus the verbatim copy of the block input `x`.
    ///
    /// State kept: `hn1 + qkv(3h) + probs + ao + x1 + hn2 + m1(f) +
    /// gm(f)`; plus `x` — net `bs·(8h + 2f) + b·heads·s²` floats.
    pub fn stash_entry_bytes(&self) -> u64 {
        let (h, f) = (self.hidden, self.ffn);
        4 * (self.bs() * (8 * h + 2 * f) + self.probs_elems())
    }

    /// Transient workspace bytes one `block_fwd` call registers:
    /// `hn1 + qkv(3h) + kt(h) + probs + aoh + ao + attn + x1 + hn2 +
    /// m1(f) + gm(f) + m2 + y` — `bs·(12h + 2f) + b·heads·s²` floats —
    /// plus the single B-panel packing buffer of the `mode` GEMM engine
    /// (`kt` is the transposed-K scratch the output-tiled attention
    /// score kernel reads; zero-cost layout change, one extra `bs·h`).
    pub fn fwd_workspace_bytes(&self, mode: GemmMode) -> u64 {
        let (h, f) = (self.hidden, self.ffn);
        4 * (self.bs() * (12 * h + 2 * f) + self.probs_elems() + self.fwd_panel_elems(mode))
    }

    /// Bytes of stashed forward state that survive a `take()`: the entry
    /// minus the verbatim `x` copy (which is dropped on lookup). A
    /// stash-hit backward holds exactly this on top of its gradient
    /// workspace.
    pub fn stash_state_bytes(&self) -> u64 {
        self.stash_entry_bytes() - 4 * self.bs() * self.hidden
    }

    /// Transient workspace bytes of the gradient sweep alone (shared by
    /// both backward paths): the activation-shaped gradients
    /// `bs·(11h + 2f)` plus the transposed-V scratch `vt` (`bs·h`), the
    /// parameter gradients `2hf + 4h²`, the bias-shaped gradients
    /// `9h + f` (db2 + dln2g/b + dbo + dbqkv(3h) + dln1g/b), and the
    /// backward B-panel of the `mode` GEMM engine (sized to the union of
    /// forward and backward matmul shapes — `block_bwd` allocates it
    /// once up front on both paths).
    fn grad_sweep_bytes(&self, mode: GemmMode) -> u64 {
        let (h, f) = (self.hidden, self.ffn);
        4 * (self.bs() * (12 * h + 2 * f)
            + 2 * h * f
            + 4 * h * h
            + 9 * h
            + f
            + self.bwd_panel_elems(mode))
    }

    /// Workspace of a stash-hit `block_bwd` call: the gradient sweep plus
    /// the consumed forward state, which stays physically live (and is
    /// metered as workspace) until the call returns.
    pub fn bwd_workspace_bytes(&self, mode: GemmMode) -> u64 {
        self.grad_sweep_bytes(mode) + self.stash_state_bytes()
    }

    /// Workspace of a rematerialising `block_bwd` call: the recomputed
    /// forward's buffers plus the gradient sweep. The recomputed forward
    /// reuses the backward's union B-panel instead of packing its own,
    /// so the forward term carries no panel (hence `Naive`) — the panel
    /// is counted once, inside the gradient-sweep term.
    pub fn remat_bwd_workspace_bytes(&self, mode: GemmMode) -> u64 {
        self.fwd_workspace_bytes(GemmMode::Naive) + self.grad_sweep_bytes(mode)
    }

    /// Transient workspace of one fused `head_loss` call: logits +
    /// dlogits (`2·bs·v` — the largest single buffer of a training step
    /// at realistic vocab sizes) plus `dx` (`bs·h`), `dW` (`h·v`) and
    /// the head's B-panel. Mirrors the allocation sites in
    /// `runtime::hostexec::transformer::{head_common, HeadLoss}`.
    pub fn head_loss_workspace_bytes(&self, vocab: u64, mode: GemmMode) -> u64 {
        let h = self.hidden;
        let panel = if mode == GemmMode::Naive {
            0
        } else {
            Self::pe(h, vocab).max(Self::pe(vocab, h)).max(Self::pe(self.bs(), vocab))
        };
        4 * (2 * self.bs() * vocab + self.bs() * h + h * vocab + panel)
    }

    /// Transient workspace of one `head_eval` call: logits + dlogits
    /// (`head_common` allocates both on the eval path too) plus the
    /// logits-matmul B-panel.
    pub fn head_eval_workspace_bytes(&self, vocab: u64, mode: GemmMode) -> u64 {
        let panel = if mode == GemmMode::Naive { 0 } else { Self::pe(self.hidden, vocab) };
        4 * (2 * self.bs() * vocab + panel)
    }

    /// Predicted executor workspace peak over a full **training step**:
    /// the fattest block-program call under `plan`, or the head-loss
    /// call, whichever is larger (calls never overlap — the workspace
    /// drains between programs).
    pub fn predicted_step_workspace_peak_bytes(
        &self,
        plan: MemoryPlan,
        blocks: u64,
        vocab: u64,
        mode: GemmMode,
    ) -> u64 {
        self.predicted_workspace_peak_bytes(plan, blocks, mode)
            .max(self.head_loss_workspace_bytes(vocab, mode))
    }

    /// Predicted arena peak for a model with `blocks` layers trained
    /// under `plan`: the budget admits whole entries, newest-needed
    /// first, so the steady-state peak is exactly
    /// `stashable · entry_bytes`.
    pub fn predicted_stash_peak_bytes(&self, plan: MemoryPlan, blocks: u64) -> u64 {
        plan.stashable_blocks(self.stash_entry_bytes(), blocks) * self.stash_entry_bytes()
    }

    /// Predicted workspace peak over a training step: remat backward is
    /// the fattest call when any block rematerialises; otherwise the
    /// larger of forward and pure backward.
    pub fn predicted_workspace_peak_bytes(
        &self,
        plan: MemoryPlan,
        blocks: u64,
        mode: GemmMode,
    ) -> u64 {
        if plan.stashable_blocks(self.stash_entry_bytes(), blocks) < blocks {
            self.remat_bwd_workspace_bytes(mode)
        } else {
            self.fwd_workspace_bytes(mode).max(self.bwd_workspace_bytes(mode))
        }
    }

    // -- serving (KV-cached decode) ----------------------------------------

    /// Bytes one cached token occupies in one block's KV cache: a K row
    /// plus a V row, each `hidden` fp32 — `8·hidden`. The serving engine
    /// (`crate::serve`) stores exactly these rows (`block_decode`'s
    /// `knew`/`vnew` outputs), so the measured
    /// [`crate::runtime::MemStats::kv_live_bytes`] is this times cached
    /// tokens times layers, reconciled in `rust/tests/serve.rs`.
    pub fn kv_bytes_per_token_per_layer(&self) -> u64 {
        2 * self.hidden * 4
    }

    /// Whole-model KV-cache bytes for `tokens` cached positions across
    /// `layers` blocks — the quantity `ADAMA_KV_BUDGET` caps.
    pub fn kv_cache_bytes(&self, layers: u64, tokens: u64) -> u64 {
        layers * tokens * self.kv_bytes_per_token_per_layer()
    }

    /// Cached tokens that fit a KV byte budget (whole tokens only —
    /// the serving engine admits or evicts full rows across all layers).
    pub fn kv_budget_tokens(&self, layers: u64, budget_bytes: u64) -> u64 {
        budget_bytes / self.kv_cache_bytes(layers, 1)
    }

    /// Transient workspace bytes of one `block_decode` call over a ragged
    /// batch of `n` new rows attending to `p` cached rows (`p = Σ lens`):
    /// `hn1 + qkv(3h) + aoh + ao + attn + x1 + hn2 + m2 + y + knew +
    /// vnew` (`13·n·h`), the MLP pair `m1 + gel` (`2·n·f`), the
    /// transposed-K gather over cached and fresh rows (`h·(p+n)`), plus
    /// the forward B-panel of the `mode` engine (the decode matmul
    /// shapes are the forward set with `n` rows). Mirrors the allocation
    /// sites in `runtime::hostexec::transformer::BlockDecode`
    /// one-for-one.
    pub fn decode_workspace_bytes(&self, n: u64, p: u64, mode: GemmMode) -> u64 {
        let (h, f) = (self.hidden, self.ffn);
        4 * (13 * n * h + 2 * n * f + h * (p + n) + self.fwd_panel_elems(mode))
    }

    /// Transient workspace bytes of one `head_logits` call over `n` rows:
    /// the logits buffer plus the single-matmul B-panel. Mirrors
    /// `runtime::hostexec::transformer::HeadLogits`.
    pub fn head_logits_workspace_bytes(&self, n: u64, vocab: u64, mode: GemmMode) -> u64 {
        let panel = if mode == GemmMode::Naive { 0 } else { Self::pe(self.hidden, vocab) };
        4 * (n * vocab + panel)
    }

    /// The stash-policy analogue of [`DtypePolicy::act_coeff`]: bytes per
    /// (token × layer × hidden) when every block stashes. Where the
    /// remat policy keeps K=4 (block inputs only), full stashing keeps
    /// `4·(8 + 2·f/h) + 4·heads·s/h` — the paper-scale projection of the
    /// memory side of the stash-vs-recompute trade (Fig. 5/7 context).
    pub fn stash_act_coeff(&self) -> f64 {
        self.stash_entry_bytes() as f64 / (self.bs() * self.hidden) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bert_large_scenario(strategy: Strategy) -> Scenario {
        Scenario {
            model: PaperModel::bert_large(),
            dtype: DtypePolicy::paper_fp32(),
            strategy,
            optimizer: OptimizerKind::AdamGA,
            minibatch_per_gpu: 8,
            accum_steps: 8,
            gpus: 8,
        }
    }

    #[test]
    fn table2_adam_baseline_near_6_15_gb() {
        // calibration check: Adam baseline @ mb 8 should be ~6.15 GB
        let mut s = bert_large_scenario(Strategy::NoAccum);
        s.minibatch_per_gpu = 8;
        let gb = peak_memory(&s).total() as f64 / 1e9;
        assert!((5.7..6.6).contains(&gb), "BERT-Large Adam baseline {gb:.2} GB");
    }

    #[test]
    fn adama_saving_over_ga_is_grad_delta_and_constant_in_n() {
        // Fig 5: AdamA saves (P - max_layer)·4 bytes regardless of N
        let mut deltas = Vec::new();
        for n in [2u64, 4, 8, 16] {
            let mut ga = bert_large_scenario(Strategy::GradAccum);
            ga.accum_steps = n;
            let mut aa = bert_large_scenario(Strategy::AdamA);
            aa.accum_steps = n;
            deltas.push(peak_memory(&ga).total() - peak_memory(&aa).total());
        }
        let want = (PaperModel::bert_large().params
            - PaperModel::bert_large().max_layer_params())
            * 4;
        for d in &deltas {
            assert_eq!(*d, want);
        }
        let gb = want as f64 / 1e9;
        assert!((1.0..1.7).contains(&gb), "Fig-5 delta {gb:.2} GB (paper: 1.6)");
    }

    #[test]
    fn fig6a_bert4b_saving_around_23_percent() {
        let model = PaperModel::bert_4b();
        let mk = |strategy| Scenario {
            model: model.clone(),
            dtype: DtypePolicy::paper_fp32(),
            strategy,
            optimizer: OptimizerKind::AdamGA,
            minibatch_per_gpu: 8,
            accum_steps: 8,
            gpus: 8,
        };
        let ga = peak_memory(&mk(Strategy::GradAccum)).total() as f64;
        let aa = peak_memory(&mk(Strategy::AdamA)).total() as f64;
        let saving = 1.0 - aa / ga;
        assert!((0.18..0.28).contains(&saving), "BERT-4B saving {saving:.3} (paper: 0.232)");
    }

    #[test]
    fn table2_optimizer_ordering() {
        // AdamA < Adafactor/SM3 < Adam at BERT-Large mb8 (paper Table 2)
        let m = PaperModel::bert_large();
        let d = DtypePolicy::paper_fp32();
        let mk = |strategy, optimizer| {
            peak_memory(&Scenario {
                model: m.clone(),
                dtype: d,
                strategy,
                optimizer,
                minibatch_per_gpu: 8,
                accum_steps: 8,
                gpus: 8,
            })
            .total()
        };
        let adam = mk(Strategy::NoAccum, OptimizerKind::AdamGA);
        let adafactor = mk(Strategy::NoAccum, OptimizerKind::Adafactor);
        let sm3 = mk(Strategy::NoAccum, OptimizerKind::Sm3);
        let adama = mk(Strategy::AdamA, OptimizerKind::AdamA);
        assert!(adama < adafactor && adama < sm3, "AdamA wins Table 2");
        assert!(adafactor < adam && sm3 < adam);
    }

    #[test]
    fn zoo_state_bytes_closed_forms() {
        // mixed 2-D + 1-D shapes: P = 6*4 + 5 = 29
        let shapes = [(6u64, 4u64), (5, 0)];
        let p = 29u64;
        assert_eq!(zoo_state_bytes(OptAlgo::Adam, &shapes, false), 4 * 2 * p);
        // factored: rows+cols on the matrix, full v on the vector
        assert_eq!(zoo_state_bytes(OptAlgo::Adafactor, &shapes, false), 4 * ((6 + 4) + 5));
        assert_eq!(
            zoo_state_bytes(OptAlgo::Sm3, &shapes, false),
            zoo_state_bytes(OptAlgo::Adafactor, &shapes, false)
        );
        // mini: full m + one v per row block (one for the vector)
        assert_eq!(zoo_state_bytes(OptAlgo::AdamMini, &shapes, false), 4 * (p + 6 + 1));
        // the state-resident seam folds the P-float accumulator in
        for algo in OptAlgo::ALL {
            assert_eq!(
                zoo_state_bytes(algo, &shapes, true),
                zoo_state_bytes(algo, &shapes, false) + 4 * p
            );
        }
    }

    #[test]
    fn paper_shapes_account_for_the_model() {
        // Shapes must reproduce the coarse per-layer 12H² + V·H accounting
        // that PaperModel::max_layer_params and Table 2 rely on.
        let m = PaperModel::bert_large();
        let shapes = paper_shapes(&m);
        let p: u64 = shapes.iter().map(|&(r, c)| r * c.max(1)).sum();
        let matrices = m.vocab * m.hidden + m.layers * 12 * m.hidden * m.hidden;
        assert!(p >= matrices, "vectors only add");
        assert!((p - matrices) < m.layers * 16 * m.hidden, "vector overhead stays ~13H/layer");
        // paper-scale ordering matches the Table-2 comparator story
        let adam = zoo_state_bytes(OptAlgo::Adam, &shapes, false);
        let fac = zoo_state_bytes(OptAlgo::Adafactor, &shapes, false);
        let mini = zoo_state_bytes(OptAlgo::AdamMini, &shapes, false);
        assert!(fac * 50 < adam, "factored state is sublinear at paper scale");
        assert!(fac < mini && mini < adam);
        // the coarse Table-2 formula models the β₁>0 Adafactor (full first
        // moment + factors); the zoo rule is the β₁=0 variant, so its
        // state-resident composition (factors + P-float accumulator) is
        // the comparable quantity — they agree within a few percent.
        let coarse =
            optimizer_state_bytes(&m, OptimizerKind::Adafactor, &DtypePolicy::paper_fp32());
        let resident = zoo_state_bytes(OptAlgo::Adafactor, &shapes, true);
        let ratio = resident as f64 / coarse as f64;
        assert!((0.9..1.1).contains(&ratio), "resident {resident} vs coarse {coarse}");
    }

    #[test]
    fn table3_ratios_match_paper_shape() {
        let d = DtypePolicy::paper_fp32();
        // per-GPU minibatch 256/8 = 32, N=8 (paper settings)
        for cap in [16u64 << 30, 32 << 30, 80 << 30] {
            let ga = max_model_params(cap, Strategy::GradAccum, d, 32, 8, 8);
            let aa = max_model_params(cap, Strategy::AdamA, d, 32, 8, 8);
            let z1 = max_model_params(cap, Strategy::Zero1, d, 32, 8, 8);
            let z1aa = max_model_params(cap, Strategy::Zero1AdamA, d, 32, 8, 8);
            let r1 = aa as f64 / ga as f64;
            let r2 = z1aa as f64 / z1 as f64;
            assert!((1.15..1.55).contains(&r1), "PyTorch ratio {r1:.2} @ {cap}");
            assert!(r2 > 1.8, "ZeRO ratio {r2:.2} @ {cap}");
            assert!(z1aa > aa, "combined scheme fits the largest model");
        }
    }

    #[test]
    fn gpt3_scaling_hits_target() {
        for t in [1_400_000_000u64, 4_000_000_000, 18_200_000_000] {
            let m = PaperModel::gpt3_scaled("x", t);
            let ratio = m.params as f64 / t as f64;
            assert!((0.7..1.3).contains(&ratio), "{t} -> {} ({ratio:.2})", m.params);
        }
    }

    #[test]
    fn host_block_dims_formulas_are_consistent() {
        // tiny config dims: b=4, s=32, h=64, heads=2, f=256
        let d = HostBlockDims { batch: 4, seq: 32, hidden: 64, heads: 2, ffn: 256 };
        let (naive, packed) = (GemmMode::Naive, GemmMode::Packed);
        let bs = 4 * 32u64;
        let probs = 4 * 2 * 32 * 32u64;
        assert_eq!(d.stash_entry_bytes(), 4 * (bs * (8 * 64 + 2 * 256) + probs));
        assert_eq!(d.fwd_workspace_bytes(naive), 4 * (bs * (12 * 64 + 2 * 256) + probs));
        assert_eq!(d.stash_state_bytes(), 4 * (bs * (7 * 64 + 2 * 256) + probs));
        assert_eq!(
            d.grad_sweep_bytes(naive),
            4 * (bs * (12 * 64 + 2 * 256) + 2 * 64 * 256 + 4 * 64 * 64 + 9 * 64 + 256)
        );
        // panel terms: naive packs nothing; packed adds exactly the
        // fattest min(k,KC)·min(n,NC) panel of each program's matmuls
        // (h=64, f=256, bs=128 => fwd h·f, bwd bs·f, capped by KC/NC=256)
        assert_eq!(d.fwd_panel_elems(naive), 0);
        assert_eq!(d.fwd_panel_elems(packed), 64 * 256);
        assert_eq!(d.bwd_panel_elems(packed), 128 * 256);
        assert_eq!(
            d.fwd_workspace_bytes(packed),
            d.fwd_workspace_bytes(naive) + 4 * d.fwd_panel_elems(packed)
        );
        assert_eq!(
            d.grad_sweep_bytes(packed),
            d.grad_sweep_bytes(naive) + 4 * d.bwd_panel_elems(packed)
        );
        for gm in GemmMode::all() {
            assert_eq!(d.bwd_workspace_bytes(gm), d.grad_sweep_bytes(gm) + d.stash_state_bytes());
            // the rematerialised forward reuses the backward union panel,
            // so remat = panel-free forward + panel-carrying sweep
            assert_eq!(
                d.remat_bwd_workspace_bytes(gm),
                d.fwd_workspace_bytes(naive) + d.grad_sweep_bytes(gm)
            );
        }
        // head programs (tiny vocab = 256): logits dominate the head side
        let v = 256u64;
        assert_eq!(d.head_loss_workspace_bytes(v, naive), 4 * (2 * bs * v + bs * 64 + 64 * v));
        assert_eq!(
            d.head_loss_workspace_bytes(v, packed),
            4 * (2 * bs * v + bs * 64 + 64 * v + 128 * 256)
        );
        assert_eq!(d.head_eval_workspace_bytes(v, naive), 4 * 2 * bs * v);
        assert_eq!(d.head_eval_workspace_bytes(v, packed), 4 * (2 * bs * v + 64 * 256));
        for gm in GemmMode::all() {
            assert!(d.head_loss_workspace_bytes(v, gm) > d.head_eval_workspace_bytes(v, gm));
            // at tiny scale the remat block backward still dominates the
            // step peak; at BERT-vocab scale the head takes over — the
            // step-level prediction covers both regimes
            assert_eq!(
                d.predicted_step_workspace_peak_bytes(MemoryPlan::remat(), 2, v, gm),
                d.remat_bwd_workspace_bytes(gm)
            );
            let big_vocab = 30522u64;
            assert_eq!(
                d.predicted_step_workspace_peak_bytes(MemoryPlan::remat(), 2, big_vocab, gm),
                d.head_loss_workspace_bytes(big_vocab, gm)
            );
            // a stash entry is strictly smaller than the forward recompute
            // it saves, and a stash-hit backward is strictly lighter than
            // a rematerialising one (that's the whole trade)
            assert!(d.stash_entry_bytes() < d.fwd_workspace_bytes(gm));
            assert!(d.bwd_workspace_bytes(gm) < d.remat_bwd_workspace_bytes(gm));
        }
    }

    #[test]
    fn serving_kv_formulas_are_consistent() {
        // tiny config dims: b=4, s=32, h=64, heads=2, f=256
        let d = HostBlockDims { batch: 4, seq: 32, hidden: 64, heads: 2, ffn: 256 };
        // one token, one layer: a K row + a V row of h fp32 each
        assert_eq!(d.kv_bytes_per_token_per_layer(), 2 * 64 * 4);
        assert_eq!(d.kv_cache_bytes(2, 10), 2 * 10 * 2 * 64 * 4);
        // budget→tokens is the exact floor inverse
        let per_tok = d.kv_cache_bytes(2, 1);
        assert_eq!(d.kv_budget_tokens(2, 5 * per_tok + per_tok - 1), 5);
        assert_eq!(d.kv_budget_tokens(2, 5 * per_tok), 5);
        // decode workspace: ragged batch of n=3 new rows over p=7 cached
        let (n, p) = (3u64, 7u64);
        assert_eq!(
            d.decode_workspace_bytes(n, p, GemmMode::Naive),
            4 * (13 * n * 64 + 2 * n * 256 + 64 * (p + n))
        );
        assert_eq!(
            d.decode_workspace_bytes(n, p, GemmMode::Packed),
            d.decode_workspace_bytes(n, p, GemmMode::Naive) + 4 * d.fwd_panel_elems(GemmMode::Packed)
        );
        // head_logits: logits + panel only
        let v = 256u64;
        assert_eq!(d.head_logits_workspace_bytes(n, v, GemmMode::Naive), 4 * n * v);
        assert_eq!(
            d.head_logits_workspace_bytes(n, v, GemmMode::Packed),
            4 * (n * v + 64 * 256)
        );
        // a decode step over one token is far lighter than a training
        // forward over the full micro-batch — the point of serving split
        for gm in GemmMode::all() {
            assert!(d.decode_workspace_bytes(1, 32, gm) < d.fwd_workspace_bytes(gm));
        }
    }

    #[test]
    fn predicted_stash_peak_follows_budget() {
        let d = HostBlockDims { batch: 4, seq: 32, hidden: 64, heads: 2, ffn: 256 };
        let e = d.stash_entry_bytes();
        let blocks = 2u64;
        assert_eq!(d.predicted_stash_peak_bytes(MemoryPlan::remat(), blocks), 0);
        assert_eq!(
            d.predicted_stash_peak_bytes(MemoryPlan::unlimited(), blocks),
            blocks * e
        );
        // half budget fits exactly one of the two blocks
        assert_eq!(d.predicted_stash_peak_bytes(MemoryPlan::bytes(e * blocks / 2), blocks), e);
        // remat workspace dominates whenever any block recomputes
        for gm in GemmMode::all() {
            assert!(
                d.predicted_workspace_peak_bytes(MemoryPlan::remat(), blocks, gm)
                    > d.predicted_workspace_peak_bytes(MemoryPlan::unlimited(), blocks, gm)
            );
        }
    }

    #[test]
    fn stash_coefficient_dwarfs_remat_coefficient() {
        // the remat policy keeps K=4 bytes per token·layer·hidden (block
        // inputs only); full stashing keeps an order of magnitude more —
        // the memory side of the recompute trade at any scale
        let d = HostBlockDims { batch: 8, seq: 128, hidden: 1024, heads: 16, ffn: 4096 };
        let k = d.stash_act_coeff();
        let remat_k = DtypePolicy::runtime_remat().act_coeff as f64;
        assert!(k > 10.0 * remat_k, "stash coeff {k:.1} vs remat {remat_k}");
    }

    #[test]
    fn runtime_policy_matches_tracker_formulas() {
        // analytic(K=4, remat) for the tiny runtime config must equal what
        // the tracker measures: act = N_blocks·B·S·H·4 per micro-batch.
        let d = DtypePolicy::runtime_remat();
        let model = PaperModel {
            name: "tiny".into(),
            params: 100,
            hidden: 64,
            layers: 2,
            vocab: 256,
            seq: 32,
        };
        let s = Scenario {
            model,
            dtype: d,
            strategy: Strategy::AdamA,
            optimizer: OptimizerKind::AdamA,
            minibatch_per_gpu: 8,
            accum_steps: 2,
            gpus: 1,
        };
        let b = peak_memory(&s);
        assert_eq!(b.activations, 4 * 32 * 64 * 2 * 4); // rows·seq·hidden·layers·K
    }
}
