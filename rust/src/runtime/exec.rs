//! Backend-neutral execution interface: [`Value`], [`Arg`], [`Program`]
//! and [`Executor`].
//!
//! This is the seam that decouples the training stack from any particular
//! runtime. The coordinator, optimizers and collectives speak only these
//! types; `runtime::hostexec` implements them in pure rust (the default),
//! and `runtime::pjrt` (cargo feature `pjrt`) implements them over the
//! PJRT C API and the AOT HLO artifacts.
//!
//! ## How the seam fits together
//!
//! * A [`Program`] is one executable unit — an optimizer kernel, a
//!   transformer layer, the fused MLP — resolved by manifest name
//!   (`"common/adama_acc_16384"`, `"tiny/block_fwd"`, ...). Programs are
//!   pure functions of their arguments plus backend-internal caches; the
//!   training stack never sees backend types.
//! * An [`Executor`] turns manifest entries into loaded programs and
//!   reports backend facts (platform, thread count, execute-call count,
//!   [`MemStats`] when the backend instruments memory).
//! * [`crate::runtime::Library`] caches loaded programs and picks the
//!   backend (`ADAMA_BACKEND=host|pjrt`).
//!
//! ## Determinism contract
//!
//! Backends must be *run-to-run and thread-count deterministic*: the same
//! program on the same argument bits returns the same output bits,
//! regardless of `ADAMA_THREADS` or pool contention. The host executor
//! guarantees this via fixed contiguous work assignment (see
//! [`crate::runtime::pool`]); `rust/tests/determinism.rs` enforces it for
//! every builtin program at 1/2/3/8 threads.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::manifest::{ArtifactEntry, Manifest};

/// An owned host tensor crossing the executor boundary (f32 or s32, the
/// only dtypes the artifact set uses). Replaces the raw PJRT literal type
/// in all public signatures.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Value {
    /// f32 value with the given logical shape.
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Ok(Self::F32 { data, shape: shape.to_vec() })
    }

    /// i32 value with the given logical shape.
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Ok(Self::I32 { data, shape: shape.to_vec() })
    }

    /// Rank-0 f32 scalar (losses).
    pub fn scalar_f32(x: f32) -> Self {
        Self::F32 { data: vec![x], shape: Vec::new() }
    }

    /// Rank-0 i32 scalar (counts).
    pub fn scalar_i32(x: i32) -> Self {
        Self::I32 { data: vec![x], shape: Vec::new() }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Self::F32 { shape, .. } | Self::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Self::F32 { data, .. } => data.len(),
            Self::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Self::F32 { .. } => "f32",
            Self::I32 { .. } => "s32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Self::F32 { data, .. } => Ok(data),
            Self::I32 { .. } => bail!("expected f32 value, got s32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Self::I32 { data, .. } => Ok(data),
            Self::F32 { .. } => bail!("expected s32 value, got f32"),
        }
    }

    /// First element of an f32 value (rank-0 or rank-1 scalars).
    pub fn first_f32(&self) -> Result<f32> {
        self.as_f32()?.first().copied().context("empty f32 value")
    }

    /// First element of an i32 value.
    pub fn first_i32(&self) -> Result<i32> {
        self.as_i32()?.first().copied().context("empty i32 value")
    }

    /// Borrow as a program argument.
    pub fn as_arg(&self) -> Arg<'_> {
        match self {
            Self::F32 { data, shape } => Arg::F32(data, shape),
            Self::I32 { data, shape } => Arg::I32(data, shape),
        }
    }
}

/// A borrowed host-array argument for [`Program::run`] — the
/// zero-intermediate-copy input path (host slice → backend).
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> Arg<'a> {
    pub fn shape(&self) -> &'a [usize] {
        match *self {
            Arg::F32(_, s) | Arg::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match *self {
            Arg::F32(d, _) => d.len(),
            Arg::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32(&self) -> Result<&'a [f32]> {
        match *self {
            Arg::F32(d, _) => Ok(d),
            Arg::I32(..) => bail!("expected f32 argument, got s32"),
        }
    }

    pub fn i32(&self) -> Result<&'a [i32]> {
        match *self {
            Arg::I32(d, _) => Ok(d),
            Arg::F32(..) => bail!("expected s32 argument, got f32"),
        }
    }
}

/// A loaded, executable program (an AOT artifact on PJRT; a pure-rust
/// implementation on the host executor). Thread-safe: worker threads in
/// the data-parallel simulators share programs through `Arc`.
pub trait Program: Send + Sync {
    /// Execute with borrowed host-slice arguments; returns owned outputs.
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>>;

    /// Execute with owned [`Value`] arguments (convenience over [`run`]).
    ///
    /// [`run`]: Program::run
    fn run_v(&self, args: &[Value]) -> Result<Vec<Value>> {
        let views: Vec<Arg<'_>> = args.iter().map(Value::as_arg).collect();
        self.run(&views)
    }
}

/// Backend-neutral memory instrumentation snapshot (see
/// [`Executor::memory`]): the activation stash arena plus the transient
/// per-call workspace of the executing backend. Byte counts are exact
/// for the programs the backend meters — on the host executor that is
/// the transformer **block** programs, the **head** programs (whose
/// logits are the largest single buffer of a step at realistic vocab
/// sizes) and the fused MLP, each buffer registered at its allocation
/// site; only the embed transients remain outside the meter (cheap,
/// O(bs·h)). The metered subset is what lets `crate::memmodel`
/// predictions be reconciled against measurements as a tested
/// invariant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemStats {
    /// Configured stash budget; `None` = unlimited, `Some(0)` = pure
    /// remat (never stash).
    pub stash_budget_bytes: Option<u64>,
    /// Bytes currently held by stashed activation entries.
    pub stash_live_bytes: u64,
    /// High-water mark of `stash_live_bytes`.
    pub stash_peak_bytes: u64,
    /// Transient workspace bytes live right now (usually 0 between calls).
    pub workspace_live_bytes: u64,
    /// High-water mark of per-call transient workspace.
    pub workspace_peak_bytes: u64,
    /// Forward calls that stashed their intermediates.
    pub stashed: u64,
    /// Backward calls that consumed a stash (recompute skipped).
    pub stash_hits: u64,
    /// Entries evicted to make room under the byte budget.
    pub stash_evictions: u64,
    /// Backward calls that fell back to rematerialisation.
    pub remats: u64,
    /// Bytes currently held by serving KV caches (`serve::KvCache`
    /// registers every per-sequence key/value buffer here; always 0 in
    /// training runs).
    pub kv_live_bytes: u64,
    /// High-water mark of `kv_live_bytes`.
    pub kv_peak_bytes: u64,
}

/// A program-loading backend. Implementations: `hostexec::HostExecutor`
/// (pure rust, always available) and `pjrt::PjrtExecutor` (feature
/// `pjrt`, compiles HLO artifacts).
pub trait Executor: Send + Sync {
    /// Human-readable backend name ("host", "cpu", ...).
    fn platform(&self) -> String;

    /// Resolve a manifest program name (e.g. `"common/adama_acc_16384"`,
    /// `"tiny/block_fwd"`, `"mlp_small/mlp_train"`) into an executable.
    fn load(
        &self,
        name: &str,
        entry: &ArtifactEntry,
        manifest: &Manifest,
    ) -> Result<Arc<dyn Program>>;

    /// Total program executions issued through this executor (perf
    /// accounting; mirrors the PJRT execute-call counter).
    fn exec_calls(&self) -> u64;

    /// Worker threads the backend uses for intra-program parallelism
    /// (1 = serial). The host executor sizes this from `ADAMA_THREADS`;
    /// backends without an in-process pool report 1.
    fn threads(&self) -> usize {
        1
    }

    /// SIMD dispatch level of the backend's vector kernels, when it has
    /// one. The host executor reports its `ADAMA_SIMD`-resolved
    /// [`crate::runtime::simd::Level`]; backends without an in-process
    /// SIMD layer return `None`.
    fn simd_level(&self) -> Option<crate::runtime::simd::Level> {
        None
    }

    /// GEMM engine of the backend's matmul kernels, when it has one. The
    /// host executor reports its `ADAMA_GEMM`-resolved
    /// [`crate::runtime::hostexec::gemm::GemmMode`]; backends without an
    /// in-process GEMM layer return `None`.
    fn gemm_mode(&self) -> Option<crate::runtime::hostexec::gemm::GemmMode> {
        None
    }

    /// Update rule forced at the executor seam, when one is. The host
    /// executor reports its `ADAMA_OPT`-resolved
    /// [`crate::runtime::optstep::OptAlgo`] (or the `host_with_opt`
    /// override); `None` keeps whatever the training config asks for.
    /// `optim::build_optimizer` resolves this before the config, so
    /// DP/ZeRO rank forks inherit the selection.
    fn opt_algo(&self) -> Option<crate::runtime::optstep::OptAlgo> {
        None
    }

    /// Memory instrumentation snapshot, when the backend provides one.
    /// The host executor reports its activation stash arena and per-call
    /// workspace meters; backends without instrumentation return `None`.
    fn memory(&self) -> Option<MemStats> {
        None
    }

    /// Drop any retained activation stash entries (no-op for backends
    /// without a stash). The coordinator calls this after forward-only
    /// phases (eval), whose stashed intermediates no backward will ever
    /// consume — without it they would sit in the arena until budget or
    /// entry-count recycling, inflating the measured stash peaks.
    fn clear_stash(&self) {}

    /// Register `bytes` of serving KV-cache memory with the backend's
    /// memory instrumentation (`crate::serve::KvCache` calls this at
    /// every append so [`MemStats::kv_live_bytes`] reconciles exactly
    /// against `memmodel` predictions). No-op on backends without
    /// instrumentation.
    fn kv_alloc(&self, bytes: u64) {
        let _ = bytes;
    }

    /// Release `bytes` of serving KV-cache memory (a sequence retired or
    /// was evicted under the `ADAMA_KV_BUDGET` cap). No-op on backends
    /// without instrumentation.
    fn kv_free(&self, bytes: u64) {
        let _ = bytes;
    }
}

// ---------------------------------------------------------------------------
// Construction/extraction helpers (the former `literal.rs` surface, now
// backend-neutral).
// ---------------------------------------------------------------------------

/// f32 value with the given logical shape (single copy of the slice).
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Value> {
    Value::f32(data.to_vec(), shape)
}

/// i32 value with the given logical shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Value> {
    Value::i32(data.to_vec(), shape)
}

/// Rank-1 single-element f32 value (runtime scalar inputs use shape [1]).
pub fn lit_scalar_f32(x: f32) -> Result<Value> {
    Value::f32(vec![x], &[1])
}

/// Extract an f32 value (any rank) into a Vec.
pub fn to_vec_f32(v: &Value) -> Result<Vec<f32>> {
    Ok(v.as_f32()?.to_vec())
}

/// Extract an i32 value into a Vec.
pub fn to_vec_i32(v: &Value) -> Result<Vec<i32>> {
    Ok(v.as_i32()?.to_vec())
}

/// Copy a value into a caller-provided buffer (alloc-free extraction).
pub fn copy_into_f32(v: &Value, dst: &mut [f32]) -> Result<()> {
    let src = v.as_f32()?;
    ensure!(src.len() == dst.len(), "value/dst length mismatch");
    dst.copy_from_slice(src);
    Ok(())
}

/// Copy the first `dst.len()` elements of a (possibly zero-padded) chunk
/// value into `dst` — the tail-chunk extraction path of the optimizer
/// kernels.
pub fn copy_chunk(v: &Value, dst: &mut [f32]) -> Result<()> {
    let src = v.as_f32()?;
    if src.len() == dst.len() {
        dst.copy_from_slice(src);
        return Ok(());
    }
    ensure!(src.len() > dst.len(), "chunk value smaller than destination");
    dst.copy_from_slice(&src[..dst.len()]);
    Ok(())
}

/// f32 scalar extraction — for losses.
pub fn scalar_f32(v: &Value) -> Result<f32> {
    v.first_f32().context("scalar f32")
}

/// i32 scalar extraction — for correct-prediction counts.
pub fn scalar_i32(v: &Value) -> Result<i32> {
    v.first_i32().context("scalar i32")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_checks() {
        assert!(Value::f32(vec![1.0, 2.0], &[2]).is_ok());
        assert!(Value::f32(vec![1.0, 2.0], &[3]).is_err());
        let s = Value::scalar_f32(4.0);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(scalar_f32(&s).unwrap(), 4.0);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let v = Value::i32(vec![1, 2], &[2]).unwrap();
        assert!(v.as_f32().is_err());
        assert_eq!(v.as_i32().unwrap(), &[1, 2]);
        assert_eq!(v.dtype(), "s32");
    }

    #[test]
    fn copy_chunk_handles_padded_tails() {
        let v = Value::f32(vec![1.0, 2.0, 3.0, 0.0], &[4]).unwrap();
        let mut dst = [0.0f32; 3];
        copy_chunk(&v, &mut dst).unwrap();
        assert_eq!(dst, [1.0, 2.0, 3.0]);
        let mut exact = [0.0f32; 4];
        copy_chunk(&v, &mut exact).unwrap();
        assert_eq!(exact, [1.0, 2.0, 3.0, 0.0]);
        let mut too_big = [0.0f32; 5];
        assert!(copy_chunk(&v, &mut too_big).is_err());
    }
}
