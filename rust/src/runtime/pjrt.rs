//! PJRT backend (cargo feature `pjrt`): loads AOT artifacts (HLO text)
//! and executes them through the PJRT C API.
//!
//! The interchange format is HLO *text* — jax >= 0.5 serialized protos use
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids and round-trips cleanly.
//!
//! The raw `xla` crate types hold C pointers and are `!Send`; PJRT's C API
//! is documented thread-safe (clients, executables and literals may be
//! used concurrently), so we expose `Send + Sync` wrappers and keep all
//! mutation inside XLA. Worker threads in the data-parallel simulator
//! share one CPU client and its compiled executables through these
//! wrappers.
//!
//! This is the only module in the crate that names `xla` types; everything
//! above it speaks [`Value`]/[`Program`]/[`Executor`].

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::exec::{Arg, Executor, Program, Value};
use super::manifest::{ArtifactEntry, Manifest, TensorSpec};

/// Thread-safe PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    /// Total `execute` calls issued through this engine (perf accounting).
    exec_calls: Arc<AtomicU64>,
}

// SAFETY: PJRT C API objects (client/executable/buffer) are thread-safe per
// the PJRT API contract; the `xla` crate merely forgot the marker impls.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU PJRT client (the testbed substrate for the paper's
    /// GPUs — see DESIGN.md §Substitutions).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, exec_calls: Arc::new(AtomicU64::new(0)) })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Parse HLO text and compile it to a loaded executable.
    pub fn compile_hlo_file(&self, path: &Path, entry: &ArtifactEntry) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            client: self.client.clone(),
            calls: self.exec_calls.clone(),
            outputs: entry.outputs.clone(),
        })
    }

    /// Total number of PJRT `execute` calls issued (metrics).
    pub fn exec_calls(&self) -> u64 {
        self.exec_calls.load(Ordering::Relaxed)
    }
}

/// A compiled HLO module.
///
/// All artifacts are lowered with `return_tuple=True`, so execution always
/// yields one tuple literal which [`Program::run`] decomposes.
///
/// NOTE: inputs go through `buffer_from_host_buffer` + `execute_b` with
/// buffers this wrapper owns. The published `xla` 0.1.6 crate's
/// `execute()` (literal inputs) leaks every input device buffer —
/// `input_buffer_ptrs.push_back(buffer.release())` in `xla_rs.cc` with no
/// corresponding free — which at our call volume (~1.3k PJRT calls per
/// small-model step) is ~250 MB/step. Creating `PjRtBuffer`s ourselves
/// restores RAII ownership.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    calls: Arc<AtomicU64>,
    /// Output dtypes/shapes from the manifest (PJRT literals do not carry
    /// enough metadata through the thin bindings to recover them).
    outputs: Vec<TensorSpec>,
}

// SAFETY: see `Engine` — PJRT executables are thread-safe.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    fn literal_to_value(&self, idx: usize, lit: &xla::Literal) -> Result<Value> {
        let spec = self
            .outputs
            .get(idx)
            .with_context(|| format!("artifact returned unexpected output #{idx}"))?;
        ensure!(
            lit.element_count() == spec.elements(),
            "output #{idx}: literal has {} elements, manifest says {}",
            lit.element_count(),
            spec.elements()
        );
        match spec.dtype.as_str() {
            "s32" => Value::i32(lit.to_vec::<i32>().context("literal -> Vec<i32>")?, &spec.shape),
            "f32" => Value::f32(lit.to_vec::<f32>().context("literal -> Vec<f32>")?, &spec.shape),
            other => anyhow::bail!("output #{idx}: unsupported manifest dtype '{other}'"),
        }
    }
}

impl Program for Executable {
    /// Execute straight from host slices (no intermediate `Literal`) —
    /// one memcpy per argument into XLA-owned device buffers.
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        let inputs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|a| match a {
                Arg::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
                Arg::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
            })
            .collect::<std::result::Result<_, _>>()
            .context("host slice -> device buffer")?;
        let bufs = self.exe.execute_b(&inputs).context("PJRT execute_b")?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        ensure!(
            !bufs.is_empty() && !bufs[0].is_empty(),
            "PJRT execution returned no output buffers"
        );
        let lit = bufs[0][0].to_literal_sync().context("device->host transfer")?;
        let lits = lit.to_tuple().context("decomposing output tuple")?;
        lits.iter()
            .enumerate()
            .map(|(i, l)| self.literal_to_value(i, l))
            .collect()
    }
}

/// [`Executor`] over a PJRT engine + an artifact directory.
pub struct PjrtExecutor {
    engine: Arc<Engine>,
    root: PathBuf,
}

impl PjrtExecutor {
    pub fn new(root: impl Into<PathBuf>, engine: Arc<Engine>) -> Self {
        Self { engine, root: root.into() }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}

impl Executor for PjrtExecutor {
    fn platform(&self) -> String {
        self.engine.platform_name()
    }

    fn load(
        &self,
        name: &str,
        entry: &ArtifactEntry,
        _manifest: &Manifest,
    ) -> Result<Arc<dyn Program>> {
        let path = self.root.join(&entry.file);
        let exe = self
            .engine
            .compile_hlo_file(&path, entry)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        Ok(Arc::new(exe))
    }

    fn exec_calls(&self) -> u64 {
        self.engine.exec_calls()
    }
}
