//! PJRT runtime: load AOT artifacts (HLO text) and execute them from rust.
//!
//! The interchange format is HLO *text* — jax >= 0.5 serialized protos use
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

mod engine;
mod literal;
mod manifest;

pub use engine::{Arg, Engine, Executable};
pub use literal::{
    copy_chunk, copy_into_f32, lit_f32, lit_i32, lit_scalar_f32, scalar_f32, scalar_i32,
    to_vec_f32, to_vec_i32,
};
pub use manifest::{
    ArtifactEntry, Hyper as ManifestHyper, Manifest, MlpConfigEntry, MlpHyper, ModelConfigEntry,
    ModelHyper, TensorSpec,
};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};
use std::sync::Mutex;

/// Lazily-compiled, cached library of every artifact in `manifest.json`.
///
/// Artifact names are manifest-relative: `"common/adama_acc_65536"`,
/// `"tiny/block_fwd"`, `"mlp_small/mlp_train"`.
pub struct ArtifactLibrary {
    engine: Arc<Engine>,
    root: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl ArtifactLibrary {
    /// Open the artifact directory produced by `make artifacts`.
    pub fn open(root: impl AsRef<Path>, engine: Arc<Engine>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let manifest = Manifest::load(root.join("manifest.json"))?;
        Ok(Self { engine, root, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Locate the artifact root: `$ADAMA_ARTIFACTS`, `./artifacts`, or the
    /// crate-relative default (useful for tests/benches run from anywhere).
    pub fn default_root() -> PathBuf {
        if let Ok(p) = std::env::var("ADAMA_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("manifest.json").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Open the default artifact root with a fresh CPU engine.
    pub fn open_default() -> Result<Arc<Self>> {
        let engine = Arc::new(Engine::cpu()?);
        Ok(Arc::new(Self::open(Self::default_root(), engine)?))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Manifest entry (shapes/dtypes) for `group/name`.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .entry(name)
            .with_context(|| format!("no artifact '{name}' in manifest"))
    }

    /// Compile (or fetch from cache) the executable for `group/name`.
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.entry(name)?;
        let path = self.root.join(&entry.file);
        let exe = Arc::new(
            self.engine
                .compile_hlo_file(&path)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile a set of artifacts (startup warm-up).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
