//! Pluggable execution runtime.
//!
//! [`Library`] resolves manifest program names (`"common/adama_acc_16384"`,
//! `"tiny/block_fwd"`, `"mlp_small/mlp_train"`) to executable [`Program`]s
//! through an [`Executor`] backend:
//!
//! * [`hostexec::HostExecutor`] — pure-rust reference implementations of
//!   every program (optimizer kernels, transformer layers, MLP). Always
//!   available; needs no artifacts, no Python, no native libraries. When
//!   no `artifacts/` directory exists, [`Library::open_default`] uses this
//!   backend with a built-in manifest ([`Manifest::builtin`]). Hot paths
//!   run on the in-tree deterministic thread pool ([`pool`]); thread
//!   count comes from `ADAMA_THREADS` (default: available parallelism)
//!   and results are bit-for-bit identical at any setting.
//!   `ADAMA_SIMD=auto|avx2|sse2|neon|scalar` picks the [`simd`] dispatch
//!   level for the vectorised hot loops (default `auto` = best the CPU
//!   supports); every level is bit-for-bit identical to scalar, so this
//!   too is a pure performance knob.
//!   `ADAMA_GEMM=auto|packed|naive` picks the matmul engine
//!   ([`hostexec::gemm`]): the packed, cache-blocked GEMM (default) or
//!   the naive A/B baseline — bit-identical by the same contract.
//!   `ADAMA_ACT_BUDGET` (or [`Library::host_with_plan`]) sets the
//!   activation stash budget: `0`/unset = per-layer remat (default),
//!   `<n>[k|m|g]` = stash under a byte cap, `unlimited` = always stash —
//!   see [`hostexec::actmem`]. Stashed and remat backward are
//!   bit-identical, so the budget is a pure memory/throughput knob.
//!   The distributed runners add two scheduling knobs of the same
//!   strictly-parsed family: `ADAMA_ASYNC=0|1` overlaps the per-layer
//!   collectives with backward compute on a per-rank comm thread, and
//!   `ADAMA_BUCKET_BYTES=<n>[k|m|g]` coalesces small gradients into one
//!   gate crossing — both resolved by `collective::fabric`
//!   (`parse_async` / `parse_bucket_bytes`) and both pure performance
//!   knobs: sync and async runs are bit-identical, ledgers included.
//! * `pjrt::PjrtExecutor` (cargo feature `pjrt`) — compiles the AOT HLO
//!   artifacts produced by `python/compile/aot.py` through the PJRT C API.
//!   Selected automatically when the feature is enabled and artifacts are
//!   found; `ADAMA_BACKEND=host|pjrt` overrides the choice.

pub mod exec;
pub mod hostexec;
mod manifest;
pub mod optstep;
#[cfg(feature = "pjrt")]
mod pjrt;
pub mod pool;
pub mod simd;

pub use exec::{
    copy_chunk, copy_into_f32, lit_f32, lit_i32, lit_scalar_f32, scalar_f32, scalar_i32,
    to_vec_f32, to_vec_i32, Arg, Executor, MemStats, Program, Value,
};
pub use hostexec::actmem::{ActBudget, MemoryPlan};
pub use hostexec::gemm::GemmMode;
pub use hostexec::HostExecutor;
pub use optstep::{OptAlgo, OptStep};
pub use pool::ThreadPool;
pub use simd::Level as SimdLevel;
pub use manifest::{
    ArtifactEntry, Hyper as ManifestHyper, Manifest, MlpConfigEntry, MlpHyper, ModelConfigEntry,
    ModelHyper, TensorSpec,
};
#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, Executable, PjrtExecutor};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

/// Lazily-loaded, cached library of every program in the manifest,
/// dispatched through a backend-neutral [`Executor`].
pub struct Library {
    executor: Arc<dyn Executor>,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<dyn Program>>>,
}

/// Backward-compatible name from the PJRT-only era.
pub type ArtifactLibrary = Library;

impl Library {
    /// Pure-rust host library with the built-in default manifest — runs on
    /// a clean machine with zero native dependencies. Pool size comes from
    /// `ADAMA_THREADS` (default: available parallelism). Invalid
    /// `ADAMA_THREADS`/`ADAMA_SIMD`/`ADAMA_GEMM`/`ADAMA_ACT_BUDGET`
    /// values are clear errors naming the accepted spellings.
    pub fn try_host() -> Result<Arc<Self>> {
        Ok(Self::with_executor(Arc::new(HostExecutor::try_new()?), Manifest::builtin()))
    }

    /// [`Library::try_host`], panicking (with the underlying message) on
    /// an invalid `ADAMA_*` environment.
    pub fn host() -> Arc<Self> {
        Self::try_host().expect("invalid ADAMA_* environment")
    }

    /// [`Library::host`] with the executor's thread pool pinned to
    /// `threads` workers (1 = fully serial) — the determinism suite and
    /// the perf benches sweep this.
    pub fn host_with_threads(threads: usize) -> Arc<Self> {
        Self::with_executor(Arc::new(HostExecutor::with_threads(threads)), Manifest::builtin())
    }

    /// [`Library::host_with_threads`] with an explicit activation stash
    /// plan (the API twin of `ADAMA_ACT_BUDGET`): the stash-vs-remat
    /// tests and benches construct remat/budgeted/unlimited libraries
    /// side by side with this. SIMD level still comes from `ADAMA_SIMD`.
    pub fn host_with_plan(threads: usize, plan: MemoryPlan) -> Arc<Self> {
        Self::with_executor(
            Arc::new(HostExecutor::with_plan(threads, plan)),
            Manifest::builtin(),
        )
    }

    /// Explicit pool size, activation stash plan and SIMD dispatch level
    /// (the API twin of `ADAMA_SIMD`) — the SIMD parity tests and the
    /// `perf_microbench` SIMD-vs-scalar rows construct scalar/vector
    /// libraries side by side with this. GEMM engine still comes from
    /// `ADAMA_GEMM`.
    pub fn host_with_simd(threads: usize, plan: MemoryPlan, level: simd::Level) -> Arc<Self> {
        Self::with_executor(
            Arc::new(HostExecutor::with_simd(threads, plan, level)),
            Manifest::builtin(),
        )
    }

    /// Fully explicit host library: pool size, activation stash plan,
    /// SIMD dispatch level and GEMM engine (the API twin of
    /// `ADAMA_GEMM`) — the GEMM parity sweeps and the `perf_microbench`
    /// packed-vs-naive rows construct both engines side by side with
    /// this.
    pub fn host_with_gemm(
        threads: usize,
        plan: MemoryPlan,
        level: simd::Level,
        gemm: GemmMode,
    ) -> Arc<Self> {
        Self::with_executor(
            Arc::new(HostExecutor::with_gemm(threads, plan, level, gemm)),
            Manifest::builtin(),
        )
    }

    /// Fully explicit host library including the update-rule override
    /// (the API twin of `ADAMA_OPT`): `Some(algo)` makes
    /// `optim::build_optimizer` build that zoo rule regardless of the
    /// training config; `None` keeps the configured optimizer. The
    /// optimizer-zoo parity suites construct per-rule libraries side by
    /// side with this.
    pub fn host_with_opt(
        threads: usize,
        plan: MemoryPlan,
        level: simd::Level,
        gemm: GemmMode,
        opt: Option<OptAlgo>,
    ) -> Arc<Self> {
        Self::with_executor(
            Arc::new(HostExecutor::with_opt(threads, plan, level, gemm, opt)),
            Manifest::builtin(),
        )
    }

    /// Same manifest, host executor re-pinned to `threads` pool workers;
    /// non-host backends (and already-matching pools under the remat
    /// default) are returned unchanged. The DP/ZeRO thread simulators
    /// call this **once per rank** so M ranks don't fan out into M·T
    /// pool threads — and, when an activation stash budget is set, so
    /// every rank owns a private arena (the fork then happens even at a
    /// matching thread count).
    pub fn fork_with_threads(self: &Arc<Self>, threads: usize) -> Arc<Self> {
        if self.executor.platform() != "host" {
            return self.clone();
        }
        // carry the activation plan over so forked ranks keep the same
        // stash-vs-remat behaviour (encode/decode both live in actmem).
        // The None arm is unreachable today — non-host executors returned
        // above and the host executor always reports MemStats — so the
        // env fallback is a safe default for hypothetical uninstrumented
        // host-like backends, not a parse path (invalid env degrades to
        // remat here rather than failing an infallible fork)
        let plan = match self.executor.memory() {
            Some(m) => MemoryPlan::from_budget_bytes(m.stash_budget_bytes),
            None => MemoryPlan::from_env().unwrap_or_else(|_| MemoryPlan::remat()),
        };
        // with stashing enabled, concurrently-running ranks must NOT
        // share one arena/meter (interleaving-dependent accounting,
        // cross-rank eviction) — fork even at a matching thread count so
        // each rank gets a private arena
        if self.executor.threads() == threads && plan == MemoryPlan::remat() {
            return self.clone();
        }
        // forked ranks keep the parent's SIMD dispatch level and GEMM
        // engine, so a rank fork is bit-identical to (and as fast as)
        // the parent executor
        let level = self
            .executor
            .simd_level()
            .unwrap_or_else(|| simd::Level::from_env().unwrap_or_else(|_| simd::detect()));
        let gemm = self
            .executor
            .gemm_mode()
            .unwrap_or_else(|| GemmMode::from_env().unwrap_or(GemmMode::Packed));
        // the update-rule override travels with the fork too, so DP/ZeRO
        // ranks build the same optimizer the parent library would
        let opt = self.executor.opt_algo();
        Self::with_executor(
            Arc::new(HostExecutor::with_opt(threads, plan, level, gemm, opt)),
            self.manifest.clone(),
        )
    }

    /// Fork this host library with the update-rule override replaced by
    /// `opt` (threads, activation plan, SIMD level and GEMM engine are
    /// carried over). Unlike [`Library::fork_with_threads`] this always
    /// builds a fresh executor when the override changes — `DpSpec` /
    /// `Zero1Spec` `with_opt` route through here so an explicit spec
    /// selection beats the ambient `ADAMA_OPT`. Non-host backends are
    /// returned unchanged (they have no seam to override).
    pub fn fork_with_opt(self: &Arc<Self>, opt: Option<OptAlgo>) -> Arc<Self> {
        if self.executor.platform() != "host" {
            return self.clone();
        }
        if self.executor.opt_algo() == opt {
            return self.clone();
        }
        let plan = match self.executor.memory() {
            Some(m) => MemoryPlan::from_budget_bytes(m.stash_budget_bytes),
            None => MemoryPlan::from_env().unwrap_or_else(|_| MemoryPlan::remat()),
        };
        let level = self
            .executor
            .simd_level()
            .unwrap_or_else(|| simd::Level::from_env().unwrap_or_else(|_| simd::detect()));
        let gemm = self
            .executor
            .gemm_mode()
            .unwrap_or_else(|| GemmMode::from_env().unwrap_or(GemmMode::Packed));
        Self::with_executor(
            Arc::new(HostExecutor::with_opt(self.executor.threads(), plan, level, gemm, opt)),
            self.manifest.clone(),
        )
    }

    /// Library over an explicit executor + manifest pair.
    pub fn with_executor(executor: Arc<dyn Executor>, manifest: Manifest) -> Arc<Self> {
        Arc::new(Self { executor, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Open the artifact directory produced by `make artifacts` on a PJRT
    /// engine.
    #[cfg(feature = "pjrt")]
    pub fn open(root: impl AsRef<std::path::Path>, engine: Arc<Engine>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let manifest = Manifest::load(root.join("manifest.json"))?;
        Ok(Self {
            executor: Arc::new(PjrtExecutor::new(root, engine)),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Locate the artifact root: `$ADAMA_ARTIFACTS`, `./artifacts`, or the
    /// crate-relative default (useful for tests/benches run from anywhere).
    pub fn default_root() -> PathBuf {
        if let Ok(p) = std::env::var("ADAMA_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("manifest.json").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Strictly parse an `ADAMA_BACKEND` value: `host`/`pjrt` force a
    /// backend, unset/empty auto-selects; anything else is an error
    /// naming the accepted values.
    pub fn parse_backend(spec: Option<&str>) -> Result<&'static str> {
        match spec.map(str::trim).unwrap_or("") {
            "" => Ok(""),
            "host" => Ok("host"),
            "pjrt" => Ok("pjrt"),
            other => bail!("unknown ADAMA_BACKEND '{other}' (expected host|pjrt, unset = auto)"),
        }
    }

    /// Open the default library.
    ///
    /// With the `pjrt` feature and an artifact directory present this is
    /// the PJRT backend; otherwise the pure-rust host executor with the
    /// built-in manifest. `ADAMA_BACKEND=host` forces the host executor;
    /// `ADAMA_BACKEND=pjrt` fails loudly instead of falling back — as do
    /// invalid `ADAMA_THREADS`/`ADAMA_SIMD`/`ADAMA_ACT_BUDGET` values.
    pub fn open_default() -> Result<Arc<Self>> {
        let forced = Self::parse_backend(std::env::var("ADAMA_BACKEND").ok().as_deref())?;
        if forced == "pjrt" && !cfg!(feature = "pjrt") {
            bail!("ADAMA_BACKEND=pjrt but this build lacks the `pjrt` cargo feature");
        }
        if forced != "host" {
            if let Some(lib) = Self::try_open_pjrt()? {
                return Ok(lib);
            }
            if forced == "pjrt" {
                bail!(
                    "ADAMA_BACKEND=pjrt but no artifacts at {} (run `make artifacts`)",
                    Self::default_root().display()
                );
            }
        }
        Self::try_host()
    }

    /// PJRT arm of [`Library::open_default`]: `Some` when the feature is
    /// compiled in and an artifact directory exists.
    #[cfg(feature = "pjrt")]
    fn try_open_pjrt() -> Result<Option<Arc<Self>>> {
        let root = Self::default_root();
        if !root.join("manifest.json").exists() {
            return Ok(None);
        }
        let engine = Arc::new(Engine::cpu()?);
        Ok(Some(Arc::new(Self::open(root, engine)?)))
    }

    #[cfg(not(feature = "pjrt"))]
    fn try_open_pjrt() -> Result<Option<Arc<Self>>> {
        Ok(None)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The backend this library dispatches to.
    pub fn executor(&self) -> &Arc<dyn Executor> {
        &self.executor
    }

    /// Manifest entry (shapes/dtypes) for `group/name`.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .entry(name)
            .with_context(|| format!("no program '{name}' in manifest"))
    }

    /// Load (or fetch from cache) the program for `group/name`.
    pub fn get(&self, name: &str) -> Result<Arc<dyn Program>> {
        if let Some(p) = self.cache.lock().unwrap().get(name) {
            return Ok(p.clone());
        }
        let entry = self.entry(name)?;
        let prog = self
            .executor
            .load(name, entry, &self.manifest)
            .with_context(|| format!("loading program '{name}'"))?;
        self.cache.lock().unwrap().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Eagerly load a set of programs (startup warm-up).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Number of loaded programs currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_library_loads_and_caches_programs() {
        let lib = Library::host();
        assert_eq!(lib.executor().platform(), "host");
        let a = lib.get("common/adama_acc_16384").unwrap();
        let n = lib.compiled_count();
        let _b = lib.get("common/adama_acc_16384").unwrap();
        assert_eq!(lib.compiled_count(), n, "cache must be reused");
        // programs execute and bump the call counter
        let m = vec![0.0f32; 8];
        let out = a
            .run(&[
                Arg::F32(&m, &[8]),
                Arg::F32(&m, &[8]),
                Arg::F32(&m, &[8]),
                Arg::F32(&[1.0], &[1]),
            ])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!(lib.executor().exec_calls() >= 1);
    }

    #[test]
    fn fork_with_threads_repins_the_host_pool() {
        let lib = Library::host_with_threads(3);
        assert_eq!(lib.executor().threads(), 3);
        let serial = lib.fork_with_threads(1);
        assert_eq!(serial.executor().threads(), 1);
        assert_eq!(serial.executor().platform(), "host");
        // same pool size: no re-wrap, the same library comes back
        let same = lib.fork_with_threads(3);
        assert!(Arc::ptr_eq(&lib, &same));
        // forked library still resolves the same manifest
        assert!(serial.get("common/adama_acc_16384").is_ok());
    }

    #[test]
    fn backend_spec_parse_is_strict() {
        assert_eq!(Library::parse_backend(None).unwrap(), "");
        assert_eq!(Library::parse_backend(Some("")).unwrap(), "");
        assert_eq!(Library::parse_backend(Some(" host ")).unwrap(), "host");
        assert_eq!(Library::parse_backend(Some("pjrt")).unwrap(), "pjrt");
        let err = Library::parse_backend(Some("tpu")).unwrap_err();
        assert!(format!("{err}").contains("host|pjrt"), "{err}");
    }

    #[test]
    fn opt_override_travels_with_forks() {
        let lib = Library::host_with_opt(
            2,
            MemoryPlan::remat(),
            simd::Level::Scalar,
            GemmMode::Naive,
            Some(OptAlgo::Sm3),
        );
        assert_eq!(lib.executor().opt_algo(), Some(OptAlgo::Sm3));
        // thread re-pin carries the override
        let serial = lib.fork_with_threads(1);
        assert_eq!(serial.executor().opt_algo(), Some(OptAlgo::Sm3));
        assert_eq!(serial.executor().gemm_mode(), Some(GemmMode::Naive));
        // matching override: no re-wrap
        let same = lib.fork_with_opt(Some(OptAlgo::Sm3));
        assert!(Arc::ptr_eq(&lib, &same));
        // changed override: fresh executor, other knobs carried
        let mini = lib.fork_with_opt(Some(OptAlgo::AdamMini));
        assert_eq!(mini.executor().opt_algo(), Some(OptAlgo::AdamMini));
        assert_eq!(mini.executor().threads(), 2);
        assert_eq!(mini.executor().simd_level(), Some(simd::Level::Scalar));
        let cleared = mini.fork_with_opt(None);
        assert_eq!(cleared.executor().opt_algo(), None);
    }

    #[test]
    fn unknown_program_is_a_clear_error() {
        let lib = Library::host();
        let err = lib.get("common/definitely_missing_1").unwrap_err();
        assert!(format!("{err:?}").contains("definitely_missing"), "{err:?}");
    }
}
