//! Host implementations of the `mlp_*/{mlp_train, mlp_eval}` programs,
//! mirroring `python/compile/model.py::make_mlp_train / make_mlp_eval`.
//!
//! Model: `logits = relu(x @ W1 + b1) @ W2 + b2`, mean token cross-entropy
//! over the micro-batch. `mlp_train` returns the loss and the gradients
//! w.r.t. (W1, b1, W2, b2) — not x — exactly like the lowered artifact.
//!
//! The matmuls and the softmax run on the executor's deterministic thread
//! pool and dispatch through the bit-exact SIMD layer
//! ([`crate::runtime::simd`]) and the packed GEMM engine
//! ([`super::gemm`], `ADAMA_GEMM`); the element-wise relu maps stay
//! serial scalar (trivial next to the matmuls, and `f32::max` NaN/−0.0
//! semantics are not worth re-stating in lanes).
//!
//! The MLP is a single fused fwd+bwd program, so there is nothing to
//! stash — but its transient workspace is metered through the executor's
//! [`super::actmem::WsMeter`] like the transformer's, so the host
//! executor's measured activation accounting covers every model program.
//! That includes the single B-panel packing buffer the packed GEMM
//! engine uses: each `run` allocates one panel sized to the largest
//! matmul it will issue (zero elements under the naive engine) and
//! meters it up front.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::actmem::ActivationArena;
use super::gemm::{self, GemmMode};
use super::math;
use crate::runtime::exec::{Arg, Program, Value};
use crate::runtime::manifest::MlpHyper;
use crate::runtime::pool::ThreadPool;
use crate::runtime::simd;

pub(super) fn build(
    short: &str,
    hyper: &MlpHyper,
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    level: simd::Level,
    gm: GemmMode,
) -> Result<Box<dyn Program>> {
    let (hyper, simd, gemm) = (hyper.clone(), level, gm);
    match short {
        "mlp_train" => Ok(Box::new(MlpProgram { hyper, train: true, pool, arena, simd, gemm })),
        "mlp_eval" => Ok(Box::new(MlpProgram { hyper, train: false, pool, arena, simd, gemm })),
        other => bail!("host executor: unknown mlp program '{other}'"),
    }
}

/// Largest B-panel (in f32 elements) any matmul in one `run` call packs:
/// forward needs `x@W1` ([b,d]·[d,hd]) and `relu@W2` ([b,hd]·[hd,c]);
/// training adds the three gradient matmuls. Zero under the naive engine
/// (no packing buffer at all).
fn panel_elems_for(gm: GemmMode, train: bool, b: usize, d: usize, hd: usize, c: usize) -> usize {
    if gm == GemmMode::Naive {
        return 0;
    }
    let pe = gemm::panel_elems;
    let fwd = pe(d, hd).max(pe(hd, c));
    if !train {
        return fwd;
    }
    fwd.max(pe(b, c)).max(pe(c, hd)).max(pe(b, hd))
}

struct MlpProgram {
    hyper: MlpHyper,
    train: bool,
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
}

struct MlpArgs<'a> {
    x: &'a [f32],
    labels: &'a [i32],
    w1: &'a [f32],
    b1: &'a [f32],
    w2: &'a [f32],
    b2: &'a [f32],
    batch: usize,
}

impl MlpProgram {
    fn unpack<'a>(&self, args: &[Arg<'a>]) -> Result<MlpArgs<'a>> {
        ensure!(args.len() == 6, "mlp program takes 6 args, got {}", args.len());
        let (d, hd, c) = (self.hyper.features, self.hyper.hidden, self.hyper.classes);
        let x = args[0].f32().context("mlp x")?;
        let labels = args[1].i32().context("mlp labels")?;
        ensure!(!labels.is_empty(), "mlp: empty batch");
        ensure!(x.len() == labels.len() * d, "mlp: x/labels shape mismatch");
        let w1 = args[2].f32()?;
        let b1 = args[3].f32()?;
        let w2 = args[4].f32()?;
        let b2 = args[5].f32()?;
        ensure!(w1.len() == d * hd, "mlp W1 shape");
        ensure!(b1.len() == hd, "mlp b1 shape");
        ensure!(w2.len() == hd * c, "mlp W2 shape");
        ensure!(b2.len() == c, "mlp b2 shape");
        for &l in labels {
            ensure!((0..c as i32).contains(&l), "mlp label {l} out of range 0..{c}");
        }
        Ok(MlpArgs { x, labels, w1, b1, w2, b2, batch: labels.len() })
    }
}

impl Program for MlpProgram {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        let a = self.unpack(args)?;
        let (d, hd, c) = (self.hyper.features, self.hyper.hidden, self.hyper.classes);
        let b = a.batch;
        let pool = &self.pool;
        let lvl = self.simd;
        let gm = self.gemm;
        let mut ws = self.arena.ws().scope();

        // one B-panel packing buffer serves every matmul in this call
        let mut panel = vec![0.0f32; panel_elems_for(gm, self.train, b, d, hd, c)];
        ws.add(panel.len());

        // forward
        let mut h1 = vec![0.0f32; b * hd];
        ws.add(h1.len());
        math::matmul(pool, lvl, gm, &mut panel, a.x, a.w1, b, d, hd, &mut h1);
        math::add_bias(lvl, &mut h1, a.b1);
        let hr: Vec<f32> = h1.iter().map(|&v| v.max(0.0)).collect();
        ws.add(hr.len());
        let mut logits = vec![0.0f32; b * c];
        ws.add(logits.len());
        math::matmul(pool, lvl, gm, &mut panel, &hr, a.w2, b, hd, c, &mut logits);
        math::add_bias(lvl, &mut logits, a.b2);

        let mut dlogits = vec![0.0f32; b * c];
        ws.add(dlogits.len());
        let (nll, ncorrect) = math::softmax_xent(pool, lvl, &logits, a.labels, b, c, &mut dlogits);
        let loss = (nll / b as f64) as f32;

        if !self.train {
            return Ok(vec![Value::scalar_f32(loss), Value::scalar_i32(ncorrect)]);
        }

        // backward (mean loss: scale softmax-onehot by 1/B, lane-parallel)
        let inv_b = 1.0 / b as f32;
        simd::scale(lvl, &mut dlogits, inv_b);
        let mut dw2 = vec![0.0f32; hd * c];
        math::matmul_tn(pool, lvl, gm, &mut panel, &hr, &dlogits, b, hd, c, &mut dw2);
        let mut db2 = vec![0.0f32; c];
        math::col_sums(&dlogits, b, c, &mut db2);
        let mut dhr = vec![0.0f32; b * hd];
        math::matmul_nt(pool, lvl, gm, &mut panel, &dlogits, a.w2, b, c, hd, &mut dhr);
        ws.add(dw2.len() + db2.len() + dhr.len());
        // relu'
        let dh1: Vec<f32> =
            dhr.iter().zip(&h1).map(|(&g, &u)| if u > 0.0 { g } else { 0.0 }).collect();
        ws.add(dh1.len());
        let mut dw1 = vec![0.0f32; d * hd];
        math::matmul_tn(pool, lvl, gm, &mut panel, a.x, &dh1, b, d, hd, &mut dw1);
        let mut db1 = vec![0.0f32; hd];
        math::col_sums(&dh1, b, hd, &mut db1);
        ws.add(dw1.len() + db1.len());

        Ok(vec![
            Value::scalar_f32(loss),
            Value::f32(dw1, &[d, hd])?,
            Value::f32(db1, &[hd])?,
            Value::f32(dw2, &[hd, c])?,
            Value::f32(db2, &[c])?,
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn hyper() -> MlpHyper {
        MlpHyper { features: 5, hidden: 7, classes: 3, microbatch: 4 }
    }

    fn tp() -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(1))
    }

    fn ar() -> Arc<ActivationArena> {
        Arc::new(ActivationArena::new(super::super::actmem::MemoryPlan::remat()))
    }

    fn lv() -> simd::Level {
        simd::Level::from_env().expect("valid ADAMA_SIMD")
    }

    fn gm() -> GemmMode {
        GemmMode::from_env().expect("valid ADAMA_GEMM")
    }

    fn prog(train: bool) -> MlpProgram {
        MlpProgram { hyper: hyper(), train, pool: tp(), arena: ar(), simd: lv(), gemm: gm() }
    }

    struct Setup {
        x: Vec<f32>,
        labels: Vec<i32>,
        w1: Vec<f32>,
        b1: Vec<f32>,
        w2: Vec<f32>,
        b2: Vec<f32>,
    }

    fn setup() -> Setup {
        let h = hyper();
        let mut rng = Rng::new(11);
        let b = 4usize;
        Setup {
            x: (0..b * h.features).map(|_| rng.normal()).collect(),
            labels: (0..b).map(|_| rng.below(h.classes) as i32).collect(),
            w1: (0..h.features * h.hidden).map(|_| 0.5 * rng.normal()).collect(),
            b1: (0..h.hidden).map(|_| 0.1 * rng.normal()).collect(),
            w2: (0..h.hidden * h.classes).map(|_| 0.5 * rng.normal()).collect(),
            b2: (0..h.classes).map(|_| 0.1 * rng.normal()).collect(),
        }
    }

    fn loss_of(s: &Setup) -> f32 {
        let prog = prog(false);
        let out = prog
            .run(&[
                Arg::F32(&s.x, &[4, 5]),
                Arg::I32(&s.labels, &[4]),
                Arg::F32(&s.w1, &[5, 7]),
                Arg::F32(&s.b1, &[7]),
                Arg::F32(&s.w2, &[7, 3]),
                Arg::F32(&s.b2, &[3]),
            ])
            .unwrap();
        out[0].first_f32().unwrap()
    }

    #[test]
    fn train_grads_match_finite_differences() {
        let s = setup();
        let prog = prog(true);
        let out = prog
            .run(&[
                Arg::F32(&s.x, &[4, 5]),
                Arg::I32(&s.labels, &[4]),
                Arg::F32(&s.w1, &[5, 7]),
                Arg::F32(&s.b1, &[7]),
                Arg::F32(&s.w2, &[7, 3]),
                Arg::F32(&s.b2, &[3]),
            ])
            .unwrap();
        assert_eq!(out.len(), 5);

        let eps = 1e-2f32;
        let tol = |fd: f32, an: f32| (fd - an).abs() < 0.01 + 0.05 * fd.abs().max(an.abs());

        // dW1
        let dw1 = out[1].as_f32().unwrap();
        for i in 0..dw1.len() {
            let mut sp = setup();
            sp.w1[i] += eps;
            let mut sm = setup();
            sm.w1[i] -= eps;
            let fd = (loss_of(&sp) - loss_of(&sm)) / (2.0 * eps);
            assert!(tol(fd, dw1[i]), "dW1[{i}]: fd {fd} vs {}", dw1[i]);
        }
        // db1
        let db1 = out[2].as_f32().unwrap();
        for i in 0..db1.len() {
            let mut sp = setup();
            sp.b1[i] += eps;
            let mut sm = setup();
            sm.b1[i] -= eps;
            let fd = (loss_of(&sp) - loss_of(&sm)) / (2.0 * eps);
            assert!(tol(fd, db1[i]), "db1[{i}]: fd {fd} vs {}", db1[i]);
        }
        // dW2
        let dw2 = out[3].as_f32().unwrap();
        for i in 0..dw2.len() {
            let mut sp = setup();
            sp.w2[i] += eps;
            let mut sm = setup();
            sm.w2[i] -= eps;
            let fd = (loss_of(&sp) - loss_of(&sm)) / (2.0 * eps);
            assert!(tol(fd, dw2[i]), "dW2[{i}]: fd {fd} vs {}", dw2[i]);
        }
        // db2
        let db2 = out[4].as_f32().unwrap();
        for i in 0..db2.len() {
            let mut sp = setup();
            sp.b2[i] += eps;
            let mut sm = setup();
            sm.b2[i] -= eps;
            let fd = (loss_of(&sp) - loss_of(&sm)) / (2.0 * eps);
            assert!(tol(fd, db2[i]), "db2[{i}]: fd {fd} vs {}", db2[i]);
        }
    }

    #[test]
    fn eval_counts_correct_predictions() {
        let s = setup();
        let prog = prog(false);
        let out = prog
            .run(&[
                Arg::F32(&s.x, &[4, 5]),
                Arg::I32(&s.labels, &[4]),
                Arg::F32(&s.w1, &[5, 7]),
                Arg::F32(&s.b1, &[7]),
                Arg::F32(&s.w2, &[7, 3]),
                Arg::F32(&s.b2, &[3]),
            ])
            .unwrap();
        let loss = out[0].first_f32().unwrap();
        let ncorrect = out[1].first_i32().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0..=4).contains(&ncorrect));
    }

    #[test]
    fn rejects_malformed_arguments() {
        let s = setup();
        let prog = prog(true);
        // wrong arg count
        assert!(prog.run(&[Arg::F32(&s.x, &[4, 5])]).is_err());
        // out-of-range label
        let bad = vec![99i32; 4];
        assert!(prog
            .run(&[
                Arg::F32(&s.x, &[4, 5]),
                Arg::I32(&bad, &[4]),
                Arg::F32(&s.w1, &[5, 7]),
                Arg::F32(&s.b1, &[7]),
                Arg::F32(&s.w2, &[7, 3]),
                Arg::F32(&s.b2, &[3]),
            ])
            .is_err());
    }
}
