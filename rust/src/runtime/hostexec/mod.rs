//! Pure-rust host execution backend — the reference implementation of
//! every program in the manifest.
//!
//! [`HostExecutor`] dispatches on program *names* (the same
//! `"group/name"` scheme the manifest uses):
//!
//! * `common/<op>_<chunk>` — optimizer kernels ([`kernels`], mirroring
//!   `python/compile/kernels/ref.py`);
//! * `mlp_<cfg>/{mlp_train, mlp_eval}` — the MLP classifier (`mlp`);
//! * `<cfg>/{embed_fwd, embed_bwd, block_fwd, block_bwd, head_loss,
//!   head_eval}` — the per-layer transformer LM (`transformer`);
//! * `<cfg>/{embed_decode, block_decode, head_logits}` — the forward-only
//!   ragged-batch decode variants the serving engine ([`crate::serve`])
//!   drives against a per-sequence KV cache; bit-identical to the
//!   full-context forward (see the `transformer` module docs).
//!
//! With this backend the full training stack — `Trainer`, `MlpTrainer`,
//! the optimizer zoo, the DP/ZeRO thread simulators and the memory
//! tracker — runs end-to-end with zero native dependencies.
//!
//! ## Parallelism & the determinism contract
//!
//! Every program runs its hot loops on the executor's in-tree
//! deterministic thread pool ([`crate::runtime::pool`]): matmuls,
//! layer norm, softmax-xent and attention split output rows across
//! workers; the chunked optimizer kernels split element spans. Work is
//! assigned as fixed contiguous ranges (no stealing) and each output
//! element is written by exactly one worker with unchanged per-element
//! arithmetic order, while cross-row reductions stay serial — so **every
//! program is bit-for-bit identical at any thread count**
//! (`rust/tests/determinism.rs` enforces this at `ADAMA_THREADS=1,2,3,8`).
//!
//! Thread count: `ADAMA_THREADS` (default: available parallelism);
//! [`HostExecutor::with_threads`] pins it programmatically — the DP/ZeRO
//! simulators pin 1 thread per rank via `Library::fork_with_threads`.
//!
//! The lane-parallel inner loops (optimizer kernels, matmul tiles,
//! layer-norm, the element-wise softmax/attention stages) additionally
//! dispatch through [`crate::runtime::simd`] — `ADAMA_SIMD` /
//! [`HostExecutor::with_simd`] pick scalar, SSE2, AVX2 or NEON code
//! paths that are **bit-for-bit identical** by construction, so the
//! determinism contract is unchanged (`rust/tests/simd_parity.rs`).
//!
//! The matmul variants further dispatch on the [`gemm`] engine —
//! `ADAMA_GEMM` / [`HostExecutor::with_gemm`] pick the packed,
//! cache-blocked engine (default) or the naive A/B baseline. Both are
//! bit-identical (the per-element fold order survives blocking — see
//! the `gemm` module docs), so the engine, like the thread count and
//! SIMD level, is a pure performance knob.
//!
//! ## Activation memory: stash vs recompute
//!
//! `block_bwd` rematerialises its forward by default (the artifact
//! contract). Setting an activation byte budget — `ADAMA_ACT_BUDGET`
//! (`0` = remat, `<n>[k|m|g]` = byte cap, `unlimited`) or
//! [`HostExecutor::with_plan`] — lets `block_fwd` **stash** its
//! intermediates into a tracked [`actmem::ActivationArena`] that
//! `block_bwd` consumes, trading activation bytes for the recompute.
//! Stashed and rematerialised backward are bit-identical (the stash
//! holds exactly what recompute would rebuild, and hits require a
//! bit-for-bit input match), so the budget is a pure memory/throughput
//! knob — `rust/tests/actstash.rs` locks this down and reconciles the
//! arena's live/peak accounting against `crate::memmodel` predictions.
//! [`Executor::memory`] exposes the measured counters.

pub mod actmem;
pub mod gemm;
pub mod math;

pub mod kernels;
mod mlp;
mod transformer;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use self::actmem::{ActivationArena, MemoryPlan};
use self::gemm::GemmMode;
use super::exec::{Arg, Executor, MemStats, Program, Value};
use super::manifest::{ArtifactEntry, Manifest};
use super::optstep::OptAlgo;
use super::pool::{self, ThreadPool};
use super::simd;

/// The always-available pure-rust executor.
pub struct HostExecutor {
    calls: Arc<AtomicU64>,
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
    opt: Option<OptAlgo>,
}

impl Default for HostExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl HostExecutor {
    /// Pool size from `ADAMA_THREADS` / available parallelism; activation
    /// plan from `ADAMA_ACT_BUDGET` (default: pure remat); SIMD level
    /// from `ADAMA_SIMD` (default: best the CPU supports). Invalid env
    /// values are clear errors naming the accepted spellings — the
    /// `Library::open_default` path surfaces them instead of silently
    /// falling back.
    pub fn try_new() -> Result<Self> {
        Self::try_with_threads(pool::default_threads()?)
    }

    /// [`Self::try_new`], panicking (with the underlying message) on an
    /// invalid `ADAMA_*` environment.
    pub fn new() -> Self {
        Self::try_new().expect("invalid ADAMA_* environment")
    }

    /// Pin the intra-program pool to `threads` workers (1 = fully serial);
    /// activation plan still comes from `ADAMA_ACT_BUDGET`, SIMD level
    /// from `ADAMA_SIMD`, GEMM engine from `ADAMA_GEMM`.
    pub fn try_with_threads(threads: usize) -> Result<Self> {
        Ok(Self::with_opt(
            threads,
            MemoryPlan::from_env()?,
            simd::Level::from_env()?,
            GemmMode::from_env()?,
            OptAlgo::from_env()?,
        ))
    }

    /// [`Self::try_with_threads`], panicking on an invalid environment.
    pub fn with_threads(threads: usize) -> Self {
        Self::try_with_threads(threads).expect("invalid ADAMA_* environment")
    }

    /// Explicit pool size + activation stash plan; SIMD level still comes
    /// from `ADAMA_SIMD` (panics on an invalid value — construct through
    /// [`Self::with_simd`] for a fully explicit executor).
    pub fn with_plan(threads: usize, plan: MemoryPlan) -> Self {
        Self::with_simd(
            threads,
            plan,
            simd::Level::from_env().expect("invalid ADAMA_SIMD environment"),
        )
    }

    /// Explicit pool size, activation plan and SIMD level; the GEMM
    /// engine still comes from `ADAMA_GEMM` (panics on an invalid value —
    /// construct through [`Self::with_gemm`] for a fully explicit
    /// executor).
    pub fn with_simd(threads: usize, plan: MemoryPlan, level: simd::Level) -> Self {
        Self::with_gemm(
            threads,
            plan,
            level,
            GemmMode::from_env().expect("invalid ADAMA_GEMM environment"),
        )
    }

    /// Explicit pool size, activation plan, SIMD level and GEMM engine;
    /// the update-rule override still comes from `ADAMA_OPT` (panics on
    /// an invalid value — construct through [`Self::with_opt`] for a
    /// fully explicit executor). Every level and both engines are
    /// bit-identical (the SIMD layer's contract plus the packed engine's
    /// fold-order proof, see [`crate::runtime::simd`] and [`gemm`]), so
    /// those — like the thread count — are pure performance knobs.
    pub fn with_gemm(threads: usize, plan: MemoryPlan, level: simd::Level, gemm: GemmMode) -> Self {
        Self::with_opt(
            threads,
            plan,
            level,
            gemm,
            OptAlgo::from_env().expect("invalid ADAMA_OPT environment"),
        )
    }

    /// Fully explicit construction: pool size, activation stash plan,
    /// SIMD dispatch level, GEMM engine and update-rule override (the
    /// API twin of `ADAMA_OPT`; `None` keeps the configured optimizer).
    /// Unlike the other knobs the update rule is *not* a pure
    /// performance knob — it selects which optimizer the training stack
    /// builds (`optim::build_optimizer` resolves it before the config).
    pub fn with_opt(
        threads: usize,
        plan: MemoryPlan,
        level: simd::Level,
        gemm: GemmMode,
        opt: Option<OptAlgo>,
    ) -> Self {
        Self {
            calls: Arc::new(AtomicU64::new(0)),
            pool: Arc::new(ThreadPool::new(threads)),
            arena: Arc::new(ActivationArena::new(plan)),
            simd: level,
            gemm,
            opt,
        }
    }

    /// The executor's activation stash arena (shared by all of its block
    /// programs).
    pub fn arena(&self) -> &Arc<ActivationArena> {
        &self.arena
    }

    /// The executor's SIMD dispatch level.
    pub fn simd(&self) -> simd::Level {
        self.simd
    }

    /// The executor's GEMM engine.
    pub fn gemm(&self) -> GemmMode {
        self.gemm
    }
}

/// Call-counting wrapper so [`Executor::exec_calls`] mirrors the PJRT
/// engine's execute-call instrumentation.
struct Counted {
    inner: Box<dyn Program>,
    calls: Arc<AtomicU64>,
}

impl Program for Counted {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.run(args)
    }
}

impl Executor for HostExecutor {
    fn platform(&self) -> String {
        "host".to_string()
    }

    fn load(
        &self,
        name: &str,
        _entry: &ArtifactEntry,
        manifest: &Manifest,
    ) -> Result<Arc<dyn Program>> {
        let (group, short) = name
            .split_once('/')
            .with_context(|| format!("host executor: program name '{name}' lacks a group"))?;
        let inner: Box<dyn Program> = if group == "common" {
            kernels::build(short, &manifest.hyper, self.pool.clone(), self.simd)?
        } else if let Some(mlp_name) = group.strip_prefix("mlp_") {
            let cfg = manifest.mlp_config(mlp_name)?;
            mlp::build(
                short,
                &cfg.model,
                self.pool.clone(),
                self.arena.clone(),
                self.simd,
                self.gemm,
            )?
        } else {
            let cfg = manifest.model_config(group)?;
            transformer::build(
                short,
                &cfg.model,
                self.pool.clone(),
                self.arena.clone(),
                self.simd,
                self.gemm,
            )?
        };
        Ok(Arc::new(Counted { inner, calls: self.calls.clone() }))
    }

    fn exec_calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn simd_level(&self) -> Option<simd::Level> {
        Some(self.simd)
    }

    fn gemm_mode(&self) -> Option<GemmMode> {
        Some(self.gemm)
    }

    fn opt_algo(&self) -> Option<OptAlgo> {
        self.opt
    }

    fn memory(&self) -> Option<MemStats> {
        Some(self.arena.stats())
    }

    fn clear_stash(&self) {
        self.arena.clear();
    }

    fn kv_alloc(&self, bytes: u64) {
        self.arena.kv_alloc(bytes);
    }

    fn kv_free(&self, bytes: u64) {
        self.arena.kv_free(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_every_builtin_program() {
        let manifest = Manifest::builtin();
        let exec = HostExecutor::with_threads(2);
        assert_eq!(exec.threads(), 2);
        // every manifest entry must resolve to a host implementation
        let mut names: Vec<String> = Vec::new();
        for key in manifest.common.keys() {
            names.push(format!("common/{key}"));
        }
        for (cfg, entry) in &manifest.configs {
            for key in entry.artifacts.keys() {
                names.push(format!("{cfg}/{key}"));
            }
        }
        for (cfg, entry) in &manifest.mlp_configs {
            for key in entry.artifacts.keys() {
                names.push(format!("mlp_{cfg}/{key}"));
            }
        }
        assert!(names.len() > 40, "builtin manifest unexpectedly small");
        for name in names {
            let entry = manifest.entry(&name).unwrap_or_else(|| panic!("no entry {name}"));
            exec.load(&name, entry, &manifest)
                .unwrap_or_else(|e| panic!("cannot load {name}: {e:?}"));
        }
    }

    #[test]
    fn call_counter_increments() {
        let manifest = Manifest::builtin();
        let exec = HostExecutor::with_threads(1);
        let entry = manifest.entry("common/grad_acc_16384").unwrap();
        let prog = exec.load("common/grad_acc_16384", entry, &manifest).unwrap();
        let acc = vec![0.0f32; 4];
        let g = vec![1.0f32; 4];
        let before = exec.exec_calls();
        prog.run(&[Arg::F32(&acc, &[4]), Arg::F32(&g, &[4]), Arg::F32(&[0.5], &[1])])
            .unwrap();
        assert_eq!(exec.exec_calls(), before + 1);
    }
}
