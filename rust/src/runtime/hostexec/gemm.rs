//! Packed, cache-blocked, output-tiled GEMM engine for the host
//! executor's three matmul variants — bit-identical to the naive loops.
//!
//! ## Blocking scheme
//!
//! The driver walks the output in `NC`-column stripes and the reduction
//! axis in `KC`-step blocks. For each `(N block, K block)` pair it packs
//! the corresponding `kc × nc` block of B into a contiguous panel
//! (row-major over the K axis, at most `KC·NC` f32 = 256 KiB, so the
//! panel stays L2-resident and every inner-loop B access is a unit-
//! stride lane load regardless of the source layout). Output rows are
//! then split across the deterministic thread pool in contiguous
//! balanced ranges and each range is swept by the register tile
//! [`crate::runtime::simd::gemm_tile`]: `MR = 4` output rows × one
//! `Lanes`-width column tile held in registers across the whole K block,
//! with the panel's `kc × WIDTH` column tile (≤ 8 KiB) L1-resident
//! across row blocks.
//!
//! ## Why blocking preserves the bit-exactness contract
//!
//! Every output element's K fold stays the naive serial expression tree:
//! K blocks are visited in ascending order, the accumulator starts at
//! `0.0` on the first block and is otherwise reloaded from `out` (an f32
//! store/load round-trip is lossless), each step is multiply-then-add
//! with no FMA, and lanes span adjacent output *columns*, never the
//! reduction axis. Packing only relocates B values. So the packed engine
//! is 0-ULP identical to the naive loops at every block size, thread
//! count, and SIMD level — `rust/tests/proptests.rs` asserts packed ==
//! naive bit-for-bit, and the determinism/parity suites pass unmodified.
//!
//! ## The A-side stride trick
//!
//! A is never packed: the tile reads `a(r, p) = a[a_off + r·ars +
//! p·ads]`, so one driver serves all three variants —
//!
//! * `matmul`    (NN): `ars = k, ads = 1`, B packed from rows;
//! * `matmul_tn` (TN): `ars = 1, ads = m`, B packed from rows;
//! * `matmul_nt` (NT): `ars = k, ads = 1`, B transpose-packed — which is
//!   exactly the lane-parallel *output* tiling of the old scalar dot
//!   products.
//!
//! ## Workspace
//!
//! The packing panel is the engine's only allocation, and it is owned by
//! the **caller**: each host program allocates one panel sized by
//! [`panel_elems`] to the maximum over its matmul shapes, registers it
//! with the actmem workspace meter, and reuses it across every call.
//! `crate::memmodel::HostBlockDims` predicts the same panel bytes
//! analytically from the shared [`KC`]/[`NC`] constants.
//!
//! ## Mode selection
//!
//! [`GemmMode`] (`ADAMA_GEMM`, strict-parsed like every other knob)
//! A/Bs the engine against the naive loops; `packed` is the default.

use anyhow::{bail, Result};

use crate::runtime::pool::{partition, ThreadPool};
use crate::runtime::simd;

/// K-block depth of one packed panel (f32 elements).
pub const KC: usize = 256;

/// N-block width of one packed panel (f32 elements).
pub const NC: usize = 256;

/// Below this many output elements (`m·n`) the driver skips the pool
/// broadcast and runs the tile serially — same cutoff rationale as the
/// pool helpers, and bit-free by the determinism contract.
const SERIAL_CUTOFF: usize = 1024;

/// Panel capacity (f32 elements) one `(k, n)` matmul needs:
/// `min(k, KC) · min(n, NC)`. Callers size their shared panel to the max
/// over every matmul shape they issue; `crate::memmodel` states the same
/// formula on `u64` dims.
pub fn panel_elems(k: usize, n: usize) -> usize {
    k.min(KC) * n.min(NC)
}

/// GEMM engine selector — the API twin of `ADAMA_GEMM`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmMode {
    /// The packed, cache-blocked, output-tiled engine (default).
    Packed,
    /// The original parallelised axpy-row / scalar-dot loops — the A/B
    /// baseline `perf_microbench` gates the packed speedup against.
    Naive,
}

impl GemmMode {
    /// Strictly resolve an `ADAMA_GEMM` value: `packed`/`naive` pin the
    /// engine, `auto`/unset/empty mean packed; any other spelling is an
    /// error naming the accepted values (no silent fallback).
    pub fn parse(spec: Option<&str>) -> Result<GemmMode> {
        let s = match spec.map(str::trim) {
            Some(s) if !s.is_empty() => s.to_ascii_lowercase(),
            _ => return Ok(GemmMode::Packed),
        };
        match s.as_str() {
            "auto" | "packed" => Ok(GemmMode::Packed),
            "naive" => Ok(GemmMode::Naive),
            other => bail!("invalid ADAMA_GEMM '{other}': expected auto|packed|naive"),
        }
    }

    /// Mode from the `ADAMA_GEMM` environment variable.
    pub fn from_env() -> Result<GemmMode> {
        Self::parse(std::env::var("ADAMA_GEMM").ok().as_deref())
    }

    /// Stable lower-case name (the `ADAMA_GEMM` spelling).
    pub fn name(self) -> &'static str {
        match self {
            GemmMode::Packed => "packed",
            GemmMode::Naive => "naive",
        }
    }

    /// Both modes, packed first — the sweep set for parity tests and the
    /// bench's A/B rows.
    pub fn all() -> [GemmMode; 2] {
        [GemmMode::Packed, GemmMode::Naive]
    }
}

/// How the driver reads B when packing a panel.
#[derive(Clone, Copy)]
pub enum BLayout {
    /// `b:[k, n]` row-major — panel rows are contiguous row slices.
    Rows,
    /// `b:[n, k]` row-major (the NT variant) — the pack gathers
    /// `panel[p][jj] = b[jj][p]`, i.e. packing *is* the transpose.
    Trans,
}

/// Raw output base pointer crossing into pool workers; each worker only
/// writes the disjoint row range [`partition`] assigned to it.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Packed-GEMM driver: `out[m, n] = A @ B` with `a(r, p) = a[r·ars +
/// p·ads]` and B described by `blay` (see the module docs). `panel` is
/// the caller-owned packing buffer — grown on demand, but callers are
/// expected to pre-size it via [`panel_elems`] so the metered workspace
/// is exact.
#[allow(clippy::too_many_arguments)]
pub fn packed_gemm(
    pool: &ThreadPool,
    lvl: simd::Level,
    a: &[f32],
    ars: usize,
    ads: usize,
    b: &[f32],
    blay: BLayout,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    panel: &mut Vec<f32>,
) {
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // the naive loops zero-fill (empty fold); match them exactly
        out.fill(0.0);
        return;
    }
    let need = panel_elems(k, n);
    if panel.len() < need {
        panel.resize(need, 0.0);
    }
    let threads = pool.threads();
    let ranges = if threads == 1 || m * n < SERIAL_CUTOFF || m < 2 {
        vec![(0usize, m)]
    } else {
        partition(m, threads)
    };
    let mut jb = 0usize;
    while jb < n {
        let nc = NC.min(n - jb);
        let mut pb = 0usize;
        while pb < k {
            let kc = KC.min(k - pb);
            match blay {
                BLayout::Rows => {
                    for p in 0..kc {
                        let src = &b[(pb + p) * n + jb..(pb + p) * n + jb + nc];
                        panel[p * nc..(p + 1) * nc].copy_from_slice(src);
                    }
                }
                BLayout::Trans => {
                    for p in 0..kc {
                        let row = &mut panel[p * nc..(p + 1) * nc];
                        for (jj, o) in row.iter_mut().enumerate() {
                            *o = b[(jb + jj) * k + pb + p];
                        }
                    }
                }
            }
            let first = pb == 0;
            let packed: &[f32] = &panel[..kc * nc];
            if ranges.len() == 1 {
                simd::gemm_tile(lvl, out, n, jb, nc, a, pb * ads, ars, ads, packed, kc, m, first);
            } else {
                let base = SendPtr(out.as_mut_ptr());
                pool.run(|w| {
                    if let Some(&(r0, cnt)) = ranges.get(w) {
                        // SAFETY: row ranges are disjoint across workers
                        // and `out` outlives `run`, which joins every
                        // worker before returning.
                        let span =
                            unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * n), cnt * n) };
                        let a_off = r0 * ars + pb * ads;
                        simd::gemm_tile(
                            lvl, span, n, jb, nc, a, a_off, ars, ads, packed, kc, cnt, first,
                        );
                    }
                });
            }
            pb += kc;
        }
        jb += nc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vector(seed: u64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let k = (i as u64).wrapping_mul(6364136223846793005).wrapping_add(seed);
                ((k >> 33) as f32) / (1u64 << 31) as f32 - 0.5
            })
            .collect()
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    /// Reference NN matmul: the literal serial fold.
    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn parse_is_strict() {
        assert_eq!(GemmMode::parse(None).unwrap(), GemmMode::Packed);
        assert_eq!(GemmMode::parse(Some("")).unwrap(), GemmMode::Packed);
        assert_eq!(GemmMode::parse(Some("auto")).unwrap(), GemmMode::Packed);
        assert_eq!(GemmMode::parse(Some("packed")).unwrap(), GemmMode::Packed);
        assert_eq!(GemmMode::parse(Some(" Naive ")).unwrap(), GemmMode::Naive);
        let err = GemmMode::parse(Some("fast")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("ADAMA_GEMM") && msg.contains("auto|packed|naive"), "{msg}");
        assert_eq!(GemmMode::all()[0].name(), "packed");
        assert_eq!(GemmMode::all()[1].name(), "naive");
    }

    #[test]
    fn panel_elems_caps_at_block_size() {
        assert_eq!(panel_elems(3, 5), 15);
        assert_eq!(panel_elems(1000, 5), KC * 5);
        assert_eq!(panel_elems(3, 1000), 3 * NC);
        assert_eq!(panel_elems(1000, 1000), KC * NC);
        assert_eq!(panel_elems(0, 7), 0);
    }

    #[test]
    fn packed_nn_matches_naive_across_block_boundaries() {
        let lvl = crate::runtime::simd::detect();
        let pool = ThreadPool::new(1);
        // sizes straddle KC/NC: below, at, and above one block
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (3, 7, 5), (4, 300, 2), (2, 5, 300), (5, 260, 270)]
        {
            let a = vector(1, m * k);
            let b = vector(2, k * n);
            let want = naive_nn(&a, &b, m, k, n);
            let mut got = vec![0.0f32; m * n];
            let mut panel = Vec::new();
            packed_gemm(&pool, lvl, &a, k, 1, &b, BLayout::Rows, m, k, n, &mut got, &mut panel);
            assert_eq!(bits(&got), bits(&want), "({m},{k},{n})");
            assert!(panel.len() <= panel_elems(k, n));
        }
    }

    #[test]
    fn transpose_pack_matches_nt_reference() {
        let lvl = crate::runtime::simd::detect();
        let pool = ThreadPool::new(1);
        let (m, k, n) = (6usize, 270usize, 9usize);
        let a = vector(3, m * k);
        let bt = vector(4, n * k); // b:[n, k]
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a[i * k + p] * bt[j * k + p];
                }
                want[i * n + j] = acc;
            }
        }
        let mut got = vec![0.0f32; m * n];
        let mut panel = Vec::new();
        packed_gemm(&pool, lvl, &a, k, 1, &bt, BLayout::Trans, m, k, n, &mut got, &mut panel);
        assert_eq!(bits(&got), bits(&want));
    }

    #[test]
    fn parallel_is_bitwise_identical_to_serial() {
        let lvl = crate::runtime::simd::detect();
        let (m, k, n) = (37usize, 65usize, 61usize); // > SERIAL_CUTOFF outputs
        let a = vector(5, m * k);
        let b = vector(6, k * n);
        let serial = ThreadPool::new(1);
        let mut want = vec![0.0f32; m * n];
        packed_gemm(&serial, lvl, &a, k, 1, &b, BLayout::Rows, m, k, n, &mut want, &mut Vec::new());
        for threads in [2usize, 3, 7] {
            let poolt = ThreadPool::new(threads);
            let mut got = vec![0.0f32; m * n];
            packed_gemm(
                &poolt, lvl, &a, k, 1, &b, BLayout::Rows, m, k, n, &mut got, &mut Vec::new(),
            );
            assert_eq!(bits(&got), bits(&want), "{threads} threads");
        }
    }

    #[test]
    fn degenerate_dims_zero_fill_like_naive() {
        let lvl = crate::runtime::simd::detect();
        let pool = ThreadPool::new(1);
        // k = 0: empty fold, the naive loops leave exact zeros
        let mut out = vec![1.0f32; 6];
        packed_gemm(&pool, lvl, &[], 0, 1, &[], BLayout::Rows, 2, 0, 3, &mut out, &mut Vec::new());
        assert!(out.iter().all(|&v| v == 0.0));
        // m = 0 / n = 0: nothing to write, nothing read
        let mut empty: Vec<f32> = Vec::new();
        packed_gemm(
            &pool, lvl, &[], 3, 1, &[1.0, 2.0, 3.0], BLayout::Rows, 0, 1, 3, &mut empty,
            &mut Vec::new(),
        );
    }
}
