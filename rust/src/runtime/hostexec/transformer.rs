//! Host implementations of the per-layer transformer programs
//! (`embed_fwd`, `embed_bwd`, `block_fwd`, `block_bwd`, `head_loss`,
//! `head_eval`, plus the forward-only serving variants `embed_decode`,
//! `block_decode`, `head_logits`), mirroring `python/compile/model.py`
//! exactly:
//!
//! * pre-LN block: `x + attn(ln1(x))` then `+ mlp(ln2(·))`, causal
//!   multi-head attention, tanh-GELU MLP;
//! * `block_bwd` rematerialises its forward internally (per-layer remat,
//!   the artifact contract) **unless** the executor's activation arena
//!   holds a stash for its input — then the recompute is skipped
//!   entirely (see [`super::actmem`] for the budget semantics);
//! * `head_loss` is the fused mean-token-cross-entropy fwd+bwd returning
//!   `(loss, dx, dW)`.
//!
//! Gradients are hand-derived VJPs, verified against central finite
//! differences in the test module below. Stashed and rematerialised
//! backward are bit-identical: the stash stores exactly the
//! [`FwdState`] the recompute would reproduce (the executor is
//! bit-deterministic), and a stash hit requires a bit-for-bit match of
//! the block input.
//!
//! Hot paths run on the deterministic thread pool: matmuls/layer-norm
//! via [`math`] (dispatching on the [`super::gemm::GemmMode`] engine),
//! and the attention core parallelised over `(batch, head[, query-row])`
//! tasks into disjoint per-task scratch that is merged serially
//! afterwards. Each scratch element receives its contributions from
//! exactly one task with the serial loop's accumulation order, so
//! outputs are bit-identical at any thread count. The lane-parallel
//! element-wise stages (probability normalisation, the weighted value
//! sums and attention VJP axpys, residual adds, embedding
//! gathers/scatters) additionally dispatch through
//! [`crate::runtime::simd`], which is bit-exact by contract. The
//! attention score dots and VJP `dprobs` dots are lane-parallel too —
//! across *output* key positions, against per-(batch, head) transposed
//! K/V scratch (`simd::attn_scores` / `simd::attn_dots`), each output's
//! own d-fold unchanged — so only the order-sensitive softmax max/exp
//! sums and the transcendental GELU maps stay scalar.
//!
//! Every matmul reuses one caller-owned packed-GEMM panel per program
//! call, sized by [`super::gemm::panel_elems`] to the max over that
//! program's shapes (zero in naive mode) and registered with the
//! workspace meter up front.
//!
//! Every buffer the block **and head** programs allocate is registered
//! with the arena's workspace meter ([`super::actmem::WsMeter`]), so
//! measured activation bytes reconcile exactly against the
//! `crate::memmodel::HostBlockDims` predictions — including the head
//! logits, the largest single buffer of a step at realistic vocab sizes.
//!
//! ## Serving decode programs (`crate::serve`)
//!
//! `block_decode` is the KV-cached incremental twin of `block_fwd`: it
//! takes a pad-free **ragged batch** of new rows (`x [n, h]` — each
//! sequence contributes `news[i]` fresh rows on top of `lens[i]` cached
//! context rows) plus the concatenated per-sequence K/V caches, and
//! returns the new activations together with the fresh K/V rows the
//! caller appends to its cache. **Decode is bit-identical to the
//! full-context forward**: every kernel the block touches is
//! row-independent with a fixed per-element fold order — matmul folds k
//! ascending per output element regardless of the row count, layer-norm
//! and GELU are per-row/per-element, and each attention score is a
//! serial d-ascending fold independent of the key-block stride
//! ([`simd::attn_scores`]) — so computing position `t` from cached K/V
//! produces exactly the bits the full `[1, t+1, h]` forward would
//! (`rust/tests/serve.rs` sweeps this at every thread count × SIMD level
//! × GEMM mode). `embed_decode` and `head_logits` are the matching
//! ragged embedding gather and logits projection.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::actmem::{ActivationArena, Fnv, WsScope};
use super::gemm::{self, GemmMode};
use super::math;
use crate::runtime::exec::{Arg, Program, Value};
use crate::runtime::manifest::ModelHyper;
use crate::runtime::pool::ThreadPool;
use crate::runtime::simd;

pub(super) fn build(
    short: &str,
    h: &ModelHyper,
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    level: simd::Level,
    gm: GemmMode,
) -> Result<Box<dyn Program>> {
    ensure!(h.heads > 0 && h.hidden % h.heads == 0, "hidden {} not divisible by heads {}", h.hidden, h.heads);
    Ok(match short {
        "embed_fwd" => {
            let (vocab, hidden) = (h.vocab, h.hidden);
            Box::new(EmbedFwd { vocab, hidden, pool, simd: level }) as Box<dyn Program>
        }
        "embed_bwd" => Box::new(EmbedBwd { vocab: h.vocab, hidden: h.hidden, simd: level }),
        "block_fwd" => Box::new(BlockFwd { heads: h.heads, pool, arena, simd: level, gemm: gm }),
        "block_bwd" => Box::new(BlockBwd { heads: h.heads, pool, arena, simd: level, gemm: gm }),
        "head_loss" => Box::new(HeadLoss { pool, arena, simd: level, gemm: gm }),
        "head_eval" => Box::new(HeadEval { pool, arena, simd: level, gemm: gm }),
        "embed_decode" => Box::new(EmbedDecode {
            vocab: h.vocab,
            hidden: h.hidden,
            seq: h.seq,
            pool,
            simd: level,
        }),
        "block_decode" => Box::new(BlockDecode { heads: h.heads, pool, arena, simd: level, gemm: gm }),
        "head_logits" => Box::new(HeadLogits { pool, arena, simd: level, gemm: gm }),
        other => bail!("host executor: unknown model program '{other}'"),
    })
}

/// Packed-GEMM panel elements the block forward's four matmuls need
/// (zero in naive mode) — `memmodel::HostBlockDims::fwd_panel_elems`
/// states the same maximum.
fn fwd_panel_elems(gm: GemmMode, h: usize, f: usize) -> usize {
    if gm == GemmMode::Naive {
        return 0;
    }
    let pe = gemm::panel_elems;
    pe(h, 3 * h).max(pe(h, h)).max(pe(h, f)).max(pe(f, h))
}

/// Panel elements for the block backward — the forward set (remat runs
/// inside the backward's scope with the same panel) plus every VJP
/// matmul shape. Mirrored by `memmodel::HostBlockDims::bwd_panel_elems`.
fn bwd_panel_elems(gm: GemmMode, bs: usize, h: usize, f: usize) -> usize {
    if gm == GemmMode::Naive {
        return 0;
    }
    let pe = gemm::panel_elems;
    fwd_panel_elems(gm, h, f)
        .max(pe(h, f))
        .max(pe(bs, h))
        .max(pe(f, h))
        .max(pe(bs, f))
        .max(pe(h, h))
        .max(pe(3 * h, h))
        .max(pe(bs, 3 * h))
}

/// Extract `[b, s, h]` dims from a rank-3 f32 activation argument.
fn act_dims(a: &Arg<'_>) -> Result<(usize, usize, usize)> {
    let sh = a.shape();
    ensure!(sh.len() == 3, "expected rank-3 activation, got shape {sh:?}");
    Ok((sh[0], sh[1], sh[2]))
}

// ---------------------------------------------------------------------------
// embedding
// ---------------------------------------------------------------------------

struct EmbedFwd {
    vocab: usize,
    hidden: usize,
    pool: Arc<ThreadPool>,
    simd: simd::Level,
}

impl Program for EmbedFwd {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(args.len() == 3, "embed_fwd takes (tokens, E, P)");
        let tokens = args[0].i32().context("embed_fwd tokens")?;
        let e = args[1].f32()?;
        let p = args[2].f32()?;
        let sh = args[0].shape();
        ensure!(sh.len() == 2, "tokens must be [B,S]");
        let (b, s, h, v) = (sh[0], sh[1], self.hidden, self.vocab);
        ensure!(e.len() == v * h, "embed E shape");
        ensure!(p.len() == s * h, "embed P shape (seq {s})");
        for &tok in tokens {
            ensure!((0..v as i32).contains(&tok), "token {tok} out of range 0..{v}");
        }

        let lvl = self.simd;
        let mut x = vec![0.0f32; b * s * h];
        // one gather row per (batch, position) — row-parallel, lane-
        // parallel within the row
        self.pool.for_rows(&mut x, h, |rs, orow| {
            let tok = tokens[rs] as usize;
            let erow = &e[tok * h..(tok + 1) * h];
            let prow = &p[(rs % s) * h..(rs % s + 1) * h];
            simd::add(lvl, orow, erow, prow);
        });
        Ok(vec![Value::f32(x, &[b, s, h])?])
    }
}

struct EmbedBwd {
    vocab: usize,
    hidden: usize,
    simd: simd::Level,
}

impl Program for EmbedBwd {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(args.len() == 2, "embed_bwd takes (tokens, dx)");
        let tokens = args[0].i32()?;
        let dx = args[1].f32()?;
        let (b, s, h) = act_dims(&args[1])?;
        ensure!(h == self.hidden, "embed_bwd hidden mismatch");
        ensure!(tokens.len() == b * s, "tokens/dx mismatch");

        // serial across rows: the dE scatter-add races on repeated tokens
        // and is cheap (O(bs·h)) next to the block backward sweeps; each
        // row add is lane-parallel.
        let lvl = self.simd;
        let v = self.vocab;
        let mut de = vec![0.0f32; v * h];
        let mut dp = vec![0.0f32; s * h];
        for bi in 0..b {
            for si in 0..s {
                let tok = tokens[bi * s + si];
                ensure!((0..v as i32).contains(&tok), "token {tok} out of range 0..{v}");
                let drow = &dx[(bi * s + si) * h..(bi * s + si + 1) * h];
                let erow = &mut de[tok as usize * h..(tok as usize + 1) * h];
                simd::add_assign(lvl, erow, drow);
                let prow = &mut dp[si * h..(si + 1) * h];
                simd::add_assign(lvl, prow, drow);
            }
        }
        Ok(vec![Value::f32(de, &[v, h])?, Value::f32(dp, &[s, h])?])
    }
}

// ---------------------------------------------------------------------------
// transformer block
// ---------------------------------------------------------------------------

/// The 12 per-block tensors, in manifest/artifact argument order.
struct BlockParams<'a> {
    ln1g: &'a [f32],
    ln1b: &'a [f32],
    wqkv: &'a [f32],
    bqkv: &'a [f32],
    wo: &'a [f32],
    bo: &'a [f32],
    ln2g: &'a [f32],
    ln2b: &'a [f32],
    w1: &'a [f32],
    b1: &'a [f32],
    w2: &'a [f32],
    b2: &'a [f32],
    /// FFN width, inferred from w1.
    f: usize,
}

fn unpack_block<'a>(args: &[Arg<'a>], off: usize, h: usize) -> Result<BlockParams<'a>> {
    ensure!(args.len() == off + 12, "block program takes {} args, got {}", off + 12, args.len());
    let get = |i: usize| args[off + i].f32();
    let p = BlockParams {
        ln1g: get(0)?,
        ln1b: get(1)?,
        wqkv: get(2)?,
        bqkv: get(3)?,
        wo: get(4)?,
        bo: get(5)?,
        ln2g: get(6)?,
        ln2b: get(7)?,
        w1: get(8)?,
        b1: get(9)?,
        w2: get(10)?,
        b2: get(11)?,
        f: get(8)?.len() / h.max(1),
    };
    ensure!(p.ln1g.len() == h && p.ln1b.len() == h, "ln1 shape");
    ensure!(p.wqkv.len() == h * 3 * h && p.bqkv.len() == 3 * h, "attn qkv shape");
    ensure!(p.wo.len() == h * h && p.bo.len() == h, "attn out shape");
    ensure!(p.ln2g.len() == h && p.ln2b.len() == h, "ln2 shape");
    ensure!(p.f > 0 && p.w1.len() == h * p.f && p.b1.len() == p.f, "mlp w1 shape");
    ensure!(p.w2.len() == p.f * h && p.b2.len() == h, "mlp w2 shape");
    Ok(p)
}

/// Forward intermediates kept for the backward sweep. This is also the
/// stash-arena payload: when `block_fwd` stashes, the backward consumes
/// exactly this state (minus `y`, which left as the forward output).
struct FwdState {
    hn1: Vec<f32>,   // ln1(x)                [bs, h]
    qkv: Vec<f32>,   // hn1 @ wqkv + bqkv     [bs, 3h]
    probs: Vec<f32>, // causal softmax        [b*heads*s*s]
    ao: Vec<f32>,    // merged head outputs   [bs, h]
    x1: Vec<f32>,    // x + attn              [bs, h]
    hn2: Vec<f32>,   // ln2(x1)               [bs, h]
    m1: Vec<f32>,    // hn2 @ w1 + b1         [bs, f]
    gm: Vec<f32>,    // gelu(m1)              [bs, f]
    y: Vec<f32>,     // x1 + mlp out          [bs, h]
}

impl FwdState {
    /// Exact payload bytes (used for arena accounting).
    fn bytes(&self) -> u64 {
        let elems = self.hn1.len()
            + self.qkv.len()
            + self.probs.len()
            + self.ao.len()
            + self.x1.len()
            + self.hn2.len()
            + self.m1.len()
            + self.gm.len()
            + self.y.len();
        (elems * 4) as u64
    }
}

/// Stash key: FNV-1a over the block input and all 12 parameter tensors
/// (bit patterns + dims). A key match is additionally verified by a
/// bit-for-bit compare of `x` inside the arena, so collisions cannot
/// corrupt gradients — at worst the parameters collide, which would
/// require ~2^64 luck on top of an identical input.
fn stash_key(x: &[f32], p: &BlockParams<'_>, b: usize, s: usize, h: usize) -> u64 {
    let mut f = Fnv::new();
    f.u64(b as u64);
    f.u64(s as u64);
    f.u64(h as u64);
    f.f32s(x);
    for t in [
        p.ln1g, p.ln1b, p.wqkv, p.bqkv, p.wo, p.bo, p.ln2g, p.ln2b, p.w1, p.b1, p.w2, p.b2,
    ] {
        f.f32s(t);
    }
    f.finish()
}

#[allow(clippy::too_many_arguments)]
fn block_forward(
    pool: &ThreadPool,
    lvl: simd::Level,
    gm: GemmMode,
    panel: &mut Vec<f32>,
    ws: &mut WsScope<'_>,
    x: &[f32],
    p: &BlockParams<'_>,
    b: usize,
    s: usize,
    h: usize,
    heads: usize,
) -> FwdState {
    let bs = b * s;
    let f = p.f;
    let dh = h / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let w3 = 3 * h;

    let mut hn1 = vec![0.0f32; bs * h];
    ws.add(hn1.len());
    math::layer_norm(pool, lvl, x, p.ln1g, p.ln1b, bs, h, &mut hn1);
    let mut qkv = vec![0.0f32; bs * w3];
    ws.add(qkv.len());
    math::matmul(pool, lvl, gm, panel, &hn1, p.wqkv, bs, h, w3, &mut qkv);
    math::add_bias(lvl, &mut qkv, p.bqkv);

    // per-(batch, head) transposed K — kt[d, j] = k[j, d] — so the score
    // dots vectorise across *output* key positions j with each output's
    // own d-fold unchanged. Serial gather, one producer per element.
    let mut kt = vec![0.0f32; bs * h];
    ws.add(kt.len());
    for bi in 0..b {
        for hd in 0..heads {
            let base = (bi * heads + hd) * dh * s;
            for j in 0..s {
                let krow = &qkv[(bi * s + j) * w3 + h + hd * dh..][..dh];
                for (d, &kv) in krow.iter().enumerate() {
                    kt[base + d * s + j] = kv;
                }
            }
        }
    }

    // attention core, parallel over (batch, head, query-row) tasks: task t
    // writes its probs row and its dh-wide head-output row `aoh[t]`; the
    // head-major scratch is re-interleaved into [bs, h] serially below
    // (pure copy — each element has exactly one producer).
    let mut probs = vec![0.0f32; b * heads * s * s];
    let mut aoh = vec![0.0f32; b * heads * s * dh];
    ws.add(probs.len() + aoh.len());
    pool.for_rows2(&mut probs, s, &mut aoh, dh, |t, prow, orow| {
        let i = t % s;
        let hd = (t / s) % heads;
        let bi = t / (s * heads);
        let qc = hd * dh;
        let vc = 2 * h + hd * dh;
        let qrow = &qkv[(bi * s + i) * w3..(bi * s + i + 1) * w3];
        // causal scores over j <= i: lane-parallel over j against the
        // transposed K, each score's d-fold then ·scale exactly as the
        // scalar loop; the max sweep compares the same values in the
        // same j order
        let kt_h = &kt[(bi * heads + hd) * dh * s..][..dh * s];
        let mut scores = vec![0.0f32; i + 1];
        simd::attn_scores(lvl, &mut scores, &qrow[qc..qc + dh], kt_h, s, scale);
        let mut mx = f32::NEG_INFINITY;
        for &sc in scores.iter() {
            if sc > mx {
                mx = sc;
            }
        }
        let mut sum = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - mx).exp();
            sum += *sc;
        }
        let inv = 1.0 / sum;
        // j > i stays zero (causal mask); the normalisation is
        // lane-parallel
        simd::scale_into(lvl, &mut prow[..=i], &scores, inv);
        // weighted value sum into this task's head-output row: one
        // lane-parallel axpy per key position, j ascending
        for (j, &pij) in prow[..=i].iter().enumerate() {
            let vrow = &qkv[(bi * s + j) * w3..(bi * s + j + 1) * w3];
            simd::axpy(lvl, orow, &vrow[vc..vc + dh], pij);
        }
    });
    drop(kt);
    let mut ao = vec![0.0f32; bs * h];
    ws.add(ao.len());
    for bi in 0..b {
        for hd in 0..heads {
            for i in 0..s {
                let t = (bi * heads + hd) * s + i;
                ao[(bi * s + i) * h + hd * dh..][..dh]
                    .copy_from_slice(&aoh[t * dh..(t + 1) * dh]);
            }
        }
    }

    let mut attn = vec![0.0f32; bs * h];
    ws.add(attn.len());
    math::matmul(pool, lvl, gm, panel, &ao, p.wo, bs, h, h, &mut attn);
    math::add_bias(lvl, &mut attn, p.bo);
    let mut x1 = vec![0.0f32; bs * h];
    ws.add(x1.len());
    simd::add(lvl, &mut x1, x, &attn);

    let mut hn2 = vec![0.0f32; bs * h];
    ws.add(hn2.len());
    math::layer_norm(pool, lvl, &x1, p.ln2g, p.ln2b, bs, h, &mut hn2);
    let mut m1 = vec![0.0f32; bs * f];
    ws.add(m1.len());
    math::matmul(pool, lvl, gm, panel, &hn2, p.w1, bs, h, f, &mut m1);
    math::add_bias(lvl, &mut m1, p.b1);
    let mut gel = vec![0.0f32; bs * f];
    ws.add(gel.len());
    // scalar map on purpose: tanh-GELU is a libm call, not lane-exact
    pool.for_rows(&mut gel, f, |r, row| {
        let mi = &m1[r * f..(r + 1) * f];
        for (o, &u) in row.iter_mut().zip(mi) {
            *o = math::gelu(u);
        }
    });
    let mut m2 = vec![0.0f32; bs * h];
    ws.add(m2.len());
    math::matmul(pool, lvl, gm, panel, &gel, p.w2, bs, f, h, &mut m2);
    math::add_bias(lvl, &mut m2, p.b2);
    let mut y = vec![0.0f32; bs * h];
    ws.add(y.len());
    simd::add(lvl, &mut y, &x1, &m2);

    FwdState { hn1, qkv, probs, ao, x1, hn2, m1, gm: gel, y }
}

/// Rematerialise the forward, then pull back `dy` — the stash-miss path
/// (and the test harness's entry point). Forward and backward share the
/// caller's panel (sized for the union of both shape sets).
#[allow(clippy::too_many_arguments)]
fn block_backward_remat(
    pool: &ThreadPool,
    lvl: simd::Level,
    gm: GemmMode,
    panel: &mut Vec<f32>,
    ws: &mut WsScope<'_>,
    x: &[f32],
    dy: &[f32],
    p: &BlockParams<'_>,
    b: usize,
    s: usize,
    h: usize,
    heads: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let st = block_forward(pool, lvl, gm, panel, ws, x, p, b, s, h, heads);
    block_backward(pool, lvl, gm, panel, ws, x, dy, p, &st, b, s, h, heads)
}

/// Pull back `dy` through a block given its forward state (stashed or
/// just rematerialised): returns `(dx, 12 dparams)`.
#[allow(clippy::too_many_arguments)]
fn block_backward(
    pool: &ThreadPool,
    lvl: simd::Level,
    gm: GemmMode,
    panel: &mut Vec<f32>,
    ws: &mut WsScope<'_>,
    x: &[f32],
    dy: &[f32],
    p: &BlockParams<'_>,
    st: &FwdState,
    b: usize,
    s: usize,
    h: usize,
    heads: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let bs = b * s;
    let f = p.f;
    let dh = h / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let w3 = 3 * h;

    // y = x1 + m2: residual copies dy to both branches
    let dm2 = dy;
    let mut dx1 = dy.to_vec();
    ws.add(dx1.len());

    // m2 = gm @ w2 + b2
    let mut dgm = vec![0.0f32; bs * f];
    math::matmul_nt(pool, lvl, gm, panel, dm2, p.w2, bs, h, f, &mut dgm);
    let mut dw2 = vec![0.0f32; f * h];
    math::matmul_tn(pool, lvl, gm, panel, &st.gm, dm2, bs, f, h, &mut dw2);
    let mut db2 = vec![0.0f32; h];
    math::col_sums(dm2, bs, h, &mut db2);
    ws.add(dgm.len() + dw2.len() + db2.len());

    // gm = gelu(m1) — scalar map (libm tanh in the derivative)
    let mut dm1 = vec![0.0f32; bs * f];
    ws.add(dm1.len());
    pool.for_rows(&mut dm1, f, |r, row| {
        for (j, o) in row.iter_mut().enumerate() {
            let idx = r * f + j;
            *o = dgm[idx] * math::gelu_grad(st.m1[idx]);
        }
    });

    // m1 = hn2 @ w1 + b1
    let mut dhn2 = vec![0.0f32; bs * h];
    math::matmul_nt(pool, lvl, gm, panel, &dm1, p.w1, bs, f, h, &mut dhn2);
    let mut dw1 = vec![0.0f32; h * f];
    math::matmul_tn(pool, lvl, gm, panel, &st.hn2, &dm1, bs, h, f, &mut dw1);
    let mut db1 = vec![0.0f32; f];
    math::col_sums(&dm1, bs, f, &mut db1);
    ws.add(dhn2.len() + dw1.len() + db1.len());

    // hn2 = ln2(x1): contributes into dx1
    let mut dln2g = vec![0.0f32; h];
    let mut dln2b = vec![0.0f32; h];
    ws.add(dln2g.len() + dln2b.len());
    math::layer_norm_bwd(lvl, &st.x1, p.ln2g, &dhn2, bs, h, &mut dx1, &mut dln2g, &mut dln2b);

    // x1 = x + attn: residual again
    let mut dx = dx1.clone();
    ws.add(dx.len());
    let dattn = dx1;

    // attn = ao @ wo + bo
    let mut dao = vec![0.0f32; bs * h];
    math::matmul_nt(pool, lvl, gm, panel, &dattn, p.wo, bs, h, h, &mut dao);
    let mut dwo = vec![0.0f32; h * h];
    math::matmul_tn(pool, lvl, gm, panel, &st.ao, &dattn, bs, h, h, &mut dwo);
    let mut dbo = vec![0.0f32; h];
    math::col_sums(&dattn, bs, h, &mut dbo);
    ws.add(dao.len() + dwo.len() + dbo.len());

    // per-(batch, head) transposed V — vt[d, j] = v[j, d] — so the VJP
    // dprobs dots vectorise across output key positions like the forward
    // scores. Serial gather, one producer per element.
    let mut vt = vec![0.0f32; bs * h];
    ws.add(vt.len());
    for bi in 0..b {
        for hd in 0..heads {
            let base = (bi * heads + hd) * dh * s;
            for j in 0..s {
                let vrow = &st.qkv[(bi * s + j) * w3 + 2 * h + hd * dh..][..dh];
                for (d, &vv) in vrow.iter().enumerate() {
                    vt[base + d * s + j] = vv;
                }
            }
        }
    }

    // attention core VJP: softmax(qkᵀ·scale, causal) @ v, parallel over
    // (batch, head) tasks. Each task accumulates its dq/dk/dv into a
    // private [s, 3·dh] scratch row block (q | k | v), replicating the
    // serial i-then-j loop order; the scratch is re-interleaved into
    // [bs, 3h] serially below (pure copy — one producer per element).
    let mut scratch = vec![0.0f32; b * heads * s * 3 * dh];
    ws.add(scratch.len());
    pool.for_rows(&mut scratch, s * 3 * dh, |t, dq| {
        let hd = t % heads;
        let bi = t / heads;
        let qc = hd * dh;
        for i in 0..s {
            let drow = &dao[(bi * s + i) * h..(bi * s + i + 1) * h];
            let prow = &st.probs[((bi * heads + hd) * s + i) * s..][..s];
            // dprobs[j] = datt[i]·v[j]: lane-parallel over j against the
            // transposed V, each dot's d-fold unchanged; the softmax row
            // VJP's Σ dp·p then reduces in the same ascending-j order as
            // the old interleaved loop, on identical dp values
            let vt_h = &vt[(bi * heads + hd) * dh * s..][..dh * s];
            let mut dp = vec![0.0f32; i + 1];
            simd::attn_dots(lvl, &mut dp, &drow[qc..qc + dh], vt_h, s);
            let mut dot = 0.0f32;
            for (j, &dpj) in dp.iter().enumerate() {
                dot += dpj * prow[j];
            }
            for j in 0..=i {
                let ds = prow[j] * (dp[j] - dot); // masked scores: prob 0 ⇒ ds 0
                // `scale * ds * x` is left-associative: hoist (scale·ds)
                // and the per-d updates become lane-parallel axpys into
                // three disjoint dh-wide scratch segments (q@row i,
                // k/v@row j) — per-element accumulation order across j
                // is unchanged
                let c = scale * ds;
                let krow = &st.qkv[(bi * s + j) * w3 + h + hd * dh..][..dh];
                let qrow = &st.qkv[(bi * s + i) * w3 + qc..][..dh];
                simd::axpy(lvl, &mut dq[i * 3 * dh..i * 3 * dh + dh], krow, c);
                simd::axpy(lvl, &mut dq[j * 3 * dh + dh..j * 3 * dh + 2 * dh], qrow, c);
                simd::axpy(
                    lvl,
                    &mut dq[j * 3 * dh + 2 * dh..(j + 1) * 3 * dh],
                    &drow[qc..qc + dh],
                    prow[j],
                );
            }
        }
    });
    let mut dqkv = vec![0.0f32; bs * w3];
    ws.add(dqkv.len());
    for bi in 0..b {
        for hd in 0..heads {
            let base = (bi * heads + hd) * s * 3 * dh;
            for r in 0..s {
                let row = &scratch[base + r * 3 * dh..][..3 * dh];
                let dst = &mut dqkv[(bi * s + r) * w3..(bi * s + r + 1) * w3];
                dst[hd * dh..hd * dh + dh].copy_from_slice(&row[..dh]);
                dst[h + hd * dh..h + hd * dh + dh].copy_from_slice(&row[dh..2 * dh]);
                dst[2 * h + hd * dh..2 * h + hd * dh + dh].copy_from_slice(&row[2 * dh..]);
            }
        }
    }

    // qkv = hn1 @ wqkv + bqkv
    let mut dhn1 = vec![0.0f32; bs * h];
    math::matmul_nt(pool, lvl, gm, panel, &dqkv, p.wqkv, bs, w3, h, &mut dhn1);
    let mut dwqkv = vec![0.0f32; h * w3];
    math::matmul_tn(pool, lvl, gm, panel, &st.hn1, &dqkv, bs, h, w3, &mut dwqkv);
    let mut dbqkv = vec![0.0f32; w3];
    math::col_sums(&dqkv, bs, w3, &mut dbqkv);
    ws.add(dhn1.len() + dwqkv.len() + dbqkv.len());

    // hn1 = ln1(x): contributes into dx
    let mut dln1g = vec![0.0f32; h];
    let mut dln1b = vec![0.0f32; h];
    ws.add(dln1g.len() + dln1b.len());
    math::layer_norm_bwd(lvl, x, p.ln1g, &dhn1, bs, h, &mut dx, &mut dln1g, &mut dln1b);

    (
        dx,
        vec![
            dln1g, dln1b, dwqkv, dbqkv, dwo, dbo, dln2g, dln2b, dw1, db1, dw2, db2,
        ],
    )
}

struct BlockFwd {
    heads: usize,
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
}

impl Program for BlockFwd {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        let (b, s, h) = act_dims(args.first().context("block_fwd: missing x")?)?;
        ensure!(h % self.heads == 0, "hidden {h} not divisible by heads {}", self.heads);
        let x = args[0].f32()?;
        let p = unpack_block(args, 1, h)?;
        let mut ws = self.arena.ws().scope();
        // one packed-GEMM panel for all four forward matmuls, metered
        // up front (zero elements in naive mode)
        let mut panel = vec![0.0f32; fwd_panel_elems(self.gemm, h, p.f)];
        ws.add(panel.len());
        let mut st = block_forward(
            &self.pool, self.simd, self.gemm, &mut panel, &mut ws, x, &p, b, s, h, self.heads,
        );
        let y = std::mem::take(&mut st.y);
        if self.arena.enabled() {
            let key = stash_key(x, &p, b, s, h);
            self.arena.try_stash(key, x, st.bytes(), Box::new(st));
        }
        Ok(vec![Value::f32(y, &[b, s, h])?])
    }
}

struct BlockBwd {
    heads: usize,
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
}

impl Program for BlockBwd {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(args.len() >= 2, "block_bwd takes (x, dy, *params)");
        let (b, s, h) = act_dims(&args[0])?;
        ensure!(h % self.heads == 0, "hidden {h} not divisible by heads {}", self.heads);
        let x = args[0].f32()?;
        let dy = args[1].f32()?;
        ensure!(dy.len() == x.len(), "block_bwd: x/dy shape mismatch");
        let p = unpack_block(args, 2, h)?;
        let f = p.f;
        let mut ws = self.arena.ws().scope();
        // one panel covering the VJP matmuls AND the remat forward's
        // (both paths allocate the same max so the workspace formula
        // has no stash-hit/remat branch); metered up front
        let mut panel = vec![0.0f32; bwd_panel_elems(self.gemm, b * s, h, f)];
        ws.add(panel.len());
        let stashed = if self.arena.enabled() {
            self.arena.take(stash_key(x, &p, b, s, h), x)
        } else {
            // remat default: skip the key hash entirely, cost nothing
            self.arena.note_remat();
            None
        };
        let (dx, dparams) = match stashed {
            // stash hit: the state block_fwd computed for this exact
            // (x, params) — bit-identical to what remat would rebuild
            Some(payload) => {
                let st = payload
                    .downcast::<FwdState>()
                    .map_err(|_| anyhow::anyhow!("stash payload is not a FwdState"))?;
                // the consumed state left the arena's books but stays
                // physically live until this call returns — count it as
                // workspace so measured bytes track real memory
                ws.add_bytes(st.bytes());
                let (pool, lvl, gm) = (&self.pool, self.simd, self.gemm);
                block_backward(
                    pool, lvl, gm, &mut panel, &mut ws, x, dy, &p, &st, b, s, h, self.heads,
                )
            }
            // miss (remat default, evicted, or forward-only leftover):
            // recompute the forward in place
            None => {
                let (pool, lvl, gm) = (&self.pool, self.simd, self.gemm);
                block_backward_remat(
                    pool, lvl, gm, &mut panel, &mut ws, x, dy, &p, b, s, h, self.heads,
                )
            }
        };

        let shapes: [Vec<usize>; 12] = [
            vec![h],
            vec![h],
            vec![h, 3 * h],
            vec![3 * h],
            vec![h, h],
            vec![h],
            vec![h],
            vec![h],
            vec![h, f],
            vec![f],
            vec![f, h],
            vec![h],
        ];
        let mut out = Vec::with_capacity(13);
        out.push(Value::f32(dx, &[b, s, h])?);
        for (d, shape) in dparams.into_iter().zip(shapes.iter()) {
            out.push(Value::f32(d, shape)?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// head
// ---------------------------------------------------------------------------

struct HeadLoss {
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
}

/// Shared head plumbing: logits + mean-token cross-entropy.
/// Returns (loss, dlogits_unscaled, ncorrect, dims). The logits are the
/// largest single buffer of a training step at realistic vocab sizes, so
/// both head buffers are registered with the arena's workspace meter —
/// `memmodel::HostBlockDims::head_*_workspace_bytes` predicts exactly
/// these registrations (plus the caller-metered GEMM panel).
#[allow(clippy::too_many_arguments)]
fn head_common(
    pool: &ThreadPool,
    lvl: simd::Level,
    gm: GemmMode,
    panel: &mut Vec<f32>,
    ws: &mut WsScope<'_>,
    args: &[Arg<'_>],
) -> Result<(f32, Vec<f32>, i32, (usize, usize, usize, usize))> {
    ensure!(args.len() == 3, "head program takes (x, W, labels)");
    let (b, s, h) = act_dims(&args[0])?;
    let x = args[0].f32()?;
    let w = args[1].f32()?;
    ensure!(!w.is_empty() && w.len() % h == 0, "head W shape");
    let v = w.len() / h;
    let labels = args[2].i32()?;
    ensure!(labels.len() == b * s, "head labels shape");
    for &l in labels {
        ensure!((0..v as i32).contains(&l), "label {l} out of range 0..{v}");
    }
    let bs = b * s;
    let mut logits = vec![0.0f32; bs * v];
    ws.add(logits.len());
    math::matmul(pool, lvl, gm, panel, x, w, bs, h, v, &mut logits);
    let mut dlogits = vec![0.0f32; bs * v];
    ws.add(dlogits.len());
    let (nll, ncorrect) = math::softmax_xent(pool, lvl, &logits, labels, bs, v, &mut dlogits);
    let loss = (nll / bs as f64) as f32;
    Ok((loss, dlogits, ncorrect, (b, s, h, v)))
}

/// Panel elements for `head_loss` (logits + dx + dW matmuls) — mirrored
/// by `memmodel::HostBlockDims::head_loss_panel_elems`.
fn head_loss_panel_elems(gm: GemmMode, bs: usize, h: usize, v: usize) -> usize {
    if gm == GemmMode::Naive {
        return 0;
    }
    let pe = gemm::panel_elems;
    pe(h, v).max(pe(v, h)).max(pe(bs, v))
}

impl Program for HeadLoss {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        let lvl = self.simd;
        let gm = self.gemm;
        ensure!(args.len() == 3, "head program takes (x, W, labels)");
        let mut ws = self.arena.ws().scope();
        // W is [h, v]: size the panel before head_common so one metered
        // allocation serves all three matmuls
        let (b0, s0, h0) = act_dims(&args[0])?;
        let v0 = if h0 == 0 { 0 } else { args[1].len() / h0 };
        let mut panel = vec![0.0f32; head_loss_panel_elems(gm, b0 * s0, h0, v0)];
        ws.add(panel.len());
        let (loss, mut dlogits, _nc, (b, s, h, v)) =
            head_common(&self.pool, lvl, gm, &mut panel, &mut ws, args)?;
        let x = args[0].f32()?;
        let w = args[1].f32()?;
        let bs = b * s;
        let inv = 1.0 / bs as f32;
        self.pool.for_spans(&mut dlogits, |_, span| {
            simd::scale(lvl, span, inv);
        });
        let mut dx = vec![0.0f32; bs * h];
        math::matmul_nt(&self.pool, lvl, gm, &mut panel, &dlogits, w, bs, v, h, &mut dx);
        let mut dw = vec![0.0f32; h * v];
        math::matmul_tn(&self.pool, lvl, gm, &mut panel, x, &dlogits, bs, h, v, &mut dw);
        ws.add(dx.len() + dw.len());
        Ok(vec![
            Value::scalar_f32(loss),
            Value::f32(dx, &[b, s, h])?,
            Value::f32(dw, &[h, v])?,
        ])
    }
}

struct HeadEval {
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
}

impl Program for HeadEval {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(args.len() == 3, "head program takes (x, W, labels)");
        let mut ws = self.arena.ws().scope();
        let h = act_dims(&args[0])?.2;
        let v = if h == 0 { 0 } else { args[1].len() / h };
        let mut panel = if self.gemm == GemmMode::Naive {
            Vec::new()
        } else {
            vec![0.0f32; gemm::panel_elems(h, v)]
        };
        ws.add(panel.len());
        let (loss, _dl, ncorrect, _dims) =
            head_common(&self.pool, self.simd, self.gemm, &mut panel, &mut ws, args)?;
        Ok(vec![Value::scalar_f32(loss), Value::scalar_i32(ncorrect)])
    }
}

// ---------------------------------------------------------------------------
// serving decode programs (forward-only, KV-cached, ragged batches)
// ---------------------------------------------------------------------------

/// Extract `[n, h]` dims from a rank-2 f32 ragged-batch argument.
fn row_dims(a: &Arg<'_>) -> Result<(usize, usize)> {
    let sh = a.shape();
    ensure!(sh.len() == 2, "expected rank-2 row batch, got shape {sh:?}");
    Ok((sh[0], sh[1]))
}

/// `embed_decode`: ragged embedding gather for serving. Args
/// `(tokens [n] s32, pos [n] s32, E [v,h], P [s,h])` → `x [n, h]` with
/// `x[r] = E[tokens[r]] + P[pos[r]]` — the exact per-row computation of
/// `embed_fwd`, so a decoded row is bit-identical to the full-context
/// gather at the same position. Positions must lie inside the config's
/// learned positional table (`pos < s`), which bounds the serving
/// context length.
struct EmbedDecode {
    vocab: usize,
    hidden: usize,
    seq: usize,
    pool: Arc<ThreadPool>,
    simd: simd::Level,
}

impl Program for EmbedDecode {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(args.len() == 4, "embed_decode takes (tokens, pos, E, P)");
        let tokens = args[0].i32().context("embed_decode tokens")?;
        let pos = args[1].i32().context("embed_decode pos")?;
        let e = args[2].f32()?;
        let p = args[3].f32()?;
        let (n, h, v, s) = (tokens.len(), self.hidden, self.vocab, self.seq);
        ensure!(pos.len() == n, "embed_decode: tokens/pos length mismatch");
        ensure!(e.len() == v * h, "embed E shape");
        ensure!(p.len() == s * h, "embed P shape (seq {s})");
        for &tok in tokens {
            ensure!((0..v as i32).contains(&tok), "token {tok} out of range 0..{v}");
        }
        for &pi in pos {
            ensure!(
                (0..s as i32).contains(&pi),
                "position {pi} out of range 0..{s} (context exceeds the positional table)"
            );
        }
        let lvl = self.simd;
        let mut x = vec![0.0f32; n * h];
        self.pool.for_rows(&mut x, h, |r, orow| {
            let erow = &e[tokens[r] as usize * h..(tokens[r] as usize + 1) * h];
            let prow = &p[pos[r] as usize * h..(pos[r] as usize + 1) * h];
            simd::add(lvl, orow, erow, prow);
        });
        Ok(vec![Value::f32(x, &[n, h])?])
    }
}

/// `block_decode`: the KV-cached incremental forward of one transformer
/// block over a pad-free ragged batch.
///
/// Args: `x [n, h]` (new rows, sequences concatenated in order),
/// `news [nseq] s32` (fresh rows per sequence, ≥ 1), `lens [nseq] s32`
/// (cached context rows per sequence), `kcat [p, h]` / `vcat [p, h]`
/// (the concatenated K/V caches, `p = Σ lens`, same sequence order),
/// then the 12 block parameters. Outputs: `y [n, h]`, `knew [n, h]`,
/// `vnew [n, h]` — the caller appends `knew`/`vnew` to its cache.
///
/// Bit-exactness: row `ii` of sequence `i` attends over its `lens[i] +
/// ii + 1` context positions with exactly the expression tree of
/// [`block_forward`] at the same global position — same per-element
/// matmul folds (row-count independent), same serial softmax max/exp
/// sums, same ascending-`j` value axpys — so incremental decode equals
/// the full-context forward bit for bit at any thread count, SIMD level
/// and GEMM mode.
struct BlockDecode {
    heads: usize,
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
}

impl Program for BlockDecode {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(args.len() == 17, "block_decode takes (x, news, lens, kcat, vcat, 12 params)");
        let (n, h) = row_dims(&args[0])?;
        ensure!(n > 0, "block_decode: empty batch");
        ensure!(h % self.heads == 0, "hidden {h} not divisible by heads {}", self.heads);
        let x = args[0].f32()?;
        let news = args[1].i32()?;
        let lens = args[2].i32()?;
        let nseq = news.len();
        ensure!(nseq > 0 && lens.len() == nseq, "block_decode: news/lens length mismatch");
        ensure!(news.iter().all(|&c| c > 0), "block_decode: every sequence needs ≥1 new row");
        ensure!(lens.iter().all(|&c| c >= 0), "block_decode: negative cache length");
        let total_new: usize = news.iter().map(|&c| c as usize).sum();
        ensure!(total_new == n, "block_decode: Σnews {total_new} != rows {n}");
        let p_rows: usize = lens.iter().map(|&c| c as usize).sum();
        let kcat = args[3].f32()?;
        let vcat = args[4].f32()?;
        ensure!(kcat.len() == p_rows * h, "block_decode: kcat shape");
        ensure!(vcat.len() == p_rows * h, "block_decode: vcat shape");
        let p = unpack_block(args, 5, h)?;

        let heads = self.heads;
        let dh = h / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let w3 = 3 * h;
        let f = p.f;
        let (pool, lvl, gm) = (&self.pool, self.simd, self.gemm);

        let mut ws = self.arena.ws().scope();
        // the decode matmul shapes are the forward set (n rows instead
        // of b·s) — one panel, metered up front
        let mut panel = vec![0.0f32; fwd_panel_elems(gm, h, f)];
        ws.add(panel.len());

        let mut hn1 = vec![0.0f32; n * h];
        ws.add(hn1.len());
        math::layer_norm(pool, lvl, x, p.ln1g, p.ln1b, n, h, &mut hn1);
        let mut qkv = vec![0.0f32; n * w3];
        ws.add(qkv.len());
        math::matmul(pool, lvl, gm, panel.as_mut(), &hn1, p.wqkv, n, h, w3, &mut qkv);
        math::add_bias(lvl, &mut qkv, p.bqkv);

        // per-row bookkeeping: owning sequence, global position, cache
        // row offset, and each sequence's transposed-K scratch offset
        let mut row_seq = vec![0usize; n];
        let mut row_pos = vec![0usize; n];
        let mut seq_row0 = vec![0usize; nseq]; // first new row of each sequence
        let mut seq_koff = vec![0usize; nseq]; // first cache row of each sequence
        let mut seq_kt = vec![0usize; nseq]; // kt scratch offset of each sequence
        let mut seq_t = vec![0usize; nseq]; // total context length L + n_i
        {
            let (mut r, mut koff, mut kt_off) = (0usize, 0usize, 0usize);
            for si in 0..nseq {
                let (l, c) = (lens[si] as usize, news[si] as usize);
                seq_row0[si] = r;
                seq_koff[si] = koff;
                seq_kt[si] = kt_off;
                seq_t[si] = l + c;
                for ii in 0..c {
                    row_seq[r + ii] = si;
                    row_pos[r + ii] = l + ii;
                }
                r += c;
                koff += l;
                kt_off += h * (l + c);
            }
        }

        // per-(sequence, head) transposed K over cached + fresh rows:
        // kt[d, j] — the same gather `block_forward` builds from its own
        // qkv, here sourced from the cache for j < len. Serial, one
        // producer per element.
        let kt_elems = h * (p_rows + n);
        let mut kt = vec![0.0f32; kt_elems];
        ws.add(kt.len());
        for si in 0..nseq {
            let (l, t) = (lens[si] as usize, seq_t[si]);
            let (row0, koff) = (seq_row0[si], seq_koff[si]);
            for hd in 0..heads {
                let base = seq_kt[si] + hd * dh * t;
                for j in 0..t {
                    let krow: &[f32] = if j < l {
                        &kcat[(koff + j) * h + hd * dh..][..dh]
                    } else {
                        &qkv[(row0 + j - l) * w3 + h + hd * dh..][..dh]
                    };
                    for (d, &kv) in krow.iter().enumerate() {
                        kt[base + d * t + j] = kv;
                    }
                }
            }
        }

        // attention core, parallel over (new row, head) tasks. Each task
        // reproduces the full-context forward's score/softmax/value
        // chain for its global position, reading cached K/V for the
        // prefix — identical expression tree, so identical bits.
        let mut aoh = vec![0.0f32; n * h];
        ws.add(aoh.len());
        pool.for_rows(&mut aoh, dh, |t, orow| {
            let r = t / heads;
            let hd = t % heads;
            let si = row_seq[r];
            let (l, tlen) = (lens[si] as usize, seq_t[si]);
            let (row0, koff) = (seq_row0[si], seq_koff[si]);
            let pi = row_pos[r];
            let qc = hd * dh;
            let qrow = &qkv[r * w3 + qc..][..dh];
            let kt_h = &kt[seq_kt[si] + hd * dh * tlen..][..dh * tlen];
            let mut scores = vec![0.0f32; pi + 1];
            simd::attn_scores(lvl, &mut scores, qrow, kt_h, tlen, scale);
            let mut mx = f32::NEG_INFINITY;
            for &sc in scores.iter() {
                if sc > mx {
                    mx = sc;
                }
            }
            let mut sum = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                sum += *sc;
            }
            let inv = 1.0 / sum;
            let mut prow = vec![0.0f32; pi + 1];
            simd::scale_into(lvl, &mut prow, &scores, inv);
            for (j, &pij) in prow.iter().enumerate() {
                let vrow: &[f32] = if j < l {
                    &vcat[(koff + j) * h + hd * dh..][..dh]
                } else {
                    &qkv[(row0 + j - l) * w3 + 2 * h + hd * dh..][..dh]
                };
                simd::axpy(lvl, orow, vrow, pij);
            }
        });
        drop(kt);
        let mut ao = vec![0.0f32; n * h];
        ws.add(ao.len());
        for r in 0..n {
            for hd in 0..heads {
                ao[r * h + hd * dh..][..dh]
                    .copy_from_slice(&aoh[(r * heads + hd) * dh..][..dh]);
            }
        }

        let mut attn = vec![0.0f32; n * h];
        ws.add(attn.len());
        math::matmul(pool, lvl, gm, panel.as_mut(), &ao, p.wo, n, h, h, &mut attn);
        math::add_bias(lvl, &mut attn, p.bo);
        let mut x1 = vec![0.0f32; n * h];
        ws.add(x1.len());
        simd::add(lvl, &mut x1, x, &attn);

        let mut hn2 = vec![0.0f32; n * h];
        ws.add(hn2.len());
        math::layer_norm(pool, lvl, &x1, p.ln2g, p.ln2b, n, h, &mut hn2);
        let mut m1 = vec![0.0f32; n * f];
        ws.add(m1.len());
        math::matmul(pool, lvl, gm, panel.as_mut(), &hn2, p.w1, n, h, f, &mut m1);
        math::add_bias(lvl, &mut m1, p.b1);
        let mut gel = vec![0.0f32; n * f];
        ws.add(gel.len());
        pool.for_rows(&mut gel, f, |r, row| {
            let mi = &m1[r * f..(r + 1) * f];
            for (o, &u) in row.iter_mut().zip(mi) {
                *o = math::gelu(u);
            }
        });
        let mut m2 = vec![0.0f32; n * h];
        ws.add(m2.len());
        math::matmul(pool, lvl, gm, panel.as_mut(), &gel, p.w2, n, f, h, &mut m2);
        math::add_bias(lvl, &mut m2, p.b2);
        let mut y = vec![0.0f32; n * h];
        ws.add(y.len());
        simd::add(lvl, &mut y, &x1, &m2);

        // fresh K/V rows for the caller's cache (columns h..2h / 2h..3h
        // of qkv — the exact bits the next step's j < len branch reads)
        let mut knew = vec![0.0f32; n * h];
        let mut vnew = vec![0.0f32; n * h];
        ws.add(knew.len() + vnew.len());
        for r in 0..n {
            knew[r * h..(r + 1) * h].copy_from_slice(&qkv[r * w3 + h..][..h]);
            vnew[r * h..(r + 1) * h].copy_from_slice(&qkv[r * w3 + 2 * h..][..h]);
        }

        Ok(vec![
            Value::f32(y, &[n, h])?,
            Value::f32(knew, &[n, h])?,
            Value::f32(vnew, &[n, h])?,
        ])
    }
}

/// Panel elements for `head_logits` (one `[n,h]·[h,v]` matmul) —
/// mirrored by `memmodel::HostBlockDims::head_logits_panel_elems`.
fn head_logits_panel_elems(gm: GemmMode, h: usize, v: usize) -> usize {
    if gm == GemmMode::Naive {
        return 0;
    }
    gemm::panel_elems(h, v)
}

/// `head_logits`: ragged logits projection for serving. Args
/// `(x [n, h], W [h, v])` → `logits [n, v]`. The matmul's per-element
/// fold is row-count independent, so a single decoded row's logits are
/// bit-identical to the same row of the full-context head projection.
/// The caller (the serving engine) picks the next token by first-max
/// argmax — the same tie-break `math::softmax_xent` uses for its
/// correct-prediction count.
struct HeadLogits {
    pool: Arc<ThreadPool>,
    arena: Arc<ActivationArena>,
    simd: simd::Level,
    gemm: GemmMode,
}

impl Program for HeadLogits {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(args.len() == 2, "head_logits takes (x, W)");
        let (n, h) = row_dims(&args[0])?;
        let x = args[0].f32()?;
        let w = args[1].f32()?;
        ensure!(h > 0 && !w.is_empty() && w.len() % h == 0, "head W shape");
        let v = w.len() / h;
        let mut ws = self.arena.ws().scope();
        let mut panel = vec![0.0f32; head_logits_panel_elems(self.gemm, h, v)];
        ws.add(panel.len());
        let mut logits = vec![0.0f32; n * v];
        ws.add(logits.len());
        math::matmul(&self.pool, self.simd, self.gemm, &mut panel, x, w, n, h, v, &mut logits);
        Ok(vec![Value::f32(logits, &[n, v])?])
    }
}

// ---------------------------------------------------------------------------
// tests: finite-difference verification of every hand-derived VJP
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::hostexec::actmem::{MemoryPlan, WsMeter};
    use crate::tensor::Rng;

    /// SIMD level for the tests: from `ADAMA_SIMD`, so the CI matrix
    /// exercises both the scalar and vector paths through these suites.
    fn lv() -> simd::Level {
        simd::Level::from_env().expect("valid ADAMA_SIMD")
    }

    /// GEMM engine for the tests: from `ADAMA_GEMM`, so the CI matrix
    /// exercises the packed and naive engines through these suites.
    fn gm() -> GemmMode {
        GemmMode::from_env().expect("valid ADAMA_GEMM")
    }

    /// Forward with a throwaway workspace meter (signature helper).
    fn fwd(
        pool: &ThreadPool,
        x: &[f32],
        p: &BlockParams<'_>,
        b: usize,
        s: usize,
        h: usize,
        heads: usize,
    ) -> FwdState {
        let m = WsMeter::default();
        block_forward(pool, lv(), gm(), &mut Vec::new(), &mut m.scope(), x, p, b, s, h, heads)
    }

    /// Remat backward with a throwaway workspace meter.
    #[allow(clippy::too_many_arguments)]
    fn bwd(
        pool: &ThreadPool,
        x: &[f32],
        dy: &[f32],
        p: &BlockParams<'_>,
        b: usize,
        s: usize,
        h: usize,
        heads: usize,
    ) -> (Vec<f32>, Vec<Vec<f32>>) {
        let m = WsMeter::default();
        block_backward_remat(
            pool, lv(), gm(), &mut Vec::new(), &mut m.scope(), x, dy, p, b, s, h, heads,
        )
    }

    const B: usize = 2;
    const S: usize = 3;
    const H: usize = 4;
    const HEADS: usize = 2;
    const F: usize = 8;

    fn tp() -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(1))
    }

    /// Program constructors with the env-selected SIMD level and GEMM
    /// engine — keeps the call sites short and fmt-stable.
    fn bfwd(arena: Arc<ActivationArena>) -> BlockFwd {
        BlockFwd { heads: HEADS, pool: tp(), arena, simd: lv(), gemm: gm() }
    }

    fn bbwd(arena: Arc<ActivationArena>) -> BlockBwd {
        BlockBwd { heads: HEADS, pool: tp(), arena, simd: lv(), gemm: gm() }
    }

    fn hloss(arena: Arc<ActivationArena>) -> HeadLoss {
        HeadLoss { pool: tp(), arena, simd: lv(), gemm: gm() }
    }

    fn heval(arena: Arc<ActivationArena>) -> HeadEval {
        HeadEval { pool: tp(), arena, simd: lv(), gemm: gm() }
    }

    /// Owned block parameters in manifest order.
    struct Params {
        t: Vec<Vec<f32>>,
    }

    impl Params {
        fn sizes() -> [usize; 12] {
            [H, H, H * 3 * H, 3 * H, H * H, H, H, H, H * F, F, F * H, H]
        }

        fn random(seed: u64) -> Self {
            let mut rng = Rng::new(seed);
            let t = Self::sizes()
                .iter()
                .enumerate()
                .map(|(idx, &n)| {
                    (0..n)
                        .map(|_| match idx {
                            0 | 6 => 1.0 + 0.1 * rng.normal(), // LN gains near 1
                            1 | 7 | 3 | 5 | 9 | 11 => 0.1 * rng.normal(), // biases small
                            _ => 0.4 * rng.normal(),
                        })
                        .collect()
                })
                .collect();
            Self { t }
        }

        fn view(&self) -> BlockParams<'_> {
            BlockParams {
                ln1g: &self.t[0],
                ln1b: &self.t[1],
                wqkv: &self.t[2],
                bqkv: &self.t[3],
                wo: &self.t[4],
                bo: &self.t[5],
                ln2g: &self.t[6],
                ln2b: &self.t[7],
                w1: &self.t[8],
                b1: &self.t[9],
                w2: &self.t[10],
                b2: &self.t[11],
                f: F,
            }
        }
    }

    fn randvec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| scale * rng.normal()).collect()
    }

    /// Scalar objective: L = Σ y ∘ r for a fixed random cotangent r.
    fn objective(pool: &ThreadPool, x: &[f32], p: &Params, r: &[f32]) -> f32 {
        let st = fwd(pool, x, &p.view(), B, S, H, HEADS);
        st.y.iter().zip(r).map(|(a, c)| a * c).sum()
    }

    fn close(fd: f32, an: f32) -> bool {
        (fd - an).abs() < 0.02 + 0.05 * fd.abs().max(an.abs())
    }

    #[test]
    fn block_backward_dx_matches_finite_differences() {
        let pool = tp();
        let x = randvec(1, B * S * H, 0.8);
        let p = Params::random(2);
        let r = randvec(3, B * S * H, 1.0);
        let (dx, _dp) = bwd(&pool, &x, &r, &p.view(), B, S, H, HEADS);
        let eps = 1e-2f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd =
                (objective(&pool, &xp, &p, &r) - objective(&pool, &xm, &p, &r)) / (2.0 * eps);
            assert!(close(fd, dx[i]), "dx[{i}]: fd {fd} vs analytic {}", dx[i]);
        }
    }

    #[test]
    fn block_backward_dparams_match_finite_differences() {
        let pool = tp();
        let x = randvec(4, B * S * H, 0.8);
        let p = Params::random(5);
        let r = randvec(6, B * S * H, 1.0);
        let (_dx, dp) = bwd(&pool, &x, &r, &p.view(), B, S, H, HEADS);
        let eps = 1e-2f32;
        for (ti, size) in Params::sizes().iter().enumerate() {
            assert_eq!(dp[ti].len(), *size, "tensor {ti} grad size");
            for i in 0..*size {
                let mut pp = Params::random(5);
                pp.t[ti][i] += eps;
                let mut pm = Params::random(5);
                pm.t[ti][i] -= eps;
                let fd =
                    (objective(&pool, &x, &pp, &r) - objective(&pool, &x, &pm, &r)) / (2.0 * eps);
                assert!(
                    close(fd, dp[ti][i]),
                    "param {ti}[{i}]: fd {fd} vs analytic {}",
                    dp[ti][i]
                );
            }
        }
    }

    #[test]
    fn block_is_causal() {
        // Perturbing position s0 must not change outputs at earlier
        // positions (causal mask), and must change later ones.
        let pool = tp();
        let x = randvec(7, B * S * H, 0.8);
        let p = Params::random(8);
        let y0 = fwd(&pool, &x, &p.view(), B, S, H, HEADS).y;
        let mut x2 = x.clone();
        for j in 0..H {
            x2[(S - 1) * H + j] += 0.5; // batch 0, last position
        }
        let y1 = fwd(&pool, &x2, &p.view(), B, S, H, HEADS).y;
        for si in 0..S - 1 {
            for j in 0..H {
                let idx = si * H + j;
                assert_eq!(y0[idx], y1[idx], "earlier position {si} changed");
            }
        }
        let last: f32 = (0..H)
            .map(|j| (y0[(S - 1) * H + j] - y1[(S - 1) * H + j]).abs())
            .sum();
        assert!(last > 1e-3, "perturbed position must change");
    }

    #[test]
    fn block_forward_and_backward_thread_count_invariant() {
        // Bigger-than-cutoff shapes so the attention fan-out is live, then
        // bit-compare 1-thread vs 3-thread results.
        let (b, s, h, heads) = (2usize, 32usize, 8usize, 2usize);
        let f = 4 * h;
        let sizes = [h, h, h * 3 * h, 3 * h, h * h, h, h, h, h * f, f, f * h, h];
        let mut rng = Rng::new(99);
        let t: Vec<Vec<f32>> =
            sizes.iter().map(|&n| (0..n).map(|_| 0.3 * rng.normal()).collect()).collect();
        let p = BlockParams {
            ln1g: &t[0],
            ln1b: &t[1],
            wqkv: &t[2],
            bqkv: &t[3],
            wo: &t[4],
            bo: &t[5],
            ln2g: &t[6],
            ln2b: &t[7],
            w1: &t[8],
            b1: &t[9],
            w2: &t[10],
            b2: &t[11],
            f,
        };
        let x = randvec(100, b * s * h, 0.8);
        let dy = randvec(101, b * s * h, 1.0);
        let p1 = ThreadPool::new(1);
        let p3 = ThreadPool::new(3);
        let y1 = fwd(&p1, &x, &p, b, s, h, heads).y;
        let y3 = fwd(&p3, &x, &p, b, s, h, heads).y;
        assert!(y1.iter().zip(&y3).all(|(a, c)| a.to_bits() == c.to_bits()));
        let (dx1, dp1) = bwd(&p1, &x, &dy, &p, b, s, h, heads);
        let (dx3, dp3) = bwd(&p3, &x, &dy, &p, b, s, h, heads);
        assert!(dx1.iter().zip(&dx3).all(|(a, c)| a.to_bits() == c.to_bits()));
        for (g1, g3) in dp1.iter().zip(&dp3) {
            assert!(g1.iter().zip(g3).all(|(a, c)| a.to_bits() == c.to_bits()));
        }
    }

    #[test]
    fn head_loss_grads_match_finite_differences() {
        let (b, s, h, v) = (1usize, 2usize, 3usize, 5usize);
        let x = randvec(9, b * s * h, 1.0);
        let w = randvec(10, h * v, 0.7);
        let labels: Vec<i32> = vec![1, 4];

        let arena = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        let head = hloss(arena);
        let run = |x: &[f32], w: &[f32]| -> (f32, Vec<Value>) {
            let out = head
                .run(&[
                    Arg::F32(x, &[b, s, h]),
                    Arg::F32(w, &[h, v]),
                    Arg::I32(&labels, &[b, s]),
                ])
                .unwrap();
            (out[0].first_f32().unwrap(), out)
        };
        let (_, out) = run(&x, &w);
        let dx = out[1].as_f32().unwrap().to_vec();
        let dw = out[2].as_f32().unwrap().to_vec();

        let eps = 1e-2f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (run(&xp, &w).0 - run(&xm, &w).0) / (2.0 * eps);
            assert!((fd - dx[i]).abs() < 5e-3, "dx[{i}]: fd {fd} vs {}", dx[i]);
        }
        for i in 0..w.len() {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let fd = (run(&x, &wp).0 - run(&x, &wm).0) / (2.0 * eps);
            assert!((fd - dw[i]).abs() < 5e-3, "dW[{i}]: fd {fd} vs {}", dw[i]);
        }
    }

    #[test]
    fn embed_roundtrip_and_grads() {
        let (vocab, hidden, b, s) = (6usize, 4usize, 2usize, 3usize);
        let tokens: Vec<i32> = vec![0, 2, 5, 2, 1, 0];
        let e = randvec(11, vocab * hidden, 0.5);
        let p = randvec(12, s * hidden, 0.5);

        let fwd = EmbedFwd { vocab, hidden, pool: tp(), simd: lv() };
        let out = fwd
            .run(&[
                Arg::I32(&tokens, &[b, s]),
                Arg::F32(&e, &[vocab, hidden]),
                Arg::F32(&p, &[s, hidden]),
            ])
            .unwrap();
        let x = out[0].as_f32().unwrap();
        // spot-check: x[0,0] = E[0] + P[0]
        for j in 0..hidden {
            assert!((x[j] - (e[j] + p[j])).abs() < 1e-6);
        }

        // embed_bwd: scatter-add over tokens, batch-sum over positions
        let dx = randvec(13, b * s * hidden, 1.0);
        let bwd = EmbedBwd { vocab, hidden, simd: lv() };
        let out = bwd
            .run(&[Arg::I32(&tokens, &[b, s]), Arg::F32(&dx, &[b, s, hidden])])
            .unwrap();
        let de = out[0].as_f32().unwrap();
        let dp = out[1].as_f32().unwrap();
        // token 2 appears at flat positions 1 and 3
        for j in 0..hidden {
            let want = dx[hidden + j] + dx[3 * hidden + j];
            assert!((de[2 * hidden + j] - want).abs() < 1e-6);
            // dP[si] sums over batch
            let want_p = dx[j] + dx[(s * hidden) + j];
            assert!((dp[j] - want_p).abs() < 1e-6);
        }
        // totals conserved
        let total_dx: f32 = dx.iter().sum();
        let total_de: f32 = de.iter().sum();
        assert!((total_dx - total_de).abs() < 1e-4);
    }

    #[test]
    fn block_programs_have_artifact_shapes() {
        let x = randvec(14, B * S * H, 0.5);
        let dy = randvec(15, B * S * H, 0.5);
        let p = Params::random(16);
        let mut args: Vec<Arg<'_>> = vec![Arg::F32(&x, &[B, S, H]), Arg::F32(&dy, &[B, S, H])];
        let shapes: [Vec<usize>; 12] = [
            vec![H],
            vec![H],
            vec![H, 3 * H],
            vec![3 * H],
            vec![H, H],
            vec![H],
            vec![H],
            vec![H],
            vec![H, F],
            vec![F],
            vec![F, H],
            vec![H],
        ];
        for (t, sh) in p.t.iter().zip(shapes.iter()) {
            args.push(Arg::F32(t, sh));
        }
        let arena = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        let out = bbwd(arena.clone()).run(&args).unwrap();
        assert_eq!(out.len(), 13);
        assert_eq!(out[0].shape(), &[B, S, H]);
        for (o, sh) in out[1..].iter().zip(shapes.iter()) {
            assert_eq!(o.shape(), &sh[..]);
        }

        let fwd_args: Vec<Arg<'_>> =
            args.iter().enumerate().filter(|(i, _)| *i != 1).map(|(_, a)| *a).collect();
        let out = bfwd(arena).run(&fwd_args).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[B, S, H]);
    }

    /// Build the (x, dy, params) argument vectors for the block programs.
    fn block_args<'a>(
        x: &'a [f32],
        dy: &'a [f32],
        p: &'a Params,
    ) -> (Vec<Arg<'a>>, Vec<Arg<'a>>) {
        let shapes: [Vec<usize>; 12] = [
            vec![H],
            vec![H],
            vec![H, 3 * H],
            vec![3 * H],
            vec![H, H],
            vec![H],
            vec![H],
            vec![H],
            vec![H, F],
            vec![F],
            vec![F, H],
            vec![H],
        ];
        let shapes: Vec<Vec<usize>> = shapes.to_vec();
        // leak the shapes: test-only, keeps the borrow story trivial
        let shapes: &'static [Vec<usize>] = Box::leak(shapes.into_boxed_slice());
        let mut fwd_args: Vec<Arg<'a>> = vec![Arg::F32(x, &[B, S, H])];
        let mut bwd_args: Vec<Arg<'a>> =
            vec![Arg::F32(x, &[B, S, H]), Arg::F32(dy, &[B, S, H])];
        for (t, sh) in p.t.iter().zip(shapes.iter()) {
            fwd_args.push(Arg::F32(t, sh));
            bwd_args.push(Arg::F32(t, sh));
        }
        (fwd_args, bwd_args)
    }

    #[test]
    fn stashed_backward_is_bit_identical_to_remat() {
        let x = randvec(21, B * S * H, 0.8);
        let dy = randvec(22, B * S * H, 1.0);
        let p = Params::random(23);
        let (fwd_args, bwd_args) = block_args(&x, &dy, &p);

        // remat reference
        let remat = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        let ref_out = bbwd(remat).run(&bwd_args).unwrap();

        // stash path: forward populates the arena, backward consumes it
        let arena = Arc::new(ActivationArena::new(MemoryPlan::unlimited()));
        let y = bfwd(arena.clone()).run(&fwd_args).unwrap();
        assert_eq!(arena.stats().stashed, 1, "forward must stash");
        let stash_out = bbwd(arena.clone()).run(&bwd_args).unwrap();
        let s = arena.stats();
        assert_eq!(s.stash_hits, 1, "backward must consume the stash");
        assert_eq!(s.stash_live_bytes, 0, "consumed entry must be freed");
        assert!(s.stash_peak_bytes > 0);

        assert_eq!(ref_out.len(), stash_out.len());
        for (a, b) in ref_out.iter().zip(&stash_out) {
            let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            assert!(a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
        // the forward output is unaffected by stashing
        assert_eq!(y[0].shape(), &[B, S, H]);
    }

    #[test]
    fn workspace_and_stash_accounting_match_memmodel() {
        // the allocation-site-level reconciliation: every ws.add()/stash
        // in this file must be mirrored by memmodel::HostBlockDims
        use crate::memmodel::HostBlockDims;
        let dims = HostBlockDims {
            batch: B as u64,
            seq: S as u64,
            hidden: H as u64,
            heads: HEADS as u64,
            ffn: F as u64,
        };
        let x = randvec(41, B * S * H, 0.8);
        let dy = randvec(42, B * S * H, 1.0);
        let p = Params::random(43);
        let (fwd_args, bwd_args) = block_args(&x, &dy, &p);

        let arena = Arc::new(ActivationArena::new(MemoryPlan::unlimited()));
        bfwd(arena.clone()).run(&fwd_args).unwrap();
        let s1 = arena.stats();
        assert_eq!(s1.workspace_peak_bytes, dims.fwd_workspace_bytes(gm()));
        assert_eq!(s1.stash_live_bytes, dims.stash_entry_bytes());

        bbwd(arena.clone()).run(&bwd_args).unwrap();
        let s2 = arena.stats();
        assert_eq!(
            s2.workspace_peak_bytes,
            dims.fwd_workspace_bytes(gm()).max(dims.bwd_workspace_bytes(gm())),
            "stash-hit backward must not pay the recompute workspace"
        );
        assert_eq!(s2.workspace_live_bytes, 0);

        let remat = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        bbwd(remat.clone()).run(&bwd_args).unwrap();
        assert_eq!(remat.stats().workspace_peak_bytes, dims.remat_bwd_workspace_bytes(gm()));
    }

    #[test]
    fn head_workspace_accounting_matches_memmodel() {
        // PR-3 follow-up: the head logits (largest single buffer at
        // realistic vocab sizes) are metered through the actmem arena and
        // predicted exactly by memmodel.
        use crate::memmodel::HostBlockDims;
        let dims = HostBlockDims {
            batch: B as u64,
            seq: S as u64,
            hidden: H as u64,
            heads: HEADS as u64,
            ffn: F as u64,
        };
        let v = 5usize;
        let x = randvec(51, B * S * H, 0.8);
        let w = randvec(52, H * v, 0.6);
        let labels: Vec<i32> = (0..B * S).map(|i| (i % v) as i32).collect();
        let args = [Arg::F32(&x, &[B, S, H]), Arg::F32(&w, &[H, v]), Arg::I32(&labels, &[B, S])];

        let arena = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        hloss(arena.clone()).run(&args).unwrap();
        let stats = arena.stats();
        assert_eq!(stats.workspace_peak_bytes, dims.head_loss_workspace_bytes(v as u64, gm()));
        assert_eq!(stats.workspace_live_bytes, 0, "head workspace must drain");

        let arena = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        heval(arena.clone()).run(&args).unwrap();
        assert_eq!(
            arena.stats().workspace_peak_bytes,
            dims.head_eval_workspace_bytes(v as u64, gm())
        );
    }

    #[test]
    fn block_decode_matches_block_fwd_bit_for_bit() {
        // the serving headline at unit scale: prefill-all-at-once AND
        // token-by-token KV-cached decode both reproduce the exact bits
        // of the full-context block forward
        let x = randvec(61, S * H, 0.8);
        let p = Params::random(62);
        let arena = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        let dec = BlockDecode {
            heads: HEADS,
            pool: tp(),
            arena: arena.clone(),
            simd: lv(),
            gemm: gm(),
        };

        // full-context reference: block_fwd on [1, S, H]
        let shapes: [Vec<usize>; 12] = [
            vec![H],
            vec![H],
            vec![H, 3 * H],
            vec![3 * H],
            vec![H, H],
            vec![H],
            vec![H],
            vec![H],
            vec![H, F],
            vec![F],
            vec![F, H],
            vec![H],
        ];
        let mut fwd_args: Vec<Arg<'_>> = vec![Arg::F32(&x, &[1, S, H])];
        for (t, sh) in p.t.iter().zip(shapes.iter()) {
            fwd_args.push(Arg::F32(t, sh));
        }
        let want = bfwd(arena.clone()).run(&fwd_args).unwrap();
        let want = want[0].as_f32().unwrap();

        // prefill: all S rows in one ragged call, empty cache
        let news = [S as i32];
        let lens = [0i32];
        let empty: Vec<f32> = Vec::new();
        let mut dec_args: Vec<Arg<'_>> = vec![
            Arg::F32(&x, &[S, H]),
            Arg::I32(&news, &[1]),
            Arg::I32(&lens, &[1]),
            Arg::F32(&empty, &[0, H]),
            Arg::F32(&empty, &[0, H]),
        ];
        for (t, sh) in p.t.iter().zip(shapes.iter()) {
            dec_args.push(Arg::F32(t, sh));
        }
        let out = dec.run(&dec_args).unwrap();
        let y = out[0].as_f32().unwrap();
        assert!(
            y.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "prefill decode must equal full forward"
        );

        // token-by-token: grow the KV cache one row at a time
        let mut kcache: Vec<f32> = Vec::new();
        let mut vcache: Vec<f32> = Vec::new();
        let mut got: Vec<f32> = Vec::new();
        for t in 0..S {
            let row = &x[t * H..(t + 1) * H];
            let news = [1i32];
            let lens = [t as i32];
            let mut args: Vec<Arg<'_>> = vec![
                Arg::F32(row, &[1, H]),
                Arg::I32(&news, &[1]),
                Arg::I32(&lens, &[1]),
                Arg::F32(&kcache, &[t, H]),
                Arg::F32(&vcache, &[t, H]),
            ];
            for (tn, sh) in p.t.iter().zip(shapes.iter()) {
                args.push(Arg::F32(tn, sh));
            }
            let out = dec.run(&args).unwrap();
            got.extend_from_slice(out[0].as_f32().unwrap());
            kcache.extend_from_slice(out[1].as_f32().unwrap());
            vcache.extend_from_slice(out[2].as_f32().unwrap());
        }
        assert!(
            got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "incremental decode must equal full forward"
        );
    }

    #[test]
    fn head_logits_matches_head_loss_logits_path() {
        // head_logits on the last row equals the full-context projection
        // of that row (matmul folds are row-count independent)
        let (n, h, v) = (3usize, H, 5usize);
        let x = randvec(71, n * h, 0.8);
        let w = randvec(72, h * v, 0.6);
        let arena = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        let head = HeadLogits { pool: tp(), arena, simd: lv(), gemm: gm() };
        let full =
            head.run(&[Arg::F32(&x, &[n, h]), Arg::F32(&w, &[h, v])]).unwrap();
        let full = full[0].as_f32().unwrap().to_vec();
        let last = &x[(n - 1) * h..];
        let one = head.run(&[Arg::F32(last, &[1, h]), Arg::F32(&w, &[h, v])]).unwrap();
        let one = one[0].as_f32().unwrap();
        assert!(one
            .iter()
            .zip(&full[(n - 1) * v..])
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn stash_misses_on_changed_input_and_rematerialises() {
        let x = randvec(31, B * S * H, 0.8);
        let dy = randvec(32, B * S * H, 1.0);
        let p = Params::random(33);
        let arena = Arc::new(ActivationArena::new(MemoryPlan::unlimited()));
        let (fwd_args, _) = block_args(&x, &dy, &p);
        bfwd(arena.clone()).run(&fwd_args).unwrap();

        // different x: the stashed entry must NOT be consumed
        let x2 = randvec(34, B * S * H, 0.8);
        let (_, bwd_args2) = block_args(&x2, &dy, &p);
        let remat = Arc::new(ActivationArena::new(MemoryPlan::remat()));
        let want = bbwd(remat).run(&bwd_args2).unwrap();
        let got = bbwd(arena.clone()).run(&bwd_args2).unwrap();
        let s = arena.stats();
        assert_eq!(s.stash_hits, 0);
        assert_eq!(s.remats, 1);
        assert_eq!(s.stash_live_bytes, s.stash_peak_bytes);
        for (a, b) in want.iter().zip(&got) {
            let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            assert!(a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits()));
        }
    }
}
