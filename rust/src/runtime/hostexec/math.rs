//! Dense f32 math for the host executor's model programs, parallelised
//! over the deterministic chunked thread pool ([`crate::runtime::pool`])
//! and vectorised through the runtime-dispatched SIMD layer
//! ([`crate::runtime::simd`]).
//!
//! ## Matmuls: packed engine with a naive A/B baseline
//!
//! The three matmul variants dispatch on [`gemm::GemmMode`]
//! (`ADAMA_GEMM`):
//!
//! * **Packed** (default) routes through [`gemm::packed_gemm`] — B
//!   packed into L2-resident `KC × NC` panels, `MR × Lanes`-width
//!   register tiles over the output, cache-blocking over M/N/K, rows
//!   pool-parallel. The NT variant's former scalar dot products become
//!   lane-parallel *output* tiles via transpose-packing. See the
//!   `gemm` module docs for the blocking scheme and the proof that
//!   every output element keeps the naive serial fold.
//! * **Naive** keeps the original loops below — row-parallel axpy for
//!   NN/TN, serial scalar dots for NT — as the A/B baseline the
//!   nightly bench gates the packed speedup against.
//!
//! Both engines produce the exact per-element accumulation order of the
//! serial scalar loop (p ascending, multiply-then-add, no FMA; the SIMD
//! layer vectorises only across independent outputs), so results are
//! bit-for-bit identical at any thread count, any `ADAMA_SIMD` level
//! *and* either `ADAMA_GEMM` engine (locked down by
//! `rust/tests/determinism.rs`, `rust/tests/simd_parity.rs` and the
//! packed==naive proptests).
//!
//! The packing panel is caller-owned (`panel: &mut Vec<f32>`): each host
//! program pre-sizes one panel via [`gemm::panel_elems`] to the max over
//! its matmul shapes, meters it through the actmem `WsMeter`, and reuses
//! it across calls. Naive mode never touches it.
//!
//! Cross-row reductions (`col_sums`, `layer_norm_bwd`'s dg/db, the NLL
//! sum) and the remaining in-row reductions (per-row mean/var, max/exp
//! sweeps) are order-sensitive, so they stay serial scalar or reduce
//! fixed-size per-row partials in ascending row order.

use super::gemm::{self, BLayout, GemmMode};
use crate::runtime::pool::ThreadPool;
use crate::runtime::simd;

/// `out[m,n] = a[m,k] @ b[k,n]`. Packed: blocked engine with `ars = k,
/// ads = 1`. Naive: output rows pool-parallel, per-`p` axpy rows
/// lane-parallel. Both keep each cell's p-ascending serial fold.
#[allow(clippy::too_many_arguments)]
pub fn matmul(
    pool: &ThreadPool,
    lvl: simd::Level,
    gm: GemmMode,
    panel: &mut Vec<f32>,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if gm == GemmMode::Packed {
        gemm::packed_gemm(pool, lvl, a, k, 1, b, BLayout::Rows, m, k, n, out, panel);
        return;
    }
    pool.for_rows(out, n, |i, row| {
        row.fill(0.0);
        for p in 0..k {
            let aip = a[i * k + p];
            simd::axpy(lvl, row, &b[p * n..(p + 1) * n], aip);
        }
    });
}

/// `out[m,n] = aᵀ @ b` with `a:[p,m]`, `b:[p,n]` (weight-gradient shape).
/// Packed: the blocked engine reads A transposed in place (`ars = 1,
/// ads = m`) — no A copy. Naive: row-parallel axpy. Both keep the
/// r-ascending per-cell fold of the original serial form.
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn(
    pool: &ThreadPool,
    lvl: simd::Level,
    gm: GemmMode,
    panel: &mut Vec<f32>,
    a: &[f32],
    b: &[f32],
    p: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), p * m);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(out.len(), m * n);
    if gm == GemmMode::Packed {
        gemm::packed_gemm(pool, lvl, a, 1, m, b, BLayout::Rows, m, p, n, out, panel);
        return;
    }
    pool.for_rows(out, n, |i, row| {
        row.fill(0.0);
        for r in 0..p {
            let ari = a[r * m + i];
            simd::axpy(lvl, row, &b[r * n..(r + 1) * n], ari);
        }
    });
}

/// `out[m,n] = a @ bᵀ` with `a:[m,k]`, `b:[n,k]` (input-gradient shape).
/// The inner dot product is an in-order reduction over `k`, which the
/// bit-exactness contract forbids folding into lanes. Packed mode
/// vectorises it anyway — across *outputs*: transpose-packing B turns
/// adjacent output columns into independent lane-parallel folds, each
/// still the serial k-ascending dot. Naive mode keeps the scalar dot
/// per cell (rows pool-parallel).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt(
    pool: &ThreadPool,
    lvl: simd::Level,
    gm: GemmMode,
    panel: &mut Vec<f32>,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if gm == GemmMode::Packed {
        gemm::packed_gemm(pool, lvl, a, k, 1, b, BLayout::Trans, m, k, n, out, panel);
        return;
    }
    let _ = lvl; // naive reduction kernel: no lane-parallel inner step
    pool.for_rows(out, n, |i, row| {
        let arow = &a[i * k..(i + 1) * k];
        for (j, o) in row.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *o = acc;
        }
    });
}

/// Add a `[cols]` bias to every row of `x:[rows, cols]`. Rows stay
/// serial (cheap O(rows·cols) next to the adjacent matmuls) but each
/// row's add is lane-parallel.
pub fn add_bias(lvl: simd::Level, x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_mut(bias.len()) {
        simd::add_assign(lvl, row, bias);
    }
}

/// `out[j] = Σ_i x[i,j]` — bias-gradient column sums. Serial on purpose:
/// the row-order float accumulation is the determinism contract.
pub fn col_sums(x: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(out.len(), cols);
    out.fill(0.0);
    for row in x.chunks(cols) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

/// Tanh-approximated GELU (jax.nn.gelu with approximate=True — the form
/// baked into the AOT artifacts). Scalar: `tanh` is a libm call whose
/// bits a vector polynomial could not reproduce.
pub fn gelu(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_A * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

/// d gelu(x) / dx for the tanh approximation.
pub fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_A * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

/// LayerNorm eps matching `model.py::layer_norm`.
pub const LN_EPS: f32 = 1e-5;

/// Row-wise layer norm: `out = (x - mu)/sqrt(var + eps) * g + b` with the
/// biased variance (1/cols), matching `jnp.var`. Rows are pool-parallel
/// (each output row depends only on its input row); the mean/variance
/// reductions stay serial per row, the normalise step is lane-parallel.
#[allow(clippy::too_many_arguments)]
pub fn layer_norm(
    pool: &ThreadPool,
    lvl: simd::Level,
    x: &[f32],
    g: &[f32],
    b: &[f32],
    rows: usize,
    cols: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * cols);
    pool.for_rows(out, cols, |r, oi| {
        let xi = &x[r * cols..(r + 1) * cols];
        let mu = xi.iter().sum::<f32>() / cols as f32;
        let var = xi.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
        let rstd = 1.0 / (var + LN_EPS).sqrt();
        simd::norm_affine(lvl, oi, xi, g, b, mu, rstd);
    });
}

/// Layer-norm backward: accumulates `dx` (+=, for residual fan-in) and
/// fills `dg`/`db` gradients (+= as well, caller zeroes). Serial across
/// rows (dg/db accumulate in row order — the order-sensitive part); the
/// per-row dx closed form is lane-parallel.
#[allow(clippy::too_many_arguments)]
pub fn layer_norm_bwd(
    lvl: simd::Level,
    x: &[f32],
    g: &[f32],
    dy: &[f32],
    rows: usize,
    cols: usize,
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(dy.len(), rows * cols);
    debug_assert_eq!(dx.len(), rows * cols);
    let inv_c = 1.0 / cols as f32;
    for r in 0..rows {
        let xi = &x[r * cols..(r + 1) * cols];
        let di = &dy[r * cols..(r + 1) * cols];
        let mu = xi.iter().sum::<f32>() * inv_c;
        let var = xi.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() * inv_c;
        let rstd = 1.0 / (var + LN_EPS).sqrt();
        // dxhat, plus the two row means the closed form needs
        let mut mean_dxhat = 0.0f32;
        let mut mean_dxhat_xhat = 0.0f32;
        for j in 0..cols {
            let xhat = (xi[j] - mu) * rstd;
            let dxhat = di[j] * g[j];
            mean_dxhat += dxhat;
            mean_dxhat_xhat += dxhat * xhat;
            dg[j] += di[j] * xhat;
            db[j] += di[j];
        }
        mean_dxhat *= inv_c;
        mean_dxhat_xhat *= inv_c;
        let oi = &mut dx[r * cols..(r + 1) * cols];
        simd::ln_bwd_dx(lvl, oi, xi, di, g, mu, rstd, mean_dxhat, mean_dxhat_xhat);
    }
}

/// Per-row softmax cross-entropy over `logits:[rows, cols]` with integer
/// labels. Returns `(total_nll, ncorrect)` and fills `dlogits` with the
/// *unscaled* `(softmax - onehot)` — callers divide by the token count.
///
/// Rows are pool-parallel into `dlogits` plus per-row `[nll, correct]`
/// partials; the partials then reduce serially in ascending row order, so
/// the f64 NLL sum is bit-identical to the fully serial loop. The max/exp
/// sweeps stay scalar (reduction + libm); the probability normalisation
/// is lane-parallel.
#[allow(clippy::too_many_arguments)]
pub fn softmax_xent(
    pool: &ThreadPool,
    lvl: simd::Level,
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    cols: usize,
    dlogits: &mut [f32],
) -> (f64, i32) {
    debug_assert_eq!(logits.len(), rows * cols);
    debug_assert_eq!(labels.len(), rows);
    debug_assert_eq!(dlogits.len(), rows * cols);
    let mut row_stats = vec![0.0f64; rows * 2]; // [nll, correct] per row
    pool.for_rows2(dlogits, cols, &mut row_stats, 2, |r, di, stat| {
        let li = &logits[r * cols..(r + 1) * cols];
        let label = labels[r] as usize;
        debug_assert!(label < cols);
        // max + argmax (first occurrence, matching jnp.argmax)
        let mut mx = f32::NEG_INFINITY;
        let mut amax = 0usize;
        for (j, &v) in li.iter().enumerate() {
            if v > mx {
                mx = v;
                amax = j;
            }
        }
        let mut sum = 0.0f32;
        for (d, &v) in di.iter_mut().zip(li) {
            let e = (v - mx).exp();
            *d = e;
            sum += e;
        }
        let inv_sum = 1.0 / sum;
        simd::scale(lvl, di, inv_sum); // now softmax probabilities
        stat[0] = -((li[label] - mx) - sum.ln()) as f64;
        stat[1] = f64::from(u8::from(amax == label));
        di[label] -= 1.0; // softmax - onehot
    });
    let mut nll = 0.0f64;
    let mut ncorrect = 0i32;
    for stat in row_stats.chunks_exact(2) {
        nll += stat[0];
        ncorrect += stat[1] as i32;
    }
    (nll, ncorrect)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serial() -> ThreadPool {
        ThreadPool::new(1)
    }

    /// Detected SIMD level — unit tests run the vector path where the
    /// host supports one (parity with scalar is pinned in
    /// `rust/tests/simd_parity.rs`).
    fn lv() -> simd::Level {
        simd::detect()
    }

    /// GEMM engine under test — the env-selected mode, so the
    /// `ADAMA_GEMM` CI legs sweep both engines through every unit test.
    fn gm() -> GemmMode {
        GemmMode::from_env().expect("invalid ADAMA_GEMM environment")
    }

    #[test]
    fn matmul_agrees_with_transposed_forms() {
        let pool = serial();
        let mut panel = Vec::new();
        // a:[2,3], b:[3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let mut ab = [0.0f32; 4];
        matmul(&pool, lv(), gm(), &mut panel, &a, &b, 2, 3, 2, &mut ab);
        assert_eq!(ab, [58.0, 64.0, 139.0, 154.0]);

        // aᵀ@b with a stored as [p=2, m=3] must equal matmul of transposed a
        let mut tn = [0.0f32; 9];
        matmul_tn(&pool, lv(), gm(), &mut panel, &a, &a, 2, 3, 3, &mut tn);
        // (aᵀa)[i][j] = sum_r a[r,i] a[r,j]
        assert_eq!(tn[0], 1.0 * 1.0 + 4.0 * 4.0);
        assert_eq!(tn[4], 2.0 * 2.0 + 5.0 * 5.0);

        // a@bᵀ with b stored as [n=3, k=3]
        let c = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let mut nt = [0.0f32; 6];
        matmul_nt(&pool, lv(), gm(), &mut panel, &a, &c, 2, 3, 3, &mut nt);
        assert_eq!(nt, a);
    }

    #[test]
    fn packed_and_naive_engines_are_bitwise_identical() {
        let pool = serial();
        let (m, k, n) = (9usize, 31usize, 14usize);
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 29 + 3) as f32 * 0.013).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 17 + 7) as f32 * 0.021).cos()).collect();
        let bt: Vec<f32> = (0..n * k).map(|i| ((i * 23 + 1) as f32 * 0.017).sin()).collect();
        let at: Vec<f32> = (0..k * m).map(|i| ((i * 41 + 9) as f32 * 0.011).cos()).collect();
        let same = |x: &[f32], y: &[f32]| x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits());

        let mut panel = Vec::new();
        let (mut p1, mut n1) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        matmul(&pool, lv(), GemmMode::Packed, &mut panel, &a, &b, m, k, n, &mut p1);
        matmul(&pool, lv(), GemmMode::Naive, &mut panel, &a, &b, m, k, n, &mut n1);
        assert!(same(&p1, &n1), "matmul NN");

        let (mut p2, mut n2) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        matmul_tn(&pool, lv(), GemmMode::Packed, &mut panel, &at, &b, k, m, n, &mut p2);
        matmul_tn(&pool, lv(), GemmMode::Naive, &mut panel, &at, &b, k, m, n, &mut n2);
        assert!(same(&p2, &n2), "matmul TN");

        let (mut p3, mut n3) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        matmul_nt(&pool, lv(), GemmMode::Packed, &mut panel, &a, &bt, m, k, n, &mut p3);
        matmul_nt(&pool, lv(), GemmMode::Naive, &mut panel, &a, &bt, m, k, n, &mut n3);
        assert!(same(&p3, &n3), "matmul NT");
    }

    #[test]
    fn parallel_rows_bitwise_match_serial() {
        // big enough to clear the pool's inline cutoff on every path
        let (m, k, n) = (48usize, 17usize, 40usize);
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 37 + 11) as f32 * 0.01).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 13 + 5) as f32 * 0.02).cos()).collect();
        let pool1 = ThreadPool::new(1);
        for threads in [2usize, 3, 7] {
            let poolt = ThreadPool::new(threads);
            let mut o1 = vec![0.0f32; m * n];
            let mut o2 = vec![0.0f32; m * n];
            matmul(&pool1, lv(), gm(), &mut Vec::new(), &a, &b, m, k, n, &mut o1);
            matmul(&poolt, lv(), gm(), &mut Vec::new(), &a, &b, m, k, n, &mut o2);
            assert!(o1.iter().zip(&o2).all(|(x, y)| x.to_bits() == y.to_bits()));

            let g: Vec<f32> = (0..n).map(|j| 1.0 + 0.01 * j as f32).collect();
            let bias = vec![0.1f32; n];
            let mut l1 = vec![0.0f32; m * n];
            let mut l2 = vec![0.0f32; m * n];
            layer_norm(&pool1, lv(), &o1, &g, &bias, m, n, &mut l1);
            layer_norm(&poolt, lv(), &o1, &g, &bias, m, n, &mut l2);
            assert!(l1.iter().zip(&l2).all(|(x, y)| x.to_bits() == y.to_bits()));

            let labels: Vec<i32> = (0..m).map(|r| (r % n) as i32).collect();
            let mut d1 = vec![0.0f32; m * n];
            let mut d2 = vec![0.0f32; m * n];
            let (nll1, nc1) = softmax_xent(&pool1, lv(), &l1, &labels, m, n, &mut d1);
            let (nll2, nc2) = softmax_xent(&poolt, lv(), &l1, &labels, m, n, &mut d2);
            assert_eq!(nll1.to_bits(), nll2.to_bits());
            assert_eq!(nc1, nc2);
            assert!(d1.iter().zip(&d2).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn layer_norm_rows_are_standardised() {
        let pool = serial();
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let g = [1.0f32, 1.0, 1.0, 1.0];
        let b = [0.0f32; 4];
        let mut out = [0.0f32; 4];
        layer_norm(&pool, lv(), &x, &g, &b, 1, 4, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layer_norm_bwd_matches_finite_differences() {
        let pool = serial();
        let x = [0.3f32, -0.7, 1.1, 0.4, 0.9, -0.2, 0.05, -1.3];
        let g = [1.1f32, 0.9, 1.0, 1.2];
        let b = [0.1f32, -0.1, 0.0, 0.2];
        let dy = [0.7f32, -0.3, 0.5, 0.2, -0.6, 0.4, 0.1, 0.8];
        let (rows, cols) = (2usize, 4usize);

        let mut dx = vec![0.0f32; 8];
        let mut dg = vec![0.0f32; 4];
        let mut db = vec![0.0f32; 4];
        layer_norm_bwd(lv(), &x, &g, &dy, rows, cols, &mut dx, &mut dg, &mut db);

        let loss = |x: &[f32], g: &[f32], b: &[f32]| -> f32 {
            let mut out = vec![0.0f32; 8];
            layer_norm(&pool, lv(), x, g, b, rows, cols, &mut out);
            out.iter().zip(&dy).map(|(o, d)| o * d).sum()
        };
        let eps = 1e-2f32;
        for i in 0..8 {
            let mut xp = x;
            xp[i] += eps;
            let mut xm = x;
            xm[i] -= eps;
            let fd = (loss(&xp, &g, &b) - loss(&xm, &g, &b)) / (2.0 * eps);
            assert!((fd - dx[i]).abs() < 1e-2, "dx[{i}]: fd {fd} vs {}", dx[i]);
        }
        for i in 0..4 {
            let mut gp = g;
            gp[i] += eps;
            let mut gm = g;
            gm[i] -= eps;
            let fd = (loss(&x, &gp, &b) - loss(&x, &gm, &b)) / (2.0 * eps);
            assert!((fd - dg[i]).abs() < 1e-2, "dg[{i}]: fd {fd} vs {}", dg[i]);
        }
    }

    #[test]
    fn gelu_grad_matches_finite_differences() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3f32;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((fd - gelu_grad(x)).abs() < 1e-3, "x={x}: {fd} vs {}", gelu_grad(x));
        }
    }

    #[test]
    fn softmax_xent_uniform_is_ln_n_and_grads_sum_to_zero() {
        let pool = serial();
        let logits = [0.0f32; 8]; // 2 rows x 4 classes
        let labels = [1i32, 3];
        let mut d = [0.0f32; 8];
        let (nll, ncorrect) = softmax_xent(&pool, lv(), &logits, &labels, 2, 4, &mut d);
        assert!(((nll / 2.0) - (4.0f64).ln()).abs() < 1e-6);
        assert_eq!(ncorrect, 0); // argmax is index 0 on ties
        for r in 0..2 {
            let s: f32 = d[r * 4..(r + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
        assert!((d[1] - (0.25 - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn add_bias_is_level_invariant() {
        let bias: Vec<f32> = (0..13).map(|j| 0.1 * j as f32 - 0.5).collect();
        let base: Vec<f32> = (0..3 * 13).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut want = base.clone();
        add_bias(simd::Level::Scalar, &mut want, &bias);
        for level in simd::Level::all_supported() {
            let mut got = base.clone();
            add_bias(level, &mut got, &bias);
            assert!(got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }
}
