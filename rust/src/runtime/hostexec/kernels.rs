//! Reference optimizer kernels — the host executor's `common/*` program
//! set, mirroring `python/compile/kernels/ref.py` exactly.
//!
//! The free functions are the scalar reference math (re-exported as
//! `optim::host_math` for the direct host-loop backend, comparator
//! optimizers and tests); the crate-internal `build` entry point wraps
//! them as chunked [`Program`]s
//! with the same positional signatures as the AOT artifacts, so the
//! kernel-dispatch path (`ChunkRunner`) is bit-for-bit identical to the
//! host-loop path.
//!
//! The program wrappers split each chunk into contiguous element spans
//! across the executor's thread pool and run each span through the
//! executor's [`crate::runtime::simd`] dispatch level; every kernel is
//! purely element-wise and the SIMD layer is bit-exact by contract, so
//! neither the split nor the lane width can change a single bit at any
//! thread count or `ADAMA_SIMD` setting (the serial free functions below
//! remain the oracles — `rust/tests/simd_parity.rs` sweeps the parity).

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::exec::{Arg, Program, Value};
use crate::runtime::manifest::Hyper;
use crate::runtime::pool::ThreadPool;
use crate::runtime::simd;

// ---------------------------------------------------------------------------
// scalar reference math (ref.py oracles)
// ---------------------------------------------------------------------------

/// AdamA inner-loop accumulation (Alg. 2): m += (1-β₁)·s·g, v += (1-β₂)·(s·g)².
pub fn adama_acc(m: &mut [f32], v: &mut [f32], g: &[f32], gscale: f32, b1: f32, b2: f32) {
    for i in 0..m.len() {
        let sg = g[i] * gscale;
        m[i] += (1.0 - b1) * sg;
        v[i] += (1.0 - b2) * sg * sg;
    }
}

/// Fused mini-batch-start decay + first micro-batch accumulation.
#[allow(clippy::too_many_arguments)]
pub fn adama_decay_acc(
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    gscale: f32,
    ms: f32,
    vs: f32,
    b1: f32,
    b2: f32,
) {
    for i in 0..m.len() {
        let sg = g[i] * gscale;
        m[i] = ms * m[i] + (1.0 - b1) * sg;
        v[i] = vs * v[i] + (1.0 - b2) * sg * sg;
    }
}

/// In-place scale (the mini-batch-start decay, Alg. 2 line 3).
pub fn scale(x: &mut [f32], s: f32) {
    for a in x.iter_mut() {
        *a *= s;
    }
}

/// Bias-corrected Adam parameter step shared by Adam and AdamA.
pub fn adam_update(p: &mut [f32], m: &[f32], v: &[f32], lr: f32, bc1: f32, bc2: f32, eps: f32) {
    for i in 0..p.len() {
        p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
    }
}

/// Baseline fused Adam step from a fully-accumulated gradient.
#[allow(clippy::too_many_arguments)]
pub fn adam_full(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
    b1: f32,
    b2: f32,
    eps: f32,
) {
    for i in 0..p.len() {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
    }
}

/// Gradient-accumulation baseline: acc += gscale · g.
pub fn grad_acc(acc: &mut [f32], g: &[f32], gscale: f32) {
    for i in 0..acc.len() {
        acc[i] += g[i] * gscale;
    }
}

// ---- §5 extensions ----

/// AdamW (decoupled weight decay) parameter step.
#[allow(clippy::too_many_arguments)]
pub fn adamw_update(
    p: &mut [f32],
    m: &[f32],
    v: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
    wd: f32,
    eps: f32,
) {
    for i in 0..p.len() {
        p[i] -= lr * ((m[i] / bc1) / ((v[i] / bc2).sqrt() + eps) + wd * p[i]);
    }
}

/// Momentum-SGD accumulation, first micro-batch (fused decay).
pub fn sgdm_decay_acc(u: &mut [f32], g: &[f32], gscale: f32, mu: f32) {
    for i in 0..u.len() {
        u[i] = mu * u[i] + gscale * g[i];
    }
}

pub fn sgdm_acc(u: &mut [f32], g: &[f32], gscale: f32) {
    for i in 0..u.len() {
        u[i] += gscale * g[i];
    }
}

pub fn sgdm_update(p: &mut [f32], u: &[f32], lr: f32, wd: f32) {
    for i in 0..p.len() {
        p[i] -= lr * (u[i] + wd * p[i]);
    }
}

// ---- optimizer zoo (ADAMA_OPT) ----

/// Adafactor parameter step from the factored second moment: one call per
/// matrix row (or vector), `c` the column (or full 1-D) moment slice and
/// `rfac` the row moment normalised by the mean row moment (`1.0` for
/// 1-D): p_j -= lr·g_j / (√(rfac·c_j) + eps).
pub fn fac_update(p: &mut [f32], g: &[f32], c: &[f32], lr: f32, rfac: f32, eps: f32) {
    for i in 0..p.len() {
        p[i] -= lr * g[i] / ((rfac * c[i]).sqrt() + eps);
    }
}

/// SM3-II cover reconstruction + parameter step: one call per matrix row
/// with `r` the row accumulator and `c` the column accumulator slice
/// (`r = +∞`, `c = v` degrades to full AdaGrad for 1-D):
/// nu_j = min(r, c_j) + g_j², p_j -= lr·g_j/(√nu_j + eps). The fresh
/// per-element bound `nu` is returned so the caller can fold the new
/// row/column maxima.
pub fn sm3_update(p: &mut [f32], nu: &mut [f32], g: &[f32], c: &[f32], lr: f32, r: f32, eps: f32) {
    for i in 0..p.len() {
        let b = r.min(c[i]) + g[i] * g[i];
        nu[i] = b;
        p[i] -= lr * g[i] / (b.sqrt() + eps);
    }
}

/// Adam-mini parameter step with a block-shared learning-rate scale
/// (`scale = lr/(√(v_block/bc2) + eps)`, computed per block by the
/// caller): p_i -= scale·(m_i/bc1).
pub fn mini_update(p: &mut [f32], m: &[f32], scale: f32, bc1: f32) {
    for i in 0..p.len() {
        p[i] -= scale * (m[i] / bc1);
    }
}

// ---------------------------------------------------------------------------
// Program wrappers (the `common/<op>_<chunk>` artifact signatures)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    AdamaAcc,
    AdamaDecayAcc,
    AdamaDecay,
    AdamUpdate,
    AdamFull,
    GradAcc,
    AdamaAccUpdate,
    AdamwUpdate,
    SgdmDecayAcc,
    SgdmAcc,
    SgdmUpdate,
    FacUpdate,
    Sm3Update,
    MiniUpdate,
}

struct Kernel {
    kind: Kind,
    b1: f32,
    b2: f32,
    eps: f32,
    pool: Arc<ThreadPool>,
    simd: simd::Level,
}

/// Resolve a `common/` short name (e.g. `"adama_decay_acc_16384"`) to its
/// host program. The trailing chunk size is parsed but not enforced — the
/// host kernels are shape-polymorphic over the buffer length.
pub(super) fn build(
    short: &str,
    hyper: &Hyper,
    pool: Arc<ThreadPool>,
    level: simd::Level,
) -> Result<Box<dyn Program>> {
    let (op, chunk) = short
        .rsplit_once('_')
        .and_then(|(op, c)| c.parse::<usize>().ok().map(|c| (op, c)))
        .with_context(|| format!("host executor: unparseable kernel name '{short}'"))?;
    ensure!(chunk > 0, "kernel '{short}': zero chunk");
    let kind = match op {
        "adama_acc" => Kind::AdamaAcc,
        "adama_decay_acc" => Kind::AdamaDecayAcc,
        "adama_decay" => Kind::AdamaDecay,
        "adam_update" => Kind::AdamUpdate,
        "adam_full" => Kind::AdamFull,
        "grad_acc" => Kind::GradAcc,
        "adama_acc_update" => Kind::AdamaAccUpdate,
        "adamw_update" => Kind::AdamwUpdate,
        "sgdm_decay_acc" => Kind::SgdmDecayAcc,
        "sgdm_acc" => Kind::SgdmAcc,
        "sgdm_update" => Kind::SgdmUpdate,
        "fac_update" => Kind::FacUpdate,
        "sm3_update" => Kind::Sm3Update,
        "mini_update" => Kind::MiniUpdate,
        other => bail!("host executor: unknown optimizer kernel '{other}'"),
    };
    Ok(Box::new(Kernel {
        kind,
        b1: hyper.beta1 as f32,
        b2: hyper.beta2 as f32,
        eps: hyper.eps as f32,
        pool,
        simd: level,
    }))
}

/// Pull `args[idx]` as an f32 buffer and check it against the first
/// buffer's length.
fn buf<'a>(args: &[Arg<'a>], idx: usize, len: usize) -> Result<&'a [f32]> {
    let a = args.get(idx).with_context(|| format!("kernel: missing argument #{idx}"))?;
    let d = a.f32()?;
    ensure!(d.len() == len, "kernel arg #{idx}: length {} != {}", d.len(), len);
    Ok(d)
}

/// Pull the trailing scalar-vector argument with an exact length.
fn scalars<'a>(args: &[Arg<'a>], idx: usize, n: usize) -> Result<&'a [f32]> {
    let a = args.get(idx).with_context(|| format!("kernel: missing scalars #{idx}"))?;
    let d = a.f32()?;
    ensure!(d.len() == n, "kernel scalars #{idx}: length {} != {}", d.len(), n);
    Ok(d)
}

fn out(data: Vec<f32>, shape: &[usize]) -> Value {
    Value::F32 { data, shape: shape.to_vec() }
}

impl Program for Kernel {
    fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Value>> {
        ensure!(!args.is_empty(), "kernel: no arguments");
        let n = args[0].len();
        let shape = args[0].shape();
        let (b1, b2, eps) = (self.b1, self.b2, self.eps);
        let pool = &self.pool;
        let lvl = self.simd;
        Ok(match self.kind {
            Kind::AdamaAcc => {
                let (mut m, mut v) = (buf(args, 0, n)?.to_vec(), buf(args, 1, n)?.to_vec());
                let g = buf(args, 2, n)?;
                let gscale = scalars(args, 3, 1)?[0];
                pool.for_spans2(&mut m, &mut v, |off, mm, vv| {
                    simd::adama_acc(lvl, mm, vv, &g[off..off + mm.len()], gscale, b1, b2);
                });
                vec![out(m, shape), out(v, shape)]
            }
            Kind::AdamaDecayAcc => {
                let (mut m, mut v) = (buf(args, 0, n)?.to_vec(), buf(args, 1, n)?.to_vec());
                let g = buf(args, 2, n)?;
                let sc = scalars(args, 3, 3)?; // [gscale, ms, vs]
                let (gscale, msc, vsc) = (sc[0], sc[1], sc[2]);
                pool.for_spans2(&mut m, &mut v, |off, mm, vv| {
                    simd::adama_decay_acc(
                        lvl,
                        mm,
                        vv,
                        &g[off..off + mm.len()],
                        gscale,
                        msc,
                        vsc,
                        b1,
                        b2,
                    );
                });
                vec![out(m, shape), out(v, shape)]
            }
            Kind::AdamaDecay => {
                let (mut m, mut v) = (buf(args, 0, n)?.to_vec(), buf(args, 1, n)?.to_vec());
                let ms = scalars(args, 2, 1)?[0];
                let vs = scalars(args, 3, 1)?[0];
                pool.for_spans2(&mut m, &mut v, |_, mm, vv| {
                    simd::scale(lvl, mm, ms);
                    simd::scale(lvl, vv, vs);
                });
                vec![out(m, shape), out(v, shape)]
            }
            Kind::AdamUpdate => {
                let mut p = buf(args, 0, n)?.to_vec();
                let m = buf(args, 1, n)?;
                let v = buf(args, 2, n)?;
                let sc = scalars(args, 3, 3)?; // [lr, bc1, bc2]
                let (lr, bc1, bc2) = (sc[0], sc[1], sc[2]);
                pool.for_spans(&mut p, |off, pp| {
                    let end = off + pp.len();
                    simd::adam_update(lvl, pp, &m[off..end], &v[off..end], lr, bc1, bc2, eps);
                });
                vec![out(p, shape)]
            }
            Kind::AdamFull => {
                let mut p = buf(args, 0, n)?.to_vec();
                let (mut m, mut v) = (buf(args, 1, n)?.to_vec(), buf(args, 2, n)?.to_vec());
                let g = buf(args, 3, n)?;
                let sc = scalars(args, 4, 3)?;
                let (lr, bc1, bc2) = (sc[0], sc[1], sc[2]);
                pool.for_spans3(&mut p, &mut m, &mut v, |off, pp, mm, vv| {
                    simd::adam_full(
                        lvl,
                        pp,
                        mm,
                        vv,
                        &g[off..off + pp.len()],
                        lr,
                        bc1,
                        bc2,
                        b1,
                        b2,
                        eps,
                    );
                });
                vec![out(p, shape), out(m, shape), out(v, shape)]
            }
            Kind::GradAcc => {
                let mut acc = buf(args, 0, n)?.to_vec();
                let g = buf(args, 1, n)?;
                let gscale = scalars(args, 2, 1)?[0];
                pool.for_spans(&mut acc, |off, aa| {
                    simd::grad_acc(lvl, aa, &g[off..off + aa.len()], gscale);
                });
                vec![out(acc, shape)]
            }
            Kind::AdamaAccUpdate => {
                let mut p = buf(args, 0, n)?.to_vec();
                let (mut m, mut v) = (buf(args, 1, n)?.to_vec(), buf(args, 2, n)?.to_vec());
                let g = buf(args, 3, n)?;
                let gscale = scalars(args, 4, 1)?[0];
                let sc = scalars(args, 5, 3)?;
                let (lr, bc1, bc2) = (sc[0], sc[1], sc[2]);
                pool.for_spans3(&mut p, &mut m, &mut v, |off, pp, mm, vv| {
                    simd::adama_acc(lvl, mm, vv, &g[off..off + pp.len()], gscale, b1, b2);
                    simd::adam_update(lvl, pp, mm, vv, lr, bc1, bc2, eps);
                });
                vec![out(p, shape), out(m, shape), out(v, shape)]
            }
            Kind::AdamwUpdate => {
                let mut p = buf(args, 0, n)?.to_vec();
                let m = buf(args, 1, n)?;
                let v = buf(args, 2, n)?;
                let sc = scalars(args, 3, 4)?; // [lr, bc1, bc2, wd]
                let (lr, bc1, bc2, wd) = (sc[0], sc[1], sc[2], sc[3]);
                pool.for_spans(&mut p, |off, pp| {
                    let end = off + pp.len();
                    simd::adamw_update(lvl, pp, &m[off..end], &v[off..end], lr, bc1, bc2, wd, eps);
                });
                vec![out(p, shape)]
            }
            Kind::SgdmDecayAcc => {
                let mut u = buf(args, 0, n)?.to_vec();
                let g = buf(args, 1, n)?;
                let sc = scalars(args, 2, 2)?; // [gscale, mu]
                let (gscale, mu) = (sc[0], sc[1]);
                pool.for_spans(&mut u, |off, uu| {
                    simd::sgdm_decay_acc(lvl, uu, &g[off..off + uu.len()], gscale, mu);
                });
                vec![out(u, shape)]
            }
            Kind::SgdmAcc => {
                let mut u = buf(args, 0, n)?.to_vec();
                let g = buf(args, 1, n)?;
                let gscale = scalars(args, 2, 1)?[0];
                pool.for_spans(&mut u, |off, uu| {
                    simd::sgdm_acc(lvl, uu, &g[off..off + uu.len()], gscale);
                });
                vec![out(u, shape)]
            }
            Kind::SgdmUpdate => {
                let mut p = buf(args, 0, n)?.to_vec();
                let u = buf(args, 1, n)?;
                let sc = scalars(args, 2, 2)?; // [lr, wd]
                let (lr, wd) = (sc[0], sc[1]);
                pool.for_spans(&mut p, |off, pp| {
                    simd::sgdm_update(lvl, pp, &u[off..off + pp.len()], lr, wd);
                });
                vec![out(p, shape)]
            }
            Kind::FacUpdate => {
                let mut p = buf(args, 0, n)?.to_vec();
                let g = buf(args, 1, n)?;
                let c = buf(args, 2, n)?;
                let sc = scalars(args, 3, 2)?; // [lr, rfac]
                let (lr, rfac) = (sc[0], sc[1]);
                pool.for_spans(&mut p, |off, pp| {
                    let end = off + pp.len();
                    simd::fac_update(lvl, pp, &g[off..end], &c[off..end], lr, rfac, eps);
                });
                vec![out(p, shape)]
            }
            Kind::Sm3Update => {
                // min() has no Lanes primitive, so this kernel is scalar
                // inside each span — still pool-parallel and trivially
                // bit-exact at any thread count (pure element-wise)
                let mut p = buf(args, 0, n)?.to_vec();
                let g = buf(args, 1, n)?;
                let c = buf(args, 2, n)?;
                let sc = scalars(args, 3, 2)?; // [lr, r]
                let (lr, r) = (sc[0], sc[1]);
                let mut nu = vec![0.0f32; n];
                pool.for_spans2(&mut p, &mut nu, |off, pp, nn| {
                    let end = off + pp.len();
                    sm3_update(pp, nn, &g[off..end], &c[off..end], lr, r, eps);
                });
                vec![out(p, shape), out(nu, shape)]
            }
            Kind::MiniUpdate => {
                let mut p = buf(args, 0, n)?.to_vec();
                let m = buf(args, 1, n)?;
                let sc = scalars(args, 2, 2)?; // [scale, bc1]
                let (scale, bc1) = (sc[0], sc[1]);
                pool.for_spans(&mut p, |off, pp| {
                    simd::mini_update(lvl, pp, &m[off..off + pp.len()], scale, bc1);
                });
                vec![out(p, shape)]
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Hyper;

    fn hyper() -> Hyper {
        Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    fn tp(threads: usize) -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(threads))
    }

    /// Build at the detected SIMD level, so these unit tests exercise the
    /// vector path wherever the test host supports one.
    fn lvl() -> simd::Level {
        simd::detect()
    }

    #[test]
    fn kernel_name_parsing() {
        assert!(build("adama_acc_16384", &hyper(), tp(1), lvl()).is_ok());
        assert!(build("adama_decay_acc_1048576", &hyper(), tp(1), lvl()).is_ok());
        assert!(build("sgdm_update_16384", &hyper(), tp(1), lvl()).is_ok());
        assert!(build("nonsense_16384", &hyper(), tp(1), lvl()).is_err());
        assert!(build("adama_acc", &hyper(), tp(1), lvl()).is_err());
    }

    #[test]
    fn program_matches_scalar_math_bitwise() {
        let prog = build("adama_acc_8", &hyper(), tp(2), lvl()).unwrap();
        let m = vec![0.5f32, -1.0, 2.0, 0.0];
        let v = vec![0.1f32, 0.2, 0.0, 3.0];
        let g = vec![1.0f32, -2.0, 0.25, 4.0];
        let outv = prog
            .run(&[
                Arg::F32(&m, &[4]),
                Arg::F32(&v, &[4]),
                Arg::F32(&g, &[4]),
                Arg::F32(&[0.5], &[1]),
            ])
            .unwrap();
        let (mut m2, mut v2) = (m.clone(), v.clone());
        adama_acc(&mut m2, &mut v2, &g, 0.5, 0.9, 0.999);
        assert_eq!(outv[0].as_f32().unwrap(), &m2[..]);
        assert_eq!(outv[1].as_f32().unwrap(), &v2[..]);
    }

    #[test]
    fn parallel_program_matches_scalar_math_bitwise_on_big_chunks() {
        // 5000 elements clears the pool's serial cutoff: the span split is
        // live, and must not change a single bit vs the serial oracle.
        let n = 5000usize;
        let m: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let v: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos().abs()).collect();
        let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.73).sin() * 2.0).collect();
        let p: Vec<f32> = (0..n).map(|i| (i as f32 * 0.19).cos()).collect();
        for threads in [1usize, 4] {
            let acc = build("adama_acc_16384", &hyper(), tp(threads), lvl()).unwrap();
            let got = acc
                .run(&[
                    Arg::F32(&m, &[n]),
                    Arg::F32(&v, &[n]),
                    Arg::F32(&g, &[n]),
                    Arg::F32(&[0.25], &[1]),
                ])
                .unwrap();
            let (mut m2, mut v2) = (m.clone(), v.clone());
            adama_acc(&mut m2, &mut v2, &g, 0.25, 0.9, 0.999);
            assert_eq!(got[0].as_f32().unwrap(), &m2[..], "{threads} threads: m");
            assert_eq!(got[1].as_f32().unwrap(), &v2[..], "{threads} threads: v");

            let upd = build("adam_update_16384", &hyper(), tp(threads), lvl()).unwrap();
            let got = upd
                .run(&[
                    Arg::F32(&p, &[n]),
                    Arg::F32(&m2, &[n]),
                    Arg::F32(&v2, &[n]),
                    Arg::F32(&[1e-3, 0.1, 0.001], &[3]),
                ])
                .unwrap();
            let mut p2 = p.clone();
            adam_update(&mut p2, &m2, &v2, 1e-3, 0.1, 0.001, 1e-8);
            assert_eq!(got[0].as_f32().unwrap(), &p2[..], "{threads} threads: p");
        }
    }

    #[test]
    fn host_adama_acc_math() {
        let mut m = vec![0.0, 1.0];
        let mut v = vec![0.0, 2.0];
        adama_acc(&mut m, &mut v, &[4.0, -4.0], 0.5, 0.9, 0.999);
        assert!((m[0] - 0.2).abs() < 1e-6);
        assert!((m[1] - 0.8).abs() < 1e-6);
        assert!((v[0] - 0.004).abs() < 1e-6);
        assert!((v[1] - 2.004).abs() < 1e-6);
    }

    #[test]
    fn host_full_step_equals_acc_plus_update_when_n1() {
        // AdamA(N=1) == Adam: decay + single accumulate + update must equal
        // the fused full step.
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let g = vec![0.3, -0.7, 2.0];
        let mut p1 = vec![1.0, 2.0, 3.0];
        let mut m1 = vec![0.05, -0.02, 0.0];
        let mut v1 = vec![0.01, 0.02, 0.0];
        let (mut p2, mut m2, mut v2) = (p1.clone(), m1.clone(), v1.clone());
        let (lr, bc1, bc2) = (0.01, 0.1, 0.001);

        adam_full(&mut p1, &mut m1, &mut v1, &g, lr, bc1, bc2, b1, b2, eps);

        scale(&mut m2, b1);
        scale(&mut v2, b2);
        adama_acc(&mut m2, &mut v2, &g, 1.0, b1, b2);
        adam_update(&mut p2, &m2, &v2, lr, bc1, bc2, eps);

        for i in 0..3 {
            assert!((p1[i] - p2[i]).abs() < 1e-6);
            assert!((m1[i] - m2[i]).abs() < 1e-6);
            assert!((v1[i] - v2[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn zoo_programs_match_scalar_math_bitwise() {
        let n = 5003usize;
        let p: Vec<f32> = (0..n).map(|i| (i as f32 * 0.19).cos()).collect();
        let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.73).sin() * 2.0).collect();
        let c: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos().abs()).collect();
        let m: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        for threads in [1usize, 4] {
            let fac = build("fac_update_16384", &hyper(), tp(threads), lvl()).unwrap();
            let got = fac
                .run(&[
                    Arg::F32(&p, &[n]),
                    Arg::F32(&g, &[n]),
                    Arg::F32(&c, &[n]),
                    Arg::F32(&[1e-2, 1.25], &[2]),
                ])
                .unwrap();
            let mut p2 = p.clone();
            fac_update(&mut p2, &g, &c, 1e-2, 1.25, 1e-8);
            assert_eq!(got[0].as_f32().unwrap(), &p2[..], "{threads} threads: fac p");

            let sm3 = build("sm3_update_16384", &hyper(), tp(threads), lvl()).unwrap();
            let got = sm3
                .run(&[
                    Arg::F32(&p, &[n]),
                    Arg::F32(&g, &[n]),
                    Arg::F32(&c, &[n]),
                    Arg::F32(&[1e-2, 0.5], &[2]),
                ])
                .unwrap();
            let (mut p2, mut nu2) = (p.clone(), vec![0.0f32; n]);
            sm3_update(&mut p2, &mut nu2, &g, &c, 1e-2, 0.5, 1e-8);
            assert_eq!(got[0].as_f32().unwrap(), &p2[..], "{threads} threads: sm3 p");
            assert_eq!(got[1].as_f32().unwrap(), &nu2[..], "{threads} threads: sm3 nu");

            let mini = build("mini_update_16384", &hyper(), tp(threads), lvl()).unwrap();
            let got = mini
                .run(&[
                    Arg::F32(&p, &[n]),
                    Arg::F32(&m, &[n]),
                    Arg::F32(&[3e-3, 0.1], &[2]),
                ])
                .unwrap();
            let mut p2 = p.clone();
            mini_update(&mut p2, &m, 3e-3, 0.1);
            assert_eq!(got[0].as_f32().unwrap(), &p2[..], "{threads} threads: mini p");
        }
    }

    #[test]
    fn zoo_kernels_leave_zero_padding_at_zero() {
        // chunk_value stages short rows into zero-padded chunk buffers; the
        // padded tail must stay exactly 0 so the copy-back can't corrupt
        // anything even if sliced generously.
        let (mut p, mut nu) = (vec![0.0f32; 4], vec![0.0f32; 4]);
        fac_update(&mut p, &[0.0; 4], &[0.0; 4], 1e-2, 1.25, 1e-8);
        assert_eq!(p, vec![0.0; 4]);
        sm3_update(&mut p, &mut nu, &[0.0; 4], &[0.0; 4], 1e-2, 0.5, 1e-8);
        assert_eq!(p, vec![0.0; 4]);
        assert_eq!(nu, vec![0.0; 4]);
        // 1-D SM3 passes r = +inf with a zero accumulator tail: min(inf, 0) = 0.
        sm3_update(&mut p, &mut nu, &[0.0; 4], &[0.0; 4], 1e-2, f32::INFINITY, 1e-8);
        assert_eq!(p, vec![0.0; 4]);
        assert_eq!(nu, vec![0.0; 4]);
        mini_update(&mut p, &[0.0; 4], 3e-3, 0.1);
        assert_eq!(p, vec![0.0; 4]);
    }
}
