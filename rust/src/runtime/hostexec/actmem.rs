//! Activation memory manager for the host executor: budget-gated
//! stash-vs-recompute for the per-layer transformer backward.
//!
//! The artifact contract (and the AdamA paper's activation story) is
//! *per-layer rematerialisation*: `block_bwd` recomputes its forward
//! internally, so only the block **inputs** survive between forward and
//! backward. That minimises activation memory but doubles the forward
//! FLOPs of every backward sweep. This module adds the other end of the
//! trade-off: `block_fwd` may **stash** its full intermediate state
//! (attention scores/softmax, head outputs, MLP hidden) into a tracked
//! [`ActivationArena`], and `block_bwd` consumes the stash when present —
//! skipping the recompute — falling back to remat otherwise.
//!
//! ## Budget semantics ([`MemoryPlan`] / `ADAMA_ACT_BUDGET`)
//!
//! The arena is gated by a byte budget:
//!
//! * [`ActBudget::Remat`] (`ADAMA_ACT_BUDGET` unset, empty, or `0`) —
//!   never stash; bitwise-identical to the pre-existing remat path. This
//!   is the default so that the artifact contract stays the baseline.
//! * [`ActBudget::Bytes`] (`ADAMA_ACT_BUDGET=<n>`, with optional
//!   `k`/`m`/`g` suffix) — stash while the arena's live bytes fit; when a
//!   new entry would overflow, the **oldest** entries are evicted first
//!   (they are the least likely to be consumed next: backward walks
//!   layers in reverse, so the newest stash is needed first). Because
//!   every block of a config stashes the same number of bytes, greedy
//!   admission maximises the number of recomputes avoided under the
//!   budget.
//! * [`ActBudget::Unlimited`] (`ADAMA_ACT_BUDGET=unlimited`) — stash
//!   every block; backward never recomputes.
//!
//! ## Correctness & the determinism contract
//!
//! A stash entry is keyed by an FNV-1a hash over the block input `x` and
//! all 12 parameter tensors (bit patterns), and additionally stores a
//! verbatim copy of `x` that is compared bit-for-bit on lookup. A hit
//! therefore guarantees the stashed state is exactly what recompute would
//! produce (the host executor is bit-deterministic at any thread count),
//! so **stashed and rematerialised backward are bit-identical** —
//! `rust/tests/actstash.rs` locks this down at 1 and 4 threads. A miss
//! (evicted entry, changed parameters, forward-only callers such as eval)
//! silently falls back to remat; it can never produce wrong gradients,
//! only a slower correct one.
//!
//! Forward-only callers (eval loops) push entries that no backward ever
//! consumes. The coordinator releases them eagerly
//! (`Executor::clear_stash` after each eval micro-batch); for other
//! forward-only users, budgeted arenas recycle leftovers through
//! oldest-first eviction and unlimited arenas are bounded by
//! [`MAX_ENTRIES`] as a backstop.
//!
//! ## Accounting
//!
//! The arena tracks live/peak stashed bytes plus stash/hit/evict/remat
//! counters, and a [`WsMeter`] tracks the transient workspace the
//! transformer/MLP programs allocate per call. Both surface through
//! [`crate::runtime::Executor::memory`] as a backend-neutral
//! [`MemStats`], and `crate::memmodel::HostBlockDims` predicts the same
//! numbers analytically — the measured-vs-predicted gap is a tested
//! invariant (`rust/tests/actstash.rs`).

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::runtime::exec::MemStats;

/// Backstop on arena entry count so forward-only callers (eval) cannot
/// grow an [`ActBudget::Unlimited`] arena without bound.
pub const MAX_ENTRIES: usize = 512;

/// Activation byte budget for the stash arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActBudget {
    /// Never stash: pure per-layer remat (the artifact contract).
    Remat,
    /// Stash while live bytes fit; evict oldest-first on overflow.
    Bytes(u64),
    /// Stash every block; backward never recomputes.
    Unlimited,
}

/// Per-executor activation policy — the API twin of `ADAMA_ACT_BUDGET`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryPlan {
    pub budget: ActBudget,
}

impl Default for MemoryPlan {
    fn default() -> Self {
        Self::remat()
    }
}

impl MemoryPlan {
    /// Pure remat (budget 0) — the default, matching the artifact contract.
    pub fn remat() -> Self {
        Self { budget: ActBudget::Remat }
    }

    /// Stash everything (no byte cap).
    pub fn unlimited() -> Self {
        Self { budget: ActBudget::Unlimited }
    }

    /// Stash under an explicit byte cap (0 collapses to [`Self::remat`]).
    pub fn bytes(n: u64) -> Self {
        if n == 0 {
            Self::remat()
        } else {
            Self { budget: ActBudget::Bytes(n) }
        }
    }

    /// Strictly parse an `ADAMA_ACT_BUDGET` value: unset/empty/`0` →
    /// remat, `unlimited|inf|max` → unlimited, a number with an optional
    /// `k`/`m`/`g` (×1024) suffix → byte cap. Anything else is an error
    /// naming the accepted values (no silent fallback).
    pub fn parse(spec: Option<&str>) -> Result<Self> {
        Self::parse_named(spec, "ADAMA_ACT_BUDGET")
    }

    /// [`Self::parse`] with the env-var name spelled out in the error —
    /// the same budget grammar backs `ADAMA_KV_BUDGET` (serving KV
    /// caches), whose errors must name *their* knob.
    pub fn parse_named(spec: Option<&str>, var: &str) -> Result<Self> {
        let s = match spec.map(str::trim) {
            Some(s) if !s.is_empty() => s.to_ascii_lowercase(),
            _ => return Ok(Self::remat()),
        };
        if matches!(s.as_str(), "unlimited" | "inf" | "max") {
            return Ok(Self::unlimited());
        }
        let (digits, mult): (&str, u64) = match s.as_bytes().last() {
            Some(b'k') => (&s[..s.len() - 1], 1 << 10),
            Some(b'm') => (&s[..s.len() - 1], 1 << 20),
            Some(b'g') => (&s[..s.len() - 1], 1 << 30),
            _ => (s.as_str(), 1),
        };
        match digits.trim().parse::<u64>() {
            Ok(n) => Ok(Self::bytes(n.saturating_mul(mult))),
            Err(_) => bail!(
                "invalid {var} '{s}': expected 0/unset, <n>[k|m|g], \
                 or unlimited|inf|max"
            ),
        }
    }

    /// Plan from the `ADAMA_ACT_BUDGET` environment variable.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::var("ADAMA_ACT_BUDGET").ok().as_deref())
    }

    /// Inverse of the `MemStats::stash_budget_bytes` encoding produced
    /// by [`ActivationArena::stats`] (`Some(0)` = remat, `Some(n)` =
    /// byte cap, `None` = unlimited) — both directions live in this file
    /// so they cannot drift apart. `Library::fork_with_threads` uses
    /// this to carry a running executor's plan into per-rank forks.
    pub fn from_budget_bytes(budget: Option<u64>) -> Self {
        match budget {
            Some(0) => Self::remat(),
            Some(n) => Self::bytes(n),
            None => Self::unlimited(),
        }
    }

    /// How many uniform `entry_bytes`-sized blocks fit under this budget
    /// (capped at `blocks`) — the arena's steady-state stash depth, and
    /// what `crate::memmodel` uses for the analytic prediction. Every
    /// stashed block saves one full block-forward recompute, so for
    /// uniform entries greedy admission is the optimal plan.
    pub fn stashable_blocks(&self, entry_bytes: u64, blocks: u64) -> u64 {
        match self.budget {
            ActBudget::Remat => 0,
            ActBudget::Unlimited => blocks,
            ActBudget::Bytes(cap) => {
                if entry_bytes == 0 {
                    blocks
                } else {
                    (cap / entry_bytes).min(blocks)
                }
            }
        }
    }
}

/// FNV-1a over raw bytes — the stash key hash (serial, thread-count
/// independent by construction).
pub(crate) struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Self {
        Self(0xcbf29ce484222325)
    }

    pub fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }

    pub fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for x in xs {
            self.0 = (self.0 ^ x.to_bits() as u64).wrapping_mul(0x100000001b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

struct Entry {
    key: u64,
    /// Verbatim copy of the block input: hits are verified bit-for-bit,
    /// so a hash collision can never corrupt gradients.
    x: Vec<f32>,
    bytes: u64,
    payload: Box<dyn Any + Send>,
}

#[derive(Default)]
struct ArenaCounters {
    stashed: AtomicU64,
    hits: AtomicU64,
    evictions: AtomicU64,
    remats: AtomicU64,
}

/// Tracked stash arena shared by every `block_fwd`/`block_bwd` program of
/// a [`crate::runtime::hostexec::HostExecutor`]. See the module docs for
/// budget and correctness semantics.
pub struct ActivationArena {
    plan: MemoryPlan,
    entries: Mutex<VecDeque<Entry>>,
    live: AtomicI64,
    peak: AtomicI64,
    counters: ArenaCounters,
    ws: WsMeter,
    kv_live: AtomicI64,
    kv_peak: AtomicI64,
}

impl ActivationArena {
    pub fn new(plan: MemoryPlan) -> Self {
        Self {
            plan,
            entries: Mutex::new(VecDeque::new()),
            live: AtomicI64::new(0),
            peak: AtomicI64::new(0),
            counters: ArenaCounters::default(),
            ws: WsMeter::default(),
            kv_live: AtomicI64::new(0),
            kv_peak: AtomicI64::new(0),
        }
    }

    pub fn plan(&self) -> MemoryPlan {
        self.plan
    }

    /// Fast gate for callers: `false` means "never stash" — skip the key
    /// hash entirely (the remat default must cost nothing extra).
    pub fn enabled(&self) -> bool {
        self.plan.budget != ActBudget::Remat
    }

    /// Workspace meter for transient per-call buffers.
    pub fn ws(&self) -> &WsMeter {
        &self.ws
    }

    /// Register `bytes` of serving KV-cache memory (a per-sequence
    /// key/value buffer grew). `serve::KvCache` calls this at every
    /// append so measured `MemStats::kv_live_bytes` reconciles exactly
    /// against `memmodel::HostBlockDims::kv_cache_bytes`.
    pub fn kv_alloc(&self, bytes: u64) {
        let now = self.kv_live.fetch_add(bytes as i64, Ordering::SeqCst) + bytes as i64;
        self.kv_peak.fetch_max(now, Ordering::SeqCst);
    }

    /// Release `bytes` of serving KV-cache memory (a sequence retired or
    /// was evicted under the `ADAMA_KV_BUDGET` cap).
    pub fn kv_free(&self, bytes: u64) {
        let now = self.kv_live.fetch_sub(bytes as i64, Ordering::SeqCst) - bytes as i64;
        debug_assert!(now >= 0, "kv live bytes went negative");
    }

    /// KV-cache bytes currently registered.
    pub fn kv_live(&self) -> u64 {
        self.kv_live.load(Ordering::SeqCst).max(0) as u64
    }

    /// High-water mark of [`Self::kv_live`].
    pub fn kv_peak(&self) -> u64 {
        self.kv_peak.load(Ordering::SeqCst).max(0) as u64
    }

    fn add_live(&self, delta: i64) {
        let now = self.live.fetch_add(delta, Ordering::SeqCst) + delta;
        debug_assert!(now >= 0, "arena live bytes went negative");
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    /// Try to admit a stash entry under the budget; evicts oldest entries
    /// as needed. Returns whether the entry was stored (callers drop the
    /// payload otherwise — remat will cover it).
    pub fn try_stash(
        &self,
        key: u64,
        x: &[f32],
        payload_bytes: u64,
        payload: Box<dyn Any + Send>,
    ) -> bool {
        let bytes = payload_bytes + (x.len() * 4) as u64;
        let cap = match self.plan.budget {
            ActBudget::Remat => return false,
            ActBudget::Bytes(cap) if bytes > cap => return false,
            ActBudget::Bytes(cap) => Some(cap),
            ActBudget::Unlimited => None,
        };
        let mut q = self.entries.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut live = self.live.load(Ordering::SeqCst).max(0) as u64;
        while q.len() >= MAX_ENTRIES || cap.is_some_and(|c| live + bytes > c) {
            match q.pop_front() {
                Some(old) => {
                    live = live.saturating_sub(old.bytes);
                    self.add_live(-(old.bytes as i64));
                    self.counters.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
        q.push_back(Entry { key, x: x.to_vec(), bytes, payload });
        self.add_live(bytes as i64);
        self.counters.stashed.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Record a backward that rematerialised without consulting the
    /// stash (the zero-overhead remat default skips key hashing).
    pub fn note_remat(&self) {
        self.counters.remats.fetch_add(1, Ordering::Relaxed);
    }

    /// Consume the newest entry matching `(key, x)`; `x` is compared
    /// bit-for-bit. `None` means the caller must rematerialise (recorded
    /// in the remat counter).
    pub fn take(&self, key: u64, x: &[f32]) -> Option<Box<dyn Any + Send>> {
        if self.enabled() {
            let mut q =
                self.entries.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            // newest-first: backward walks layers in reverse order
            if let Some(i) = q.iter().rposition(|e| {
                e.key == key
                    && e.x.len() == x.len()
                    && e.x.iter().zip(x).all(|(a, b)| a.to_bits() == b.to_bits())
            }) {
                let e = q.remove(i).expect("rposition returned a valid index");
                self.add_live(-(e.bytes as i64));
                drop(q);
                self.counters.hits.fetch_add(1, Ordering::Relaxed);
                return Some(e.payload);
            }
        }
        self.counters.remats.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Drop every stashed entry (peaks and counters are kept). Useful
    /// for long eval-only phases under an unlimited budget, where
    /// forward-only entries would otherwise sit until [`MAX_ENTRIES`]
    /// recycling kicks in.
    pub fn clear(&self) {
        let mut q = self.entries.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let freed: u64 = q.iter().map(|e| e.bytes).sum();
        q.clear();
        if freed > 0 {
            // under the lock, like every other live-counter mutation, so
            // concurrent admission decisions never see stale live bytes
            self.add_live(-(freed as i64));
        }
    }

    /// Backend-neutral snapshot for [`crate::runtime::Executor::memory`].
    pub fn stats(&self) -> MemStats {
        MemStats {
            stash_budget_bytes: match self.plan.budget {
                ActBudget::Remat => Some(0),
                ActBudget::Bytes(n) => Some(n),
                ActBudget::Unlimited => None,
            },
            stash_live_bytes: self.live.load(Ordering::SeqCst).max(0) as u64,
            stash_peak_bytes: self.peak.load(Ordering::SeqCst).max(0) as u64,
            workspace_live_bytes: self.ws.live(),
            workspace_peak_bytes: self.ws.peak(),
            stashed: self.counters.stashed.load(Ordering::Relaxed),
            stash_hits: self.counters.hits.load(Ordering::Relaxed),
            stash_evictions: self.counters.evictions.load(Ordering::Relaxed),
            remats: self.counters.remats.load(Ordering::Relaxed),
            kv_live_bytes: self.kv_live(),
            kv_peak_bytes: self.kv_peak(),
        }
    }
}

/// Live/peak meter for transient per-call workspace buffers, the second
/// half of the host executor's activation accounting (the arena tracks
/// what *survives* a call; this tracks what a call allocates and frees).
#[derive(Default)]
pub struct WsMeter {
    live: AtomicI64,
    peak: AtomicI64,
}

impl WsMeter {
    /// Open a per-call scope; buffers registered with [`WsScope::add`]
    /// count as live until the scope drops (call exit).
    pub fn scope(&self) -> WsScope<'_> {
        WsScope { meter: self, bytes: 0 }
    }

    pub fn live(&self) -> u64 {
        self.live.load(Ordering::SeqCst).max(0) as u64
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::SeqCst).max(0) as u64
    }
}

/// RAII accounting scope for one program call's workspace.
pub struct WsScope<'a> {
    meter: &'a WsMeter,
    bytes: i64,
}

impl WsScope<'_> {
    /// Register `elems` f32 elements of freshly allocated workspace.
    pub fn add(&mut self, elems: usize) {
        self.add_bytes((elems * 4) as u64);
    }

    /// Register workspace by byte count (e.g. a consumed stash payload,
    /// which stays physically live until the backward finishes).
    pub fn add_bytes(&mut self, bytes: u64) {
        let bytes = bytes as i64;
        self.bytes += bytes;
        let now = self.meter.live.fetch_add(bytes, Ordering::SeqCst) + bytes;
        self.meter.peak.fetch_max(now, Ordering::SeqCst);
    }
}

impl Drop for WsScope<'_> {
    fn drop(&mut self) {
        self.meter.live.fetch_sub(self.bytes, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parsing() {
        assert_eq!(MemoryPlan::parse(None).unwrap(), MemoryPlan::remat());
        assert_eq!(MemoryPlan::parse(Some("")).unwrap(), MemoryPlan::remat());
        assert_eq!(MemoryPlan::parse(Some("0")).unwrap(), MemoryPlan::remat());
        assert_eq!(MemoryPlan::parse(Some("unlimited")).unwrap(), MemoryPlan::unlimited());
        assert_eq!(MemoryPlan::parse(Some("INF")).unwrap(), MemoryPlan::unlimited());
        assert_eq!(MemoryPlan::parse(Some("4096")).unwrap(), MemoryPlan::bytes(4096));
        assert_eq!(MemoryPlan::parse(Some("64k")).unwrap(), MemoryPlan::bytes(64 << 10));
        assert_eq!(MemoryPlan::parse(Some("2M")).unwrap(), MemoryPlan::bytes(2 << 20));
        assert_eq!(MemoryPlan::parse(Some("1g")).unwrap(), MemoryPlan::bytes(1 << 30));
        // invalid specs are clear errors naming the accepted values
        for bad in ["garbage", "-3", "12q", "k"] {
            let err = MemoryPlan::parse(Some(bad)).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains("ADAMA_ACT_BUDGET") && msg.contains("unlimited"), "{bad}: {msg}");
        }
        // the named variant reports the caller's knob (ADAMA_KV_BUDGET)
        let err = MemoryPlan::parse_named(Some("nope"), "ADAMA_KV_BUDGET").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("ADAMA_KV_BUDGET") && msg.contains("unlimited"), "{msg}");
        assert_eq!(
            MemoryPlan::parse_named(Some("8k"), "ADAMA_KV_BUDGET").unwrap(),
            MemoryPlan::bytes(8 << 10)
        );
    }

    #[test]
    fn budget_bytes_encoding_roundtrips_through_stats() {
        for plan in [MemoryPlan::remat(), MemoryPlan::bytes(123), MemoryPlan::unlimited()] {
            let a = ActivationArena::new(plan);
            assert_eq!(MemoryPlan::from_budget_bytes(a.stats().stash_budget_bytes), plan);
        }
    }

    #[test]
    fn stashable_blocks_under_budgets() {
        assert_eq!(MemoryPlan::remat().stashable_blocks(100, 4), 0);
        assert_eq!(MemoryPlan::unlimited().stashable_blocks(100, 4), 4);
        assert_eq!(MemoryPlan::bytes(250).stashable_blocks(100, 4), 2);
        assert_eq!(MemoryPlan::bytes(1000).stashable_blocks(100, 4), 4);
        assert_eq!(MemoryPlan::bytes(99).stashable_blocks(100, 4), 0);
    }

    #[test]
    fn arena_stash_take_roundtrip_and_accounting() {
        let a = ActivationArena::new(MemoryPlan::unlimited());
        let x = vec![1.0f32, 2.0, 3.0];
        assert!(a.try_stash(7, &x, 100, Box::new(42usize)));
        let s = a.stats();
        assert_eq!(s.stash_live_bytes, 100 + 12);
        assert_eq!(s.stashed, 1);

        // wrong key, then wrong x bits: both miss (and count as remats)
        assert!(a.take(8, &x).is_none());
        let x2 = vec![1.0f32, 2.0, 4.0];
        assert!(a.take(7, &x2).is_none());
        // exact match consumes
        let got = a.take(7, &x).expect("hit");
        assert_eq!(*got.downcast::<usize>().unwrap(), 42);
        let s = a.stats();
        assert_eq!(s.stash_live_bytes, 0);
        assert_eq!(s.stash_peak_bytes, 112);
        assert_eq!(s.stash_hits, 1);
        assert_eq!(s.remats, 2);
    }

    #[test]
    fn budget_evicts_oldest_first() {
        // budget fits two 112-byte entries, not three
        let a = ActivationArena::new(MemoryPlan::bytes(250));
        let xs: Vec<Vec<f32>> = (0..3).map(|i| vec![i as f32; 3]).collect();
        for (i, x) in xs.iter().enumerate() {
            assert!(a.try_stash(i as u64, x, 100, Box::new(i)));
        }
        let s = a.stats();
        assert_eq!(s.stash_evictions, 1);
        assert_eq!(s.stash_live_bytes, 224);
        // entry 0 was evicted; 1 and 2 remain
        assert!(a.take(0, &xs[0]).is_none());
        assert!(a.take(2, &xs[2]).is_some());
        assert!(a.take(1, &xs[1]).is_some());
    }

    #[test]
    fn clear_frees_everything_but_keeps_peaks() {
        let a = ActivationArena::new(MemoryPlan::unlimited());
        assert!(a.try_stash(1, &[1.0], 100, Box::new(())));
        assert!(a.try_stash(2, &[2.0], 100, Box::new(())));
        a.clear();
        let s = a.stats();
        assert_eq!(s.stash_live_bytes, 0);
        assert_eq!(s.stash_peak_bytes, 208);
        assert!(a.take(1, &[1.0]).is_none(), "cleared entries are gone");
    }

    #[test]
    fn remat_plan_never_stashes() {
        let a = ActivationArena::new(MemoryPlan::remat());
        assert!(!a.enabled());
        assert!(!a.try_stash(1, &[1.0], 100, Box::new(())));
        assert_eq!(a.stats().stash_peak_bytes, 0);
    }

    #[test]
    fn oversized_entry_is_rejected_not_thrashed() {
        let a = ActivationArena::new(MemoryPlan::bytes(50));
        assert!(!a.try_stash(1, &[1.0], 100, Box::new(())));
        assert_eq!(a.stats().stash_evictions, 0);
    }

    #[test]
    fn ws_meter_scopes_nest_and_free() {
        let m = WsMeter::default();
        {
            let mut outer = m.scope();
            outer.add(10);
            {
                let mut inner = m.scope();
                inner.add(5);
                assert_eq!(m.live(), 60);
            }
            assert_eq!(m.live(), 40);
        }
        assert_eq!(m.live(), 0);
        assert_eq!(m.peak(), 60);
    }

    #[test]
    fn kv_meter_tracks_live_and_peak() {
        let a = ActivationArena::new(MemoryPlan::remat());
        a.kv_alloc(100);
        a.kv_alloc(50);
        assert_eq!(a.kv_live(), 150);
        a.kv_free(100);
        assert_eq!(a.kv_live(), 50);
        assert_eq!(a.kv_peak(), 150);
        let s = a.stats();
        assert_eq!(s.kv_live_bytes, 50);
        assert_eq!(s.kv_peak_bytes, 150);
        // KV bytes are a separate client: the stash accounting is untouched
        assert_eq!(s.stash_live_bytes, 0);
        assert_eq!(s.stash_peak_bytes, 0);
    }

    #[test]
    fn fnv_distinguishes_bit_patterns() {
        let mut a = Fnv::new();
        a.f32s(&[0.0, 1.0]);
        let mut b = Fnv::new();
        b.f32s(&[-0.0, 1.0]);
        assert_ne!(a.finish(), b.finish(), "0.0 vs -0.0 must differ");
    }
}
