//! `xla::Literal` construction/extraction helpers for the hot path.
//!
//! Literals are created with `create_from_shape` + `copy_raw_from`, which
//! is a single memcpy into XLA-owned storage (no per-element conversion).

use anyhow::{ensure, Context, Result};
use xla::{ArrayElement, ElementType, Literal};

/// f32 literal with the given logical shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    ensure!(
        shape.iter().product::<usize>() == data.len(),
        "shape {:?} != data len {}",
        shape,
        data.len()
    );
    let mut lit = Literal::create_from_shape(ElementType::F32.primitive_type(), shape);
    lit.copy_raw_from(data).context("copy_raw_from f32")?;
    Ok(lit)
}

/// i32 literal with the given logical shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    ensure!(
        shape.iter().product::<usize>() == data.len(),
        "shape {:?} != data len {}",
        shape,
        data.len()
    );
    let mut lit = Literal::create_from_shape(ElementType::S32.primitive_type(), shape);
    lit.copy_raw_from(data).context("copy_raw_from i32")?;
    Ok(lit)
}

/// Rank-1 single-element f32 literal (runtime scalar inputs use shape [1]).
pub fn lit_scalar_f32(x: f32) -> Result<Literal> {
    lit_f32(&[x], &[1])
}

/// Extract an f32 literal (any rank) into a Vec.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal -> Vec<f32>")
}

/// Extract an i32 literal into a Vec.
pub fn to_vec_i32(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("literal -> Vec<i32>")
}

/// Copy a literal into a caller-provided buffer (alloc-free extraction).
pub fn copy_into_f32(lit: &Literal, dst: &mut [f32]) -> Result<()> {
    ensure!(lit.element_count() == dst.len(), "literal/dst length mismatch");
    lit.copy_raw_to(dst).context("literal copy_raw_to")
}

/// Copy the first `dst.len()` elements of a (possibly zero-padded) chunk
/// literal into `dst` — the tail-chunk extraction path of the optimizer
/// kernels. Falls back to a temporary only when the literal is larger.
pub fn copy_chunk(lit: &Literal, dst: &mut [f32]) -> Result<()> {
    let n = lit.element_count();
    if n == dst.len() {
        return lit.copy_raw_to(dst).context("copy_chunk exact");
    }
    ensure!(n > dst.len(), "chunk literal smaller than destination");
    let mut tmp = vec![0.0f32; n];
    lit.copy_raw_to(&mut tmp).context("copy_chunk padded")?;
    dst.copy_from_slice(&tmp[..dst.len()]);
    Ok(())
}

/// Element count sanity helper.
#[allow(dead_code)]
pub fn element_count(lit: &Literal) -> usize {
    lit.element_count()
}

/// f32 scalar (rank-0) extraction — for losses.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>().context("scalar f32")
}

/// i32 scalar (rank-0) extraction — for correct-prediction counts.
pub fn scalar_i32(lit: &Literal) -> Result<i32> {
    lit.get_first_element::<i32>().context("scalar i32")
}

/// Size in bytes of `n` elements of the given element type.
#[allow(dead_code)]
pub fn bytes_of<T: ArrayElement>(n: usize) -> usize {
    n * T::ELEMENT_SIZE_IN_BYTES
}
