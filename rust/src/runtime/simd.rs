//! Runtime-dispatched SIMD kernels for the host executor's element-wise
//! hot loops — **bit-for-bit identical** to the scalar reference at every
//! dispatch level.
//!
//! The host executor's dominant cost under the AdamA micro-batch loop is
//! a family of embarrassingly lane-parallel f32 sweeps: the chunked
//! optimizer kernels (`adama_acc`, `adam_update`, ...), the matmul inner
//! loops, layer-norm's normalise step and the element-wise stages of
//! softmax/attention. This module implements them once, generically over
//! a [`Lanes`] block abstraction, with `core::arch` AVX2/SSE2
//! instantiations selected at runtime (`is_x86_feature_detected!`) and a
//! portable scalar instantiation that *is* the reference semantics.
//!
//! ## The bit-exactness contract
//!
//! Every function here must return exactly the bits the scalar reference
//! (the plain loops in [`crate::runtime::hostexec::kernels`], equal to
//! dispatching at [`Level::Scalar`]) returns, for any input and any lane
//! width. The rules that make this possible:
//!
//! * vectorise only **across independent output elements** — never fold
//!   a reduction (dot product, row mean, NLL sum) into lanes, because
//!   that reassociates floating-point addition;
//! * keep each output element's expression tree identical to the scalar
//!   code: same operations, same order, same rounding points;
//! * use only IEEE-754 correctly-rounded single operations (`add`,
//!   `sub`, `mul`, `div`, `sqrt`) — **no FMA contraction** (the scalar
//!   code does not contract) and no approximate `rcpps`/`rsqrtps`;
//! * sweep the remainder (`len % WIDTH`) with the literal scalar
//!   expressions.
//!
//! Under these rules an SSE2/AVX2 lane block computes exactly what
//! `WIDTH` independent scalar iterations compute, so the determinism
//! suite, the backend-parity suite and the actstash bit-identity tests
//! pass unmodified at any `ADAMA_SIMD` setting —
//! `rust/tests/simd_parity.rs` sweeps every kernel × dispatch level ×
//! thread count at 0 ULP, including remainder-length slices. (The one
//! caveat: NaN *payload* propagation follows whatever the hardware does
//! for the chosen operand order, as it already did for the scalar code.)
//!
//! ## Output tiling and the packed GEMM micro-kernel
//!
//! [`gemm_tile`] (the register tile of the packed GEMM engine in
//! [`crate::runtime::hostexec::gemm`]) and the attention-score kernels
//! ([`attn_scores`], [`attn_dots`]) vectorise *in-row dot products* —
//! which looks like it should violate the no-lane-reductions rule, but
//! does not: the lanes span `WIDTH` **adjacent output columns**, never
//! the reduction axis. Each lane accumulates one output element's own
//! K-loop fold (`acc = acc + a·b`, p ascending, multiply-then-add, no
//! FMA), so every output element still computes the exact scalar
//! expression tree. Cache blocking over K is equally invisible: the
//! partial accumulator is stored to and reloaded from `out` between
//! K-blocks, and an f32 store/load round-trip is lossless, so the fold
//! remains one contiguous left-associated sum from `0.0` at every block
//! size. That is why the packed engine is bit-identical to the naive
//! loops at any block size, thread count, and SIMD level.
//!
//! ## Dispatch
//!
//! [`Level`] is resolved once per executor from `ADAMA_SIMD`
//! (`auto|avx2|sse2|neon|scalar`, default `auto` = the best level the
//! CPU reports). Unparseable values and levels the CPU cannot honour are
//! **clear errors** naming the accepted spellings — no silent fallback.
//! x86_64 dispatches SSE2/AVX2, aarch64 dispatches NEON, and every other
//! target always dispatches scalar. [`crate::runtime::Library`]
//! threads the level through
//! [`crate::runtime::hostexec::HostExecutor`] into every program.
//!
//! ## Adding a new ISA
//!
//! 1. add a [`Level`] variant and wire it through [`detect`],
//!    [`Level::parse`] and [`Level::supported`];
//! 2. implement [`Lanes`] for the new register type: `WIDTH`, unaligned
//!    `load`/`store`, `splat`, and the five exact ops — they must be the
//!    ISA's IEEE correctly-rounded instructions, with FMA left unused;
//! 3. add a `#[target_feature]` wrapper arm to the `dispatch!` macro
//!    (gate it on the runtime detection check exactly like `avx2`);
//! 4. run `rust/tests/simd_parity.rs` — the 0-ULP sweep is the gate, and
//!    `cargo bench --bench perf_microbench` must show the new level at
//!    least matching scalar.

use anyhow::{bail, ensure, Result};

/// SIMD dispatch level for the host executor's vector kernels.
///
/// `Scalar` is the reference semantics; `Sse2`/`Avx2` are bit-identical
/// accelerations (see the module docs for the contract). Construct via
/// [`Level::from_env`] / [`Level::parse`] / [`detect`] — the kernel
/// entry points re-check CPU support at dispatch time, so even a
/// hand-constructed unsupported level degrades safely instead of
/// executing unavailable instructions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Plain scalar loops — the reference semantics, always available.
    Scalar,
    /// 128-bit `core::arch` lanes (4 × f32). Baseline on x86_64.
    Sse2,
    /// 256-bit `core::arch` lanes (8 × f32), runtime-detected.
    Avx2,
    /// 128-bit aarch64 NEON lanes (4 × f32), runtime-detected.
    Neon,
}

/// Best level the running CPU supports (`Scalar` off x86_64/aarch64).
pub fn detect() -> Level {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            Level::Avx2
        } else {
            // SSE2 is part of the x86_64 baseline
            Level::Sse2
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            Level::Neon
        } else {
            Level::Scalar
        }
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Level::Scalar
    }
}

impl Level {
    /// Whether the running CPU can execute this level.
    pub fn supported(self) -> bool {
        match self {
            Level::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Level::Sse2 => true,
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Level::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            _ => false,
        }
    }

    /// Strictly resolve an `ADAMA_SIMD` value: `scalar`/`sse2`/`avx2`/
    /// `neon` pin the level, `auto`/unset/empty detect the best one; any
    /// other spelling, or a level the running CPU cannot execute, is an
    /// error naming the accepted values (no silent fallback).
    pub fn parse(spec: Option<&str>) -> Result<Level> {
        let req = match spec.map(str::trim) {
            Some(s) if !s.is_empty() => s.to_ascii_lowercase(),
            _ => return Ok(detect()),
        };
        let want = match req.as_str() {
            "auto" => return Ok(detect()),
            "scalar" => Level::Scalar,
            "sse2" => Level::Sse2,
            "avx2" => Level::Avx2,
            "neon" => Level::Neon,
            other => bail!("invalid ADAMA_SIMD '{other}': expected auto|avx2|sse2|neon|scalar"),
        };
        ensure!(
            want.supported(),
            "ADAMA_SIMD '{req}' is not supported on this CPU/target (best available: {})",
            detect().name()
        );
        Ok(want)
    }

    /// Level from the `ADAMA_SIMD` environment variable.
    pub fn from_env() -> Result<Level> {
        Self::parse(std::env::var("ADAMA_SIMD").ok().as_deref())
    }

    /// Stable lower-case name (the `ADAMA_SIMD` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }

    /// Every level the running CPU supports, scalar first — the sweep
    /// set for parity tests and benches.
    pub fn all_supported() -> Vec<Level> {
        [Level::Scalar, Level::Sse2, Level::Avx2, Level::Neon]
            .into_iter()
            .filter(|l| l.supported())
            .collect()
    }
}

/// A block of `WIDTH` f32 lanes with exactly-rounded element-wise ops.
///
/// Implementations must make every operation behave as `WIDTH`
/// independent scalar f32 operations (IEEE-754 correctly rounded, no
/// FMA, no approximations) — that property is what lets the generic
/// kernel bodies below be bit-identical across instantiations. See the
/// module docs for the full contract and how to add an ISA.
pub trait Lanes: Copy {
    /// Lanes per block.
    const WIDTH: usize;

    /// Load `WIDTH` consecutive f32s from `src` (unaligned).
    ///
    /// # Safety
    /// `src` must be valid for reading `WIDTH` consecutive f32s.
    unsafe fn load(src: *const f32) -> Self;

    /// Store `WIDTH` consecutive f32s to `dst` (unaligned).
    ///
    /// # Safety
    /// `dst` must be valid for writing `WIDTH` consecutive f32s.
    unsafe fn store(self, dst: *mut f32);

    /// Broadcast a scalar into every lane.
    fn splat(x: f32) -> Self;

    fn add(self, o: Self) -> Self;
    fn sub(self, o: Self) -> Self;
    fn mul(self, o: Self) -> Self;
    fn div(self, o: Self) -> Self;
    fn sqrt(self) -> Self;
}

/// One f32 "lane block": the portable reference instantiation.
#[derive(Clone, Copy)]
struct Scalar(f32);

impl Lanes for Scalar {
    const WIDTH: usize = 1;

    #[inline(always)]
    unsafe fn load(src: *const f32) -> Self {
        Scalar(*src)
    }

    #[inline(always)]
    unsafe fn store(self, dst: *mut f32) {
        *dst = self.0;
    }

    #[inline(always)]
    fn splat(x: f32) -> Self {
        Scalar(x)
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        Scalar(self.0 + o.0)
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        Scalar(self.0 - o.0)
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        Scalar(self.0 * o.0)
    }

    #[inline(always)]
    fn div(self, o: Self) -> Self {
        Scalar(self.0 / o.0)
    }

    #[inline(always)]
    fn sqrt(self) -> Self {
        Scalar(self.0.sqrt())
    }
}

// `unused_unsafe` allowance: on toolchains where the arithmetic
// intrinsics are safe-to-call (target feature statically enabled) the
// `unsafe` blocks below would warn; older toolchains require them.
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
mod x86 {
    use std::arch::x86_64::*;

    use super::Lanes;

    /// 4 × f32 SSE2 lanes (`__m128`).
    #[derive(Clone, Copy)]
    pub(super) struct Sse2(__m128);

    impl Lanes for Sse2 {
        const WIDTH: usize = 4;

        #[inline(always)]
        unsafe fn load(src: *const f32) -> Self {
            Sse2(unsafe { _mm_loadu_ps(src) })
        }

        #[inline(always)]
        unsafe fn store(self, dst: *mut f32) {
            unsafe { _mm_storeu_ps(dst, self.0) }
        }

        #[inline(always)]
        fn splat(x: f32) -> Self {
            Sse2(unsafe { _mm_set1_ps(x) })
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            Sse2(unsafe { _mm_add_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            Sse2(unsafe { _mm_sub_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            Sse2(unsafe { _mm_mul_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            Sse2(unsafe { _mm_div_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            Sse2(unsafe { _mm_sqrt_ps(self.0) })
        }
    }

    /// 8 × f32 AVX lanes (`__m256`), dispatched under the avx2 check.
    #[derive(Clone, Copy)]
    pub(super) struct Avx2(__m256);

    impl Lanes for Avx2 {
        const WIDTH: usize = 8;

        #[inline(always)]
        unsafe fn load(src: *const f32) -> Self {
            Avx2(unsafe { _mm256_loadu_ps(src) })
        }

        #[inline(always)]
        unsafe fn store(self, dst: *mut f32) {
            unsafe { _mm256_storeu_ps(dst, self.0) }
        }

        #[inline(always)]
        fn splat(x: f32) -> Self {
            Avx2(unsafe { _mm256_set1_ps(x) })
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_add_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_sub_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_mul_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_div_ps(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            Avx2(unsafe { _mm256_sqrt_ps(self.0) })
        }
    }
}

// Same `unused_unsafe` story as the x86 module: on aarch64 toolchains
// where NEON is statically enabled the arithmetic intrinsics are
// safe-to-call and the inner `unsafe` blocks would warn.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
mod arm {
    use std::arch::aarch64::*;

    use super::Lanes;

    /// 4 × f32 NEON lanes (`float32x4_t`). `vaddq`/`vsubq`/`vmulq`/
    /// `vdivq`/`vsqrtq` are the A64 IEEE-754 correctly-rounded single
    /// operations (scalar semantics per lane, no FMA contraction), so
    /// the bit-exactness contract holds exactly as for SSE2/AVX2.
    #[derive(Clone, Copy)]
    pub(super) struct Neon(float32x4_t);

    impl Lanes for Neon {
        const WIDTH: usize = 4;

        #[inline(always)]
        unsafe fn load(src: *const f32) -> Self {
            Neon(unsafe { vld1q_f32(src) })
        }

        #[inline(always)]
        unsafe fn store(self, dst: *mut f32) {
            unsafe { vst1q_f32(dst, self.0) }
        }

        #[inline(always)]
        fn splat(x: f32) -> Self {
            Neon(unsafe { vdupq_n_f32(x) })
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            Neon(unsafe { vaddq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            Neon(unsafe { vsubq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            Neon(unsafe { vmulq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn div(self, o: Self) -> Self {
            Neon(unsafe { vdivq_f32(self.0, o.0) })
        }

        #[inline(always)]
        fn sqrt(self) -> Self {
            Neon(unsafe { vsqrtq_f32(self.0) })
        }
    }
}

/// Generate the public runtime-dispatched entry point for one generic
/// kernel body: `$name(level, args...)` monomorphises `$body` at the
/// requested [`Level`], re-checking CPU support so an unsupported level
/// degrades to the next one down instead of executing missing
/// instructions. New ISAs add an arm here.
macro_rules! dispatch {
    ($(#[$meta:meta])* $name:ident => $body:ident ( $($arg:ident : $ty:ty),* $(,)? )) => {
        $(#[$meta])*
        #[allow(clippy::too_many_arguments)]
        pub fn $name(level: Level, $($arg: $ty),*) {
            #[cfg(target_arch = "x86_64")]
            {
                #[allow(clippy::too_many_arguments)]
                #[target_feature(enable = "sse2")]
                unsafe fn sse2($($arg: $ty),*) {
                    $body::<x86::Sse2>($($arg),*)
                }
                #[allow(clippy::too_many_arguments)]
                #[target_feature(enable = "avx2")]
                unsafe fn avx2($($arg: $ty),*) {
                    $body::<x86::Avx2>($($arg),*)
                }
                match level {
                    // SAFETY: avx2 is gated on runtime CPUID detection
                    // and sse2 is part of the x86_64 baseline, so the
                    // target-feature code only runs on silicon that
                    // implements it.
                    Level::Avx2 if is_x86_feature_detected!("avx2") => {
                        return unsafe { avx2($($arg),*) };
                    }
                    Level::Sse2 | Level::Avx2 => return unsafe { sse2($($arg),*) },
                    // Scalar, plus foreign-ISA levels (hand-constructed
                    // Neon on x86): degrade to the scalar reference.
                    _ => {}
                }
            }
            #[cfg(target_arch = "aarch64")]
            {
                #[allow(clippy::too_many_arguments)]
                #[target_feature(enable = "neon")]
                unsafe fn neon($($arg: $ty),*) {
                    $body::<arm::Neon>($($arg),*)
                }
                // SAFETY: gated on runtime NEON detection exactly like
                // the avx2 arm above.
                if matches!(level, Level::Neon)
                    && std::arch::is_aarch64_feature_detected!("neon")
                {
                    return unsafe { neon($($arg),*) };
                }
            }
            let _ = level;
            $body::<Scalar>($($arg),*)
        }
    };
}

// ---------------------------------------------------------------------------
// generic kernel bodies
//
// Each body is the scalar reference loop, restated once over `L: Lanes`
// with a literal-scalar remainder sweep. Expression trees (operation
// order, rounding points) are kept EXACTLY as in
// `runtime::hostexec::kernels` / `runtime::hostexec::math` — that
// correspondence is the bit-exactness contract, locked down by
// `rust/tests/simd_parity.rs`.
// ---------------------------------------------------------------------------

#[inline(always)]
fn adama_acc_g<L: Lanes>(m: &mut [f32], v: &mut [f32], g: &[f32], gscale: f32, b1: f32, b2: f32) {
    let n = m.len();
    debug_assert!(v.len() == n && g.len() == n);
    let c1 = L::splat(1.0 - b1);
    let c2 = L::splat(1.0 - b2);
    let gs = L::splat(gscale);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let sg = L::load(g.as_ptr().add(i)).mul(gs);
            L::load(m.as_ptr().add(i)).add(c1.mul(sg)).store(m.as_mut_ptr().add(i));
            L::load(v.as_ptr().add(i)).add(c2.mul(sg).mul(sg)).store(v.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        let sg = g[i] * gscale;
        m[i] += (1.0 - b1) * sg;
        v[i] += (1.0 - b2) * sg * sg;
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn adama_decay_acc_g<L: Lanes>(
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    gscale: f32,
    ms: f32,
    vs: f32,
    b1: f32,
    b2: f32,
) {
    let n = m.len();
    debug_assert!(v.len() == n && g.len() == n);
    let c1 = L::splat(1.0 - b1);
    let c2 = L::splat(1.0 - b2);
    let gs = L::splat(gscale);
    let msv = L::splat(ms);
    let vsv = L::splat(vs);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let sg = L::load(g.as_ptr().add(i)).mul(gs);
            let mv = msv.mul(L::load(m.as_ptr().add(i))).add(c1.mul(sg));
            mv.store(m.as_mut_ptr().add(i));
            let vv = vsv.mul(L::load(v.as_ptr().add(i))).add(c2.mul(sg).mul(sg));
            vv.store(v.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        let sg = g[i] * gscale;
        m[i] = ms * m[i] + (1.0 - b1) * sg;
        v[i] = vs * v[i] + (1.0 - b2) * sg * sg;
        i += 1;
    }
}

#[inline(always)]
fn scale_g<L: Lanes>(x: &mut [f32], s: f32) {
    let n = x.len();
    let sv = L::splat(s);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds the lane access.
        unsafe {
            L::load(x.as_ptr().add(i)).mul(sv).store(x.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        x[i] *= s;
        i += 1;
    }
}

#[inline(always)]
fn adam_update_g<L: Lanes>(
    p: &mut [f32],
    m: &[f32],
    v: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    let n = p.len();
    debug_assert!(m.len() == n && v.len() == n);
    let lrv = L::splat(lr);
    let bc1v = L::splat(bc1);
    let bc2v = L::splat(bc2);
    let epsv = L::splat(eps);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let mh = L::load(m.as_ptr().add(i)).div(bc1v);
            let den = L::load(v.as_ptr().add(i)).div(bc2v).sqrt().add(epsv);
            let pv = L::load(p.as_ptr().add(i)).sub(lrv.mul(mh).div(den));
            pv.store(p.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn adam_full_g<L: Lanes>(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
    b1: f32,
    b2: f32,
    eps: f32,
) {
    let n = p.len();
    debug_assert!(m.len() == n && v.len() == n && g.len() == n);
    let b1v = L::splat(b1);
    let b2v = L::splat(b2);
    let c1 = L::splat(1.0 - b1);
    let c2 = L::splat(1.0 - b2);
    let lrv = L::splat(lr);
    let bc1v = L::splat(bc1);
    let bc2v = L::splat(bc2);
    let epsv = L::splat(eps);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let gv = L::load(g.as_ptr().add(i));
            let mv = b1v.mul(L::load(m.as_ptr().add(i))).add(c1.mul(gv));
            mv.store(m.as_mut_ptr().add(i));
            let vv = b2v.mul(L::load(v.as_ptr().add(i))).add(c2.mul(gv).mul(gv));
            vv.store(v.as_mut_ptr().add(i));
            let den = vv.div(bc2v).sqrt().add(epsv);
            let pv = L::load(p.as_ptr().add(i)).sub(lrv.mul(mv.div(bc1v)).div(den));
            pv.store(p.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
        i += 1;
    }
}

#[inline(always)]
fn grad_acc_g<L: Lanes>(acc: &mut [f32], g: &[f32], gscale: f32) {
    let n = acc.len();
    debug_assert!(g.len() == n);
    let gs = L::splat(gscale);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds the lane accesses.
        unsafe {
            let av = L::load(acc.as_ptr().add(i)).add(L::load(g.as_ptr().add(i)).mul(gs));
            av.store(acc.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        acc[i] += g[i] * gscale;
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn adamw_update_g<L: Lanes>(
    p: &mut [f32],
    m: &[f32],
    v: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
    wd: f32,
    eps: f32,
) {
    let n = p.len();
    debug_assert!(m.len() == n && v.len() == n);
    let lrv = L::splat(lr);
    let bc1v = L::splat(bc1);
    let bc2v = L::splat(bc2);
    let wdv = L::splat(wd);
    let epsv = L::splat(eps);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let pv = L::load(p.as_ptr().add(i));
            let mh = L::load(m.as_ptr().add(i)).div(bc1v);
            let den = L::load(v.as_ptr().add(i)).div(bc2v).sqrt().add(epsv);
            pv.sub(lrv.mul(mh.div(den).add(wdv.mul(pv)))).store(p.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        p[i] -= lr * ((m[i] / bc1) / ((v[i] / bc2).sqrt() + eps) + wd * p[i]);
        i += 1;
    }
}

#[inline(always)]
fn sgdm_decay_acc_g<L: Lanes>(u: &mut [f32], g: &[f32], gscale: f32, mu: f32) {
    let n = u.len();
    debug_assert!(g.len() == n);
    let gs = L::splat(gscale);
    let muv = L::splat(mu);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds the lane accesses.
        unsafe {
            let uv = muv.mul(L::load(u.as_ptr().add(i))).add(gs.mul(L::load(g.as_ptr().add(i))));
            uv.store(u.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        u[i] = mu * u[i] + gscale * g[i];
        i += 1;
    }
}

#[inline(always)]
fn sgdm_acc_g<L: Lanes>(u: &mut [f32], g: &[f32], gscale: f32) {
    let n = u.len();
    debug_assert!(g.len() == n);
    let gs = L::splat(gscale);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds the lane accesses.
        unsafe {
            let uv = L::load(u.as_ptr().add(i)).add(gs.mul(L::load(g.as_ptr().add(i))));
            uv.store(u.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        u[i] += gscale * g[i];
        i += 1;
    }
}

#[inline(always)]
fn sgdm_update_g<L: Lanes>(p: &mut [f32], u: &[f32], lr: f32, wd: f32) {
    let n = p.len();
    debug_assert!(u.len() == n);
    let lrv = L::splat(lr);
    let wdv = L::splat(wd);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds the lane accesses.
        unsafe {
            let pv = L::load(p.as_ptr().add(i));
            let uv = L::load(u.as_ptr().add(i));
            pv.sub(lrv.mul(uv.add(wdv.mul(pv)))).store(p.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        p[i] -= lr * (u[i] + wd * p[i]);
        i += 1;
    }
}

#[inline(always)]
fn fac_update_g<L: Lanes>(p: &mut [f32], g: &[f32], c: &[f32], lr: f32, rfac: f32, eps: f32) {
    let n = p.len();
    debug_assert!(g.len() == n && c.len() == n);
    let lrv = L::splat(lr);
    let rfacv = L::splat(rfac);
    let epsv = L::splat(eps);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let gv = L::load(g.as_ptr().add(i));
            let den = rfacv.mul(L::load(c.as_ptr().add(i))).sqrt().add(epsv);
            let pv = L::load(p.as_ptr().add(i)).sub(lrv.mul(gv).div(den));
            pv.store(p.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        p[i] -= lr * g[i] / ((rfac * c[i]).sqrt() + eps);
        i += 1;
    }
}

#[inline(always)]
fn mini_update_g<L: Lanes>(p: &mut [f32], m: &[f32], scale: f32, bc1: f32) {
    let n = p.len();
    debug_assert!(m.len() == n);
    let sv = L::splat(scale);
    let bc1v = L::splat(bc1);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds the lane accesses.
        unsafe {
            let mh = L::load(m.as_ptr().add(i)).div(bc1v);
            L::load(p.as_ptr().add(i)).sub(sv.mul(mh)).store(p.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        p[i] -= scale * (m[i] / bc1);
        i += 1;
    }
}

#[inline(always)]
fn axpy_g<L: Lanes>(out: &mut [f32], x: &[f32], a: f32) {
    let n = out.len();
    debug_assert!(x.len() >= n);
    let av = L::splat(a);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n <= x.len()` bounds the lane accesses.
        unsafe {
            let ov = L::load(out.as_ptr().add(i)).add(av.mul(L::load(x.as_ptr().add(i))));
            ov.store(out.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

#[inline(always)]
fn add_assign_g<L: Lanes>(out: &mut [f32], x: &[f32]) {
    let n = out.len();
    debug_assert!(x.len() >= n);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n <= x.len()` bounds the lane accesses.
        unsafe {
            let ov = L::load(out.as_ptr().add(i)).add(L::load(x.as_ptr().add(i)));
            ov.store(out.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        out[i] += x[i];
        i += 1;
    }
}

#[inline(always)]
fn add_g<L: Lanes>(out: &mut [f32], a: &[f32], b: &[f32]) {
    let n = out.len();
    debug_assert!(a.len() == n && b.len() == n);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds the lane accesses.
        unsafe {
            let ov = L::load(a.as_ptr().add(i)).add(L::load(b.as_ptr().add(i)));
            ov.store(out.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        out[i] = a[i] + b[i];
        i += 1;
    }
}

#[inline(always)]
fn scale_into_g<L: Lanes>(out: &mut [f32], x: &[f32], s: f32) {
    let n = out.len();
    debug_assert!(x.len() >= n);
    let sv = L::splat(s);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n <= x.len()` bounds the lane accesses.
        unsafe {
            L::load(x.as_ptr().add(i)).mul(sv).store(out.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        out[i] = x[i] * s;
        i += 1;
    }
}

#[inline(always)]
fn norm_affine_g<L: Lanes>(out: &mut [f32], x: &[f32], g: &[f32], b: &[f32], mu: f32, rstd: f32) {
    let n = out.len();
    debug_assert!(x.len() == n && g.len() == n && b.len() == n);
    let muv = L::splat(mu);
    let rstdv = L::splat(rstd);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let xv = L::load(x.as_ptr().add(i));
            let gv = L::load(g.as_ptr().add(i));
            let bv = L::load(b.as_ptr().add(i));
            xv.sub(muv).mul(rstdv).mul(gv).add(bv).store(out.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        out[i] = (x[i] - mu) * rstd * g[i] + b[i];
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn ln_bwd_dx_g<L: Lanes>(
    dx: &mut [f32],
    x: &[f32],
    dy: &[f32],
    g: &[f32],
    mu: f32,
    rstd: f32,
    mean_dxhat: f32,
    mean_dxhat_xhat: f32,
) {
    let n = dx.len();
    debug_assert!(x.len() == n && dy.len() == n && g.len() == n);
    let muv = L::splat(mu);
    let rstdv = L::splat(rstd);
    let m1v = L::splat(mean_dxhat);
    let m2v = L::splat(mean_dxhat_xhat);
    let mut i = 0usize;
    while i + L::WIDTH <= n {
        // SAFETY: `i + WIDTH <= n` bounds every lane access below.
        unsafe {
            let xhat = L::load(x.as_ptr().add(i)).sub(muv).mul(rstdv);
            let dxhat = L::load(dy.as_ptr().add(i)).mul(L::load(g.as_ptr().add(i)));
            let adj = rstdv.mul(dxhat.sub(m1v).sub(xhat.mul(m2v)));
            L::load(dx.as_ptr().add(i)).add(adj).store(dx.as_mut_ptr().add(i));
        }
        i += L::WIDTH;
    }
    while i < n {
        let xhat = (x[i] - mu) * rstd;
        let dxhat = dy[i] * g[i];
        dx[i] += rstd * (dxhat - mean_dxhat - xhat * mean_dxhat_xhat);
        i += 1;
    }
}

/// Packed-GEMM register tile: one `(row block, K block)` update of an
/// `nc`-column output stripe starting at column `jb` of `out:[rows, n]`.
///
/// `panel:[kc, nc]` holds the B block contiguously; `a(r, p)` is read at
/// `a[a_off + r*ars + p*ads]` (the stride pair encodes NN/TN/NT without
/// copying A). Lanes span `WIDTH` adjacent output **columns** — the
/// K-loop stays a per-element left-associated `acc + a·b` fold from
/// `0.0` (`first`) or from the previous K-block's partial reloaded out
/// of `out` (lossless f32 round-trip), so every output element computes
/// exactly the naive scalar expression tree. `MR` output rows share each
/// loaded B lane to keep the panel column tile register/L1-resident.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn gemm_tile_g<L: Lanes>(
    out: &mut [f32],
    n: usize,
    jb: usize,
    nc: usize,
    a: &[f32],
    a_off: usize,
    ars: usize,
    ads: usize,
    panel: &[f32],
    kc: usize,
    rows: usize,
    first: bool,
) {
    const MR: usize = 4;
    debug_assert!(jb + nc <= n);
    debug_assert!(out.len() >= rows * n);
    debug_assert!(panel.len() >= kc * nc);
    let mut j = 0usize;
    while j + L::WIDTH <= nc {
        let col = jb + j;
        let mut r = 0usize;
        while r < rows {
            let mr = MR.min(rows - r);
            let mut acc = [L::splat(0.0); MR];
            if !first {
                for (q, av) in acc.iter_mut().enumerate().take(mr) {
                    // SAFETY: (r+q) < rows and col + WIDTH <= jb + nc <= n
                    // bound the lane access inside `out:[rows, n]`.
                    *av = unsafe { L::load(out.as_ptr().add((r + q) * n + col)) };
                }
            }
            for p in 0..kc {
                // SAFETY: p < kc and j + WIDTH <= nc bound the panel lane.
                let bv = unsafe { L::load(panel.as_ptr().add(p * nc + j)) };
                for (q, av) in acc.iter_mut().enumerate().take(mr) {
                    let aval = L::splat(a[a_off + (r + q) * ars + p * ads]);
                    *av = av.add(aval.mul(bv));
                }
            }
            for (q, av) in acc.iter().enumerate().take(mr) {
                // SAFETY: same bounds as the load above.
                unsafe { av.store(out.as_mut_ptr().add((r + q) * n + col)) };
            }
            r += mr;
        }
        j += L::WIDTH;
    }
    // remainder columns: the literal scalar fold
    while j < nc {
        let col = jb + j;
        for r in 0..rows {
            let mut acc = if first { 0.0f32 } else { out[r * n + col] };
            for p in 0..kc {
                acc += a[a_off + r * ars + p * ads] * panel[p * nc + j];
            }
            out[r * n + col] = acc;
        }
        j += 1;
    }
}

/// Attention score row: `out[j] = (Σ_d q[d]·kt[d*ldk + j])·scale` for
/// every key position `j`. `kt` is the transposed key block (`[dh, ldk]`
/// layout) so lanes span adjacent **output** positions `j` while each
/// element's dot stays the serial `d`-ascending fold from `0.0` — the
/// exact expression tree of the old per-`j` scalar dot, now computed for
/// `WIDTH` scores at once.
#[inline(always)]
fn attn_scores_g<L: Lanes>(out: &mut [f32], q: &[f32], kt: &[f32], ldk: usize, scale: f32) {
    let n = out.len();
    let dh = q.len();
    debug_assert!(kt.len() >= dh.saturating_sub(1) * ldk + n);
    let sv = L::splat(scale);
    let mut j = 0usize;
    while j + L::WIDTH <= n {
        let mut acc = L::splat(0.0);
        for (d, &qd) in q.iter().enumerate() {
            // SAFETY: j + WIDTH <= n <= ldk bounds the lane access.
            let kv = unsafe { L::load(kt.as_ptr().add(d * ldk + j)) };
            acc = acc.add(L::splat(qd).mul(kv));
        }
        // SAFETY: j + WIDTH <= n bounds the store.
        unsafe { acc.mul(sv).store(out.as_mut_ptr().add(j)) };
        j += L::WIDTH;
    }
    while j < n {
        let mut acc = 0.0f32;
        for (d, &qd) in q.iter().enumerate() {
            acc += qd * kt[d * ldk + j];
        }
        out[j] = acc * scale;
        j += 1;
    }
}

/// [`attn_scores`] without the scale multiply: `out[j] = Σ_d q[d]·
/// kt[d*ldk + j]` — the attention-VJP `dprobs` dot against the
/// transposed value block.
#[inline(always)]
fn attn_dots_g<L: Lanes>(out: &mut [f32], q: &[f32], kt: &[f32], ldk: usize) {
    let n = out.len();
    let dh = q.len();
    debug_assert!(kt.len() >= dh.saturating_sub(1) * ldk + n);
    let mut j = 0usize;
    while j + L::WIDTH <= n {
        let mut acc = L::splat(0.0);
        for (d, &qd) in q.iter().enumerate() {
            // SAFETY: j + WIDTH <= n <= ldk bounds the lane access.
            let kv = unsafe { L::load(kt.as_ptr().add(d * ldk + j)) };
            acc = acc.add(L::splat(qd).mul(kv));
        }
        // SAFETY: j + WIDTH <= n bounds the store.
        unsafe { acc.store(out.as_mut_ptr().add(j)) };
        j += L::WIDTH;
    }
    while j < n {
        let mut acc = 0.0f32;
        for (d, &qd) in q.iter().enumerate() {
            acc += qd * kt[d * ldk + j];
        }
        out[j] = acc;
        j += 1;
    }
}

// ---------------------------------------------------------------------------
// dispatched entry points
// ---------------------------------------------------------------------------

dispatch! {
    /// AdamA inner-loop accumulation: `m += (1-β₁)·s·g, v += (1-β₂)·(s·g)²`.
    adama_acc => adama_acc_g(
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        gscale: f32,
        b1: f32,
        b2: f32,
    )
}

dispatch! {
    /// Fused mini-batch-start decay + first micro-batch accumulation.
    #[allow(clippy::too_many_arguments)]
    adama_decay_acc => adama_decay_acc_g(
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        gscale: f32,
        ms: f32,
        vs: f32,
        b1: f32,
        b2: f32,
    )
}

dispatch! {
    /// In-place scale: `x *= s`.
    scale => scale_g(x: &mut [f32], s: f32)
}

dispatch! {
    /// Bias-corrected Adam parameter step.
    #[allow(clippy::too_many_arguments)]
    adam_update => adam_update_g(
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
    )
}

dispatch! {
    /// Baseline fused Adam step from a fully-accumulated gradient.
    #[allow(clippy::too_many_arguments)]
    adam_full => adam_full_g(
        p: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        b1: f32,
        b2: f32,
        eps: f32,
    )
}

dispatch! {
    /// Gradient-accumulation baseline: `acc += gscale·g`.
    grad_acc => grad_acc_g(acc: &mut [f32], g: &[f32], gscale: f32)
}

dispatch! {
    /// AdamW (decoupled weight decay) parameter step.
    #[allow(clippy::too_many_arguments)]
    adamw_update => adamw_update_g(
        p: &mut [f32],
        m: &[f32],
        v: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
        wd: f32,
        eps: f32,
    )
}

dispatch! {
    /// Momentum-SGD accumulation, first micro-batch (fused decay).
    sgdm_decay_acc => sgdm_decay_acc_g(u: &mut [f32], g: &[f32], gscale: f32, mu: f32)
}

dispatch! {
    /// Momentum-SGD accumulation: `u += gscale·g`.
    sgdm_acc => sgdm_acc_g(u: &mut [f32], g: &[f32], gscale: f32)
}

dispatch! {
    /// Momentum-SGD parameter step: `p -= lr·(u + wd·p)`.
    sgdm_update => sgdm_update_g(p: &mut [f32], u: &[f32], lr: f32, wd: f32)
}

dispatch! {
    /// Adafactor row step: `p -= lr·g/(√(rfac·c) + eps)` — the factored
    /// second moment reconstructed from the row factor `rfac` and the
    /// column moment slice `c`.
    fac_update => fac_update_g(
        p: &mut [f32],
        g: &[f32],
        c: &[f32],
        lr: f32,
        rfac: f32,
        eps: f32,
    )
}

dispatch! {
    /// Adam-mini block step: `p -= scale·(m/bc1)` with the block-shared
    /// learning-rate `scale`.
    mini_update => mini_update_g(p: &mut [f32], m: &[f32], scale: f32, bc1: f32)
}

dispatch! {
    /// `out += a·x` — the matmul/attention inner step (`out[j] += a * x[j]`).
    axpy => axpy_g(out: &mut [f32], x: &[f32], a: f32)
}

dispatch! {
    /// `out += x` element-wise (bias rows, residual fan-in).
    add_assign => add_assign_g(out: &mut [f32], x: &[f32])
}

dispatch! {
    /// `out = a + b` element-wise (residual connections).
    add => add_g(out: &mut [f32], a: &[f32], b: &[f32])
}

dispatch! {
    /// `out = x·s` element-wise (softmax probability normalisation).
    scale_into => scale_into_g(out: &mut [f32], x: &[f32], s: f32)
}

dispatch! {
    /// Layer-norm normalise step: `out = (x - mu)·rstd·g + b`.
    norm_affine => norm_affine_g(
        out: &mut [f32],
        x: &[f32],
        g: &[f32],
        b: &[f32],
        mu: f32,
        rstd: f32,
    )
}

dispatch! {
    /// Layer-norm backward dx row:
    /// `dx += rstd·(dy·g - mean_dxhat - (x-mu)·rstd·mean_dxhat_xhat)`.
    #[allow(clippy::too_many_arguments)]
    ln_bwd_dx => ln_bwd_dx_g(
        dx: &mut [f32],
        x: &[f32],
        dy: &[f32],
        g: &[f32],
        mu: f32,
        rstd: f32,
        mean_dxhat: f32,
        mean_dxhat_xhat: f32,
    )
}

dispatch! {
    /// Packed-GEMM register tile: one `(row block, K block)` stripe
    /// update with lane-parallel output columns (see the module docs'
    /// output-tiling section for the fold-order argument).
    #[allow(clippy::too_many_arguments)]
    gemm_tile => gemm_tile_g(
        out: &mut [f32],
        n: usize,
        jb: usize,
        nc: usize,
        a: &[f32],
        a_off: usize,
        ars: usize,
        ads: usize,
        panel: &[f32],
        kc: usize,
        rows: usize,
        first: bool,
    )
}

dispatch! {
    /// Attention score row against a transposed key block:
    /// `out[j] = (Σ_d q[d]·kt[d·ldk + j])·scale`, lanes across `j`.
    attn_scores => attn_scores_g(out: &mut [f32], q: &[f32], kt: &[f32], ldk: usize, scale: f32)
}

dispatch! {
    /// Attention-VJP dot row against a transposed value block:
    /// `out[j] = Σ_d q[d]·kt[d·ldk + j]`, lanes across `j`.
    attn_dots => attn_dots_g(out: &mut [f32], q: &[f32], kt: &[f32], ldk: usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    /// Deterministic "awkward" test vector: mixed signs/magnitudes plus
    /// exact zeros, sized to cover lane remainders.
    fn vector(seed: u64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let k = (i as u64).wrapping_mul(6364136223846793005).wrapping_add(seed);
                let u = ((k >> 33) as f32) / (1u64 << 31) as f32 - 0.5;
                if i % 17 == 0 {
                    0.0
                } else {
                    u * (1.0 + (i % 7) as f32)
                }
            })
            .collect()
    }

    const LENS: [usize; 12] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 31, 33, 1025];

    #[test]
    fn parse_and_detect() {
        assert_eq!(Level::parse(Some("scalar")).unwrap(), Level::Scalar);
        assert_eq!(Level::parse(None).unwrap(), detect());
        assert_eq!(Level::parse(Some("")).unwrap(), detect());
        assert_eq!(Level::parse(Some("auto")).unwrap(), detect());
        // invalid spellings are clear errors naming the accepted values
        let err = Level::parse(Some("garbage")).unwrap_err();
        assert!(format!("{err}").contains("auto|avx2|sse2|neon|scalar"), "{err}");
        #[cfg(not(target_arch = "x86_64"))]
        assert!(Level::parse(Some("avx2")).is_err(), "unsupported level must error");
        #[cfg(not(target_arch = "aarch64"))]
        assert!(Level::parse(Some("neon")).is_err(), "unsupported level must error");
        assert!(detect().supported());
        let all = Level::all_supported();
        assert_eq!(all[0], Level::Scalar);
        assert!(all.contains(&detect()));
        #[cfg(target_arch = "x86_64")]
        assert!(all.contains(&Level::Sse2));
        #[cfg(target_arch = "aarch64")]
        assert!(all.contains(&Level::Neon) || !Level::Neon.supported());
    }

    #[test]
    fn every_level_matches_scalar_optimizer_kernels() {
        for &n in &LENS {
            let m0 = vector(1, n);
            let v0: Vec<f32> = vector(2, n).iter().map(|x| x.abs()).collect();
            let p0 = vector(3, n);
            let g = vector(4, n);
            for level in Level::all_supported() {
                let (mut m, mut v, mut p) = (m0.clone(), v0.clone(), p0.clone());
                adama_acc(level, &mut m, &mut v, &g, 0.25, 0.9, 0.999);
                adama_decay_acc(level, &mut m, &mut v, &g, 0.25, 0.9, 0.999, 0.9, 0.999);
                adam_update(level, &mut p, &m, &v, 1e-3, 0.1, 0.001, 1e-8);
                adam_full(level, &mut p, &mut m, &mut v, &g, 1e-3, 0.1, 0.001, 0.9, 0.999, 1e-8);
                adamw_update(level, &mut p, &m, &v, 1e-3, 0.1, 0.001, 0.01, 1e-8);
                grad_acc(level, &mut p, &g, 0.5);
                sgdm_decay_acc(level, &mut m, &g, 0.5, 0.9);
                sgdm_acc(level, &mut m, &g, 0.5);
                sgdm_update(level, &mut p, &m, 1e-2, 0.01);
                scale(level, &mut v, 0.999);
                fac_update(level, &mut p, &g, &v, 1e-2, 1.25, 1e-8);
                mini_update(level, &mut p, &m, 3e-3, 0.1);

                let (mut ms, mut vs, mut ps) = (m0.clone(), v0.clone(), p0.clone());
                adama_acc(Level::Scalar, &mut ms, &mut vs, &g, 0.25, 0.9, 0.999);
                adama_decay_acc(Level::Scalar, &mut ms, &mut vs, &g, 0.25, 0.9, 0.999, 0.9, 0.999);
                adam_update(Level::Scalar, &mut ps, &ms, &vs, 1e-3, 0.1, 0.001, 1e-8);
                adam_full(
                    Level::Scalar,
                    &mut ps,
                    &mut ms,
                    &mut vs,
                    &g,
                    1e-3,
                    0.1,
                    0.001,
                    0.9,
                    0.999,
                    1e-8,
                );
                adamw_update(Level::Scalar, &mut ps, &ms, &vs, 1e-3, 0.1, 0.001, 0.01, 1e-8);
                grad_acc(Level::Scalar, &mut ps, &g, 0.5);
                sgdm_decay_acc(Level::Scalar, &mut ms, &g, 0.5, 0.9);
                sgdm_acc(Level::Scalar, &mut ms, &g, 0.5);
                sgdm_update(Level::Scalar, &mut ps, &ms, 1e-2, 0.01);
                scale(Level::Scalar, &mut vs, 0.999);
                fac_update(Level::Scalar, &mut ps, &g, &vs, 1e-2, 1.25, 1e-8);
                mini_update(Level::Scalar, &mut ps, &ms, 3e-3, 0.1);

                assert_eq!(bits(&m), bits(&ms), "{} n={n}: m", level.name());
                assert_eq!(bits(&v), bits(&vs), "{} n={n}: v", level.name());
                assert_eq!(bits(&p), bits(&ps), "{} n={n}: p", level.name());
            }
        }
    }

    #[test]
    fn every_level_matches_scalar_dense_helpers() {
        for &n in &LENS {
            let a = vector(11, n);
            let b = vector(12, n);
            let g = vector(13, n);
            let bias = vector(14, n);
            let base = vector(15, n);
            for level in Level::all_supported() {
                let check = |name: &str, got: &[f32], want: &[f32]| {
                    assert_eq!(bits(got), bits(want), "{name} at {} n={n}", level.name());
                };

                let (mut got, mut want) = (base.clone(), base.clone());
                axpy(level, &mut got, &a, 0.37);
                axpy(Level::Scalar, &mut want, &a, 0.37);
                check("axpy", &got, &want);

                let (mut got, mut want) = (base.clone(), base.clone());
                add_assign(level, &mut got, &a);
                add_assign(Level::Scalar, &mut want, &a);
                check("add_assign", &got, &want);

                let (mut got, mut want) = (base.clone(), base.clone());
                add(level, &mut got, &a, &b);
                add(Level::Scalar, &mut want, &a, &b);
                check("add", &got, &want);

                let (mut got, mut want) = (base.clone(), base.clone());
                scale_into(level, &mut got, &a, 0.73);
                scale_into(Level::Scalar, &mut want, &a, 0.73);
                check("scale_into", &got, &want);

                let (mut got, mut want) = (base.clone(), base.clone());
                norm_affine(level, &mut got, &a, &g, &bias, 0.11, 1.7);
                norm_affine(Level::Scalar, &mut want, &a, &g, &bias, 0.11, 1.7);
                check("norm_affine", &got, &want);

                let (mut got, mut want) = (base.clone(), base.clone());
                ln_bwd_dx(level, &mut got, &a, &b, &g, 0.11, 1.7, 0.05, -0.02);
                ln_bwd_dx(Level::Scalar, &mut want, &a, &b, &g, 0.11, 1.7, 0.05, -0.02);
                check("ln_bwd_dx", &got, &want);
            }
        }
    }

    #[test]
    fn unsupported_level_degrades_instead_of_crashing() {
        // Even a hand-constructed Avx2/Neon level must run (dispatch
        // re-checks CPU support); where supported it is just the fast
        // path, elsewhere it degrades to scalar.
        let mut x = vector(9, 37);
        let mut y = x.clone();
        scale(Level::Avx2, &mut x, 0.5);
        scale(Level::Scalar, &mut y, 0.5);
        assert_eq!(bits(&x), bits(&y));
        let mut z = vector(9, 37);
        scale(Level::Neon, &mut z, 0.5);
        assert_eq!(bits(&z), bits(&y));
    }

    #[test]
    fn every_level_matches_scalar_attention_kernels() {
        // out-length sweep covers lane remainders; ldk > n exercises the
        // transposed-block stride.
        let (dh, ldk) = (12usize, 40usize);
        let q = vector(21, dh);
        let kt = vector(22, dh * ldk);
        for &n in &[0usize, 1, 3, 4, 5, 8, 9, 33, 40] {
            for level in Level::all_supported() {
                let mut got = vec![0.0f32; n];
                let mut want = vec![0.0f32; n];
                attn_scores(level, &mut got, &q, &kt, ldk, 0.37);
                attn_scores(Level::Scalar, &mut want, &q, &kt, ldk, 0.37);
                assert_eq!(bits(&got), bits(&want), "attn_scores {} n={n}", level.name());

                let mut got = vec![0.0f32; n];
                let mut want = vec![0.0f32; n];
                attn_dots(level, &mut got, &q, &kt, ldk);
                attn_dots(Level::Scalar, &mut want, &q, &kt, ldk);
                assert_eq!(bits(&got), bits(&want), "attn_dots {} n={n}", level.name());
            }
        }
    }

    #[test]
    fn every_level_matches_scalar_gemm_tile() {
        // a:[rows, K] row-major (ars=K, ads=1), panel:[kc, nc]; two
        // K-blocks exercise the first/reload path, odd nc the scalar
        // column remainder, rows % MR != 0 the short row block.
        let (rows, n, jb, nc) = (7usize, 30usize, 3usize, 19usize);
        let kcs = [5usize, 8];
        let k: usize = kcs.iter().sum();
        let a = vector(31, rows * k);
        let panels: Vec<Vec<f32>> = kcs.iter().map(|&kc| vector(32 + kc as u64, kc * nc)).collect();
        let run = |level: Level| {
            let mut out = vector(33, rows * n); // pre-filled: `first` must overwrite
            let mut pb = 0usize;
            for (bi, &kc) in kcs.iter().enumerate() {
                gemm_tile(
                    level, &mut out, n, jb, nc, &a, pb, k, 1, &panels[bi], kc, rows, pb == 0,
                );
                pb += kc;
            }
            out
        };
        let want = run(Level::Scalar);
        // the scalar dispatch must equal the hand-written naive loop
        let mut naive = vector(33, rows * n);
        for r in 0..rows {
            for j in 0..nc {
                let mut acc = 0.0f32;
                let mut pb = 0usize;
                for (bi, &kc) in kcs.iter().enumerate() {
                    for p in 0..kc {
                        acc += a[r * k + pb + p] * panels[bi][p * nc + j];
                    }
                    pb += kc;
                }
                naive[r * n + jb + j] = acc;
            }
        }
        assert_eq!(bits(&want), bits(&naive), "scalar tile vs naive loop");
        for level in Level::all_supported() {
            assert_eq!(bits(&run(level)), bits(&want), "gemm_tile {}", level.name());
        }
    }
}
