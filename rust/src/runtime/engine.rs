//! PJRT client + executable wrappers.
//!
//! The raw `xla` crate types hold C pointers and are `!Send`; PJRT's C API
//! is documented thread-safe (clients, executables and literals may be used
//! concurrently), so we expose `Send + Sync` wrappers and keep all mutation
//! inside XLA. Worker threads in the data-parallel simulator share one CPU
//! client and its compiled executables through these wrappers.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

/// Thread-safe PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    /// Total `execute` calls issued through this engine (perf accounting).
    exec_calls: AtomicU64,
}

// SAFETY: PJRT C API objects (client/executable/buffer) are thread-safe per
// the PJRT API contract; the `xla` crate merely forgot the marker impls.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU PJRT client (the testbed substrate for the paper's
    /// GPUs — see DESIGN.md §Substitutions).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, exec_calls: AtomicU64::new(0) })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Parse HLO text and compile it to a loaded executable.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            client: self.client.clone(),
            engine_calls: &self.exec_calls as *const AtomicU64,
        })
    }

    /// Total number of PJRT `execute` calls issued (metrics).
    pub fn exec_calls(&self) -> u64 {
        self.exec_calls.load(Ordering::Relaxed)
    }
}

/// A borrowed host-array argument for [`Executable::run_args`] — the
/// zero-intermediate-copy input path (host slice → device buffer).
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// A compiled HLO module.
///
/// All artifacts are lowered with `return_tuple=True`, so execution always
/// yields one tuple literal which [`Executable::run`] decomposes.
///
/// NOTE: execution goes through `execute_b` with buffers this wrapper owns.
/// The published `xla` 0.1.6 crate's `execute()` (literal inputs) leaks
/// every input device buffer — `input_buffer_ptrs.push_back(buffer
/// .release())` in `xla_rs.cc` with no corresponding free — which at our
/// call volume (~1.3k PJRT calls per small-model step) is ~250 MB/step.
/// Creating `PjRtBuffer`s ourselves restores RAII ownership.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    engine_calls: *const AtomicU64,
}

// SAFETY: see `Engine` — PJRT executables are thread-safe; the counter
// pointer aliases the owning engine which outlives every executable in
// this crate (both live inside the same `ArtifactLibrary`/`Arc<Engine>`).
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    fn finish(&self, bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        unsafe { &*self.engine_calls }.fetch_add(1, Ordering::Relaxed);
        let lit = bufs[0][0].to_literal_sync().context("device->host transfer")?;
        lit.to_tuple().context("decomposing output tuple")
    }

    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let inputs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<Result<_, _>>()
            .context("literal -> device buffer")?;
        let bufs = self.exe.execute_b(&inputs).context("PJRT execute_b")?;
        self.finish(bufs)
    }

    /// Execute straight from host slices (no intermediate `Literal`) —
    /// the hot-path entry used by the chunked optimizer kernels.
    pub fn run_args(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
        let inputs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|a| match a {
                Arg::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
                Arg::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
            })
            .collect::<Result<_, _>>()
            .context("host slice -> device buffer")?;
        let bufs = self.exe.execute_b(&inputs).context("PJRT execute_b")?;
        self.finish(bufs)
    }
}
