//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`),
//! parsed with the in-tree JSON parser (no serde in the offline dep set).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "s32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * 4 // f32 and s32 are both 4 bytes
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            shape: j.get("shape")?.usize_vec()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(Self {
            file: j.get("file")?.as_str()?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            sha256: j.opt("sha256").and_then(|s| s.as_str().ok()).unwrap_or("").to_string(),
        })
    }
}

fn artifact_map(j: &Json) -> Result<BTreeMap<String, ArtifactEntry>> {
    j.as_obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), ArtifactEntry::from_json(v)?)))
        .collect()
}

#[derive(Debug, Clone)]
pub struct ModelHyper {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub microbatch: usize,
    pub ffn: usize,
}

#[derive(Debug, Clone)]
pub struct ModelConfigEntry {
    pub model: ModelHyper,
    /// Ordered (name, shape) pairs — the parameter registry ground truth.
    pub param_shapes: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

#[derive(Debug, Clone)]
pub struct MlpHyper {
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
    pub microbatch: usize,
}

#[derive(Debug, Clone)]
pub struct MlpConfigEntry {
    pub model: MlpHyper,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub hyper: Hyper,
    pub chunk_sizes: Vec<usize>,
    pub common: BTreeMap<String, ArtifactEntry>,
    pub configs: BTreeMap<String, ModelConfigEntry>,
    pub mlp_configs: BTreeMap<String, MlpConfigEntry>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let hyper = j.get("hyper")?;
        let hyper = Hyper {
            beta1: hyper.get("beta1")?.as_f64()?,
            beta2: hyper.get("beta2")?.as_f64()?,
            eps: hyper.get("eps")?.as_f64()?,
        };

        let mut configs = BTreeMap::new();
        for (name, c) in j.get("configs")?.as_obj()? {
            let m = c.get("model")?;
            let model = ModelHyper {
                vocab: m.get("vocab")?.as_usize()?,
                hidden: m.get("hidden")?.as_usize()?,
                layers: m.get("layers")?.as_usize()?,
                heads: m.get("heads")?.as_usize()?,
                seq: m.get("seq")?.as_usize()?,
                microbatch: m.get("microbatch")?.as_usize()?,
                ffn: m.get("ffn")?.as_usize()?,
            };
            let mut param_shapes = Vec::new();
            for pair in c.get("param_shapes")?.as_arr()? {
                let pair = pair.as_arr()?;
                if pair.len() != 2 {
                    bail!("bad param_shapes entry");
                }
                param_shapes.push((pair[0].as_str()?.to_string(), pair[1].usize_vec()?));
            }
            configs.insert(
                name.clone(),
                ModelConfigEntry { model, param_shapes, artifacts: artifact_map(c.get("artifacts")?)? },
            );
        }

        let mut mlp_configs = BTreeMap::new();
        for (name, c) in j.get("mlp_configs")?.as_obj()? {
            let m = c.get("model")?;
            let model = MlpHyper {
                features: m.get("features")?.as_usize()?,
                hidden: m.get("hidden")?.as_usize()?,
                classes: m.get("classes")?.as_usize()?,
                microbatch: m.get("microbatch")?.as_usize()?,
            };
            mlp_configs.insert(
                name.clone(),
                MlpConfigEntry { model, artifacts: artifact_map(c.get("artifacts")?)? },
            );
        }

        Ok(Self {
            hyper,
            chunk_sizes: j.get("chunk_sizes")?.usize_vec()?,
            common: artifact_map(j.get("common")?)?,
            configs,
            mlp_configs,
        })
    }

    /// Resolve `"group/name"` into its artifact entry.
    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        let (group, short) = name.split_once('/')?;
        match group {
            "common" => self.common.get(short),
            g if g.starts_with("mlp_") => {
                self.mlp_configs.get(&g[4..]).and_then(|c| c.artifacts.get(short))
            }
            g => self.configs.get(g).and_then(|c| c.artifacts.get(short)),
        }
    }

    pub fn model_config(&self, name: &str) -> Result<&ModelConfigEntry> {
        self.configs.get(name).with_context(|| format!("no model config '{name}'"))
    }

    pub fn mlp_config(&self, name: &str) -> Result<&MlpConfigEntry> {
        self.mlp_configs.get(name).with_context(|| format!("no mlp config '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "hyper": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-08},
      "chunk_sizes": [16384],
      "common": {"adama_acc_16384": {"file": "common/a.hlo.txt",
        "inputs": [{"shape": [16384], "dtype": "f32"}],
        "outputs": [{"shape": [16384], "dtype": "f32"}], "sha256": "x"}},
      "configs": {"tiny": {
        "model": {"vocab": 256, "hidden": 64, "layers": 2, "heads": 2,
                  "seq": 32, "microbatch": 4, "ffn": 256},
        "param_shapes": [["embed.E", [256, 64]], ["head.W", [64, 256]]],
        "artifacts": {"block_fwd": {"file": "tiny/b.hlo.txt",
          "inputs": [], "outputs": []}}}},
      "mlp_configs": {"tiny": {
        "model": {"features": 16, "hidden": 32, "classes": 4, "microbatch": 8},
        "artifacts": {}}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hyper.beta1, 0.9);
        assert_eq!(m.chunk_sizes, vec![16384]);
        assert_eq!(m.configs["tiny"].model.hidden, 64);
        assert_eq!(m.configs["tiny"].param_shapes[0].0, "embed.E");
        assert_eq!(m.mlp_configs["tiny"].model.classes, 4);
    }

    #[test]
    fn entry_resolution() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.entry("common/adama_acc_16384").is_some());
        assert!(m.entry("tiny/block_fwd").is_some());
        assert!(m.entry("tiny/missing").is_none());
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn tensor_spec_bytes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = &m.common["adama_acc_16384"];
        assert_eq!(e.inputs[0].elements(), 16384);
        assert_eq!(e.inputs[0].bytes(), 65536);
    }
}
