//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`),
//! parsed with the in-tree JSON parser (no serde in the offline dep set).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "s32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * 4 // f32 and s32 are both 4 bytes
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            shape: j.get("shape")?.usize_vec()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(Self {
            file: j.get("file")?.as_str()?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            sha256: j.opt("sha256").and_then(|s| s.as_str().ok()).unwrap_or("").to_string(),
        })
    }
}

fn artifact_map(j: &Json) -> Result<BTreeMap<String, ArtifactEntry>> {
    j.as_obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), ArtifactEntry::from_json(v)?)))
        .collect()
}

#[derive(Debug, Clone)]
pub struct ModelHyper {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub microbatch: usize,
    pub ffn: usize,
}

#[derive(Debug, Clone)]
pub struct ModelConfigEntry {
    pub model: ModelHyper,
    /// Ordered (name, shape) pairs — the parameter registry ground truth.
    pub param_shapes: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

#[derive(Debug, Clone)]
pub struct MlpHyper {
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
    pub microbatch: usize,
}

#[derive(Debug, Clone)]
pub struct MlpConfigEntry {
    pub model: MlpHyper,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub hyper: Hyper,
    pub chunk_sizes: Vec<usize>,
    pub common: BTreeMap<String, ArtifactEntry>,
    pub configs: BTreeMap<String, ModelConfigEntry>,
    pub mlp_configs: BTreeMap<String, MlpConfigEntry>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let hyper = j.get("hyper")?;
        let hyper = Hyper {
            beta1: hyper.get("beta1")?.as_f64()?,
            beta2: hyper.get("beta2")?.as_f64()?,
            eps: hyper.get("eps")?.as_f64()?,
        };

        let mut configs = BTreeMap::new();
        for (name, c) in j.get("configs")?.as_obj()? {
            let m = c.get("model")?;
            let model = ModelHyper {
                vocab: m.get("vocab")?.as_usize()?,
                hidden: m.get("hidden")?.as_usize()?,
                layers: m.get("layers")?.as_usize()?,
                heads: m.get("heads")?.as_usize()?,
                seq: m.get("seq")?.as_usize()?,
                microbatch: m.get("microbatch")?.as_usize()?,
                ffn: m.get("ffn")?.as_usize()?,
            };
            let mut param_shapes = Vec::new();
            for pair in c.get("param_shapes")?.as_arr()? {
                let pair = pair.as_arr()?;
                if pair.len() != 2 {
                    bail!("bad param_shapes entry");
                }
                param_shapes.push((pair[0].as_str()?.to_string(), pair[1].usize_vec()?));
            }
            configs.insert(
                name.clone(),
                ModelConfigEntry { model, param_shapes, artifacts: artifact_map(c.get("artifacts")?)? },
            );
        }

        let mut mlp_configs = BTreeMap::new();
        for (name, c) in j.get("mlp_configs")?.as_obj()? {
            let m = c.get("model")?;
            let model = MlpHyper {
                features: m.get("features")?.as_usize()?,
                hidden: m.get("hidden")?.as_usize()?,
                classes: m.get("classes")?.as_usize()?,
                microbatch: m.get("microbatch")?.as_usize()?,
            };
            mlp_configs.insert(
                name.clone(),
                MlpConfigEntry { model, artifacts: artifact_map(c.get("artifacts")?)? },
            );
        }

        Ok(Self {
            hyper,
            chunk_sizes: j.get("chunk_sizes")?.usize_vec()?,
            common: artifact_map(j.get("common")?)?,
            configs,
            mlp_configs,
        })
    }

    /// Built-in default manifest for the pure-rust host executor.
    ///
    /// Mirrors what `python/compile/aot.py` writes (same configs, same
    /// program names, same hyper-parameters), so `Trainer`, `MlpTrainer`
    /// and the optimizer kernels run on a clean machine with no
    /// `artifacts/` directory at all. `file` fields are advisory — the
    /// host executor dispatches on program *names*.
    pub fn builtin() -> Self {
        let hyper = Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let chunk_sizes = vec![16384, 65536, 1048576];

        let mut common = BTreeMap::new();
        for &c in &chunk_sizes {
            for (op, n_bufs, n_scalars, n_outs) in [
                ("adama_acc", 3usize, 1usize, 2usize),
                ("adama_decay_acc", 3, 3, 2),
                ("adama_decay", 2, 0, 2), // + two [1] scalar args, see below
                ("adam_update", 3, 3, 1),
                ("adam_full", 4, 3, 3),
                ("grad_acc", 2, 1, 1),
                ("adama_acc_update", 4, 0, 3), // gscale [1] + [lr,bc1,bc2]
                ("adamw_update", 3, 4, 1),
                ("sgdm_decay_acc", 2, 2, 1),
                ("sgdm_acc", 2, 1, 1),
                ("sgdm_update", 2, 2, 1),
                // optimizer-zoo update kernels (ADAMA_OPT): factored
                // Adafactor rows, SM3 cover reconstruction, Adam-mini
                // block-wise learning rates
                ("fac_update", 3, 2, 1),
                ("sm3_update", 3, 2, 2),
                ("mini_update", 2, 2, 1),
            ] {
                let mut inputs: Vec<TensorSpec> = (0..n_bufs).map(|_| f32_spec(&[c])).collect();
                match op {
                    "adama_decay" => {
                        inputs.push(f32_spec(&[1]));
                        inputs.push(f32_spec(&[1]));
                    }
                    "adama_acc_update" => {
                        inputs.push(f32_spec(&[1]));
                        inputs.push(f32_spec(&[3]));
                    }
                    _ if n_scalars > 0 => inputs.push(f32_spec(&[n_scalars])),
                    _ => {}
                }
                let outputs: Vec<TensorSpec> = (0..n_outs).map(|_| f32_spec(&[c])).collect();
                common.insert(
                    format!("{op}_{c}"),
                    ArtifactEntry {
                        file: format!("common/{op}_{c}.hlo.txt"),
                        inputs,
                        outputs,
                        sha256: String::new(),
                    },
                );
            }
        }

        let mut configs = BTreeMap::new();
        configs.insert("tiny".to_string(), builtin_model_entry("tiny", 256, 64, 2, 2, 32, 4));
        configs.insert("small".to_string(), builtin_model_entry("small", 2048, 256, 4, 4, 64, 8));

        let mut mlp_configs = BTreeMap::new();
        mlp_configs.insert("tiny".to_string(), builtin_mlp_entry("tiny", 16, 32, 4, 8));
        mlp_configs.insert("small".to_string(), builtin_mlp_entry("small", 32, 128, 10, 16));

        Self { hyper, chunk_sizes, common, configs, mlp_configs }
    }

    /// Resolve `"group/name"` into its artifact entry.
    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        let (group, short) = name.split_once('/')?;
        match group {
            "common" => self.common.get(short),
            g if g.starts_with("mlp_") => {
                self.mlp_configs.get(&g[4..]).and_then(|c| c.artifacts.get(short))
            }
            g => self.configs.get(g).and_then(|c| c.artifacts.get(short)),
        }
    }

    pub fn model_config(&self, name: &str) -> Result<&ModelConfigEntry> {
        self.configs.get(name).with_context(|| format!("no model config '{name}'"))
    }

    pub fn mlp_config(&self, name: &str) -> Result<&MlpConfigEntry> {
        self.mlp_configs.get(name).with_context(|| format!("no mlp config '{name}'"))
    }
}

fn f32_spec(shape: &[usize]) -> TensorSpec {
    TensorSpec { shape: shape.to_vec(), dtype: "f32".to_string() }
}

fn s32_spec(shape: &[usize]) -> TensorSpec {
    TensorSpec { shape: shape.to_vec(), dtype: "s32".to_string() }
}

/// One transformer config entry mirroring
/// `python/compile/model.py::ModelConfig` (ffn_mult = 4) and the artifact
/// signatures lowered by `aot.py::lower_model_config`.
fn builtin_model_entry(
    name: &str,
    vocab: usize,
    hidden: usize,
    layers: usize,
    heads: usize,
    seq: usize,
    microbatch: usize,
) -> ModelConfigEntry {
    let ffn = hidden * 4;
    let (v, h, f, s, b) = (vocab, hidden, ffn, seq, microbatch);

    let mut param_shapes: Vec<(String, Vec<usize>)> =
        vec![("embed.E".into(), vec![v, h]), ("embed.P".into(), vec![s, h])];
    for i in 0..layers {
        let p = format!("block{i}.");
        for (tensor, shape) in [
            ("ln1.g", vec![h]),
            ("ln1.b", vec![h]),
            ("attn.wqkv", vec![h, 3 * h]),
            ("attn.bqkv", vec![3 * h]),
            ("attn.wo", vec![h, h]),
            ("attn.bo", vec![h]),
            ("ln2.g", vec![h]),
            ("ln2.b", vec![h]),
            ("mlp.w1", vec![h, f]),
            ("mlp.b1", vec![f]),
            ("mlp.w2", vec![f, h]),
            ("mlp.b2", vec![h]),
        ] {
            param_shapes.push((format!("{p}{tensor}"), shape));
        }
    }
    param_shapes.push(("head.W".into(), vec![h, v]));

    // the 12 per-block tensors, in artifact argument order
    let block_specs: Vec<TensorSpec> = param_shapes
        .iter()
        .filter(|(n, _)| n.starts_with("block0."))
        .map(|(_, shape)| f32_spec(shape))
        .collect();

    let entry = |file: String, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| ArtifactEntry {
        file,
        inputs,
        outputs,
        sha256: String::new(),
    };
    let mut artifacts = BTreeMap::new();
    artifacts.insert(
        "embed_fwd".to_string(),
        entry(
            format!("{name}/embed_fwd.hlo.txt"),
            vec![s32_spec(&[b, s]), f32_spec(&[v, h]), f32_spec(&[s, h])],
            vec![f32_spec(&[b, s, h])],
        ),
    );
    artifacts.insert(
        "embed_bwd".to_string(),
        entry(
            format!("{name}/embed_bwd.hlo.txt"),
            vec![s32_spec(&[b, s]), f32_spec(&[b, s, h])],
            vec![f32_spec(&[v, h]), f32_spec(&[s, h])],
        ),
    );
    let mut block_fwd_in = vec![f32_spec(&[b, s, h])];
    block_fwd_in.extend(block_specs.iter().cloned());
    artifacts.insert(
        "block_fwd".to_string(),
        entry(format!("{name}/block_fwd.hlo.txt"), block_fwd_in, vec![f32_spec(&[b, s, h])]),
    );
    let mut block_bwd_in = vec![f32_spec(&[b, s, h]), f32_spec(&[b, s, h])];
    block_bwd_in.extend(block_specs.iter().cloned());
    let mut block_bwd_out = vec![f32_spec(&[b, s, h])];
    block_bwd_out.extend(block_specs.iter().cloned());
    artifacts.insert(
        "block_bwd".to_string(),
        entry(format!("{name}/block_bwd.hlo.txt"), block_bwd_in, block_bwd_out),
    );
    artifacts.insert(
        "head_loss".to_string(),
        entry(
            format!("{name}/head_loss.hlo.txt"),
            vec![f32_spec(&[b, s, h]), f32_spec(&[h, v]), s32_spec(&[b, s])],
            vec![f32_spec(&[]), f32_spec(&[b, s, h]), f32_spec(&[h, v])],
        ),
    );
    artifacts.insert(
        "head_eval".to_string(),
        entry(
            format!("{name}/head_eval.hlo.txt"),
            vec![f32_spec(&[b, s, h]), f32_spec(&[h, v]), s32_spec(&[b, s])],
            vec![f32_spec(&[]), s32_spec(&[])],
        ),
    );

    // serving decode programs (crate::serve). The host executor sizes
    // ragged batches at run time, so the row counts below are nominal
    // (one new row, a full-seq cache): shapes in these entries are
    // advisory, like `file`.
    artifacts.insert(
        "embed_decode".to_string(),
        entry(
            format!("{name}/embed_decode.hlo.txt"),
            vec![s32_spec(&[1]), s32_spec(&[1]), f32_spec(&[v, h]), f32_spec(&[s, h])],
            vec![f32_spec(&[1, h])],
        ),
    );
    let mut block_decode_in = vec![
        f32_spec(&[1, h]),
        s32_spec(&[1]),
        s32_spec(&[1]),
        f32_spec(&[s, h]),
        f32_spec(&[s, h]),
    ];
    block_decode_in.extend(block_specs.iter().cloned());
    artifacts.insert(
        "block_decode".to_string(),
        entry(
            format!("{name}/block_decode.hlo.txt"),
            block_decode_in,
            vec![f32_spec(&[1, h]), f32_spec(&[1, h]), f32_spec(&[1, h])],
        ),
    );
    artifacts.insert(
        "head_logits".to_string(),
        entry(
            format!("{name}/head_logits.hlo.txt"),
            vec![f32_spec(&[1, h]), f32_spec(&[h, v])],
            vec![f32_spec(&[1, v])],
        ),
    );

    ModelConfigEntry {
        model: ModelHyper { vocab, hidden, layers, heads, seq, microbatch, ffn },
        param_shapes,
        artifacts,
    }
}

/// One MLP config entry mirroring `model.py::MlpConfig` and
/// `aot.py::lower_mlp_config`.
fn builtin_mlp_entry(
    name: &str,
    features: usize,
    hidden: usize,
    classes: usize,
    microbatch: usize,
) -> MlpConfigEntry {
    let (d, hd, c, b) = (features, hidden, classes, microbatch);
    let params = [f32_spec(&[d, hd]), f32_spec(&[hd]), f32_spec(&[hd, c]), f32_spec(&[c])];
    let mut inputs = vec![f32_spec(&[b, d]), s32_spec(&[b])];
    inputs.extend(params.iter().cloned());
    let mut train_out = vec![f32_spec(&[])];
    train_out.extend(params.iter().cloned());

    let mut artifacts = BTreeMap::new();
    artifacts.insert(
        "mlp_train".to_string(),
        ArtifactEntry {
            file: format!("mlp_{name}/mlp_train.hlo.txt"),
            inputs: inputs.clone(),
            outputs: train_out,
            sha256: String::new(),
        },
    );
    artifacts.insert(
        "mlp_eval".to_string(),
        ArtifactEntry {
            file: format!("mlp_{name}/mlp_eval.hlo.txt"),
            inputs,
            outputs: vec![f32_spec(&[]), s32_spec(&[])],
            sha256: String::new(),
        },
    );

    MlpConfigEntry {
        model: MlpHyper { features, hidden, classes, microbatch },
        artifacts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "hyper": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-08},
      "chunk_sizes": [16384],
      "common": {"adama_acc_16384": {"file": "common/a.hlo.txt",
        "inputs": [{"shape": [16384], "dtype": "f32"}],
        "outputs": [{"shape": [16384], "dtype": "f32"}], "sha256": "x"}},
      "configs": {"tiny": {
        "model": {"vocab": 256, "hidden": 64, "layers": 2, "heads": 2,
                  "seq": 32, "microbatch": 4, "ffn": 256},
        "param_shapes": [["embed.E", [256, 64]], ["head.W", [64, 256]]],
        "artifacts": {"block_fwd": {"file": "tiny/b.hlo.txt",
          "inputs": [], "outputs": []}}}},
      "mlp_configs": {"tiny": {
        "model": {"features": 16, "hidden": 32, "classes": 4, "microbatch": 8},
        "artifacts": {}}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hyper.beta1, 0.9);
        assert_eq!(m.chunk_sizes, vec![16384]);
        assert_eq!(m.configs["tiny"].model.hidden, 64);
        assert_eq!(m.configs["tiny"].param_shapes[0].0, "embed.E");
        assert_eq!(m.mlp_configs["tiny"].model.classes, 4);
    }

    #[test]
    fn entry_resolution() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.entry("common/adama_acc_16384").is_some());
        assert!(m.entry("tiny/block_fwd").is_some());
        assert!(m.entry("tiny/missing").is_none());
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn builtin_manifest_is_complete() {
        let m = Manifest::builtin();
        // kernel programs for every chunk size
        for &c in &m.chunk_sizes {
            for op in ["adama_acc", "adama_decay_acc", "adam_update", "adam_full", "grad_acc"] {
                assert!(
                    m.entry(&format!("common/{op}_{c}")).is_some(),
                    "missing common/{op}_{c}"
                );
            }
        }
        // model configs group into valid layer specs
        for name in ["tiny", "small"] {
            let cfg = m.model_config(name).unwrap();
            assert_eq!(cfg.param_shapes.len(), 2 + 12 * cfg.model.layers + 1);
            assert!(m.entry(&format!("{name}/block_bwd")).is_some());
            // block_bwd: (x, dy, 12 params) -> (dx, 12 grads)
            let bwd = &cfg.artifacts["block_bwd"];
            assert_eq!(bwd.inputs.len(), 14);
            assert_eq!(bwd.outputs.len(), 13);
            // serving decode programs ride along with every model config
            for prog in ["embed_decode", "block_decode", "head_logits"] {
                assert!(
                    m.entry(&format!("{name}/{prog}")).is_some(),
                    "missing {name}/{prog}"
                );
            }
            // block_decode: (x, news, lens, kcat, vcat, 12 params) -> (y, knew, vnew)
            let dec = &cfg.artifacts["block_decode"];
            assert_eq!(dec.inputs.len(), 17);
            assert_eq!(dec.outputs.len(), 3);
        }
        for name in ["tiny", "small"] {
            let cfg = m.mlp_config(name).unwrap();
            assert!(cfg.artifacts.contains_key("mlp_train"));
            assert!(cfg.artifacts.contains_key("mlp_eval"));
            assert!(m.entry(&format!("mlp_{name}/mlp_train")).is_some());
        }
        assert_eq!(m.hyper.beta1, 0.9);
    }

    #[test]
    fn tensor_spec_bytes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = &m.common["adama_acc_16384"];
        assert_eq!(e.inputs[0].elements(), 16384);
        assert_eq!(e.inputs[0].bytes(), 65536);
    }
}
