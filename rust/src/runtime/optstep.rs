//! Exec-layer optimizer seam: which update rule drives the step, and the
//! [`OptStep`] trait the optimizer zoo implements behind it.
//!
//! `ADAMA_OPT=adam|adafactor|sm3|adam_mini` (strictly parsed, like every
//! `ADAMA_*` knob) overrides the configured optimizer with one of the
//! zoo's update rules; `Library::host_with_opt` / `Library::fork_with_opt`
//! are the API twins and the DP/ZeRO rank forks inherit the selection.
//! All four rules share the paper's core trick — micro-batch gradients are
//! folded **linearly** into a state-resident accumulator the moment a
//! layer's gradient materialises (the gradient buffer is released right
//! after), and the rule's nonlinear moment math runs once per mini-batch
//! at apply time. Because the fold is linear and the micro-batch scale
//! `1/M` is a power of two for M ∈ {1,2,4,8}, an M-way split is
//! **bit-for-bit identical** to the single-batch update on the summed
//! gradient — the Algorithm-1 invariant `rust/tests/optzoo.rs` asserts
//! for every rule against a serial scalar oracle.

use anyhow::{bail, Result};

/// The zoo's update rules, selectable at the executor seam.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptAlgo {
    /// Standard Adam on the summed gradient (the paper's Adam+GA baseline
    /// re-expressed through the seam: full `m`/`v`, fused update).
    Adam,
    /// Adafactor (Shazeer & Stern 2018): factored second moments — one
    /// row and one column accumulator per matrix; vectors keep a full
    /// second moment. β₁ = 0 (the memory-saving configuration).
    Adafactor,
    /// SM3 (Anil et al. 2019): cover-set accumulators — the per-element
    /// second moment is reconstructed as `min(row_i, col_j) + g²`;
    /// vectors fall back to full AdaGrad.
    Sm3,
    /// Adam-mini (Zhang et al. 2024): full first moment, one shared
    /// second-moment scalar per parameter block (here: per matrix row;
    /// one per vector).
    AdamMini,
}

impl OptAlgo {
    pub const ALL: [OptAlgo; 4] =
        [OptAlgo::Adam, OptAlgo::Adafactor, OptAlgo::Sm3, OptAlgo::AdamMini];

    pub fn name(self) -> &'static str {
        match self {
            OptAlgo::Adam => "adam",
            OptAlgo::Adafactor => "adafactor",
            OptAlgo::Sm3 => "sm3",
            OptAlgo::AdamMini => "adam_mini",
        }
    }

    /// Strictly parse an `ADAMA_OPT` value: a rule name forces the zoo,
    /// unset/empty keeps the configured optimizer; anything else is an
    /// error naming the accepted values.
    pub fn parse(spec: Option<&str>) -> Result<Option<OptAlgo>> {
        match spec.map(str::trim).unwrap_or("") {
            "" => Ok(None),
            "adam" => Ok(Some(OptAlgo::Adam)),
            "adafactor" => Ok(Some(OptAlgo::Adafactor)),
            "sm3" => Ok(Some(OptAlgo::Sm3)),
            "adam_mini" | "adam-mini" | "adammini" => Ok(Some(OptAlgo::AdamMini)),
            other => bail!(
                "invalid ADAMA_OPT '{other}': expected adam|adafactor|sm3|adam_mini \
                 (unset = the configured optimizer)"
            ),
        }
    }

    /// Resolve `ADAMA_OPT` from the environment.
    pub fn from_env() -> Result<Option<OptAlgo>> {
        Self::parse(std::env::var("ADAMA_OPT").ok().as_deref())
    }

    /// Per-tensor state-buffer lengths (elements, excluding the shared
    /// gradient-side accumulator) for a `rows`×`cols` tensor; `cols == 0`
    /// encodes a 1-D tensor of length `rows`. This is the allocation
    /// contract between the zoo and [`OptStep::apply`]'s `state` slice.
    pub fn state_lens(self, rows: usize, cols: usize) -> Vec<usize> {
        let n = rows * cols.max(1);
        match self {
            OptAlgo::Adam => vec![n, n],
            OptAlgo::Adafactor | OptAlgo::Sm3 => {
                if cols > 0 {
                    vec![rows, cols]
                } else {
                    vec![n]
                }
            }
            OptAlgo::AdamMini => vec![n, if cols > 0 { rows } else { 1 }],
        }
    }
}

/// One update rule behind the executor seam.
///
/// `apply` updates one tensor in place from the mini-batch's accumulated
/// gradient: `p` and `acc` are the tensor's `rows`×`cols` elements
/// (`cols == 0` = 1-D of length `rows`), `state` holds the rule's
/// per-tensor buffers laid out per [`OptAlgo::state_lens`], `step` is the
/// 1-based mini-batch counter and `lr` the resolved learning rate.
/// Implementations route their bulk element-wise work through the chunked
/// hostexec kernels (`fac_update`/`sm3_update`/`mini_update`/`adam_full`)
/// and keep only the tiny factored-statistic folds serial, so every rule
/// is bit-identical across backends, SIMD levels and thread counts.
pub trait OptStep: Send {
    fn algo(&self) -> OptAlgo;

    fn apply(
        &mut self,
        p: &mut [f32],
        acc: &[f32],
        state: &mut [Vec<f32>],
        rows: usize,
        cols: usize,
        step: u64,
        lr: f32,
    ) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_strict() {
        assert_eq!(OptAlgo::parse(None).unwrap(), None);
        assert_eq!(OptAlgo::parse(Some("")).unwrap(), None);
        assert_eq!(OptAlgo::parse(Some(" adam ")).unwrap(), Some(OptAlgo::Adam));
        assert_eq!(OptAlgo::parse(Some("adafactor")).unwrap(), Some(OptAlgo::Adafactor));
        assert_eq!(OptAlgo::parse(Some("sm3")).unwrap(), Some(OptAlgo::Sm3));
        assert_eq!(OptAlgo::parse(Some("adam-mini")).unwrap(), Some(OptAlgo::AdamMini));
        assert_eq!(OptAlgo::parse(Some("adammini")).unwrap(), Some(OptAlgo::AdamMini));
        let err = OptAlgo::parse(Some("adagrad")).unwrap_err();
        assert!(format!("{err}").contains("adam|adafactor|sm3|adam_mini"), "{err}");
    }

    #[test]
    fn state_lens_match_the_rules() {
        // adam: m + v, full
        assert_eq!(OptAlgo::Adam.state_lens(4, 6), vec![24, 24]);
        assert_eq!(OptAlgo::Adam.state_lens(5, 0), vec![5, 5]);
        // adafactor/sm3: factored rows+cols; 1-D keeps a full moment
        assert_eq!(OptAlgo::Adafactor.state_lens(4, 6), vec![4, 6]);
        assert_eq!(OptAlgo::Adafactor.state_lens(5, 0), vec![5]);
        assert_eq!(OptAlgo::Sm3.state_lens(4, 6), vec![4, 6]);
        assert_eq!(OptAlgo::Sm3.state_lens(5, 0), vec![5]);
        // adam-mini: full m + one v per row (one per vector)
        assert_eq!(OptAlgo::AdamMini.state_lens(4, 6), vec![24, 4]);
        assert_eq!(OptAlgo::AdamMini.state_lens(5, 0), vec![5, 1]);
    }
}
