//! In-tree deterministic chunked thread pool (the offline workspace has
//! no rayon; this is the subset the host executor needs).
//!
//! ## Determinism contract
//!
//! Work is only ever split into **contiguous, balanced ranges** of rows or
//! spans ([`partition`]), each processed start-to-end by exactly one
//! worker, and every helper requires the per-item computation to be
//! independent of the split (each output row/element is written by exactly
//! one closure invocation, with unchanged per-element arithmetic order).
//! Under that contract results are bit-for-bit identical at **any** thread
//! count — including the serial inline fallbacks below — which is what
//! `rust/tests/determinism.rs` locks down. There is deliberately no work
//! stealing: chunk→worker assignment is a pure function of `(n, threads)`.
//!
//! Cross-row *reductions* (column sums, scalar losses) are not expressible
//! through these helpers on purpose; callers keep them serial or reduce
//! fixed per-row partials in row order (see `hostexec::math`).
//!
//! ## Configuration
//!
//! `ADAMA_THREADS=N` pins the pool size ([`resolve_threads`]); unset,
//! empty or `auto` defaults to the machine's available parallelism, and
//! any other value is a **clear error** naming the accepted range (no
//! silent fallback). The DP/ZeRO runners re-pin their ranks to a per-rank
//! pool via `Library::fork_with_threads` to avoid oversubscription.
//!
//! ## Nesting and concurrent callers
//!
//! [`ThreadPool::run`] takes an issue lock with `try_lock`: a nested or
//! concurrent parallel region simply degrades to an inline serial sweep of
//! the same ranges (bit-identical by the contract above), so the pool can
//! never deadlock on itself.

use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

/// Hard upper bound on pool size (sanity cap for bogus `ADAMA_THREADS`).
pub const MAX_THREADS: usize = 256;

/// Below this many elements in the primary buffer the helpers run inline —
/// broadcast latency would dominate. Safe: the split never affects bits.
const SERIAL_CUTOFF: usize = 1024;

/// Strictly resolve a thread-count spec (the `ADAMA_THREADS` value): an
/// integer in `1..=`[`MAX_THREADS`] pins the count; unset, empty or
/// `auto` means the machine's available parallelism; anything else is an
/// error naming the accepted values (no silent fallback).
pub fn resolve_threads(spec: Option<&str>) -> Result<usize> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let s = match spec.map(str::trim) {
        Some(s) if !s.is_empty() => s,
        _ => return Ok(hw),
    };
    if s.eq_ignore_ascii_case("auto") {
        return Ok(hw);
    }
    match s.parse::<usize>() {
        Ok(n) if (1..=MAX_THREADS).contains(&n) => Ok(n),
        _ => bail!(
            "invalid ADAMA_THREADS '{s}': expected an integer 1..={MAX_THREADS}, or \
             `auto`/unset for available parallelism"
        ),
    }
}

/// Thread count from the `ADAMA_THREADS` environment variable.
pub fn default_threads() -> Result<usize> {
    resolve_threads(std::env::var("ADAMA_THREADS").ok().as_deref())
}

/// Contiguous balanced split of `0..n` into at most `parts` non-empty
/// `(offset, len)` ranges: the first `n % parts` ranges get one extra
/// element. `n = 0` yields no ranges; `n < parts` yields `n` unit ranges.
pub fn partition(n: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts > 0, "partition: zero parts");
    let k = parts.min(n);
    let mut out = Vec::with_capacity(k);
    if k == 0 {
        return out;
    }
    let (base, rem) = (n / k, n % k);
    let mut off = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push((off, len));
        off += len;
    }
    debug_assert_eq!(off, n);
    out
}

/// A job broadcast to every worker: called once per worker index. The
/// `'static` is a lie erased in [`ThreadPool::run`], which joins all
/// workers before returning.
type Job = &'static (dyn Fn(usize) + Sync);

struct State {
    epoch: u64,
    job: Option<Job>,
    remaining: usize,
    shutdown: bool,
    panicked: bool,
}

struct Shared {
    state: Mutex<State>,
    start: Condvar,
    done: Condvar,
}

impl Shared {
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// Raw base pointer that may cross into workers; each worker only touches
/// the disjoint range [`partition`] assigned to it.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Deterministic fixed-assignment thread pool. `new(1)` spawns no threads
/// and every helper runs inline (zero overhead), so a 1-thread pool *is*
/// the serial executor.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    issue: Mutex<()>,
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    let mut seen = 0u64;
    loop {
        let job;
        {
            let mut st = shared.lock();
            job = loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("pool: epoch bumped without a job");
                }
                st = shared
                    .start
                    .wait(st)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            };
        }
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(id)));
        let mut st = shared.lock();
        if res.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

impl ThreadPool {
    /// Pool with `threads` total workers (the caller thread is worker 0;
    /// `threads - 1` OS threads are spawned).
    pub fn new(threads: usize) -> Self {
        let threads = threads.clamp(1, MAX_THREADS);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                remaining: 0,
                shutdown: false,
                panicked: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|id| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("adama-pool-{id}"))
                    .spawn(move || worker_loop(sh, id))
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, handles, threads, issue: Mutex::new(()) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Invoke `f(worker)` once for every worker index `0..threads`,
    /// concurrently. The caller participates as worker 0. If the pool is
    /// busy (nested or concurrent region) the sweep runs inline serially —
    /// bit-identical under the determinism contract.
    pub fn run<F: Fn(usize) + Sync>(&self, f: F) {
        if self.handles.is_empty() {
            f(0);
            return;
        }
        let _guard = match self.issue.try_lock() {
            Ok(g) => g,
            // a previous caught panic may have poisoned the lock — recover
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            // busy: nested or concurrent region — degrade to inline serial
            Err(std::sync::TryLockError::WouldBlock) => {
                for w in 0..self.threads {
                    f(w);
                }
                return;
            }
        };
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the reference only escapes into worker threads, and this
        // function does not return until `remaining` hits 0 (every worker
        // has finished executing the job) and the slot is cleared.
        let job: Job = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f_ref)
        };
        {
            let mut st = self.shared.lock();
            st.job = Some(job);
            st.epoch = st.epoch.wrapping_add(1);
            st.remaining = self.handles.len();
            self.shared.start.notify_all();
        }
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        let worker_panicked;
        {
            let mut st = self.shared.lock();
            while st.remaining > 0 {
                st = self
                    .shared
                    .done
                    .wait(st)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            st.job = None;
            worker_panicked = std::mem::take(&mut st.panicked);
        }
        if let Err(e) = caller {
            std::panic::resume_unwind(e);
        }
        if worker_panicked {
            panic!("thread pool worker panicked");
        }
    }

    /// Parallel loop over the rows of `data` (`width` elements each):
    /// `f(row_index, row)`. Rows are assigned to workers in contiguous
    /// balanced blocks; each row is written by exactly one invocation.
    pub fn for_rows<T, F>(&self, data: &mut [T], width: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(width > 0, "for_rows: zero width");
        assert_eq!(data.len() % width, 0, "for_rows: len {} % width {width} != 0", data.len());
        let rows = data.len() / width;
        if self.threads == 1 || rows < 2 || data.len() < SERIAL_CUTOFF {
            for (r, row) in data.chunks_mut(width).enumerate() {
                f(r, row);
            }
            return;
        }
        let ranges = partition(rows, self.threads);
        let base = SendPtr(data.as_mut_ptr());
        self.run(|w| {
            if let Some(&(r0, cnt)) = ranges.get(w) {
                for r in r0..r0 + cnt {
                    // SAFETY: row ranges are disjoint across workers and
                    // `data` outlives `run`, which joins every worker.
                    let row =
                        unsafe { std::slice::from_raw_parts_mut(base.0.add(r * width), width) };
                    f(r, row);
                }
            }
        });
    }

    /// Two-output variant of [`for_rows`]: `a` and `b` must have the same
    /// row count (widths `wa`, `wb`); `f(row, a_row, b_row)`.
    ///
    /// [`for_rows`]: ThreadPool::for_rows
    pub fn for_rows2<T, U, F>(&self, a: &mut [T], wa: usize, b: &mut [U], wb: usize, f: F)
    where
        T: Send,
        U: Send,
        F: Fn(usize, &mut [T], &mut [U]) + Sync,
    {
        assert!(wa > 0 && wb > 0, "for_rows2: zero width");
        assert_eq!(a.len() % wa, 0, "for_rows2: a len/width mismatch");
        assert_eq!(b.len() % wb, 0, "for_rows2: b len/width mismatch");
        let rows = a.len() / wa;
        assert_eq!(rows, b.len() / wb, "for_rows2: row-count mismatch");
        if self.threads == 1 || rows < 2 || a.len().max(b.len()) < SERIAL_CUTOFF {
            for (r, (ra, rb)) in a.chunks_mut(wa).zip(b.chunks_mut(wb)).enumerate() {
                f(r, ra, rb);
            }
            return;
        }
        let ranges = partition(rows, self.threads);
        let pa = SendPtr(a.as_mut_ptr());
        let pb = SendPtr(b.as_mut_ptr());
        self.run(|w| {
            if let Some(&(r0, cnt)) = ranges.get(w) {
                for r in r0..r0 + cnt {
                    // SAFETY: as in `for_rows`; the two buffers are distinct
                    // allocations with disjoint per-worker row ranges.
                    let ra = unsafe { std::slice::from_raw_parts_mut(pa.0.add(r * wa), wa) };
                    let rb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(r * wb), wb) };
                    f(r, ra, rb);
                }
            }
        });
    }

    /// Parallel sweep over contiguous spans of a flat buffer:
    /// `f(offset, span)`, one span per worker. For element-wise kernels.
    pub fn for_spans<T, F>(&self, data: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if self.threads == 1 || n < SERIAL_CUTOFF {
            if n > 0 {
                f(0, data);
            }
            return;
        }
        let ranges = partition(n, self.threads);
        let p = SendPtr(data.as_mut_ptr());
        self.run(|w| {
            if let Some(&(off, len)) = ranges.get(w) {
                // SAFETY: spans are disjoint; `data` outlives `run`.
                let s = unsafe { std::slice::from_raw_parts_mut(p.0.add(off), len) };
                f(off, s);
            }
        });
    }

    /// [`for_spans`] over two equal-length buffers sharing offsets.
    ///
    /// [`for_spans`]: ThreadPool::for_spans
    pub fn for_spans2<T, F>(&self, a: &mut [T], b: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T], &mut [T]) + Sync,
    {
        assert_eq!(a.len(), b.len(), "for_spans2: length mismatch");
        let n = a.len();
        if self.threads == 1 || n < SERIAL_CUTOFF {
            if n > 0 {
                f(0, a, b);
            }
            return;
        }
        let ranges = partition(n, self.threads);
        let pa = SendPtr(a.as_mut_ptr());
        let pb = SendPtr(b.as_mut_ptr());
        self.run(|w| {
            if let Some(&(off, len)) = ranges.get(w) {
                // SAFETY: disjoint spans over two distinct buffers.
                let sa = unsafe { std::slice::from_raw_parts_mut(pa.0.add(off), len) };
                let sb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(off), len) };
                f(off, sa, sb);
            }
        });
    }

    /// [`for_spans`] over three equal-length buffers sharing offsets.
    ///
    /// [`for_spans`]: ThreadPool::for_spans
    pub fn for_spans3<T, F>(&self, a: &mut [T], b: &mut [T], c: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T], &mut [T], &mut [T]) + Sync,
    {
        assert!(a.len() == b.len() && b.len() == c.len(), "for_spans3: length mismatch");
        let n = a.len();
        if self.threads == 1 || n < SERIAL_CUTOFF {
            if n > 0 {
                f(0, a, b, c);
            }
            return;
        }
        let ranges = partition(n, self.threads);
        let pa = SendPtr(a.as_mut_ptr());
        let pb = SendPtr(b.as_mut_ptr());
        let pc = SendPtr(c.as_mut_ptr());
        self.run(|w| {
            if let Some(&(off, len)) = ranges.get(w) {
                // SAFETY: disjoint spans over three distinct buffers.
                let sa = unsafe { std::slice::from_raw_parts_mut(pa.0.add(off), len) };
                let sb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(off), len) };
                let sc = unsafe { std::slice::from_raw_parts_mut(pc.0.add(off), len) };
                f(off, sa, sb, sc);
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
        }
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_covers_and_balances() {
        assert!(partition(0, 4).is_empty());
        assert_eq!(partition(3, 8), vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(partition(10, 3), vec![(0, 4), (4, 3), (7, 3)]);
        assert_eq!(partition(8, 4), vec![(0, 2), (2, 2), (4, 2), (6, 2)]);
    }

    #[test]
    fn run_visits_every_worker_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.run(|w| {
                hits[w].fetch_add(1, Ordering::Relaxed);
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 50);
        }
    }

    #[test]
    fn for_rows_is_bitwise_thread_count_invariant() {
        let n_rows = 64;
        let width = 32; // 2048 elements: above the serial cutoff
        let fill = |pool: &ThreadPool| {
            let mut data = vec![0.0f32; n_rows * width];
            pool.for_rows(&mut data, width, |r, row| {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = ((r * 31 + j) as f32).sin();
                }
            });
            data
        };
        let serial = fill(&ThreadPool::new(1));
        for t in [2usize, 3, 8] {
            let par = fill(&ThreadPool::new(t));
            assert!(
                serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "for_rows drifted at {t} threads"
            );
        }
    }

    #[test]
    fn for_spans_cover_all_offsets() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u32; 5000];
        pool.for_spans(&mut data, |off, span| {
            for (i, v) in span.iter_mut().enumerate() {
                *v = (off + i) as u32;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
        let mut a = vec![1.0f32; 4096];
        let mut b = vec![2.0f32; 4096];
        pool.for_spans2(&mut a, &mut b, |_, sa, sb| {
            for (x, y) in sa.iter_mut().zip(sb.iter_mut()) {
                *x += *y;
                *y = 0.0;
            }
        });
        assert!(a.iter().all(|&x| x == 3.0) && b.iter().all(|&y| y == 0.0));
    }

    #[test]
    fn nested_run_degrades_serially_without_deadlock() {
        let pool = ThreadPool::new(4);
        let inner = AtomicUsize::new(0);
        pool.run(|_| {
            // nested region: issue lock is held, must fall back inline
            pool.run(|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        // each of the 4 outer workers swept all 4 inner indices serially
        assert_eq!(inner.load(Ordering::Relaxed), 16);
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn worker_panic_propagates_to_caller() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0.0f32; 8192];
        pool.for_rows(&mut data, 64, |r, _| {
            assert!(r != 100, "row 100 panicked");
        });
    }

    #[test]
    fn resolve_threads_spec() {
        assert_eq!(resolve_threads(Some("3")).unwrap(), 3);
        assert_eq!(resolve_threads(Some(" 12 ")).unwrap(), 12);
        let hw = resolve_threads(None).unwrap();
        assert!(hw >= 1);
        assert_eq!(resolve_threads(Some("")).unwrap(), hw);
        assert_eq!(resolve_threads(Some("auto")).unwrap(), hw);
        assert_eq!(resolve_threads(Some("AUTO")).unwrap(), hw);
        // invalid specs are clear errors naming the accepted values, not
        // silent fallbacks
        for bad in ["0", "banana", "999999", "-4", "1.5"] {
            let err = resolve_threads(Some(bad)).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains("ADAMA_THREADS") && msg.contains("auto"), "{bad}: {msg}");
        }
    }
}
