//! Figure 5 — BERT-Large memory vs accumulation steps, GA vs AdamA.
//!
//! Paper: AdamA saves a *constant* ~1.6 GB over gradient accumulation at
//! every N (the full-model-minus-max-layer gradient buffer). Two parts:
//!
//! 1. paper scale — the analytic model at BERT-Large, mini-batch 256 on
//!    8 GPUs, sweeping N;
//! 2. validation — the same formulas at `tiny` scale against *measured*
//!    `MemoryTracker` peaks from real training runs;
//! 3. stash-vs-remat — the host executor's `ADAMA_ACT_BUDGET` sweep at
//!    budgets 0 / half / unlimited, asserting the measured stash arena
//!    peak equals the `memmodel::HostBlockDims` prediction exactly.

use adama::config::OptimizerKind;
use adama::data::MarkovCorpus;
use adama::memmodel::{peak_memory, DtypePolicy, HostBlockDims, PaperModel, Scenario, Strategy};
use adama::runtime::{Library, MemoryPlan};
use adama::util::stats::fmt_bytes;
use adama::{Category, Trainer};

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, gb, lib_or_exit};

fn main() {
    let lib = lib_or_exit();

    banner("Figure 5 (paper scale): BERT-Large per-GPU memory, mb 256 / 8 GPUs");
    println!(
        "{:>3} {:>12} {:>12} {:>12}",
        "N", "GA (GB)", "AdamA (GB)", "saving (GB)"
    );
    let model = PaperModel::bert_large();
    for n in [1u64, 2, 4, 8, 16] {
        let mk = |strategy| {
            peak_memory(&Scenario {
                model: model.clone(),
                dtype: DtypePolicy::paper_fp32(),
                strategy,
                optimizer: OptimizerKind::AdamGA,
                minibatch_per_gpu: 32,
                accum_steps: n,
                gpus: 8,
            })
            .total()
        };
        let ga = mk(Strategy::GradAccum);
        let aa = mk(Strategy::AdamA);
        println!("{n:>3} {:>12.2} {:>12.2} {:>12.2}", gb(ga), gb(aa), gb(ga - aa));
    }
    println!("(paper: constant 1.6 GB saving at every N)");

    banner("validation: measured tracker peaks at tiny scale");
    println!(
        "{:>3} {:<7} {:>14} {:>14} {:>14}",
        "N", "optim", "grads peak", "acts peak", "optstate"
    );
    for n in [2usize, 4, 8] {
        for opt in [OptimizerKind::AdamGA, OptimizerKind::AdamA] {
            let mut t = Trainer::new(lib.clone(), cfg("tiny", opt, n, 42)).unwrap();
            let h = t.spec().hyper.clone();
            let mut c = MarkovCorpus::new(h.vocab, 7, 1);
            for _ in 0..2 {
                t.train_step(&c.minibatch(n, h.microbatch, h.seq)).unwrap();
            }
            println!(
                "{n:>3} {:<7} {:>14} {:>14} {:>14}",
                opt.name(),
                fmt_bytes(t.tracker().peak(Category::Gradients)),
                fmt_bytes(t.tracker().peak(Category::Activations)),
                fmt_bytes(t.tracker().peak(Category::OptimizerStates)),
            );
        }
    }
    // invariants printed above are asserted in rust/tests/; here we just
    // exhibit the measured constant-saving shape.

    banner("stash-vs-remat: measured executor activation peaks vs memmodel (tiny)");
    let hyper = lib.manifest().model_config("tiny").expect("tiny config").model.clone();
    let dims = HostBlockDims::from_model(&hyper);
    let blocks = hyper.layers as u64;
    let entry = dims.stash_entry_bytes();
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>6} {:>7}",
        "budget", "stash peak", "predicted", "ws peak", "hits", "remats"
    );
    for (name, plan) in [
        ("0", MemoryPlan::remat()),
        ("half", MemoryPlan::bytes(entry * blocks / 2)),
        ("unlimited", MemoryPlan::unlimited()),
    ] {
        let plib = Library::host_with_plan(lib.executor().threads(), plan);
        let mut t =
            Trainer::new(plib.clone(), cfg("tiny", OptimizerKind::AdamA, 2, 42)).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 1);
        for _ in 0..2 {
            t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
        }
        let mem = plib.executor().memory().expect("host executor instruments memory");
        let predicted = dims.predicted_stash_peak_bytes(plan, blocks);
        println!(
            "{:<10} {:>14} {:>14} {:>14} {:>6} {:>7}",
            name,
            fmt_bytes(mem.stash_peak_bytes as usize),
            fmt_bytes(predicted as usize),
            fmt_bytes(mem.workspace_peak_bytes as usize),
            mem.stash_hits,
            mem.remats
        );
        assert_eq!(
            mem.stash_peak_bytes, predicted,
            "measured stash peak must equal the analytic prediction"
        );
    }
    println!("(per-block stash entry: {}; blocks: {blocks})", fmt_bytes(entry as usize));
}
