//! Table 2 — memory vs memory-efficient optimizers at BERT-Large, mb 8.
//!
//! Paper: Adam 6.15 GB > SM3 4.90 > Adafactor 4.83 > AdamA 4.18 GB —
//! AdamA wins because it attacks activations+gradients, which dominate
//! the optimizer-state savings of Adafactor/SM3. Three parts: the
//! analytic table at paper scale, measured state/grad bytes from the
//! real optimizer implementations at tiny scale (GA-style comparator
//! metering), and the `ADAMA_OPT` zoo behind the executor seam with its
//! measured `MemStats` state bytes reconciled byte-for-byte against the
//! `memmodel::zoo_state_bytes` analytic formula. The reconciliation rows
//! are appended to `BENCH_perf.json` for the nightly trajectory.

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::{
    optimizer_state_bytes, paper_shapes, peak_memory, zoo_state_bytes, DtypePolicy, PaperModel,
    Scenario, Strategy,
};
use adama::runtime::OptAlgo;
use adama::util::json::{obj, Json};
use adama::util::stats::fmt_bytes;
use adama::{Category, Trainer};

#[path = "support/mod.rs"]
mod support;
use support::{banner, gb, lib_or_exit};

fn main() {
    // shed any ambient ADAMA_OPT: the measured sections pick metering
    // (GA-style vs seam) explicitly per row
    let lib = lib_or_exit().fork_with_opt(None);
    let model = PaperModel::bert_large();
    let d = DtypePolicy::paper_fp32();

    banner("Table 2 (paper scale): BERT-Large @ mini-batch 8 per GPU");
    println!(
        "{:<18} {:<10} {:>14} {:>12}",
        "optimizer", "target", "opt-state", "total (GB)"
    );
    let rows: [(&str, &str, OptimizerKind, Strategy); 5] = [
        ("Adam (baseline)", "N/A", OptimizerKind::AdamGA, Strategy::NoAccum),
        ("Adafactor", "OS", OptimizerKind::Adafactor, Strategy::NoAccum),
        ("SM3", "OS", OptimizerKind::Sm3, Strategy::NoAccum),
        ("Adam-mini", "OS", OptimizerKind::AdamMini, Strategy::NoAccum),
        ("AdamA (N=8)", "A + G", OptimizerKind::AdamA, Strategy::AdamA),
    ];
    let mut totals = Vec::new();
    for (name, target, opt, strategy) in rows {
        let b = peak_memory(&Scenario {
            model: model.clone(),
            dtype: d,
            strategy,
            optimizer: opt,
            minibatch_per_gpu: 8,
            accum_steps: 8,
            gpus: 8,
        });
        println!(
            "{name:<18} {target:<10} {:>14} {:>12.2}",
            fmt_bytes(optimizer_state_bytes(&model, opt, &d) as usize),
            gb(b.total())
        );
        totals.push(b.total());
    }
    assert!(totals[4] < totals[1] && totals[4] < totals[2] && totals[2] < totals[0]);
    println!("(paper: 6.15 / 4.83 / 4.90 / 4.18 GB — same ordering)");

    banner("measured at tiny scale (real optimizer state + grad buffers)");
    println!(
        "{:<12} {:>14} {:>14} {:>14}",
        "optimizer", "opt-state", "grad-persist", "grad-peak"
    );
    for opt in [
        OptimizerKind::AdamGA,
        OptimizerKind::Adafactor,
        OptimizerKind::Sm3,
        OptimizerKind::AdamMini,
        OptimizerKind::AdamA,
    ] {
        let cfg = TrainConfig {
            model: "tiny".into(),
            optimizer: opt,
            backend: OptimBackend::Host,
            accum_steps: 4,
            chunk: 16384,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(lib.clone(), cfg).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 1);
        t.train_step(&c.minibatch(4, h.microbatch, h.seq)).unwrap();
        println!(
            "{:<12} {:>14} {:>14} {:>14}",
            opt.name(),
            fmt_bytes(t.tracker().peak(Category::OptimizerStates)),
            fmt_bytes(t.optimizer_mut().persistent_grad_bytes()),
            fmt_bytes(t.tracker().peak(Category::Gradients)),
        );
    }

    banner("ADAMA_OPT zoo behind the executor seam: measured vs memmodel");
    println!(
        "{:<12} {:>16} {:>16}  {:<10} {:>16}",
        "algo", "measured", "analytic", "reconciled", "paper-scale"
    );
    let psh = paper_shapes(&model);
    let mut zoo_rows: Vec<Json> = Vec::new();
    for algo in OptAlgo::ALL {
        let zlib = lib.fork_with_opt(Some(algo));
        let cfg = TrainConfig {
            model: "tiny".into(),
            backend: OptimBackend::Host,
            accum_steps: 4,
            chunk: 16384,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(zlib, cfg).unwrap();
        let h = t.spec().hyper.clone();
        let shapes: Vec<(u64, u64)> = t
            .spec()
            .layers
            .iter()
            .flat_map(|l| l.params.iter())
            .map(|v| {
                if v.shape.len() == 2 {
                    (v.shape[0] as u64, v.shape[1] as u64)
                } else {
                    (v.elements() as u64, 0)
                }
            })
            .collect();
        let mut c = MarkovCorpus::new(h.vocab, 7, 1);
        t.train_step(&c.minibatch(4, h.microbatch, h.seq)).unwrap();
        // state-resident composition: the accumulator is optimizer state
        // and no persistent gradient memory remains (the paper's trick).
        let measured = t.tracker().peak(Category::OptimizerStates) as u64;
        let analytic = zoo_state_bytes(algo, &shapes, true);
        assert_eq!(
            measured,
            analytic,
            "{}: measured MemStats state bytes must reconcile exactly with memmodel",
            algo.name()
        );
        assert_eq!(t.optimizer_mut().state_bytes() as u64, measured);
        assert_eq!(t.optimizer_mut().persistent_grad_bytes(), 0);
        let paper_bytes = zoo_state_bytes(algo, &psh, true);
        println!(
            "{:<12} {:>16} {:>16}  {:<10} {:>16}",
            algo.name(),
            fmt_bytes(measured as usize),
            fmt_bytes(analytic as usize),
            "exact",
            fmt_bytes(paper_bytes as usize),
        );
        zoo_rows.push(obj(vec![
            ("op", Json::Str(format!("table2_opt_state_{}", algo.name()))),
            ("backend", Json::Str("host".into())),
            ("measured_state_bytes", Json::Num(measured as f64)),
            ("analytic_state_bytes", Json::Num(analytic as f64)),
            ("paper_scale_state_bytes", Json::Num(paper_bytes as f64)),
            ("reconciled", Json::Bool(measured == analytic)),
        ]));
    }

    // Append the reconciliation rows to BENCH_perf.json so the nightly
    // trajectory sees them next to the perf_microbench results; start a
    // fresh report if the microbench has not run in this working dir.
    let path = "BENCH_perf.json";
    let mut report = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(|| obj(vec![("platform", Json::Str("host".into()))]));
    if let Json::Obj(map) = &mut report {
        let results = map
            .entry("results".to_string())
            .or_insert_with(|| Json::Arr(Vec::new()));
        if let Json::Arr(arr) = results {
            arr.retain(|r| {
                r.opt("op")
                    .and_then(|o| o.as_str().ok())
                    .map_or(true, |op| !op.starts_with("table2_opt_state_"))
            });
            arr.extend(zoo_rows);
        }
    }
    match std::fs::write(path, report.to_string_pretty()) {
        Ok(()) => println!("\nappended zoo reconciliation rows to {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
