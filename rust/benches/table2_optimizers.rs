//! Table 2 — memory vs memory-efficient optimizers at BERT-Large, mb 8.
//!
//! Paper: Adam 6.15 GB > SM3 4.90 > Adafactor 4.83 > AdamA 4.18 GB —
//! AdamA wins because it attacks activations+gradients, which dominate
//! the optimizer-state savings of Adafactor/SM3. Two parts: the analytic
//! table at paper scale, and measured state/grad bytes from the real
//! optimizer implementations at tiny scale.

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::{optimizer_state_bytes, peak_memory, DtypePolicy, PaperModel, Scenario, Strategy};
use adama::util::stats::fmt_bytes;
use adama::{Category, Trainer};

#[path = "support/mod.rs"]
mod support;
use support::{banner, gb, lib_or_exit};

fn main() {
    let lib = lib_or_exit();
    let model = PaperModel::bert_large();
    let d = DtypePolicy::paper_fp32();

    banner("Table 2 (paper scale): BERT-Large @ mini-batch 8 per GPU");
    println!(
        "{:<18} {:<10} {:>14} {:>12}",
        "optimizer", "target", "opt-state", "total (GB)"
    );
    let rows: [(&str, &str, OptimizerKind, Strategy); 4] = [
        ("Adam (baseline)", "N/A", OptimizerKind::AdamGA, Strategy::NoAccum),
        ("Adafactor", "OS", OptimizerKind::Adafactor, Strategy::NoAccum),
        ("SM3", "OS", OptimizerKind::Sm3, Strategy::NoAccum),
        ("AdamA (N=8)", "A + G", OptimizerKind::AdamA, Strategy::AdamA),
    ];
    let mut totals = Vec::new();
    for (name, target, opt, strategy) in rows {
        let b = peak_memory(&Scenario {
            model: model.clone(),
            dtype: d,
            strategy,
            optimizer: opt,
            minibatch_per_gpu: 8,
            accum_steps: 8,
            gpus: 8,
        });
        println!(
            "{name:<18} {target:<10} {:>14} {:>12.2}",
            fmt_bytes(optimizer_state_bytes(&model, opt, &d) as usize),
            gb(b.total())
        );
        totals.push(b.total());
    }
    assert!(totals[3] < totals[1] && totals[3] < totals[2] && totals[2] < totals[0]);
    println!("(paper: 6.15 / 4.83 / 4.90 / 4.18 GB — same ordering)");

    banner("measured at tiny scale (real optimizer state + grad buffers)");
    println!(
        "{:<12} {:>14} {:>14} {:>14}",
        "optimizer", "opt-state", "grad-persist", "grad-peak"
    );
    for opt in [
        OptimizerKind::AdamGA,
        OptimizerKind::Adafactor,
        OptimizerKind::Sm3,
        OptimizerKind::AdamA,
    ] {
        let cfg = TrainConfig {
            model: "tiny".into(),
            optimizer: opt,
            backend: OptimBackend::Host,
            accum_steps: 4,
            chunk: 16384,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(lib.clone(), cfg).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 1);
        t.train_step(&c.minibatch(4, h.microbatch, h.seq)).unwrap();
        println!(
            "{:<12} {:>14} {:>14} {:>14}",
            opt.name(),
            fmt_bytes(t.tracker().peak(Category::OptimizerStates)),
            fmt_bytes(t.optimizer_mut().persistent_grad_bytes()),
            fmt_bytes(t.tracker().peak(Category::Gradients)),
        );
    }
}
