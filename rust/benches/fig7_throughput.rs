//! Figure 7 + §4.3 — training throughput, Adam vs AdamA.
//!
//! Paper: (a) single GPU ResNet-50, (b) BERT-Base ×4 GPUs, (c) BERT-Large
//! ×8 GPUs — AdamA within 2% of Adam, gap shrinking as N grows (constant
//! state-sync volume amortised over more micro-batches); ZeRO-S1+AdamA
//! costs ~5% vs ZeRO-S1. Four parts here:
//!
//! 1. measured single-device steps/s on the tiny transformer (Adam vs
//!    AdamA across N);
//! 2. measured multi-worker (M=2) samples/s for the three sync
//!    strategies, plus ZeRO-S1 combos (on the concurrent fabric);
//! 3. measured overlap: the concurrent fabric vs the bit-identical
//!    serial simulator for DP state-sync and the ZeRO-S1+AdamA
//!    release-immediately flow, with and without async issue
//!    (`ADAMA_ASYNC=1` semantics), plus the per-layer AdamA flow
//!    against a post-backward bulk sync at 2 and 4 ranks;
//! 4. α-β projection of (c) at paper scale (BERT-Large, DGX A100).

use std::time::Instant;

use adama::collective::{
    run_data_parallel, run_zero1, ClusterSpec, CollectiveEngine, CommCostModel, DpSpec,
    SyncStrategy, Zero1Spec,
};
use adama::config::OptimizerKind;
use adama::data::MarkovCorpus;
use adama::Trainer;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

fn main() {
    let lib = lib_or_exit();
    let steps = if quick() { 3 } else { 8 };

    banner("Fig 7a (measured, single device): tiny transformer, samples/s");
    println!("{:>3} {:>12} {:>12} {:>8}", "N", "Adam", "AdamA", "AdamA/Adam");
    for n in [2usize, 4, 8] {
        let mut rates = Vec::new();
        for opt in [OptimizerKind::AdamGA, OptimizerKind::AdamA] {
            let mut t = Trainer::new(lib.clone(), cfg("tiny", opt, n, 42)).unwrap();
            let h = t.spec().hyper.clone();
            let mut c = MarkovCorpus::new(h.vocab, 7, 1);
            // warmup
            t.train_step(&c.minibatch(n, h.microbatch, h.seq)).unwrap();
            let t0 = Instant::now();
            let mut samples = 0usize;
            for _ in 0..steps {
                let mbs = c.minibatch(n, h.microbatch, h.seq);
                samples += mbs.iter().map(|m| m.batch).sum::<usize>();
                t.train_step(&mbs).unwrap();
            }
            rates.push(samples as f64 / t0.elapsed().as_secs_f64());
        }
        println!("{n:>3} {:>12.1} {:>12.1} {:>9.3}", rates[0], rates[1], rates[1] / rates[0]);
    }
    println!("(paper: ratio within 0.98; communication-free single device)");

    banner("Fig 7b/c (measured, M=2 workers): sync strategies, samples/s");
    println!("{:<22} {:>3} {:>12} {:>14}", "strategy", "N", "samples/s", "comm bytes/step");
    for (sync, opt) in [
        (SyncStrategy::Gradients, OptimizerKind::AdamGA),
        (SyncStrategy::OptimizerStates, OptimizerKind::AdamA),
        (SyncStrategy::GradPerMicrobatch, OptimizerKind::AdamA),
    ] {
        for n in [2usize, 8] {
            let mut c = cfg("tiny", opt, n, 42);
            c.workers = 2;
            let t0 = Instant::now();
            let r = run_data_parallel(lib.clone(), DpSpec::new(c, sync, steps as u64, 7))
                .unwrap();
            let h = lib.manifest().model_config("tiny").unwrap().model.clone();
            let samples = steps * n * h.microbatch * 2;
            println!(
                "{:<22} {n:>3} {:>12.1} {:>14}",
                sync.name(),
                samples as f64 / t0.elapsed().as_secs_f64(),
                r.comm_bytes / steps as u64
            );
        }
    }

    banner("§4.3 (measured, M=2): ZeRO-S1 vs ZeRO-S1+AdamA");
    for opt in [OptimizerKind::AdamGA, OptimizerKind::AdamA] {
        let mut c = cfg("tiny", opt, 4, 42);
        c.workers = 2;
        let t0 = Instant::now();
        let r = run_zero1(lib.clone(), Zero1Spec::new(c, steps as u64, 7)).unwrap();
        let h = lib.manifest().model_config("tiny").unwrap().model.clone();
        let samples = steps * 4 * h.microbatch * 2;
        println!(
            "ZeRO-S1+{:<7} {:>10.1} samples/s, {:>12} comm bytes/step",
            opt.name(),
            samples as f64 / t0.elapsed().as_secs_f64(),
            r.comm_bytes / steps as u64
        );
    }

    banner("Fig 7 overlap (measured, M=2): concurrent fabric vs serial simulator");
    // The systems half of the paper's Fig-7 claim: gradients fold into
    // optimizer states per micro-batch and are released immediately, so
    // the reduce can proceed while other ranks are still in backward.
    // Engines are bit-identical (rust/tests/fabric_parity.rs), so the
    // ratio isolates concurrent scheduling from numerics.
    println!("{:<26} {:>12} {:>12} {:>8}", "flow", "serial s/s", "fabric s/s", "ratio");
    {
        let h = lib.manifest().model_config("tiny").unwrap().model.clone();
        let mut c = cfg("tiny", OptimizerKind::AdamA, 4, 42);
        c.workers = 2;
        let samples = (steps * 4 * h.microbatch * 2) as f64;
        let mut dp_rates = Vec::new();
        for engine in [CollectiveEngine::Serial, CollectiveEngine::Fabric] {
            let t0 = Instant::now();
            run_data_parallel(
                lib.clone(),
                DpSpec::new(c.clone(), SyncStrategy::OptimizerStates, steps as u64, 7)
                    .with_engine(engine),
            )
            .unwrap();
            dp_rates.push(samples / t0.elapsed().as_secs_f64());
        }
        println!(
            "{:<26} {:>12.1} {:>12.1} {:>8.2}",
            "DP state-allreduce",
            dp_rates[0],
            dp_rates[1],
            dp_rates[1] / dp_rates[0]
        );
        let mut z_rates = Vec::new();
        for engine in [CollectiveEngine::Serial, CollectiveEngine::Fabric] {
            let t0 = Instant::now();
            run_zero1(
                lib.clone(),
                Zero1Spec::new(c.clone(), steps as u64, 7).with_engine(engine),
            )
            .unwrap();
            z_rates.push(samples / t0.elapsed().as_secs_f64());
        }
        println!(
            "{:<26} {:>12.1} {:>12.1} {:>8.2}",
            "ZeRO-S1+AdamA overlap",
            z_rates[0],
            z_rates[1],
            z_rates[1] / z_rates[0]
        );
        // same flow with async issue: each per-layer reduce-scatter is
        // handed to the comm thread (ADAMA_ASYNC=1 semantics), so layer
        // k's wire time hides under layer k-1's backward. The serial
        // engine's blocking shim makes its column a sync baseline.
        let mut za_rates = Vec::new();
        for engine in [CollectiveEngine::Serial, CollectiveEngine::Fabric] {
            let t0 = Instant::now();
            run_zero1(
                lib.clone(),
                Zero1Spec::new(c.clone(), steps as u64, 7)
                    .with_engine(engine)
                    .with_async(true),
            )
            .unwrap();
            za_rates.push(samples / t0.elapsed().as_secs_f64());
        }
        println!(
            "{:<26} {:>12.1} {:>12.1} {:>8.2}",
            "ZeRO-S1+AdamA async issue",
            za_rates[0],
            za_rates[1],
            za_rates[1] / za_rates[0]
        );
        println!("(per-layer reduce-scatter issued inside backward as each gradient is produced)");
    }

    banner("Fig 7 overlap (measured): per-layer AdamA flow vs post-backward all-reduce");
    // The paper's scheduling claim head-on: AdamA's per-layer
    // release-immediately reductions (async issue) against the classic
    // post-backward bulk sync (ZeRO-S1+AdamGA reduces every gradient
    // after backward finishes) — same model, same fabric, wall-clock.
    println!(
        "{:<6} {:>16} {:>16} {:>8}",
        "ranks", "post-bwd s/s", "per-layer s/s", "ratio"
    );
    {
        let h = lib.manifest().model_config("tiny").unwrap().model.clone();
        for m in [2usize, 4] {
            let samples = (steps * 4 * h.microbatch * m) as f64;
            let rate = |opt: OptimizerKind, async_issue: bool| {
                let mut c = cfg("tiny", opt, 4, 42);
                c.workers = m;
                let t0 = Instant::now();
                run_zero1(
                    lib.clone(),
                    Zero1Spec::new(c, steps as u64, 7)
                        .with_engine(CollectiveEngine::Fabric)
                        .with_async(async_issue),
                )
                .unwrap();
                samples / t0.elapsed().as_secs_f64()
            };
            let post_bwd = rate(OptimizerKind::AdamGA, false);
            let per_layer = rate(OptimizerKind::AdamA, true);
            println!(
                "{m:<6} {post_bwd:>16.1} {per_layer:>16.1} {:>8.2}",
                per_layer / post_bwd
            );
        }
        println!("(>1.00: backward compute hides the per-layer wire time the bulk sync exposes)");
    }

    banner("Fig 7c (α-β projection): BERT-Large on DGX A100, samples/s ratio");
    let m = CommCostModel::new(ClusterSpec::dgx_a100());
    let p = 340_000_000u64;
    let tokens_per_mb = 1024 * 128 / 8; // paper: micro-batch 1024 seqs / 8 GPUs... per-GPU rows*seq
    println!("{:>3} {:>10} {:>10} {:>8}", "N", "Adam s/s", "AdamA s/s", "ratio");
    for n in [2usize, 4, 8, 16] {
        let adam = m.step_time(p, n, tokens_per_mb as u64, 4 * p, 1);
        let adama = m.step_time(p, n, tokens_per_mb as u64, 8 * p, 1);
        let s_adam = (n * 128) as f64 / adam;
        let s_adama = (n * 128) as f64 / adama;
        println!("{n:>3} {s_adam:>10.1} {s_adama:>10.1} {:>8.4}", s_adama / s_adam);
    }
    println!("(paper: ≥0.98 everywhere, gap shrinking with N)");
}
