//! Shared bench-harness support (criterion is unavailable offline; each
//! bench is a `harness = false` binary that regenerates one paper
//! table/figure and prints it).

use std::sync::Arc;

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::runtime::ArtifactLibrary;
use adama::util::cliargs::Args;

/// Open artifacts or exit 0 with a notice (benches must not fail the
/// pipeline when `make artifacts` hasn't run).
pub fn lib_or_exit() -> Arc<ArtifactLibrary> {
    let root = ArtifactLibrary::default_root();
    if !root.join("manifest.json").exists() {
        println!("SKIP: no artifacts at {} (run `make artifacts`)", root.display());
        std::process::exit(0);
    }
    ArtifactLibrary::open_default().expect("opening artifacts")
}

/// `--quick` trims workloads for CI-style runs.
pub fn quick() -> bool {
    Args::parse_env().flag("quick")
}

pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

pub fn cfg(model: &str, opt: OptimizerKind, n: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        optimizer: opt,
        backend: OptimBackend::Kernel,
        accum_steps: n,
        chunk: 16384,
        seed,
        ..TrainConfig::default()
    }
}

pub fn gb(bytes: u64) -> f64 {
    bytes as f64 / 1e9
}
