//! Shared bench-harness support (criterion is unavailable offline; each
//! bench is a `harness = false` binary that regenerates one paper
//! table/figure).
#![allow(dead_code)] // each bench binary uses a different subset

use std::sync::Arc;

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::runtime::Library;
use adama::util::cliargs::Args;

/// Open the default execution library. The host executor guarantees a
/// backend on a clean machine; with the `pjrt` feature + artifacts the
/// benches measure the PJRT path instead.
pub fn lib_or_exit() -> Arc<Library> {
    Library::open_default().expect("opening execution library")
}

/// `--quick` trims workloads for CI-style runs.
pub fn quick() -> bool {
    Args::parse_env().flag("quick")
}

pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

pub fn cfg(model: &str, opt: OptimizerKind, n: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        optimizer: opt,
        backend: OptimBackend::Kernel,
        accum_steps: n,
        chunk: 16384,
        seed,
        ..TrainConfig::default()
    }
}

pub fn gb(bytes: u64) -> f64 {
    bytes as f64 / 1e9
}
