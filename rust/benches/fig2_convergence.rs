//! Figure 2 — sample-wise convergence: Adam vs AdamA, N ∈ {2, 4, 8}.
//!
//! Paper: BERT-Large pretraining loss curves coincide for Adam and AdamA
//! at every accumulation step count. Here: the `tiny` transformer on the
//! Markov corpus; for each N both optimizers consume *identical* data and
//! the curves must track each other closely (and both must descend).
//!
//! Output: CSV series `N,step,adam_loss,adama_loss` + summary rows.
//! A second sweep drives every `ADAMA_OPT` zoo rule through the same
//! protocol: all rules must descend, and the seam-built `adam` rule must
//! reproduce the config-built Adam+GA curve bit-for-bit (the dual
//! metering changes bookkeeping, never math).

use adama::config::OptimizerKind;
use adama::data::MarkovCorpus;
use adama::runtime::OptAlgo;
use adama::Trainer;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

fn main() {
    // shed any ambient ADAMA_OPT so the comparator sections stay
    // config-built; the zoo sweep re-selects rules explicitly
    let lib = lib_or_exit().fork_with_opt(None);
    let steps = if quick() { 10 } else { 40 };
    banner("Figure 2: convergence parity, Adam vs AdamA (tiny/Markov)");
    println!("N,step,adam_loss,adama_loss");

    let mut summary = Vec::new();
    for n in [2usize, 4, 8] {
        let mut adam = Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamGA, n, 42))
            .expect("adam trainer");
        let mut adama = Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamA, n, 42))
            .expect("adama trainer");
        let h = adam.spec().hyper.clone();
        let mut c1 = MarkovCorpus::new(h.vocab, 7, 1000 + n as u64);
        let mut c2 = MarkovCorpus::new(h.vocab, 7, 1000 + n as u64);

        let mut max_gap = 0.0f32;
        let (mut first, mut last) = (0.0f32, 0.0f32);
        for s in 0..steps {
            let a = adam.train_step(&c1.minibatch(n, h.microbatch, h.seq)).unwrap();
            let b = adama.train_step(&c2.minibatch(n, h.microbatch, h.seq)).unwrap();
            println!("{n},{},{:.4},{:.4}", s + 1, a.loss, b.loss);
            max_gap = max_gap.max((a.loss - b.loss).abs());
            if s == 0 {
                first = b.loss;
            }
            last = b.loss;
        }
        summary.push((n, first, last, max_gap));
    }

    banner("summary (paper: curves coincide for all N)");
    println!("{:>3} {:>11} {:>11} {:>16}", "N", "first_loss", "last_loss", "max|Adam-AdamA|");
    for (n, first, last, gap) in summary {
        println!("{n:>3} {first:>11.4} {last:>11.4} {gap:>16.4}");
        assert!(last < first, "loss must descend");
    }

    banner("ADAMA_OPT zoo sweep: every rule, identical data, N=4");
    println!("algo,step,loss");
    let n = 4usize;
    // reference: the config-built Adam+GA comparator on the same stream
    let mut ga = Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamGA, n, 42)).unwrap();
    let h = ga.spec().hyper.clone();
    let mut cga = MarkovCorpus::new(h.vocab, 7, 2000);
    let ga_losses: Vec<f32> = (0..steps)
        .map(|_| ga.train_step(&cga.minibatch(n, h.microbatch, h.seq)).unwrap().loss)
        .collect();
    for algo in OptAlgo::ALL {
        let zlib = lib.fork_with_opt(Some(algo));
        let mut t =
            Trainer::new(zlib, cfg("tiny", OptimizerKind::AdamA, n, 42)).expect("zoo trainer");
        let mut c = MarkovCorpus::new(h.vocab, 7, 2000);
        let mut losses = Vec::new();
        for s in 0..steps {
            let st = t.train_step(&c.minibatch(n, h.microbatch, h.seq)).unwrap();
            println!("{},{},{:.4}", algo.name(), s + 1, st.loss);
            losses.push(st.loss);
        }
        assert!(
            losses[steps - 1] < losses[0],
            "{}: loss must descend ({} !< {})",
            algo.name(),
            losses[steps - 1],
            losses[0]
        );
        if algo == OptAlgo::Adam {
            // seam metering vs GA metering: identical bits
            let same = losses
                .iter()
                .zip(&ga_losses)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "seam adam must reproduce Adam+GA bit-for-bit");
        }
    }
    println!("(all rules descend; seam adam == Adam+GA bitwise)");
}
