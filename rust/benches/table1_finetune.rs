//! Table 1 — downstream fine-tuning parity.
//!
//! Paper: BERT-Large checkpoints pretrained with Adam vs AdamA (N=2,4,8)
//! fine-tune to the same GLUE scores. Substitute: pretrain the tiny LM on
//! the Markov corpus with each optimizer, then fine-tune each checkpoint
//! on a suite of synthetic downstream "tasks" (CycleCorpus languages with
//! different strides) and report final eval loss / next-token accuracy.

use adama::config::OptimizerKind;
use adama::data::{CycleCorpus, MarkovCorpus};
use adama::runtime::OptAlgo;
use adama::Trainer;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

const TASKS: [(&str, usize); 4] = [("cycle3", 3), ("cycle7", 7), ("cycle11", 11), ("cycle29", 29)];

fn main() {
    let lib = lib_or_exit().fork_with_opt(None);
    let (pre_steps, ft_steps) = if quick() { (8, 5) } else { (30, 15) };

    // ---- pretrain checkpoints ----
    let settings: Vec<(String, OptimizerKind, usize)> = vec![
        ("Adam".into(), OptimizerKind::AdamGA, 4),
        ("AdamA(N=2)".into(), OptimizerKind::AdamA, 2),
        ("AdamA(N=4)".into(), OptimizerKind::AdamA, 4),
        ("AdamA(N=8)".into(), OptimizerKind::AdamA, 8),
    ];
    let dir = std::env::temp_dir().join("adama_table1");
    std::fs::create_dir_all(&dir).unwrap();

    banner("Table 1: pretrain -> fine-tune parity (tiny LM)");
    let mut checkpoints = Vec::new();
    for (name, opt, n) in &settings {
        let mut t = Trainer::new(lib.clone(), cfg("tiny", *opt, *n, 42)).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 11);
        for _ in 0..pre_steps {
            t.train_step(&c.minibatch(*n, h.microbatch, h.seq)).unwrap();
        }
        let path = dir.join(format!("{name}.ck"));
        t.save_checkpoint(&path).unwrap();
        println!("pretrained {name:<12} final loss {:.4}", t.metrics().last_loss().unwrap());
        checkpoints.push((name.clone(), path));
    }

    // ---- fine-tune on each task ----
    let mut header = format!("{:<12}", "setting");
    for (task, _) in TASKS {
        header += &format!(" {:>8}-l {:>8}-a", task, task);
    }
    banner("fine-tuning results (loss / accuracy per task)");
    println!("{header}");
    let mut acc_matrix: Vec<Vec<f32>> = Vec::new();
    for (name, path) in &checkpoints {
        let mut row = format!("{name:<12}");
        let mut accs = Vec::new();
        for (_, stride) in TASKS {
            let mut t = Trainer::new(
                lib.clone(),
                cfg("tiny", OptimizerKind::AdamA, 2, 42),
            )
            .unwrap();
            t.load_checkpoint(path).unwrap();
            let h = t.spec().hyper.clone();
            let mut c = CycleCorpus::new(h.vocab, stride, 17);
            for _ in 0..ft_steps {
                t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
            }
            let mut heldout = CycleCorpus::new(h.vocab, stride, 9999);
            let eval = heldout.minibatch(4, h.microbatch, h.seq);
            let (loss, acc) = t.eval(&eval).unwrap();
            row += &format!(" {loss:>10.3} {acc:>10.3}");
            accs.push(acc);
        }
        println!("{row}");
        acc_matrix.push(accs);
    }

    // parity check: per task, Adam vs every AdamA within a few points
    for (ti, (task, _)) in TASKS.iter().enumerate() {
        let adam_acc = acc_matrix[0][ti];
        for (si, row) in acc_matrix.iter().enumerate().skip(1) {
            let gap = (row[ti] - adam_acc).abs();
            assert!(
                gap < 0.12,
                "{task}: {} acc {} vs Adam {adam_acc} (gap {gap})",
                settings[si].0,
                row[ti]
            );
        }
    }
    println!("\nparity holds: AdamA checkpoints fine-tune like Adam's (paper Table 1)");

    // ---- ADAMA_OPT zoo rows: pretrain with each rule, same protocol ----
    banner("zoo checkpoints: pretrain per ADAMA_OPT rule, fine-tune with AdamA");
    println!("{header}");
    for algo in OptAlgo::ALL {
        let zlib = lib.fork_with_opt(Some(algo));
        let mut t = Trainer::new(zlib, cfg("tiny", OptimizerKind::AdamA, 4, 42)).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 11);
        for _ in 0..pre_steps {
            t.train_step(&c.minibatch(4, h.microbatch, h.seq)).unwrap();
        }
        let path = dir.join(format!("zoo_{}.ck", algo.name()));
        t.save_checkpoint(&path).unwrap();

        let mut row = format!("{:<12}", algo.name());
        for (task, stride) in TASKS {
            let mut ft =
                Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamA, 2, 42)).unwrap();
            ft.load_checkpoint(&path).unwrap();
            let mut tc = CycleCorpus::new(h.vocab, stride, 17);
            let mut heldout = CycleCorpus::new(h.vocab, stride, 9999);
            let eval = heldout.minibatch(4, h.microbatch, h.seq);
            let (loss0, _) = ft.eval(&eval).unwrap();
            for _ in 0..ft_steps {
                ft.train_step(&tc.minibatch(2, h.microbatch, h.seq)).unwrap();
            }
            let (loss, acc) = ft.eval(&eval).unwrap();
            row += &format!(" {loss:>10.3} {acc:>10.3}");
            // every zoo checkpoint must remain a usable starting point
            assert!(
                loss < loss0,
                "{task}: fine-tuning from the {} checkpoint must reduce eval loss \
                 ({loss} !< {loss0})",
                algo.name()
            );
        }
        println!("{row}");
    }
    println!("(every zoo rule's checkpoint fine-tunes; protocol as Table 1)");
}
