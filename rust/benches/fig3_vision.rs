//! Figure 3 — convergence parity on a non-transformer model.
//!
//! Paper: ResNet-50 on ImageNet, Adam vs AdamA training loss + test
//! accuracy coincide. Substitute (DESIGN.md §Substitutions): MLP
//! classifier on Gaussian blobs via the `mlp_*` artifacts — the claim
//! under test is "parity holds off-transformer", which any second
//! architecture/task exercises.

use adama::config::OptimizerKind;
use adama::coordinator::MlpTrainer;
use adama::data::BlobData;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

fn main() {
    let lib = lib_or_exit();
    let steps = if quick() { 10 } else { 60 };
    let n = 8usize;

    banner("Figure 3: MLP/blobs (vision substitute), Adam vs AdamA (N=8)");

    let mut adam = MlpTrainer::new(lib.clone(), cfg("small", OptimizerKind::AdamGA, n, 3)).unwrap();
    let mut adama = MlpTrainer::new(lib.clone(), cfg("small", OptimizerKind::AdamA, n, 3)).unwrap();
    let h = adam.hyper.clone();

    // noisy regime: per-sample gradient noise dominates the mini-batch mean,
    // which is where the paper's Adam/AdamA parity lives (see Fig. 4).
    let mut d1 = BlobData::with_noise(h.features, h.classes, 5, 100, 2.5);
    let mut d2 = BlobData::with_noise(h.features, h.classes, 5, 100, 2.5);
    let mut heldout = BlobData::with_noise(h.features, h.classes, 5, 999, 2.5);
    let eval_set: Vec<_> = (0..16).map(|_| heldout.batch(h.microbatch)).collect();

    println!("step,adam_loss,adama_loss");
    let (mut l_adam, mut l_adama) = (0.0f32, 0.0f32);
    for s in 1..=steps {
        let b1: Vec<_> = (0..n).map(|_| d1.batch(h.microbatch)).collect();
        let b2: Vec<_> = (0..n).map(|_| d2.batch(h.microbatch)).collect();
        l_adam = adam.train_step(&b1).unwrap();
        l_adama = adama.train_step(&b2).unwrap();
        if s % 5 == 0 || s == 1 {
            println!("{s},{l_adam:.4},{l_adama:.4}");
        }
    }

    let (el_a, acc_a) = adam.eval(&eval_set).unwrap();
    let (el_b, acc_b) = adama.eval(&eval_set).unwrap();
    banner("final (paper: ResNet-50 75.43% vs 75.39% — parity)");
    println!("{:<8} {:>11} {:>10} {:>9}", "optim", "train_loss", "eval_loss", "eval_acc");
    println!("{:<8} {l_adam:>11.4} {el_a:>10.4} {acc_a:>9.3}", "Adam");
    println!("{:<8} {l_adama:>11.4} {el_b:>10.4} {acc_b:>9.3}", "AdamA");
    assert!((acc_a - acc_b).abs() < 0.08, "accuracy parity violated");
    assert!(acc_a > 0.4 && acc_b > 0.4, "both must learn the task");
}
