//! Table 3 — largest trainable model per DGX system.
//!
//! Paper: with mini-batch 256, N=8, 8 GPUs — AdamA fits 1.26–1.33×
//! larger models than GA under PyTorch, and ZeRO-S1+AdamA fits ~3×
//! larger than ZeRO-S1 alone (18.2B on DGX A100). Binary search over
//! GPT-3-scaled models against each system's per-GPU capacity.

use adama::collective::ClusterSpec;
use adama::memmodel::{max_model_params, DtypePolicy, Strategy};

#[path = "support/mod.rs"]
mod support;
use support::{banner, lib_or_exit};

fn b(params: u64) -> String {
    format!("{:.1}B", params as f64 / 1e9)
}

fn main() {
    let _lib = lib_or_exit();
    let d = DtypePolicy::paper_fp32();
    // paper setting: global mini-batch 256 on 8 GPUs => 32 rows/GPU, N=8
    let (mb, n, gpus) = (32u64, 8u64, 8u64);

    banner("Table 3: largest model per system (mini-batch 256, N=8, 8 GPUs)");
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>14} {:>9} {:>9}",
        "system", "GA", "AdamA", "ratio", "ZeRO-S1(+GA)", "Z1+AdamA", "ratio"
    );
    for spec in [ClusterSpec::dgx1(), ClusterSpec::dgx2(), ClusterSpec::dgx_a100()] {
        let cap = spec.mem_bytes;
        let ga = max_model_params(cap, Strategy::GradAccum, d, mb, n, gpus);
        let aa = max_model_params(cap, Strategy::AdamA, d, mb, n, gpus);
        // paper's ZeRO-S1 baseline runs DeepSpeed default (no micro-batching)
        let z1 = max_model_params(cap, Strategy::Zero1, d, mb, n, gpus);
        let z1aa = max_model_params(cap, Strategy::Zero1AdamA, d, mb, n, gpus);
        let r1 = aa as f64 / ga as f64;
        let r2 = z1aa as f64 / z1 as f64;
        println!(
            "{:<10} {:>8} {:>8} {:>7.2}x {:>14} {:>9} {:>8.2}x",
            spec.name,
            b(ga),
            b(aa),
            r1,
            b(z1),
            b(z1aa),
            r2
        );
        assert!(r1 > 1.1, "AdamA must fit larger models than GA");
        assert!(r2 > 1.8, "combined scheme must fit much larger models");
    }
    println!("(paper: DGX-1 1.4→1.8B / 1.1→3.3B; DGX-2 3.0→4.0B / 2.5→6.8B;");
    println!("        DGX A100 7.6→9.6B / 5.8→18.2B)");
}
